"""Core types of ``repro-lint``: findings, source files, pass registry.

The analyzer is **zero-dependency** (stdlib ``ast`` only) so it can run
in the docs/lint CI jobs without installing the package, and fast enough
to run on every commit.  Design:

  * a **pass** inspects parsed source and yields :class:`Finding`s; local
    passes (``cacheable=True``) see one file at a time and their results
    are cached per file content hash, repo-level passes (jit discipline
    needs a cross-file call graph, the surface passes walk docs/) run
    every time;
  * an inline ``# lint: disable=<rule>[,<rule>...]`` comment on the
    flagged line suppresses findings of those rules (``all`` wildcard);
  * a committed JSON **baseline** (``tools/lint/baseline.json``)
    grandfathers known findings by ``(rule, path, message)`` fingerprint
    so the gate can be adopted on an imperfect tree without hiding new
    violations.

Rule catalogue with the invariant each protects: ``docs/LINTS.md``.
"""
from __future__ import annotations

import ast
import dataclasses
import re

# bump when a pass's semantics change: invalidates every cache entry
LINT_VERSION = "1"

_SUPPRESS = re.compile(r"#\s*lint:\s*disable=([A-Za-z0-9_,\- ]+)")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at a source location.

    ``fingerprint()`` deliberately excludes the line number: baselined
    findings survive unrelated edits that shift lines, and a *new*
    duplicate of a grandfathered message still surfaces (the baseline is
    a multiset, consumed one match per occurrence)."""
    rule: str
    path: str           # repo-relative, posix separators
    line: int
    col: int
    message: str
    baselined: bool = False

    def fingerprint(self) -> tuple:
        return (self.rule, self.path, self.message)

    def format(self) -> str:
        mark = "  [baselined]" if self.baselined else ""
        return f"{self.path}:{self.line}:{self.col}: {self.rule}: " \
               f"{self.message}{mark}"

    def to_json(self) -> dict:
        return {"rule": self.rule, "path": self.path, "line": self.line,
                "col": self.col, "message": self.message,
                "baselined": self.baselined}

    @classmethod
    def from_json(cls, d: dict) -> "Finding":
        return cls(rule=d["rule"], path=d["path"], line=d["line"],
                   col=d["col"], message=d["message"],
                   baselined=d.get("baselined", False))


class SourceFile:
    """A parsed source file plus its suppression map."""

    def __init__(self, rel: str, abspath: str, text: str):
        self.rel = rel
        self.abspath = abspath
        self.text = text
        self.tree = ast.parse(text, filename=abspath)
        # line number -> set of rule names disabled on that line
        self.suppressions: dict[int, set[str]] = {}
        for i, line in enumerate(text.splitlines(), start=1):
            m = _SUPPRESS.search(line)
            if m:
                rules = {r.strip() for r in m.group(1).split(",")
                         if r.strip()}
                self.suppressions[i] = rules

    def suppressed(self, finding: Finding) -> bool:
        rules = self.suppressions.get(finding.line)
        return bool(rules) and (finding.rule in rules or "all" in rules)


class LintContext:
    """Everything a pass may look at: the repo root and the parsed files."""

    def __init__(self, root: str, files: dict[str, SourceFile]):
        self.root = root
        self.files = files          # rel path -> SourceFile, sorted


class LintPass:
    """Base class.  Subclasses set ``name`` (pass id), ``rules`` (the
    rule ids it can emit — used by ``--select``/``--skip`` and the
    docs), and either override :meth:`check_file` (local pass, cacheable
    per file) or :meth:`run` (repo-level pass, ``cacheable = False``)."""

    name: str = ""
    rules: tuple = ()
    cacheable: bool = True

    def check_file(self, sf: SourceFile, ctx: LintContext) -> list:
        return []

    def run(self, ctx: LintContext) -> list:
        out = []
        for sf in ctx.files.values():
            out.extend(self.check_file(sf, ctx))
        return out


PASSES: dict[str, LintPass] = {}


def register(cls):
    """Class decorator adding a pass to the global registry."""
    inst = cls()
    assert inst.name and inst.name not in PASSES, inst.name
    PASSES[inst.name] = inst
    return cls


# -- shared AST helpers ------------------------------------------------------

def attr_chain(node) -> str | None:
    """Dotted-name string of a Name/Attribute chain (``jax.random.split``
    -> "jax.random.split"), or None for anything more dynamic."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def chain_base(chain: str | None) -> str | None:
    """Last component of a dotted chain ("jax.jit" -> "jit")."""
    return chain.rsplit(".", 1)[-1] if chain else None


def chain_root(chain: str | None) -> str | None:
    """First component of a dotted chain ("jax.jit" -> "jax")."""
    return chain.split(".", 1)[0] if chain else None


def build_parents(tree) -> dict:
    """Child node -> parent node for the whole tree."""
    parents = {}
    for node in ast.walk(tree):
        for child in ast.iter_child_nodes(node):
            parents[child] = node
    return parents


def enclosing_functions(node, parents) -> list:
    """Innermost-first chain of enclosing Function/AsyncFunction/Lambda
    nodes.  A decorator expression is *not* inside the function it
    decorates (it evaluates in the enclosing scope)."""
    out = []
    cur, prev = parents.get(node), node
    while cur is not None:
        if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef,
                            ast.Lambda)):
            in_decorator = (not isinstance(cur, ast.Lambda)
                            and any(prev is d or _contains(d, prev)
                                    for d in cur.decorator_list))
            if not in_decorator:
                out.append(cur)
        prev, cur = cur, parents.get(cur)
    return out


def _contains(tree, node) -> bool:
    return any(n is node for n in ast.walk(tree))


def calls_in(node) -> set:
    """Basenames of every function called anywhere under ``node``."""
    out = set()
    for n in ast.walk(node):
        if isinstance(n, ast.Call):
            base = chain_base(attr_chain(n.func))
            if base:
                out.add(base)
    return out


def contains_call_rooted(node, roots: tuple) -> bool:
    """Whether any Call under ``node`` has a func chain rooted at one of
    ``roots`` (e.g. ("jax", "jnp"))."""
    for n in ast.walk(node):
        if isinstance(n, ast.Call):
            r = chain_root(attr_chain(n.func))
            if r in roots:
                return True
    return False
