"""prng discipline: schedule-invariant sampling under ``serving/``.

The serving layer's bit-identity guarantee (PRs 3/6/9: same tokens
regardless of batch composition, chunk schedule, or speculation window)
holds because every random draw is keyed **only** by
``(rng_seed, request_id, position)`` through the registered helpers —
``sampler.request_key`` / ``sampler.root_key`` and the spec-decode
``accept_key`` / ``residual_key`` wrappers.  A raw ``PRNGKey`` /
``split`` / ``fold_in`` anywhere else introduces key state that depends
on *when* the draw happens, which is exactly what breaks schedule
invariance.

``prng-raw-key``
    Direct ``jax.random.PRNGKey`` / ``split`` / ``fold_in`` under
    ``serving/`` outside the registered helper definitions.

``prng-unkeyed-draw``
    A ``jax.random.<draw>(...)`` whose key argument is built by a call
    that is not one of the registered helpers (a key passed in as a
    plain variable is trusted — its construction site is checked by
    ``prng-raw-key``).
"""
from __future__ import annotations

import ast

from tools.lint.core import (
    Finding, LintPass, attr_chain, build_parents, chain_base,
    enclosing_functions, register,
)

# the registered key-derivation helpers and the only files allowed to
# define them with raw jax.random primitives
KEY_HELPERS = {"request_key", "root_key", "accept_key", "residual_key"}
HELPER_FILES = {"sampler.py", "spec.py"}

_RAW = {"jax.random.PRNGKey", "jax.random.split", "jax.random.fold_in"}
_DRAWS = {"uniform", "normal", "categorical", "bernoulli", "gumbel",
          "randint", "truncated_normal", "exponential", "choice",
          "permutation"}


def _in_scope(rel: str) -> bool:
    return "serving" in rel.replace("\\", "/").split("/")


@register
class PrngDisciplinePass(LintPass):
    name = "prng-discipline"
    rules = ("prng-raw-key", "prng-unkeyed-draw")

    def check_file(self, sf, ctx):
        if not _in_scope(sf.rel):
            return []
        parents = build_parents(sf.tree)
        basename = sf.rel.rsplit("/", 1)[-1]
        is_helper_file = basename in HELPER_FILES
        out = []
        for node in ast.walk(sf.tree):
            if not isinstance(node, ast.Call):
                continue
            chain = attr_chain(node.func)
            if chain in _RAW:
                fns = enclosing_functions(node, parents)
                names = {getattr(f, "name", None) for f in fns}
                if is_helper_file and names & KEY_HELPERS:
                    continue    # the registered derivation sites
                out.append(Finding(
                    rule="prng-raw-key", path=sf.rel,
                    line=node.lineno, col=node.col_offset,
                    message=f"direct `{chain}` in serving code; derive"
                            f" keys via sampler.request_key/root_key (or"
                            f" spec accept_key/residual_key) so sampling"
                            f" stays schedule-invariant"))
            elif (chain and chain.startswith("jax.random.")
                    and chain_base(chain) in _DRAWS and node.args):
                key = node.args[0]
                if isinstance(key, ast.Call):
                    kbase = chain_base(attr_chain(key.func))
                    if kbase not in KEY_HELPERS:
                        out.append(Finding(
                            rule="prng-unkeyed-draw", path=sf.rel,
                            line=node.lineno, col=node.col_offset,
                            message=f"`{chain}` draw keyed by"
                                    f" `{kbase}(...)`, not a registered"
                                    f" request_key/accept_key helper"))
        return out
