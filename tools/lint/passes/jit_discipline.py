"""jit discipline: stable compile counts and no host syncs in traced code.

Three rules, all driven by one repo-wide sweep (the pass needs a
cross-file call graph, so it is not per-file cacheable):

``jit-cache-discipline``
    ``jax.jit`` / ``pjit`` / ``shard_map`` call sites must be module
    level, or live inside a function that stores the result into a
    module-level cache dict (the ``_STEP_CACHE`` pattern in
    ``serving/engine.py``), or be part of an AOT ``.lower(...)`` chain,
    or sit inside a function that is itself jit-traced (``shard_map``
    inside a jitted model function re-traces with its parent and adds
    no extra compile).  Anything else creates a fresh compiled program
    per call and silently breaks the compile-count gates.

``jit-host-sync``
    Inside a jit-traced body (transitive call-graph closure from every
    jit root), flag ``.item()``, ``float()``/``int()``/``bool()`` over a
    jax/jnp-derived value, and ``np.*`` calls fed a jax/jnp-derived
    value.  These force a device sync mid-trace (or fail under jit).
    Static shape/config math (``np.prod(mesh.shape...)``) is not
    jax-derived and is not flagged.

``eager-loop-sync``
    In host-side serving code (``src/repro/serving/``), a
    ``float()``/``int()``/``np.asarray()`` wrapped around a fresh
    jax/jnp call *inside a loop body* dispatches one device program and
    one blocking transfer per iteration — the spec-decode verify bug
    this PR fixes.  Hoist to one batched draw before the loop.
"""
from __future__ import annotations

import ast

from tools.lint.core import (
    Finding, LintPass, attr_chain, build_parents, calls_in, chain_base,
    chain_root, enclosing_functions, register,
)

_JIT_BASES = {"jit", "pjit", "shard_map"}
_JAX_ROOTS = ("jax", "jnp")
_NP_ROOTS = ("np", "numpy", "onp")
_COERCE = {"float", "int", "bool"}


def _is_jit_maker(call: ast.Call) -> str | None:
    """Return the jit-maker kind ("jit"/"pjit"/"shard_map") if ``call``
    constructs a compiled program, else None."""
    chain = attr_chain(call.func)
    base = chain_base(chain)
    if base not in _JIT_BASES:
        return None
    root = chain_root(chain)
    if base == "jit" and root not in ("jax",):
        return None            # someone's unrelated .jit attribute
    return base


def _jit_decorator_target(dec) -> bool:
    """True if ``dec`` is ``@jax.jit``/``@pjit``/``@shard_map`` or a
    ``@partial(jax.jit, ...)`` wrapping of one."""
    if isinstance(dec, ast.Call):
        base = chain_base(attr_chain(dec.func))
        if base in _JIT_BASES:
            return True
        if base == "partial" and dec.args:
            return chain_base(attr_chain(dec.args[0])) in _JIT_BASES
        return False
    return chain_base(attr_chain(dec)) in _JIT_BASES


def _module_cache_dicts(tree) -> set:
    """Names of module-level dict-valued assignments (jit cache stores)."""
    out = set()
    for node in tree.body:
        targets = []
        if isinstance(node, ast.Assign):
            targets, value = node.targets, node.value
        elif isinstance(node, ast.AnnAssign) and node.value is not None:
            targets, value = [node.target], node.value
        else:
            continue
        is_dict = isinstance(value, ast.Dict) or (
            isinstance(value, ast.Call)
            and chain_base(attr_chain(value.func)) == "dict")
        if is_dict:
            out.update(t.id for t in targets if isinstance(t, ast.Name))
    return out


def _stores_into(fn, cache_names: set) -> bool:
    """Whether ``fn``'s body assigns into one of ``cache_names`` via a
    subscript (``_CACHE[key] = ...``) or ``.setdefault`` call."""
    for n in ast.walk(fn):
        if isinstance(n, (ast.Assign, ast.AugAssign)):
            targets = n.targets if isinstance(n, ast.Assign) else [n.target]
            for t in targets:
                if (isinstance(t, ast.Subscript)
                        and chain_base(attr_chain(t.value)) in cache_names):
                    return True
        if (isinstance(n, ast.Call)
                and isinstance(n.func, ast.Attribute)
                and n.func.attr == "setdefault"
                and chain_base(attr_chain(n.func.value)) in cache_names):
            return True
    return False


class _FileFacts:
    """Per-file extraction feeding the repo-wide call graph."""

    def __init__(self, sf):
        self.sf = sf
        self.parents = build_parents(sf.tree)
        self.cache_names = _module_cache_dicts(sf.tree)
        # basename -> function node(s) defined in this file
        self.defs: dict[str, list] = {}
        # jit roots: names whose bodies end up traced
        self.root_names: set = set()
        # lambda nodes passed directly to a jit maker (bodies are traced)
        self.root_lambdas: list = []
        # (call node, kind) for every jit-maker call site
        self.sites: list = []
        # id() of inner defs returned by their enclosing factory
        self.factory_products: set = set()
        self._collect()

    def _collect(self):
        for node in ast.walk(self.sf.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self.defs.setdefault(node.name, []).append(node)
                if any(_jit_decorator_target(d)
                       for d in node.decorator_list):
                    self.root_names.add(node.name)
                self._mark_factory_products(node)
            elif isinstance(node, ast.Call):
                kind = _is_jit_maker(node)
                if kind is None:
                    continue
                self.sites.append((node, kind))
                # jax.jit(f) / shard_map(f, ...): f's body is traced
                if node.args:
                    fn = node.args[0]
                    base = chain_base(attr_chain(fn))
                    if base:
                        self.root_names.add(base)
                    elif isinstance(fn, ast.Lambda):
                        self.root_lambdas.append(fn)

    def _mark_factory_products(self, g):
        """Inner defs that ``g`` returns (the ``make_*``/builder idiom):
        the closure is built once per factory call, and callers own the
        jit/cache discipline for the product."""
        inner = {n.name: n for n in ast.walk(g)
                 if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
                 and n is not g}
        if not inner:
            return
        for ret in ast.walk(g):
            if isinstance(ret, ast.Return) and ret.value is not None:
                for n in ast.walk(ret.value):
                    if isinstance(n, ast.Name) and n.id in inner:
                        self.factory_products.add(id(inner[n.id]))


def _reachable(facts: list) -> set:
    """Transitive closure of traced function bodies from every jit root,
    as a set of ``(file, name)`` pairs.

    Name resolution is deliberately conservative: a called basename
    binds to a def in the *same file* first, and crosses files only
    when exactly one file in the sweep defines it.  Basename-global
    matching is wrong here — generic inner names (``step``, ``body``,
    ``fn``) appear both in traced scan bodies and in host-side engine
    methods, and one shared name would cascade the whole host layer
    into the traced set."""
    by_file = {ff.sf.rel: ff for ff in facts}
    file_count: dict[str, set] = {}
    for ff in facts:
        for name in ff.defs:
            file_count.setdefault(name, set()).add(ff.sf.rel)

    def resolve(rel: str, base: str):
        if base in by_file[rel].defs:
            return (rel, base)
        owners = file_count.get(base)
        if owners and len(owners) == 1:
            return (next(iter(owners)), base)
        return None

    frontier = set()
    for ff in facts:
        for name in ff.root_names:
            node = resolve(ff.sf.rel, name)
            if node:
                frontier.add(node)
        for lam in ff.root_lambdas:
            for base in calls_in(lam):
                node = resolve(ff.sf.rel, base)
                if node:
                    frontier.add(node)
    seen = set()
    while frontier:
        rel, name = frontier.pop()
        if (rel, name) in seen:
            continue
        seen.add((rel, name))
        callees = set()
        for fn in by_file[rel].defs[name]:
            callees |= calls_in(fn)
        for base in callees:
            node = resolve(rel, base)
            if node and node not in seen:
                frontier.add(node)
    return seen


def _returned_uncalled(call, parents) -> bool:
    """True when the jit-maker ``call``'s *result* is returned as-is
    (``return jax.jit(step)`` — the factory idiom; the caller owns the
    cache discipline for the product).  ``return jax.jit(f)(x)`` does
    not qualify: the fresh program is invoked, not handed out."""
    cur, prev = parents.get(call), call
    while cur is not None:
        if isinstance(cur, ast.Return):
            return True
        if isinstance(cur, ast.Call) and cur.func is prev:
            return False
        if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef,
                            ast.Lambda)):
            return False
        prev, cur = cur, parents.get(cur)
    return False


def _jax_locals(fn) -> set:
    """Local names assigned from a jax/jnp-rooted expression in ``fn``."""
    out = set()
    for n in ast.walk(fn):
        if isinstance(n, ast.Assign) and _jax_derived(n.value, out):
            out.update(t.id for t in n.targets if isinstance(t, ast.Name))
    return out


def _jax_derived(expr, jax_names: set) -> bool:
    """Whether ``expr`` contains a jax/jnp-rooted call or a name known to
    hold a jax value."""
    for n in ast.walk(expr):
        if isinstance(n, ast.Call):
            if chain_root(attr_chain(n.func)) in _JAX_ROOTS:
                return True
        elif isinstance(n, ast.Name) and n.id in jax_names:
            return True
    return False


@register
class JitDisciplinePass(LintPass):
    name = "jit-discipline"
    rules = ("jit-cache-discipline", "jit-host-sync", "eager-loop-sync")
    cacheable = False           # needs the cross-file call graph

    def run(self, ctx):
        facts = [_FileFacts(sf) for sf in ctx.files.values()]
        traced = _reachable(facts)
        out = []
        for ff in facts:
            out.extend(self._check_sites(ff, traced))
            out.extend(self._check_host_sync(ff, traced))
            out.extend(self._check_eager_loops(ff, traced))
        return out

    # -- jit-cache-discipline ------------------------------------------

    def _check_sites(self, ff, traced):
        out = []
        for call, kind in ff.sites:
            parent = ff.parents.get(call)
            if isinstance(parent, ast.Attribute) and parent.attr == "lower":
                continue        # AOT: jax.jit(f).lower(...) compiles once
            enclosing = enclosing_functions(call, ff.parents)
            if not enclosing:
                continue        # module level: shared by construction
            named = [f for f in enclosing
                     if isinstance(f, (ast.FunctionDef,
                                       ast.AsyncFunctionDef))]
            if any(_stores_into(f, ff.cache_names) for f in named):
                continue        # the _STEP_CACHE pattern
            if any((ff.sf.rel, f.name) in traced for f in named):
                continue        # already inside a traced body
            if any(id(f) in ff.factory_products for f in named):
                continue        # make_*-style builder: caller caches
            if _returned_uncalled(call, ff.parents):
                continue        # factory hands the program out uncalled
            fname = named[0].name if named else "<lambda>"
            out.append(Finding(
                rule="jit-cache-discipline", path=ff.sf.rel,
                line=call.lineno, col=call.col_offset,
                message=f"{kind} call inside `{fname}` is neither module"
                        f"-level nor stored in a module-level cache dict;"
                        f" each call compiles a fresh program"))
        return out

    # -- jit-host-sync -------------------------------------------------

    def _check_host_sync(self, ff, traced):
        out = []
        bodies = []
        for name in ff.defs:
            if (ff.sf.rel, name) in traced:
                bodies.extend(ff.defs[name])
        bodies.extend(ff.root_lambdas)
        seen_nodes = set()
        for fn in bodies:
            # only names provably bound to jax values count as traced:
            # coercions of plain args/config attrs (static shape math
            # like ``np.sqrt(cfg.d_model)``) must not be flagged
            jax_names = _jax_locals(fn)
            for n in ast.walk(fn):
                if not isinstance(n, ast.Call) or id(n) in seen_nodes:
                    continue
                seen_nodes.add(id(n))
                msg = self._host_sync_msg(n, jax_names)
                if msg:
                    fname = getattr(fn, "name", "<lambda>")
                    out.append(Finding(
                        rule="jit-host-sync", path=ff.sf.rel,
                        line=n.lineno, col=n.col_offset,
                        message=f"{msg} inside jit-traced `{fname}` forces"
                                f" a host sync (or fails under jit)"))
        return out

    @staticmethod
    def _host_sync_msg(call, jax_names):
        func = call.func
        if isinstance(func, ast.Attribute) and func.attr == "item":
            return "`.item()`"
        chain = attr_chain(func)
        base = chain_base(chain)
        if (isinstance(func, ast.Name) and base in _COERCE and call.args
                and _jax_derived(call.args[0], jax_names)):
            return f"`{base}()` over a traced value"
        if (chain_root(chain) in _NP_ROOTS
                and any(_jax_derived(a, jax_names) for a in call.args)):
            return f"`{chain}()` over a traced value"
        return None

    # -- eager-loop-sync -----------------------------------------------

    def _check_eager_loops(self, ff, traced):
        if "/serving/" not in "/" + ff.sf.rel:
            return []
        out = []
        host_fns = [fn for name, fns in ff.defs.items()
                    if (ff.sf.rel, name) not in traced for fn in fns]
        flagged = set()     # nested loops: report each call site once
        for fn in host_fns:
            for loop in ast.walk(fn):
                if not isinstance(loop, (ast.For, ast.While)):
                    continue
                for n in ast.walk(loop):
                    if not isinstance(n, ast.Call) or id(n) in flagged:
                        continue
                    base = chain_base(attr_chain(n.func))
                    if base not in (_COERCE | {"asarray", "array"}):
                        continue
                    if not n.args:
                        continue
                    # flag only a *fresh* device computation per
                    # iteration: the arg itself contains a jax/jnp call
                    if _jax_derived(n.args[0], set()):
                        flagged.add(id(n))
                        out.append(Finding(
                            rule="eager-loop-sync", path=ff.sf.rel,
                            line=n.lineno, col=n.col_offset,
                            message=f"`{base}(...)` over a fresh jax"
                                    f" computation inside a loop in"
                                    f" `{fn.name}`: one device dispatch +"
                                    f" blocking transfer per iteration —"
                                    f" hoist to a batched draw"))
        return out
