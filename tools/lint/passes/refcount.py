"""refcount pairing: exception safety of the paged-KV page allocator.

``kvcache/paged.py`` maintains the invariant *page refcount == number of
logical holders* (sequences + prefix-tree nodes).  Any path that has
already called ``_alloc_raw`` / ``_incref`` and then raises without an
intervening ``_decref`` (or rollback/release helper, or an enclosing
``try`` whose handler/finally decrefs) leaks pages: the free list
shrinks forever and the pool eventually reports OutOfPages under
capacity it actually has.  The ``admit_shared`` undo loop is the model
compliant shape.

``refcount-leak-on-raise``
    A ``raise`` statement textually after the function's first
    ``_alloc_raw``/``_incref`` with no ``_decref``/rollback between the
    two and no enclosing handler that releases.

This is a line-order heuristic (no path-sensitive dataflow): a raise
*above* the first alloc is trivially safe, one below must show a
release between alloc and raise or an enclosing cleanup.  Misses are
possible; false positives get an inline suppression with a comment
explaining why the path cannot leak.
"""
from __future__ import annotations

import ast

from tools.lint.core import (
    Finding, LintPass, attr_chain, build_parents, chain_base,
    enclosing_functions, register,
)

_ACQUIRE = {"_alloc_raw", "_incref"}
_RELEASE = {"_decref", "rollback", "release", "free_pages"}


def _in_scope(rel: str) -> bool:
    parts = rel.replace("\\", "/").split("/")
    return parts[-1] == "paged.py" or "kvcache" in parts


def _call_lines(fn, names: set) -> list:
    out = []
    for n in ast.walk(fn):
        if (isinstance(n, ast.Call)
                and chain_base(attr_chain(n.func)) in names):
            out.append(n.lineno)
    return sorted(out)


def _cleanup_in_enclosing_try(raise_node, parents) -> bool:
    """Whether an enclosing ``try`` releases in a handler or finally."""
    cur = parents.get(raise_node)
    while cur is not None and not isinstance(
            cur, (ast.FunctionDef, ast.AsyncFunctionDef)):
        if isinstance(cur, ast.Try):
            cleanup = list(cur.finalbody)
            for h in cur.handlers:
                cleanup.extend(h.body)
            for stmt in cleanup:
                if _call_lines(stmt, _RELEASE):
                    return True
        cur = parents.get(cur)
    return False


@register
class RefcountPairingPass(LintPass):
    name = "refcount-pairing"
    rules = ("refcount-leak-on-raise",)

    def check_file(self, sf, ctx):
        if not _in_scope(sf.rel):
            return []
        parents = build_parents(sf.tree)
        out = []
        for fn in ast.walk(sf.tree):
            if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            acquire = _call_lines(fn, _ACQUIRE)
            if not acquire:
                continue
            first_acquire = acquire[0]
            releases = _call_lines(fn, _RELEASE)
            for node in ast.walk(fn):
                if not isinstance(node, ast.Raise):
                    continue
                if node.lineno <= first_acquire:
                    continue    # raised before anything was acquired
                if any(first_acquire < r <= node.lineno
                       for r in releases):
                    continue    # an undo/rollback sits on the path
                if _cleanup_in_enclosing_try(node, parents):
                    continue
                fname = next((f.name for f in enclosing_functions(
                    node, parents) if not isinstance(f, ast.Lambda)),
                    fn.name)
                out.append(Finding(
                    rule="refcount-leak-on-raise", path=sf.rel,
                    line=node.lineno, col=node.col_offset,
                    message=f"raise in `{fname}` after"
                            f" _alloc_raw/_incref (line {first_acquire})"
                            f" with no _decref/rollback on the path:"
                            f" pages leak on this exception"))
        return out
