"""Pass modules.  Importing this package registers every pass."""
from tools.lint.passes import (  # noqa: F401
    async_blocking,
    jit_discipline,
    prng_discipline,
    refcount,
    surface,
)
