"""surface lint: docs links, spec doctests, API surface, metric names.

The logic that used to live in ``tools/check_docs.py`` and
``tools/check_metrics.py``, re-homed as registry passes so one runner
(``python -m tools.lint``) covers every repo invariant.  The old
scripts remain as thin wrappers calling these functions, because CI's
``docs`` job and tests/test_{docs,telemetry}.py invoke them by path.

``surface-docs``
    Intra-repo Markdown links resolve; ``docs/FORMATS.md`` doctests
    pass; every ``repro.serving.__all__`` name appears in
    ``docs/API.md``.

``surface-metrics``
    Every literal metric name emitted via ``.counter/.gauge/.histogram``
    under ``src/`` is documented in ``docs/OBSERVABILITY.md``, and the
    doc still describes the dynamic ``kvstat_`` namespace.

Both passes run only when the repo root has a ``docs/`` directory, so
fixture trees in tests are exempt, and are never cached (they depend on
the Markdown files, not on any one Python file).
"""
from __future__ import annotations

import ast
import doctest
import os
import re
import sys

from tools.lint.core import Finding, LintPass, register

SKIP_DIRS = {".git", ".github", "__pycache__", ".pytest_cache",
             "node_modules"}
# [text](target) — target captured up to the first unescaped ')'
_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_EXTERNAL = ("http://", "https://", "mailto:")
# .counter("name" / .gauge("name" / .histogram("name" — emission sites
# only (reads go through .get("...")/.value("...")); \s* spans newlines
_EMIT = re.compile(
    r"\.(?:counter|gauge|histogram)\(\s*['\"]([A-Za-z0-9_.]+)['\"]")


# -- docs checks (ex tools/check_docs.py) -----------------------------------

def md_files(repo: str) -> list[str]:
    out = []
    for root, dirs, files in os.walk(repo):
        dirs[:] = [d for d in dirs if d not in SKIP_DIRS]
        out.extend(os.path.join(root, f) for f in files
                   if f.endswith(".md"))
    return sorted(out)


def check_links(repo: str) -> list[str]:
    """Return human-readable error strings for dangling intra-repo links."""
    errors = []
    for path in md_files(repo):
        with open(path, encoding="utf-8") as fh:
            text = fh.read()
        # fenced code blocks may contain ``[x](y)``-looking noise
        text = re.sub(r"```.*?```", "", text, flags=re.S)
        for m in _LINK.finditer(text):
            target = m.group(1)
            if target.startswith(_EXTERNAL) or target.startswith("#"):
                continue
            target = target.split("#", 1)[0]
            if not target:
                continue
            resolved = os.path.normpath(
                os.path.join(os.path.dirname(path), target))
            if not os.path.exists(resolved):
                rel = os.path.relpath(path, repo)
                errors.append(f"{rel}: dangling link -> {m.group(1)}")
    return errors


def run_doctests(repo: str) -> list[str]:
    """Doctest docs/FORMATS.md; returns error strings (empty = pass)."""
    src = os.path.join(repo, "src")
    if src not in sys.path:
        sys.path.insert(0, src)
    spec = os.path.join(repo, "docs", "FORMATS.md")
    if not os.path.exists(spec):
        return ["docs/FORMATS.md is missing"]
    res = doctest.testfile(spec, module_relative=False, verbose=False)
    if res.failed:
        return [f"docs/FORMATS.md: {res.failed}/{res.attempted} "
                f"doctests failed"]
    if not res.attempted:
        return ["docs/FORMATS.md: no doctests found (worked example gone?)"]
    return []


def check_api_surface(repo: str) -> list[str]:
    """Every ``repro.serving.__all__`` name must appear in docs/API.md."""
    init = os.path.join(repo, "src", "repro", "serving", "__init__.py")
    api = os.path.join(repo, "docs", "API.md")
    if not os.path.exists(api):
        return ["docs/API.md is missing"]
    with open(init, encoding="utf-8") as fh:
        tree = ast.parse(fh.read(), init)
    names: list[str] = []
    for node in ast.walk(tree):
        if (isinstance(node, ast.Assign)
                and any(isinstance(t, ast.Name) and t.id == "__all__"
                        for t in node.targets)):
            names = [ast.literal_eval(elt) for elt in node.value.elts]
    if not names:
        return ["repro/serving/__init__.py: no __all__ found"]
    with open(api, encoding="utf-8") as fh:
        doc = fh.read()
    return [f"docs/API.md: public name {n!r} from repro.serving.__all__ "
            f"is undocumented" for n in names if n not in doc]


# -- metric checks (ex tools/check_metrics.py) ------------------------------

def emitted_names(repo: str) -> dict[str, list[str]]:
    """Metric name -> ["path:line", ...] of every literal emission site."""
    out: dict[str, list[str]] = {}
    src = os.path.join(repo, "src")
    for root, dirs, files in os.walk(src):
        dirs[:] = [d for d in dirs if d != "__pycache__"]
        for f in files:
            if not f.endswith(".py"):
                continue
            path = os.path.join(root, f)
            with open(path, encoding="utf-8") as fh:
                text = fh.read()
            rel = os.path.relpath(path, repo)
            for m in _EMIT.finditer(text):
                line = text.count("\n", 0, m.start()) + 1
                out.setdefault(m.group(1), []).append(f"{rel}:{line}")
    return out


def check_metrics(repo: str) -> list[str]:
    """Return human-readable error strings (empty = clean)."""
    doc_path = os.path.join(repo, "docs", "OBSERVABILITY.md")
    if not os.path.exists(doc_path):
        return ["docs/OBSERVABILITY.md is missing"]
    with open(doc_path, encoding="utf-8") as fh:
        doc = fh.read()
    names = emitted_names(repo)
    errors = []
    for name in sorted(names):
        if name not in doc:
            errors.append(
                f"metric {name!r} (emitted at {names[name][0]}) is not "
                f"documented in docs/OBSERVABILITY.md")
    if "kvstat_" not in doc:
        errors.append("docs/OBSERVABILITY.md no longer describes the "
                      "kvstat_ forwarding namespace")
    if not names:
        errors.append("no metric emissions found under src/ — "
                      "has the telemetry subsystem moved?")
    return errors


# -- registry wrappers -------------------------------------------------------

def _as_findings(rule: str, errors: list[str], default_path: str) -> list:
    out = []
    for e in errors:
        # checker strings lead with "path: ..." when file-specific
        path, msg = default_path, e
        head = e.split(":", 1)[0]
        if "/" in head or head.endswith(".md") or head.endswith(".py"):
            path, msg = head, e.split(":", 1)[1].strip()
        out.append(Finding(rule=rule, path=path, line=0, col=0,
                           message=msg))
    return out


@register
class SurfaceDocsPass(LintPass):
    name = "surface-docs"
    rules = ("surface-docs",)
    cacheable = False

    def run(self, ctx):
        if not os.path.isdir(os.path.join(ctx.root, "docs")):
            return []
        errors = (check_links(ctx.root) + run_doctests(ctx.root)
                  + check_api_surface(ctx.root))
        return _as_findings("surface-docs", errors, "docs")


@register
class SurfaceMetricsPass(LintPass):
    name = "surface-metrics"
    rules = ("surface-metrics",)
    cacheable = False

    def run(self, ctx):
        if not os.path.isdir(os.path.join(ctx.root, "docs")):
            return []
        errors = check_metrics(ctx.root)
        return _as_findings("surface-metrics", errors,
                            "docs/OBSERVABILITY.md")
