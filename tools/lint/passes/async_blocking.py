"""async blocking: keep the event loop responsive in the serving frontend.

``async_engine.py`` runs every engine replica's step loop on one asyncio
event loop; a synchronous stall in any coroutine freezes token streams
for *all* requests on *all* replicas.  Scope: every ``async def`` in
``async_engine.py`` / ``router.py`` (and any other serving file).

``async-blocking-call``
    Inside ``async def``: ``time.sleep`` (use ``asyncio.sleep``), file
    I/O (``open``/``read_text``/``write_text``/...), or ``asyncio.run``
    (nested loops deadlock).

``async-sync-step``
    A non-awaited ``.step()`` / ``.run()`` call inside ``async def``.
    The engine's ``step()`` is CPU-bound host code, so the frontend is
    *allowed* to call it synchronously **if** the enclosing loop body
    also awaits (the ``eng.step(); await asyncio.sleep(0)`` cooperative
    pattern) — otherwise the coroutine monopolizes the loop for the
    whole drain.
"""
from __future__ import annotations

import ast

from tools.lint.core import (
    Finding, LintPass, attr_chain, build_parents, chain_base, register,
)

_SCOPE_FILES = {"async_engine.py", "router.py"}
_BLOCK_CHAINS = {"time.sleep", "asyncio.run"}
_IO_BASES = {"open", "read_text", "write_text", "read_bytes",
             "write_bytes"}


def _in_scope(rel: str) -> bool:
    parts = rel.replace("\\", "/").split("/")
    return parts[-1] in _SCOPE_FILES or "serving" in parts


def _awaited(call, parents) -> bool:
    p = parents.get(call)
    return isinstance(p, ast.Await)


def _enclosing_loop(node, stop, parents):
    cur = parents.get(node)
    while cur is not None and cur is not stop:
        if isinstance(cur, (ast.For, ast.While, ast.AsyncFor)):
            return cur
        cur = parents.get(cur)
    return None


def _has_await(node) -> bool:
    return any(isinstance(n, ast.Await) for n in ast.walk(node))


@register
class AsyncBlockingPass(LintPass):
    name = "async-blocking"
    rules = ("async-blocking-call", "async-sync-step")

    def check_file(self, sf, ctx):
        if not _in_scope(sf.rel):
            return []
        parents = build_parents(sf.tree)
        out = []
        for fn in ast.walk(sf.tree):
            if not isinstance(fn, ast.AsyncFunctionDef):
                continue
            for node in ast.walk(fn):
                if not isinstance(node, ast.Call):
                    continue
                chain = attr_chain(node.func)
                base = chain_base(chain)
                if chain in _BLOCK_CHAINS or (
                        isinstance(node.func, ast.Name)
                        and base in _IO_BASES) or (
                        isinstance(node.func, ast.Attribute)
                        and base in _IO_BASES and base != "open"):
                    out.append(Finding(
                        rule="async-blocking-call", path=sf.rel,
                        line=node.lineno, col=node.col_offset,
                        message=f"`{chain or base}` blocks the event"
                                f" loop inside async `{fn.name}`; every"
                                f" stream on this loop stalls"))
                elif (isinstance(node.func, ast.Attribute)
                        and base in {"step", "run"}
                        and not _awaited(node, parents)):
                    loop = _enclosing_loop(node, fn, parents)
                    if loop is not None and _has_await(loop):
                        continue    # cooperative: loop body also awaits
                    out.append(Finding(
                        rule="async-sync-step", path=sf.rel,
                        line=node.lineno, col=node.col_offset,
                        message=f"sync `.{base}()` in async `{fn.name}`"
                                f" without a cooperative await in the"
                                f" same loop; pair it with `await"
                                f" asyncio.sleep(0)` or await it"))
        return out
