"""``repro-lint``: AST-based invariant analyzer for this repo.

Run as ``python -m tools.lint`` (see ``tools/lint/runner.py`` for the
CLI, ``docs/LINTS.md`` for the rule catalogue).
"""
from tools.lint.core import (  # noqa: F401
    Finding, LintContext, LintPass, PASSES, SourceFile, register,
)
from tools.lint import passes as _passes  # noqa: F401  (registers passes)
from tools.lint.runner import main, run_lint  # noqa: F401
