"""Runner + CLI for ``repro-lint`` (``python -m tools.lint``).

Walks ``src/ benchmarks/ examples/ tools/`` under the repo root, parses
every ``*.py`` once, and drives the registered passes.  Cacheable
(per-file) pass results are memoized in ``<root>/.lint_cache.json``
keyed by file content hash and a tool-source hash, so a warm run only
re-analyzes edited files.  Findings then flow through inline
suppressions and the committed baseline; only *new* findings fail the
run (exit 1).

    python -m tools.lint                 # human-readable report
    python -m tools.lint --check         # CI gate (same exit semantics)
    python -m tools.lint --json-out f.json
    python -m tools.lint --select prng-raw-key,refcount-pairing
    python -m tools.lint --write-baseline   # grandfather current findings
"""
from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys

from tools.lint.core import (
    Finding, LINT_VERSION, LintContext, PASSES, SourceFile,
)

DEFAULT_DIRS = ("src", "benchmarks", "examples", "tools")
SKIP_DIRS = {"__pycache__", ".git", ".pytest_cache", ".hypothesis"}


def repo_root() -> str:
    return os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))))


def _tool_key() -> str:
    """Hash of the analyzer's own sources: editing any pass invalidates
    every cache entry."""
    h = hashlib.sha256(LINT_VERSION.encode())
    tool_dir = os.path.dirname(os.path.abspath(__file__))
    for root, dirs, files in os.walk(tool_dir):
        dirs[:] = sorted(d for d in dirs if d not in SKIP_DIRS)
        for f in sorted(files):
            if f.endswith(".py"):
                with open(os.path.join(root, f), "rb") as fh:
                    h.update(fh.read())
    return h.hexdigest()[:16]


def iter_py_files(root: str, dirs=DEFAULT_DIRS) -> list[str]:
    out = []
    for d in dirs:
        top = os.path.join(root, d)
        if not os.path.isdir(top):
            continue
        for cur, subdirs, files in os.walk(top):
            subdirs[:] = sorted(s for s in subdirs if s not in SKIP_DIRS)
            for f in sorted(files):
                if f.endswith(".py"):
                    out.append(os.path.join(cur, f))
    return sorted(out)


def load_files(root: str, paths: list[str]):
    """Parse sources; returns ({rel: SourceFile}, [parse-error Finding])."""
    files, errors = {}, []
    for path in paths:
        rel = os.path.relpath(path, root).replace(os.sep, "/")
        with open(path, encoding="utf-8") as fh:
            text = fh.read()
        try:
            files[rel] = SourceFile(rel, path, text)
        except SyntaxError as e:
            errors.append(Finding(
                rule="parse-error", path=rel, line=e.lineno or 0,
                col=e.offset or 0, message=f"file does not parse: {e.msg}"))
    return files, errors


class _Cache:
    def __init__(self, path: str, enabled: bool):
        self.path = path
        self.enabled = enabled
        self.key = _tool_key()
        self.data: dict = {"version": self.key, "files": {}}
        self.dirty = False
        if enabled and os.path.exists(path):
            try:
                with open(path, encoding="utf-8") as fh:
                    loaded = json.load(fh)
                if loaded.get("version") == self.key:
                    self.data = loaded
            except (ValueError, OSError):
                pass

    def lookup(self, rel: str, sha: str, pass_name: str):
        ent = self.data["files"].get(rel)
        if not ent or ent.get("sha") != sha:
            return None
        hit = ent.get("passes", {}).get(pass_name)
        return None if hit is None else [Finding.from_json(d) for d in hit]

    def store(self, rel: str, sha: str, pass_name: str, findings):
        ent = self.data["files"].setdefault(rel, {"sha": sha, "passes": {}})
        if ent.get("sha") != sha:
            ent.update({"sha": sha, "passes": {}})
        ent["passes"][pass_name] = [f.to_json() for f in findings]
        self.dirty = True

    def flush(self):
        if self.enabled and self.dirty:
            tmp = self.path + ".tmp"
            with open(tmp, "w", encoding="utf-8") as fh:
                json.dump(self.data, fh)
            os.replace(tmp, self.path)


def load_baseline(path: str) -> list[dict]:
    if not os.path.exists(path):
        return []
    with open(path, encoding="utf-8") as fh:
        data = json.load(fh)
    return data.get("findings", data if isinstance(data, list) else [])


def apply_baseline(findings, baseline: list[dict]):
    """Consume baseline entries (a multiset over (rule, path, message))
    and mark matching findings; returns (findings, unused_entries)."""
    pool: dict[tuple, int] = {}
    for ent in baseline:
        fp = (ent["rule"], ent["path"], ent["message"])
        pool[fp] = pool.get(fp, 0) + 1
    out = []
    import dataclasses
    for f in findings:
        fp = f.fingerprint()
        if pool.get(fp, 0) > 0:
            pool[fp] -= 1
            f = dataclasses.replace(f, baselined=True)
        out.append(f)
    unused = sum(pool.values())
    return out, unused


def run_lint(root: str, *, select=None, skip=None, use_cache=True,
             baseline_path=None):
    """Run every (selected) pass; returns a result dict."""
    root = os.path.abspath(root)
    paths = iter_py_files(root)
    files, findings = load_files(root, paths)
    ctx = LintContext(root, files)
    cache = _Cache(os.path.join(root, ".lint_cache.json"), use_cache)

    def wanted(p):
        names = {p.name, *p.rules}
        if select and not (names & set(select)):
            return False
        if skip and (names & set(skip)):
            return False
        return True

    for lint_pass in PASSES.values():
        if not wanted(lint_pass):
            continue
        if lint_pass.cacheable:
            for rel, sf in files.items():
                sha = hashlib.sha256(sf.text.encode()).hexdigest()[:16]
                hit = cache.lookup(rel, sha, lint_pass.name)
                if hit is None:
                    hit = list(lint_pass.check_file(sf, ctx))
                    cache.store(rel, sha, lint_pass.name, hit)
                findings.extend(hit)
        else:
            findings.extend(lint_pass.run(ctx))
    cache.flush()

    kept, suppressed = [], 0
    for f in findings:
        sf = files.get(f.path)
        if sf is not None and sf.suppressed(f):
            suppressed += 1
        else:
            kept.append(f)

    if baseline_path is None:
        baseline_path = os.path.join(root, "tools", "lint",
                                     "baseline.json")
    kept, unused_baseline = apply_baseline(
        kept, load_baseline(baseline_path))
    kept.sort(key=lambda f: (f.path, f.line, f.rule))
    new = [f for f in kept if not f.baselined]
    return {
        "findings": kept, "new": new, "suppressed": suppressed,
        "unused_baseline": unused_baseline, "files": len(files),
        "baseline_path": baseline_path,
    }


def write_baseline(result, path: str):
    entries = [{"rule": f.rule, "path": f.path, "message": f.message}
               for f in result["findings"]]
    with open(path, "w", encoding="utf-8") as fh:
        json.dump({"findings": entries}, fh, indent=2, sort_keys=True)
        fh.write("\n")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m tools.lint",
        description="repro-lint: AST invariant analyzer (docs/LINTS.md)")
    ap.add_argument("--root", default=repo_root(),
                    help="repo root to analyze (default: this repo)")
    ap.add_argument("--check", action="store_true",
                    help="CI mode: terse output, exit 1 on new findings")
    ap.add_argument("--json", action="store_true",
                    help="print the JSON report to stdout")
    ap.add_argument("--json-out", metavar="FILE",
                    help="also write the JSON report to FILE")
    ap.add_argument("--select", metavar="NAMES",
                    help="comma-separated pass/rule names to run")
    ap.add_argument("--skip", metavar="NAMES",
                    help="comma-separated pass/rule names to skip")
    ap.add_argument("--no-cache", action="store_true",
                    help="ignore and do not write .lint_cache.json")
    ap.add_argument("--baseline", metavar="FILE",
                    help="baseline path (default: tools/lint/baseline.json"
                         " under --root)")
    ap.add_argument("--write-baseline", action="store_true",
                    help="write current findings to the baseline and exit")
    args = ap.parse_args(argv)

    split = lambda s: [x.strip() for x in s.split(",") if x.strip()]
    result = run_lint(
        args.root,
        select=split(args.select) if args.select else None,
        skip=split(args.skip) if args.skip else None,
        use_cache=not args.no_cache,
        baseline_path=args.baseline)

    if args.write_baseline:
        write_baseline(result, result["baseline_path"])
        print(f"[lint] baseline written: {result['baseline_path']} "
              f"({len(result['findings'])} findings)")
        return 0

    report = {
        "files": result["files"],
        "new": len(result["new"]),
        "baselined": len(result["findings"]) - len(result["new"]),
        "suppressed": result["suppressed"],
        "findings": [f.to_json() for f in result["findings"]],
    }
    if args.json_out:
        with open(args.json_out, "w", encoding="utf-8") as fh:
            json.dump(report, fh, indent=2)
            fh.write("\n")
    if args.json:
        print(json.dumps(report, indent=2))
    else:
        shown = result["new"] if args.check else result["findings"]
        for f in shown:
            print(f.format())
        status = "FAIL" if result["new"] else "OK"
        print(f"[lint] {status}: {result['files']} files, "
              f"{len(result['new'])} new finding(s), "
              f"{report['baselined']} baselined, "
              f"{result['suppressed']} suppressed")
        if result["unused_baseline"]:
            print(f"[lint] note: {result['unused_baseline']} stale "
                  f"baseline entr(y/ies) no longer match — consider "
                  f"--write-baseline")
    return 1 if result["new"] else 0


if __name__ == "__main__":
    raise SystemExit(main())
