"""Docs hygiene gate (run by the CI ``docs`` job and tests/test_docs.py).

Two checks keep the docs/ subsystem from rotting:

  1. **Links**: every intra-repo Markdown link (``[text](path)`` with a
     relative target) in every tracked ``*.md`` file must resolve to an
     existing file or directory.  External (``http(s)://``, ``mailto:``)
     and pure-anchor (``#...``) targets are ignored; a ``#fragment``
     suffix on a file target is stripped before the existence check.
  2. **Doctests**: the worked byte-level example in ``docs/FORMATS.md``
     is executed (``doctest``), so the spec's claims about the actual
     bitstreams stay true against the code.
  3. **API surface**: every name in ``repro.serving.__all__`` (parsed
     from the source with ``ast`` — no import needed) must appear in
     ``docs/API.md``, so the stable-surface doc cannot silently drift
     from the package.

Usage:  python tools/check_docs.py   (exit 0 = clean)
"""
from __future__ import annotations

import ast
import doctest
import os
import re
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SKIP_DIRS = {".git", ".github", "__pycache__", ".pytest_cache", "node_modules"}
# [text](target) — target captured up to the first unescaped ')'
_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_EXTERNAL = ("http://", "https://", "mailto:")


def md_files() -> list[str]:
    out = []
    for root, dirs, files in os.walk(REPO):
        dirs[:] = [d for d in dirs if d not in SKIP_DIRS]
        out.extend(os.path.join(root, f) for f in files if f.endswith(".md"))
    return sorted(out)


def check_links() -> list[str]:
    """Return human-readable error strings for dangling intra-repo links."""
    errors = []
    for path in md_files():
        with open(path, encoding="utf-8") as fh:
            text = fh.read()
        # fenced code blocks may contain ``[x](y)``-looking noise
        text = re.sub(r"```.*?```", "", text, flags=re.S)
        for m in _LINK.finditer(text):
            target = m.group(1)
            if target.startswith(_EXTERNAL) or target.startswith("#"):
                continue
            target = target.split("#", 1)[0]
            if not target:
                continue
            resolved = os.path.normpath(
                os.path.join(os.path.dirname(path), target))
            if not os.path.exists(resolved):
                rel = os.path.relpath(path, REPO)
                errors.append(f"{rel}: dangling link -> {m.group(1)}")
    return errors


def run_doctests() -> list[str]:
    """Doctest docs/FORMATS.md; returns error strings (empty = pass)."""
    sys.path.insert(0, os.path.join(REPO, "src"))
    spec = os.path.join(REPO, "docs", "FORMATS.md")
    if not os.path.exists(spec):
        return ["docs/FORMATS.md is missing"]
    res = doctest.testfile(spec, module_relative=False, verbose=False)
    if res.failed:
        return [f"docs/FORMATS.md: {res.failed}/{res.attempted} "
                f"doctests failed"]
    if not res.attempted:
        return ["docs/FORMATS.md: no doctests found (worked example gone?)"]
    return []


def check_api_surface() -> list[str]:
    """Every ``repro.serving.__all__`` name must appear in docs/API.md."""
    init = os.path.join(REPO, "src", "repro", "serving", "__init__.py")
    api = os.path.join(REPO, "docs", "API.md")
    if not os.path.exists(api):
        return ["docs/API.md is missing"]
    with open(init, encoding="utf-8") as fh:
        tree = ast.parse(fh.read(), init)
    names: list[str] = []
    for node in ast.walk(tree):
        if (isinstance(node, ast.Assign)
                and any(isinstance(t, ast.Name) and t.id == "__all__"
                        for t in node.targets)):
            names = [ast.literal_eval(elt) for elt in node.value.elts]
    if not names:
        return ["repro/serving/__init__.py: no __all__ found"]
    with open(api, encoding="utf-8") as fh:
        doc = fh.read()
    return [f"docs/API.md: public name {n!r} from repro.serving.__all__ "
            f"is undocumented" for n in names if n not in doc]


def main() -> int:
    errors = check_links() + run_doctests() + check_api_surface()
    for e in errors:
        print(f"[check_docs] {e}", file=sys.stderr)
    if not errors:
        n = len(md_files())
        print(f"[check_docs] OK: links in {n} markdown files resolve, "
              f"FORMATS.md doctests pass, serving __all__ covered by "
              f"API.md")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main())
