"""Docs hygiene gate — thin compatibility wrapper.

The checks now live in the unified analyzer as the ``surface-docs``
pass (``tools/lint/passes/surface.py``; run via ``python -m tools.lint``).
This wrapper keeps the historical entry points working — the CI ``docs``
job and tests/test_docs.py load this file by path and call
``check_links()`` / ``run_doctests()`` / ``check_api_surface()`` with no
arguments.

Usage:  python tools/check_docs.py   (exit 0 = clean)
"""
from __future__ import annotations

import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if REPO not in sys.path:
    sys.path.insert(0, REPO)

from tools.lint.passes import surface as _surface  # noqa: E402

md_files = lambda: _surface.md_files(REPO)


def check_links() -> list[str]:
    """Return human-readable error strings for dangling intra-repo links."""
    return _surface.check_links(REPO)


def run_doctests() -> list[str]:
    """Doctest docs/FORMATS.md; returns error strings (empty = pass)."""
    return _surface.run_doctests(REPO)


def check_api_surface() -> list[str]:
    """Every ``repro.serving.__all__`` name must appear in docs/API.md."""
    return _surface.check_api_surface(REPO)


def main() -> int:
    errors = check_links() + run_doctests() + check_api_surface()
    for e in errors:
        print(f"[check_docs] {e}", file=sys.stderr)
    if not errors:
        n = len(md_files())
        print(f"[check_docs] OK: links in {n} markdown files resolve, "
              f"FORMATS.md doctests pass, serving __all__ covered by "
              f"API.md")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main())
