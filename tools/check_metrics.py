"""Metric-name lint (run by the CI ``docs`` job and tests/test_telemetry.py).

Every metric name emitted in ``src/`` — a string literal passed to
``.counter("...")`` / ``.gauge("...")`` / ``.histogram("...")``, which
covers both registry instruments and tracer counter tracks — must be
documented in ``docs/OBSERVABILITY.md``.  Dynamically built names (the
``kvstat_<key>`` forwarding namespace, ``STAT_PREFIX + k``) are not
string literals and are exempt from the per-name check, but the doc must
still describe the ``kvstat_`` namespace itself.

The check is textual on purpose: it needs no imports, runs in the docs
CI job without installing the package, and fails the moment someone
adds a metric without telling the one place operators look names up.

Usage:  python tools/check_metrics.py   (exit 0 = clean)
"""
from __future__ import annotations

import os
import re
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DOC = os.path.join(REPO, "docs", "OBSERVABILITY.md")
SRC = os.path.join(REPO, "src")

# .counter("name" / .gauge("name" / .histogram("name" — emission sites only
# (reads go through .get("...") / .value("...") and are not required here).
# \s* spans newlines: wrapped calls like ``.counter(\n    "name")`` count.
_EMIT = re.compile(
    r"\.(?:counter|gauge|histogram)\(\s*['\"]([A-Za-z0-9_.]+)['\"]")


def emitted_names() -> dict[str, list[str]]:
    """Metric name -> ["path:line", ...] of every literal emission site."""
    out: dict[str, list[str]] = {}
    for root, dirs, files in os.walk(SRC):
        dirs[:] = [d for d in dirs if d != "__pycache__"]
        for f in files:
            if not f.endswith(".py"):
                continue
            path = os.path.join(root, f)
            with open(path, encoding="utf-8") as fh:
                text = fh.read()
            rel = os.path.relpath(path, REPO)
            for m in _EMIT.finditer(text):
                line = text.count("\n", 0, m.start()) + 1
                out.setdefault(m.group(1), []).append(f"{rel}:{line}")
    return out


def check_metrics() -> list[str]:
    """Return human-readable error strings (empty = clean)."""
    if not os.path.exists(DOC):
        return ["docs/OBSERVABILITY.md is missing"]
    with open(DOC, encoding="utf-8") as fh:
        doc = fh.read()
    names = emitted_names()
    errors = []
    for name in sorted(names):
        if name not in doc:
            errors.append(
                f"metric {name!r} (emitted at {names[name][0]}) is not "
                f"documented in docs/OBSERVABILITY.md")
    if "kvstat_" not in doc:
        errors.append("docs/OBSERVABILITY.md no longer describes the "
                      "kvstat_ forwarding namespace")
    if not names:
        errors.append("no metric emissions found under src/ — "
                      "has the telemetry subsystem moved?")
    return errors


def main() -> int:
    errors = check_metrics()
    for e in errors:
        print(f"[check_metrics] {e}", file=sys.stderr)
    if not errors:
        print(f"[check_metrics] OK: {len(emitted_names())} metric names "
              f"emitted in src/ are all documented in "
              f"docs/OBSERVABILITY.md")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main())
