"""Metric-name lint — thin compatibility wrapper.

The check now lives in the unified analyzer as the ``surface-metrics``
pass (``tools/lint/passes/surface.py``; run via ``python -m tools.lint``).
This wrapper keeps the historical entry points working — the CI ``docs``
job and tests/test_telemetry.py load this file by path and call
``emitted_names()`` / ``check_metrics()`` with no arguments.

Usage:  python tools/check_metrics.py   (exit 0 = clean)
"""
from __future__ import annotations

import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if REPO not in sys.path:
    sys.path.insert(0, REPO)

from tools.lint.passes import surface as _surface  # noqa: E402


def emitted_names() -> dict[str, list[str]]:
    """Metric name -> ["path:line", ...] of every literal emission site."""
    return _surface.emitted_names(REPO)


def check_metrics() -> list[str]:
    """Return human-readable error strings (empty = clean)."""
    return _surface.check_metrics(REPO)


def main() -> int:
    errors = check_metrics()
    for e in errors:
        print(f"[check_metrics] {e}", file=sys.stderr)
    if not errors:
        print(f"[check_metrics] OK: {len(emitted_names())} metric names "
              f"emitted in src/ are all documented in "
              f"docs/OBSERVABILITY.md")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main())
