"""Repo tooling (``tools.lint`` + thin compat CLI wrappers)."""
