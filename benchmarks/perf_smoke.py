"""Perf-smoke tier: small-shape serving/decode benchmarks + regression gate.

Runs in minutes on a CPU CI runner and writes ``BENCH_serving.json`` —
the first point of the repo's benchmark trajectory:

  * ``serving``  — the mixed long/short-prompt stream through the
    chunked-prefill engine (``kvcache_bench.run_mixed``): decode
    tokens/s, TTFT mean + p50/p95/p99 (per-request registry histograms
    from the fully instrumented run — the regression gate therefore
    covers telemetry overhead, also published as
    ``telemetry_overhead_frac``), prefill compile counts (chunked must
    stay at <= 1 per process; the whole-prompt engine's per-length count
    is the contrast figure);
  * ``oversubscribed`` — the deterministic swap/preemption workload
    (``kvcache_bench.run_oversubscribed``): swap traffic bytes and
    preemption counts (bit-identity is asserted inside);
  * ``speculative`` — the zero-extended draft/target pair at batch 1
    (``kvcache_bench.run_speculative``): acceptance rate (1.0 by
    construction — gated as a correctness canary) and spec vs
    target-only tok/s (bit-identity is asserted inside);
  * ``prefix`` — the chat-style common-prefix stream served with prefix
    sharing on vs off (``kvcache_bench.run_prefix_shared``): hit rate
    and matched-token counts (deterministic — gated as counts/bands),
    plus the hit requests' TTFT against the no-sharing baseline
    (strictly-below is asserted inside; one physical prefix copy and
    bit-identity too);
  * ``frontend`` — the bursty trace-replay through the async front end
    + router over 2 engine replicas (``load_replay.run``): streamed
    TTFT p50/p95 (submit → first token on the stream), throughput, and
    the shed rate / completion counts under the spike (deterministic —
    gated as bands; async-vs-sync bit-identity is asserted inside);
  * ``decode`` — the ECF8 decode microbench at its smallest shape
    (``decode_microbench``): MB/s of the jnp and fixed-rate paths.

``--check BASELINE`` compares against a committed baseline
(``benchmarks/baselines/BENCH_serving.json``) and **fails on a > 30 %
regression**.  Wall-clock metrics are normalized by a machine-speed
probe (a fixed numpy matmul timed in the same process) so the gate
tracks code regressions rather than runner-hardware variance; counter
metrics (compile counts, preemptions) must not grow at all, and swap
traffic bytes stay inside the same 30 % band.

Usage:
  PYTHONPATH=src python -m benchmarks.perf_smoke --out BENCH_serving.json \
      --check benchmarks/baselines/BENCH_serving.json
  PYTHONPATH=src python -m benchmarks.perf_smoke --out \
      benchmarks/baselines/BENCH_serving.json          # refresh baseline
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

TOLERANCE = 0.30

# metric path -> direction ("higher"/"lower" is better, probe-normalized;
# "count" must not increase; "band" must stay within TOLERANCE either way)
GATES = {
    ("serving", "chunked_tok_per_s"): "higher",
    ("serving", "chunked_ttft_mean_s"): "lower",
    ("serving", "chunked_prefill_compiles"): "count",
    ("oversubscribed", "swap_out_bytes"): "band",
    ("oversubscribed", "swap_in_bytes"): "band",
    ("oversubscribed", "n_preempted"): "count",
    ("speculative", "spec_tok_per_s"): "higher",
    ("speculative", "accept_rate"): "band",
    ("prefix", "hit_rate"): "band",
    ("prefix", "chunk_tokens_shared"): "count",
    ("prefix", "cow_splits"): "count",
    ("prefix", "ttft_hit_shared_s"): "lower",
    ("frontend", "ttft_p50_s"): "lower",
    ("frontend", "ttft_p95_s"): "lower",
    ("frontend", "tok_per_s"): "higher",
    ("frontend", "shed_rate"): "band",
    ("frontend", "n_completed"): "band",
    ("decode", "tpu_jnp_MBps"): "higher",
    ("decode", "fr_MBps"): "higher",
}
_TIMED = ("higher", "lower")


def machine_probe_mflops() -> float:
    """MFLOP/s of a fixed f32 matmul — the machine-speed proxy that
    normalizes wall-clock gates across CI runners.  Best-of-5 trials of
    the *minimum* per-call time: the fastest observation is the stable
    one (scheduling noise only ever slows a call down)."""
    a = np.random.default_rng(0).standard_normal((384, 384)).astype(
        np.float32)
    for _ in range(3):
        a @ a
    best = float("inf")
    for _ in range(5):
        t0 = time.perf_counter()
        n = 10
        for _ in range(n):
            (a @ a).sum()
        best = min(best, (time.perf_counter() - t0) / n)
    return 2 * 384 ** 3 / best / 1e6


def collect(verbose: bool = True, repeats: int = 3,
            trace_out: str | None = None) -> dict:
    """Gather the smoke metrics.  Timed benches run ``repeats`` times and
    keep their **best** observation (load spikes only ever slow a run
    down — best-of is the stable statistic on a shared CI runner);
    compile counts come from the first, cold run (later runs hit the
    process-wide jit cache by design).  ``trace_out`` saves the
    oversubscribed run's Chrome-trace JSON (the CI artifact next to
    ``BENCH_serving.json``)."""
    from benchmarks import decode_microbench, kvcache_bench, load_replay
    probe = machine_probe_mflops()
    decs = [decode_microbench.run(verbose=verbose and i == 0,
                                  sizes=(1 << 16,))[0]
            for i in range(repeats)]
    mixeds = [kvcache_bench.run_mixed(verbose=verbose and i == 0)
              for i in range(repeats)]
    dec = {k: max(d[k] for d in decs) for k in ("tpu_jnp_MBps", "fr_MBps")}
    over = kvcache_bench.run_oversubscribed(verbose=verbose,
                                            trace_out=trace_out)
    specs = [kvcache_bench.run_speculative(verbose=verbose and i == 0)
             for i in range(repeats)]
    spec = max(specs, key=lambda r: r["spec_tok_per_s"])
    prefs = [kvcache_bench.run_prefix_shared(verbose=verbose and i == 0)
             for i in range(repeats)]
    pref = min(prefs, key=lambda r: r["ttft_hit_shared_s"])
    fronts = [load_replay.run(verbose=verbose and i == 0)
              for i in range(repeats)]
    front = fronts[0]           # counts are deterministic across repeats
    return {
        "schema": 1,
        "probe_mflops": probe,
        "serving": {
            "chunked_tok_per_s": max(m["chunked"]["tok_per_s"]
                                     for m in mixeds),
            "chunked_ttft_mean_s": min(m["chunked"]["ttft_mean_s"]
                                       for m in mixeds),
            "chunked_ttft_short_mean_s":
                min(m["chunked"]["ttft_short_mean_s"] for m in mixeds),
            # per-request submit->first-token percentiles from the
            # telemetry registry histogram of the instrumented run (the
            # gated mean above keeps baseline compatibility)
            "chunked_ttft_p50_s": min(m["chunked"]["ttft_p50_s"]
                                      for m in mixeds),
            "chunked_ttft_p95_s": min(m["chunked"]["ttft_p95_s"]
                                      for m in mixeds),
            "chunked_ttft_p99_s": min(m["chunked"]["ttft_p99_s"]
                                      for m in mixeds),
            "telemetry_overhead_frac":
                min(m["chunked"]["telemetry_overhead_frac"]
                    for m in mixeds),
            "chunked_prefill_compiles":
                mixeds[0]["chunked"]["prefill_compiles"],
            "whole_tok_per_s": max(m["whole"]["tok_per_s"]
                                   for m in mixeds),
            "whole_ttft_mean_s": min(m["whole"]["ttft_mean_s"]
                                     for m in mixeds),
            "whole_prefill_compiles":
                mixeds[0]["whole"]["prefill_compiles"],
        },
        "oversubscribed": {
            "swap_out_bytes": over["swap_out_bytes"],
            "swap_in_bytes": over["swap_in_bytes"],
            "n_preempted": over["n_preempted"],
            "steps": over["steps"],
        },
        "speculative": {
            # best-of run, same statistic discipline as the other timed
            # benches; acceptance is 1.0 by construction (zero-extended
            # target) so "band" gates it as a correctness canary
            "k": spec["k"],
            "accept_rate": spec["accept_rate"],
            "tokens_per_round": spec["tokens_per_round"],
            "target_tok_per_s": spec["target_tok_per_s"],
            "spec_tok_per_s": spec["spec_tok_per_s"],
            "speedup": spec["speedup"],
        },
        "prefix": {
            # hit rate / matched tokens / CoW splits are deterministic
            # on this workload; the TTFT pair is best-of like the other
            # timed benches (strictly-below is asserted per run inside)
            "n_requests": pref["n_requests"],
            "prefix_tokens": pref["prefix_tokens"],
            "hit_rate": pref["hit_rate"],
            "match_tokens": pref["match_tokens"],
            "chunk_tokens_shared": pref["chunk_tokens_shared"],
            "chunk_tokens_nosharing": pref["chunk_tokens_nosharing"],
            "cow_splits": pref["cow_splits"],
            "ttft_hit_nosharing_s": min(p["ttft_hit_nosharing_s"]
                                        for p in prefs),
            "ttft_hit_shared_s": pref["ttft_hit_shared_s"],
            "ttft_speedup": max(p["ttft_speedup"] for p in prefs),
        },
        "frontend": {
            # the shed set / completion counts / prefix hits are
            # deterministic (tick-based replay); the latency and
            # throughput stats are best-of like every timed bench
            "n_requests": front["n_requests"],
            "n_replicas": front["n_replicas"],
            "n_completed": front["n_completed"],
            "n_shed": front["n_shed"],
            "shed_rate": front["shed_rate"],
            "prefix_hits": front["prefix_hits"],
            "tok_per_s": max(f["tok_per_s"] for f in fronts),
            "ttft_p50_s": min(f["ttft_p50_s"] for f in fronts),
            "ttft_p95_s": min(f["ttft_p95_s"] for f in fronts),
        },
        "decode": {
            "tpu_jnp_MBps": dec["tpu_jnp_MBps"],
            "fr_MBps": dec["fr_MBps"],
        },
    }


def check(measured: dict, baseline: dict, tol: float = TOLERANCE) -> list:
    """Regression gate -> list of failure strings (empty = pass)."""
    fails = []
    scale = measured["probe_mflops"] / max(baseline["probe_mflops"], 1e-9)
    for (sec, key), kind in GATES.items():
        try:
            m, b = measured[sec][key], baseline[sec][key]
        except KeyError:
            fails.append(f"{sec}.{key}: missing from measurement/baseline")
            continue
        if kind in _TIMED:
            # a regression must show both raw (same-class runner) and
            # probe-normalized (a runner half the baseline machine's
            # speed is expected to hit half the tokens/s and twice the
            # TTFT) — requiring both keeps probe noise from failing a
            # healthy run while a real 30% code regression fails both
            norm = (m / max(scale, 1e-9) if kind == "higher"
                    else m * scale)
            if kind == "higher":
                bad = (m < (1 - tol) * b) and (norm < (1 - tol) * b)
            else:
                bad = (m > (1 + tol) * b) and (norm > (1 + tol) * b)
            if bad:
                fails.append(
                    f"{sec}.{key}: {m:.4g} (probe-normalized {norm:.4g}) "
                    f"vs baseline {b:.4g} — >{tol:.0%} regression")
        elif kind == "count":
            if m > b:
                fails.append(f"{sec}.{key}: {m} > baseline {b}")
        else:  # band
            if not (1 - tol) * b <= m <= (1 + tol) * b:
                fails.append(f"{sec}.{key}: {m} outside +-{tol:.0%} of "
                             f"baseline {b}")
    return fails


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_serving.json")
    ap.add_argument("--check", default=None, metavar="BASELINE_JSON",
                    help="compare against a committed baseline and exit "
                         "non-zero on a >30%% regression")
    ap.add_argument("--quiet", action="store_true")
    ap.add_argument("--trace-out", default=None, metavar="TRACE_JSON",
                    help="write the oversubscribed run's Chrome-trace "
                         "JSON (uploaded as a CI artifact next to the "
                         "benchmark JSON)")
    args = ap.parse_args(argv)

    measured = collect(verbose=not args.quiet, trace_out=args.trace_out)
    with open(args.out, "w") as f:
        json.dump(measured, f, indent=2, sort_keys=True)
        f.write("\n")
    srv = measured["serving"]
    print(f"[perf-smoke] wrote {args.out} "
          f"(probe {measured['probe_mflops']:.0f} MFLOP/s, serving "
          f"{srv['chunked_tok_per_s']:.1f} tok/s, TTFT mean "
          f"{srv['chunked_ttft_mean_s'] * 1e3:.0f} ms, p50/p95/p99 "
          f"{srv['chunked_ttft_p50_s'] * 1e3:.0f}/"
          f"{srv['chunked_ttft_p95_s'] * 1e3:.0f}/"
          f"{srv['chunked_ttft_p99_s'] * 1e3:.0f} ms)")
    spc = measured["speculative"]
    print(f"[perf-smoke] speculative {spc['spec_tok_per_s']:.1f} tok/s vs "
          f"target-only {spc['target_tok_per_s']:.1f} "
          f"({spc['speedup']:.2f}x at accept rate "
          f"{spc['accept_rate']:.2f}, k={spc['k']})")
    pfx = measured["prefix"]
    print(f"[perf-smoke] prefix sharing hit rate {pfx['hit_rate']:.2f}, "
          f"hit TTFT {pfx['ttft_hit_shared_s'] * 1e3:.0f} ms vs "
          f"no-sharing {pfx['ttft_hit_nosharing_s'] * 1e3:.0f} ms "
          f"({pfx['ttft_speedup']:.2f}x, "
          f"{pfx['match_tokens']} prompt tokens never recomputed)")
    fr = measured["frontend"]
    print(f"[perf-smoke] frontend replay {fr['n_completed']}/"
          f"{fr['n_requests']} completed on {fr['n_replicas']} replicas "
          f"({fr['shed_rate']:.0%} shed), {fr['tok_per_s']:.1f} tok/s "
          f"streamed, TTFT p50/p95 {fr['ttft_p50_s'] * 1e3:.0f}/"
          f"{fr['ttft_p95_s'] * 1e3:.0f} ms, "
          f"{fr['prefix_hits']} prefix hits")
    print(f"[perf-smoke] telemetry overhead "
          f"{srv['telemetry_overhead_frac']:.1%} tok/s "
          f"(target < 2%; the published chunked numbers come from the "
          f"instrumented run, so the {TOLERANCE:.0%} gate bounds it)")

    if args.check:
        with open(args.check) as f:
            baseline = json.load(f)
        fails = check(measured, baseline)
        if fails:
            for msg in fails:
                print(f"[perf-smoke] REGRESSION {msg}")
            raise SystemExit(1)
        print(f"[perf-smoke] no regression vs {args.check} "
              f"(tolerance {TOLERANCE:.0%})")
    return measured


if __name__ == "__main__":
    main()
