"""Paper Table 1: lossless memory savings per model.

For every assigned architecture (plus the paper's own Qwen3-8B row),
synthesize trained-like fp8 weights at true per-tensor shapes, compress
with all three containers, verify bit-exactness, and report the savings.
The paper's band is 9.8-26.9% (LLMs 9.8-14.8%, DiT-like 21-26.9%); our
per-family alphas land the synthesized savings inside those bands.
"""
from __future__ import annotations

import numpy as np

from repro.configs import ASSIGNED, get
from repro.core import fixedrate, paper_format, tpu_format
from .common import arch_layer_tensors


def run(verbose: bool = True):
    results = []
    archs = ASSIGNED + ["qwen3-8b"]
    for arch in archs:
        tensors, cfg = arch_layer_tensors(arch)
        tot = {"fp8": 0, "paper": 0, "tpu": 0, "fr": 0}
        for tname, bits in tensors.items():
            n = bits.size
            cp = paper_format.encode(bits)
            ct = tpu_format.encode(bits)
            cf = fixedrate.encode(bits)
            # lossless verification: vectorized decoders on every tensor;
            # the paper container's sequential python decoder only on small
            # tensors (exhaustively covered in tests/test_lossless.py)
            if n <= 100_000:
                assert np.array_equal(paper_format.decode_sequential(cp),
                                      bits)
            assert np.array_equal(
                np.asarray(tpu_format.decode_jnp(ct)), bits.reshape(-1))
            assert np.array_equal(fixedrate.decode_ref(cf), bits)
            tot["fp8"] += n
            tot["paper"] += cp.n_bytes_total
            tot["tpu"] += ct.nbytes("ragged")
            tot["fr"] += cf.nbytes
        row = {
            "arch": arch, "family": cfg.family,
            "paper_save": 100 * (1 - tot["paper"] / tot["fp8"]),
            "tpu_save": 100 * (1 - tot["tpu"] / tot["fp8"]),
            "fr_save": 100 * (1 - tot["fr"] / tot["fp8"]),
            "params_b": cfg.param_count() / 1e9,
        }
        results.append(row)
        if verbose:
            print(f"{arch:26s} [{cfg.family:6s}] {row['params_b']:6.1f}B  "
                  f"paper {row['paper_save']:5.1f}%  "
                  f"ECF8-TPU {row['tpu_save']:5.1f}%  "
                  f"ECF8-FR {row['fr_save']:5.1f}%   lossless ✓")
    saves = [r["tpu_save"] for r in results]
    if verbose:
        print(f"\nECF8-TPU savings range: [{min(saves):.1f}%,"
              f" {max(saves):.1f}%] — paper Table 1 band: 9.8-26.9%")
    assert 5.0 < min(saves) and max(saves) < 35.0, saves
    return results


if __name__ == "__main__":
    run()
