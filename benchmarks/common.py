"""Shared benchmark utilities: per-arch synthetic trained-like weights."""
from __future__ import annotations

import time

import numpy as np

from repro.configs import get
from repro.core import stats

# per-family alpha: DiT-like models show heavier concentration in the paper
# (25-27% savings) vs LLMs (10-15%); we model that with family alphas fitted
# so the synthesized savings land inside the paper's per-family bands.
FAMILY_ALPHA = {
    "dense": 1.9, "moe": 1.85, "hybrid": 1.9, "ssm": 1.9, "vlm": 1.8,
    "audio": 1.9, "dit": 1.55,
}

MAX_SAMPLE_ELEMS = 1_000_000


def arch_layer_tensors(name: str, seed: int = 0):
    """Representative weight tensors of one layer (+ embedding slice) at
    true shapes (column-sliced to cap encode time; the compression ratio is
    a per-element statistic, so slicing does not change it)."""
    cfg = get(name)
    d, hd = cfg.d_model, cfg.hd
    alpha = FAMILY_ALPHA.get(cfg.family, 1.9)

    def cap(shape):
        n = int(np.prod(shape))
        if n <= MAX_SAMPLE_ELEMS:
            return shape
        scale = n / MAX_SAMPLE_ELEMS
        return (shape[0], max(int(shape[1] / scale), 1))

    out = {}
    k = seed
    ts = {
        "wq": (d, cfg.n_heads * hd),
        "wk": (d, cfg.n_kv_heads * hd),
        "wo": (cfg.n_heads * hd, d),
        "embed": (cfg.vocab_size, d),
    }
    if cfg.d_ff:
        ts["wi"] = (d, cfg.d_ff)
    if cfg.n_experts:
        ts["expert_wi"] = (cfg.n_experts * d, cfg.moe_d_ff)
    for name_, shape in ts.items():
        k += 1
        out[name_] = stats.synthesize_fp8_weights(
            cap(shape), alpha=alpha, seed=k)
    return out, cfg


def timed(fn, *args, repeat: int = 3, **kw):
    """(result, seconds_per_call) with a warmup call."""
    fn(*args, **kw)
    t0 = time.perf_counter()
    for _ in range(repeat):
        r = fn(*args, **kw)
    return r, (time.perf_counter() - t0) / repeat
