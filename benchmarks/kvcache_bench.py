"""KV-cache exponent entropy per layer (fig1-style) + memory savings.

The paper's Figure 1 measures exponent entropy of *weights*; this
benchmark measures the same statistic on K/V cache pages produced by
real prefills, validating the Heilper & Singer observation the kvcache
subsystem is built on: cache activations concentrate their exponents
just like trained weights, so the page codec's entropy coding wins.

Reports, per arch / layer / K-or-V:
  * Shannon entropy of the bf16 8-bit exponent field (bits/element);
  * the page codec's true compressed ratio vs raw bf16 bytes;
and an engine-level savings table (paged pages-in-use vs the monolithic
``(max_batch, max_len)`` cache) from a short mixed-length stream.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get, smoke_variant
from repro.core import stats
from repro.kvcache import codec
from repro.models import model as M
from repro.runtime.monitor import KVCacheMonitor
from repro.serving import GenerationEngine, Request

ARCHS = ("qwen3-8b", "gemma2-9b")
PREFILL_T = 64


def _attn_cache_leaves(cfg, cache):
    """Yield (layer_name, kind, k_or_v, (n_kv, T, hd) array)."""
    unit = cfg.unit
    n_units = cfg.n_layers // unit
    for j in range(unit):
        kind = cfg.pattern[j]
        if kind not in ("attn", "nope", "local"):
            continue
        leaf = cache["units"][f"pos{j}"]
        for u in range(n_units):
            for kn in ("k", "v"):
                yield f"L{u * unit + j}", kind, kn, np.asarray(leaf[kn][u, 0])
    for t in range(cfg.n_layers - n_units * unit):
        name = f"layer{t}"
        kind = cfg.layer_kind(n_units * unit + t)
        if kind not in ("attn", "nope", "local"):
            continue
        leaf = cache["tail"][name]
        for kn in ("k", "v"):
            yield f"L{n_units * unit + t}", kind, kn, np.asarray(leaf[kn][0])


def run(verbose: bool = True):
    rows = []
    for arch in ARCHS:
        cfg = smoke_variant(get(arch))
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        toks = jax.random.randint(jax.random.PRNGKey(1), (1, PREFILL_T), 0,
                                  cfg.vocab_size)
        _, cache = M.prefill(params, cfg, toks, max_len=PREFILL_T)
        for lname, kind, kn, kv in _attn_cache_leaves(cfg, cache):
            page = np.asarray(jnp.asarray(kv, jnp.bfloat16))
            exp, _, _ = codec.split_planes(page)
            H = stats.shannon_entropy(np.bincount(exp, minlength=256))
            cp = codec.encode_page(page)
            rows.append({"arch": arch, "layer": lname, "kind": kind,
                         "kv": kn, "H": H, "ratio": cp.ratio()})

    if verbose:
        print(f"{'arch':18s} {'layer':6s} {'kind':6s} {'kv':3s}"
              f" {'H(E8) bits':>10s} {'coded/raw':>10s}")
        for r in rows:
            print(f"{r['arch']:18s} {r['layer']:6s} {r['kind']:6s}"
                  f" {r['kv']:3s} {r['H']:10.3f} {r['ratio']:10.3f}")

    ents = [r["H"] for r in rows]
    ratios = [r["ratio"] for r in rows]
    assert 0.5 < min(ents) and max(ents) < 6.0, (min(ents), max(ents))
    assert max(ratios) < 1.0, max(ratios)   # every layer compresses

    # engine-level savings: mixed-length stream through the paged engine
    cfg = smoke_variant(get(ARCHS[0]))
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    mon = KVCacheMonitor()
    eng = GenerationEngine(params, cfg, max_batch=4, max_len=64,
                           page_size=16, compress_cold=True, kv_monitor=mon)
    rng = np.random.default_rng(0)
    for _ in range(8):
        eng.submit(Request(
            prompt=rng.integers(0, cfg.vocab_size,
                                size=rng.integers(2, 24)).tolist(),
            max_new_tokens=int(rng.integers(4, 24))))
    eng.run()
    s = mon.summary()
    if verbose:
        print(f"\nengine ({ARCHS[0]}, batch 4, window 64, page 16):")
        print(f"  monolithic cache      {s['monolithic_bytes']:>10d} B")
        print(f"  paged peak            {s['peak_paged_bytes']:>10d} B "
              f"({100 * (1 - s['paged_vs_monolithic']):.1f}% saved)")
        print(f"  cold-page compression {s['cold_compression_ratio']:.3f}x "
              f"raw")
    assert s["peak_paged_bytes"] < s["monolithic_bytes"]
    return {
        "layers": len(rows),
        "entropy_range": (min(ents), max(ents)),
        "worst_ratio": max(ratios),
        "paged_vs_monolithic": s["paged_vs_monolithic"],
        "cold_compression_ratio": s["cold_compression_ratio"],
    }


if __name__ == "__main__":
    run()
