"""KV-cache exponent entropy per layer (fig1-style) + memory savings.

The paper's Figure 1 measures exponent entropy of *weights*; this
benchmark measures the same statistic on K/V cache pages produced by
real prefills, validating the Heilper & Singer observation the kvcache
subsystem is built on: cache activations concentrate their exponents
just like trained weights, so the page codec's entropy coding wins.

Reports, per arch / layer / K-or-V:
  * Shannon entropy of the bf16 8-bit exponent field (bits/element);
  * the page codec's true compressed ratio vs raw bf16 bytes;
an engine-level savings table (paged pages-in-use vs the monolithic
``(max_batch, max_len)`` cache) from a short mixed-length stream; an
**oversubscription variant**: a workload whose aggregate page demand is
>= 2x the raw pool, served through the host swap tier + preemptive
scheduler (``--swap-bytes``), reporting swap-in/out bytes and preemption
counts and asserting the tokens stay bit-identical to the monolithic
reference; a **speculative variant** (``run_speculative``): a
zero-extended draft/target pair served at batch 1, reporting acceptance
rate vs tok/s speedup and asserting the spec tokens bit-identical to
target-only; and a **sharded variant** (subprocess with virtual devices,
like tests/test_sharding.py) that serves the same stream on a 2-way data
mesh and a 2-way model mesh, recording pages-per-shard and the
cross-shard gather cost of each layout (zero page bytes on the data mesh
by construction; the tiny per-layer (acc, m, l) stat-merge all-gather on
the model mesh), plus the oversubscribed workload on the 2-way data
mesh (per-shard free lists + per-shard swap ledgers, still
bit-identical).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get, smoke_variant
from repro.core import stats
from repro.kvcache import codec
from repro.models import model as M
from repro.runtime.monitor import KVCacheMonitor
from repro.serving import EngineConfig, GenerationEngine, Request

ARCHS = ("qwen3-8b", "gemma2-9b")
PREFILL_T = 64


def _attn_cache_leaves(cfg, cache):
    """Yield (layer_name, kind, k_or_v, (n_kv, T, hd) array)."""
    unit = cfg.unit
    n_units = cfg.n_layers // unit
    for j in range(unit):
        kind = cfg.pattern[j]
        if kind not in ("attn", "nope", "local"):
            continue
        leaf = cache["units"][f"pos{j}"]
        for u in range(n_units):
            for kn in ("k", "v"):
                yield f"L{u * unit + j}", kind, kn, np.asarray(leaf[kn][u, 0])
    for t in range(cfg.n_layers - n_units * unit):
        name = f"layer{t}"
        kind = cfg.layer_kind(n_units * unit + t)
        if kind not in ("attn", "nope", "local"):
            continue
        leaf = cache["tail"][name]
        for kn in ("k", "v"):
            yield f"L{n_units * unit + t}", kind, kn, np.asarray(leaf[kn][0])


def run(verbose: bool = True):
    rows = []
    for arch in ARCHS:
        cfg = smoke_variant(get(arch))
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        toks = jax.random.randint(jax.random.PRNGKey(1), (1, PREFILL_T), 0,
                                  cfg.vocab_size)
        _, cache = M.prefill(params, cfg, toks, max_len=PREFILL_T)
        for lname, kind, kn, kv in _attn_cache_leaves(cfg, cache):
            page = np.asarray(jnp.asarray(kv, jnp.bfloat16))
            exp, _, _ = codec.split_planes(page)
            H = stats.shannon_entropy(np.bincount(exp, minlength=256))
            cp = codec.encode_page(page)
            rows.append({"arch": arch, "layer": lname, "kind": kind,
                         "kv": kn, "H": H, "ratio": cp.ratio()})

    if verbose:
        print(f"{'arch':18s} {'layer':6s} {'kind':6s} {'kv':3s}"
              f" {'H(E8) bits':>10s} {'coded/raw':>10s}")
        for r in rows:
            print(f"{r['arch']:18s} {r['layer']:6s} {r['kind']:6s}"
                  f" {r['kv']:3s} {r['H']:10.3f} {r['ratio']:10.3f}")

    ents = [r["H"] for r in rows]
    ratios = [r["ratio"] for r in rows]
    assert 0.5 < min(ents) and max(ents) < 6.0, (min(ents), max(ents))
    assert max(ratios) < 1.0, max(ratios)   # every layer compresses

    # engine-level savings: mixed-length stream through the paged engine
    cfg = smoke_variant(get(ARCHS[0]))
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    mon = KVCacheMonitor()
    eng = GenerationEngine(params, cfg, config=EngineConfig(max_batch=4, max_len=64,
                           page_size=16, compress_cold=True, kv_monitor=mon))
    rng = np.random.default_rng(0)
    for _ in range(8):
        eng.submit(Request(
            prompt=rng.integers(0, cfg.vocab_size,
                                size=rng.integers(2, 24)).tolist(),
            max_new_tokens=int(rng.integers(4, 24))))
    eng.run()
    s = mon.summary()
    if verbose:
        print(f"\nengine ({ARCHS[0]}, batch 4, window 64, page 16):")
        print(f"  monolithic cache      {s['monolithic_bytes']:>10d} B")
        print(f"  paged peak            {s['peak_paged_bytes']:>10d} B "
              f"({100 * (1 - s['paged_vs_monolithic']):.1f}% saved)")
        print(f"  cold-page compression {s['cold_compression_ratio']:.3f}x "
              f"raw")
    assert s["peak_paged_bytes"] < s["monolithic_bytes"]

    over = run_oversubscribed(verbose=verbose)
    mixed = run_mixed(verbose=verbose)
    speculative = run_speculative(verbose=verbose)
    prefix = run_prefix_shared(verbose=verbose)
    sharded = run_sharded(verbose=verbose)
    return {
        "layers": len(rows),
        "entropy_range": (min(ents), max(ents)),
        "worst_ratio": max(ratios),
        "paged_vs_monolithic": s["paged_vs_monolithic"],
        "cold_compression_ratio": s["cold_compression_ratio"],
        "oversubscribed": over,
        "mixed": mixed,
        "speculative": speculative,
        "prefix": prefix,
        "sharded": sharded,
    }


def _zero_extended_target(dparams, dcfg, tcfg, seed: int = 99):
    """Graft the draft's weights into a deeper target whose extra blocks
    are exact identities: the extra layers' output projections (attn
    ``wo`` and mlp ``wo``) are zeroed, so each contributes ``x + 0`` to
    the residual stream and the target's logits are **bit-equal** to the
    draft's — while a target step costs ``n_layers_t / n_layers_d`` x
    the draft step.  This turns the smoke-shape speculative bench into a
    real measurement: acceptance is 1.0 by construction (random smoke
    weights would accept ~1/V of proposals) and any speedup comes from
    the engine actually replacing k+1 target decode steps with cheap
    draft steps plus one k+1-wide verify forward."""
    tparams = M.init_params(jax.random.PRNGKey(seed), tcfg)
    n_d = dcfg.n_layers
    dflat = {jax.tree_util.keystr(p): v
             for p, v in jax.tree_util.tree_flatten_with_path(dparams)[0]}

    def graft(path, leaf):
        names = [str(getattr(k, "key", getattr(k, "idx", k)))
                 for k in path]
        if "units" not in names:
            return dflat[jax.tree_util.keystr(path)]    # embed/norm/unembed
        if names[-1] == "wo":
            leaf = jnp.zeros_like(leaf)
        return leaf.at[:n_d].set(dflat[jax.tree_util.keystr(path)])

    return jax.tree_util.tree_map_with_path(graft, tparams)


def run_speculative(verbose: bool = True, spec_k: int = 4,
                    target_layers: int = 16):
    """Speculative decoding headline: acceptance rate vs tok/s speedup.

    Serves the same greedy stream through the target-only engine and the
    speculative engine (draft proposes ``spec_k`` tokens/round, target
    verifies all k+1 positions in one batched forward with exact
    rejection sampling), asserting the spec output **bit-identical** to
    target-only and reporting acceptance rate, tokens/round and the
    tok/s speedup.  The draft/target pair is the zero-extended
    construction (``_zero_extended_target``), so acceptance is exactly
    1.0 and the speedup is a pure engine-efficiency figure.  The stream
    serves at batch 1 — the latency-bound regime speculative decoding
    targets (the verify forward runs per slot, so at high batch
    occupancy the saved decode steps are offset by per-slot verify
    dispatches; at smoke shapes the crossover is ~batch 2).  Feeds the
    ``speculative`` section of ``BENCH_serving.json`` (perf-smoke CI
    tier)."""
    import time
    from dataclasses import replace
    dcfg = smoke_variant(get(ARCHS[0]))
    tcfg = replace(dcfg, n_layers=target_layers)
    dparams = M.init_params(jax.random.PRNGKey(0), dcfg)
    tparams = _zero_extended_target(dparams, dcfg, tcfg)

    def stream():
        rng = np.random.default_rng(3)
        return [Request(prompt=rng.integers(1, dcfg.vocab_size,
                                            size=rng.integers(4, 12)).tolist(),
                        max_new_tokens=24, id=30_000 + i)
                for i in range(3)]

    def serve(**kw):
        def once():
            eng = GenerationEngine(tparams, tcfg, config=EngineConfig(max_batch=1, max_len=64,
                                   page_size=16, **kw))
            reqs = stream()
            for r in reqs:
                eng.submit(r)
            t0 = time.perf_counter()
            eng.run()
            dt = time.perf_counter() - t0
            toks = sum(len(r.out_tokens) for r in reqs)
            return [r.out_tokens for r in reqs], toks / max(dt, 1e-9), eng
        once()                      # warm the jit caches
        return once()

    base_toks, base_tps, _ = serve()
    spec_toks, spec_tps, eng = serve(draft_params=dparams, draft_cfg=dcfg,
                                     spec_k=spec_k)
    assert eng.spec_on, "speculative gating rejected the smoke pair"
    assert spec_toks == base_toks, \
        "speculative decoding deviated from target-only"
    sc = eng.spec_counters()
    n_tok = sum(len(t) for t in spec_toks)
    out = {
        "k": spec_k,
        "draft_layers": dcfg.n_layers,
        "target_layers": tcfg.n_layers,
        "accept_rate": sc["spec_accept_rate"],
        "rounds": sc["spec_rounds"],
        "drafted": sc["spec_drafted"],
        "accepted": sc["spec_accepted"],
        "tokens_per_round": n_tok / max(sc["spec_rounds"], 1),
        "target_tok_per_s": base_tps,
        "spec_tok_per_s": spec_tps,
        "speedup": spec_tps / max(base_tps, 1e-9),
        "bit_identical_to_target_only": True,
    }
    assert out["accept_rate"] == 1.0, out["accept_rate"]
    if out["speedup"] < 1.0:
        # correctness (bit-identity, acceptance) is asserted above; raw
        # speedup on the tiny smoke shapes is CPU-warmth-dependent, so
        # regressions are gated by perf_smoke's baseline comparison
        # (machine-probe normalised) rather than a hard assert here
        import warnings
        warnings.warn(f"speculative smoke speedup {out['speedup']:.2f}x "
                      f"< 1.0 on this run", stacklevel=2)
    if verbose:
        print(f"\nspeculative decoding ({ARCHS[0]} smoke: "
              f"{dcfg.n_layers}-layer draft -> {tcfg.n_layers}-layer "
              f"zero-extended target, k={spec_k}, batch 1):")
        print(f"  target-only {base_tps:8.1f} tok/s")
        print(f"  speculative {spec_tps:8.1f} tok/s "
              f"({out['speedup']:.2f}x, accept rate "
              f"{out['accept_rate']:.3f}, "
              f"{out['tokens_per_round']:.2f} tokens/round)")
        print("  spec tokens bit-identical to target-only: True")
    return out


def run_prefix_shared(verbose: bool = True):
    """Cross-request prefix sharing on a chat-style workload.

    Every request carries the same 48-token system prompt plus a short
    per-user suffix.  The stream is served twice through the chunked
    engine — sharing off, then sharing on — and the bench asserts the
    sharing run is **bit-identical**, that the N-1 follow-up requests
    all hit the prefix index, that while the hits are in flight they
    hold ONE physical copy of the prefix pages (checked on the
    refcounts and the page tables, not the stats), and that the hit
    requests' wall-clock TTFT lands **strictly below** the no-sharing
    baseline (the matched 48 of 51 prompt tokens are never recomputed,
    so a hit pays one chunk step instead of seven).  Feeds the
    ``prefix`` section of ``BENCH_serving.json`` (perf-smoke CI tier)."""
    import time
    from repro.serving.telemetry import Telemetry
    cfg = smoke_variant(get(ARCHS[0]))
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(11)
    prefix = rng.integers(1, cfg.vocab_size, size=48).tolist()  # 6 pages
    suffixes = [rng.integers(1, cfg.vocab_size, size=3).tolist()
                for _ in range(6)]

    def stream():
        return [Request(prompt=prefix + sfx, max_new_tokens=8,
                        id=40_000 + i)
                for i, sfx in enumerate(suffixes)]

    def drive(eng, reqs, ttft, t0):
        for _ in range(10_000):
            busy = eng.step()
            now = time.perf_counter() - t0
            for r in reqs:
                if r.out_tokens and r.id not in ttft:
                    ttft[r.id] = now
            if not busy and not any(s is not None for s in eng.slots):
                break
        assert all(r.done for r in reqs)

    def serve(sharing: bool):
        tel = Telemetry()
        eng = GenerationEngine(params, cfg, config=EngineConfig(max_batch=3, max_len=64,
                               cache_mode="paged", page_size=8,
                               prefill_chunk=8, telemetry=tel,
                               prefix_sharing=sharing))
        reqs, ttft = stream(), {}
        # the first request warms the index (a miss either way) ...
        eng.submit(reqs[0])
        drive(eng, reqs[:1], ttft, time.perf_counter())
        # ... then the chat follow-ups arrive together
        t0 = time.perf_counter()
        for r in reqs[1:]:
            eng.submit(r)
        eng.step()
        if sharing:
            # one physical copy while the hits are in flight: every
            # admitted slot's page table starts with the SAME pids,
            # refcounted once per slot plus once for the index
            slots = [eng.slots.index(r) for r in reqs[1:] if r in eng.slots]
            rows = [eng.paged._slot_pages[s][:len(prefix) // 8]
                    for s in slots]
            assert len(rows) >= 2 and all(r == rows[0] for r in rows), rows
            for pid in rows[0]:
                assert eng.paged._ref[pid] == len(rows) + 1
        now = time.perf_counter() - t0
        for r in reqs:
            if r.out_tokens and r.id not in ttft:
                ttft[r.id] = now
        drive(eng, reqs, ttft, t0)
        hit_ttft = [ttft[r.id] for r in reqs[1:]]
        reg = tel.registry
        return {
            "tokens": [r.out_tokens for r in reqs],
            "ttft_hit_mean_s": sum(hit_ttft) / len(hit_ttft),
            "chunk_tokens": eng.n_chunk_tokens,
            "hits": reg.counter("prefix_hit_total").value,
            "misses": reg.counter("prefix_miss_total").value,
            "match_tokens": reg.counter("prefix_match_tokens_total").value,
            "stats": eng.paged.stats(),
        }

    serve(False)                        # warm the jit caches
    off = serve(False)
    on = serve(True)
    assert on.pop("tokens") == off.pop("tokens"), \
        "prefix sharing deviated from the no-sharing engine"
    sp = on.pop("stats")
    off.pop("stats")
    n = len(suffixes)
    assert on["hits"] == n - 1 and on["misses"] == 1, (on["hits"],
                                                      on["misses"])
    assert on["match_tokens"] == (n - 1) * len(prefix)
    assert sp["prefix_cow_splits_total"] == 0
    assert on["chunk_tokens"] == off["chunk_tokens"] - on["match_tokens"]
    assert on["ttft_hit_mean_s"] < off["ttft_hit_mean_s"], (on, off)
    out = {
        "n_requests": n,
        "prefix_tokens": len(prefix),
        "hit_rate": on["hits"] / n,
        "match_tokens": on["match_tokens"],
        "chunk_tokens_nosharing": off["chunk_tokens"],
        "chunk_tokens_shared": on["chunk_tokens"],
        "ttft_hit_nosharing_s": off["ttft_hit_mean_s"],
        "ttft_hit_shared_s": on["ttft_hit_mean_s"],
        "ttft_speedup": off["ttft_hit_mean_s"] / max(on["ttft_hit_mean_s"],
                                                     1e-9),
        "cow_splits": sp["prefix_cow_splits_total"],
        "prefix_retired_total": sp["prefix_retired_total"],
        "bit_identical_to_nosharing": True,
    }
    if verbose:
        print(f"\nprefix sharing ({ARCHS[0]}, batch 3, {n} chat requests, "
              f"{len(prefix)}-token shared system prompt):")
        print(f"  hit rate {out['hit_rate']:.2f} "
              f"({on['hits']} hits / {on['misses']} miss), "
              f"{out['match_tokens']} prompt tokens never recomputed")
        print(f"  prefill chunk tokens {out['chunk_tokens_nosharing']} -> "
              f"{out['chunk_tokens_shared']}")
        print(f"  hit TTFT {out['ttft_hit_nosharing_s'] * 1e3:7.1f} ms -> "
              f"{out['ttft_hit_shared_s'] * 1e3:7.1f} ms "
              f"({out['ttft_speedup']:.2f}x)")
        print("  shared tokens bit-identical to no-sharing: True "
              "(one physical prefix copy asserted on the refcounts)")
    return out


# long-prompt/short-prompt mix for the chunked-prefill TTFT benchmark: the
# long prompts monopolize whole-prompt prefill while the short requests
# wait; chunked prefill bounds that head-of-line blocking per step
MIXED_WORKLOAD = (
    [48, 4, 40, 6, 3, 44, 8, 5],                        # prompt lengths
    [12, 10, 12, 10, 12, 10, 12, 10],                   # max_new_tokens
)


def _mixed_stream(cfg, id_base=20_000):
    rng = np.random.default_rng(7)
    lens, news = MIXED_WORKLOAD
    return [Request(prompt=rng.integers(1, cfg.vocab_size, size=n).tolist(),
                    max_new_tokens=m, id=id_base + i)
            for i, (n, m) in enumerate(zip(lens, news))]


def run_mixed(verbose: bool = True, trace_out: str | None = None):
    """Chunked vs whole-prompt prefill on a mixed long/short stream.

    Drives ``engine.step()`` by hand and records, per request, the
    host wall-clock **time to first token** (submit -> first sampled
    token) plus end-to-end decode tokens/s; asserts the chunked engine's
    tokens are bit-identical to the whole-prompt engine's and that the
    chunk path compiled exactly one prefill program for every prompt
    length in the stream (the whole-prompt engine compiles one per
    distinct length).  These numbers seed ``BENCH_serving.json`` in the
    perf-smoke CI tier (``benchmarks/perf_smoke.py``).

    The **published chunked numbers come from a fully instrumented run**
    (metrics registry + span tracer), so the perf-smoke regression gate
    covers telemetry overhead by construction; the same run's registry
    histograms supply the TTFT p50/p95/p99, and an identical chunked run
    with telemetry *off* pins ``telemetry_overhead_frac`` (tok/s cost of
    observation; target < 2%) and the on/off bit-identity."""
    import time
    from repro.serving.telemetry import Telemetry
    cfg = smoke_variant(get(ARCHS[0]))
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    short = {i for i, n in enumerate(MIXED_WORKLOAD[0]) if n <= 8}

    def serve(telemetry=None, **kw):
        eng = GenerationEngine(params, cfg, config=EngineConfig(max_batch=4, max_len=64,
                               page_size=16, telemetry=telemetry, **kw))
        # the jitted-step caches are process-shared across engines, so
        # report the *delta* this stream caused
        c0 = eng.prefill_compile_count()
        reqs = _mixed_stream(cfg)
        for r in reqs:
            eng.submit(r)
        ttft = {}
        t0 = time.perf_counter()
        for _ in range(10_000):
            busy = eng.step()
            now = time.perf_counter() - t0
            for i, r in enumerate(reqs):
                if r.out_tokens and i not in ttft:
                    ttft[i] = now
            if not busy and not any(s is not None for s in eng.slots):
                break
        dt = time.perf_counter() - t0
        assert all(r.done for r in reqs)
        toks = sum(len(r.out_tokens) for r in reqs)
        return {
            "tokens": [r.out_tokens for r in reqs],
            "tok_per_s": toks / max(dt, 1e-9),
            "ttft_mean_s": sum(ttft.values()) / len(ttft),
            "ttft_short_mean_s": (sum(ttft[i] for i in short)
                                  / len(short)),
            "steps": eng.steps,
            "prefill_compiles": eng.prefill_compile_count() - c0,
        }

    whole = serve()
    bare = serve(prefill_chunk=16)          # chunked, telemetry off (warm)
    tel = Telemetry()
    chunked = serve(prefill_chunk=16, telemetry=tel)
    assert bare["tokens"] == whole["tokens"], \
        "chunked prefill deviated from the whole-prompt engine"
    assert chunked.pop("tokens") == bare.pop("tokens"), \
        "telemetry changed the token stream"
    whole.pop("tokens")
    # one chunk program serves every prompt length (0 when an earlier
    # engine in this process already traced it); the whole-prompt engine
    # retraces per distinct length not yet seen by the shared jit cache
    assert bare["prefill_compiles"] <= 1, bare["prefill_compiles"]
    assert whole["prefill_compiles"] >= chunked["prefill_compiles"]
    h = tel.registry.get("serving_ttft_seconds")
    chunked.update(
        ttft_p50_s=h.percentile(0.50), ttft_p95_s=h.percentile(0.95),
        ttft_p99_s=h.percentile(0.99),
        # both chunked runs are warm (`whole` paid the params transfer /
        # first-dispatch cost), so their tok/s ratio isolates what the
        # registry + tracer cost on top of identical engine work
        telemetry_overhead_frac=max(
            1 - chunked["tok_per_s"] / max(bare["tok_per_s"], 1e-9), 0.0))
    if trace_out:
        from repro.runtime.trace_export import export_chrome_trace
        export_chrome_trace(tel.tracer, trace_out, registry=tel.registry)
    out = {"whole": whole, "chunked": chunked,
           "prompt_lengths": sorted(set(MIXED_WORKLOAD[0]))}
    if verbose:
        print(f"\nmixed long/short stream ({ARCHS[0]}, batch 4, "
              f"{len(MIXED_WORKLOAD[0])} requests, prompt lengths "
              f"{out['prompt_lengths']}):")
        for name, r in (("whole-prompt", whole), ("chunked(16)", chunked)):
            print(f"  {name:12s} {r['tok_per_s']:8.1f} tok/s  TTFT mean "
                  f"{r['ttft_mean_s'] * 1e3:7.1f} ms (short "
                  f"{r['ttft_short_mean_s'] * 1e3:7.1f} ms)  "
                  f"{r['prefill_compiles']} prefill compile(s)")
        print(f"  chunked TTFT p50/p95/p99 "
              f"{chunked['ttft_p50_s'] * 1e3:.1f}/"
              f"{chunked['ttft_p95_s'] * 1e3:.1f}/"
              f"{chunked['ttft_p99_s'] * 1e3:.1f} ms (registry histogram)")
        frac = chunked["telemetry_overhead_frac"]
        print(f"  telemetry overhead {frac:.1%} tok/s vs uninstrumented "
              f"chunked (target < 2%)")
        print("  chunked tokens bit-identical to whole-prompt "
              "(telemetry on and off): True")
        if trace_out:
            print(f"  wrote Chrome trace {trace_out}")
    return out


# mixed-length, mixed-priority stream sized so its aggregate page demand
# is >= 2x the raw pools used below; injected into _SHARDED_BODY too, and
# mirrored by tests/test_serving.py's oversubscription tests
OVERSUB_WORKLOAD = (
    [[i + 1] * (7 + 3 * (i % 3)) for i in range(6)],    # prompts
    [14, 10, 16, 9, 12, 11],                            # max_new_tokens
    [0, 1, 0, 2, 1, 0],                                 # priorities
)


def _oversub_stream():
    prompts, news, prios = OVERSUB_WORKLOAD
    return [Request(prompt=p, max_new_tokens=n, priority=pr, id=10_000 + i)
            for i, (p, n, pr) in enumerate(zip(prompts, news, prios))]


def run_oversubscribed(verbose: bool = True, trace_out: str | None = None):
    """Serve a >= 2x-oversubscribed workload through swap + preemption.

    The seed engine raises ``OutOfPages`` on this stream; with the swap
    tier the whole workload completes, bit-identical to the monolithic
    reference, and the report shows what that cost in swap traffic.
    ``trace_out`` writes the oversubscribed run's Chrome-trace JSON
    (per-request lifecycle spans including the preempted intervals +
    engine evict/fault spans — the CI perf-smoke artifact)."""
    from repro.serving.telemetry import Telemetry
    cfg = smoke_variant(get(ARCHS[0]))
    params = M.init_params(jax.random.PRNGKey(0), cfg)

    def serve(**kw):
        eng = GenerationEngine(params, cfg, config=EngineConfig(max_batch=2, max_len=48, **kw))
        reqs = _oversub_stream()
        for r in reqs:
            eng.submit(r)
        eng.run()
        assert all(r.done for r in reqs)
        return [r.out_tokens for r in reqs], eng

    mono, _ = serve(cache_mode="monolithic")
    tel = Telemetry()
    mon = KVCacheMonitor(registry=tel.registry)
    over, eng = serve(cache_mode="paged", page_size=8, n_pages=5,
                      compress_cold=True, n_cold_slots=1,
                      swap_bytes=1 << 28, kv_monitor=mon, telemetry=tel)
    if trace_out:
        from repro.runtime.trace_export import export_chrome_trace
        trace = export_chrome_trace(tel.tracer, trace_out,
                                    registry=tel.registry)
        names = {e["name"] for e in trace["traceEvents"]}
        assert {"preempted", "resume", "evict", "fault"} <= names, names
    demand = sum(eng.paged.pages_worst_case(len(r.prompt), r.max_new_tokens)
                 for r in _oversub_stream())
    assert demand >= 2 * eng.paged.n_pages, (demand, eng.paged.n_pages)
    assert over == mono, "oversubscribed serve deviated from monolithic"
    s = mon.summary()
    assert s["n_preempted"] > 0 and s["swap_in_bytes_total"] > 0
    out = {
        "aggregate_demand_pages": demand,
        "n_pages": eng.paged.n_pages,
        "oversubscription": demand / eng.paged.n_pages,
        "steps": eng.steps,
        "n_preempted": s["n_preempted"],
        "n_resumed": s["n_resumed"],
        "swap_out_bytes": s["swap_out_bytes_total"],
        "swap_in_bytes": s["swap_in_bytes_total"],
        "peak_swap_bytes": s["peak_swap_bytes"],
        "bit_identical_to_monolithic": True,
    }
    if verbose:
        print(f"\noversubscribed engine ({ARCHS[0]}, batch 2, pool "
              f"{out['n_pages']} pages, demand {demand} pages = "
              f"{out['oversubscription']:.1f}x):")
        print(f"  completed in {out['steps']} steps, "
              f"{out['n_preempted']} preemptions "
              f"({out['n_resumed']} resumed)")
        print(f"  swap traffic out/in {out['swap_out_bytes']}/"
              f"{out['swap_in_bytes']} B, peak host-resident "
              f"{out['peak_swap_bytes']} B")
        print("  tokens bit-identical to monolithic: True")
        if trace_out:
            print(f"  wrote Chrome trace {trace_out} (includes "
                  f"preempt/resume + evict/fault spans)")
    return out


_SHARDED_BODY = """
    import json, time
    import numpy as np, jax, jax.numpy as jnp
    from jax.sharding import Mesh
    from repro.configs import get, smoke_variant
    from repro.models import model as M
    from repro.runtime.monitor import KVCacheMonitor
    from repro.serving import EngineConfig, GenerationEngine, Request

    cfg = smoke_variant(get('qwen3-8b'))
    params = M.init_params(jax.random.PRNGKey(0), cfg)

    def stream():
        rng = np.random.default_rng(0)
        return [Request(prompt=rng.integers(0, cfg.vocab_size,
                                            size=rng.integers(2, 24)).tolist(),
                        max_new_tokens=int(rng.integers(4, 24)))
                for _ in range(8)]

    def serve(mesh):
        mon = KVCacheMonitor()
        eng = GenerationEngine(params, cfg, config=EngineConfig(max_batch=4, max_len=64,
                               page_size=16, compress_cold=True,
                               kv_monitor=mon, mesh=mesh))
        reqs = stream()
        for r in reqs:
            eng.submit(r)
        t0 = time.perf_counter()
        eng.run()
        dt = time.perf_counter() - t0
        toks = sum(len(r.out_tokens) for r in reqs)
        return eng, {'tok_per_s': toks / max(dt, 1e-9), 'steps': eng.steps,
                     'pages_per_shard_peak': mon.peak_per_shard(),
                     'tokens': [r.out_tokens for r in reqs]}

    out = {}
    _, out['single'] = serve(None)
    eng, out['data_mesh'] = serve(Mesh(np.array(jax.devices()), ('data',)))
    # data mesh: every slot's pages live on its own shard -> no page bytes
    # ever cross a device for the gather
    out['data_mesh']['cross_shard_gather_bytes_per_step'] = 0
    out['data_mesh']['bit_identical_to_single'] = (
        out['data_mesh'].pop('tokens') == out['single']['tokens'])
    out['single'].pop('tokens')
    _, out['model_mesh'] = serve(Mesh(np.array(jax.devices()), ('model',)))
    out['model_mesh'].pop('tokens')
    # model mesh: pages split round-robin over model shards; each decode
    # step all-gathers (acc, m, l) per attention layer to merge stats
    n_model = len(jax.devices())
    B, Hq, hd = 4, cfg.n_heads, cfg.hd
    out['model_mesh']['cross_shard_gather_bytes_per_step'] = (
        eng.paged.n_attn_layers * n_model * (B * Hq * hd * 4 + 2 * B * Hq * 4))

    # oversubscribed + swap on the data mesh: aggregate page demand >= 2x
    # the raw pool, per-shard free lists + per-shard swap ledgers, tokens
    # still bit-identical to the single-device monolithic reference
    def oversub_reqs():
        prompts, news, prios = __OVERSUB_WORKLOAD__
        return [Request(prompt=p, max_new_tokens=n, priority=pr,
                        id=10_000 + i)
                for i, (p, n, pr) in enumerate(zip(prompts, news, prios))]

    def serve_over(mesh, **kw):
        mon = KVCacheMonitor()
        eng = GenerationEngine(params, cfg, config=EngineConfig(max_batch=4, max_len=48,
                               kv_monitor=mon, mesh=mesh, **kw))
        reqs = oversub_reqs()
        for r in reqs:
            eng.submit(r)
        eng.run()
        assert all(r.done for r in reqs)
        return [r.out_tokens for r in reqs], eng, mon

    mono_o, _, _ = serve_over(None, cache_mode='monolithic')
    toks_o, eng_o, mon_o = serve_over(
        Mesh(np.array(jax.devices()), ('data',)), cache_mode='paged',
        page_size=8, n_pages=8, compress_cold=True, n_cold_slots=2,
        swap_bytes=1 << 28)
    demand = sum(eng_o.paged.pages_worst_case(len(r.prompt),
                                              r.max_new_tokens)
                 for r in oversub_reqs())
    assert demand >= 2 * eng_o.paged.n_pages, (demand, eng_o.paged.n_pages)
    s_o = mon_o.summary()
    assert toks_o == mono_o
    assert s_o['n_preempted'] > 0 and s_o['swap_in_bytes_total'] > 0
    out['oversubscribed_data_mesh'] = {
        'aggregate_demand_pages': demand, 'n_pages': eng_o.paged.n_pages,
        'steps': eng_o.steps, 'n_preempted': s_o['n_preempted'],
        'n_resumed': s_o['n_resumed'],
        'swap_out_bytes': s_o['swap_out_bytes_total'],
        'swap_in_bytes': s_o['swap_in_bytes_total'],
        'bit_identical_to_single': True,
    }
    print('RESULT ' + json.dumps(out))
"""


def run_sharded(n_devices: int = 2, verbose: bool = True):
    """Serve the mixed stream on 2-way data / model meshes (subprocess with
    ``--xla_force_host_platform_device_count``, keeping this process at 1
    device) and report pages-per-shard + cross-shard gather cost."""
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ,
               PYTHONPATH=os.path.join(repo, "src"),
               XLA_FLAGS=f"--xla_force_host_platform_device_count"
                         f"={n_devices}")
    body = textwrap.dedent(_SHARDED_BODY).replace(
        "__OVERSUB_WORKLOAD__", repr(OVERSUB_WORKLOAD))
    p = subprocess.run([sys.executable, "-c", body],
                       env=env, capture_output=True, text=True, timeout=900)
    assert p.returncode == 0, f"sharded bench failed:\n{p.stderr[-4000:]}"
    out = json.loads(p.stdout.strip().splitlines()[-1].removeprefix("RESULT "))
    assert out["data_mesh"]["bit_identical_to_single"]
    if verbose:
        print(f"\nsharded engine (qwen3-8b smoke, batch 4, {n_devices} "
              f"virtual devices):")
        for name in ("single", "data_mesh", "model_mesh"):
            r = out[name]
            extra = ""
            if "pages_per_shard_peak" in r:
                extra = (f"  pages/shard peak {r['pages_per_shard_peak']}"
                         f"  x-shard gather "
                         f"{r.get('cross_shard_gather_bytes_per_step', 0)}"
                         f" B/step")
            print(f"  {name:11s} {r['tok_per_s']:8.1f} tok/s "
                  f"({r['steps']} steps){extra}")
        print("  data_mesh tokens bit-identical to single-device: True")
        o = out["oversubscribed_data_mesh"]
        print(f"  oversubscribed on the data mesh: demand "
              f"{o['aggregate_demand_pages']} pages vs pool {o['n_pages']}, "
              f"{o['n_preempted']} preemptions, swap out/in "
              f"{o['swap_out_bytes']}/{o['swap_in_bytes']} B, "
              f"bit-identical: True")
    return out


if __name__ == "__main__":
    run()
