"""Trace-replay load generator for the async serving front end.

The top-level serving benchmark (perf-smoke section ``frontend``):
replays a **seeded bursty arrival trace** — Poisson background traffic
with a spike window, long/short prompt mix, three priority classes, and
an optional shared system prompt — through ``AsyncServingFrontend`` +
``Router`` over ``GenerationEngine`` replicas, and reports *streamed*
TTFT percentiles (submit → first token on the stream, the latency a
streaming client sees), throughput, and the shed rate under the burst.

Replay is **tick-based**: requests whose arrival tick has come are
submitted, then the frontend pumps exactly one ``step()``.  Everything
the frontend decides — admission order, replica placement, shedding —
is a function of tick state, so a given ``seed`` always reproduces the
same placements and the same shed set (asserted by
``tests/test_async_serving.py``); wall clock feeds only the latency
histograms.  The completed requests' token streams are asserted
bit-identical to a synchronous single-engine run of the same request
set — the differential check riding along in the benchmark.

Usage:
  PYTHONPATH=src python -m benchmarks.load_replay
"""
from __future__ import annotations

import asyncio
import time

import numpy as np

# deterministic workload shape: one tick = one engine step, and a
# request costs ~8 steps (chunked prefill + decode) on 6 slots, so the
# background rate sits under capacity and the spike overruns it ~3x —
# the bounded admission queue sheds a stable handful inside the burst
N_REQUESTS = 24
SPIKE = (8, 12)          # tick window of the burst
BASE_RATE = 0.3          # requests/tick outside the spike
SPIKE_RATE = 3.0         # requests/tick inside it
SYSTEM_TOKENS = 16       # shared system prompt (page-aligned at chunk 8)


def build_trace(seed: int = 0, n_requests: int = N_REQUESTS,
                vocab: int = 64) -> list[dict]:
    """The seeded arrival trace: a list of request specs sorted by
    arrival tick.  Pure numpy — no engine state — so tests replay the
    identical trace against differently shaped frontends."""
    rng = np.random.default_rng(seed)
    system = rng.integers(1, vocab, size=SYSTEM_TOKENS).tolist()
    trace, tick = [], 0
    while len(trace) < n_requests:
        rate = SPIKE_RATE if SPIKE[0] <= tick < SPIKE[1] else BASE_RATE
        for _ in range(min(rng.poisson(rate), n_requests - len(trace))):
            long = rng.random() < 0.3
            n_prompt = int(rng.integers(20, 29) if long
                           else rng.integers(4, 9))
            prompt = rng.integers(1, vocab, size=n_prompt).tolist()
            if rng.random() < 0.5:          # chat-style shared prefix
                prompt = system + prompt
            trace.append({
                "tick": tick,
                "prompt": prompt,
                "max_new_tokens": int(rng.integers(4, 8)),
                "priority": int(rng.choice([0, 0, 0, 0, 1, 1, 2])),
            })
        tick += 1
    return trace


async def replay(frontend, trace, *, id_base: int = 9_000):
    """Tick-by-tick replay of ``trace`` through ``frontend``; returns
    ``(streams, requests)`` aligned with the trace (a shed request's
    stream has ``.shed`` set and no tokens)."""
    from repro.serving import Request
    from repro.serving.async_engine import FrontendOverloaded
    streams, reqs = [], []
    it = iter(enumerate(trace))
    nxt = next(it, None)
    tick = 0
    while True:
        while nxt is not None and nxt[1]["tick"] <= tick:
            i, item = nxt
            req = Request(prompt=item["prompt"],
                          max_new_tokens=item["max_new_tokens"],
                          priority=item["priority"], id=id_base + i)
            reqs.append(req)
            try:
                streams.append(frontend.submit_nowait(req))
            except FrontendOverloaded:
                streams.append(None)
            nxt = next(it, None)
        busy = await frontend.step()
        tick += 1
        if nxt is None and not busy:
            break
    await frontend.drain()
    return streams, reqs


def run(verbose: bool = True, seed: int = 0, n_replicas: int = 2):
    """Build the replica fleet, replay the trace, and return the
    perf-smoke ``frontend`` section.  Asserts the streamed tokens of
    every completed request are bit-identical to a synchronous
    single-engine run of the same accepted request set."""
    import jax
    from repro.configs import get, smoke_variant
    from repro.models import model as M
    from repro.serving import (AsyncServingFrontend, EngineConfig,
                               GenerationEngine, Request, Router, Telemetry)

    cfg = smoke_variant(get("qwen3-8b"))
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    # every replica shares one rng_seed: placements cannot change tokens
    ecfg = EngineConfig(max_batch=3, max_len=64, prefill_chunk=8,
                        prefix_sharing=True)
    trace = build_trace(seed=seed, vocab=cfg.vocab_size)

    tel = Telemetry(trace=False)
    # replicas publish into ONE registry (frontend_*/router_* next to
    # the serving_*/prefix_* counters) — no second tracker
    from dataclasses import replace as _replace
    router = Router([GenerationEngine(params, cfg,
                                      config=_replace(ecfg, telemetry=tel))
                     for _ in range(n_replicas)], telemetry=tel)
    frontend = AsyncServingFrontend(router, max_pending=6,
                                    shed_policy="reject", telemetry=tel)
    t0 = time.perf_counter()
    streams, reqs = asyncio.run(replay(frontend, trace))
    wall_s = time.perf_counter() - t0

    done = [(r, s) for r, s in zip(reqs, streams) if s is not None]
    shed = sum(1 for s in streams if s is None)
    n_tok = sum(len(s.tokens) for _, s in done)
    assert all(r.done and s.tokens == r.out_tokens for r, s in done)

    # differential: one synchronous engine serving the accepted set
    # (same ids => same sampling keys) must emit identical streams
    ref = {}
    eng = GenerationEngine(params, cfg, config=ecfg)
    for r, _ in done:
        rr = Request(prompt=r.prompt, max_new_tokens=r.max_new_tokens,
                     priority=r.priority, id=r.id)
        ref[r.id] = rr
        eng.submit(rr)
    eng.run()
    assert all(s.tokens == ref[r.id].out_tokens for r, s in done), \
        "async streams diverged from the synchronous engine"

    ttft = tel.registry.get("frontend_stream_ttft_seconds")
    out = {
        "n_requests": len(trace),
        "n_replicas": n_replicas,
        "n_completed": len(done),
        "n_shed": shed,
        "shed_rate": shed / len(trace),
        "tok_per_s": n_tok / max(wall_s, 1e-9),
        "ttft_p50_s": ttft.percentile(0.50),
        "ttft_p95_s": ttft.percentile(0.95),
        "prefix_hits": int(tel.registry.value("prefix_hit_total")),
        "placements": [idx for _, idx, _ in router.placements],
    }
    if verbose:
        print(f"[load-replay] {out['n_completed']}/{out['n_requests']} "
              f"requests completed, {shed} shed "
              f"({out['shed_rate']:.0%}) on {n_replicas} replicas, "
              f"{out['tok_per_s']:.1f} tok/s streamed, TTFT p50/p95 "
              f"{out['ttft_p50_s'] * 1e3:.0f}/"
              f"{out['ttft_p95_s'] * 1e3:.0f} ms, "
              f"{out['prefix_hits']} prefix hits")
    return out


if __name__ == "__main__":
    run()
