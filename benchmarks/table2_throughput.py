"""Paper Table 2: throughput under a fixed memory budget (roofline form).

The paper's mechanism: batched decode is weight-streaming-bound; under a
fixed HBM budget, smaller weights leave room for more KV cache => larger
max batch => higher tokens/s (throughput ~ batch while streaming-bound).

This container has no GPUs, so the claim is expressed exactly as the paper
frames it, with v5e-class numbers:

  max_batch = floor((HBM_budget - weight_bytes) / kv_bytes_per_seq)
  step_time = weight_bytes / HBM_bw        (weight-streaming bound)
  tokens/s  = max_batch / step_time

Reported per LLM arch for FP8 vs ECF8 weights (measured compression ratio
from table1 synthesis).  The paper's observed uplift band is 11.3-177.1%.
"""
from __future__ import annotations

from repro.configs import ASSIGNED, get
from .table1_memory import run as table1_run

HBM_PER_CHIP = 16e9          # v5e-class
HBM_BW = 819e9
CHIPS = 8                    # one serving host (8 chips)
SEQ = 8192                   # serving context per request


def kv_bytes_per_seq(cfg) -> float:
    hd = cfg.hd
    n_local = sum(1 for i in range(cfg.n_layers)
                  if cfg.layer_kind(i) == "local")
    n_global = sum(1 for i in range(cfg.n_layers)
                   if cfg.layer_kind(i) in ("attn", "nope"))
    b = 2 * cfg.n_kv_heads * hd * 2  # k+v, bf16
    total = n_global * SEQ * b + n_local * min(cfg.local_window, SEQ) * b
    # recurrent state (fixed size per seq)
    n_rec = cfg.n_layers - n_local - n_global
    total += n_rec * 8 * cfg.d_model * 4
    return total


def run(verbose: bool = True):
    t1 = {r["arch"]: r for r in table1_run(verbose=False)}
    rows = []
    budget = CHIPS * HBM_PER_CHIP
    for arch in ASSIGNED + ["qwen3-8b"]:
        cfg = get(arch)
        n = cfg.param_count()
        w_fp8 = float(n)
        save = t1[arch]["tpu_save"] / 100.0
        w_ecf8 = w_fp8 * (1 - save)
        kv = kv_bytes_per_seq(cfg)
        out = {"arch": arch}
        for tag, w in (("fp8", w_fp8), ("ecf8", w_ecf8)):
            free = budget - w - 0.05 * budget  # 5% activations headroom
            batch = max(int(free / kv), 0)
            step = w / (CHIPS * HBM_BW)  # weights stream once per token
            out[f"batch_{tag}"] = batch
            out[f"tps_{tag}"] = batch / step if step else 0.0
        out["uplift_pct"] = (100 * (out["tps_ecf8"] / out["tps_fp8"] - 1)
                             if out["tps_fp8"] else float("nan"))
        rows.append(out)
        if verbose:
            print(f"{arch:26s} batch {out['batch_fp8']:5d} -> "
                  f"{out['batch_ecf8']:5d}   tok/s {out['tps_fp8']:9.0f} ->"
                  f" {out['tps_ecf8']:9.0f}   (+{out['uplift_pct']:.1f}%)")
    ups = [r["uplift_pct"] for r in rows if r["tps_fp8"] > 0]
    if verbose:
        print(f"\nthroughput uplift range [{min(ups):.1f}%, {max(ups):.1f}%]"
              f" — paper Table 2 band: 11.3-177.1% (model- and"
              f" budget-dependent)")
    return rows


if __name__ == "__main__":
    run()
