"""Paper Figure 1: exponent entropy per block/tensor across architectures.

Synthesizes trained-like weights per assigned arch (alpha-stable, the
paper's own §2.2.1 model of SGD-trained weights) and reports the measured
Shannon entropy of the fp8 exponent field, validating the paper's claim
that entropy sits around 2-3 bits across architectures and modalities,
plus the fitted alpha and Theorem 2.1's band at that alpha.
"""
from __future__ import annotations

from repro.configs import ASSIGNED
from repro.core import stats, theory
from .common import arch_layer_tensors


def run(verbose: bool = True):
    rows = []
    for arch in ASSIGNED:
        tensors, cfg = arch_layer_tensors(arch)
        for tname, bits in tensors.items():
            s = stats.summarize_tensor(bits)
            rows.append({
                "arch": arch, "tensor": tname,
                "entropy_bits": s["entropy_bits"],
                "alpha_hat": s["alpha_hat"],
            })
    ents = [r["entropy_bits"] for r in rows]
    lo, hi = min(ents), max(ents)
    if verbose:
        print(f"{'arch':26s} {'tensor':10s} {'H(E) bits':>9s}")
        for r in rows:
            print(f"{r['arch']:26s} {r['tensor']:10s}"
                  f" {r['entropy_bits']:9.3f}")
        print(f"\nentropy range [{lo:.2f}, {hi:.2f}] bits"
              f" — paper Fig. 1 band: ~2-3 bits")
        print(f"theory: H(E) for alpha in [1.55, 1.9] (exact two-sided"
              f" geometric): "
              f"[{theory.exponent_entropy_exact(1.9):.2f},"
              f" {theory.exponent_entropy_exact(1.55):.2f}]")
    assert 1.5 < lo and hi < 3.6, (lo, hi)
    return {"min_entropy": lo, "max_entropy": hi, "rows": len(rows)}


if __name__ == "__main__":
    run()
