"""Benchmark suite entry point: one benchmark per paper table/figure.

  fig1_entropy       — paper Fig. 1 (exponent entropy across archs)
  table1_memory      — paper Table 1 (lossless memory savings)
  table2_throughput  — paper Table 2 (throughput under memory budget,
                       roofline form on this CPU-only container)
  decode_microbench  — decode-path MB/s (host wall-clock)
  kvcache_bench      — per-layer K/V exponent entropy (fig1-style) +
                       paged-cache memory savings table
  roofline_table     — §Roofline aggregation of the dry-run artifacts
                       (skipped gracefully when artifacts are absent)

Usage:  PYTHONPATH=src python -m benchmarks.run
"""
from __future__ import annotations

import time
import traceback


def main() -> None:
    from . import (decode_microbench, fig1_entropy, kvcache_bench,
                   roofline_table, table1_memory, table2_throughput)
    suites = [
        ("fig1_entropy", fig1_entropy.run),
        ("table1_memory", table1_memory.run),
        ("table2_throughput", table2_throughput.run),
        ("decode_microbench", decode_microbench.run),
        ("kvcache_bench", kvcache_bench.run),
        ("roofline_table", roofline_table.run),
    ]
    failures = []
    for name, fn in suites:
        print(f"\n{'=' * 72}\n== {name}\n{'=' * 72}")
        t0 = time.time()
        try:
            fn(verbose=True)
            print(f"[{name}] OK in {time.time() - t0:.1f}s")
        except AssertionError as e:
            if name == "roofline_table":
                print(f"[{name}] skipped/failed: {e}")
            else:
                failures.append(name)
                traceback.print_exc()
        except FileNotFoundError as e:
            print(f"[{name}] skipped (no artifacts): {e}")
    print(f"\n{'=' * 72}")
    if failures:
        raise SystemExit(f"benchmark failures: {failures}")
    print("all benchmarks passed")


if __name__ == "__main__":
    main()
