"""Aggregate the dry-run artifacts into the EXPERIMENTS.md roofline table.

Reads experiments/artifacts/*.json (written by repro.launch.dryrun) and
prints the per-(arch x shape x mesh) three-term roofline with the dominant
bottleneck, MODEL_FLOPS ratio and the mfu bound.  Used both as a benchmark
(it asserts every non-skipped cell compiled) and as the §Roofline report
generator (--markdown).
"""
from __future__ import annotations

import glob
import json
import os
import sys

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "experiments",
                         "artifacts")


def load(mesh: str = "single", tag: str = ""):
    rows = []
    suffix = f"__{mesh}{('__' + tag) if tag else ''}.json"
    for f in sorted(glob.glob(os.path.join(ARTIFACTS, "*" + suffix))):
        if not tag and "__" in os.path.basename(f)[:-5].split(
                f"__{mesh}")[-1]:
            continue  # tagged artifact; only exact-suffix matches
        a = json.load(open(f))
        if a.get("mesh") != mesh:
            continue
        rows.append(a)
    return rows


def run(verbose: bool = True, mesh: str = "single", markdown: bool = False,
        tag: str = ""):
    rows = load(mesh, tag)
    ok = [a for a in rows if not a.get("skipped") and "error" not in a]
    skipped = [a for a in rows if a.get("skipped")]
    failed = [a for a in rows if "error" in a]
    assert not failed, [f"{a['arch']}/{a['shape']}" for a in failed]

    hdr = (f"| arch | shape | compute s | memory s | collective s | "
           f"dominant | useful | mfu_bound |") if markdown else (
        f"{'arch':26s} {'shape':12s} {'compute':>9s} {'memory':>9s} "
        f"{'coll':>9s} {'dominant':>10s} {'useful':>7s} {'mfu_bd':>7s}")
    lines = [hdr]
    if markdown:
        lines.append("|---|---|---|---|---|---|---|---|")
    for a in sorted(ok, key=lambda a: (a["arch"], a["shape"])):
        r = a["roofline"]
        if markdown:
            lines.append(
                f"| {a['arch']} | {a['shape']} | {r['t_compute']:.3f} | "
                f"{r['t_memory']:.3f} | {r['t_collective']:.3f} | "
                f"{r['dominant']} | {r['useful_flops_ratio']:.3f} | "
                f"{r['mfu_bound']:.4f} |")
        else:
            lines.append(
                f"{a['arch']:26s} {a['shape']:12s} {r['t_compute']:9.3f} "
                f"{r['t_memory']:9.3f} {r['t_collective']:9.3f} "
                f"{r['dominant']:>10s} {r['useful_flops_ratio']:7.3f} "
                f"{r['mfu_bound']:7.4f}")
    if verbose:
        print("\n".join(lines))
        print(f"\n{len(ok)} cells ok, {len(skipped)} skipped "
              f"(long_500k rule), 0 failed  [mesh={mesh}"
              f"{', tag=' + tag if tag else ''}]")
    return ok


if __name__ == "__main__":
    md = "--markdown" in sys.argv
    tag = ""
    for a in sys.argv[1:]:
        if a.startswith("--tag="):
            tag = a.split("=", 1)[1]
    for m in ("single", "multi"):
        print(f"\n===== mesh: {m} =====")
        run(mesh=m, markdown=md, tag=tag)
