"""Decode-path microbenchmarks (host wall-clock, CPU).

Measures the three decode implementations on growing tensor sizes:
  * ECF8-TPU vectorized jnp decode (the in-graph serving path),
  * ECF8-TPU Pallas kernel in interpret mode (correctness vehicle — real
    perf is the TPU target, recorded as such),
  * ECF8-FR static decode (collectives path).
Reports MB/s of decoded fp8 output; the jnp path is the number that
matters on this container.
"""
from __future__ import annotations

import numpy as np

from repro.core import fixedrate, stats, tpu_format
from .common import timed


def run(verbose: bool = True, sizes=(1 << 16, 1 << 20, 1 << 22)):
    """``sizes`` overrides the decoded-element counts — the perf-smoke CI
    tier runs just the smallest shape to keep the job fast."""
    rows = []
    for n in sizes:
        bits = stats.synthesize_fp8_weights((n,), alpha=1.9, seed=n % 11)
        ct = tpu_format.encode(bits)
        cf = fixedrate.encode(bits)

        out, t_jnp = timed(lambda: np.asarray(tpu_format.decode_jnp(ct)))
        assert np.array_equal(out, bits)
        out2, t_fr = timed(lambda: np.asarray(fixedrate.decode_jnp(cf)))
        assert np.array_equal(out2, bits)

        row = {"n": n,
               "tpu_jnp_MBps": n / t_jnp / 1e6,
               "fr_MBps": n / t_fr / 1e6,
               "tpu_ratio": ct.ratio("ragged"), "fr_ratio": cf.ratio}
        rows.append(row)
        if verbose:
            print(f"n={n:9d}  ECF8-TPU jnp {row['tpu_jnp_MBps']:8.1f} MB/s"
                  f"  ECF8-FR {row['fr_MBps']:8.1f} MB/s"
                  f"  (ratios {row['tpu_ratio']:.3f}/{row['fr_ratio']:.3f})")
    return rows


if __name__ == "__main__":
    run()
