"""Roofline terms from the compiled dry-run artifact (no real hardware).

Hardware constants: TPU v5e class chip —
    peak compute  197 TFLOP/s (bf16)
    HBM bandwidth 819 GB/s
    ICI           ~50 GB/s per link (we budget one link's worth per chip for
                  the dominant ring; a real v5e has 4; this is conservative
                  and recorded as an assumption)

Terms per (arch x shape x mesh), all in seconds-per-step:

    compute    = HLO_FLOPs_per_chip / peak
    memory     = HLO_bytes_per_chip / HBM_bw
    collective = collective_wire_bytes_per_chip / ICI_bw

``cost_analysis()`` of the SPMD-partitioned executable reports per-device
flops/bytes; collective bytes come from ``analysis.hlo_parse`` over the
post-optimization HLO (also per-device).  MODEL_FLOPS = 6*N*D (dense) or
6*N_active*D (MoE), D = tokens processed per step; the ratio
MODEL_FLOPS / HLO_FLOPs_total shows how much compiled compute is "useful"
(catches remat recompute and redundancy; >1 is impossible, ~0.33 under full
remat is expected for training: fwd+bwd+rematerialized fwd).
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.configs.base import ArchConfig, ShapeConfig


@dataclass(frozen=True)
class HW:
    peak_flops: float = 197e12        # bf16 FLOP/s per chip
    hbm_bw: float = 819e9             # bytes/s per chip
    ici_bw: float = 50e9              # bytes/s per chip (one link budget)


DEFAULT_HW = HW()


def model_flops(cfg: ArchConfig, shape: ShapeConfig) -> float:
    """6*N*D useful-FLOPs estimate (N = active params, D = tokens/step).

    train counts fwd+bwd (6ND); prefill counts fwd only (2ND); decode steps
    process batch*1 tokens (2ND per generated token)."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        toks = shape.global_batch * shape.seq_len
        return 6.0 * n * toks
    if shape.kind == "prefill":
        toks = shape.global_batch * shape.seq_len
        return 2.0 * n * toks
    toks = shape.global_batch * 1
    return 2.0 * n * toks


def roofline_terms(cost: dict, coll: dict, n_chips: int,
                   cfg: ArchConfig = None, shape: ShapeConfig = None,
                   hw: HW = DEFAULT_HW) -> dict:
    """Three roofline terms (+ diagnostics) from dry-run artifacts.

    ``cost``: compiled.cost_analysis() dict (per-chip).
    ``coll``: hlo_parse.collective_bytes() dict (per-chip).
    """
    flops_chip = float(cost.get("flops", 0.0))
    bytes_chip = float(cost.get("bytes accessed", 0.0))
    coll_chip = float(coll.get("total", 0.0))

    t_compute = flops_chip / hw.peak_flops
    t_memory = bytes_chip / hw.hbm_bw
    t_coll = coll_chip / hw.ici_bw
    terms = {"compute": t_compute, "memory": t_memory,
             "collective": t_coll}
    dominant = max(terms, key=terms.get)
    out = {
        **{f"t_{k}": v for k, v in terms.items()},
        "dominant": dominant,
        "flops_per_chip": flops_chip,
        "bytes_per_chip": bytes_chip,
        "collective_bytes_per_chip": coll_chip,
        "hlo_flops_total": flops_chip * n_chips,
        "n_chips": n_chips,
    }
    if cfg is not None and shape is not None:
        mf = model_flops(cfg, shape)
        out["model_flops"] = mf
        out["useful_flops_ratio"] = (
            mf / max(flops_chip * n_chips, 1.0))
        # roofline fraction: useful work over what the bound permits in the
        # dominated time (how close the step is to its own roofline)
        step_time = max(terms.values())
        out["step_time_bound"] = step_time
        out["mfu_bound"] = mf / (n_chips * hw.peak_flops * step_time) \
            if step_time > 0 else 0.0
    return out


def fmt_seconds(s: float) -> str:
    if s >= 1.0:
        return f"{s:.2f}s"
    if s >= 1e-3:
        return f"{s * 1e3:.2f}ms"
    return f"{s * 1e6:.1f}us"
