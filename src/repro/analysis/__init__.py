from .hlo_parse import collective_bytes  # noqa: F401
from .roofline import roofline_terms, HW  # noqa: F401
