"""Parse collective traffic out of post-SPMD HLO text.

``compiled.as_text()`` is the per-device (SPMD-partitioned) module, so the
shapes on collective instructions are per-chip.  Wire-byte model per op
(ring algorithms, (n-1)/n ~ 1):

    all-gather          : output bytes          (each chip receives ~out)
    reduce-scatter      : operand bytes         (each chip sends ~in)
    all-reduce          : 2 x bytes             (reduce-scatter + all-gather)
    all-to-all          : operand bytes
    collective-permute  : operand bytes

Async pairs (``-start``/``-done``) are counted once (on ``-start``).
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "c64": 8, "c128": 16,
}

_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
        "collective-permute")
_FACTOR = {"all-gather": 1.0, "all-reduce": 2.0, "reduce-scatter": 1.0,
           "all-to-all": 1.0, "collective-permute": 1.0}

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_LINE_RE = re.compile(
    r"=\s*(.*?)\s+(" + "|".join(_OPS) + r")(-start)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\{?\{([0-9,]+)\}")


def _shape_bytes(shape_str: str, largest_only: bool = False) -> int:
    parts = []
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        parts.append(n * _DTYPE_BYTES[dt])
    if not parts:
        return 0
    return max(parts) if largest_only else sum(parts)


def collective_bytes(hlo_text: str) -> dict:
    """Per-chip collective wire bytes by op kind, from post-SPMD HLO text.

    Returns {op: bytes, ..., "total": bytes, "count": n_ops,
             "ops": [(op, bytes, group_size), ...]}.
    """
    by_op: dict = defaultdict(float)
    ops = []
    for line in hlo_text.splitlines():
        m = _LINE_RE.search(line)
        if not m:
            continue
        shape_str, op = m.group(1), m.group(2)
        # async `-start` ops have tuple shapes (operand, result): count the
        # largest component only (the gathered/reduced result)
        raw = _shape_bytes(shape_str, largest_only=m.group(3) is not None)
        g = _GROUPS_RE.search(line)
        group_size = len(g.group(1).split(",")) if g else 0
        eff = raw * _FACTOR[op]
        by_op[op] += eff
        ops.append((op, eff, group_size))
    out = dict(by_op)
    out["total"] = float(sum(by_op.values()))
    out["count"] = len(ops)
    out["ops"] = ops
    return out


def op_histogram(hlo_text: str, kinds=("fusion", "dot", "scatter", "gather",
                                       "transpose", "reshape", "copy")) -> dict:
    """Rough instruction histogram of the optimized module (perf forensics)."""
    hist: dict = defaultdict(int)
    for line in hlo_text.splitlines():
        for k in kinds + _OPS:
            if f" {k}(" in line or f" {k}-start(" in line:
                hist[k] += 1
                break
    return dict(hist)
