"""Trip-count-aware HLO cost analysis.

XLA's ``HloCostAnalysis`` (what ``compiled.cost_analysis()`` reports) counts
every ``while`` body **once**, regardless of trip count.  Our models stack
layers with ``lax.scan`` and chunk attention with ``lax.map``/``fori_loop``,
all of which lower to ``while`` — so the reported FLOPs/bytes/collectives
undercount by the loop trip counts (52x for a 52-layer scan), which would
poison every roofline term.

This module re-derives costs from the post-optimization HLO text:

  1. parse the module into computations and instructions;
  2. cost each instruction (dot FLOPs from ``dot_dimension_numbers`` +
     operand shapes; fusion = its computation's internal FLOPs, call-site
     bytes; elementwise ~ 1 flop/elem);
  3. recover each while loop's static trip count from its condition
     computation (``compare(counter, constant(N)), direction=LT`` — the
     jax scan/fori pattern);
  4. fold costs over the call graph, scaling while bodies by trip count
     (nested loops multiply), and scaling collective wire bytes the same
     way.

Validated against hand-computable jitted programs in tests/test_hlo_cost.py
(e.g. a scanned matmul: trips x 2MNK) and cross-checked against
MODEL_FLOPS=6ND per arch in the dry-run (useful ratio must be <= 1).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from functools import lru_cache

from .hlo_parse import _DTYPE_BYTES, _FACTOR, _OPS

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$")
_COMP_HDR_RE = re.compile(r"^(ENTRY\s+)?%([\w.\-]+)\s*\(")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_ATTR_COMP_RE = {
    "body": re.compile(r"body=%?([\w.\-]+)"),
    "condition": re.compile(r"condition=%?([\w.\-]+)"),
    "calls": re.compile(r"calls=%?([\w.\-]+)"),
    "to_apply": re.compile(r"to_apply=%?([\w.\-]+)"),
    "branches": re.compile(r"branch_computations=\{([^}]*)\}"),
}
_DIMS_RE = {
    "lhs_c": re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}"),
    "lhs_b": re.compile(r"lhs_batch_dims=\{([0-9,]*)\}"),
}

_ELEMENTWISE_1FLOP = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "exponential", "exponential-minus-one", "tanh", "log",
    "log-plus-one", "rsqrt", "sqrt", "power", "cosine", "sine", "logistic",
    "atan2", "cbrt", "erf", "round-nearest-afz", "round-nearest-even",
    "floor", "ceil", "remainder", "clamp", "select", "compare",
}
_ZERO_BYTES_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
}


@dataclass
class Instr:
    name: str
    shape_str: str
    opcode: str
    rest: str                 # everything after the opening paren
    operands: list = field(default_factory=list)


@dataclass
class Computation:
    name: str
    instrs: dict = field(default_factory=dict)
    order: list = field(default_factory=list)
    is_entry: bool = False

    def root(self):
        return self.instrs.get(self._root_name) if hasattr(
            self, "_root_name") else None


def _shape_elems(shape_str: str):
    """[(dtype, n_elems, bytes), ...] for every array in the shape string."""
    out = []
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        out.append((dt, n, n * _DTYPE_BYTES[dt]))
    return out


def _total_bytes(shape_str: str) -> int:
    return sum(b for _, _, b in _shape_elems(shape_str))


def _total_elems(shape_str: str) -> int:
    return sum(n for _, n, _ in _shape_elems(shape_str))


def parse_module(text: str) -> dict:
    """HLO text -> {computation_name: Computation}."""
    comps: dict = {}
    cur: Computation | None = None
    for line in text.splitlines():
        stripped = line.strip()
        if not stripped or stripped.startswith("//"):
            continue
        if stripped.startswith("}"):
            cur = None
            continue
        if stripped.endswith("{") and "->" in stripped:
            m = _COMP_HDR_RE.match(stripped)
            if m:
                cur = Computation(name=m.group(2), is_entry=bool(m.group(1)))
                comps[cur.name] = cur
                continue
        if cur is None:
            continue
        im = _INSTR_RE.match(line)
        if not im:
            continue
        is_root, name, shape_str, opcode, rest = im.groups()
        # operand names: %refs inside the call parens (up to the matching
        # close — approximated by cutting at '), ' attr boundary)
        call_part = rest.split("), ")[0]
        operands = _OPERAND_RE.findall(call_part)
        inst = Instr(name=name, shape_str=shape_str, opcode=opcode,
                     rest=rest, operands=operands)
        cur.instrs[name] = inst
        cur.order.append(name)
        if is_root:
            cur._root_name = name
    return comps


def _attr_comps(inst: Instr) -> dict:
    out = {}
    for key, rx in _ATTR_COMP_RE.items():
        m = rx.search(inst.rest)
        if m:
            if key == "branches":
                out[key] = _OPERAND_RE.findall(m.group(1))
            else:
                out[key] = m.group(1)
    return out


def _const_value(inst: Instr | None) -> int | None:
    if inst is None or inst.opcode != "constant":
        return None
    m = re.search(r"^(-?\d+)\)", inst.rest)
    return int(m.group(1)) if m else None


def _trip_count(cond: Computation, comps: dict) -> int | None:
    """Static trip count from a jax-style loop condition computation.

    Handles both a direct ``compare(i, constant(N)), direction=LT`` root
    and the post-fusion form where the compare is wrapped in a kLoop fusion
    and the constant is a call-site operand."""
    root_name = getattr(cond, "_root_name", None)
    if root_name is None:
        return None
    r = cond.instrs[root_name]
    cand = None
    if r.opcode == "compare" and "direction=LT" in r.rest:
        cand = cond.instrs.get(r.operands[-1])
    elif r.opcode == "fusion":
        inner = comps.get(_attr_comps(r).get("calls", ""))
        iroot_name = getattr(inner, "_root_name", None) if inner else None
        if iroot_name is None:
            return None
        iroot = inner.instrs[iroot_name]
        if iroot.opcode != "compare" or "direction=LT" not in iroot.rest:
            return None
        second = inner.instrs.get(iroot.operands[-1])
        if second is None or second.opcode != "parameter":
            return None
        m = re.search(r"^(\d+)\)", second.rest)
        if m is None or int(m.group(1)) >= len(r.operands):
            return None
        cand = cond.instrs.get(r.operands[int(m.group(1))])
    v = _const_value(cand)
    return max(v, 0) if v is not None else None


def _dot_flops(inst: Instr, comp: Computation) -> float:
    out_elems = _total_elems(inst.shape_str)
    mc = _DIMS_RE["lhs_c"].search(inst.rest)
    contract = 1
    if mc and inst.operands:
        lhs = comp.instrs.get(inst.operands[0])
        if lhs is not None:
            dims_m = _SHAPE_RE.search(lhs.shape_str)
            if dims_m:
                lhs_dims = [int(d) for d in dims_m.group(2).split(",") if d]
                for i in (int(x) for x in mc.group(1).split(",") if x):
                    if i < len(lhs_dims):
                        contract *= lhs_dims[i]
    return 2.0 * out_elems * contract


class HloCost:
    """Folds instruction costs over the call graph with loop scaling.

    ``vmem_tiles``: optional {"qcs": {int, ...}, "kc": int} — shapes that
    pair a q-tile dim with the kv-chunk dim (the flash-attention s/p tiles
    and their row statistics) are counted as VMEM-resident: zero HBM bytes,
    full FLOPs.  Used for the Pallas-flash-kernel-adjusted roofline
    (kernels/flash_fwd.py; EXPERIMENTS.md §Perf)."""

    def __init__(self, text: str, vmem_tiles: dict | None = None):
        self.comps = parse_module(text)
        self.entry = next((c for c in self.comps.values() if c.is_entry),
                          None)
        self.unknown_trip_loops = 0
        self.vmem_tiles = vmem_tiles
        self.vmem_dropped_bytes = 0.0
        self._memo: dict = {}

    def _is_vmem_tile(self, shape_str: str) -> bool:
        if not self.vmem_tiles:
            return False
        dims: list = []
        for _, d_str in _SHAPE_RE.findall(shape_str):
            dims += [int(d) for d in d_str.split(",") if d]
        qcs, kc = self.vmem_tiles["qcs"], self.vmem_tiles["kc"]
        has_q = any(d in qcs for d in dims)
        if has_q and kc in dims:
            return True
        if has_q and dims and dims[-1] == 32:   # row-stat reduce windows
            return True
        return False

    # -- per-instruction costs -------------------------------------------

    def _instr_cost(self, inst: Instr, comp: Computation,
                    inside_fusion: bool) -> dict:
        flops = 0.0
        bytes_ = 0.0
        coll = {}
        op = inst.opcode
        if op == "dot":
            flops = _dot_flops(inst, comp)
        elif op in _ELEMENTWISE_1FLOP:
            flops = float(_total_elems(inst.shape_str))
        elif op == "reduce":
            # ~1 flop per input element
            for name in inst.operands[: max(1, len(inst.operands) // 2)]:
                src = comp.instrs.get(name)
                if src is not None:
                    flops += _total_elems(src.shape_str)
        elif op == "convolution":
            # generic fallback: 2 * out_elems * (in_feature window) — rare
            flops = 2.0 * _total_elems(inst.shape_str)

        base = op.replace("-start", "")
        if base in _OPS and not op.endswith("-done"):
            largest = op.endswith("-start")
            parts = _shape_elems(inst.shape_str)
            if parts:
                b = (max(p[2] for p in parts) if largest
                     else sum(p[2] for p in parts))
                b *= self._storage_dtype_ratio(inst, comp)
                coll[base] = b * _FACTOR[base]

        if not inside_fusion and op not in _ZERO_BYTES_OPS:
            if self._is_vmem_tile(inst.shape_str):
                self.vmem_dropped_bytes += self._instr_bytes(inst, comp)
            else:
                bytes_ = self._instr_bytes(inst, comp)
        return {"flops": flops, "bytes": bytes_, "coll": coll}

    def _instr_bytes(self, inst: Instr, comp: Computation) -> float:
        """HBM bytes touched by one instruction (slice-aware, like XLA's
        HloCostAnalysis: sliced/scattered ops charge the moved bytes, not
        the full buffer operand)."""
        op = inst.opcode
        out_b = float(_total_bytes(inst.shape_str))
        if op in ("dynamic-slice", "gather", "slice"):
            return 2.0 * out_b                       # read slice + write
        if op == "dynamic-update-slice":
            upd = (comp.instrs.get(inst.operands[1])
                   if len(inst.operands) > 1 else None)
            ub = _total_bytes(upd.shape_str) if upd else out_b
            return 2.0 * ub                          # read update + write
        if op == "scatter":
            upd = (comp.instrs.get(inst.operands[-1])
                   if inst.operands else None)
            ub = _total_bytes(upd.shape_str) if upd else out_b
            idx = (comp.instrs.get(inst.operands[1])
                   if len(inst.operands) > 2 else None)
            ib = _total_bytes(idx.shape_str) if idx else 0
            return 3.0 * ub + ib                     # read+write+dest read
        b = out_b
        for name in inst.operands:
            src = comp.instrs.get(name)
            if src is not None:
                b += _total_bytes(src.shape_str)
        return b

    _TRANSPARENT = ("bitcast", "copy", "convert", "reshape", "transpose")

    def _storage_dtype_ratio(self, inst: Instr, comp: Computation) -> float:
        """XLA:CPU promotes bf16 compute to f32 (no native bf16 ALUs), so
        collectives on widened operands appear at f32 in the CPU-lowered
        dry-run HLO; on the TPU target the wire would carry the storage
        dtype.  When a collective's operand is a convert (or a fusion whose
        computation converts) from a narrower dtype, count the wire at the
        narrower width.  Recorded as a hardware-adaptation assumption in
        DESIGN.md."""
        if not inst.operands:
            return 1.0
        src = comp.instrs.get(inst.operands[0])
        if src is None:
            return 1.0
        out_parts = _shape_elems(inst.shape_str)
        if not out_parts:
            return 1.0
        out_bytes_per = _DTYPE_BYTES.get(out_parts[0][0], 4)

        def narrowest_convert(instr, cmp) -> int | None:
            if instr.opcode == "convert":
                op0 = cmp.instrs.get(instr.operands[0]) if instr.operands \
                    else None
                if op0 is not None:
                    p = _shape_elems(op0.shape_str)
                    if p:
                        return _DTYPE_BYTES.get(p[0][0], 4)
                # operand may be a computation parameter: parse the convert
                # input dtype from the instruction's own rest (unavailable)
                return None
            if instr.opcode == "fusion":
                inner = self.comps.get(_attr_comps(instr).get("calls", ""))
                if inner is not None:
                    widths = []
                    for n in inner.order:
                        ii = inner.instrs[n]
                        if ii.opcode == "convert":
                            p = _shape_elems(ii.shape_str)
                            src_p = (
                                _shape_elems(
                                    inner.instrs[ii.operands[0]].shape_str)
                                if ii.operands and ii.operands[0]
                                in inner.instrs else [])
                            for q in src_p:
                                widths.append(_DTYPE_BYTES.get(q[0], 4))
                    if widths:
                        return min(widths)
            return None

        w = narrowest_convert(src, comp)
        if w is not None and w < out_bytes_per:
            return w / out_bytes_per
        return 1.0

    def _uses_map(self, comp: Computation) -> dict:
        if not hasattr(comp, "_uses"):
            uses: dict = {}
            for iname in comp.order:
                for op in comp.instrs[iname].operands:
                    uses.setdefault(op, []).append(iname)
            comp._uses = uses
        return comp._uses

    def _param_read_bytes(self, inner: Computation, pname: str,
                          full: float) -> float:
        """Bytes actually read from one fusion parameter, following the
        dataflow through transparent ops: slicing consumers charge their
        slice, a DUS consuming it as the in-place buffer charges nothing,
        anything else charges the full operand."""
        uses_map = self._uses_map(inner)
        frontier = [pname]
        seen = set()
        charged = 0.0
        while frontier:
            nm = frontier.pop()
            for uname in uses_map.get(nm, ()):
                if uname in seen:
                    continue
                seen.add(uname)
                u = inner.instrs[uname]
                if u.opcode in ("dynamic-slice", "slice", "gather"):
                    charged += _total_bytes(u.shape_str)
                elif u.opcode == "dynamic-update-slice" and \
                        u.operands and u.operands[0] == nm:
                    pass  # in-place destination: not read
                elif u.opcode in self._TRANSPARENT:
                    frontier.append(uname)
                else:
                    return full
        return charged

    def _fusion_bytes(self, inst: Instr, comp: Computation,
                      inner: Computation | None) -> float:
        """Call-site bytes of a fusion, slice/update-aware: scan-over-layer
        weight stacks consumed via dynamic-slice charge the slice; a fusion
        rooted in dynamic-update-slice writes only the update (XLA aliases
        the buffer in place)."""
        out_b = float(_total_bytes(inst.shape_str))
        if inner is None:
            return out_b + sum(
                _total_bytes(comp.instrs[n].shape_str)
                for n in inst.operands if n in comp.instrs)
        root_name = getattr(inner, "_root_name", None)
        root = inner.instrs.get(root_name) if root_name else None
        if root is not None and root.opcode == "dynamic-update-slice":
            upd = (inner.instrs.get(root.operands[1])
                   if len(root.operands) > 1 else None)
            out_b = float(_total_bytes(upd.shape_str)) if upd else out_b

        params = {}
        for iname in inner.order:
            ii = inner.instrs[iname]
            if ii.opcode == "parameter":
                m = re.search(r"^(\d+)\)", ii.rest)
                if m:
                    params[int(m.group(1))] = iname
        total = out_b
        for idx, op_name in enumerate(inst.operands):
            src = comp.instrs.get(op_name)
            full = float(_total_bytes(src.shape_str)) if src else 0.0
            pname = params.get(idx)
            if pname is None:
                total += full
            else:
                total += self._param_read_bytes(inner, pname, full)
        return total

    # -- per-computation totals ------------------------------------------

    def comp_cost(self, name: str, inside_fusion: bool = False) -> dict:
        key = (name, inside_fusion)
        if key in self._memo:
            return self._memo[key]
        comp = self.comps.get(name)
        total = {"flops": 0.0, "bytes": 0.0, "coll": {}}
        if comp is None:
            return total

        def add(dst, src, scale=1.0):
            dst["flops"] += src["flops"] * scale
            dst["bytes"] += src["bytes"] * scale
            for k, v in src["coll"].items():
                dst["coll"][k] = dst["coll"].get(k, 0.0) + v * scale

        for iname in comp.order:
            inst = comp.instrs[iname]
            refs = _attr_comps(inst)
            if inst.opcode == "while":
                trip = None
                if "condition" in refs:
                    trip = _trip_count(
                        self.comps.get(refs["condition"], Computation("")),
                        self.comps)
                if trip is None:
                    trip = 1
                    self.unknown_trip_loops += 1
                body = self.comp_cost(refs.get("body", ""), False)
                cond = self.comp_cost(refs.get("condition", ""), False)
                add(total, body, trip)
                add(total, cond, trip)
            elif inst.opcode == "fusion":
                inner = self.comp_cost(refs.get("calls", ""), True)
                add(total, {"flops": inner["flops"], "bytes": 0.0,
                            "coll": inner["coll"]})
                if not inside_fusion:
                    fb = self._fusion_bytes(
                        inst, comp, self.comps.get(refs.get("calls", "")))
                    if self._is_vmem_tile(inst.shape_str):
                        self.vmem_dropped_bytes += fb
                        fb = 0.0
                    add(total, {"flops": 0.0, "coll": {}, "bytes": fb})
            elif inst.opcode == "conditional":
                branches = refs.get("branches", [])
                if branches:
                    costs = [self.comp_cost(b, inside_fusion)
                             for b in branches]
                    add(total, max(costs, key=lambda c: c["flops"]
                                   + c["bytes"]))
                add(total, self._instr_cost(inst, comp, inside_fusion))
            elif inst.opcode in ("call", "custom-call", "async-start"):
                callee = refs.get("to_apply") or refs.get("calls")
                if callee:
                    add(total, self.comp_cost(callee, inside_fusion))
                add(total, self._instr_cost(inst, comp, inside_fusion))
            elif inst.opcode in ("reduce", "sort", "map", "scatter",
                                 "reduce-window", "select-and-scatter"):
                # have applied computations; their cost ~ per-element,
                # approximated by the instruction cost itself
                add(total, self._instr_cost(inst, comp, inside_fusion))
            else:
                add(total, self._instr_cost(inst, comp, inside_fusion))
        self._memo[key] = total
        return total

    def entry_cost(self) -> dict:
        if self.entry is None:
            return {"flops": 0.0, "bytes": 0.0, "coll": {}}
        out = self.comp_cost(self.entry.name)
        out = dict(out)
        out["coll"] = dict(out["coll"])
        out["coll"]["total"] = float(sum(out["coll"].values()))
        out["unknown_trip_loops"] = self.unknown_trip_loops
        return out


def analyze(hlo_text: str, vmem_tiles: dict | None = None) -> dict:
    """Trip-count-aware {flops, bytes, coll{...,total}} of the entry."""
    hc = HloCost(hlo_text, vmem_tiles=vmem_tiles)
    out = hc.entry_cost()
    out["vmem_dropped_bytes"] = hc.vmem_dropped_bytes
    return out


def byte_histogram(hlo_text: str, top: int = 25) -> list:
    """Top HBM-byte contributors [(scaled_bytes, trips, opcode, name,
    shape)] — the §Perf profiling view of the compiled artifact."""
    hc = HloCost(hlo_text)
    hc.entry_cost()
    rows: list = []

    def walk(comp_name, scale):
        comp = hc.comps.get(comp_name)
        if comp is None:
            return
        for iname in comp.order:
            inst = comp.instrs[iname]
            refs = _attr_comps(inst)
            if inst.opcode == "while":
                trip = _trip_count(
                    hc.comps.get(refs.get("condition", ""),
                                 Computation("")), hc.comps) or 1
                walk(refs.get("body", ""), scale * trip)
            elif inst.opcode == "fusion":
                b = hc._fusion_bytes(inst, comp,
                                     hc.comps.get(refs.get("calls", "")))
                rows.append((b * scale, scale, inst.opcode, iname,
                             inst.shape_str[:70]))
            elif inst.opcode not in _ZERO_BYTES_OPS:
                rows.append((hc._instr_bytes(inst, comp) * scale, scale,
                             inst.opcode, iname, inst.shape_str[:70]))

    if hc.entry is not None:
        walk(hc.entry.name, 1.0)
    rows.sort(reverse=True)
    return rows[:top]
