"""Public serving API.

``__all__`` below is the stable surface — documented in docs/API.md
(tools/check_docs.py fails if the two drift apart).  Everything else in
this package is internal and may change between PRs.
"""
from .config import EngineConfig, EngineConfigError  # noqa: F401
from .engine import GenerationEngine, Request  # noqa: F401
from .async_engine import (AsyncServingFrontend, FrontendClosed,  # noqa: F401
                           FrontendOverloaded, TokenStream)
from .router import Router  # noqa: F401
from .telemetry import MetricsRegistry, Telemetry  # noqa: F401
from .sampler import filter_logits, greedy, residual_probs, sample_logits  # noqa: F401
from .scheduler import Preempted, Scheduler  # noqa: F401
from . import spec  # noqa: F401

__all__ = [
    "EngineConfig",
    "EngineConfigError",
    "GenerationEngine",
    "Request",
    "AsyncServingFrontend",
    "TokenStream",
    "FrontendOverloaded",
    "FrontendClosed",
    "Router",
    "Telemetry",
    "MetricsRegistry",
]
