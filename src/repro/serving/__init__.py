from .sampler import filter_logits, greedy, residual_probs, sample_logits  # noqa: F401
from .engine import GenerationEngine, Request  # noqa: F401
from .scheduler import Preempted, Scheduler  # noqa: F401
from . import spec  # noqa: F401
