from .sampler import greedy, sample_logits  # noqa: F401
from .engine import GenerationEngine, Request  # noqa: F401
