from .sampler import filter_logits, greedy, sample_logits  # noqa: F401
from .engine import GenerationEngine, Request  # noqa: F401
from .scheduler import Preempted, Scheduler  # noqa: F401
