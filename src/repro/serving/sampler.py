"""Token samplers (pure functions over final-position logits).

``filter_logits`` is the masking stage exposed on its own so its
invariants are directly testable (tests/test_sampler.py): surviving
logits keep their *original* values (masking never renormalizes over
excluded entries — renormalization happens implicitly in the final
softmax over the survivors), the greedy token always survives, and
top-k/top-p select exactly the documented sets.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def greedy(logits):
    """logits (B, 1, V) -> (B, 1) int32."""
    return jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)[:, None]


def root_key(seed: int):
    """The engine's root PRNG key.  The single registered construction
    site for serving key material: everything downstream derives from
    this key via :func:`request_key` (and the spec-decode
    ``accept_key``/``residual_key`` wrappers), which is what keeps
    sampling schedule-invariant — enforced statically by the
    ``prng-discipline`` lint pass (docs/LINTS.md)."""
    return jax.random.PRNGKey(seed)


def request_key(rng0, req_id, position):
    """The serving engine's per-draw PRNG key: fold (request id, token
    position) into the engine seed.  A request's sampled stream is a pure
    function of its own state — independent of batching, scheduling,
    preemption, and (with chunked prefill) of how many chunks its prompt
    was split into: the **first** token always draws at position 0,
    whether its logits came from a whole-prompt prefill or from the final
    chunk.  Works under ``vmap`` (the engine draws one batched sample per
    step) and eagerly (the per-request first-token draw)."""
    return jax.random.fold_in(jax.random.fold_in(rng0, req_id), position)


def filter_logits(x, *, top_k: int = 0, top_p: float = 0.0):
    """Mask logits ``x`` (B, V) float32 to the sampling support.

    top-k keeps the k largest entries; top-p keeps the smallest set whose
    softmax mass reaches ``top_p``.  Excluded entries become ``-inf``;
    included entries are returned **unchanged** (no renormalization at
    this stage), so downstream ``softmax``/``categorical`` distributes
    mass proportionally to the original logits."""
    if top_k:
        kth = jax.lax.top_k(x, top_k)[0][..., -1:]
        x = jnp.where(x < kth, -jnp.inf, x)
    if top_p:
        srt = jnp.sort(x, axis=-1)[:, ::-1]
        probs = jax.nn.softmax(srt, axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        # smallest set with cumulative mass >= top_p
        cutoff_idx = jnp.argmax(cum >= top_p, axis=-1)
        cutoff = jnp.take_along_axis(srt, cutoff_idx[:, None], axis=-1)
        x = jnp.where(x < cutoff, -jnp.inf, x)
    return x


def residual_probs(p, q):
    """The exact rejection-sampling residual ``max(0, p - q) / Z``.

    ``p``/``q`` are probability vectors (..., V) — the target and draft
    distributions at one position.  ``Z = sum(max(0, p - q))`` equals
    ``1 - sum(min(p, q))``, which is exactly the total rejection
    probability, so sampling the residual after a rejection makes the
    marginal next-token distribution equal ``p`` identically (the
    speculative-decoding identity; proof in docs/ARCHITECTURE.md).

    Edge cases (tests/test_speculative.py): ``p == q`` gives ``Z == 0``
    — a rejection is then impossible (the acceptance probability
    ``min(1, p/q)`` is 1 everywhere q has mass), so the residual is
    unreachable; this returns ``p`` to keep the function total.  A
    one-hot ``p`` concentrates the residual on its hot token; a
    zero-overlap ``q`` leaves the residual equal to ``p``."""
    r = jnp.maximum(p - q, 0.0)
    z = jnp.sum(r, axis=-1, keepdims=True)
    return jnp.where(z > 0, r / jnp.where(z > 0, z, 1.0), p)


def sample_logits(logits, rng, *, temperature: float = 1.0,
                  top_k: int = 0, top_p: float = 0.0):
    """Temperature / top-k / top-p sampling.  logits (B, 1, V) -> (B, 1).

    ``temperature <= 0`` is exact greedy (argmax, no randomness); for a
    fixed ``rng`` the result is deterministic and identical under
    ``jax.jit`` (tests/test_sampler.py)."""
    x = logits[:, -1, :].astype(jnp.float32)
    if temperature <= 0.0:
        return greedy(logits)
    x = filter_logits(x / temperature, top_k=top_k, top_p=top_p)
    tok = jax.random.categorical(rng, x, axis=-1)
    return tok.astype(jnp.int32)[:, None]
