"""Token samplers (pure functions over final-position logits)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def greedy(logits):
    """logits (B, 1, V) -> (B, 1) int32."""
    return jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)[:, None]


def sample_logits(logits, rng, *, temperature: float = 1.0,
                  top_k: int = 0, top_p: float = 0.0):
    """Temperature / top-k / top-p sampling.  logits (B, 1, V) -> (B, 1)."""
    x = logits[:, -1, :].astype(jnp.float32)
    if temperature <= 0.0:
        return greedy(logits)
    x = x / temperature
    if top_k:
        kth = jax.lax.top_k(x, top_k)[0][..., -1:]
        x = jnp.where(x < kth, -jnp.inf, x)
    if top_p:
        srt = jnp.sort(x, axis=-1)[:, ::-1]
        probs = jax.nn.softmax(srt, axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        # smallest set with cumulative mass >= top_p
        cutoff_idx = jnp.argmax(cum >= top_p, axis=-1)
        cutoff = jnp.take_along_axis(srt, cutoff_idx[:, None], axis=-1)
        x = jnp.where(x < cutoff, -jnp.inf, x)
    tok = jax.random.categorical(rng, x, axis=-1)
    return tok.astype(jnp.int32)[:, None]
