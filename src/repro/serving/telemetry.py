"""Serving telemetry: zero-dependency metrics registry + facade.

The engine's numbers used to come from ad-hoc ``KVCacheMonitor`` dict
snapshots and benchmark-local timers — no per-request latency, no
percentiles, no way to see one request's lifecycle.  This module is the
metrics half of the telemetry subsystem (the span tracer lives in
``runtime.tracing``, the Chrome-trace exporter in
``runtime.trace_export``):

  * :class:`Counter` — monotone total (requests, tokens, compile events,
    swap bytes).
  * :class:`Gauge` — last-write-wins level (queue depth, pages in use);
    also tracks the peak over its lifetime, which is what the serving
    summary reports.
  * :class:`Histogram` — fixed-bucket distribution with p50/p95/p99
    estimation (TTFT, request latency, decode-step seconds).  Buckets
    are fixed at construction, so ``observe`` is O(log n_buckets) with
    no allocation — cheap enough for the engine hot loop.
  * :class:`MetricsRegistry` — get-or-create keyed store of the above;
    ``snapshot()`` serializes everything to plain dicts (what
    ``trace_export`` embeds and ``launch/serve.py --metrics-interval``
    prints).
  * :class:`Telemetry` — the bundle the engine takes: one registry plus
    an optional :class:`repro.runtime.tracing.SpanTracer` and its
    request-state tracker.

Every metric name emitted in ``src/`` must be documented in
``docs/OBSERVABILITY.md`` — ``tools/check_metrics.py`` (run by the CI
docs gate) enforces this.  Telemetry never changes engine behavior: it
is host-side observation only, and the serving differential tests
assert bit-identity with telemetry on vs off.
"""
from __future__ import annotations

import math
from bisect import bisect_left


class Metric:
    """Base: a named instrument with a unit and a one-line description."""

    kind = "metric"

    def __init__(self, name: str, unit: str = "", desc: str = ""):
        self.name, self.unit, self.desc = name, unit, desc

    def describe(self) -> dict:
        return {"type": self.kind, "unit": self.unit, "desc": self.desc}


class Counter(Metric):
    """Monotonically increasing total."""

    kind = "counter"

    def __init__(self, name: str, unit: str = "", desc: str = ""):
        super().__init__(name, unit, desc)
        self.value = 0

    def inc(self, n: int | float = 1) -> None:
        self.value += n

    def describe(self) -> dict:
        return {**super().describe(), "value": self.value}


class Gauge(Metric):
    """Last-write-wins level; remembers its lifetime peak."""

    kind = "gauge"

    def __init__(self, name: str, unit: str = "", desc: str = ""):
        super().__init__(name, unit, desc)
        self.value = 0.0
        self.peak = float("-inf")
        self.n_sets = 0

    def set(self, v: float) -> None:
        self.value = v
        if v > self.peak:
            self.peak = v
        self.n_sets += 1

    def describe(self) -> dict:
        return {**super().describe(), "value": self.value,
                "peak": self.peak if self.n_sets else 0.0}


class Histogram(Metric):
    """Fixed-bucket histogram with interpolated percentile estimation.

    ``edges`` are ascending bucket upper bounds; observations land in
    ``(edges[i-1], edges[i]]`` (bucket 0 is everything ``<= edges[0]``,
    the overflow bucket everything above ``edges[-1]``).  Buckets never
    grow, so memory is bounded and ``observe`` allocates nothing.
    ``percentile`` interpolates linearly inside the winning bucket,
    clamping the outermost buckets to the observed min/max — accuracy is
    one bucket width, which the default geometric edges keep at ~20%
    relative error over nine decades of seconds."""

    kind = "histogram"

    def __init__(self, name: str, edges=None, unit: str = "s",
                 desc: str = ""):
        super().__init__(name, unit, desc)
        self.edges = list(edges) if edges is not None \
            else geometric_edges(1e-5, 60.0, factor=1.2)
        if sorted(self.edges) != self.edges or len(self.edges) < 1:
            raise ValueError(f"histogram {name}: edges must be ascending")
        self.counts = [0] * (len(self.edges) + 1)
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    def observe(self, v: float) -> None:
        self.counts[bisect_left(self.edges, v)] += 1
        self.count += 1
        self.sum += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else math.nan

    def percentile(self, q: float) -> float:
        """Interpolated ``q``-quantile (``q`` in [0, 1])."""
        if self.count == 0:
            return math.nan
        target = q * self.count
        cum = 0
        for i, c in enumerate(self.counts):
            if c and cum + c >= target:
                lo = self.min if i == 0 else self.edges[i - 1]
                hi = self.max if i == len(self.edges) else self.edges[i]
                lo = max(lo, self.min)
                hi = min(hi, self.max)
                if hi <= lo:
                    return lo
                return lo + (hi - lo) * max(target - cum, 0.0) / c
            cum += c
        return self.max

    def describe(self) -> dict:
        return {**super().describe(), "count": self.count, "sum": self.sum,
                "min": self.min if self.count else None,
                "max": self.max if self.count else None,
                "mean": self.mean if self.count else None,
                "p50": self.percentile(0.50) if self.count else None,
                "p95": self.percentile(0.95) if self.count else None,
                "p99": self.percentile(0.99) if self.count else None}


def geometric_edges(lo: float, hi: float, factor: float = 1.2) -> list:
    """Geometric bucket edges from ``lo`` up to at least ``hi``."""
    if not (lo > 0 and hi > lo and factor > 1):
        raise ValueError((lo, hi, factor))
    edges, e = [], lo
    while e < hi * factor:
        edges.append(e)
        e *= factor
    return edges


def linear_edges(lo: float, hi: float, n: int) -> list:
    """``n`` equal-width bucket edges spanning [lo, hi]."""
    step = (hi - lo) / n
    return [lo + step * (i + 1) for i in range(n)]


class MetricsRegistry:
    """Get-or-create store of named metrics.

    A name is bound to one instrument kind for the registry's lifetime;
    re-requesting it returns the same object (so call sites never need
    to thread metric handles around), and requesting it as a different
    kind raises."""

    def __init__(self):
        self._metrics: dict[str, Metric] = {}

    def _get(self, cls, name: str, **kw):
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = cls(name, **kw)
        elif not isinstance(m, cls):
            raise TypeError(f"metric {name!r} already registered as "
                            f"{m.kind}, requested {cls.kind}")
        return m

    def counter(self, name: str, unit: str = "", desc: str = "") -> Counter:
        return self._get(Counter, name, unit=unit, desc=desc)

    def gauge(self, name: str, unit: str = "", desc: str = "") -> Gauge:
        return self._get(Gauge, name, unit=unit, desc=desc)

    def histogram(self, name: str, edges=None, unit: str = "s",
                  desc: str = "") -> Histogram:
        return self._get(Histogram, name, edges=edges, unit=unit, desc=desc)

    def get(self, name: str):
        return self._metrics.get(name)

    def __contains__(self, name: str) -> bool:
        return name in self._metrics

    def names(self) -> list:
        return sorted(self._metrics)

    def value(self, name: str, default=0):
        """Scalar value of a counter/gauge (``default`` when absent)."""
        m = self._metrics.get(name)
        return default if m is None else m.value

    def snapshot(self) -> dict:
        """JSON-safe dump of every metric (sorted by name)."""
        return {n: self._metrics[n].describe() for n in self.names()}


class Telemetry:
    """The bundle the serving engine takes: registry + optional tracer.

    ``trace=False`` keeps only the metrics registry (counters/gauges/
    histograms still collect; no per-event buffer is kept at all) —
    the cheapest always-on configuration.  With tracing on, the span
    buffer is bounded by ``trace_capacity`` events; overflow increments
    a drop counter instead of growing (``SpanTracer``)."""

    def __init__(self, registry=None, tracer=None, *, trace: bool = True,
                 trace_capacity: int = 200_000):
        from repro.runtime.tracing import RequestStateTracker, SpanTracer
        self.registry = registry if registry is not None else MetricsRegistry()
        if tracer is None and trace:
            tracer = SpanTracer(capacity=trace_capacity)
        self.tracer = tracer
        self.requests = (RequestStateTracker(tracer)
                         if tracer is not None else None)


def serving_report_line(registry: MetricsRegistry) -> str:
    """One-line periodic stats report for ``launch/serve.py
    --metrics-interval`` (and anything else that wants a heartbeat)."""
    parts = []
    toks = registry.value("serving_tokens_generated_total")
    parts.append(f"tok={int(toks)}")
    fin = registry.value("serving_requests_finished_total")
    sub = registry.value("serving_requests_submitted_total")
    parts.append(f"done={int(fin)}/{int(sub)}")
    parts.append(f"q={int(registry.value('serving_queue_depth'))}")
    parts.append(f"act={int(registry.value('serving_active_slots'))}")
    h = registry.get("serving_decode_step_seconds")
    if h is not None and h.count:
        parts.append(f"step p50={h.percentile(0.5) * 1e3:.1f}ms "
                     f"p99={h.percentile(0.99) * 1e3:.1f}ms")
    t = registry.get("serving_ttft_seconds")
    if t is not None and t.count:
        parts.append(f"ttft p50={t.percentile(0.5) * 1e3:.0f}ms "
                     f"p95={t.percentile(0.95) * 1e3:.0f}ms")
    if "kvstat_pages_in_use" in registry:
        parts.append(f"pages={int(registry.value('kvstat_pages_in_use'))}")
    if "kvcache_swap_bytes_used" in registry:
        parts.append(
            f"swap={int(registry.value('kvcache_swap_bytes_used'))}B")
    npre = registry.value("serving_preempted_total")
    if npre:
        parts.append(f"preempt={int(npre)}")
    return " ".join(parts)
