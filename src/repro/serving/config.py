"""Typed engine configuration: one validated object instead of 19 kwargs.

``EngineConfig`` consolidates every ``GenerationEngine`` constructor
option into a frozen dataclass with grouped fields (batch window / paged
cache / swap + preemption / chunked prefill / prefix sharing /
speculative decoding / telemetry), and — more importantly — centralises
the **feature-gating matrix** that used to live as scattered
warn-and-fall-back checks inside ``GenerationEngine.__init__``:

========================  =====================================================
feature                   requires
========================  =====================================================
paged cache               a pageable decoder stack (any 'attn'/'nope' layer,
                          no encoder-decoder) and ``max_batch`` divisible by
                          the mesh batch-axes size
chunked prefill           the paged cache, an all-'attn'/'nope' layer stack,
                          no model mesh axis
prefix sharing            chunked prefill and a single batch shard
speculative decoding      the paged cache, an all-'attn'/'nope' target stack,
                          no model mesh axis, whole-prompt prefill, a draft
                          sharing the target vocabulary
========================  =====================================================

``validate(cfg)`` resolves a config against an architecture + mesh and
returns the resolved copy.  Arch-driven resolution (an encoder-decoder
or pure-recurrent stack simply has nothing to page) is silent — it is
not a user error.  A *user-requested feature* that cannot be served is
a **fallback**: in the default lenient mode it warns (the exact
warnings the engine used to emit) and disables the feature; with
``strict=True`` — the mode ``launch/serve.py`` uses at argument-parse
time — every fallback is an ``EngineConfigError`` instead, raised
before any parameters are initialised.

Runtime objects (``mesh``, ``draft_params``, ``telemetry``,
``kv_monitor``) are carried but excluded from equality/``repr`` so
resolved configs compare by their declarative fields.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass, field, replace

from repro.configs.base import ArchConfig
from repro.kvcache.paged import PAGED_KINDS
from repro.runtime import sharding as SH

CACHE_MODES = ("paged", "monolithic")


class EngineConfigError(ValueError):
    """An EngineConfig field (or flag combination) that cannot be
    served: invalid values, and — under ``validate(strict=True)`` —
    user-requested features the architecture/mesh cannot support."""


@dataclass(frozen=True)
class EngineConfig:
    """Declarative ``GenerationEngine`` configuration (see module
    docstring for the gating matrix and docs/API.md for the public
    surface).  Field groups mirror the subsystems:

    * batch window: ``max_batch``, ``max_len``, ``rng_seed``, ``mesh``
    * paged cache: ``cache_mode``, ``page_size``, ``n_pages``,
      ``compress_cold``, ``n_cold_slots``
    * swap + preemption: ``swap_bytes`` (positive cap, ``-1`` unbounded,
      ``None``/``0`` off), ``preemption``
    * chunked prefill: ``prefill_chunk``, ``prefill_budget``
    * prefix sharing: ``prefix_sharing``
    * speculative decoding: ``draft_params``, ``draft_cfg``, ``spec_k``
    * observability: ``telemetry``, ``kv_monitor``
    """

    # -- batch window / keys --
    max_batch: int = 8
    max_len: int = 512
    rng_seed: int = 0
    mesh: object = field(default=None, compare=False, repr=False)
    # -- paged cache --
    cache_mode: str = "paged"
    page_size: int = 16
    n_pages: int | None = None
    compress_cold: bool = False
    n_cold_slots: int | None = None
    # -- swap + preemption --
    swap_bytes: int | None = None
    preemption: bool = True
    # -- chunked prefill --
    prefill_chunk: int = 0
    prefill_budget: int | None = None
    # -- prefix sharing --
    prefix_sharing: bool = False
    # -- speculative decoding --
    draft_params: object = field(default=None, compare=False, repr=False)
    draft_cfg: ArchConfig | None = None
    spec_k: int = 4
    # -- observability --
    telemetry: object = field(default=None, compare=False, repr=False)
    kv_monitor: object = field(default=None, compare=False, repr=False)

    def __post_init__(self):
        bad = []
        if self.cache_mode not in CACHE_MODES:
            bad.append(f"cache_mode={self.cache_mode!r} "
                       f"(must be one of {CACHE_MODES})")
        if self.max_batch < 1:
            bad.append(f"max_batch={self.max_batch} (must be >= 1)")
        if self.max_len < 1:
            bad.append(f"max_len={self.max_len} (must be >= 1)")
        if self.page_size < 1:
            bad.append(f"page_size={self.page_size} (must be >= 1)")
        if self.spec_k < 1:
            bad.append(f"spec_k={self.spec_k} (must be >= 1)")
        if bad:
            raise EngineConfigError("; ".join(bad))

    # -- mesh-derived helpers ---------------------------------------------

    def n_shards(self) -> int:
        """Size of the mesh batch axes (1 without a mesh) — the divisor
        ``max_batch`` must honour for per-shard slot ranges."""
        if self.mesh is None:
            return 1
        return SH._axis_size(self.mesh, SH.batch_axes(self.mesh))

    def n_model_shards(self) -> int:
        if self.mesh is not None and "model" in self.mesh.axis_names:
            return self.mesh.shape["model"]
        return 1

    # -- the gating matrix -------------------------------------------------

    def validate(self, cfg: ArchConfig, *, strict: bool = False
                 ) -> "EngineConfig":
        """Resolve this config against architecture ``cfg`` and the
        attached mesh; return the resolved copy the engine serves.

        Arch-driven resolution (nothing to page) is silent.  Every
        *user-requested* feature that cannot be served either warns and
        falls back (default) or — ``strict=True`` — raises one
        ``EngineConfigError`` listing every incompatibility at once."""
        problems: list[str] = []
        cache_mode = self.cache_mode
        n_shards, n_model = self.n_shards(), self.n_model_shards()
        # arch-driven: encoder-decoders and pure recurrent stacks have
        # nothing to page — a silent resolve, never an error
        if cache_mode == "paged" and (
                cfg.encoder_decoder
                or not any(cfg.layer_kind(i) in ("attn", "nope")
                           for i in range(cfg.n_layers))):
            cache_mode = "monolithic"
        if cache_mode == "paged" and self.max_batch % n_shards:
            problems.append(
                f"max_batch={self.max_batch} not divisible by the mesh "
                f"batch-axes size {n_shards}; falling back to the "
                f"monolithic cache")
            cache_mode = "monolithic"
        all_paged = all(cfg.layer_kind(i) in PAGED_KINDS
                        for i in range(cfg.n_layers))
        chunk = min(max(self.prefill_chunk, 0), self.max_len)
        if chunk and (cache_mode != "paged" or not all_paged
                      or cfg.encoder_decoder or n_model > 1):
            problems.append(
                f"prefill_chunk={self.prefill_chunk} needs the paged "
                f"cache, an all-'attn'/'nope' layer stack and no model "
                f"mesh axis; falling back to whole-prompt prefill")
            chunk = 0
        budget = max(self.prefill_budget or chunk, 1) if chunk else 0
        prefix_sharing = bool(self.prefix_sharing)
        if prefix_sharing and (not chunk or n_shards != 1):
            problems.append(
                "prefix_sharing needs chunked prefill (prefill_chunk > 0, "
                "with its paged-cache requirements) and a single batch "
                "shard; serving without sharing")
            prefix_sharing = False
        draft_params, draft_cfg = self.draft_params, self.draft_cfg
        if draft_cfg is not None and (
                cache_mode != "paged" or not all_paged
                or cfg.encoder_decoder or draft_cfg.encoder_decoder
                or n_model > 1 or chunk
                or draft_cfg.vocab_size != cfg.vocab_size):
            problems.append(
                "speculative decoding needs the paged cache, an "
                "all-'attn'/'nope' target stack, no model mesh axis, "
                "whole-prompt prefill and a same-vocabulary draft; "
                "serving target-only")
            draft_params = draft_cfg = None
        if problems and strict:
            raise EngineConfigError(
                "incompatible engine configuration:\n  - "
                + "\n  - ".join(problems))
        for msg in problems:
            warnings.warn(msg, stacklevel=2)
        return replace(self, cache_mode=cache_mode, prefill_chunk=chunk,
                       prefill_budget=budget, prefix_sharing=prefix_sharing,
                       draft_params=draft_params, draft_cfg=draft_cfg)

    # -- CLI mapping -------------------------------------------------------

    @classmethod
    def from_args(cls, args, cfg: ArchConfig | None = None,
                  **overrides) -> "EngineConfig":
        """Build a config from ``launch/serve.py``'s argparse namespace —
        the 1:1 flag→field mapping, in one place.

        Ignored-flag combinations (``--spec-k``/``--draft-seed`` without
        ``--draft``) raise ``EngineConfigError`` immediately; when
        ``cfg`` is given the result is also resolved with
        ``validate(cfg, strict=True)``, so incompatible feature requests
        (e.g. ``--prefix-sharing`` with ``--draft``) fail at
        argument-parse time instead of deep inside engine construction.
        ``overrides`` supply fields with no CLI flag (``mesh``,
        ``draft_cfg``, ``telemetry``, ...)."""
        ignored = []
        if not getattr(args, "draft", None):
            if getattr(args, "spec_k", None) is not None:
                ignored.append("--spec-k")
            if getattr(args, "draft_seed", None) is not None:
                ignored.append("--draft-seed")
        if ignored:
            raise EngineConfigError(
                f"{'/'.join(ignored)} ha{'s' if len(ignored) == 1 else 've'}"
                f" no effect without --draft")
        spec_k = getattr(args, "spec_k", None)
        ecfg = cls(
            max_batch=args.max_batch,
            max_len=args.max_len,
            rng_seed=args.seed,
            cache_mode=("monolithic" if args.cache == "monolithic"
                        else "paged"),
            page_size=args.page_size,
            n_pages=args.n_pages,
            compress_cold=args.cache == "paged-compressed",
            swap_bytes=args.swap_bytes,
            preemption=args.preemption,
            prefill_chunk=args.prefill_chunk,
            prefill_budget=args.prefill_budget or None,
            prefix_sharing=args.prefix_sharing,
            spec_k=spec_k if spec_k is not None else 4,
            **overrides)
        if cfg is not None:
            ecfg = ecfg.validate(cfg, strict=True)
        return ecfg
