"""Preemptive request scheduler: priority admission over virtual capacity.

The engine used to treat the device page pool as a hard ceiling: FIFO
admission, and ``OutOfPages`` the moment a workload's footprint exceeded
``n_pages``.  With the swap tier (``kvcache/swap.py``) the pool becomes a
cache over a much larger *virtual* capacity — device pages + host swap —
and this module supplies the policy layer:

  * **priority classes** — ``Request.priority`` (higher runs first);
    FIFO within a class, so priority 0 everywhere reproduces the old
    admission order exactly.
  * **admission control against virtual capacity** — a request is queued,
    not rejected, while its pages are swappable; ``OutOfPages`` is raised
    only for requests that can *never* fit (their worst-case resident
    working set exceeds every shard's page range — swap cannot help,
    because a slot's whole history must be device-resident to gather).
  * **whole-request preemption** — when a higher-priority request waits
    or an active slot cannot grow, the victim (lowest priority, then
    least-recently scheduled) is compressed and swapped out wholesale:
    the engine evicts all its pages, detaches its host state into a
    :class:`Preempted` record, and requeues it at the *front* of its
    priority class.  Resume faults the pages back and re-splices the
    slot's timeline — bit-identical to a run that was never preempted,
    because page restore is lossless and greedy/fold-in sampling depends
    only on the request's own state.

The scheduler is pure host-side policy: it owns the queues and victim
choice; the engine owns execution (prefill, evict/fault, splicing).

**Draft/target slot pairing (speculative decoding).**  With a draft
model attached (``engine.GenerationEngine(draft_params=...)``), every
target slot ``s`` is paired with row ``s`` of the draft cache — one
request owns both, so admission, victim choice and preemption stay
single-keyed on the target slot and *preempting one preempts both* by
construction: ``_preempt`` snapshots the draft row into
:attr:`Preempted.draft_state` alongside the target's swapped pages, and
resume reinstalls it before the next verify round.  The draft thus rides
the swap tier's host side (its state is host-stashed bytes, like the
hybrid-arch recurrent state in :attr:`Preempted.state`) without its own
page accounting — the draft cache is monolithic and preallocated, so it
never contributes page pressure and the admission math is unchanged.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field


@dataclass
class Preempted:
    """A swapped-out request awaiting resume — either partially generated
    (decode phase) or, with chunked prefill, partially **prefilled**:
    ``prefill_pos`` is not None for a request preempted mid-prefill, and
    names the number of prompt tokens whose K/V is already in the swapped
    pages (always == ``host_len``; the next chunk resumes there).  A
    mid-prefill record has no sampled token yet, so ``last_tok`` is a
    placeholder that resume never feeds to a decode step."""

    req: object                 # serving.engine.Request
    pages: list                 # all-negative swap sentinels (detach_slot)
    skip: set                   # incompressible-page indices (preserved)
    host_len: int               # next cache write position
    last_tok: int               # last sampled token (decode input on resume)
    state: dict = field(default_factory=dict)
    # ^ non-paged per-slot cache state (local-attention rings, recurrent
    #   states of hybrid archs) — PagedKVCache.snapshot_slot_state
    prefill_pos: int | None = None   # prompt tokens consumed (mid-prefill)
    draft_state: list | None = None
    # ^ the paired draft-model cache row (speculative decoding): host
    #   copies of every draft-cache leaf's slot slice, taken by
    #   engine._draft_snapshot at preemption and re-spliced on resume —
    #   preempting the target slot preempts its draft by construction
    #   (module docstring, "Draft/target slot pairing")

    @property
    def priority(self) -> int:
        return self.req.priority

    @property
    def prefill_tokens_left(self) -> int:
        """Prompt tokens still to prefill on resume (0 in decode phase)."""
        if self.prefill_pos is None:
            return 0
        return len(self.req.prompt) - self.prefill_pos


@dataclass
class Scheduler:
    """Queue + policy.  ``paged`` is the engine's ``PagedKVCache`` (None
    for the monolithic fallback: every request "fits" and preemption is
    structurally off)."""

    paged: object = None
    preemption: bool = True
    chunk_tokens: int = 0      # engine's prefill chunk (0 = whole-prompt)
    telemetry: object = None   # serving.telemetry.Telemetry (engine-set)
    _classes: dict = field(default_factory=dict)   # priority -> deque
    _clock: int = 0
    _last_used: dict = field(default_factory=dict)  # slot -> stamp
    n_preempted: int = 0
    n_resumed: int = 0

    # -- queue -------------------------------------------------------------

    def _note_depth(self) -> None:
        # gauge on every enqueue/dequeue (not just once per engine step)
        # so the peak catches transient depth inside an admission pass
        if self.telemetry is not None:
            self.telemetry.registry.gauge("serving_queue_depth").set(
                self.waiting)

    def submit(self, req) -> None:
        self._classes.setdefault(req.priority, deque()).append(req)
        self._note_depth()

    def requeue(self, state: Preempted) -> None:
        """Preempted work resumes before new work of its class."""
        self._classes.setdefault(state.priority, deque()).appendleft(state)
        self._note_depth()

    @property
    def waiting(self) -> int:
        return sum(len(q) for q in self._classes.values())

    def _priorities(self):
        return sorted((p for p in self._classes if self._classes[p]),
                      reverse=True)

    def head(self):
        """Highest-priority *schedulable* waiting item (None when idle);
        requests that can never fit are passed over — they only surface
        in :func:`impossible` once the engine has drained."""
        for p in self._priorities():
            for item in self._classes[p]:
                if (self.paged is None or isinstance(item, Preempted)
                        or self._ever_fits(item)):
                    return item
        return None

    def impossible(self):
        """First queued request whose worst-case resident set fits no
        shard — the diagnostic for the engine's drained-queue
        ``OutOfPages`` (never raised while other work is in flight)."""
        if self.paged is None:
            return None
        for p in self._priorities():
            for item in self._classes[p]:
                if (not isinstance(item, Preempted)
                        and not self._ever_fits(item)):
                    return item
        return None

    # -- fit tests ---------------------------------------------------------

    def prefill_tokens(self, item) -> int:
        """Prompt tokens the item still needs prefilled once admitted —
        the unit of the chunked engine's per-step token budget.  Zero for
        a decode-phase resume (its prompt is already in its pages)."""
        if isinstance(item, Preempted):
            return item.prefill_tokens_left
        if self.paged is not None and self.paged.prefix is not None:
            # prefix-shared admission skips matched positions entirely —
            # only the unmatched suffix costs prefill budget (and TTFT)
            return len(item.prompt) - self.paged.match_prefix(item.prompt)
        return len(item.prompt)

    def admission_grant(self, req) -> int:
        """Pages a fresh request is granted at (chunked) admission — the
        single source of truth for both the ``_fits`` test here and the
        engine's ``admit_slot`` allocation, which must agree to the page.

        With chunked prefill *and* a live preemption path, just the
        first chunk's pages — later chunks grow the slot page by page,
        and page pressure resolves by preempting a victim (or the
        prefilling request itself).  Without preemption the whole-prompt
        grant is required up front, exactly like the whole-prompt
        engine: admitting on a first-chunk grant with no way to evict
        could wedge a later chunk mid-flight.

        With prefix sharing, matched blocks are *not* part of the grant:
        the engine's ``admit_shared`` increfs them instead of allocating,
        so the grant covers only the unmatched suffix (the first chunk
        of it, or all of it without preemption)."""
        matched = 0
        if self.paged is not None and self.paged.prefix is not None:
            matched = self.paged.match_prefix(req.prompt)
        if self.chunk_tokens and self._can_preempt():
            return self.paged.pages_for_prefix(
                min(self.chunk_tokens, len(req.prompt) - matched))
        return (self.paged.pages_needed(len(req.prompt))
                - matched // self.paged.page_size)

    def _need_now(self, item) -> int:
        """Raw pages the item needs resident to start on a slot."""
        if isinstance(item, Preempted):
            return len(item.pages)      # conservative: cold slots may help
        return self.admission_grant(item)

    def _fits(self, item, shard: int) -> bool:
        """Admissible on ``shard`` *now and for its whole lifetime*: the
        current need must fit the shard's free list, and the worst-case
        working set must fit the shard's capacity — placing a request on
        a shard it will outgrow would wedge it mid-flight with no victim
        to preempt (it cannot swap its own history)."""
        if self.paged is None:
            return True
        # index-only prefix pages (refcount 1) are reclaimable on demand
        # by every allocation site, so they count as available here
        avail = (self.paged.free_pages_per_shard[shard]
                 + self.paged.reclaimable_pages(shard))
        if self._need_now(item) > avail:
            return False
        req = item.req if isinstance(item, Preempted) else item
        worst = self.paged.pages_worst_case(len(req.prompt),
                                            req.max_new_tokens)
        return worst <= self.paged.shard_capacity(shard)

    def _ever_fits(self, req) -> bool:
        """Whether the request's worst-case resident set fits *some*
        shard at full capacity (virtual capacity covers total footprint
        across requests, never one request's simultaneous working set).

        Deliberately conservative: the bound counts raw pages only, even
        though cold slots could hold some of the working set — cold
        space is shared and incompressible pages stay raw, so counting
        it could admit a request that later wedges mid-flight."""
        worst = self.paged.pages_worst_case(len(req.prompt),
                                            req.max_new_tokens)
        return any(worst <= self.paged.shard_capacity(k)
                   for k in range(self.paged.n_shards))

    def pick(self, slot: int, prefill_budget: int | None = None):
        """Pop the best waiting item admissible on ``slot`` now, or None.

        Strict head-of-line within a priority class: only the class's
        first *schedulable* item (never-fitting requests are passed
        over — they can't be admitted by anyone) is considered, so an
        all-priority-0 workload reproduces the seed engine's FIFO
        admission order exactly and a large request cannot be starved by
        smaller ones behind it.  A blocked class head does let lower
        classes run (utilization over strict priority while waiting).

        ``prefill_budget`` is the chunked engine's remaining per-step
        prefill token budget: once it is spent (``<= 0``), items that
        still need prompt tokens prefilled are blocked for this step —
        only decode-phase resumes (zero prefill work) admit.  A
        budget-blocked class head blocks its class like a page-blocked
        one, so FIFO within a class survives the token budget."""
        if self.paged is None:
            for p in self._priorities():
                self.touch(slot)
                item = self._classes[p].popleft()
                self._note_depth()
                return item
            return None
        shard = self.paged.shard_of_slot(slot)
        for p in self._priorities():
            q = self._classes[p]
            for i, item in enumerate(q):
                if (not isinstance(item, Preempted)
                        and not self._ever_fits(item)):
                    continue        # unschedulable: not head-of-line
                if (prefill_budget is not None and prefill_budget <= 0
                        and self.prefill_tokens(item) > 0):
                    break           # out of prefill budget this step
                if self._fits(item, shard):
                    del q[i]
                    self.touch(slot)
                    self._note_depth()
                    return item
                break               # class head blocks in-class backfill
        return None

    # -- preemption policy -------------------------------------------------

    def touch(self, slot: int) -> None:
        """LRU stamp: called on admit/resume (victims are the least
        recently scheduled, not the least recently decoded — every active
        slot decodes every step)."""
        self._clock += 1
        self._last_used[slot] = self._clock

    def _can_preempt(self) -> bool:
        """Preemption needs an attached swap store with headroom — a
        full store would make every eviction attempt fail (and roll
        back), so it disables victim selection until a fault or discard
        frees bytes."""
        if not self.preemption or self.paged is None \
                or self.paged.swap is None:
            return False
        store = self.paged.swap
        return (store.capacity_bytes is None
                or store.bytes_used < store.capacity_bytes)

    def admission_victim(self, slots, head):
        """A victim whose eviction provably lets ``head`` admit *now*.

        Strictly-lower-priority active slots only (preempting your own
        class livelocks), and only when the victim's shard would then
        hold ``head``'s current page need — so every admission
        preemption is followed by head's admission in the same pass,
        never by preempt/resume flapping across steps.  Ties break
        lowest-priority-first, then least recently scheduled."""
        if not self._can_preempt():
            return None
        need = self._need_now(head)
        hreq = head.req if isinstance(head, Preempted) else head
        worst = self.paged.pages_worst_case(len(hreq.prompt),
                                            hreq.max_new_tokens)
        best = None
        for s, req in enumerate(slots):
            if req is None or req.priority >= head.priority:
                continue
            sh = self.paged.shard_of_slot(s)
            if worst > self.paged.shard_capacity(sh):
                continue            # head could not *live* on this shard:
                                    # preempting here would only flap
            raw = self.paged.resident_raw_pages(s)
            if self.paged.free_pages_per_shard[sh] + raw < need:
                continue            # would not unblock head: keep running
            cand = (req.priority, self._last_used.get(s, 0), s)
            best = cand if best is None else min(best, cand)
        return best[2] if best is not None else None

    def victim(self, slots, *, shard=None, exclude=()):
        """Choose a page-pressure victim among active ``slots`` (a list
        of Request-or-None): lowest priority first, then least recently
        scheduled — any priority qualifies, because the slot under
        pressure cannot write at all until pages free up and it keeps
        decoding either way (progress is monotone).  ``shard`` restricts
        to slots whose pages live on that shard (free lists are
        per-shard); ``exclude`` protects the slot under pressure."""
        if not self._can_preempt():
            return None
        cands = []
        for s, req in enumerate(slots):
            if req is None or s in exclude:
                continue
            if shard is not None and self.paged.shard_of_slot(s) != shard:
                continue
            if self.paged.resident_raw_pages(s) == 0:
                continue        # holds no raw pages: evicting it would
                                # cost swap traffic and relieve nothing
            cands.append((req.priority, self._last_used.get(s, 0), s))
        return min(cands)[2] if cands else None

    def counters(self) -> dict:
        return {"n_preempted": self.n_preempted,
                "n_resumed": self.n_resumed,
                "queue_depth": self.waiting}
