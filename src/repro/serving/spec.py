"""Exact rejection sampling for speculative decoding.

A draft model proposes ``k`` tokens; the target model scores all ``k+1``
positions in one batched verify forward (``models.model.verify_chunk``),
and this module decides which proposals survive.  The acceptance rule is
the classic speculative-sampling identity (Leviathan et al. / Chen et
al.): at each position, with target distribution ``p`` and draft
distribution ``q``, a proposal ``t ~ q`` is accepted with probability
``min(1, p(t)/q(t))``; on rejection the position's token is redrawn from
the residual ``max(0, p - q) / Z`` (``sampler.residual_probs``).  The
marginal is exactly ``p``::

    P(token = t) = q(t) min(1, p(t)/q(t)) + P(reject) * (p-q)+(t)/Z
                 = min(p, q)(t) + Z * (p-q)+(t)/Z        [P(reject) = Z]
                 = min(p, q)(t) + max(0, p(t) - q(t)) = p(t)

so speculative decoding is **distribution-identical** to target-only
decoding — and **token-identical** under greedy, where acceptance is the
exact argmax comparison and every emitted token is an argmax of the
target logits (tests/test_speculative.py pins both).

Key discipline (the schedule-invariance contract from PR 3/4): every
draw at absolute token position ``pos`` derives from
``sampler.request_key(rng0, req_id, pos)`` and nothing else —

  * the **draft proposal** for ``pos`` uses the *plain-decode* rule and
    key (``sample_logits(q/T, request_key(...pos))``), so when draft and
    target agree (``q == p``) speculative output is bit-identical to
    plain decode at any temperature;
  * the **acceptance uniform** folds in :data:`ACCEPT_DRAW`;
  * the **residual resample** folds in :data:`RESIDUAL_DRAW`;
  * the **bonus token** after a fully accepted window uses the
    plain-decode rule and key on the target logits.

None of these depend on ``k``, on where ``pos`` falls inside a verify
window, or on preemption/resume — accepted tokens are schedule-,
preemption- and k-invariant (tests/test_sampler.py regression).
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from .sampler import request_key, residual_probs, sample_logits

# fold-in tags separating the three per-position draw streams: the base
# position key is the proposal/plain-decode draw; ACCEPT_DRAW is the
# acceptance uniform; RESIDUAL_DRAW is the rejection resample
ACCEPT_DRAW = 1
RESIDUAL_DRAW = 2


def accept_key(rng0, req_id, position):
    """PRNG key of the acceptance uniform at ``position``."""
    return jax.random.fold_in(request_key(rng0, req_id, position),
                              ACCEPT_DRAW)


def residual_key(rng0, req_id, position):
    """PRNG key of the residual resample at ``position``."""
    return jax.random.fold_in(request_key(rng0, req_id, position),
                              RESIDUAL_DRAW)


def propose(q_logits, rng0, req_id, position, temperature: float) -> int:
    """Draw one draft proposal from ``q_logits`` (1, 1, V) for absolute
    token ``position`` — exactly the plain-decode rule and key, so a
    draft that agrees with the target reproduces the plain-decode token
    stream bit for bit."""
    if temperature <= 0:
        return int(jnp.argmax(q_logits[0, -1]))
    key = request_key(rng0, req_id, position)
    return int(sample_logits(q_logits / temperature, key,
                             temperature=1.0)[0, 0])


def verify(p_logits, q_logits, proposals, *, rng0, req_id, pos0: int,
           temperature: float):
    """Exact rejection sampling over one verify window.

    Args:
      p_logits: (n+1, V) target logits; row ``i`` conditions on the
        accepted history plus ``proposals[:i]`` and scores the token at
        absolute position ``pos0 + i``.
      q_logits: (n, V) draft logits; row ``i`` is the distribution
        ``proposals[i]`` was drawn from.
      proposals: the n drafted tokens (candidates for ``pos0 .. pos0+n-1``).
      rng0/req_id: the engine's seed key and the request id (the fold-in
        key material — see module docstring).
      pos0: absolute position of the first proposal.
      temperature: the request's temperature; ``<= 0`` is the exact
        greedy path (argmax comparisons, no randomness).

    Returns ``(tokens, n_accepted)``: the accepted proposal prefix plus
    exactly one more token — the residual resample at the first rejected
    position, or the bonus token from the target's last row after a
    fully accepted window.  ``len(tokens) == n_accepted + 1`` always.
    """
    n = len(proposals)
    p_logits = np.asarray(p_logits, np.float32)
    if temperature <= 0:
        out = []
        for i, t in enumerate(proposals):
            tgt = int(np.argmax(p_logits[i]))
            if int(t) != tgt:
                return out + [tgt], i
            out.append(int(t))
        return out + [int(np.argmax(p_logits[n]))], n

    p = np.asarray(jax.nn.softmax(
        jnp.asarray(p_logits) / temperature, axis=-1))
    if n:
        q_logits = np.asarray(q_logits, np.float32).reshape(n, -1)
        q = np.asarray(jax.nn.softmax(
            jnp.asarray(q_logits) / temperature, axis=-1))
        # one batched keyed draw covers the whole window's acceptance
        # uniforms: bit-identical to per-position eager draws (threefry
        # is a pure per-key counter, vmap over the folded position
        # changes nothing), but a single device dispatch + transfer
        # instead of one blocking host sync per proposal
        us = np.asarray(jax.vmap(
            lambda i: jax.random.uniform(accept_key(rng0, req_id, pos0 + i))
        )(jnp.arange(n)))
    out = []
    rejected = -1
    for i, t in enumerate(proposals):
        t = int(t)
        u = float(us[i])
        # accept iff u < min(1, p(t)/q(t))  <=>  u * q(t) < p(t)
        if u * q[i, t] < p[i, t]:
            out.append(t)
            continue
        rejected = i
        break
    if rejected >= 0:
        i = rejected
        r = residual_probs(jnp.asarray(p[i]), jnp.asarray(q[i]))
        tok = int(jax.random.categorical(
            residual_key(rng0, req_id, pos0 + i), jnp.log(r)))
        return out + [tok], i
    # fully accepted window: the bonus token draws from the target's
    # last row with the plain-decode rule and key
    key = request_key(rng0, req_id, pos0 + n)
    bonus = int(sample_logits(jnp.asarray(p_logits[n])[None, None, :]
                              / temperature, key, temperature=1.0)[0, 0])
    return out + [bonus], n
