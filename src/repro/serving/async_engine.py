"""Async serving front end: admission decoupled from the engine step loop.

``AsyncServingFrontend`` is the layer above one or more synchronous
``GenerationEngine`` replicas (ROADMAP item 3): callers ``submit()``
requests from asyncio coroutines and consume **per-request streaming
token iterators** (``TokenStream``), while a single driver coroutine
(``run()``, or explicit ``step()`` calls) pumps the replicas.  The
pieces:

* **bounded admission queue** — ``submit`` lands requests in a frontend
  queue of at most ``max_pending`` entries; between engine steps the
  driver drains it through the ``Router`` into replicas, stopping while
  the chosen replica's backlog exceeds ``max_replica_backlog`` (so the
  frontend queue, not the engine scheduler, absorbs bursts).
* **explicit shed policy** — a full queue either rejects the new
  request (``shed_policy="reject"``: ``FrontendOverloaded``) or sheds
  the lowest-priority queued request in its favour
  (``"drop-lowest"``; when the newcomer itself is lowest, it is the one
  shed — its stream terminates immediately with ``.shed`` set).  Every
  shed bumps ``frontend_shed_total``.
* **streaming** — tokens appear on a request's ``TokenStream`` as the
  engine emits them, ordered, with no buffering beyond the engine step
  that produced them.  The stream is **bit-identical to the synchronous
  engine**: the frontend only moves requests and copies
  ``Request.out_tokens`` deltas; sampling keys fold
  ``(rng_seed, request.id, position)`` only, so admission timing,
  replica choice, batching and preemption cannot change any token
  (asserted by the differential tests in
  ``tests/test_async_serving.py``).
* **graceful drain** — ``drain()`` stops nothing but pumps until every
  accepted request finished; ``close()`` rejects new submissions
  (``FrontendClosed``) and optionally drains or sheds what is queued.

Determinism note: ``step()`` is a *tick* — admission, one engine step
per busy replica, stream flush.  Everything it decides (admission
order, placement, shedding) is a function of tick state, never of wall
clock, so a seeded arrival trace replayed tick-by-tick
(``benchmarks/load_replay.py``) reproduces placements and sheds
exactly; wall clock only feeds the latency histograms.

Metrics (names in docs/OBSERVABILITY.md): ``frontend_requests_total``,
``frontend_shed_total``, ``frontend_completed_total``,
``frontend_stream_tokens_total``, ``frontend_queue_depth``,
``frontend_stream_ttft_seconds``.
"""
from __future__ import annotations

import asyncio
import time
from collections import deque

from .engine import GenerationEngine, Request
from .router import Router

SHED_POLICIES = ("reject", "drop-lowest")

_DONE = object()
_SHED = object()


class FrontendOverloaded(RuntimeError):
    """Admission queue full under ``shed_policy="reject"``."""


class FrontendClosed(RuntimeError):
    """``submit`` after ``close()``."""


class TokenStream:
    """Async iterator over one request's output tokens, in order.

    ``async for tok in stream`` yields each token as the driver flushes
    it and ends when the request finishes (or was shed — check
    ``stream.shed``).  ``tokens`` accumulates every flushed token as it
    lands (consumed or not); ``collect()`` drains to completion and
    returns the full list."""

    def __init__(self, request: Request):
        self.request = request
        self.tokens: list[int] = []
        self.finished = False
        self.shed = False
        self._q: asyncio.Queue = asyncio.Queue()

    def _push(self, tok: int):
        self.tokens.append(tok)
        self._q.put_nowait(tok)

    def _finish(self):
        self.finished = True
        self._q.put_nowait(_DONE)

    def _mark_shed(self):
        self.shed = self.finished = True
        self._q.put_nowait(_SHED)

    def __aiter__(self):
        return self

    async def __anext__(self) -> int:
        item = await self._q.get()
        if item is _DONE or item is _SHED:
            raise StopAsyncIteration
        return item

    async def collect(self) -> list[int]:
        async for _ in self:
            pass
        return self.tokens


class AsyncServingFrontend:
    """Asyncio front end over engine replicas (module docstring).

    ``replicas``: a ``Router``, one ``GenerationEngine``, or a list of
    engines (wrapped in a default least-loaded router).  For cross-
    replica bit-identity every replica must share one
    ``EngineConfig.rng_seed``.  ``max_replica_backlog`` defaults to
    twice the replica's ``max_batch`` — enough queued work to refill
    every slot at the next admission pass without hiding the queue from
    the shed policy."""

    def __init__(self, replicas, *, max_pending: int = 64,
                 max_replica_backlog: int | None = None,
                 shed_policy: str = "reject", telemetry=None):
        if isinstance(replicas, Router):
            router = replicas
        elif isinstance(replicas, GenerationEngine):
            router = Router([replicas], telemetry=telemetry)
        else:
            router = Router(replicas, telemetry=telemetry)
        if shed_policy not in SHED_POLICIES:
            raise ValueError(
                f"shed_policy={shed_policy!r} (must be one of "
                f"{SHED_POLICIES})")
        if max_pending < 1:
            raise ValueError(f"max_pending={max_pending} (must be >= 1)")
        self.router = router
        self.max_pending = max_pending
        self.max_replica_backlog = max_replica_backlog
        self.shed_policy = shed_policy
        self.tel = telemetry
        self.n_shed = 0
        self.n_completed = 0
        self._pending: deque[tuple[Request, TokenStream]] = deque()
        self._live: dict[int, tuple[Request, TokenStream, int]] = {}
        self._submit_t: dict[int, float] = {}
        self._closed = False
        self._wake = asyncio.Event()

    # -- admission ---------------------------------------------------------

    def _count_shed(self, stream: TokenStream):
        self.n_shed += 1
        stream._mark_shed()
        if self.tel is not None:
            self.tel.registry.counter("frontend_shed_total").inc()

    def submit_nowait(self, req: Request) -> TokenStream:
        """Enqueue ``req``; returns its stream.  A full queue raises
        ``FrontendOverloaded`` (``shed_policy="reject"``) or sheds the
        lowest-priority queued request — possibly ``req`` itself, whose
        returned stream is then already terminated with ``.shed``."""
        if self._closed:
            raise FrontendClosed("frontend is closed to new requests")
        stream = TokenStream(req)
        if self.tel is not None:
            self.tel.registry.counter("frontend_requests_total").inc()
        if len(self._pending) >= self.max_pending:
            if self.shed_policy == "reject":
                self.n_shed += 1
                if self.tel is not None:
                    self.tel.registry.counter("frontend_shed_total").inc()
                raise FrontendOverloaded(
                    f"admission queue full ({self.max_pending} pending)")
            # drop-lowest: shed the lowest-priority queued request,
            # latest arrival within the class — unless the newcomer
            # itself is lowest-or-equal, in which case shedding it keeps
            # already-accepted work untouched
            worst = min(range(len(self._pending)),
                        key=lambda i: (self._pending[i][0].priority, -i))
            victim_req, victim_stream = self._pending[worst]
            if victim_req.priority < req.priority:
                del self._pending[worst]
                self._count_shed(victim_stream)
            else:
                self._count_shed(stream)
                return stream
        self._pending.append((req, stream))
        self._submit_t[req.id] = time.perf_counter()
        self._note_depth()
        self._wake.set()
        return stream

    async def submit(self, req: Request) -> TokenStream:
        """Coroutine flavour of :meth:`submit_nowait` (the admission
        decision itself is synchronous and immediate)."""
        return self.submit_nowait(req)

    def _backlog_limit(self, idx: int) -> int:
        if self.max_replica_backlog is not None:
            return self.max_replica_backlog
        return 2 * self.router.replicas[idx].max_batch

    def _admit(self):
        """Drain the frontend queue through the router, head-of-line in
        arrival order, stopping while the placed replica's backlog is
        full (the frontend queue absorbs the burst instead)."""
        while self._pending:
            req, stream = self._pending[0]
            idx, reason = self.router.place(req)
            if self.router.load(idx) >= self._backlog_limit(idx):
                break
            self._pending.popleft()
            self.router.submit_to(idx, req, reason=reason)
            self._live[req.id] = (req, stream, 0)
        self._note_depth()

    # -- driving -----------------------------------------------------------

    async def step(self) -> bool:
        """One tick: admit queued requests, one engine step per busy
        replica, flush new tokens to their streams.  Returns whether any
        work remains (queued, admitted, or mid-flight)."""
        self._admit()
        for eng in self.router.replicas:
            if eng.load() > 0:
                eng.step()
            # cooperative yield between replica steps: consumers see
            # tokens while other replicas still compute
            await asyncio.sleep(0)
        self._flush()
        self.router.sample_load_gauges()
        return bool(self._pending or self._live) or self.router.total_load() > 0

    def _flush(self):
        """Copy each live request's ``out_tokens`` delta to its stream —
        the only coupling between engine state and consumers, which is
        why the streamed tokens are bit-identical to a synchronous
        ``run()`` of the same requests."""
        reg = self.tel.registry if self.tel is not None else None
        for rid, (req, stream, sent) in list(self._live.items()):
            new = req.out_tokens[sent:]
            if new:
                if sent == 0 and reg is not None:
                    t0 = self._submit_t.get(rid)
                    if t0 is not None:
                        reg.histogram(
                            "frontend_stream_ttft_seconds").observe(
                                time.perf_counter() - t0)
                for tok in new:
                    stream._push(tok)
                if reg is not None:
                    reg.counter("frontend_stream_tokens_total").inc(
                        len(new))
            if req.done:
                stream._finish()
                del self._live[rid]
                self._submit_t.pop(rid, None)
                self.n_completed += 1
                if reg is not None:
                    reg.counter("frontend_completed_total").inc()
            elif new:
                self._live[rid] = (req, stream, sent + len(new))

    def _note_depth(self):
        if self.tel is not None:
            self.tel.registry.gauge("frontend_queue_depth").set(
                len(self._pending))

    async def drain(self):
        """Pump until every accepted request has finished (admission
        stays open — requests submitted meanwhile are served too)."""
        while await self.step():
            pass

    async def run(self):
        """Driver loop for background use: pump while there is work,
        park on an event while idle, exit once closed and drained."""
        while True:
            busy = await self.step()
            if not busy:
                if self._closed:
                    return
                self._wake.clear()
                await self._wake.wait()

    async def close(self, *, drain: bool = True):
        """Stop admission; then either serve out the backlog
        (``drain=True``) or shed every queued request and finish only
        what replicas already own."""
        self._closed = True
        self._wake.set()
        if not drain:
            while self._pending:
                _, stream = self._pending.popleft()
                self._count_shed(stream)
            self._note_depth()
        await self.drain()

    async def __aenter__(self):
        return self

    async def __aexit__(self, *exc):
        await self.close(drain=exc[0] is None)
