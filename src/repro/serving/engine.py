"""Continuous-batching generation engine (the serving driver).

The paper's RQ2 regime: weight-streaming-bound batched decode under a fixed
memory budget — ECF8's smaller weights buy a bigger batch, and the batch is
what buys throughput.  The engine keeps a fixed (max_batch, max_len) cache
(static shapes: one compiled decode step serves the whole run) and fills it
with requests continuously:

  * every slot has its own timeline (per-slot ``cur_len``, see
    ``model.init_cache(per_slot=True)``) — a finished request's slot is
    immediately reused by the next queued request without draining the batch;
  * a new request is prefilled as a single-row batch and its cache fragment
    is spliced into the batched cache at the free slot (stacked leaves at
    batch-axis 1, tail leaves at 0);
  * decode steps always run the full batch; inactive slots compute garbage
    that is never read (standard static-batch padding trade).

Weights may be an ECF8-compressed pytree (``core.store.compress_tree``) —
decode-on-use happens inside the same jitted step.

The default cache is **paged** (``repro.kvcache``): attention layers write
through a shared page table into fixed-size pages, short requests only
hold the pages they wrote, and full (cold) pages can be entropy-coded
losslessly in place (``compress_cold=True``) with in-graph decode-on-use —
the cache-side mirror of the paper's weight story.

Under a JAX **mesh** the paged cache stays paged: the page pool, cold
pool, page table and per-slot timelines shard over the mesh's batch axes
(``runtime.sharding.batch_axes``), the allocator keeps one free list per
batch shard so every slot's pages are local to its shard, and the decode
step routes through ``models.decode_sharded.paged_decode_attention_
sharded`` (fully local page scatter/gather per batch shard; an optional
``model`` axis splits each slot's pages and merges softmax stats).  On a
pure batch-axes mesh the sharded engine is **bit-identical** to the
single-device run.  ``cache_mode="monolithic"`` keeps the original
contiguous cache; encoder-decoders, pure recurrent stacks (nothing to
page) and meshes whose batch-axes size does not divide ``max_batch``
still fall back to it.
"""
from __future__ import annotations

import itertools
import warnings
from collections import deque
from dataclasses import dataclass, field

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.kvcache import OutOfPages, PagedKVCache
from repro.kvcache.paged import restore_cold, strip_cold
from repro.models import model as M
from repro.runtime import sharding as SH
from .sampler import greedy, sample_logits

_ids = itertools.count()


@dataclass
class Request:
    prompt: list
    max_new_tokens: int = 32
    temperature: float = 0.0
    id: int = field(default_factory=lambda: next(_ids))
    out_tokens: list = field(default_factory=list)
    done: bool = False


def _splice(full, frag, slot: int, path_names):
    """Insert a single-request cache fragment at ``slot`` of the batch.

    ``path_names`` are the stringified pytree path keys of the leaf; the
    batch axis is inferred from them (see :func:`splice_fragment`)."""
    axis = 1 if "units" in path_names else 0
    if "cur_len" in path_names:
        return full.at[slot].set(frag)
    return jax.lax.dynamic_update_slice_in_dim(
        full, frag.astype(full.dtype), slot, axis=axis)


def splice_fragment(cache, frag, slot: int):
    """Splice a single-request prefill fragment into the monolithic batched
    cache.

    Leaf placement is dispatched on the pytree *path names* (the cache is
    a plain dict tree, no metadata): leaves under ``"units"`` are
    scan-stacked ``(n_units, B, ...)`` so the batch sits at axis 1; leaves
    under ``"tail"`` (and everything else) carry the batch at axis 0; the
    ``"cur_len"`` leaf is a per-slot ``(B,)`` vector indexed directly.
    ``frag`` must have the same treedef with batch size 1."""
    flat_full, treedef = jax.tree_util.tree_flatten_with_path(cache)
    flat_frag = jax.tree_util.tree_flatten(frag)[0]
    new_leaves = []
    for (path, full), fr in zip(flat_full, flat_frag):
        names = [str(getattr(k, "key", getattr(k, "idx", k)))
                 for k in path]
        new_leaves.append(_splice(full, fr, slot, names))
    return jax.tree_util.tree_unflatten(treedef, new_leaves)


class GenerationEngine:
    def __init__(self, params, cfg: ArchConfig, *, max_batch: int = 8,
                 max_len: int = 512, mesh=None, rng_seed: int = 0,
                 cache_mode: str = "paged", page_size: int = 16,
                 n_pages: int | None = None, compress_cold: bool = False,
                 n_cold_slots: int | None = None, kv_monitor=None):
        """``mesh``: optional ``jax.sharding.Mesh``; the paged cache shards
        over its batch axes (see module docstring) and decode/prefill steps
        are jitted against it.  ``cache_mode``/``page_size``/``n_pages``/
        ``compress_cold``/``n_cold_slots`` configure the paged cache
        (``kvcache.PagedKVCache``); ``kv_monitor`` (``runtime.monitor.
        KVCacheMonitor``) records per-step memory stats."""
        self.params, self.cfg = params, cfg
        self.max_batch, self.max_len = max_batch, max_len
        self.mesh = mesh
        self.queue: deque = deque()
        self.slots: list = [None] * max_batch   # Request or None
        # fall back to the monolithic cache for encoder-decoders and pure
        # recurrent stacks (nothing to page); meshes are served paged, with
        # pool/table sharded over the batch axes — unless the batch-axes
        # size does not divide max_batch (no per-shard slot ranges then).
        n_shards = 1
        if mesh is not None:
            n_shards = SH._axis_size(mesh, SH.batch_axes(mesh))
        if cache_mode == "paged" and (
                cfg.encoder_decoder
                or not any(cfg.layer_kind(i) in ("attn", "nope")
                           for i in range(cfg.n_layers))):
            cache_mode = "monolithic"
        if cache_mode == "paged" and max_batch % n_shards:
            warnings.warn(
                f"max_batch={max_batch} not divisible by the mesh batch-"
                f"axes size {n_shards}; falling back to the monolithic "
                f"cache", stacklevel=2)
            cache_mode = "monolithic"
        self.cache_mode = cache_mode
        self.kv_monitor = kv_monitor
        if cache_mode == "paged":
            self.paged = PagedKVCache(
                cfg, max_batch, max_len, dtype=jnp.dtype(cfg.dtype),
                page_size=page_size, n_pages=n_pages,
                compress_cold=compress_cold, n_cold_slots=n_cold_slots,
                n_shards=n_shards)
            self.cache = self.paged.init_cache()
            if mesh is not None:
                # pin the pool/table/cur_len layout so every decode step
                # starts from the sharded placement instead of resharding
                self.cache = jax.device_put(self.cache, SH.named(
                    mesh, SH.cache_pspecs(cfg, self.cache, mesh)))
        else:
            self.paged = None
            self.cache = M.init_cache(cfg, max_batch, max_len,
                                      dtype=jnp.dtype(cfg.dtype),
                                      per_slot=True)
        self._host_len = [0] * max_batch        # next write position per slot
        self.rng = jax.random.PRNGKey(rng_seed)
        self._decode = jax.jit(
            lambda p, t, c: M.decode_step(p, cfg, t, c, mesh=mesh))
        self._prefill = jax.jit(
            lambda p, t: M.prefill(p, cfg, t, mesh=mesh, max_len=max_len))
        self.last_tok = jnp.zeros((max_batch, 1), jnp.int32)
        self.steps = 0

    # -- scheduling --------------------------------------------------------

    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        for slot in range(self.max_batch):
            if self.slots[slot] is not None or not self.queue:
                continue
            if (self.paged is not None
                    and not self.paged.can_admit(len(self.queue[0].prompt),
                                                 slot)):
                # another free slot may live on a shard with pages; if
                # none does, the post-loop check below decides deadlock
                continue
            req = self.queue.popleft()
            toks = jnp.asarray(req.prompt, jnp.int32)[None, :]
            logits, frag = self._prefill(self.params, toks)
            if self.paged is not None:
                self.cache = self.paged.admit(self.cache, slot, frag,
                                              len(req.prompt))
            else:
                self.cache = splice_fragment(self.cache, frag, slot)
            self._host_len[slot] = len(req.prompt)
            tok = self._sample_one(logits, req)
            req.out_tokens.append(int(tok))
            self.last_tok = self.last_tok.at[slot, 0].set(tok)
            self.slots[slot] = req
        if (self.queue and self.paged is not None
                and not any(s is not None for s in self.slots)):
            # every slot is free yet none could admit the head request:
            # no release will ever refill the free lists
            raise OutOfPages(
                f"prompt needs more pages than its shard holds (free per "
                f"shard: {self.paged.free_pages_per_shard})")

    def _sample_one(self, logits, req: Request):
        if req.temperature <= 0:
            return greedy(logits)[0, 0]
        self.rng, k = jax.random.split(self.rng)
        return sample_logits(logits, k, temperature=req.temperature)[0, 0]

    # -- stepping ----------------------------------------------------------

    def step(self) -> bool:
        """Admit + one batched decode step.  Returns False when idle."""
        self._admit()
        active = [s for s in range(self.max_batch)
                  if self.slots[s] is not None]
        if not active:
            return bool(self.queue)
        if self.paged is not None:
            for s in active:   # grow page lists to cover this step's write
                self.cache = self.paged.ensure(self.cache, s,
                                               self._host_len[s])
        # while nothing is cold, run the decode variant without the cold
        # pool (its in-graph entropy decode would be pure waste)
        stash = None
        cache_in = self.cache
        if (self.paged is not None and self.paged.compress
                and not self.paged.has_cold):
            cache_in, stash = strip_cold(self.cache)
        logits, new_cache = self._decode(self.params, self.last_tok,
                                         cache_in)
        self.cache = (restore_cold(new_cache, stash) if stash
                      else new_cache)
        self.steps += 1
        toks = np.asarray(greedy(logits))  # (B, 1)
        self.rng, k = jax.random.split(self.rng)
        # one batched sample honoring per-request temperatures: pre-scale
        # each row's logits by its slot's temperature (1.0 for greedy rows,
        # whose sampled value is never read)
        temps = np.asarray([
            self.slots[s].temperature
            if self.slots[s] is not None and self.slots[s].temperature > 0
            else 1.0 for s in range(self.max_batch)], np.float32)
        sampled = np.asarray(sample_logits(
            logits / jnp.asarray(temps)[:, None, None], k, temperature=1.0))
        for s in active:
            req = self.slots[s]
            t = int(toks[s, 0] if req.temperature <= 0 else sampled[s, 0])
            req.out_tokens.append(t)
            self.last_tok = self.last_tok.at[s, 0].set(t)
            self._host_len[s] += 1
            if len(req.out_tokens) >= req.max_new_tokens or (
                    len(req.prompt) + len(req.out_tokens) >= self.max_len):
                req.done = True
                self.slots[s] = None
                if self.paged is not None:
                    self.cache = self.paged.release(self.cache, s)
        if self.paged is not None and self.paged.compress:
            for s in range(self.max_batch):
                if self.slots[s] is not None:
                    self.cache = self.paged.compress_cold_pages(
                        self.cache, s, self._host_len[s])
        if self.kv_monitor is not None and self.paged is not None:
            self.kv_monitor.record(self.paged.stats())
        return True

    def run(self, max_steps: int = 10_000) -> list:
        """Drain the queue; returns the tracked requests (all done unless
        ``max_steps`` was hit)."""
        tracked = list(self.queue)
        for _ in range(max_steps):
            busy = self.step()
            if not busy and not any(s is not None for s in self.slots):
                break
        return tracked
