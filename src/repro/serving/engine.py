"""Continuous-batching generation engine (the serving driver).

The paper's RQ2 regime: weight-streaming-bound batched decode under a fixed
memory budget — ECF8's smaller weights buy a bigger batch, and the batch is
what buys throughput.  The engine keeps a fixed (max_batch, max_len) cache
(static shapes: one compiled decode step serves the whole run) and fills it
with requests continuously:

  * every slot has its own timeline (per-slot ``cur_len``, see
    ``model.init_cache(per_slot=True)``) — a finished request's slot is
    immediately reused by the next queued request without draining the batch;
  * a new request is prefilled as a single-row batch and its cache fragment
    is spliced into the batched cache at the free slot (stacked leaves at
    batch-axis 1, tail leaves at 0);
  * decode steps always run the full batch; inactive slots compute garbage
    that is never read (standard static-batch padding trade).

Weights may be an ECF8-compressed pytree (``core.store.compress_tree``) —
decode-on-use happens inside the same jitted step.

The default cache is **paged** (``repro.kvcache``): attention layers write
through a shared page table into fixed-size pages, short requests only
hold the pages they wrote, and full (cold) pages can be entropy-coded
losslessly in place (``compress_cold=True``) with in-graph decode-on-use —
the cache-side mirror of the paper's weight story.

With a **swap tier** (``swap_bytes``) the device pool stops being a hard
ceiling: admission is scheduled against *virtual* capacity
(``serving.scheduler.Scheduler`` — priority classes, FIFO within a
class), and when pages run out a whole victim request is compressed and
swapped to host memory (``kvcache.swap.SwapStore``), requeued, and later
resumed by faulting its pages back — bit-identical to a run that was
never preempted, because page restore is lossless and sampling keys are
folded from ``(rng_seed, request.id, position)`` only.  The engine
faults every active slot fully resident before each decode step
(fault-before-gather), so the jitted graph never sees a swapped page.

With **chunked prefill** (``prefill_chunk=C``) the compute side of
admission is rebuilt around continuous batching: each admitted prompt is
split into fixed-size ``C``-token chunks (padded, so every chunk call
has one static shape — **exactly one prefill compilation per (cfg,
mesh, max_len, C)** regardless of prompt length, where the whole-prompt
path retraces per length), chunk K/V is appended straight into the
slot's pages across chunk boundaries (``models.model.prefill_chunk``),
and prefill chunks interleave with decode steps under a per-step
**prefill token budget** (``prefill_budget``, default ``C``): every
engine step spends at most ~budget prompt tokens on prefill — draining
mid-prefill slots first (FIFO within priority), then admitting new
work — before running one batched decode step for the decode-phase
slots, so a long prompt can no longer stall every decoding request
behind a monolithic prefill (Sarathi/vLLM-style scheduling).  A slot
mid-prefill participates in the batched decode step as a masked row
(its garbage write lands at the next chunk's first position and is
overwritten; its timeline is rolled back after the step) and can be
preempted like any other slot — ``Preempted.prefill_pos`` records the
resume point, and the continuation is bit-identical to an unchunked
run.  Chunked prefill requires the paged cache and an architecture
whose every layer pages ('attn'/'nope'); other configs fall back to
whole-prompt prefill.

Under a JAX **mesh** the paged cache stays paged: the page pool, cold
pool, page table and per-slot timelines shard over the mesh's batch axes
(``runtime.sharding.batch_axes``), the allocator keeps one free list per
batch shard so every slot's pages are local to its shard, and the decode
step routes through ``models.decode_sharded.paged_decode_attention_
sharded`` (fully local page scatter/gather per batch shard; an optional
``model`` axis splits each slot's pages and merges softmax stats).  On a
pure batch-axes mesh the sharded engine is **bit-identical** to the
single-device run.  ``cache_mode="monolithic"`` keeps the original
contiguous cache; encoder-decoders, pure recurrent stacks (nothing to
page) and meshes whose batch-axes size does not divide ``max_batch``
still fall back to it.
"""
from __future__ import annotations

import itertools
import time
import warnings
from dataclasses import dataclass, field

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.kvcache import OutOfPages, PagedKVCache, SwapStore
from repro.kvcache.paged import restore_cold, strip_cold
from repro.kvcache.swap import SwapExhausted
from repro.models import model as M
from repro.runtime import sharding as SH
from . import spec as SPEC
from .config import EngineConfig
from .sampler import greedy, request_key, root_key, sample_logits
from .scheduler import Preempted, Scheduler

_ids = itertools.count()


@dataclass
class Request:
    prompt: list
    max_new_tokens: int = 32
    temperature: float = 0.0
    priority: int = 0           # higher runs first; FIFO within a class
    id: int = field(default_factory=lambda: next(_ids))
    out_tokens: list = field(default_factory=list)
    done: bool = False


# one jitted prefill/decode pair per (cfg, mesh, max_len) — engines are
# cheap, throwaway objects (tests build hundreds); sharing the jit cache
# across instances avoids recompiling identical programs
_STEP_CACHE: dict = {}
# one jitted chunk-prefill per (cfg, mesh, max_len, chunk): slot, start
# and n_valid are traced, so this single entry serves every prompt
# length — the whole point of the fixed chunk shape
_CHUNK_CACHE: dict = {}
# one jitted speculative verify per (cfg, mesh, max_len, k + 1): the
# verify width is static, slot/n_valid are traced — one compilation
# serves every request, acceptance length and timeline position
_VERIFY_CACHE: dict = {}


def _jitted_steps(cfg: ArchConfig, mesh, max_len: int):
    key = (cfg, mesh, max_len)
    if key not in _STEP_CACHE:
        _STEP_CACHE[key] = (
            jax.jit(lambda p, t, c: M.decode_step(p, cfg, t, c, mesh=mesh)),
            jax.jit(lambda p, t: M.prefill(p, cfg, t, mesh=mesh,
                                           max_len=max_len)))
    return _STEP_CACHE[key]


def _jitted_chunk(cfg: ArchConfig, mesh, max_len: int, chunk: int):
    key = (cfg, mesh, max_len, chunk)
    if key not in _CHUNK_CACHE:
        _CHUNK_CACHE[key] = jax.jit(
            lambda p, t, c, s, n: M.prefill_chunk(p, cfg, t, c, s, n,
                                                  mesh=mesh))
    return _CHUNK_CACHE[key]


def _jitted_verify(cfg: ArchConfig, mesh, max_len: int, width: int):
    key = (cfg, mesh, max_len, width)
    if key not in _VERIFY_CACHE:
        _VERIFY_CACHE[key] = jax.jit(
            lambda p, t, c, s, n: M.verify_chunk(p, cfg, t, c, s, n,
                                                 mesh=mesh))
    return _VERIFY_CACHE[key]


def compile_count(fn) -> int:
    """Traced-program count of a jitted step (-1 when the runtime does
    not expose it).  The perf-smoke tier and the recompile regression
    test read this off ``engine._jitted_steps``/``_jitted_chunk`` entries
    to pin "exactly one prefill compilation per chunk shape"."""
    try:
        return int(fn._cache_size())
    except AttributeError:
        return -1


def _splice(full, frag, slot: int, path_names):
    """Insert a single-request cache fragment at ``slot`` of the batch.

    ``path_names`` are the stringified pytree path keys of the leaf; the
    batch axis is inferred from them (see :func:`splice_fragment`)."""
    axis = 1 if "units" in path_names else 0
    if "cur_len" in path_names:
        return full.at[slot].set(frag)
    return jax.lax.dynamic_update_slice_in_dim(
        full, frag.astype(full.dtype), slot, axis=axis)


def splice_fragment(cache, frag, slot: int):
    """Splice a single-request prefill fragment into the monolithic batched
    cache.

    Leaf placement is dispatched on the pytree *path names* (the cache is
    a plain dict tree, no metadata): leaves under ``"units"`` are
    scan-stacked ``(n_units, B, ...)`` so the batch sits at axis 1; leaves
    under ``"tail"`` (and everything else) carry the batch at axis 0; the
    ``"cur_len"`` leaf is a per-slot ``(B,)`` vector indexed directly.
    ``frag`` must have the same treedef with batch size 1."""
    flat_full, treedef = jax.tree_util.tree_flatten_with_path(cache)
    flat_frag = jax.tree_util.tree_flatten(frag)[0]
    new_leaves = []
    for (path, full), fr in zip(flat_full, flat_frag):
        names = [str(getattr(k, "key", getattr(k, "idx", k)))
                 for k in path]
        new_leaves.append(_splice(full, fr, slot, names))
    return jax.tree_util.tree_unflatten(treedef, new_leaves)


class GenerationEngine:
    def __init__(self, params, cfg: ArchConfig,
                 config: EngineConfig | None = None, **legacy):
        """``config`` (``serving.config.EngineConfig``) is the primary
        constructor input: every engine option lives there as a grouped,
        validated field, and the feature-gating matrix (chunked / mesh /
        spec / prefix interactions) is applied by
        ``EngineConfig.validate`` — see that module's docstring for the
        matrix and the per-field semantics.  Passing the old flat
        keyword arguments still works via a deprecation shim
        (``GenerationEngine(params, cfg, max_batch=8, ...)`` becomes
        ``EngineConfig(max_batch=8, ...)`` with a ``DeprecationWarning``).

        Incompatible feature requests warn and fall back here exactly as
        before (the warnings now originate from ``validate``); callers
        that want errors instead validate strictly up front, like
        ``launch/serve.py`` does at argument-parse time."""
        if legacy:
            if config is not None:
                raise TypeError(
                    "pass either config=EngineConfig(...) or legacy "
                    "keyword arguments, not both")
            warnings.warn(
                "GenerationEngine(params, cfg, **kwargs) is deprecated; "
                "pass config=EngineConfig(...) instead",
                DeprecationWarning, stacklevel=2)
            config = EngineConfig(**legacy)
        elif config is None:
            config = EngineConfig()
        if (config.draft_params is None) != (config.draft_cfg is None):
            raise ValueError(
                "draft_params and draft_cfg must be provided together")
        config = config.validate(cfg)
        self.config = config
        self.params, self.cfg = params, cfg
        max_batch = self.max_batch = config.max_batch
        max_len = self.max_len = config.max_len
        mesh = self.mesh = config.mesh
        self.slots: list = [None] * max_batch   # Request or None
        self._inflight: list = []               # submitted, not yet returned
        n_shards = config.n_shards()
        self.cache_mode = cache_mode = config.cache_mode
        self.kv_monitor = config.kv_monitor
        if cache_mode == "paged":
            self.paged = PagedKVCache(
                cfg, max_batch, max_len, dtype=jnp.dtype(cfg.dtype),
                page_size=config.page_size, n_pages=config.n_pages,
                compress_cold=config.compress_cold,
                n_cold_slots=config.n_cold_slots, n_shards=n_shards)
            if config.swap_bytes:
                self.paged.attach_swap(SwapStore(
                    capacity_bytes=(None if config.swap_bytes < 0
                                    else config.swap_bytes),
                    n_shards=n_shards))
            self.cache = self.paged.init_cache()
            if mesh is not None:
                # pin the pool/table/cur_len layout so every decode step
                # starts from the sharded placement instead of resharding
                self.cache = jax.device_put(self.cache, SH.named(
                    mesh, SH.cache_pspecs(cfg, self.cache, mesh)))
        else:
            self.paged = None
            self.cache = M.init_cache(cfg, max_batch, max_len,
                                      dtype=jnp.dtype(cfg.dtype),
                                      per_slot=True)
        chunk = self.prefill_chunk = config.prefill_chunk
        self.prefill_budget = config.prefill_budget
        self.prefix_sharing = config.prefix_sharing
        if self.prefix_sharing:
            self.paged.enable_prefix_sharing()
        self._prefill_pos: dict[int, int] = {}  # slot -> prompt tokens done
        self._prefill_order: list[int] = []     # admission order (FIFO)
        self._stalled_ids: set = set()          # self-preempted this step
        self.n_chunks = self.n_chunk_tokens = self.n_interleaved_steps = 0
        self.spec_on = config.draft_cfg is not None
        self.spec_k = config.spec_k
        if self.spec_on:
            self.draft_params = config.draft_params
            self.draft_cfg = draft_cfg = config.draft_cfg
            self._draft_decode, self._draft_prefill = _jitted_steps(
                draft_cfg, mesh, max_len)
            self._verify = _jitted_verify(cfg, mesh, max_len,
                                          self.spec_k + 1)
            # the paired draft cache: always monolithic (a small draft
            # needs no paging, and rejection rollback is a per-slot
            # snapshot re-splice — works for recurrent drafts too,
            # where no positional rollback exists)
            self.draft_cache = M.init_cache(
                draft_cfg, max_batch, max_len,
                dtype=jnp.dtype(draft_cfg.dtype), per_slot=True)
        self.n_spec_rounds = self.n_spec_drafted = self.n_spec_accepted = 0
        self.scheduler = Scheduler(paged=self.paged,
                                   preemption=config.preemption,
                                   chunk_tokens=chunk)
        self._host_len = [0] * max_batch        # next write position per slot
        # sampling keys fold (rng_seed, request.id, position) — the token
        # stream of a sampled request is a pure function of its own state,
        # independent of batching, scheduling and preemption
        self.rng0 = root_key(config.rng_seed)
        self._decode, self._prefill = _jitted_steps(cfg, mesh, max_len)
        self._chunk = (_jitted_chunk(cfg, mesh, max_len, chunk)
                       if chunk else None)
        self.last_tok = jnp.zeros((max_batch, 1), jnp.int32)
        self.steps = 0
        # telemetry is host-side observation only (None = off): per-request
        # lifecycle spans, engine-phase spans and the metrics registry
        telemetry = self.tel = config.telemetry
        self._submit_t: dict = {}       # request id -> submit wall time
        self._straggler = None
        if telemetry is not None:
            from repro.runtime.monitor import (KVCacheMonitor,
                                               StragglerMonitor)
            self._straggler = StragglerMonitor()
            if self.kv_monitor is None:
                self.kv_monitor = KVCacheMonitor(
                    registry=telemetry.registry)
            self.scheduler.telemetry = telemetry
            if self.paged is not None:
                self.paged.telemetry = telemetry
                if self.paged.swap is not None:
                    self.paged.swap.attach_registry(telemetry.registry)
        # the jit caches are shared across engines: remember the counts at
        # construction so compile events register only when *this* engine
        # triggers a trace
        self._decode_compiles_seen = compile_count(self._decode)
        self._prefill_compiles_seen = self.prefill_compile_count()

    # -- telemetry ---------------------------------------------------------

    def _note_compiles(self):
        """Publish newly traced programs since the last check as compile
        events — decode retraces (e.g. the no-cold variant appearing)
        used to be invisible next to the prefill count."""
        tel = self.tel
        if tel is None:
            return
        n = compile_count(self._decode)
        if n > self._decode_compiles_seen:
            tel.registry.counter("serving_decode_compile_total").inc(
                n - self._decode_compiles_seen)
            if tel.tracer is not None:
                tel.tracer.instant("engine", "decode_compile",
                                   args={"step": self.steps})
            self._decode_compiles_seen = n
        n = self.prefill_compile_count()
        if n > self._prefill_compiles_seen:
            tel.registry.counter("serving_prefill_compile_total").inc(
                n - self._prefill_compiles_seen)
            if tel.tracer is not None:
                tel.tracer.instant("engine", "prefill_compile",
                                   args={"step": self.steps})
            self._prefill_compiles_seen = n

    def _sample_gauges(self):
        """Per-step level samples: queue depth and slot occupancy, as
        registry gauges (peak-tracking) and tracer counter tracks."""
        tel = self.tel
        if tel is None:
            return
        q = self.scheduler.waiting
        act = sum(1 for s in self.slots if s is not None)
        tel.registry.gauge("serving_queue_depth").set(q)
        tel.registry.gauge("serving_active_slots").set(act)
        if self.prefill_chunk:
            tel.registry.gauge("serving_prefilling_slots").set(
                len(self._prefill_pos))
        if self.prefix_sharing:
            tel.registry.gauge("prefix_shared_pages").set(
                self.paged.n_shared_pages())
        if tel.tracer is not None:
            tel.tracer.counter("serving_queue_depth", q)
            tel.tracer.counter("serving_active_slots", act)

    # -- scheduling --------------------------------------------------------

    def load(self) -> int:
        """Requests this engine currently owns: occupied slots plus the
        scheduler backlog (queued + preempted).  The router's
        least-loaded placement signal."""
        return (sum(1 for s in self.slots if s is not None)
                + self.scheduler.waiting)

    def prefix_match_tokens(self, prompt) -> int:
        """Longest index-resident prefix of ``prompt`` this engine could
        adopt by reference (0 without prefix sharing) — the router's
        prefix-affinity placement signal.  Purely advisory: reading the
        index allocates nothing and changes no state."""
        if self.paged is None or self.paged.prefix is None:
            return 0
        return self.paged.match_prefix(list(prompt))

    def submit(self, req: Request):
        self.scheduler.submit(req)
        self._inflight.append(req)
        if self.tel is not None:
            self._submit_t[req.id] = time.perf_counter()
            self.tel.registry.counter(
                "serving_requests_submitted_total").inc()
            if self.tel.requests is not None:
                self.tel.requests.transition(req.id, "queued")

    def _start(self, slot: int, req: Request):
        """Prefill a fresh request and splice it into ``slot``."""
        tel, t0 = self.tel, time.perf_counter()
        if tel is not None and tel.requests is not None:
            tel.requests.transition(req.id, "prefilling",
                                    args={"slot": slot})
        toks = jnp.asarray(req.prompt, jnp.int32)[None, :]
        logits, frag = self._prefill(self.params, toks)
        if self.paged is not None:
            self.cache = self.paged.admit(self.cache, slot, frag,
                                          len(req.prompt))
        else:
            self.cache = splice_fragment(self.cache, frag, slot)
        if self.spec_on:
            # spec-aware prefill: the paired draft consumes the prompt
            # too (its logits are unused — the first token is sampled
            # from the *target* prefill, identical to target-only)
            _, dfrag = self._draft_prefill(self.draft_params, toks)
            self.draft_cache = splice_fragment(self.draft_cache, dfrag,
                                               slot)
        self._host_len[slot] = len(req.prompt)
        tok = self._sample_one(logits, req)
        req.out_tokens.append(int(tok))
        self.last_tok = self.last_tok.at[slot, 0].set(tok)
        self.slots[slot] = req
        if tel is not None:
            now = time.perf_counter()
            sub = self._submit_t.get(req.id)
            if sub is not None:
                tel.registry.histogram("serving_queue_wait_seconds").observe(
                    t0 - sub)
                tel.registry.histogram("serving_ttft_seconds").observe(
                    now - sub)
            tel.registry.counter("serving_tokens_generated_total").inc()
            if tel.tracer is not None:
                tel.tracer.complete("engine", "prefill", "engine", t0, now,
                                    args={"req": req.id,
                                          "tokens": len(req.prompt)})
            if tel.requests is not None:
                tel.requests.transition(req.id, "decoding")
            self._note_compiles()

    def _start_chunked(self, slot: int, req: Request):
        """Admit a request for chunked prefill: allocate its page grant
        (``Scheduler.admission_grant`` — the same count ``pick`` tested
        against) and enter the prefill phase; no prompt compute yet,
        chunks run under the step's token budget in
        :func:`_prefill_phase`.

        With prefix sharing, admission matches the prompt against the
        prefix index first: matched pages are adopted by reference
        (``admit_shared``) and prefill resumes at the match boundary —
        the matched positions are never recomputed."""
        grant = self.scheduler.admission_grant(req)
        matched = 0
        if self.prefix_sharing:
            self.cache, matched = self.paged.admit_shared(
                self.cache, slot, req.prompt, grant)
        else:
            self.cache = self.paged.admit_slot(self.cache, slot, grant)
        self._host_len[slot] = matched
        self._prefill_pos[slot] = matched
        self._prefill_order.append(slot)
        self.slots[slot] = req
        if self.tel is not None and self.prefix_sharing:
            reg = self.tel.registry
            reg.counter("prefix_hit_total" if matched
                        else "prefix_miss_total").inc()
            if matched:
                reg.counter("prefix_match_tokens_total").inc(matched)
        if self.tel is not None:
            sub = self._submit_t.get(req.id)
            if sub is not None:
                self.tel.registry.histogram(
                    "serving_queue_wait_seconds").observe(
                        time.perf_counter() - sub)
            if self.tel.requests is not None:
                self.tel.requests.transition(req.id, "prefilling",
                                             args={"slot": slot})

    def _resume(self, slot: int, st: Preempted):
        """Re-splice a preempted request: reinstall its page list, fault
        every page back (lossless restore), reinstall any non-paged
        per-slot state (hybrid archs) and rebuild the slot timeline —
        the continuation is bit-identical to an unpreempted run.  A
        mid-prefill record re-enters the prefill phase at
        ``st.prefill_pos`` instead of rejoining the decode batch."""
        tel, t0 = self.tel, time.perf_counter()
        self.cache = self.paged.attach_slot(self.cache, slot, st.pages,
                                            st.skip)
        self.cache = self.paged.fault(self.cache, slot)
        if st.state:
            self.cache = self.paged.restore_slot_state(self.cache, slot,
                                                       st.state)
        if self.spec_on and st.draft_state is not None:
            # reinstall the paired draft-cache row (bit-exact: the state
            # never left its original bit pattern on the host)
            self._draft_restore(slot, st.draft_state)
        self.cache = dict(self.cache)
        self.cache["cur_len"] = self.cache["cur_len"].at[slot].set(
            st.host_len)
        self._host_len[slot] = st.host_len
        if st.prefill_pos is not None:
            self._prefill_pos[slot] = st.prefill_pos
            self._prefill_order.append(slot)
        else:
            self.last_tok = self.last_tok.at[slot, 0].set(st.last_tok)
        self.slots[slot] = st.req
        self.scheduler.n_resumed += 1
        if tel is not None:
            now = time.perf_counter()
            tel.registry.counter("serving_resumed_total").inc()
            tel.registry.histogram("serving_resume_seconds").observe(
                now - t0)
            if tel.tracer is not None:
                tel.tracer.complete("engine", "resume", "engine", t0, now,
                                    args={"req": st.req.id, "slot": slot})
            if tel.requests is not None:
                tel.requests.transition(
                    st.req.id, ("prefilling" if st.prefill_pos is not None
                                else "decoding"),
                    args={"slot": slot, "resumed": True})

    def _preempt(self, slot: int) -> bool:
        """Swap out a whole active request and requeue it (front of its
        priority class).  Returns False — with the engine state intact —
        when the swap store cannot take the pages."""
        req = self.slots[slot]
        tel, t0 = self.tel, time.perf_counter()
        store = self.paged.swap
        traffic = (store.swap_out_bytes, store.swap_in_bytes,
                   store.n_swap_out, store.n_swap_in)
        try:
            self.cache = self.paged.evict(self.cache, slot)
        except SwapExhausted:
            # roll back any partially evicted pages (their device space
            # was just freed, so the fault cannot itself run dry), and
            # un-count the aborted attempt so the monitor only reports
            # swapping that actually happened
            self.cache = self.paged.fault(self.cache, slot)
            (store.swap_out_bytes, store.swap_in_bytes,
             store.n_swap_out, store.n_swap_in) = traffic
            store.sync_registry()
            if tel is not None and tel.tracer is not None:
                tel.tracer.instant("engine", "preempt_aborted",
                                   args={"req": req.id, "slot": slot})
            return False
        state = self.paged.snapshot_slot_state(self.cache, slot)
        pages, skip = self.paged.detach_slot(slot)
        st = Preempted(req=req, pages=pages, skip=skip, state=state,
                       host_len=self._host_len[slot],
                       last_tok=int(self.last_tok[slot, 0]),
                       prefill_pos=self._prefill_pos.get(slot),
                       draft_state=(self._draft_snapshot(slot)
                                    if self.spec_on else None))
        if slot in self._prefill_pos:       # preempted mid-prefill
            del self._prefill_pos[slot]
            self._prefill_order.remove(slot)
        self.slots[slot] = None
        self.scheduler.n_preempted += 1
        self.scheduler.requeue(st)
        if tel is not None:
            now = time.perf_counter()
            tel.registry.counter("serving_preempted_total").inc()
            tel.registry.histogram("serving_preempt_seconds").observe(
                now - t0)
            if tel.tracer is not None:
                tel.tracer.complete("engine", "preempt", "engine", t0, now,
                                    args={"req": req.id, "slot": slot})
            if tel.requests is not None:
                tel.requests.transition(req.id, "preempted")
        return True

    def _admit(self, prefill_budget: int | None = None):
        """Fill free slots from the scheduler; preempt strictly-lower-
        priority work when the head of the queue is blocked on pages.
        ``prefill_budget``: remaining chunked-prefill tokens this step —
        once spent, only zero-prefill items (decode-phase resumes) admit,
        and the admission-victim hunt stands down (preempting for a
        request we cannot prefill yet would only flap)."""
        sched = self.scheduler
        while True:
            progress = False
            for slot in range(self.max_batch):
                if self.slots[slot] is not None:
                    continue
                item = sched.pick(slot, prefill_budget)
                if item is None:
                    continue
                if isinstance(item, Preempted):
                    self._resume(slot, item)
                elif self.prefill_chunk:
                    self._start_chunked(slot, item)
                else:
                    self._start(slot, item)
                progress = True
            if progress:
                continue
            head = sched.head()
            if head is None:
                break
            if (prefill_budget is not None and prefill_budget <= 0
                    and sched.prefill_tokens(head) > 0):
                break
            victim = sched.admission_victim(self.slots, head)
            if victim is None or not self._preempt(victim):
                break
        if (sched.waiting and self.paged is not None
                and not (prefill_budget is not None and prefill_budget <= 0)
                and not any(s is not None for s in self.slots)):
            # every slot is free yet nothing could be admitted: no release
            # will ever refill the free lists.  Raised only once the
            # batch has drained, so in-flight work always completes first.
            bad = sched.impossible()
            if bad is not None:
                raise OutOfPages(
                    f"request {bad.id} needs "
                    f"{self.paged.pages_worst_case(len(bad.prompt), bad.max_new_tokens)}"
                    f" resident pages; largest shard holds "
                    f"{max(self.paged.shard_capacity(k) for k in range(self.paged.n_shards))}"
                    f" (swap cannot hold a single slot's working set)")
            raise OutOfPages(
                f"queued work cannot be admitted with an empty batch (free "
                f"per shard: {self.paged.free_pages_per_shard})")

    def _sample_one(self, logits, req: Request):
        if req.temperature <= 0:
            return greedy(logits)[0, 0]
        key = request_key(self.rng0, req.id, len(req.out_tokens))
        return sample_logits(logits, key, temperature=req.temperature)[0, 0]

    def _finish(self, s: int, req: Request):
        """Retire a finished request: clear the slot, publish telemetry,
        release its pages."""
        req.done = True
        self.slots[s] = None
        tel = self.tel
        if tel is not None:
            tel.registry.counter("serving_requests_finished_total").inc()
            sub = self._submit_t.pop(req.id, None)
            if sub is not None:
                tel.registry.histogram(
                    "serving_request_latency_seconds").observe(
                        time.perf_counter() - sub)
            if tel.requests is not None:
                tel.requests.finish(req.id,
                                    args={"tokens": len(req.out_tokens)})
        if self.paged is not None:
            self.cache = self.paged.release(self.cache, s)

    # -- speculative decoding ----------------------------------------------

    def _draft_leaf_axis(self, path):
        """(names, batch axis) of a draft-cache leaf from its pytree path
        — the same dispatch as :func:`splice_fragment`."""
        names = [str(getattr(k, "key", getattr(k, "idx", k)))
                 for k in path]
        return names, (1 if "units" in names else 0)

    def _draft_snapshot(self, slot: int) -> list:
        """Host copies of every draft-cache leaf's ``slot`` slice — the
        paired draft row stashed into ``Preempted.draft_state`` when the
        target slot is preempted (preempting one preempts both)."""
        flat, _ = jax.tree_util.tree_flatten_with_path(self.draft_cache)
        slices = []
        for path, leaf in flat:
            names, axis = self._draft_leaf_axis(path)
            if "cur_len" in names:
                slices.append(leaf[slot])
            else:
                slices.append(jax.lax.dynamic_slice_in_dim(
                    leaf, slot, 1, axis=axis))
        # one transfer for the whole row: the preemption path's host
        # sync count stays independent of the pytree size
        return jax.device_get(slices)

    def _draft_restore(self, slot: int, snap: list):
        """Inverse of :func:`_draft_snapshot` (bit-exact: the row never
        left its original dtype/bit pattern on the host)."""
        flat, treedef = jax.tree_util.tree_flatten_with_path(
            self.draft_cache)
        leaves = []
        for (path, leaf), fr in zip(flat, snap):
            names, _ = self._draft_leaf_axis(path)
            leaves.append(_splice(leaf, jnp.asarray(fr), slot, names))
        self.draft_cache = jax.tree_util.tree_unflatten(treedef, leaves)

    def _draft_rollback(self, slot: int, snap_cache):
        """Re-splice ``slot``'s draft row from a retained round snapshot
        — the draft-side rejection rollback.  Snapshots are the
        (immutable) cache pytrees returned by each draft step, so this
        is a device-side slice/update per leaf, no host round trip; it
        also restores the recurrent state of non-positional drafts
        (slstm/mlstm), which no ``cur_len`` rollback could."""
        flat_cur, treedef = jax.tree_util.tree_flatten_with_path(
            self.draft_cache)
        flat_snap = jax.tree_util.tree_flatten(snap_cache)[0]
        leaves = []
        for (path, cur), sv in zip(flat_cur, flat_snap):
            names, axis = self._draft_leaf_axis(path)
            if "cur_len" in names:
                leaves.append(cur.at[slot].set(sv[slot]))
            else:
                fr = jax.lax.dynamic_slice_in_dim(sv, slot, 1, axis=axis)
                leaves.append(jax.lax.dynamic_update_slice_in_dim(
                    cur, fr, slot, axis=axis))
        self.draft_cache = jax.tree_util.tree_unflatten(treedef, leaves)

    def _spec_round(self, active):
        """One speculative round for every decode-phase slot: ``k``
        batched draft proposal steps (+1 state-advance step, snapshots
        retained), one verify forward per slot appending ``k + 1``
        tokens' K/V (``models.model.verify_chunk``), exact rejection
        sampling (``serving.spec.verify``), then timeline + page +
        draft-state rollback of each rejected suffix.  Emits 1..k+1
        tokens per slot; the emitted stream is distribution-identical
        to target-only decoding (token-identical under greedy).

        Draft snapshot indexing: ``snaps[j]`` is the draft cache after
        ``j`` steps, i.e. having consumed proposals ``1..j-1``.  A slot
        that emits ``j`` tokens needs exactly ``snaps[j]`` — its new
        last token is the ``j``-th emission, which the draft consumes
        at the start of the *next* round."""
        k, tel = self.spec_k, self.tel
        t0 = time.perf_counter()
        # grow every slot's page list to cover its whole verify window
        # *before* drafting: ensure-with-pressure can preempt another
        # active slot, and a victim's paired draft row must be stashed
        # in its round-start state, not mid-round advanced
        windows = {}
        for s in active:
            if self.slots[s] is None:
                continue            # preempted by an earlier slot's ensure
            n_cache = self._host_len[s]
            k_eff = max(min(k, self.max_len - 1 - n_cache), 0)
            # speculation never preempts a neighbour just to draft
            # deeper: under page pressure the window shrinks, and only
            # the mandatory +1 write (k_eff == 0: exactly the
            # target-only step's allocation) applies preemption pressure
            while k_eff:
                try:
                    self.cache = self.paged.ensure(self.cache, s,
                                                   n_cache + k_eff)
                    break
                except OutOfPages:
                    k_eff -= 1
            if not k_eff:
                self._ensure_with_pressure(s)
            windows[s] = (n_cache, k_eff)
        active = [s for s in active if self.slots[s] is not None]
        if not active:
            return
        snaps = [self.draft_cache]
        q_rows = []                     # draft logits per proposal (B, 1, V)
        props = np.zeros((self.max_batch, k), np.int64)
        tok = self.last_tok
        for j in range(1, k + 2):
            logits, dc = self._draft_decode(self.draft_params, tok,
                                            self.draft_cache)
            self.draft_cache = dc
            snaps.append(dc)
            if j > k:
                break                   # final step only advances state
            q_rows.append(logits)
            nxt = np.asarray(greedy(logits)).copy()           # (B, 1)
            # sampled rows propose with the plain-decode rule and key
            # (serving.spec module docstring) — same batched vmapped
            # draw as the target-only step loop
            samp = [s for s in active if self.slots[s].temperature > 0]
            if samp:
                rows = logits[jnp.asarray(samp)]
                ids = jnp.asarray([self.slots[s].id for s in samp],
                                  jnp.int32)
                pos = jnp.asarray(
                    [len(self.slots[s].out_tokens) + j - 1 for s in samp],
                    jnp.int32)
                temps = jnp.asarray(
                    [self.slots[s].temperature for s in samp], jnp.float32)

                def draw(row, i, p, t):
                    key = request_key(self.rng0, i, p)
                    return sample_logits(row[None] / t, key,
                                         temperature=1.0)[0, 0]

                # the per-iteration sync is inherent: draft step j+1
                # consumes step j's token (already batched over slots)
                got = np.asarray(jax.vmap(draw)(  # lint: disable=eager-loop-sync
                    rows, ids, pos, temps))
                for s, g in zip(samp, got.tolist()):
                    nxt[s, 0] = g
            props[:, j - 1] = nxt[:, 0]
            tok = jnp.asarray(nxt.astype(np.int32))
        width = k + 1
        for s in active:
            req = self.slots[s]
            # the window clamps to the slot's remaining timeline (the
            # verify writes positions n_cache .. n_cache + k_eff)
            n_cache, k_eff = windows[s]
            toks = np.zeros((1, width), np.int32)
            toks[0, 0] = int(self.last_tok[s, 0])
            toks[0, 1:1 + k_eff] = props[s, :k_eff]
            cache_in, stash = self._maybe_strip()
            logits, new_cache = self._verify(self.params,
                                             jnp.asarray(toks), cache_in,
                                             s, k_eff + 1)
            self.cache = (restore_cold(new_cache, stash) if stash
                          else new_cache)
            p_log = np.asarray(logits[0], np.float32)[: k_eff + 1]
            q_log = (np.stack([np.asarray(q_rows[j][s, 0])
                               for j in range(k_eff)])
                     if k_eff else
                     np.zeros((0, p_log.shape[-1]), np.float32))
            out, m = SPEC.verify(p_log, q_log, props[s, :k_eff].tolist(),
                                 rng0=self.rng0, req_id=req.id,
                                 pos0=len(req.out_tokens),
                                 temperature=req.temperature)
            # clip to the request's budget and the window (both >= 1:
            # a finished request never re-enters the active list)
            allow = min(req.max_new_tokens - len(req.out_tokens),
                        self.max_len - len(req.prompt)
                        - len(req.out_tokens))
            emit = out[: max(allow, 1)]
            j_emit = len(emit)
            new_len = n_cache + j_emit
            self.cache = self.paged.rollback(self.cache, s, new_len)
            self._host_len[s] = new_len
            if j_emit <= k:
                self._draft_rollback(s, snaps[j_emit])
            req.out_tokens.extend(int(t) for t in emit)
            self.last_tok = self.last_tok.at[s, 0].set(int(emit[-1]))
            self.n_spec_rounds += 1
            self.n_spec_drafted += k_eff
            self.n_spec_accepted += m
            if tel is not None:
                tel.registry.counter("spec_drafted_total").inc(k_eff)
                tel.registry.counter("spec_accepted_total").inc(m)
                tel.registry.histogram("spec_accept_rate").observe(
                    m / k_eff if k_eff else 0.0)
                tel.registry.counter(
                    "serving_tokens_generated_total").inc(j_emit)
            if (len(req.out_tokens) >= req.max_new_tokens
                    or len(req.prompt) + len(req.out_tokens)
                    >= self.max_len):
                self._finish(s, req)
        if tel is not None:
            now = time.perf_counter()
            tel.registry.histogram("serving_decode_step_seconds").observe(
                now - t0)
            if tel.tracer is not None:
                tel.tracer.complete("engine", "spec_round", "engine", t0,
                                    now, args={"step": self.steps,
                                               "active": len(active)})
            self._note_compiles()

    def spec_counters(self) -> dict:
        """Host-side speculative-decoding counters (mirrored into the
        telemetry registry as ``spec_*`` when telemetry is on)."""
        return {"spec_rounds": self.n_spec_rounds,
                "spec_drafted": self.n_spec_drafted,
                "spec_accepted": self.n_spec_accepted,
                "spec_accept_rate": (self.n_spec_accepted
                                     / max(self.n_spec_drafted, 1))}

    # -- chunked prefill ---------------------------------------------------

    def _maybe_strip(self):
        """(cache for the jitted call, stash) — while nothing is cold,
        both the decode step and the chunk step trace their no-cold-pool
        variant (the in-graph entropy decode of an empty pool is waste)."""
        if (self.paged is not None and self.paged.compress
                and not self.paged.has_cold):
            return strip_cold(self.cache)
        return self.cache, None

    def _ensure_prefill(self, slot: int, pos: int) -> bool:
        """Grow ``slot``'s page list to cover a chunk write at ``pos``.
        On pressure, preempt same-shard victims; as a last resort the
        prefilling request preempts *itself* (its chunks so far swap out
        losslessly and resume at the recorded position) — at most once
        per step, after which it pauses holding its pages, so an
        evict/fault ping-pong can never spin inside one step.  Returns
        False when the chunk must not run (self-preempted or paused)."""
        req = self.slots[slot]
        while True:
            try:
                self.cache = self.paged.ensure(self.cache, slot, pos)
                return True
            except OutOfPages:
                victim = self.scheduler.victim(
                    self.slots, shard=self.paged.shard_of_slot(slot),
                    exclude=(slot,))
                if victim is not None and self._preempt(victim):
                    continue
                if (self.scheduler._can_preempt()
                        and req.id not in self._stalled_ids
                        and self._preempt(slot)):
                    self._stalled_ids.add(req.id)
                    return False
                if self.scheduler._can_preempt():
                    return False        # paused: retry next step
                raise

    def _advance_prefill(self, slot: int, allowance: int) -> int:
        """Run prefill chunks for ``slot`` until its prompt is done or
        ~``allowance`` tokens were spent (the last chunk may overshoot by
        at most ``chunk - 1``).  The final chunk's logits produce the
        request's first token and move the slot to the decode phase.
        Returns the tokens spent."""
        req = self.slots[slot]
        C = self.prefill_chunk
        spent = 0
        while (self.slots[slot] is req and slot in self._prefill_pos
               and spent < allowance):
            pos = self._prefill_pos[slot]
            part = req.prompt[pos:pos + C]
            n = len(part)
            if not self._ensure_prefill(slot, pos + n - 1):
                return spent                    # self-preempted: requeued
            if self.prefix_sharing:
                # CoW safety invariant: block-aligned matching means the
                # chunk write starts at the match boundary, so this is
                # structurally a no-op — but any shared page in the write
                # window must split before the in-graph scatter lands
                self.cache = self.paged.make_writable(self.cache, slot,
                                                      pos, pos + n - 1)
            toks = jnp.asarray(list(part) + [0] * (C - n),
                               jnp.int32)[None, :]
            cache_in, stash = self._maybe_strip()
            tc0 = time.perf_counter()
            logits, new_cache = self._chunk(self.params, toks, cache_in,
                                            slot, n)
            self.cache = (restore_cold(new_cache, stash) if stash
                          else new_cache)
            self._prefill_pos[slot] = pos + n
            self._host_len[slot] = pos + n
            if self.prefix_sharing:
                # publish the slot's newly completed prompt blocks so
                # concurrent and future requests share them
                self.paged.register_prefix(slot, req.prompt, pos + n)
            self.n_chunks += 1
            self.n_chunk_tokens += n
            spent += n
            tel = self.tel
            if tel is not None:
                tel.registry.histogram(
                    "serving_prefill_chunk_seconds").observe(
                        time.perf_counter() - tc0)
            if pos + n >= len(req.prompt):      # final chunk: first token
                tok = self._sample_one(logits, req)
                req.out_tokens.append(int(tok))
                self.last_tok = self.last_tok.at[slot, 0].set(tok)
                del self._prefill_pos[slot]
                self._prefill_order.remove(slot)
                if tel is not None:
                    sub = self._submit_t.get(req.id)
                    if sub is not None:
                        tel.registry.histogram(
                            "serving_ttft_seconds").observe(
                                time.perf_counter() - sub)
                    tel.registry.counter(
                        "serving_tokens_generated_total").inc()
                    if tel.requests is not None:
                        tel.requests.transition(req.id, "decoding")
        return spent

    def _prefill_phase(self) -> int:
        """Spend up to ``prefill_budget`` prompt tokens on prefill work:
        mid-prefill slots drain first in admission order (FIFO within
        priority — an earlier prompt finishes before a later one starts),
        then new work admits against the remaining budget and runs its
        first chunks in the same step.  Returns tokens spent."""
        budget = self.prefill_budget
        spent = 0
        t0 = time.perf_counter()
        self._stalled_ids.clear()
        while True:
            for slot in list(self._prefill_order):
                if spent >= budget:
                    break
                if self.slots[slot] is not None and slot in self._prefill_pos:
                    spent += self._advance_prefill(slot, budget - spent)
            before = len(self._prefill_order)
            had_free = any(s is None for s in self.slots)
            self._admit(prefill_budget=budget - spent)
            if len(self._prefill_order) == before or spent >= budget \
                    or not had_free:
                break
        if spent and self.tel is not None:
            if self.tel.tracer is not None:
                self.tel.tracer.complete("engine", "prefill_phase",
                                         "engine", t0,
                                         args={"tokens": spent})
            self._note_compiles()
        return spent

    # -- stepping ----------------------------------------------------------

    def _ensure_with_pressure(self, slot: int, pos: int | None = None):
        """Grow ``slot``'s page list to cover a write at ``pos``
        (default: this step's single decode write); on page pressure,
        preempt victims on the same shard until it fits."""
        if pos is None:
            pos = self._host_len[slot]
        while True:
            try:
                self.cache = self.paged.ensure(self.cache, slot, pos)
                return
            except OutOfPages:
                victim = self.scheduler.victim(
                    self.slots, shard=self.paged.shard_of_slot(slot),
                    exclude=(slot,))
                if victim is None or not self._preempt(victim):
                    raise

    def step(self) -> bool:
        """One engine step: budgeted prefill work (chunked mode), then
        one batched decode step for the decode-phase slots.  Returns
        False when idle."""
        if self.prefill_chunk:
            prefill_spent = self._prefill_phase()
        else:
            self._admit()
            prefill_spent = 0
        active = [s for s in range(self.max_batch)
                  if self.slots[s] is not None
                  and s not in self._prefill_pos]
        if not active:
            if self._prefill_pos:
                self._record_monitor()
                self._sample_gauges()
                return True         # prefill in flight, nothing to decode
            return self.scheduler.waiting > 0
        if self.paged is not None:
            for s in active:   # grow page lists to cover this step's write
                if self.slots[s] is not None and s not in self._prefill_pos:
                    self._ensure_with_pressure(s)
                    if self.prefix_sharing:
                        # CoW safety invariant for the decode write (a
                        # structural no-op: decode writes land past the
                        # prompt, and full prompt blocks are the only
                        # shareable ones)
                        self.cache = self.paged.make_writable(
                            self.cache, s, self._host_len[s],
                            self._host_len[s])
            active = [s for s in range(self.max_batch)
                      if self.slots[s] is not None
                      and s not in self._prefill_pos]
            # fault-before-gather: the decode step must never see a
            # swapped page of an active slot (normally a no-op; resume
            # already faults, and whole-request preemption only swaps
            # vacated slots)
            for s in active:
                if self.paged.has_swapped(s):
                    self.cache = self.paged.fault(self.cache, s)
        if self.spec_on:
            # speculative mode replaces the single decode step with a
            # draft/verify round (1..k+1 tokens per slot); chunked
            # prefill is gated off, so no mid-prefill rows exist here
            self._spec_round(active)
            self.steps += 1
            if self.paged is not None and self.paged.compress:
                for s in range(self.max_batch):
                    if self.slots[s] is not None:
                        self.cache = self.paged.compress_cold_pages(
                            self.cache, s, self._host_len[s])
            self._record_monitor()
            self._sample_gauges()
            return True
        # while nothing is cold, run the decode variant without the cold
        # pool (its in-graph entropy decode would be pure waste)
        t_dec = time.perf_counter()
        cache_in, stash = self._maybe_strip()
        logits, new_cache = self._decode(self.params, self.last_tok,
                                         cache_in)
        self.cache = (restore_cold(new_cache, stash) if stash
                      else new_cache)
        self.steps += 1
        if self._prefill_pos:
            # mid-prefill rows decoded as masked garbage: the batched
            # step advanced every timeline, so roll theirs back (their
            # stray write sits at the next chunk's first position and is
            # overwritten by it)
            idx = jnp.asarray(sorted(self._prefill_pos), jnp.int32)
            self.cache = dict(self.cache)
            self.cache["cur_len"] = self.cache["cur_len"].at[idx].add(-1)
        if prefill_spent:
            self.n_interleaved_steps += 1
        toks = np.asarray(greedy(logits))  # (B, 1)
        # one batched draw for every sampled row: per-row keys fold
        # (rng_seed, request.id, position) — identical values to calling
        # _sample_one row by row, without k eager dispatches per step
        samp = [s for s in active if self.slots[s].temperature > 0]
        sampled = {}
        if samp:
            rows = logits[jnp.asarray(samp)]                  # (k, 1, V)
            ids = jnp.asarray([self.slots[s].id for s in samp], jnp.int32)
            pos = jnp.asarray([len(self.slots[s].out_tokens) for s in samp],
                              jnp.int32)
            temps = jnp.asarray([self.slots[s].temperature for s in samp],
                                jnp.float32)

            def draw(row, i, p, t):
                key = request_key(self.rng0, i, p)
                return sample_logits(row[None] / t, key,
                                     temperature=1.0)[0, 0]

            got = np.asarray(jax.vmap(draw)(rows, ids, pos, temps))
            sampled = dict(zip(samp, got.tolist()))
        tel = self.tel
        if tel is not None:
            # one timing feeds the step histogram and the straggler
            # monitor (np.asarray above materialized the device work)
            now = time.perf_counter()
            dt = now - t_dec
            tel.registry.histogram("serving_decode_step_seconds").observe(dt)
            sstat = self._straggler.observe(dt, self.steps)
            tel.registry.gauge("serving_decode_step_ewma_seconds").set(
                self._straggler.ewma_seconds)
            if sstat.is_straggler:
                tel.registry.counter("serving_decode_straggler_total").inc()
                if tel.tracer is not None:
                    tel.tracer.instant("engine", "decode_straggler",
                                       args={"step": self.steps,
                                             "z": sstat.z, "seconds": dt})
            if tel.tracer is not None:
                tel.tracer.complete("engine", "decode_step", "engine",
                                    t_dec, now,
                                    args={"step": self.steps,
                                          "active": len(active)})
            tel.registry.counter("serving_tokens_generated_total").inc(
                len(active))
            self._note_compiles()
        for s in active:
            req = self.slots[s]
            t = int(toks[s, 0] if req.temperature <= 0 else sampled[s])
            req.out_tokens.append(t)
            self.last_tok = self.last_tok.at[s, 0].set(t)
            self._host_len[s] += 1
            if len(req.out_tokens) >= req.max_new_tokens or (
                    len(req.prompt) + len(req.out_tokens) >= self.max_len):
                self._finish(s, req)
        if self.paged is not None and self.paged.compress:
            for s in range(self.max_batch):
                if self.slots[s] is not None:
                    self.cache = self.paged.compress_cold_pages(
                        self.cache, s, self._host_len[s])
        self._record_monitor()
        self._sample_gauges()
        return True

    def _record_monitor(self):
        if self.kv_monitor is None or self.paged is None:
            return
        stats = self.paged.stats()
        stats.update(self.scheduler.counters())
        if self.prefill_chunk:
            stats.update({
                "n_prefill_chunks": self.n_chunks,
                "prefill_chunk_tokens": self.n_chunk_tokens,
                "n_interleaved_steps": self.n_interleaved_steps,
                "prefilling_slots": len(self._prefill_pos),
            })
        self.kv_monitor.record(stats)
        if self.tel is not None and self.tel.tracer is not None:
            tr = self.tel.tracer
            tr.counter("kvcache_pages_in_use",
                       stats.get("pages_in_use", 0))
            if "swap_bytes_used" in stats:
                tr.counter("kvcache_swap_bytes_used",
                           stats["swap_bytes_used"])

    def prefill_compile_count(self) -> int:
        """Traced-program count of this engine's prefill path: the chunk
        step in chunked mode (must stay at 1 — or 2 once cold pages
        appear and the no-cold variant retraces — across *every* prompt
        length), else the whole-prompt prefill (retraces per length)."""
        return compile_count(self._chunk if self.prefill_chunk
                             else self._prefill)

    def decode_compile_count(self) -> int:
        """Traced-program count of this engine's decode step (1, or 2
        once cold pages appear and the no-cold variant retraces).  The
        registry's ``serving_decode_compile_total`` counts the retraces
        this engine itself triggered while stepping."""
        return compile_count(self._decode)

    def run(self, max_steps: int = 10_000, on_step=None) -> list:
        """Drain the queue; returns every submitted request that finished
        (whether it was queued, already admitted to a slot, or preempted
        when ``run`` was called — ``submit`` is the tracking point, not
        the queue snapshot).  ``on_step(step_index)``, when given, is
        called after every engine step (``launch/serve.py`` hangs the
        periodic stats line and the jax.profiler window off it)."""
        for i in range(max_steps):
            busy = self.step()
            if on_step is not None:
                on_step(i)
            if not busy and not any(s is not None for s in self.slots):
                break
        done = [r for r in self._inflight if r.done]
        self._inflight = [r for r in self._inflight if not r.done]
        return done
