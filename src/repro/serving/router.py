"""Request routing over ``GenerationEngine`` replicas.

The router is the placement policy of the async front end
(``serving.async_engine``): given a request, pick the replica that
serves it.  Placement is **deterministic** — a pure function of the
replicas' current load and prefix indices, with index order breaking
ties — so a seeded arrival trace always produces the same placement
sequence (asserted by ``tests/test_async_serving.py``), and per-request
token streams stay bit-identical no matter which replica serves them
(sampling keys fold ``(rng_seed, request.id, position)`` only; every
replica must therefore be built from the same ``EngineConfig.rng_seed``
for the bit-identity guarantee to hold across placements).

Two signals, in order:

1. **prefix affinity** — with prefix sharing enabled, the replica whose
   ``PrefixIndex`` already holds the longest prefix of the prompt
   adopts its pages by reference instead of recomputing them
   (``GenerationEngine.prefix_match_tokens``); a hit beats any load
   imbalance because the work it saves (the matched prefill tokens) is
   the dominant admission cost.  Ties fall through to load.
2. **least loaded** — fewest owned requests
   (``GenerationEngine.load()``: occupied slots + scheduler backlog);
   ties break to the lowest replica index.

Metrics (registry names in docs/OBSERVABILITY.md):
``router_placements_total``, ``router_prefix_affinity_total`` and the
dynamic per-replica gauge namespace ``router_replica<i>_load``.
"""
from __future__ import annotations

from .engine import GenerationEngine, Request

POLICIES = ("least-loaded", "round-robin")


class Router:
    """Deterministic request placement over engine replicas.

    ``policy="least-loaded"`` (default) applies prefix affinity then
    least-loaded placement; ``"round-robin"`` ignores both signals and
    cycles the replicas (the control arm in the load-replay bench).
    ``placements`` records ``(request_id, replica_index, reason)`` per
    routed request — the determinism test's observable."""

    def __init__(self, replicas, *, policy: str = "least-loaded",
                 telemetry=None):
        replicas = list(replicas)
        if not replicas:
            raise ValueError("Router needs at least one replica")
        if policy not in POLICIES:
            raise ValueError(f"policy={policy!r} (must be one of {POLICIES})")
        self.replicas = replicas
        self.policy = policy
        self.tel = telemetry
        self._rr = 0
        self.placements: list[tuple[int, int, str]] = []

    def __len__(self) -> int:
        return len(self.replicas)

    def load(self, idx: int) -> int:
        return self.replicas[idx].load()

    def total_load(self) -> int:
        return sum(eng.load() for eng in self.replicas)

    def place(self, req: Request) -> tuple[int, str]:
        """``(replica index, reason)`` for ``req`` — pure: reads load and
        prefix indices, changes nothing, so the front end may probe a
        placement and defer the submit under backpressure."""
        if self.policy == "round-robin":
            return self._rr % len(self.replicas), "round-robin"
        best, reason = 0, "least-loaded"
        matches = [eng.prefix_match_tokens(req.prompt)
                   for eng in self.replicas]
        top = max(matches)
        if top > 0:
            cands = [i for i, m in enumerate(matches) if m == top]
            reason = "prefix-affinity"
        else:
            cands = range(len(self.replicas))
        best = min(cands, key=lambda i: (self.replicas[i].load(), i))
        return best, reason

    def submit_to(self, idx: int, req: Request, *, reason: str = "direct"):
        """Hand ``req`` to replica ``idx`` and record the placement."""
        self.replicas[idx].submit(req)
        self._rr += 1
        self.placements.append((req.id, idx, reason))
        if self.tel is not None:
            reg = self.tel.registry
            reg.counter("router_placements_total").inc()
            if reason == "prefix-affinity":
                reg.counter("router_prefix_affinity_total").inc()

    def submit(self, req: Request) -> int:
        """Place and submit in one call; returns the replica index."""
        idx, reason = self.place(req)
        self.submit_to(idx, req, reason=reason)
        return idx

    def sample_load_gauges(self):
        """Publish per-replica load into the dynamic
        ``router_replica<i>_load`` gauge namespace (peak-tracked, like
        every registry gauge)."""
        if self.tel is None:
            return
        for i, eng in enumerate(self.replicas):
            self.tel.registry.gauge(f"router_replica{i}_load").set(
                eng.load())
