"""Paged KV cache: fixed-size pages, per-slot page tables, free-list alloc.

Replaces the serving engine's monolithic ``(max_batch, max_len)`` cache.
Every batch slot owns a list of fixed-size pages (``page_size`` token
positions x all KV heads); a shared ``(max_batch, pages_per_slot)`` page
table maps logical page index -> physical page id, identically for every
attention layer (one allocation decision serves the whole stack, as in
vLLM).  Slot reuse stops over-reserving: a short request only ever holds
the pages it wrote, and the engine reports pages-in-use, not worst case.

Physical id space:
  * id 0 is the **garbage page** — inactive slots' table rows point at it
    so the batched decode step can scatter/gather unconditionally;
  * ids ``1 .. n_pages-1`` are raw pool pages;
  * ids ``>= n_pages`` address the **cold pool**: pages that filled up are
    entropy-coded by ``kvcache.codec`` (lossless, exponent plane) and live
    compressed; decode-on-use happens inside the same jitted step, exactly
    like ECF8 weights.  A page whose coded stream would exceed the uniform
    stride budget stays raw (rare: adversarial exponent content);
  * **negative** ids are **swapped** pages: ``-(key + 1)`` indexes the
    host-side :class:`repro.kvcache.swap.SwapStore` (``attach_swap``).  A
    swapped page holds no device memory at all; its page-table entry is
    the same negative sentinel, which the decode path clamps to the
    garbage page — the serving engine faults every active slot resident
    (``fault``) before any decode step gathers it.

Page lifecycle (with a swap store attached)::

    hot (raw pool) --page full--> cold (compressed pool)
        \\                           |
         \\--evict (encode)--\\      evict (device->host copy)
                              v      v
                            swapped (host SwapStore)
                              |         |
               fault (Pallas decode)  fault (reinstall container)
                              v         v
                             hot       cold

Mesh sharding (``n_shards > 1``): the pool's page dim and the page table's
batch dim shard over the mesh's batch axes (``runtime.sharding
.batch_axes``).  Batch shard ``k`` owns slots ``[k*B/n, (k+1)*B/n)``, raw
page ids ``[k*n_pages/n, (k+1)*n_pages/n)`` and the matching cold-slot
range, each with its own free list — so every slot's history is entirely
local to its shard and the sharded decode step never gathers pages across
devices (``models.decode_sharded.paged_decode_attention_sharded``).

In-graph ops (``page_write`` / ``page_gather``) are pure functions used by
``models.model``'s decode attention; the ``PagedKVCache`` class is the
host-side controller driven by ``serving.engine`` across the request
lifecycle (admit -> ensure -> compress cold -> release).
"""
from __future__ import annotations

import time
import warnings

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from . import codec
from .codec import LANES

GARBAGE_PAGE = 0
PAGED_KINDS = ("attn", "nope")   # "local" keeps its ring, recurrents a state


class OutOfPages(RuntimeError):
    """Raised when the raw pool cannot cover a request's next page."""


# --------------------------------------------------------------------------
# in-graph ops (called from models.model inside the jitted decode step)
# --------------------------------------------------------------------------

def page_write(pool, page_table, cur_len, kv):
    """Scatter one new token's K (or V) into each slot's tail page.

    pool: (n_pool, n_kv, ps, hd); page_table: (B, P) int32 page ids;
    cur_len: (B,) write positions; kv: (B, n_kv, 1, hd).

    Tail pages are raw by construction (a page is only compressed once
    full), so the scatter targets the raw pool; out-of-range ids are
    dropped (``mode="drop"``) — which also makes this the per-shard write
    under a mesh: the sharded caller translates global ids to local ones
    and parks non-local entries out of range (``decode_sharded.
    paged_decode_attention_sharded``)."""
    ps = pool.shape[2]
    P = page_table.shape[1]
    p_idx = jnp.clip(cur_len // ps, 0, P - 1)
    off = cur_len % ps
    pids = jnp.take_along_axis(page_table, p_idx[:, None], axis=1)[:, 0]
    return pool.at[pids, :, off, :].set(
        kv[:, :, 0, :].astype(pool.dtype), mode="drop")


def page_write_chunk(pool, row, positions, kv, n_valid):
    """Scatter one prefill chunk's K (or V) into a single slot's pages.

    pool: (n_pool, n_kv, ps, hd); row: (P,) int32 page ids (the slot's
    page-table row); positions: (C,) absolute token positions of the
    chunk; kv: (1, n_kv, C, hd); n_valid: scalar count of real (unpadded)
    tokens.  Padded tokens are parked out of range and dropped
    (``mode="drop"``), which is also how the sharded caller silences
    non-owner shards (``decode_sharded.paged_prefill_chunk_sharded``)."""
    ps = pool.shape[2]
    P = row.shape[0]
    C = positions.shape[0]
    p_idx = jnp.clip(positions // ps, 0, P - 1)
    off = positions % ps
    pids = jnp.where(jnp.arange(C) < n_valid, row[p_idx], pool.shape[0])
    return pool.at[pids, :, off, :].set(
        kv[0].transpose(1, 0, 2).astype(pool.dtype), mode="drop")


def cold_leaves(cache: dict, kn: str):
    """The compressed-pool leaves for ``kn`` in {'k','v'}, or None.

    Returns (payload (n_cold, stride, LANES) u8, signmant (n_cold, sm) u8,
    tables (n_cold, 3, max_len) i32, perm (n_cold, n_sym) i32) — the
    argument order of ``codec.decode_pages_jnp``.  See docs/FORMATS.md §3
    for the leaf layout."""
    if f"{kn}_cpl" not in cache:
        return None
    return (cache[f"{kn}_cpl"], cache[f"{kn}_csm"],
            cache[f"{kn}_ctab"], cache[f"{kn}_cperm"])


_COLD_SUFFIXES = ("_cpl", "_csm", "_ctab", "_cperm")


def strip_cold(cache: dict):
    """Drop the cold-pool leaves from a paged cache -> (stripped, stash).

    While no page is cold, decoding the (empty) cold pool in-graph every
    step is pure waste; the engine strips these leaves so the decode step
    traces a no-cold variant, and restores them afterwards.  Costs one
    extra jit trace the first time a page actually goes cold."""
    stash = {}
    new = dict(cache)
    for section in ("units", "tail"):
        sec = dict(cache.get(section, {}))
        for name, leafd in sec.items():
            if not isinstance(leafd, dict) or "k_cpl" not in leafd:
                continue
            stash[(section, name)] = {
                k: v for k, v in leafd.items() if k.endswith(_COLD_SUFFIXES)}
            sec[name] = {k: v for k, v in leafd.items()
                         if not k.endswith(_COLD_SUFFIXES)}
        if sec:
            new[section] = sec
    return new, stash


def restore_cold(cache: dict, stash: dict):
    """Inverse of :func:`strip_cold` (cold leaves are read-only in-graph)."""
    new = dict(cache)
    for (section, name), cold in stash.items():
        sec = dict(new[section])
        sec[name] = {**sec[name], **cold}
        new[section] = sec
    return new


def page_gather(pool, page_table, cpool=None):
    """Gather each slot's pages into a contiguous KV history.

    pool: (n_pool, n_kv, ps, hd); page_table: (B, P) ids into the
    *virtual* pool; cpool: optional :func:`cold_leaves` tuple.  Cold pages
    (ids >= n_pool) are entropy-decoded in-graph and appended to the raw
    pool as a virtual suffix before the gather; ids are clipped, so
    garbage rows gather page 0 (their positions are masked by ``kv_len``
    downstream).  Returns (B, n_kv, P * ps, hd)."""
    n_kv, ps, hd = pool.shape[1:]
    virtual = pool
    if cpool is not None:
        payload, signmant, tables, perm = cpool
        dec = codec.decode_pages_jnp(
            payload, signmant, tables, perm, n_elem=n_kv * ps * hd,
            dtype_name=str(pool.dtype))
        virtual = jnp.concatenate(
            [pool, dec.reshape(-1, n_kv, ps, hd)], axis=0)
    ids = jnp.clip(page_table, 0, virtual.shape[0] - 1)
    gath = jnp.take(virtual, ids, axis=0)          # (B, P, n_kv, ps, hd)
    B, P = page_table.shape
    return gath.transpose(0, 2, 1, 3, 4).reshape(B, n_kv, P * ps, hd)


# --------------------------------------------------------------------------
# host-side controller
# --------------------------------------------------------------------------

class PrefixIndex:
    """Content-addressed index of page-aligned prompt-prefix blocks.

    Key: the token content of blocks ``0..i`` as a tuple (the dict hashes
    it; equality is checked on lookup, so a hash collision can never
    mis-match a prefix — bit-identity survives by construction).  Value:
    the physical entry holding block ``i``'s K/V — a raw pool page id,
    or a negative swap sentinel (``-(key + 1)``) once the page was
    retired to the swap tier's prefix cache.  Dict insertion order
    doubles as LRU order: :meth:`touch` moves a matched key to the back,
    reclaim walks from the front."""

    def __init__(self):
        self._entries: dict[tuple, int] = {}

    def __len__(self) -> int:
        return len(self._entries)

    def get(self, key):
        return self._entries.get(key)

    def put(self, key, entry: int) -> None:
        """Insert or update; an update keeps the key's LRU position."""
        self._entries[key] = entry

    def touch(self, key) -> None:
        self._entries[key] = self._entries.pop(key)

    def drop(self, key) -> None:
        self._entries.pop(key, None)

    def lru_keys(self) -> list:
        """Keys, least recently matched first."""
        return list(self._entries)

    def entries(self):
        return self._entries.values()


class PagedKVCache:
    """Allocator + lifecycle manager for the paged, compressible cache."""

    def __init__(self, cfg: ArchConfig, max_batch: int, max_len: int, *,
                 dtype, page_size: int = 16, n_pages: int | None = None,
                 compress_cold: bool = False, n_cold_slots: int | None = None,
                 budget_bits: int | None = None, n_shards: int = 1):
        """Args:
          cfg: architecture config (layer kinds decide which groups page).
          max_batch/max_len: static engine batch shape; every slot can hold
            at most ``max_len`` tokens (``pages_per_slot`` pages).
          dtype: cache storage dtype (fp8/bf16/f32 — must have a page-codec
            plane spec when ``compress_cold``).
          page_size: token positions per page; rounded down to a divisor of
            ``max_len``.
          n_pages: raw pool size (id 0 is the garbage page); defaults to
            the worst case (every slot full) plus the garbage page, and is
            rounded up to a multiple of ``n_shards``.
          compress_cold: entropy-code full pages into the cold pool.
          n_cold_slots: cold pool size (default: worst case minus one tail
            page per slot), rounded up to a multiple of ``n_shards``.
          budget_bits: uniform cold-payload budget in bits/symbol (default:
            the raw exponent width — never worse than the raw plane).
          n_shards: batch-shard count of the mesh the cache will live on
            (``runtime.sharding.batch_axes`` sizes multiplied); slots,
            raw pages and cold slots are partitioned contiguously into
            ``n_shards`` ranges with one free list each.  ``max_batch``
            must be divisible by it.
        """
        self.cfg = cfg
        self.max_batch, self.max_len = max_batch, max_len
        self.dtype = jnp.dtype(dtype)
        self.dtype_name = str(self.dtype)
        if n_shards < 1 or max_batch % n_shards:
            raise ValueError(
                f"max_batch={max_batch} not divisible by n_shards={n_shards}")
        self.n_shards = n_shards
        self.slots_per_shard = max_batch // n_shards
        ps = max(1, min(page_size, max_len))
        while max_len % ps:
            ps -= 1
        if ps != page_size:
            warnings.warn(
                f"page_size={page_size} does not divide max_len={max_len}; "
                f"using {ps} (a tiny page inflates the page table and the "
                f"per-token scatter/gather)", stacklevel=2)
        self.page_size = ps
        self.pages_per_slot = max_len // ps
        n_pages = n_pages or (
            n_shards + max_batch * self.pages_per_slot)
        # each shard owns a contiguous, equal range of page ids
        self.n_pages = -(-n_pages // n_shards) * n_shards
        self.pages_per_shard = self.n_pages // n_shards

        unit = cfg.unit
        self.n_units = cfg.n_layers // unit
        self.n_tail = cfg.n_layers - self.n_units * unit
        self.n_attn_layers = sum(
            1 for i in range(cfg.n_layers) if cfg.layer_kind(i) in PAGED_KINDS)
        self.has_attn = self.n_attn_layers > 0

        self.page_elems = cfg.n_kv_heads * ps * cfg.hd
        exp_bits, self.max_code_len, _ = codec.plane_spec(self.dtype_name)
        self.n_sym = 1 << exp_bits
        self.S = codec.sym_per_lane(self.page_elems)
        self.sm_nbytes = codec.sm_bytes(self.dtype_name, self.page_elems)
        self.compress = bool(compress_cold) and self.has_attn
        if budget_bits is None:
            budget_bits = exp_bits  # never worse than the raw exponent plane
        self.stride_budget = max(codec.MIN_STRIDE,
                                 -(-self.S * budget_bits // 8))
        default_cold = max_batch * max(self.pages_per_slot - 1, 1)
        n_cold = (n_cold_slots if n_cold_slots is not None
                  else default_cold) if self.compress else 0
        self.n_cold = -(-n_cold // n_shards) * n_shards if n_cold else 0
        self.cold_per_shard = self.n_cold // n_shards

        # per-shard free lists (descending, so pop() hands out low ids
        # first); shard 0's range excludes the garbage page id 0
        pps = self.pages_per_shard
        self._free = [list(range((k + 1) * pps - 1, max(k * pps, 1) - 1, -1))
                      for k in range(n_shards)]
        cps = self.cold_per_shard
        self._cold_free = [list(range((k + 1) * cps - 1, k * cps - 1, -1))
                           for k in range(n_shards)]
        self._slot_pages: dict[int, list[int]] = {}
        self._skip: dict[int, set[int]] = {}
        self._cold_bytes: dict[int, int] = {}
        # physical-page reference counts: every live raw pid has an entry
        # (1 = private).  Holders are slots (one ref per slot whose page
        # list contains the pid) and the prefix index (one ref per index
        # entry).  A pid returns to its shard's free list only when the
        # count hits zero — the audit invariant of release/rollback/
        # evict/compress, property-tested in tests/test_prefix_sharing.py
        self._ref: dict[int, int] = {}
        self.prefix = None              # PrefixIndex (enable_prefix_sharing)
        self.n_prefix_retired = 0       # index pages retired to swap
        self.n_prefix_dropped = 0       # index pages dropped (no swap room)
        self.n_cow_splits = 0           # shared pages split before a write
        self.swap = None                # SwapStore (attach_swap)
        self.telemetry = None           # serving.telemetry.Telemetry
        #   (engine-set; evict/fault publish page counts and host<->device
        #   swap spans through it — pure observation)

    # -- structure ---------------------------------------------------------

    def _groups(self):
        """Yield (section, name, kind, stacked) for every layer group."""
        unit = self.cfg.unit
        for j in range(unit):
            yield "units", f"pos{j}", self.cfg.pattern[j], True
        for t in range(self.n_tail):
            kind = self.cfg.layer_kind(self.n_units * unit + t)
            yield "tail", f"layer{t}", kind, False

    def _pool_leaves(self, stacked: bool) -> dict:
        cfg, ps = self.cfg, self.page_size
        lead = (self.n_units,) if stacked else ()
        pool = lead + (self.n_pages, cfg.n_kv_heads, ps, cfg.hd)
        d = {"k_pool": jnp.zeros(pool, self.dtype),
             "v_pool": jnp.zeros(pool, self.dtype)}
        if self.compress:
            for kn in ("k", "v"):
                d[f"{kn}_cpl"] = jnp.zeros(
                    lead + (self.n_cold, self.stride_budget, LANES),
                    jnp.uint8)
                d[f"{kn}_csm"] = jnp.zeros(
                    lead + (self.n_cold, self.sm_nbytes), jnp.uint8)
                d[f"{kn}_ctab"] = jnp.zeros(
                    lead + (self.n_cold, 3, self.max_code_len), jnp.int32)
                d[f"{kn}_cperm"] = jnp.zeros(
                    lead + (self.n_cold, self.n_sym), jnp.int32)
        return d

    def init_cache(self) -> dict:
        """The paged cache pytree: monolithic layout with attn/nope leaves
        replaced by page pools, plus the shared page table."""
        from repro.models import model as M
        cache = M.init_cache(self.cfg, self.max_batch, self.max_len,
                             dtype=self.dtype, per_slot=True)
        for section, name, kind, stacked in self._groups():
            if kind in PAGED_KINDS:
                cache[section] = {**cache[section],
                                  name: self._pool_leaves(stacked)}
        cache["page_table"] = jnp.zeros(
            (self.max_batch, self.pages_per_slot), jnp.int32)
        return cache

    # -- allocator ---------------------------------------------------------

    def _alloc_raw(self, sh: int) -> int:
        """Pop a raw page off ``sh``'s free list with refcount 1."""
        pid = self._free[sh].pop()
        self._ref[pid] = 1
        return pid

    def _incref(self, pid: int) -> None:
        self._ref[pid] = self._ref.get(pid, 0) + 1

    def _decref(self, pid: int) -> None:
        """Drop one reference; the page frees only when nobody holds it."""
        n = self._ref.get(pid, 1) - 1
        if n <= 0:
            self._ref.pop(pid, None)
            self._free[pid // self.pages_per_shard].append(pid)
        else:
            self._ref[pid] = n

    def shard_of_slot(self, slot: int) -> int:
        """Batch shard owning ``slot`` (contiguous slot ranges per shard)."""
        return slot // self.slots_per_shard

    @property
    def free_pages(self) -> int:
        """Total free raw pages across all shards."""
        return sum(len(f) for f in self._free)

    @property
    def free_pages_per_shard(self) -> list[int]:
        return [len(f) for f in self._free]

    @property
    def has_cold(self) -> bool:
        return bool(self._cold_bytes)

    def pages_needed(self, prompt_len: int) -> int:
        """Pages to cover the prompt and the first decode write."""
        return min(prompt_len // self.page_size + 1, self.pages_per_slot)

    def pages_for_prefix(self, n_tokens: int) -> int:
        """Pages that hold the first ``n_tokens`` cache positions — the
        chunked-prefill admission grant (unlike :func:`pages_needed` it
        does not cover the first decode write; later chunks and the
        decode step grow the slot page by page via :func:`ensure`)."""
        return min(max(-(-n_tokens // self.page_size), 1),
                   self.pages_per_slot)

    def can_admit(self, prompt_len: int, slot: int | None = None) -> bool:
        """Whether ``slot``'s shard (any shard when ``slot`` is None) has
        enough free pages for a ``prompt_len``-token prompt."""
        need = self.pages_needed(prompt_len)
        if slot is None:
            return any(len(f) >= need for f in self._free)
        return len(self._free[self.shard_of_slot(slot)]) >= need

    # -- request lifecycle -------------------------------------------------

    def admit(self, cache: dict, slot: int, frag: dict, prompt_len: int):
        """Allocate a fresh slot's pages (from its shard's free list) and
        splice the prefill fragment into the pool."""
        need = self.pages_needed(prompt_len)
        sh = self.shard_of_slot(slot)
        free = self._free[sh]
        if len(free) < need:
            cache = self._reclaim_prefix(cache, sh, need - len(free))
        if len(free) < need:
            raise OutOfPages(f"shard {sh}: slot {slot} needs {need} pages, "
                             f"{len(free)} free")
        pids = [self._alloc_raw(sh) for _ in range(need)]
        self._slot_pages[slot] = pids
        self._skip[slot] = set()

        row = np.zeros(self.pages_per_slot, np.int32)
        row[:need] = pids
        cache = dict(cache)
        cache["page_table"] = cache["page_table"].at[slot].set(
            jnp.asarray(row))
        cache["cur_len"] = cache["cur_len"].at[slot].set(prompt_len)
        ids = jnp.asarray(pids, jnp.int32)

        for section, name, kind, stacked in self._groups():
            dst, src = cache[section][name], frag[section][name]
            if kind in PAGED_KINDS:
                new = dict(dst)
                for kn in ("k", "v"):
                    pages = self._frag_pages(src[kn], stacked)
                    pool = dst[f"{kn}_pool"]
                    if stacked:
                        new[f"{kn}_pool"] = pool.at[:, ids].set(
                            pages[:, :need].astype(pool.dtype))
                    else:
                        new[f"{kn}_pool"] = pool.at[ids].set(
                            pages[:need].astype(pool.dtype))
            else:
                axis = 1 if stacked else 0
                new = jax.tree_util.tree_map(
                    lambda full, fr: jax.lax.dynamic_update_slice_in_dim(
                        full, fr.astype(full.dtype), slot, axis=axis),
                    dst, src)
            cache[section] = {**cache[section], name: new}
        return cache

    def admit_slot(self, cache: dict, slot: int, need: int):
        """Allocate a fresh slot for **chunked prefill**: grant ``need``
        pages (no fragment is spliced — chunks write K/V in-graph via
        :func:`page_write_chunk`) and reset the slot's timeline to
        position 0.  The grant is the first chunk's pages
        (:func:`pages_for_prefix`) when preemption can resolve later
        pressure, or the whole-prompt :func:`pages_needed` reservation
        when it cannot; later chunks append pages across chunk
        boundaries with :func:`ensure`."""
        sh = self.shard_of_slot(slot)
        free = self._free[sh]
        if len(free) < need:
            cache = self._reclaim_prefix(cache, sh, need - len(free))
        if len(free) < need:
            raise OutOfPages(f"shard {sh}: slot {slot} needs {need} pages, "
                             f"{len(free)} free")
        pids = [self._alloc_raw(sh) for _ in range(need)]
        self._slot_pages[slot] = pids
        self._skip[slot] = set()
        row = np.zeros(self.pages_per_slot, np.int32)
        row[:need] = pids
        cache = dict(cache)
        cache["page_table"] = cache["page_table"].at[slot].set(
            jnp.asarray(row))
        cache["cur_len"] = cache["cur_len"].at[slot].set(0)
        return cache

    def _frag_pages(self, x, stacked: bool):
        """Prefill fragment (.., 1, n_kv, max_len, hd) -> page-major view."""
        cfg, ps, P = self.cfg, self.page_size, self.pages_per_slot
        if stacked:
            x = x.reshape(self.n_units, cfg.n_kv_heads, P, ps, cfg.hd)
            return x.transpose(0, 2, 1, 3, 4)       # (U, P, n_kv, ps, hd)
        x = x.reshape(cfg.n_kv_heads, P, ps, cfg.hd)
        return x.transpose(1, 0, 2, 3)              # (P, n_kv, ps, hd)

    def ensure(self, cache: dict, slot: int, pos: int):
        """Grow the slot's page list to cover a write at ``pos`` (allocating
        from the slot's shard)."""
        pages = self._slot_pages.get(slot)
        if pages is None:
            return cache
        sh = self.shard_of_slot(slot)
        p = min(pos // self.page_size, self.pages_per_slot - 1)
        while len(pages) <= p:
            if not self._free[sh]:
                cache = self._reclaim_prefix(cache, sh, 1)
            if not self._free[sh]:
                raise OutOfPages(
                    f"shard {sh}: slot {slot} needs page {len(pages)}")
            pid = self._alloc_raw(sh)
            cache = dict(cache)
            cache["page_table"] = cache["page_table"].at[
                slot, len(pages)].set(pid)
            pages.append(pid)
        return cache

    def rollback(self, cache: dict, slot: int, n_tokens: int):
        """Truncate ``slot``'s timeline to ``n_tokens`` cache positions —
        the speculative-verify rejection path: a verify forward appended
        ``k + 1`` tokens' K/V (``page_write_chunk``) and the rejected
        suffix must disappear again.

        Pages past the boundary (beyond ``ceil(n_tokens / page_size)``,
        floored at one page so the admission grant is never undercut)
        return to the shard free list **in reverse-allocation order**:
        :func:`ensure` pops from the tail of the descending free list,
        so popping the slot's page list from its own tail and appending
        each id back restores the free list — and with it every future
        allocation decision — bit-exactly to the pre-verify state
        (tests/test_speculative.py rollback property test).  Freed pages
        are raw by construction: speculation allocates and rolls back
        within one engine step, before cold compression or eviction can
        touch the new pages.  Stale K/V between ``n_tokens`` and the old
        timeline inside *kept* pages is overwritten by the slot's next
        write at ``n_tokens`` and masked by ``kv_len`` until then — the
        chunked-prefill stray-write discipline.  ``cur_len[slot]`` is
        set to ``n_tokens``."""
        cache = dict(cache)
        pages = self._slot_pages.get(slot)
        if pages is not None:
            keep = min(max(-(-n_tokens // self.page_size), 1),
                       self.pages_per_slot)
            while len(pages) > keep:
                pid = pages.pop()
                if not (GARBAGE_PAGE < pid < self.n_pages):
                    raise ValueError(
                        f"rollback({slot}): page {pid} is not raw — only "
                        f"pages allocated by the current verify window "
                        f"can be rolled back")
                cache["page_table"] = cache["page_table"].at[
                    slot, len(pages)].set(GARBAGE_PAGE)
                self._decref(pid)
        cache["cur_len"] = cache["cur_len"].at[slot].set(n_tokens)
        return cache

    def release(self, cache: dict, slot: int):
        """Free a finished slot's raw pages, cold-pool entries and swapped
        pages back to the free lists / swap store that own the ids."""
        for e in self._slot_pages.pop(slot, []):
            if e < 0:
                if self.swap is not None:
                    self.swap.discard(-e - 1)
            elif e >= self.n_pages:
                cs = e - self.n_pages
                self._cold_free[cs // max(self.cold_per_shard, 1)].append(cs)
                self._cold_bytes.pop(cs, None)
            elif e != GARBAGE_PAGE:
                self._decref(e)     # shared prefix pages stay for the index
        self._skip.pop(slot, None)
        cache = dict(cache)
        cache["page_table"] = cache["page_table"].at[slot].set(
            jnp.zeros(self.pages_per_slot, jnp.int32))
        return cache

    # -- swap tier (hot/cold -> host, see kvcache/swap.py) -----------------

    def attach_swap(self, store) -> None:
        """Wire a :class:`repro.kvcache.swap.SwapStore` as the host tier;
        ``evict``/``fault`` require one."""
        self.swap = store

    def has_swapped(self, slot: int) -> bool:
        return any(e < 0 for e in self._slot_pages.get(slot, ()))

    def resident_raw_pages(self, slot: int) -> int:
        """Raw pool pages the slot currently holds (what preempting it
        would hand back to its shard's free list; cold and swapped
        entries free cold slots / swap bytes instead)."""
        return sum(1 for e in self._slot_pages.get(slot, ())
                   if GARBAGE_PAGE < e < self.n_pages)

    def n_swapped(self, slot: int) -> int:
        return sum(1 for e in self._slot_pages.get(slot, ()) if e < 0)

    def pages_worst_case(self, prompt_len: int, max_new: int) -> int:
        """Pages the request can ever hold at once: its last cache write
        lands at position ``min(prompt+max_new, max_len) - 2`` (the final
        sampled token is never written), floored at ``prompt_len`` (the
        admission grant covers the first decode write)."""
        last = max(min(prompt_len + max_new, self.max_len) - 2, prompt_len)
        return min(last // self.page_size + 1, self.pages_per_slot)

    def shard_capacity(self, shard: int) -> int:
        """Allocatable raw pages in ``shard``'s id range (shard 0 loses
        the garbage page)."""
        return self.pages_per_shard - (1 if shard == 0 else 0)

    def _iter_subpages(self):
        """Yield (section, name, stacked, kn, u) in the canonical sub-page
        order shared by evict and fault."""
        for section, name, kind, stacked in self._groups():
            if kind not in PAGED_KINDS:
                continue
            for kn in ("k", "v"):
                for u in (range(self.n_units) if stacked else (None,)):
                    yield section, name, stacked, kn, u

    def _encode_raw_page(self, cache: dict, pid: int):
        """Entropy-code one raw pool page into a host SwappedPage."""
        from . import swap as SW
        page = SW.SwappedPage(was_cold=False)
        for section, name, stacked, kn, u in self._iter_subpages():
            pool = cache[section][name][f"{kn}_pool"]
            sub = np.asarray(pool[u, pid] if stacked else pool[pid])
            cp = codec.encode_page(sub)
            page.entries.append(SW.SwapEntry(
                section, name, stacked, kn, u, cp.payload, cp.signmant,
                cp.tables(), cp.perm))
            page.nbytes += cp.nbytes()
        return page

    def _copy_cold_page(self, cache: dict, cslot: int):
        """Copy an already-coded cold page's container to the host (the
        cheap, cold-first eviction path: no re-encode)."""
        from . import swap as SW
        page = SW.SwappedPage(was_cold=True,
                              nbytes=self._cold_bytes.get(cslot, 0))
        for section, name, stacked, kn, u in self._iter_subpages():
            leafd = cache[section][name]
            idx = (u, cslot) if stacked else (cslot,)
            page.entries.append(SW.SwapEntry(
                section, name, stacked, kn, u,
                np.asarray(leafd[f"{kn}_cpl"][idx]),
                np.asarray(leafd[f"{kn}_csm"][idx]),
                np.asarray(leafd[f"{kn}_ctab"][idx]),
                np.asarray(leafd[f"{kn}_cperm"][idx])))
        return page

    def evict(self, cache: dict, slot: int, page_idxs=None):
        """Swap the slot's device-resident pages out to the host store.

        Cold pages go first (their container copies without re-encoding);
        raw pages are entropy-coded on the host — losslessly for *any*
        bit content, so even a half-written tail page round-trips
        bit-exactly.  Freed raw pages / cold slots return to their
        shard's free lists; the page list and page-table entries become
        negative swap sentinels (``-(key + 1)``)."""
        if self.swap is None:
            raise RuntimeError("evict() needs attach_swap(SwapStore)")
        pages = self._slot_pages.get(slot)
        if pages is None:
            return cache
        sh = self.shard_of_slot(slot)
        idxs = list(range(len(pages))) if page_idxs is None else list(page_idxs)
        # cold-first: already-compressed pages are the cheapest victims
        idxs.sort(key=lambda p: (pages[p] < self.n_pages, p))
        cache = dict(cache)
        t0 = time.perf_counter()
        n_moved = 0
        for p in idxs:
            e = pages[p]
            if e < 0 or e == GARBAGE_PAGE:
                continue
            if e >= self.n_pages:
                cs = e - self.n_pages
                sp = self._copy_cold_page(cache, cs)
                key = self.swap.put(sp, sh)
                self._cold_free[cs // max(self.cold_per_shard, 1)].append(cs)
                self._cold_bytes.pop(cs, None)
            else:
                # a shared page gets a *private* swap copy and a decref:
                # the prefix index keeps its own (still-resident) reference,
                # so sharing degrades gracefully under memory pressure and
                # detach_slot's all-swapped assertion holds
                sp = self._encode_raw_page(cache, e)
                key = self.swap.put(sp, sh)
                self._decref(e)
            pages[p] = -(key + 1)
            cache["page_table"] = cache["page_table"].at[slot, p].set(
                -(key + 1))
            n_moved += 1
        if n_moved and self.telemetry is not None:
            self.telemetry.registry.counter(
                "kvcache_evict_pages_total").inc(n_moved)
            if self.telemetry.tracer is not None:
                self.telemetry.tracer.complete(
                    "swap", "evict", "engine", t0,
                    args={"slot": slot, "pages": n_moved})
        return cache

    def fault(self, cache: dict, slot: int, page_idxs=None):
        """Restore the slot's swapped pages to the device (the inverse of
        :func:`evict`; a no-op when nothing is swapped).

        Cold-swapped pages reinstall their coded container into a fresh
        cold slot (never decoded); raw-swapped pages are **batch-decoded
        through the Pallas page-decode path** (``kernels.decode_pages``)
        into fresh raw pages.  Raises :class:`OutOfPages` — before any
        state is mutated — if the slot's shard cannot cover the restore.
        """
        pages = self._slot_pages.get(slot)
        if pages is None:
            return cache
        idxs = [p for p in (range(len(pages)) if page_idxs is None
                            else page_idxs) if pages[p] < 0]
        if not idxs:
            return cache
        sh = self.shard_of_slot(slot)
        # placement plan (peek only): cold-swapped pages take cold slots
        # while they last, everything else needs a raw page
        plan = []                       # (p, SwappedPage, to_cold)
        cold_budget = len(self._cold_free[sh]) if self.compress else 0
        raw_need = 0
        for p in idxs:
            sp = self.swap.peek(-pages[p] - 1)
            to_cold = sp.was_cold and cold_budget > 0
            cold_budget -= int(to_cold)
            raw_need += int(not to_cold)
            plan.append((p, sp, to_cold))
        if raw_need > len(self._free[sh]):
            cache = self._reclaim_prefix(
                cache, sh, raw_need - len(self._free[sh]))
        if raw_need > len(self._free[sh]):
            raise OutOfPages(
                f"shard {sh}: faulting {len(idxs)} swapped pages of slot "
                f"{slot} needs {raw_need} raw pages, "
                f"{len(self._free[sh])} free")

        cache = dict(cache)
        t0 = time.perf_counter()
        raw_jobs = []                   # (entry, pid) scattered after decode
        for p, sp, to_cold in plan:
            self.swap.pop(-pages[p] - 1)
            if to_cold:
                cs = self._cold_free[sh].pop()
                for ent in sp.entries:
                    leafd = dict(cache[ent.section][ent.name])
                    idx = (ent.u, cs) if ent.stacked else (cs,)
                    pay = np.zeros((self.stride_budget, LANES), np.uint8)
                    pay[: ent.payload.shape[0]] = ent.payload
                    leafd[f"{ent.kn}_cpl"] = \
                        leafd[f"{ent.kn}_cpl"].at[idx].set(pay)
                    leafd[f"{ent.kn}_csm"] = \
                        leafd[f"{ent.kn}_csm"].at[idx].set(ent.signmant)
                    leafd[f"{ent.kn}_ctab"] = \
                        leafd[f"{ent.kn}_ctab"].at[idx].set(ent.tables)
                    leafd[f"{ent.kn}_cperm"] = \
                        leafd[f"{ent.kn}_cperm"].at[idx].set(ent.perm)
                    cache[ent.section] = {**cache[ent.section],
                                          ent.name: leafd}
                self._cold_bytes[cs] = sp.nbytes
                entry = self.n_pages + cs
            else:
                pid = self._alloc_raw(sh)
                raw_jobs.extend((ent, pid) for ent in sp.entries)
                entry = pid
            pages[p] = entry
            cache["page_table"] = cache["page_table"].at[slot, p].set(entry)

        if raw_jobs:
            cache = self._restore_raw(cache, raw_jobs)
        if self.telemetry is not None:
            self.telemetry.registry.counter(
                "kvcache_fault_pages_total").inc(len(plan))
            if self.telemetry.tracer is not None:
                self.telemetry.tracer.complete(
                    "swap", "fault", "engine", t0,
                    args={"slot": slot, "pages": len(plan)})
        return cache

    def _restore_raw(self, cache: dict, jobs):
        """Batch-decode swapped sub-pages and scatter them into the raw
        pool: one Pallas ``decode_pages`` call covers every sub-page of
        every page being faulted (stride padded to the batch max)."""
        from . import kernels
        stride = max(e.payload.shape[0] for e, _ in jobs)
        stride = -(-stride // 4) * 4        # bucket shapes for the jit cache
        pay = np.zeros((len(jobs), stride, LANES), np.uint8)
        for i, (e, _) in enumerate(jobs):
            pay[i, : e.payload.shape[0]] = e.payload
        dec = kernels.decode_pages(
            jnp.asarray(pay),
            jnp.asarray(np.stack([e.signmant for e, _ in jobs])),
            jnp.asarray(np.stack([e.tables for e, _ in jobs])),
            jnp.asarray(np.stack([e.perm for e, _ in jobs])),
            n_elem=self.page_elems, dtype_name=self.dtype_name)
        shape = (self.cfg.n_kv_heads, self.page_size, self.cfg.hd)
        for i, (e, pid) in enumerate(jobs):
            pool = cache[e.section][e.name][f"{e.kn}_pool"]
            sub = dec[i].reshape(shape).astype(pool.dtype)
            idx = (e.u, pid) if e.stacked else (pid,)
            cache[e.section] = {
                **cache[e.section],
                e.name: {**cache[e.section][e.name],
                         f"{e.kn}_pool": pool.at[idx].set(sub)}}
        return cache

    def snapshot_slot_state(self, cache: dict, slot: int) -> dict:
        """Host copies of the slot's **non-paged** per-slot cache state —
        local-attention ring buffers and recurrent (rglru/slstm/mlstm)
        states of hybrid architectures live in monolithic batch-dim
        leaves next to the page pools, hold no page ids, and would be
        clobbered by the next request admitted to the slot.  Preemption
        stashes them with this and reinstalls via
        :func:`restore_slot_state` on resume."""
        snap = {}
        for section, name, kind, stacked in self._groups():
            if kind in PAGED_KINDS:
                continue
            axis = 1 if stacked else 0
            snap[(section, name)] = jax.tree_util.tree_map(
                lambda x: np.asarray(
                    jax.lax.dynamic_slice_in_dim(x, slot, 1, axis=axis)),
                cache[section][name])
        return snap

    def restore_slot_state(self, cache: dict, slot: int,
                           snap: dict) -> dict:
        """Inverse of :func:`snapshot_slot_state` (bit-exact: the state
        never leaves its original dtype/bit pattern)."""
        cache = dict(cache)
        for (section, name), sub in snap.items():
            axis = 1 if section == "units" else 0
            cache[section] = {**cache[section], name: jax.tree_util.tree_map(
                lambda full, fr: jax.lax.dynamic_update_slice_in_dim(
                    full, jnp.asarray(fr).astype(full.dtype), slot,
                    axis=axis),
                cache[section][name], sub)}
        return cache

    def detach_slot(self, slot: int):
        """Pop a preempted slot's host state -> (page list, skip set).

        Every entry must already be swapped (call :func:`evict` first);
        the engine stashes the result in its preemption record and
        reinstalls it with :func:`attach_slot` on resume."""
        pages = self._slot_pages.pop(slot)
        assert all(e < 0 for e in pages), \
            f"detach_slot({slot}): resident pages remain {pages}"
        return pages, self._skip.pop(slot, set())

    def attach_slot(self, cache: dict, slot: int, pages, skip):
        """Reinstall a preempted slot's page list (all swap sentinels) and
        page-table row; follow with :func:`fault` to make it resident."""
        self._slot_pages[slot] = list(pages)
        self._skip[slot] = set(skip)
        row = np.zeros(self.pages_per_slot, np.int32)
        row[: len(pages)] = pages
        cache = dict(cache)
        cache["page_table"] = cache["page_table"].at[slot].set(
            jnp.asarray(row))
        return cache

    # -- cross-request prefix sharing --------------------------------------

    def enable_prefix_sharing(self) -> None:
        """Attach a :class:`PrefixIndex` so requests with a common
        page-aligned prompt prefix share one physical copy of its pages
        (copy-on-write protected).  Single-shard only: page ids are
        shard-local, so a prefix cached by one shard would be unreachable
        from slots of any other."""
        if self.n_shards != 1:
            raise ValueError(
                f"prefix sharing requires n_shards == 1 (got "
                f"{self.n_shards}): pages are shard-local and slots must "
                f"gather only their own shard's pages")
        if self.prefix is None:
            self.prefix = PrefixIndex()

    @property
    def prefix_sharing(self) -> bool:
        return self.prefix is not None

    def _prefix_key(self, prompt, i: int) -> tuple:
        """Content address of prompt block ``i``: the token ids of blocks
        ``0..i``.  Keys are prefix-closed — block ``i``'s K/V is fully
        determined by (and only by) the tokens in the key, so equal keys
        imply bit-identical page content."""
        return tuple(prompt[: (i + 1) * self.page_size])

    def match_prefix(self, prompt) -> int:
        """Longest index-resident prefix of ``prompt``, in tokens (always
        a multiple of ``page_size``).

        Capped at ``(len(prompt) - 1) // page_size`` blocks so the final
        prompt token is always prefilled (it produces the first-token
        logits) and the first unmatched write lands exactly on the match
        boundary — writes never land inside a matched page.  Swap-retired
        entries whose key was LRU-evicted from the store drop out of the
        index here."""
        if self.prefix is None or not len(prompt):
            return 0
        n = 0
        for i in range((len(prompt) - 1) // self.page_size):
            key = self._prefix_key(prompt, i)
            ent = self.prefix.get(key)
            if ent is None:
                break
            if ent < 0 and (self.swap is None
                            or not self.swap.contains(-ent - 1)):
                self.prefix.drop(key)
                break
            n += 1
        return n * self.page_size

    def admit_shared(self, cache: dict, slot: int, prompt, extra: int):
        """Admit a chunked-prefill slot against the prefix index ->
        ``(cache, matched_tokens)``.

        Matched raw pages are increffed (the slot becomes a co-holder of
        the same physical page); matched swap-retired pages are faulted
        back bit-exactly (batch Pallas decode) into fresh raw pages that
        the index re-adopts; ``extra`` fresh pages cover the unmatched
        suffix.  ``cur_len`` starts at ``matched_tokens``, so prefill
        chunks resume at the match boundary with zero new compilations
        (``prefill_chunk`` reads its start position in-graph)."""
        sh = self.shard_of_slot(slot)
        ps = self.page_size
        cache = dict(cache)
        shared: list[int] = []
        raw_jobs = []               # (SwapEntry, pid) for _restore_raw
        n_faulted = 0
        t0 = time.perf_counter()
        if self.prefix is not None:
            for i in range((len(prompt) - 1) // ps if len(prompt) else 0):
                key = self._prefix_key(prompt, i)
                ent = self.prefix.get(key)
                if ent is None:
                    break
                if ent < 0:
                    k = -ent - 1
                    if self.swap is None or not self.swap.contains(k):
                        self.prefix.drop(key)
                        break
                    if not self._free[sh]:
                        cache = self._reclaim_prefix(cache, sh, 1)
                    if not self._free[sh]:
                        break       # match shrinks; the suffix is prefilled
                    sp = self.swap.pop(k)
                    ent = self._alloc_raw(sh)       # the index's reference
                    raw_jobs.extend((e2, ent) for e2 in sp.entries)
                    self.prefix.put(key, ent)
                    n_faulted += 1
                self._incref(ent)                   # the slot's reference
                self.prefix.touch(key)
                shared.append(ent)
        if raw_jobs:
            cache = self._restore_raw(cache, raw_jobs)

        extra = max(min(extra, self.pages_per_slot - len(shared)), 0)
        free = self._free[sh]
        if len(free) < extra:
            cache = self._reclaim_prefix(cache, sh, extra - len(free))
        if len(free) < extra:
            for pid in shared:      # undo: the admission failed whole
                self._decref(pid)
            raise OutOfPages(f"shard {sh}: slot {slot} needs {extra} "
                             f"pages past its shared prefix, "
                             f"{len(free)} free")
        pids = [self._alloc_raw(sh) for _ in range(extra)]
        self._slot_pages[slot] = shared + pids
        self._skip[slot] = set()
        row = np.zeros(self.pages_per_slot, np.int32)
        row[: len(shared) + extra] = shared + pids
        cache["page_table"] = cache["page_table"].at[slot].set(
            jnp.asarray(row))
        cache["cur_len"] = cache["cur_len"].at[slot].set(len(shared) * ps)
        if n_faulted and self.telemetry is not None:
            self.telemetry.registry.counter(
                "kvcache_fault_pages_total").inc(n_faulted)
            if self.telemetry.tracer is not None:
                self.telemetry.tracer.complete(
                    "swap", "prefix_fault", "engine", t0,
                    args={"slot": slot, "pages": n_faulted})
        return cache, len(shared) * ps

    def register_prefix(self, slot: int, prompt, n_tokens: int) -> None:
        """Publish the slot's fully-prefilled, page-aligned prompt blocks
        into the index (called after each prefill chunk lands).

        Caps at ``len(prompt) // page_size`` blocks: a full prompt block
        is never written again (the first decode write lands at position
        ``len(prompt)``, in a later block), so published pages are
        immutable while referenced.  Blocks whose content is already
        indexed keep the incumbent copy (LRU-touched, not replaced)."""
        if self.prefix is None:
            return
        pages = self._slot_pages.get(slot)
        if pages is None:
            return
        nb = min(min(n_tokens, len(prompt)) // self.page_size, len(pages))
        for i in range(nb):
            pid = pages[i]
            if not (GARBAGE_PAGE < pid < self.n_pages):
                continue            # cold/swapped entries are not shareable
            key = self._prefix_key(prompt, i)
            if self.prefix.get(key) is not None:
                self.prefix.touch(key)
                continue
            self.prefix.put(key, pid)
            self._incref(pid)

    def make_writable(self, cache: dict, slot: int, lo: int, hi: int):
        """Copy-on-write guard: split any shared raw page of ``slot``
        covering positions ``[lo, hi]`` into a private device copy before
        an in-graph write lands there.

        Block-aligned matching makes this structurally unreachable on the
        normal path (writes start at the match boundary and full prompt
        blocks are never rewritten), so it is a safety invariant, not the
        common path; ``n_cow_splits`` counts actual splits."""
        pages = self._slot_pages.get(slot)
        if pages is None or self.prefix is None:
            return cache
        ps = self.page_size
        sh = self.shard_of_slot(slot)
        for p in range(lo // ps, min(hi // ps, len(pages) - 1) + 1):
            pid = pages[p]
            if (not (GARBAGE_PAGE < pid < self.n_pages)
                    or self._ref.get(pid, 1) <= 1):
                continue
            if not self._free[sh]:
                cache = self._reclaim_prefix(cache, sh, 1)
            if not self._free[sh]:
                raise OutOfPages(f"shard {sh}: CoW split of slot {slot} "
                                 f"page {p} has no free page")
            new = self._alloc_raw(sh)
            cache = dict(cache)
            for section, name, kind, stacked in self._groups():
                if kind not in PAGED_KINDS:
                    continue
                leafd = dict(cache[section][name])
                for kn in ("k", "v"):
                    pool = leafd[f"{kn}_pool"]
                    leafd[f"{kn}_pool"] = (
                        pool.at[:, new].set(pool[:, pid]) if stacked
                        else pool.at[new].set(pool[pid]))
                cache[section] = {**cache[section], name: leafd}
            self._decref(pid)
            pages[p] = new
            cache["page_table"] = cache["page_table"].at[slot, p].set(new)
            self.n_cow_splits += 1
        return cache

    def _reclaim_prefix(self, cache: dict, sh: int, need: int):
        """Retire up to ``need`` index-only prefix pages (refcount 1 — no
        slot co-holds them) on shard ``sh``, least recently matched
        first.  With a swap store attached each page is entropy-coded
        into the store's **unpinned** LRU prefix cache (it faults back
        bit-exactly on the next match); when the store cannot hold it —
        or there is no store — the entry is dropped.  Either way the raw
        page frees, so every allocation site can treat index-only pages
        as reclaimable headroom."""
        if self.prefix is None or need <= 0:
            return cache
        freed = 0
        for key in self.prefix.lru_keys():
            if freed >= need:
                break
            ent = self.prefix.get(key)
            if (ent is None or ent < 0
                    or ent // self.pages_per_shard != sh
                    or self._ref.get(ent, 1) != 1):
                continue
            k = None
            if self.swap is not None:
                sp = self._encode_raw_page(cache, ent)
                k = self.swap.put(sp, sh, pinned=False)
            if k is not None:
                self.prefix.put(key, -(k + 1))      # keeps LRU position
                self.n_prefix_retired += 1
            else:
                self.prefix.drop(key)
                self.n_prefix_dropped += 1
            self._decref(ent)
            freed += 1
        return cache

    def reclaimable_pages(self, shard: int = 0) -> int:
        """Raw pages held only by the prefix index (refcount 1) on
        ``shard`` — on-demand headroom the scheduler counts as available
        when sizing admission."""
        if self.prefix is None:
            return 0
        return sum(1 for e in self.prefix.entries()
                   if e > 0 and e // self.pages_per_shard == shard
                   and self._ref.get(e, 0) == 1)

    def n_shared_pages(self) -> int:
        """Raw index pages currently co-held by at least one slot."""
        if self.prefix is None:
            return 0
        return sum(1 for e in self.prefix.entries()
                   if e > 0 and self._ref.get(e, 0) > 1)

    # -- cold compression --------------------------------------------------

    def compress_cold_pages(self, cache: dict, slot: int, pos: int):
        """Entropy-code the slot's full (non-tail) pages into the cold pool.

        ``pos`` is the next write position; pages strictly below
        ``pos // page_size`` are complete and never written again."""
        if not self.compress or slot not in self._slot_pages:
            return cache
        sh = self.shard_of_slot(slot)
        full = min(pos // self.page_size, len(self._slot_pages[slot]))
        for p in range(full):
            # shared prefix pages (refcount > 1) stay raw: compressing
            # the slot's copy would duplicate a page other holders still
            # gather from, defeating the one-physical-copy invariant
            if (self._slot_pages[slot][p] >= self.n_pages
                    or p in self._skip[slot]
                    or self._ref.get(self._slot_pages[slot][p], 1) > 1):
                continue
            if not self._cold_free[sh]:
                return cache
            cache, ok = self._compress_one(cache, slot, p)
            if not ok:
                self._skip[slot].add(p)
        return cache

    def _compress_one(self, cache: dict, slot: int, p: int):
        pid = self._slot_pages[slot][p]
        enc = []                    # (section, name, stacked, kn, u, page)
        for section, name, kind, stacked in self._groups():
            if kind not in PAGED_KINDS:
                continue
            leafd = cache[section][name]
            for kn in ("k", "v"):
                pool = leafd[f"{kn}_pool"]
                units = range(self.n_units) if stacked else (None,)
                for u in units:
                    # slice the one page on device; only page-sized data
                    # crosses to the host for encoding
                    page = np.asarray(pool[u, pid] if stacked else pool[pid])
                    cp = codec.encode_page(page)
                    if cp.stride > self.stride_budget:
                        return cache, False     # incompressible: stay raw
                    enc.append((section, name, stacked, kn, u, cp))

        cslot = self._cold_free[self.shard_of_slot(slot)].pop()
        total = 0
        cache = dict(cache)
        for section, name, stacked, kn, u, cp in enc:
            pay = np.zeros((self.stride_budget, LANES), np.uint8)
            pay[: cp.stride] = cp.payload
            leafd = dict(cache[section][name])
            idx = (u, cslot) if stacked else (cslot,)
            leafd[f"{kn}_cpl"] = leafd[f"{kn}_cpl"].at[idx].set(pay)
            leafd[f"{kn}_csm"] = leafd[f"{kn}_csm"].at[idx].set(cp.signmant)
            leafd[f"{kn}_ctab"] = leafd[f"{kn}_ctab"].at[idx].set(cp.tables())
            leafd[f"{kn}_cperm"] = leafd[f"{kn}_cperm"].at[idx].set(cp.perm)
            cache[section] = {**cache[section], name: leafd}
            total += cp.nbytes()

        entry = self.n_pages + cslot
        self._slot_pages[slot][p] = entry
        cache["page_table"] = cache["page_table"].at[slot, p].set(entry)
        self._decref(pid)
        self._cold_bytes[cslot] = total
        return cache, True

    # -- accounting --------------------------------------------------------

    def stats(self) -> dict:
        """Live memory accounting (bytes; 'raw_equiv' = same pages kept
        uncompressed, 'monolithic' = the replaced (B, max_len) cache).

        ``pages_in_use_per_shard`` counts raw+cold pages held by each batch
        shard's slots — the load-balance signal for sharded serving."""
        # physical accounting: with prefix sharing a pid can appear in
        # several slots' page lists (and in the index with no slot at
        # all) but occupies device memory exactly once
        raw_phys = {e for pages in self._slot_pages.values()
                    for e in pages if GARBAGE_PAGE < e < self.n_pages}
        prefix_resident = prefix_only = 0
        if self.prefix is not None:
            for e in self.prefix.entries():
                if e > 0:
                    prefix_resident += 1
                    if self._ref.get(e, 0) == 1:
                        prefix_only += 1
                    raw_phys.add(e)
        raw = len(raw_phys)
        cold = len(self._cold_bytes)
        swapped = sum(1 for pages in self._slot_pages.values()
                      for e in pages if e < 0)
        per_shard = [0] * self.n_shards
        for slot, pages in self._slot_pages.items():
            per_shard[self.shard_of_slot(slot)] += sum(
                1 for e in pages if e > GARBAGE_PAGE)
        page_bytes = (self.n_attn_layers * 2 * self.page_elems
                      * self.dtype.itemsize)
        cold_uniform = self.n_attn_layers * 2 * (
            self.stride_budget * LANES + self.sm_nbytes
            + 4 * (3 * self.max_code_len + self.n_sym))
        out = {
            "page_size": self.page_size,
            "n_shards": self.n_shards,
            "pages_in_use_per_shard": per_shard,
            "free_pages_per_shard": self.free_pages_per_shard,
            "pages_in_use": raw,
            "cold_pages_in_use": cold,
            "swapped_pages": swapped,
            "page_bytes": page_bytes,
            "raw_bytes_in_use": raw * page_bytes,
            "cold_bytes_ragged": sum(self._cold_bytes.values()),
            "cold_bytes_uniform": cold * cold_uniform,
            "cache_bytes_paged": raw * page_bytes
            + sum(self._cold_bytes.values()),
            "cache_bytes_raw_equiv": (raw + cold) * page_bytes,
            "monolithic_bytes": self.max_batch * self.pages_per_slot
            * page_bytes,
        }
        if self.prefix is not None:
            out.update({
                "prefix_index_blocks": len(self.prefix),
                "prefix_resident_blocks": prefix_resident,
                "prefix_reclaimable_pages": prefix_only,
                "prefix_shared_pages": self.n_shared_pages(),
                "prefix_retired_total": self.n_prefix_retired,
                "prefix_dropped_total": self.n_prefix_dropped,
                "prefix_cow_splits_total": self.n_cow_splits,
            })
        if self.swap is not None:
            out.update(self.swap.stats())
        return out
