"""Paged KV cache: fixed-size pages, per-slot page tables, free-list alloc.

Replaces the serving engine's monolithic ``(max_batch, max_len)`` cache.
Every batch slot owns a list of fixed-size pages (``page_size`` token
positions x all KV heads); a shared ``(max_batch, pages_per_slot)`` page
table maps logical page index -> physical page id, identically for every
attention layer (one allocation decision serves the whole stack, as in
vLLM).  Slot reuse stops over-reserving: a short request only ever holds
the pages it wrote, and the engine reports pages-in-use, not worst case.

Physical id space:
  * id 0 is the **garbage page** — inactive slots' table rows point at it
    so the batched decode step can scatter/gather unconditionally;
  * ids ``1 .. n_pages-1`` are raw pool pages;
  * ids ``>= n_pages`` address the **cold pool**: pages that filled up are
    entropy-coded by ``kvcache.codec`` (lossless, exponent plane) and live
    compressed; decode-on-use happens inside the same jitted step, exactly
    like ECF8 weights.  A page whose coded stream would exceed the uniform
    stride budget stays raw (rare: adversarial exponent content).

Mesh sharding (``n_shards > 1``): the pool's page dim and the page table's
batch dim shard over the mesh's batch axes (``runtime.sharding
.batch_axes``).  Batch shard ``k`` owns slots ``[k*B/n, (k+1)*B/n)``, raw
page ids ``[k*n_pages/n, (k+1)*n_pages/n)`` and the matching cold-slot
range, each with its own free list — so every slot's history is entirely
local to its shard and the sharded decode step never gathers pages across
devices (``models.decode_sharded.paged_decode_attention_sharded``).

In-graph ops (``page_write`` / ``page_gather``) are pure functions used by
``models.model``'s decode attention; the ``PagedKVCache`` class is the
host-side controller driven by ``serving.engine`` across the request
lifecycle (admit -> ensure -> compress cold -> release).
"""
from __future__ import annotations

import warnings

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from . import codec
from .codec import LANES

GARBAGE_PAGE = 0
PAGED_KINDS = ("attn", "nope")   # "local" keeps its ring, recurrents a state


class OutOfPages(RuntimeError):
    """Raised when the raw pool cannot cover a request's next page."""


# --------------------------------------------------------------------------
# in-graph ops (called from models.model inside the jitted decode step)
# --------------------------------------------------------------------------

def page_write(pool, page_table, cur_len, kv):
    """Scatter one new token's K (or V) into each slot's tail page.

    pool: (n_pool, n_kv, ps, hd); page_table: (B, P) int32 page ids;
    cur_len: (B,) write positions; kv: (B, n_kv, 1, hd).

    Tail pages are raw by construction (a page is only compressed once
    full), so the scatter targets the raw pool; out-of-range ids are
    dropped (``mode="drop"``) — which also makes this the per-shard write
    under a mesh: the sharded caller translates global ids to local ones
    and parks non-local entries out of range (``decode_sharded.
    paged_decode_attention_sharded``)."""
    ps = pool.shape[2]
    P = page_table.shape[1]
    p_idx = jnp.clip(cur_len // ps, 0, P - 1)
    off = cur_len % ps
    pids = jnp.take_along_axis(page_table, p_idx[:, None], axis=1)[:, 0]
    return pool.at[pids, :, off, :].set(
        kv[:, :, 0, :].astype(pool.dtype), mode="drop")


def cold_leaves(cache: dict, kn: str):
    """The compressed-pool leaves for ``kn`` in {'k','v'}, or None.

    Returns (payload (n_cold, stride, LANES) u8, signmant (n_cold, sm) u8,
    tables (n_cold, 3, max_len) i32, perm (n_cold, n_sym) i32) — the
    argument order of ``codec.decode_pages_jnp``.  See docs/FORMATS.md §3
    for the leaf layout."""
    if f"{kn}_cpl" not in cache:
        return None
    return (cache[f"{kn}_cpl"], cache[f"{kn}_csm"],
            cache[f"{kn}_ctab"], cache[f"{kn}_cperm"])


_COLD_SUFFIXES = ("_cpl", "_csm", "_ctab", "_cperm")


def strip_cold(cache: dict):
    """Drop the cold-pool leaves from a paged cache -> (stripped, stash).

    While no page is cold, decoding the (empty) cold pool in-graph every
    step is pure waste; the engine strips these leaves so the decode step
    traces a no-cold variant, and restores them afterwards.  Costs one
    extra jit trace the first time a page actually goes cold."""
    stash = {}
    new = dict(cache)
    for section in ("units", "tail"):
        sec = dict(cache.get(section, {}))
        for name, leafd in sec.items():
            if not isinstance(leafd, dict) or "k_cpl" not in leafd:
                continue
            stash[(section, name)] = {
                k: v for k, v in leafd.items() if k.endswith(_COLD_SUFFIXES)}
            sec[name] = {k: v for k, v in leafd.items()
                         if not k.endswith(_COLD_SUFFIXES)}
        if sec:
            new[section] = sec
    return new, stash


def restore_cold(cache: dict, stash: dict):
    """Inverse of :func:`strip_cold` (cold leaves are read-only in-graph)."""
    new = dict(cache)
    for (section, name), cold in stash.items():
        sec = dict(new[section])
        sec[name] = {**sec[name], **cold}
        new[section] = sec
    return new


def page_gather(pool, page_table, cpool=None):
    """Gather each slot's pages into a contiguous KV history.

    pool: (n_pool, n_kv, ps, hd); page_table: (B, P) ids into the
    *virtual* pool; cpool: optional :func:`cold_leaves` tuple.  Cold pages
    (ids >= n_pool) are entropy-decoded in-graph and appended to the raw
    pool as a virtual suffix before the gather; ids are clipped, so
    garbage rows gather page 0 (their positions are masked by ``kv_len``
    downstream).  Returns (B, n_kv, P * ps, hd)."""
    n_kv, ps, hd = pool.shape[1:]
    virtual = pool
    if cpool is not None:
        payload, signmant, tables, perm = cpool
        dec = codec.decode_pages_jnp(
            payload, signmant, tables, perm, n_elem=n_kv * ps * hd,
            dtype_name=str(pool.dtype))
        virtual = jnp.concatenate(
            [pool, dec.reshape(-1, n_kv, ps, hd)], axis=0)
    ids = jnp.clip(page_table, 0, virtual.shape[0] - 1)
    gath = jnp.take(virtual, ids, axis=0)          # (B, P, n_kv, ps, hd)
    B, P = page_table.shape
    return gath.transpose(0, 2, 1, 3, 4).reshape(B, n_kv, P * ps, hd)


# --------------------------------------------------------------------------
# host-side controller
# --------------------------------------------------------------------------

class PagedKVCache:
    """Allocator + lifecycle manager for the paged, compressible cache."""

    def __init__(self, cfg: ArchConfig, max_batch: int, max_len: int, *,
                 dtype, page_size: int = 16, n_pages: int | None = None,
                 compress_cold: bool = False, n_cold_slots: int | None = None,
                 budget_bits: int | None = None, n_shards: int = 1):
        """Args:
          cfg: architecture config (layer kinds decide which groups page).
          max_batch/max_len: static engine batch shape; every slot can hold
            at most ``max_len`` tokens (``pages_per_slot`` pages).
          dtype: cache storage dtype (fp8/bf16/f32 — must have a page-codec
            plane spec when ``compress_cold``).
          page_size: token positions per page; rounded down to a divisor of
            ``max_len``.
          n_pages: raw pool size (id 0 is the garbage page); defaults to
            the worst case (every slot full) plus the garbage page, and is
            rounded up to a multiple of ``n_shards``.
          compress_cold: entropy-code full pages into the cold pool.
          n_cold_slots: cold pool size (default: worst case minus one tail
            page per slot), rounded up to a multiple of ``n_shards``.
          budget_bits: uniform cold-payload budget in bits/symbol (default:
            the raw exponent width — never worse than the raw plane).
          n_shards: batch-shard count of the mesh the cache will live on
            (``runtime.sharding.batch_axes`` sizes multiplied); slots,
            raw pages and cold slots are partitioned contiguously into
            ``n_shards`` ranges with one free list each.  ``max_batch``
            must be divisible by it.
        """
        self.cfg = cfg
        self.max_batch, self.max_len = max_batch, max_len
        self.dtype = jnp.dtype(dtype)
        self.dtype_name = str(self.dtype)
        if n_shards < 1 or max_batch % n_shards:
            raise ValueError(
                f"max_batch={max_batch} not divisible by n_shards={n_shards}")
        self.n_shards = n_shards
        self.slots_per_shard = max_batch // n_shards
        ps = max(1, min(page_size, max_len))
        while max_len % ps:
            ps -= 1
        if ps != page_size:
            warnings.warn(
                f"page_size={page_size} does not divide max_len={max_len}; "
                f"using {ps} (a tiny page inflates the page table and the "
                f"per-token scatter/gather)", stacklevel=2)
        self.page_size = ps
        self.pages_per_slot = max_len // ps
        n_pages = n_pages or (
            n_shards + max_batch * self.pages_per_slot)
        # each shard owns a contiguous, equal range of page ids
        self.n_pages = -(-n_pages // n_shards) * n_shards
        self.pages_per_shard = self.n_pages // n_shards

        unit = cfg.unit
        self.n_units = cfg.n_layers // unit
        self.n_tail = cfg.n_layers - self.n_units * unit
        self.n_attn_layers = sum(
            1 for i in range(cfg.n_layers) if cfg.layer_kind(i) in PAGED_KINDS)
        self.has_attn = self.n_attn_layers > 0

        self.page_elems = cfg.n_kv_heads * ps * cfg.hd
        exp_bits, self.max_code_len, _ = codec.plane_spec(self.dtype_name)
        self.n_sym = 1 << exp_bits
        self.S = codec.sym_per_lane(self.page_elems)
        self.sm_nbytes = codec.sm_bytes(self.dtype_name, self.page_elems)
        self.compress = bool(compress_cold) and self.has_attn
        if budget_bits is None:
            budget_bits = exp_bits  # never worse than the raw exponent plane
        self.stride_budget = max(codec.MIN_STRIDE,
                                 -(-self.S * budget_bits // 8))
        default_cold = max_batch * max(self.pages_per_slot - 1, 1)
        n_cold = (n_cold_slots if n_cold_slots is not None
                  else default_cold) if self.compress else 0
        self.n_cold = -(-n_cold // n_shards) * n_shards if n_cold else 0
        self.cold_per_shard = self.n_cold // n_shards

        # per-shard free lists (descending, so pop() hands out low ids
        # first); shard 0's range excludes the garbage page id 0
        pps = self.pages_per_shard
        self._free = [list(range((k + 1) * pps - 1, max(k * pps, 1) - 1, -1))
                      for k in range(n_shards)]
        cps = self.cold_per_shard
        self._cold_free = [list(range((k + 1) * cps - 1, k * cps - 1, -1))
                           for k in range(n_shards)]
        self._slot_pages: dict[int, list[int]] = {}
        self._skip: dict[int, set[int]] = {}
        self._cold_bytes: dict[int, int] = {}

    # -- structure ---------------------------------------------------------

    def _groups(self):
        """Yield (section, name, kind, stacked) for every layer group."""
        unit = self.cfg.unit
        for j in range(unit):
            yield "units", f"pos{j}", self.cfg.pattern[j], True
        for t in range(self.n_tail):
            kind = self.cfg.layer_kind(self.n_units * unit + t)
            yield "tail", f"layer{t}", kind, False

    def _pool_leaves(self, stacked: bool) -> dict:
        cfg, ps = self.cfg, self.page_size
        lead = (self.n_units,) if stacked else ()
        pool = lead + (self.n_pages, cfg.n_kv_heads, ps, cfg.hd)
        d = {"k_pool": jnp.zeros(pool, self.dtype),
             "v_pool": jnp.zeros(pool, self.dtype)}
        if self.compress:
            for kn in ("k", "v"):
                d[f"{kn}_cpl"] = jnp.zeros(
                    lead + (self.n_cold, self.stride_budget, LANES),
                    jnp.uint8)
                d[f"{kn}_csm"] = jnp.zeros(
                    lead + (self.n_cold, self.sm_nbytes), jnp.uint8)
                d[f"{kn}_ctab"] = jnp.zeros(
                    lead + (self.n_cold, 3, self.max_code_len), jnp.int32)
                d[f"{kn}_cperm"] = jnp.zeros(
                    lead + (self.n_cold, self.n_sym), jnp.int32)
        return d

    def init_cache(self) -> dict:
        """The paged cache pytree: monolithic layout with attn/nope leaves
        replaced by page pools, plus the shared page table."""
        from repro.models import model as M
        cache = M.init_cache(self.cfg, self.max_batch, self.max_len,
                             dtype=self.dtype, per_slot=True)
        for section, name, kind, stacked in self._groups():
            if kind in PAGED_KINDS:
                cache[section] = {**cache[section],
                                  name: self._pool_leaves(stacked)}
        cache["page_table"] = jnp.zeros(
            (self.max_batch, self.pages_per_slot), jnp.int32)
        return cache

    # -- allocator ---------------------------------------------------------

    def shard_of_slot(self, slot: int) -> int:
        """Batch shard owning ``slot`` (contiguous slot ranges per shard)."""
        return slot // self.slots_per_shard

    @property
    def free_pages(self) -> int:
        """Total free raw pages across all shards."""
        return sum(len(f) for f in self._free)

    @property
    def free_pages_per_shard(self) -> list[int]:
        return [len(f) for f in self._free]

    @property
    def has_cold(self) -> bool:
        return bool(self._cold_bytes)

    def pages_needed(self, prompt_len: int) -> int:
        """Pages to cover the prompt and the first decode write."""
        return min(prompt_len // self.page_size + 1, self.pages_per_slot)

    def can_admit(self, prompt_len: int, slot: int | None = None) -> bool:
        """Whether ``slot``'s shard (any shard when ``slot`` is None) has
        enough free pages for a ``prompt_len``-token prompt."""
        need = self.pages_needed(prompt_len)
        if slot is None:
            return any(len(f) >= need for f in self._free)
        return len(self._free[self.shard_of_slot(slot)]) >= need

    # -- request lifecycle -------------------------------------------------

    def admit(self, cache: dict, slot: int, frag: dict, prompt_len: int):
        """Allocate a fresh slot's pages (from its shard's free list) and
        splice the prefill fragment into the pool."""
        need = self.pages_needed(prompt_len)
        sh = self.shard_of_slot(slot)
        free = self._free[sh]
        if len(free) < need:
            raise OutOfPages(f"shard {sh}: slot {slot} needs {need} pages, "
                             f"{len(free)} free")
        pids = [free.pop() for _ in range(need)]
        self._slot_pages[slot] = pids
        self._skip[slot] = set()

        row = np.zeros(self.pages_per_slot, np.int32)
        row[:need] = pids
        cache = dict(cache)
        cache["page_table"] = cache["page_table"].at[slot].set(
            jnp.asarray(row))
        cache["cur_len"] = cache["cur_len"].at[slot].set(prompt_len)
        ids = jnp.asarray(pids, jnp.int32)

        for section, name, kind, stacked in self._groups():
            dst, src = cache[section][name], frag[section][name]
            if kind in PAGED_KINDS:
                new = dict(dst)
                for kn in ("k", "v"):
                    pages = self._frag_pages(src[kn], stacked)
                    pool = dst[f"{kn}_pool"]
                    if stacked:
                        new[f"{kn}_pool"] = pool.at[:, ids].set(
                            pages[:, :need].astype(pool.dtype))
                    else:
                        new[f"{kn}_pool"] = pool.at[ids].set(
                            pages[:need].astype(pool.dtype))
            else:
                axis = 1 if stacked else 0
                new = jax.tree_util.tree_map(
                    lambda full, fr: jax.lax.dynamic_update_slice_in_dim(
                        full, fr.astype(full.dtype), slot, axis=axis),
                    dst, src)
            cache[section] = {**cache[section], name: new}
        return cache

    def _frag_pages(self, x, stacked: bool):
        """Prefill fragment (.., 1, n_kv, max_len, hd) -> page-major view."""
        cfg, ps, P = self.cfg, self.page_size, self.pages_per_slot
        if stacked:
            x = x.reshape(self.n_units, cfg.n_kv_heads, P, ps, cfg.hd)
            return x.transpose(0, 2, 1, 3, 4)       # (U, P, n_kv, ps, hd)
        x = x.reshape(cfg.n_kv_heads, P, ps, cfg.hd)
        return x.transpose(1, 0, 2, 3)              # (P, n_kv, ps, hd)

    def ensure(self, cache: dict, slot: int, pos: int):
        """Grow the slot's page list to cover a write at ``pos`` (allocating
        from the slot's shard)."""
        pages = self._slot_pages.get(slot)
        if pages is None:
            return cache
        sh = self.shard_of_slot(slot)
        p = min(pos // self.page_size, self.pages_per_slot - 1)
        while len(pages) <= p:
            if not self._free[sh]:
                raise OutOfPages(
                    f"shard {sh}: slot {slot} needs page {len(pages)}")
            pid = self._free[sh].pop()
            cache = dict(cache)
            cache["page_table"] = cache["page_table"].at[
                slot, len(pages)].set(pid)
            pages.append(pid)
        return cache

    def release(self, cache: dict, slot: int):
        """Free a finished slot's raw pages and cold-pool entries back to
        the free lists of the shards that own the ids."""
        for e in self._slot_pages.pop(slot, []):
            if e >= self.n_pages:
                cs = e - self.n_pages
                self._cold_free[cs // max(self.cold_per_shard, 1)].append(cs)
                self._cold_bytes.pop(cs, None)
            elif e != GARBAGE_PAGE:
                self._free[e // self.pages_per_shard].append(e)
        self._skip.pop(slot, None)
        cache = dict(cache)
        cache["page_table"] = cache["page_table"].at[slot].set(
            jnp.zeros(self.pages_per_slot, jnp.int32))
        return cache

    # -- cold compression --------------------------------------------------

    def compress_cold_pages(self, cache: dict, slot: int, pos: int):
        """Entropy-code the slot's full (non-tail) pages into the cold pool.

        ``pos`` is the next write position; pages strictly below
        ``pos // page_size`` are complete and never written again."""
        if not self.compress or slot not in self._slot_pages:
            return cache
        sh = self.shard_of_slot(slot)
        full = min(pos // self.page_size, len(self._slot_pages[slot]))
        for p in range(full):
            if (self._slot_pages[slot][p] >= self.n_pages
                    or p in self._skip[slot]):
                continue
            if not self._cold_free[sh]:
                return cache
            cache, ok = self._compress_one(cache, slot, p)
            if not ok:
                self._skip[slot].add(p)
        return cache

    def _compress_one(self, cache: dict, slot: int, p: int):
        pid = self._slot_pages[slot][p]
        enc = []                    # (section, name, stacked, kn, u, page)
        for section, name, kind, stacked in self._groups():
            if kind not in PAGED_KINDS:
                continue
            leafd = cache[section][name]
            for kn in ("k", "v"):
                pool = leafd[f"{kn}_pool"]
                units = range(self.n_units) if stacked else (None,)
                for u in units:
                    # slice the one page on device; only page-sized data
                    # crosses to the host for encoding
                    page = np.asarray(pool[u, pid] if stacked else pool[pid])
                    cp = codec.encode_page(page)
                    if cp.stride > self.stride_budget:
                        return cache, False     # incompressible: stay raw
                    enc.append((section, name, stacked, kn, u, cp))

        cslot = self._cold_free[self.shard_of_slot(slot)].pop()
        total = 0
        cache = dict(cache)
        for section, name, stacked, kn, u, cp in enc:
            pay = np.zeros((self.stride_budget, LANES), np.uint8)
            pay[: cp.stride] = cp.payload
            leafd = dict(cache[section][name])
            idx = (u, cslot) if stacked else (cslot,)
            leafd[f"{kn}_cpl"] = leafd[f"{kn}_cpl"].at[idx].set(pay)
            leafd[f"{kn}_csm"] = leafd[f"{kn}_csm"].at[idx].set(cp.signmant)
            leafd[f"{kn}_ctab"] = leafd[f"{kn}_ctab"].at[idx].set(cp.tables())
            leafd[f"{kn}_cperm"] = leafd[f"{kn}_cperm"].at[idx].set(cp.perm)
            cache[section] = {**cache[section], name: leafd}
            total += cp.nbytes()

        entry = self.n_pages + cslot
        self._slot_pages[slot][p] = entry
        cache["page_table"] = cache["page_table"].at[slot, p].set(entry)
        self._free[pid // self.pages_per_shard].append(pid)
        self._cold_bytes[cslot] = total
        return cache, True

    # -- accounting --------------------------------------------------------

    def stats(self) -> dict:
        """Live memory accounting (bytes; 'raw_equiv' = same pages kept
        uncompressed, 'monolithic' = the replaced (B, max_len) cache).

        ``pages_in_use_per_shard`` counts raw+cold pages held by each batch
        shard's slots — the load-balance signal for sharded serving."""
        raw = sum(1 for pages in self._slot_pages.values()
                  for e in pages if GARBAGE_PAGE < e < self.n_pages)
        cold = len(self._cold_bytes)
        per_shard = [0] * self.n_shards
        for slot, pages in self._slot_pages.items():
            per_shard[self.shard_of_slot(slot)] += sum(
                1 for e in pages if e != GARBAGE_PAGE)
        page_bytes = (self.n_attn_layers * 2 * self.page_elems
                      * self.dtype.itemsize)
        cold_uniform = self.n_attn_layers * 2 * (
            self.stride_budget * LANES + self.sm_nbytes
            + 4 * (3 * self.max_code_len + self.n_sym))
        return {
            "page_size": self.page_size,
            "n_shards": self.n_shards,
            "pages_in_use_per_shard": per_shard,
            "free_pages_per_shard": self.free_pages_per_shard,
            "pages_in_use": raw,
            "cold_pages_in_use": cold,
            "page_bytes": page_bytes,
            "raw_bytes_in_use": raw * page_bytes,
            "cold_bytes_ragged": sum(self._cold_bytes.values()),
            "cold_bytes_uniform": cold * cold_uniform,
            "cache_bytes_paged": raw * page_bytes
            + sum(self._cold_bytes.values()),
            "cache_bytes_raw_equiv": (raw + cold) * page_bytes,
            "monolithic_bytes": self.max_batch * self.pages_per_slot
            * page_bytes,
        }
