"""Host-side swap tier for the paged KV cache: entropy-coded page store.

The paper's exponent-concentration result makes cold pages ~27% smaller
*and bit-exact*, which turns host memory into a second cache tier: a
compressed page can leave the device pool entirely and be restored later
with zero output deviation.  ``SwapStore`` is that tier — a host dict of
``SwappedPage`` containers keyed by an opaque swap id, with per-shard
byte accounting (the paged allocator partitions device ids per batch
shard; swapped pages keep their shard affinity so a faulting slot always
restores into its own shard's free lists) and cumulative traffic
counters the serving monitor reports.

Lifecycle (driven by ``paged.PagedKVCache.evict`` / ``fault``):

  hot (raw pool page)  --evict-->  swapped: the page is sliced off the
      device, entropy-coded by ``codec.encode_page`` (one ``SwapEntry``
      per layer-group x unit x K/V sub-page) and stored here ragged —
      unlike the device cold pool there is no uniform stride budget, so
      even adversarial, incompressible pages swap (they just cost more
      bytes).
  cold (device cold pool)  --evict-->  swapped: the page is *already*
      entropy-coded on device; eviction is a plain device->host copy of
      its four container leaves (payload/signmant/tables/perm) — this is
      why victim selection is cold-first.
  swapped  --fault-->  resident: raw-swapped pages batch-decode through
      the Pallas page-decode path (``kernels.decode_pages``) into fresh
      raw pool pages; cold-swapped pages reinstall their coded container
      into a fresh cold slot without ever being decoded.

Container layout: see docs/FORMATS.md §4 (doctest-covered).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


class SwapExhausted(RuntimeError):
    """Raised when a put would exceed the store's ``capacity_bytes``."""


@dataclass
class SwapEntry:
    """One entropy-coded sub-page (one layer group x unit x K-or-V).

    ``payload`` is ragged — ``(stride, 128)`` with the page's own stride,
    zero-padded only to the 4-byte decode-window minimum; ``tables`` is
    the ``(3, L)`` canonical-decode stack and ``perm`` the canonical
    symbol order, exactly as produced by ``codec.CompressedPage``."""

    section: str            # "units" | "tail"
    name: str               # "pos0" / "layer0" / ...
    stacked: bool           # True -> leaf carries a leading unit dim
    kn: str                 # "k" | "v"
    u: int | None           # unit index for stacked leaves
    payload: np.ndarray     # (stride, LANES) uint8
    signmant: np.ndarray    # raw sign+mantissa plane, uint8
    tables: np.ndarray      # (3, L) int32
    perm: np.ndarray        # (n_sym,) int32


@dataclass
class SwappedPage:
    """All sub-pages of one physical cache page, plus restore metadata.

    ``was_cold`` records which tier the page left from: cold pages
    reinstall into the device cold pool verbatim (their payloads already
    fit the uniform stride budget); raw pages decode back into the raw
    pool.  ``nbytes`` is the ragged compressed size (payload + sign/
    mantissa + serialized codebook per sub-page) used for capacity
    accounting."""

    entries: list = field(default_factory=list)
    was_cold: bool = False
    nbytes: int = 0


class SwapStore:
    """Host store of swapped pages with capacity + traffic accounting.

    ``capacity_bytes``: hard ceiling on resident swapped bytes (``None``
    = unbounded); a put over the ceiling raises :class:`SwapExhausted`
    and the caller falls back to ``OutOfPages``.  ``n_shards`` sizes the
    per-shard byte ledgers (mesh serving keeps one device free list per
    batch shard; swap keeps the matching ledger so load imbalance is
    visible in ``stats()``).

    Pages are **pinned** by default: they belong to a live (possibly
    preempted) request and are never dropped by the store.  ``put(...,
    pinned=False)`` stores an evictable page instead — the retired
    shared-prefix cache of ``paged.PagedKVCache._reclaim_prefix``.  Under
    capacity pressure the store silently evicts unpinned pages in LRU
    order (oldest retirement first; a fault + re-retire refreshes
    recency) to make room; only when no unpinned page is left does a
    pinned put raise :class:`SwapExhausted` (an unpinned put returns
    ``None`` instead — dropping the prefix is always legal, the caller
    just forgets the index entry).  The paged allocator validates prefix
    keys with :meth:`contains` at match time, so silent eviction needs
    no callback."""

    def __init__(self, capacity_bytes: int | None = None, n_shards: int = 1):
        self.capacity_bytes = capacity_bytes
        self.n_shards = n_shards
        self._pages: dict[int, SwappedPage] = {}
        self._shard_of: dict[int, int] = {}
        self._unpinned: dict[int, None] = {}    # ordered set: LRU order
        self.n_prefix_evicted = 0               # unpinned pages dropped
        self._next_key = 0
        self.bytes_used = 0
        self.bytes_used_per_shard = [0] * n_shards
        # cumulative traffic (monitor counters; never reset)
        self.swap_out_bytes = 0
        self.swap_in_bytes = 0
        self.n_swap_out = 0
        self.n_swap_in = 0
        self._registry = None

    def __len__(self) -> int:
        return len(self._pages)

    def attach_registry(self, registry) -> None:
        """Publish store *levels* into a telemetry registry as gauges
        (``kvcache_swap_bytes_used`` / ``kvcache_swap_pages``), updated
        on every put/pop/discard.  Levels only: cumulative traffic flows
        through ``stats()`` -> ``kvstat_*`` forwarding, because the
        engine rolls the attribute counters back on an aborted eviction
        and a monotone registry counter could not follow."""
        self._registry = registry
        self.sync_registry()

    def sync_registry(self) -> None:
        if self._registry is None:
            return
        self._registry.gauge("kvcache_swap_bytes_used").set(self.bytes_used)
        self._registry.gauge("kvcache_swap_pages").set(len(self._pages))

    def put(self, page: SwappedPage, shard: int = 0,
            pinned: bool = True) -> int | None:
        """Store a swapped page; returns its opaque swap key.

        Over capacity, unpinned (prefix-cache) pages are evicted LRU-
        first to make room; if the page still does not fit, a pinned put
        raises :class:`SwapExhausted` and an unpinned put returns
        ``None`` (the page is not stored)."""
        if self.capacity_bytes is not None:
            while (self.bytes_used + page.nbytes > self.capacity_bytes
                   and self._unpinned):
                victim = next(iter(self._unpinned))
                self._evict_unpinned(victim)
            if self.bytes_used + page.nbytes > self.capacity_bytes:
                if not pinned:
                    return None
                raise SwapExhausted(
                    f"swap store full: {self.bytes_used}B used + "
                    f"{page.nbytes}B > capacity {self.capacity_bytes}B")
        key = self._next_key
        self._next_key += 1
        self._pages[key] = page
        self._shard_of[key] = shard
        if not pinned:
            self._unpinned[key] = None
        self.bytes_used += page.nbytes
        self.bytes_used_per_shard[shard] += page.nbytes
        self.swap_out_bytes += page.nbytes
        self.n_swap_out += 1
        self.sync_registry()
        return key

    def _evict_unpinned(self, key: int) -> None:
        """Silently drop an unpinned page (capacity pressure — the data
        is a cache of a reproducible prefix, not request state)."""
        page = self._pages.pop(key)
        shard = self._shard_of.pop(key)
        self._unpinned.pop(key, None)
        self.bytes_used -= page.nbytes
        self.bytes_used_per_shard[shard] -= page.nbytes
        self.n_prefix_evicted += 1
        self.sync_registry()

    def contains(self, key: int) -> bool:
        """Whether ``key`` is still resident (an unpinned page may have
        been evicted since it was stored)."""
        return key in self._pages

    def peek(self, key: int) -> SwappedPage:
        """Read without removing (capacity planning before a fault)."""
        return self._pages[key]

    def pop(self, key: int) -> SwappedPage:
        """Remove and return a page on fault (counts swap-in traffic)."""
        page = self._pages.pop(key)
        shard = self._shard_of.pop(key)
        self._unpinned.pop(key, None)
        self.bytes_used -= page.nbytes
        self.bytes_used_per_shard[shard] -= page.nbytes
        self.swap_in_bytes += page.nbytes
        self.n_swap_in += 1
        self.sync_registry()
        return page

    def discard(self, key: int) -> None:
        """Drop a page whose request finished while preempted (its data
        will never be read again — not swap-in traffic)."""
        page = self._pages.pop(key, None)
        if page is None:
            return
        shard = self._shard_of.pop(key)
        self._unpinned.pop(key, None)
        self.bytes_used -= page.nbytes
        self.bytes_used_per_shard[shard] -= page.nbytes
        self.sync_registry()

    def stats(self) -> dict:
        prefix_bytes = sum(self._pages[k].nbytes for k in self._unpinned)
        return {
            "swap_pages": len(self._pages),
            "swap_prefix_pages": len(self._unpinned),
            "swap_prefix_bytes": prefix_bytes,
            "swap_prefix_evicted_total": self.n_prefix_evicted,
            "swap_bytes_used": self.bytes_used,
            "swap_bytes_per_shard": list(self.bytes_used_per_shard),
            "swap_capacity_bytes": self.capacity_bytes,
            "swap_out_bytes_total": self.swap_out_bytes,
            "swap_in_bytes_total": self.swap_in_bytes,
            "n_swap_out": self.n_swap_out,
            "n_swap_in": self.n_swap_in,
        }
