"""Host-side swap tier for the paged KV cache: entropy-coded page store.

The paper's exponent-concentration result makes cold pages ~27% smaller
*and bit-exact*, which turns host memory into a second cache tier: a
compressed page can leave the device pool entirely and be restored later
with zero output deviation.  ``SwapStore`` is that tier — a host dict of
``SwappedPage`` containers keyed by an opaque swap id, with per-shard
byte accounting (the paged allocator partitions device ids per batch
shard; swapped pages keep their shard affinity so a faulting slot always
restores into its own shard's free lists) and cumulative traffic
counters the serving monitor reports.

Lifecycle (driven by ``paged.PagedKVCache.evict`` / ``fault``):

  hot (raw pool page)  --evict-->  swapped: the page is sliced off the
      device, entropy-coded by ``codec.encode_page`` (one ``SwapEntry``
      per layer-group x unit x K/V sub-page) and stored here ragged —
      unlike the device cold pool there is no uniform stride budget, so
      even adversarial, incompressible pages swap (they just cost more
      bytes).
  cold (device cold pool)  --evict-->  swapped: the page is *already*
      entropy-coded on device; eviction is a plain device->host copy of
      its four container leaves (payload/signmant/tables/perm) — this is
      why victim selection is cold-first.
  swapped  --fault-->  resident: raw-swapped pages batch-decode through
      the Pallas page-decode path (``kernels.decode_pages``) into fresh
      raw pool pages; cold-swapped pages reinstall their coded container
      into a fresh cold slot without ever being decoded.

Container layout: see docs/FORMATS.md §4 (doctest-covered).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


class SwapExhausted(RuntimeError):
    """Raised when a put would exceed the store's ``capacity_bytes``."""


@dataclass
class SwapEntry:
    """One entropy-coded sub-page (one layer group x unit x K-or-V).

    ``payload`` is ragged — ``(stride, 128)`` with the page's own stride,
    zero-padded only to the 4-byte decode-window minimum; ``tables`` is
    the ``(3, L)`` canonical-decode stack and ``perm`` the canonical
    symbol order, exactly as produced by ``codec.CompressedPage``."""

    section: str            # "units" | "tail"
    name: str               # "pos0" / "layer0" / ...
    stacked: bool           # True -> leaf carries a leading unit dim
    kn: str                 # "k" | "v"
    u: int | None           # unit index for stacked leaves
    payload: np.ndarray     # (stride, LANES) uint8
    signmant: np.ndarray    # raw sign+mantissa plane, uint8
    tables: np.ndarray      # (3, L) int32
    perm: np.ndarray        # (n_sym,) int32


@dataclass
class SwappedPage:
    """All sub-pages of one physical cache page, plus restore metadata.

    ``was_cold`` records which tier the page left from: cold pages
    reinstall into the device cold pool verbatim (their payloads already
    fit the uniform stride budget); raw pages decode back into the raw
    pool.  ``nbytes`` is the ragged compressed size (payload + sign/
    mantissa + serialized codebook per sub-page) used for capacity
    accounting."""

    entries: list = field(default_factory=list)
    was_cold: bool = False
    nbytes: int = 0


class SwapStore:
    """Host store of swapped pages with capacity + traffic accounting.

    ``capacity_bytes``: hard ceiling on resident swapped bytes (``None``
    = unbounded); a put over the ceiling raises :class:`SwapExhausted`
    and the caller falls back to ``OutOfPages``.  ``n_shards`` sizes the
    per-shard byte ledgers (mesh serving keeps one device free list per
    batch shard; swap keeps the matching ledger so load imbalance is
    visible in ``stats()``)."""

    def __init__(self, capacity_bytes: int | None = None, n_shards: int = 1):
        self.capacity_bytes = capacity_bytes
        self.n_shards = n_shards
        self._pages: dict[int, SwappedPage] = {}
        self._shard_of: dict[int, int] = {}
        self._next_key = 0
        self.bytes_used = 0
        self.bytes_used_per_shard = [0] * n_shards
        # cumulative traffic (monitor counters; never reset)
        self.swap_out_bytes = 0
        self.swap_in_bytes = 0
        self.n_swap_out = 0
        self.n_swap_in = 0
        self._registry = None

    def __len__(self) -> int:
        return len(self._pages)

    def attach_registry(self, registry) -> None:
        """Publish store *levels* into a telemetry registry as gauges
        (``kvcache_swap_bytes_used`` / ``kvcache_swap_pages``), updated
        on every put/pop/discard.  Levels only: cumulative traffic flows
        through ``stats()`` -> ``kvstat_*`` forwarding, because the
        engine rolls the attribute counters back on an aborted eviction
        and a monotone registry counter could not follow."""
        self._registry = registry
        self.sync_registry()

    def sync_registry(self) -> None:
        if self._registry is None:
            return
        self._registry.gauge("kvcache_swap_bytes_used").set(self.bytes_used)
        self._registry.gauge("kvcache_swap_pages").set(len(self._pages))

    def put(self, page: SwappedPage, shard: int = 0) -> int:
        """Store a swapped page; returns its opaque swap key."""
        if (self.capacity_bytes is not None
                and self.bytes_used + page.nbytes > self.capacity_bytes):
            raise SwapExhausted(
                f"swap store full: {self.bytes_used}B used + {page.nbytes}B "
                f"> capacity {self.capacity_bytes}B")
        key = self._next_key
        self._next_key += 1
        self._pages[key] = page
        self._shard_of[key] = shard
        self.bytes_used += page.nbytes
        self.bytes_used_per_shard[shard] += page.nbytes
        self.swap_out_bytes += page.nbytes
        self.n_swap_out += 1
        self.sync_registry()
        return key

    def peek(self, key: int) -> SwappedPage:
        """Read without removing (capacity planning before a fault)."""
        return self._pages[key]

    def pop(self, key: int) -> SwappedPage:
        """Remove and return a page on fault (counts swap-in traffic)."""
        page = self._pages.pop(key)
        shard = self._shard_of.pop(key)
        self.bytes_used -= page.nbytes
        self.bytes_used_per_shard[shard] -= page.nbytes
        self.swap_in_bytes += page.nbytes
        self.n_swap_in += 1
        self.sync_registry()
        return page

    def discard(self, key: int) -> None:
        """Drop a page whose request finished while preempted (its data
        will never be read again — not swap-in traffic)."""
        page = self._pages.pop(key, None)
        if page is None:
            return
        shard = self._shard_of.pop(key)
        self.bytes_used -= page.nbytes
        self.bytes_used_per_shard[shard] -= page.nbytes
        self.sync_registry()

    def stats(self) -> dict:
        return {
            "swap_pages": len(self._pages),
            "swap_bytes_used": self.bytes_used,
            "swap_bytes_per_shard": list(self.bytes_used_per_shard),
            "swap_capacity_bytes": self.capacity_bytes,
            "swap_out_bytes_total": self.swap_out_bytes,
            "swap_in_bytes_total": self.swap_in_bytes,
            "n_swap_out": self.n_swap_out,
            "n_swap_in": self.n_swap_in,
        }
