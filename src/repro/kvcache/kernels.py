"""Pallas TPU kernel: interleaved Huffman decode of compressed KV pages.

One grid cell decodes one page = 128 interleaved lane streams x
``sym_per_lane`` symbols — the same window-refill idiom as the weight
kernel (``kernels/ecf8_decode.py``) generalized for cache pages:

  * codes may be up to 12 bits (bf16/f32 pages code the full 8-bit
    exponent field), so the peek is ``max_len`` bits and the window
    refills **up to two bytes** per round (vs one for 8-bit codes);
  * decode tables are **per page** (every page carries its own canonical
    codebook) — each grid cell reads its own (1, L) table rows;
  * the kernel emits *canonical symbol indices*; the (up to 256-entry)
    canonical permutation and the sign/mantissa fuse are applied by the
    caller as plain XLA gathers (``codec.assemble_pages_jnp``) — a
    256-way in-register select would cost more than it saves.

VMEM per cell: payload (stride x 128) + output (S x 128 x 4B), both far
inside budget for realistic page sizes (<= 64K elements).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from . import codec
from .codec import LANES


def _decode_page_kernel(limit_ref, first_ref, offset_ref, payload_ref,
                        out_ref, *, sym_per_lane: int, stride: int,
                        max_len: int):
    S = sym_per_lane
    payload = payload_ref[0].astype(jnp.uint32)        # (stride, LANES)

    win = ((payload[0:1, :] << 24) | (payload[1:2, :] << 16)
           | (payload[2:3, :] << 8) | payload[3:4, :])  # (1, LANES)
    byteptr = jnp.full((1, LANES), 4, dtype=jnp.int32)
    bits_valid = jnp.full((1, LANES), 32, dtype=jnp.int32)
    row_iota = jax.lax.broadcasted_iota(jnp.int32, (stride, LANES), 0)

    def round_fn(s, carry):
        win, byteptr, bits_valid = carry
        peek = (win >> (32 - max_len)).astype(jnp.int32)  # (1, LANES)

        length = jnp.zeros((1, LANES), jnp.int32)
        sym_idx = jnp.zeros((1, LANES), jnp.int32)
        found = jnp.zeros((1, LANES), jnp.bool_)
        for l in range(1, max_len + 1):                # unrolled, static
            lim = limit_ref[0, l - 1]
            fl = first_ref[0, l - 1]
            off = offset_ref[0, l - 1]
            cond = jnp.logical_and(peek < lim, jnp.logical_not(found))
            idx_l = off + ((peek - fl) >> (max_len - l))
            length = jnp.where(cond, l, length)
            sym_idx = jnp.where(cond, idx_l, sym_idx)
            found = jnp.logical_or(found, cond)

        pl.store(out_ref, (pl.dslice(0, 1), pl.dslice(s, 1), slice(None)),
                 sym_idx.reshape(1, 1, LANES))

        win = win << length.astype(jnp.uint32)
        bits_valid = bits_valid - length
        for _ in range(2):   # <= 2 refill bytes/round for max_len <= 16
            need = bits_valid <= 24
            safe_ptr = jnp.minimum(byteptr, stride - 1)
            mask = row_iota == safe_ptr                # (stride, LANES)
            nb = jnp.sum(jnp.where(mask, payload, jnp.uint32(0)), axis=0,
                         keepdims=True)                # (1, LANES)
            shift = jnp.maximum(24 - bits_valid, 0).astype(jnp.uint32)
            win = jnp.where(need, win | (nb << shift), win)
            byteptr = byteptr + need.astype(jnp.int32)
            bits_valid = bits_valid + 8 * need.astype(jnp.int32)
        return win, byteptr, bits_valid

    jax.lax.fori_loop(0, S, round_fn, (win, byteptr, bits_valid))


@functools.partial(jax.jit, static_argnames=("n_elem", "interpret"))
def decode_page_indices_pallas(payload, tables, *, n_elem: int,
                               interpret: bool = True):
    """Decode canonical symbol indices for N pages.

    Args:
      payload: (N, stride, LANES) uint8 zero-padded lane streams.
      tables:  (N, 3, L) int32 — lj_limit / first_lj / offset per page.

    Returns (N, S, LANES) int32 canonical indices.
    """
    N, stride, _ = payload.shape
    L = tables.shape[-1]
    S = codec.sym_per_lane(n_elem)
    kernel = functools.partial(_decode_page_kernel, sym_per_lane=S,
                               stride=stride, max_len=L)
    return pl.pallas_call(
        kernel,
        grid=(N,),
        in_specs=[
            pl.BlockSpec((1, L), lambda c: (c, 0)),    # lj_limit
            pl.BlockSpec((1, L), lambda c: (c, 0)),    # first_lj
            pl.BlockSpec((1, L), lambda c: (c, 0)),    # offset
            pl.BlockSpec((1, stride, LANES), lambda c: (c, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, S, LANES), lambda c: (c, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((N, S, LANES), jnp.int32),
        interpret=interpret,
    )(
        tables[:, 0].astype(jnp.int32),
        tables[:, 1].astype(jnp.int32),
        tables[:, 2].astype(jnp.int32),
        payload,
    )


def decode_pages(payload, signmant, tables, perm, *, n_elem: int,
                 dtype_name: str, interpret: bool = True):
    """Full page decode via the Pallas kernel -> (N, n_elem) values.

    Same contract as ``codec.decode_pages_jnp`` (the pure-XLA oracle the
    serving engine uses in-graph); this path routes the entropy decode
    through the TPU kernel and fuses perm + sign/mantissa outside."""
    sym_idx = decode_page_indices_pallas(payload, tables, n_elem=n_elem,
                                         interpret=interpret)
    return codec.finish_pages_jnp(sym_idx, signmant, perm, n_elem=n_elem,
                                  dtype_name=dtype_name)


# (mesh, batch axes, n_elem, dtype, interpret) -> shard_map'ed decode;
# shared across callers so repeated cold-pool decodes reuse one program
_SHARDED_DECODE_CACHE: dict = {}


def decode_pages_sharded(payload, signmant, tables, perm, mesh, *,
                         n_elem: int, dtype_name: str,
                         interpret: bool = True):
    """Decode a cold pool whose page dim shards over the mesh batch axes.

    The serving cache shards cold-pool leaves over the batch axes
    (``runtime.sharding.cache_pspecs``); each shard's Pallas grid covers
    only its local ``N / n_shards`` pages — no page crosses a device to be
    decoded.  Same contract as :func:`decode_pages` otherwise; the page
    dim (and so the output's) must divide by the batch-axes size.
    """
    ba = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    if not ba:
        return decode_pages(payload, signmant, tables, perm, n_elem=n_elem,
                            dtype_name=dtype_name, interpret=interpret)
    b_ax = ba if len(ba) != 1 else ba[0]

    # cache the shard_map'ed callable: a fresh closure per call would
    # re-trace (and, eagerly, re-compile) the whole sharded decode every
    # time — the repeat-compile hazard the jit-cache-discipline lint flags
    key = (mesh, b_ax, n_elem, dtype_name, interpret)
    fn = _SHARDED_DECODE_CACHE.get(key)
    if fn is None:
        def body(pay, sm, tab, prm):
            return decode_pages(pay, sm, tab, prm, n_elem=n_elem,
                                dtype_name=dtype_name, interpret=interpret)

        fn = shard_map(
            body, mesh=mesh,
            in_specs=(P(b_ax, None, None), P(b_ax, None),
                      P(b_ax, None, None), P(b_ax, None)),
            out_specs=P(b_ax, None),
            check_rep=False,
        )
        _SHARDED_DECODE_CACHE[key] = fn
    return fn(payload, signmant, tables, perm)
