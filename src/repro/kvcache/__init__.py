"""Paged, ECF8-compressed KV-cache subsystem.

``paged``   — fixed-size pages, per-slot page tables, free-list allocator,
              and the in-graph page-gather/write used by decode attention.
``codec``   — lossless exponent-plane entropy codec for cache pages
              (fp8 / bf16 / f32), canonical Huffman per page.
``kernels`` — Pallas TPU decode kernel for compressed pages (+ jnp oracle).
``swap``    — host-side swap tier: entropy-coded pages leave the device
              entirely (hot -> cold -> swapped) and restore bit-exactly
              through the Pallas decode path.
"""
from . import codec, kernels, paged, swap  # noqa: F401
from .paged import OutOfPages, PagedKVCache, PrefixIndex  # noqa: F401
from .swap import SwapExhausted, SwapStore  # noqa: F401
