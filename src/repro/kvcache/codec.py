"""Lossless ECF8 page codec: exponent-plane entropy coding for K/V pages.

The paper's exponent-concentration law is a statement about *trained
tensors*; Heilper & Singer (2025) show the same low-entropy exponent
structure holds for K/V caches, and ZipNN confirms exponent-grouped
entropy coding is the winning layout.  This module extends the repo's
weight container (``core.tpu_format``) from the fp8 4-bit exponent field
to the 8-bit exponent field of bf16/f32 cache pages:

  * each element is split into an **exponent symbol** (4 bits for fp8,
    8 bits for bf16/f32) and a raw **sign+mantissa plane** (packed
    nibbles / 1 byte / 3 bytes per element);
  * the exponent plane is canonical-Huffman coded per page
    (``core.huffman.Codebook``, package-merge length-limited) into 128
    interleaved lane streams — the same TPU-native layout the weight
    decode kernel consumes, so ``kvcache.kernels`` reuses the
    window-refill idiom of ``kernels/ecf8_decode.py``;
  * round-trips are bit-exact for *any* bit content (NaNs included):
    encode/decode only ever touch integer bit views.

Layout per page: payload ``(stride, 128)`` uint8 (byte j of all lanes is
one contiguous row), every lane carries ``ceil(n_elem / 128)`` symbols,
short pages are padded with the page's modal symbol.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import fp8
from repro.core.huffman import Codebook, _concat_aranges

LANES = 128
MIN_STRIDE = 4          # decode window preloads 4 bytes
EXP4_MAX_LEN = 8        # fp8: 16 symbols, single-byte peek
EXP8_MAX_LEN = 12       # bf16/f32: 256 symbols, 12-bit peek (<= 16)

# dtype name -> (exponent bits, sign+mantissa bytes per element * 2)
# sm bytes are stored as numerator/2 so fp8's packed nibble (half a byte
# per element) stays integral.
_PLANES = {
    "float8_e4m3fn": (4, 1),
    "bfloat16": (8, 2),
    "float32": (8, 6),
}


def plane_spec(dtype_name: str) -> tuple[int, int, int]:
    """(exp_bits, max_code_len, sm_halfbytes_per_elem) for a cache dtype."""
    if dtype_name not in _PLANES:
        raise ValueError(f"unsupported page dtype {dtype_name!r}; "
                         f"supported: {sorted(_PLANES)}")
    exp_bits, sm_half = _PLANES[dtype_name]
    max_len = EXP4_MAX_LEN if exp_bits == 4 else EXP8_MAX_LEN
    return exp_bits, max_len, sm_half


def sm_bytes(dtype_name: str, n_elem: int) -> int:
    """Raw sign+mantissa plane bytes for ``n_elem`` elements."""
    _, _, sm_half = plane_spec(dtype_name)
    return (n_elem * sm_half + 1) // 2


def sym_per_lane(n_elem: int) -> int:
    """Symbols each of the 128 lane streams carries for an
    ``n_elem``-element page (``ceil(n_elem / LANES)``; short pages are
    padded to this with the page's modal symbol)."""
    return -(-n_elem // LANES)


# --------------------------------------------------------------------------
# bit-plane split / assemble (host numpy, pure integer ops)
# --------------------------------------------------------------------------

def split_planes(values: np.ndarray) -> tuple[np.ndarray, np.ndarray, str]:
    """Split a page into (exponent symbols, raw sign+mantissa bytes).

    Accepts fp8 / bf16 / f32 arrays (or a raw uint8 fp8 bit view)."""
    values = np.asarray(values)
    name = str(values.dtype)
    if name == "uint8":
        name = "float8_e4m3fn"
    if name == "float8_e4m3fn":
        bits = values.view(np.uint8).reshape(-1)
        exp = fp8.exponent_field(bits, xp=np)
        sm = fp8.pack_nibbles(fp8.signmant_nibble(bits, xp=np), xp=np)
        return exp.astype(np.int64), sm, name
    if name == "bfloat16":
        u = values.view(np.uint16).reshape(-1)
        exp = (u >> 7) & np.uint16(0xFF)
        sm = (((u >> 8) & np.uint16(0x80)) | (u & np.uint16(0x7F)))
        return exp.astype(np.int64), sm.astype(np.uint8), name
    if name == "float32":
        u = values.view(np.uint32).reshape(-1)
        exp = (u >> 23) & np.uint32(0xFF)
        sm24 = ((u >> 8) & np.uint32(0x800000)) | (u & np.uint32(0x7FFFFF))
        smb = np.stack([(sm24 >> 16) & 0xFF, (sm24 >> 8) & 0xFF,
                        sm24 & 0xFF], axis=-1).astype(np.uint8).reshape(-1)
        return exp.astype(np.int64), smb, name
    raise ValueError(f"unsupported page dtype {name!r}")


def assemble_planes(exp: np.ndarray, sm: np.ndarray, dtype_name: str,
                    n_elem: int) -> np.ndarray:
    """Inverse of :func:`split_planes` -> raw bit view (uint8/16/32)."""
    exp = np.asarray(exp, dtype=np.uint32)[:n_elem]
    if dtype_name == "float8_e4m3fn":
        nib = np.asarray(fp8.unpack_nibbles(sm, n_elem, xp=np))
        return fp8.assemble(exp.astype(np.uint8), nib, xp=np)
    if dtype_name == "bfloat16":
        sm = sm.astype(np.uint16)[:n_elem]
        u = ((sm & 0x80) << 8) | (exp.astype(np.uint16) << 7) | (sm & 0x7F)
        return u.astype(np.uint16)
    if dtype_name == "float32":
        b = sm.reshape(-1, 3).astype(np.uint32)[:n_elem]
        sm24 = (b[:, 0] << 16) | (b[:, 1] << 8) | b[:, 2]
        u = ((sm24 & 0x800000) << 8) | (exp << 23) | (sm24 & 0x7FFFFF)
        return u.astype(np.uint32)
    raise ValueError(dtype_name)


_BITVIEW = {"float8_e4m3fn": np.uint8, "bfloat16": np.uint16,
            "float32": np.uint32}


# --------------------------------------------------------------------------
# encode (host)
# --------------------------------------------------------------------------

@dataclass
class CompressedPage:
    """One entropy-coded cache page (host-side numpy arrays)."""

    payload: np.ndarray    # (stride, LANES) uint8 interleaved lane streams
    signmant: np.ndarray   # raw sign+mantissa plane, uint8
    lj_limit: np.ndarray   # (max_len,) int32 canonical decode tables
    first_lj: np.ndarray   # (max_len,) int32
    offset: np.ndarray     # (max_len,) int32
    perm: np.ndarray       # (n_symbols,) int32 canonical-order symbols
    n_elem: int
    n_active: int          # symbols with nonzero frequency
    dtype_name: str
    shape: tuple

    @property
    def stride(self) -> int:
        return self.payload.shape[0]

    def nbytes(self) -> int:
        """True (ragged) compressed bytes, codebook included.

        A canonical codebook serializes as the active-symbol list in
        canonical order (1 byte each) plus a count per code length
        (2 bytes each); the int32 decode tables are derived from that on
        load, they are a decode-speed representation, not payload."""
        header = self.n_active + 2 * len(self.lj_limit)
        return self.payload.nbytes + self.signmant.nbytes + header

    def ratio(self) -> float:
        itemsize = np.dtype(_BITVIEW[self.dtype_name]).itemsize
        return self.nbytes() / max(self.n_elem * itemsize, 1)

    def tables(self) -> np.ndarray:
        """(3, max_len) int32 stack consumed by the decode paths."""
        return np.stack([self.lj_limit, self.first_lj, self.offset])


def encode_page(values: np.ndarray) -> CompressedPage:
    """Compress one page losslessly (exponent plane entropy-coded)."""
    values = np.asarray(values)
    orig_shape = tuple(values.shape)
    exp, sm, dtype_name = split_planes(values)
    n = exp.shape[0]
    if n == 0:
        raise ValueError("empty page")
    exp_bits, max_len, _ = plane_spec(dtype_name)
    n_sym = 1 << exp_bits

    freqs = np.bincount(exp, minlength=n_sym)
    cb = Codebook.from_freqs(freqs, max_len=max_len)

    S = sym_per_lane(n)
    pad_sym = int(np.argmax(freqs))
    exp_p = np.concatenate(
        [exp, np.full(S * LANES - n, pad_sym, dtype=np.int64)])
    payload = _encode_lanes(exp_p.reshape(S, LANES), cb)
    return CompressedPage(
        payload=payload, signmant=sm,
        lj_limit=cb.lj_limit.astype(np.int32),
        first_lj=cb.first_lj.astype(np.int32),
        offset=cb.offset.astype(np.int32),
        perm=cb.sorted_syms.astype(np.int32),
        n_elem=n, n_active=int((freqs > 0).sum()),
        dtype_name=dtype_name, shape=orig_shape,
    )


def _encode_lanes(syms: np.ndarray, cb: Codebook) -> np.ndarray:
    """(S, LANES) symbols -> (stride, LANES) uint8 interleaved payload.

    Element ``i`` maps to lane ``i % LANES``, slot ``i // LANES`` — the
    layout of ``core.tpu_format`` with a single chunk per page."""
    S = syms.shape[0]
    codes_r = cb.codes[syms].T                        # (LANES, S)
    lens_r = cb.lengths[syms].T.astype(np.int64)      # (LANES, S)
    starts = np.cumsum(lens_r, axis=1) - lens_r
    lane_bits = starts[:, -1] + lens_r[:, -1]
    stride = max(int(-(-int(lane_bits.max()) // 8)), MIN_STRIDE)

    flat_lens = lens_r.reshape(-1)
    within = _concat_aranges(flat_lens)
    rep_rows = np.repeat(np.repeat(np.arange(LANES), S), flat_lens)
    bitpos = np.repeat(starts.reshape(-1), flat_lens) + within
    shift = np.repeat(flat_lens, flat_lens) - 1 - within
    bitvals = (np.repeat(codes_r.reshape(-1), flat_lens) >> shift) & 1
    bitmat = np.zeros((LANES, stride * 8), dtype=np.uint8)
    bitmat[rep_rows, bitpos] = bitvals.astype(np.uint8)

    weights = (1 << np.arange(7, -1, -1)).astype(np.uint16)
    bytemat = (bitmat.reshape(LANES, stride, 8).astype(np.uint16)
               * weights).sum(axis=2).astype(np.uint8)  # (LANES, stride)
    return bytemat.T.copy()


# --------------------------------------------------------------------------
# decode (host oracle)
# --------------------------------------------------------------------------

def decode_page(cp: CompressedPage) -> np.ndarray:
    """Readable per-lane oracle -> original values (bit-exact)."""
    _, max_len, _ = plane_spec(cp.dtype_name)
    S = sym_per_lane(cp.n_elem)
    cb = Codebook(lengths=np.zeros(len(cp.perm), np.int32), codes=None,
                  max_len=max_len)  # type: ignore[arg-type]
    cb.sorted_syms = np.asarray(cp.perm)
    cb.lj_limit = np.asarray(cp.lj_limit, dtype=np.int64)
    cb.first_lj = np.asarray(cp.first_lj, dtype=np.int64)
    cb.offset = np.asarray(cp.offset, dtype=np.int64)

    stride = cp.stride
    syms = np.zeros((S, LANES), dtype=np.int64)
    for lane in range(LANES):
        stream = cp.payload[:, lane]
        bitpos = 0
        for s in range(S):
            peek = 0
            for b in range(max_len):
                p = bitpos + b
                bit = ((int(stream[p // 8]) >> (7 - p % 8)) & 1
                       if p // 8 < stride else 0)
                peek = (peek << 1) | bit
            sym, ln = cb.decode_peek(peek)
            syms[s, lane] = sym
            bitpos += ln
    exp = syms.reshape(-1)[: cp.n_elem]
    bits = assemble_planes(exp, cp.signmant, cp.dtype_name, cp.n_elem)
    view = {"float8_e4m3fn": jnp.float8_e4m3fn, "bfloat16": jnp.bfloat16,
            "float32": np.float32}[cp.dtype_name]
    return bits.view(view).reshape(cp.shape)


# --------------------------------------------------------------------------
# decode (in-graph, vectorized over pages — the serving hot path)
# --------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("n_elem", "dtype_name"))
def decode_pages_jnp(payload, signmant, tables, perm, *, n_elem: int,
                     dtype_name: str):
    """Decode N compressed pages in parallel -> (N, n_elem) values.

    Args:
      payload:  (N, stride, LANES) uint8, zero-padded lane streams.
      signmant: (N, sm_bytes) uint8 raw sign+mantissa plane.
      tables:   (N, 3, max_len) int32 — lj_limit / first_lj / offset.
      perm:     (N, n_symbols) int32 canonical symbol permutation.

    Per-lane uint32 bit window, ``max_len``-bit peek, <= 2 refill bytes
    per round (codes can span two bytes once ``max_len > 8``); invariant:
    ``bits_valid >= 16 >= max_len`` at the top of every round.
    """
    sym_idx = _decode_indices_jnp(payload, tables, n_elem=n_elem)
    return finish_pages_jnp(sym_idx, signmant, perm, n_elem=n_elem,
                            dtype_name=dtype_name)


def finish_pages_jnp(sym_idx, signmant, perm, *, n_elem: int,
                     dtype_name: str):
    """Canonical indices (N, S, LANES) -> (N, n_elem) values.

    The shared tail of both entropy-decode paths (pure-jnp and Pallas):
    canonical permutation, then the sign/mantissa fuse."""
    syms = jnp.take_along_axis(
        perm.astype(jnp.int32), sym_idx.reshape(sym_idx.shape[0], -1),
        axis=1, mode="clip")[:, :n_elem]
    return assemble_pages_jnp(syms, signmant, n_elem=n_elem,
                              dtype_name=dtype_name)


def _decode_indices_jnp(payload, tables, *, n_elem: int):
    """Canonical-index decode of all pages -> (N, S, LANES) int32."""
    N, stride, _ = payload.shape
    S = sym_per_lane(n_elem)
    L = tables.shape[-1]
    p32 = payload.astype(jnp.uint32)
    win = ((p32[:, 0, :] << 24) | (p32[:, 1, :] << 16)
           | (p32[:, 2, :] << 8) | p32[:, 3, :])       # (N, LANES)
    byteptr = jnp.full((N, LANES), 4, dtype=jnp.int32)
    bits_valid = jnp.full((N, LANES), 32, dtype=jnp.int32)
    lj = tables[:, 0].astype(jnp.int32)                # (N, L)
    fl_t = tables[:, 1].astype(jnp.int32)
    off_t = tables[:, 2].astype(jnp.int32)

    def round_fn(s, carry):
        win, byteptr, bits_valid, outs = carry
        peek = (win >> (32 - L)).astype(jnp.int32)     # (N, LANES)
        lt = peek[..., None] < lj[:, None, :]          # (N, LANES, L)
        length = jnp.argmax(lt, axis=-1).astype(jnp.int32) + 1
        fl = jnp.take_along_axis(fl_t, length - 1, axis=1, mode="clip")
        off = jnp.take_along_axis(off_t, length - 1, axis=1, mode="clip")
        sym_idx = off + ((peek - fl) >> (L - length))
        outs = jax.lax.dynamic_update_index_in_dim(outs, sym_idx, s, axis=1)

        win = win << length.astype(jnp.uint32)
        bits_valid = bits_valid - length
        for _ in range(2):                             # <= 2 bytes/round
            need = bits_valid <= 24
            safe_ptr = jnp.minimum(byteptr, stride - 1)
            nb = jnp.take_along_axis(
                payload, safe_ptr[:, None, :], axis=1)[:, 0, :] \
                .astype(jnp.uint32)
            shift = jnp.maximum(24 - bits_valid, 0).astype(jnp.uint32)
            win = jnp.where(need, win | (nb << shift), win)
            byteptr = byteptr + need.astype(jnp.int32)
            bits_valid = bits_valid + 8 * need.astype(jnp.int32)
        return win, byteptr, bits_valid, outs

    outs = jnp.zeros((N, S, LANES), dtype=jnp.int32)
    _, _, _, outs = jax.lax.fori_loop(
        0, S, round_fn, (win, byteptr, bits_valid, outs))
    return outs


def assemble_pages_jnp(syms, signmant, *, n_elem: int, dtype_name: str):
    """(N, n_elem) exponent symbols + raw sm plane -> (N, n_elem) values."""
    syms = syms.astype(jnp.uint32)
    if dtype_name == "float8_e4m3fn":
        hi = (signmant >> 4) & jnp.uint8(0x0F)
        lo = signmant & jnp.uint8(0x0F)
        nib = jnp.stack([hi, lo], axis=-1).reshape(
            signmant.shape[0], -1)[:, :n_elem]
        bits = fp8.assemble(syms.astype(jnp.uint8), nib, xp=jnp)
        return jax.lax.bitcast_convert_type(bits, jnp.float8_e4m3fn)
    if dtype_name == "bfloat16":
        sm = signmant[:, :n_elem].astype(jnp.uint16)
        u = (((sm & 0x80) << 8) | (syms.astype(jnp.uint16) << 7)
             | (sm & 0x7F))
        return jax.lax.bitcast_convert_type(u, jnp.bfloat16)
    if dtype_name == "float32":
        b = signmant.reshape(signmant.shape[0], -1, 3).astype(jnp.uint32)
        b = b[:, :n_elem]
        sm24 = (b[..., 0] << 16) | (b[..., 1] << 8) | b[..., 2]
        u = ((sm24 & 0x800000) << 8) | (syms << 23) | (sm24 & 0x7FFFFF)
        return jax.lax.bitcast_convert_type(u, jnp.float32)
    raise ValueError(dtype_name)
