from .sharding import (  # noqa: F401
    batch_axes, cache_pspecs, opt_pspecs, param_pspecs, ShardingRules,
)
from .steps import make_decode_step, make_prefill_step, make_train_step  # noqa: F401
