"""Step-time tracking and straggler detection.

At 1000+ node scale, synchronous SPMD training is gated by the slowest
worker every step.  The mitigation stack implemented/documented here:

  1. **Detection** (implemented): per-step wall-time EWMA + robust z-score
     (median/MAD window).  A step slower than ``threshold`` MADs raises a
     straggler alarm with the offending step's stats.
  2. **In-job mitigation** (implemented): the trainer reacts to alarms by
     checkpointing eagerly (cheap, async) so a kill/replace loses nothing.
  3. **Replacement** (documented, needs a cluster scheduler): synchronous
     training with hot spares — the alarm triggers the scheduler to swap the
     slow host and the job auto-resumes from the last checkpoint on the new
     mesh (elastic restore supports a different host count; see
     ``checkpoint.manager``).

This is host-side instrumentation (wall clock), so it works identically on
CPU and real pods.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field


@dataclass
class StepStats:
    step: int
    seconds: float
    z: float
    is_straggler: bool


@dataclass
class StragglerMonitor:
    window: int = 50
    threshold_mads: float = 6.0
    min_samples: int = 10
    ewma_alpha: float = 0.05
    _times: deque = field(default_factory=lambda: deque(maxlen=200))
    _ewma: float = 0.0
    _t0: float = 0.0
    alarms: list = field(default_factory=list)

    def start(self):
        self._t0 = time.perf_counter()

    def stop(self, step: int) -> StepStats:
        dt = time.perf_counter() - self._t0
        window = list(self._times)[-self.window:]
        if len(window) >= self.min_samples:
            srt = sorted(window)
            med = srt[len(srt) // 2]
            mad = sorted(abs(x - med) for x in window)[len(window) // 2]
            z = (dt - med) / max(mad, 1e-6)
        else:
            z = 0.0
        is_straggler = (len(window) >= self.min_samples
                        and z > self.threshold_mads)
        self._times.append(dt)
        self._ewma = (dt if self._ewma == 0.0
                      else (1 - self.ewma_alpha) * self._ewma
                      + self.ewma_alpha * dt)
        stats = StepStats(step=step, seconds=dt, z=z,
                          is_straggler=is_straggler)
        if is_straggler:
            self.alarms.append(stats)
        return stats

    @property
    def ewma_seconds(self) -> float:
        return self._ewma


# --------------------------------------------------------------------------
# KV-cache accounting (serving)
# --------------------------------------------------------------------------

@dataclass
class KVCacheMonitor:
    """Per-step KV-cache memory accounting for the paged serving engine.

    The engine records ``PagedKVCache.stats()`` (merged with the
    scheduler's counters) after every decode step; ``summary()`` reduces
    the trace to the numbers the serving report prints: peak/mean paged
    bytes vs the monolithic ``(B, max_len)`` cache it replaced, the
    cold-page compression ratio, and — when the swap tier is attached —
    swap traffic (cumulative swap-in/out bytes, peak host-resident
    bytes) and preemption counts."""

    samples: list = field(default_factory=list)

    def record(self, stats: dict) -> None:
        self.samples.append(dict(stats))

    @property
    def peak_paged_bytes(self) -> int:
        return max((s["cache_bytes_paged"] for s in self.samples), default=0)

    @property
    def peak_raw_equiv_bytes(self) -> int:
        return max((s["cache_bytes_raw_equiv"] for s in self.samples),
                   default=0)

    def summary(self) -> dict:
        if not self.samples:
            return {}
        mono = self.samples[-1]["monolithic_bytes"]
        peak = self.peak_paged_bytes
        peak_raw = self.peak_raw_equiv_bytes
        # the observed ratio at the step holding the most cold data (a
        # ratio of maxima taken at different steps would be fictional)
        cold_peak = max(self.samples,
                        key=lambda s: s["cold_pages_in_use"] * s["page_bytes"])
        cold_raw = cold_peak["cold_pages_in_use"] * cold_peak["page_bytes"]
        last = self.samples[-1]
        out = {
            "steps": len(self.samples),
            "monolithic_bytes": mono,
            "peak_paged_bytes": peak,
            "peak_raw_equiv_bytes": peak_raw,
            "peak_pages_in_use": max(s["pages_in_use"] + s["cold_pages_in_use"]
                                     for s in self.samples),
            "paged_vs_monolithic": peak / max(mono, 1),
            "cold_compression_ratio": (cold_peak["cold_bytes_ragged"]
                                       / cold_raw
                                       if cold_raw else float("nan")),
        }
        if "swap_bytes_used" in last:     # swap tier attached
            out.update({
                "peak_swap_bytes": max(s.get("swap_bytes_used", 0)
                                       for s in self.samples),
                "peak_swapped_pages": max(s.get("swapped_pages", 0)
                                          for s in self.samples),
                "swap_out_bytes_total": last.get("swap_out_bytes_total", 0),
                "swap_in_bytes_total": last.get("swap_in_bytes_total", 0),
                "n_preempted": last.get("n_preempted", 0),
                "n_resumed": last.get("n_resumed", 0),
            })
        if "n_prefill_chunks" in last:    # chunked prefill active
            out.update({
                "n_prefill_chunks": last["n_prefill_chunks"],
                "prefill_chunk_tokens": last["prefill_chunk_tokens"],
                "n_interleaved_steps": last["n_interleaved_steps"],
                "peak_prefilling_slots": max(s.get("prefilling_slots", 0)
                                             for s in self.samples),
            })
        return out
