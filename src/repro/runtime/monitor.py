"""Step-time tracking, straggler detection, and KV-cache accounting.

At 1000+ node scale, synchronous SPMD training is gated by the slowest
worker every step.  The mitigation stack implemented/documented here:

  1. **Detection** (implemented): per-step wall-time EWMA + robust z-score
     (median/MAD window).  A step slower than ``threshold`` MADs raises a
     straggler alarm with the offending step's stats.
  2. **In-job mitigation** (implemented): the trainer reacts to alarms by
     checkpointing eagerly (cheap, async) so a kill/replace loses nothing.
  3. **Replacement** (documented, needs a cluster scheduler): synchronous
     training with hot spares — the alarm triggers the scheduler to swap the
     slow host and the job auto-resumes from the last checkpoint on the new
     mesh (elastic restore supports a different host count; see
     ``checkpoint.manager``).

This is host-side instrumentation (wall clock), so it works identically on
CPU and real pods.  The serving engine reuses :class:`StragglerMonitor`
for decode-step outlier detection, surfacing alarms through the
telemetry registry (``serving_decode_straggler_total``; see
``docs/OBSERVABILITY.md``).
"""
from __future__ import annotations

import math
import time
from collections import deque
from dataclasses import dataclass, field


@dataclass
class StepStats:
    step: int
    seconds: float
    z: float
    is_straggler: bool


@dataclass
class StragglerMonitor:
    window: int = 50
    threshold_mads: float = 6.0
    min_samples: int = 10
    # absolute floor: a robust z over a millisecond-scale MAD flags pure
    # scheduler jitter as a straggler (observed: 5-10 ms steps alarming at
    # z=7-13 and flooding the trainer's eager-checkpoint path) — a step
    # must also be at least this slow in absolute terms to alarm
    min_seconds: float = 0.05
    ewma_alpha: float = 0.05
    _times: deque = field(default_factory=lambda: deque(maxlen=200))
    # None = no sample yet; a legitimate 0.0-second first sample (clock
    # granularity, mocked clocks) must seed the EWMA, not be mistaken
    # for "uninitialized"
    _ewma: float | None = None
    _t0: float = 0.0
    alarms: list = field(default_factory=list)

    def start(self):
        self._t0 = time.perf_counter()

    def stop(self, step: int) -> StepStats:
        dt = time.perf_counter() - self._t0
        return self.observe(dt, step)

    def observe(self, dt: float, step: int) -> StepStats:
        """Record an externally measured step duration (the serving
        engine times its decode step once and feeds both this and its
        latency histogram from the same measurement)."""
        window = list(self._times)[-self.window:]
        if len(window) >= self.min_samples:
            srt = sorted(window)
            med = srt[len(srt) // 2]
            mad = sorted(abs(x - med) for x in window)[len(window) // 2]
            z = (dt - med) / max(mad, 1e-6)
        else:
            z = 0.0
        is_straggler = (len(window) >= self.min_samples
                        and z > self.threshold_mads
                        and dt >= self.min_seconds)
        self._times.append(dt)
        self._ewma = (dt if self._ewma is None
                      else (1 - self.ewma_alpha) * self._ewma
                      + self.ewma_alpha * dt)
        stats = StepStats(step=step, seconds=dt, z=z,
                          is_straggler=is_straggler)
        if is_straggler:
            self.alarms.append(stats)
        return stats

    @property
    def ewma_seconds(self) -> float:
        return 0.0 if self._ewma is None else self._ewma


# --------------------------------------------------------------------------
# KV-cache accounting (serving)
# --------------------------------------------------------------------------

#: stats keys whose per-step values are lists (per batch shard) — kept as
#: element-wise peaks inside the monitor rather than registry gauges
_LIST_KEYS = ("pages_in_use_per_shard", "free_pages_per_shard",
              "swap_bytes_per_shard")

#: forwarded-gauge namespace: every scalar stats key ``k`` recorded by the
#: engine lands in the registry as gauge ``kvstat_<k>`` (enumerated in
#: docs/OBSERVABILITY.md)
STAT_PREFIX = "kvstat_"


class KVCacheMonitor:
    """Per-step KV-cache accounting as a thin consumer of the telemetry
    metrics registry.

    The engine records ``PagedKVCache.stats()`` (merged with the
    scheduler's counters) after every step; instead of keeping its own
    list-of-dicts trace, the monitor forwards every scalar stat into a
    registry gauge named ``kvstat_<key>`` (gauges track last value +
    lifetime peak), keeps element-wise peaks for the per-shard list
    stats, and tracks the one correlated pair the summary needs (cold
    bytes at the step holding the most cold data — a ratio of maxima
    taken at different steps would be fictional).

    ``summary()`` reduces that to the numbers the serving report
    prints: peak/mean paged bytes vs the monolithic ``(B, max_len)``
    cache, the cold-page compression ratio, swap traffic and preemption
    counts.  Every key is read with a default, so a monitor shared
    across mixed engines (some without a swap tier or chunked prefill)
    summarizes what it saw instead of raising ``KeyError``.

    Pass the engine's ``Telemetry.registry`` to publish into the shared
    registry; by default the monitor owns a private one."""

    def __init__(self, registry=None):
        if registry is None:
            from repro.serving.telemetry import MetricsRegistry
            registry = MetricsRegistry()
        self.registry = registry
        self.n_samples = 0
        self._keys: set = set()             # scalar stat keys ever seen
        self._shard_peaks: dict = {}        # list-key -> per-shard peaks
        self._cold_peak = (0, 0)            # (raw-equiv bytes, ragged bytes)

    def record(self, stats: dict) -> None:
        self.n_samples += 1
        reg = self.registry
        for k, v in stats.items():
            if k in _LIST_KEYS or isinstance(v, (list, tuple)):
                peaks = self._shard_peaks.setdefault(k, [])
                for i, x in enumerate(v):
                    if i >= len(peaks):
                        peaks.append(x)
                    elif x > peaks[i]:
                        peaks[i] = x
            elif isinstance(v, (int, float)):
                self._keys.add(k)
                reg.gauge(STAT_PREFIX + k).set(v)
        # derived, correlated stats: total pages this step, and the cold
        # ratio at the step holding the most cold data
        total = (stats.get("pages_in_use", 0)
                 + stats.get("cold_pages_in_use", 0))
        reg.gauge(STAT_PREFIX + "pages_in_use_total").set(total)
        cold_raw = (stats.get("cold_pages_in_use", 0)
                    * stats.get("page_bytes", 0))
        if cold_raw > self._cold_peak[0]:
            self._cold_peak = (cold_raw, stats.get("cold_bytes_ragged", 0))

    # -- registry readers --------------------------------------------------

    def _peak(self, key: str, default=0):
        g = self.registry.get(STAT_PREFIX + key)
        return default if g is None or not g.n_sets else g.peak

    def _last(self, key: str, default=0):
        g = self.registry.get(STAT_PREFIX + key)
        return default if g is None or not g.n_sets else g.value

    def peak_per_shard(self, key: str = "pages_in_use_per_shard") -> list:
        """Element-wise peak of a per-shard list stat (empty when the
        engine never reported it)."""
        return list(self._shard_peaks.get(key, ()))

    @property
    def peak_paged_bytes(self) -> int:
        return self._peak("cache_bytes_paged")

    @property
    def peak_raw_equiv_bytes(self) -> int:
        return self._peak("cache_bytes_raw_equiv")

    def summary(self) -> dict:
        if not self.n_samples:
            return {}
        mono = self._last("monolithic_bytes")
        peak = self.peak_paged_bytes
        cold_raw, cold_ragged = self._cold_peak
        out = {
            "steps": self.n_samples,
            "monolithic_bytes": mono,
            "peak_paged_bytes": peak,
            "peak_raw_equiv_bytes": self.peak_raw_equiv_bytes,
            "peak_pages_in_use": self._peak("pages_in_use_total"),
            "paged_vs_monolithic": peak / max(mono, 1),
            "cold_compression_ratio": (cold_ragged / cold_raw
                                       if cold_raw else math.nan),
        }
        if "swap_bytes_used" in self._keys:     # swap tier attached
            out.update({
                "peak_swap_bytes": self._peak("swap_bytes_used"),
                "peak_swapped_pages": self._peak("swapped_pages"),
                "swap_out_bytes_total": self._last("swap_out_bytes_total"),
                "swap_in_bytes_total": self._last("swap_in_bytes_total"),
                "n_preempted": self._last("n_preempted"),
                "n_resumed": self._last("n_resumed"),
            })
        if "n_prefill_chunks" in self._keys:    # chunked prefill active
            out.update({
                "n_prefill_chunks": self._last("n_prefill_chunks"),
                "prefill_chunk_tokens": self._last("prefill_chunk_tokens"),
                "n_interleaved_steps": self._last("n_interleaved_steps"),
                "peak_prefilling_slots": self._peak("prefilling_slots"),
            })
        return out
