"""Sharding rules: logical parameter/activation/cache axes -> mesh axes.

MaxText-style rules table, applied by *path + rank* over the parameter
pytree (the model is pure pytrees, no flax metadata).  The production mesh
is ``(data=16, model=16)`` per pod, with an optional leading ``pod`` axis;
the policy (DESIGN.md §5):

  * 2-D weights: input/embed dim -> ``data`` (FSDP; all-gathered at use,
    gradients reduce-scattered), output/heads/ffn/vocab dim -> ``model``
    (Megatron TP).  Output projections (``wo``-like) are transposed in the
    table so the TP axis stays on the contracted dim.
  * MoE expert weights: experts -> ``model`` (EP), embed dim -> ``data``
    (FSDP); the per-layer shard_map all-to-all does the token exchange.
  * batch -> ``("pod", "data")`` (pod folds into DP); weight collectives
    stay intra-pod (ICI), only grad reduction crosses pods (DCI).
  * KV caches: batch -> data; kv-heads -> model when divisible, else the
    head_dim -> model (MQA/GQA archs with few kv heads, e.g. granite kv=1).
  * Scan-stacked leaves (a leading ``n_units``/``n_enc_layers`` dim) get a
    prepended None.
  * A dim is sharded only when divisible by the axis size — otherwise the
    rule degrades to replication for that dim (recorded per-arch in the
    dry-run artifacts as ``padded_dims``).

Sharding of ``CompressedTensor`` leaves (ECF8 serving): the flattened chunk
axis of the payload is itself the flattened weight element order, so
sharding chunks over ``model`` shards the decoded weight over its leading
dim; signmant/codes shard likewise.  Decode tables (<= 16 entries) replicate.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np
import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig


def batch_axes(mesh: Mesh):
    """Mesh axes the global batch shards over ('pod' folds into DP).

    Returns a tuple of axis names, a single name, or ``None`` when the
    mesh has no batch axis (pure tensor-parallel mesh) — all three forms
    drop into a ``PartitionSpec`` entry unchanged."""
    ax = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    if not ax:
        return None
    return ax if len(ax) != 1 else ax[0]


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        return int(np.prod([mesh.shape[a] for a in axis]))
    return mesh.shape[axis]


def _fit(mesh: Mesh, spec_dims, shape):
    """Drop axes that don't divide their dim (replicate those dims)."""
    out = []
    for dim, axis in zip(shape, spec_dims):
        if axis is not None and dim % _axis_size(mesh, axis) == 0:
            out.append(axis)
        else:
            out.append(None)
    return P(*out)


# parameter-name -> (spec for the *unstacked* shape), rank-dispatched
_IN_OUT = ("wq", "wk", "wv", "wi", "wi_gate", "wi_up", "w_in", "w_gate_in",
           "w_up", "w_q", "w_k", "w_v", "w_if", "w", "w_a", "w_x")
_OUT_IN = ("wo", "w_out", "w_down")


@dataclass(frozen=True)
class ShardingRules:
    """Knobs for the hillclimb loop (see EXPERIMENTS.md §Perf).

    Precedence when rules interact: the per-parameter name/rank rule in
    ``_param_rule`` picks a base spec first; ``serve_tp`` then *drops*
    the data (FSDP) axis from that spec; finally ``_fit`` drops any axis
    whose size does not divide its dim (replicating that dim).  So a knob
    can only ever remove sharding the table proposed, never add an axis
    the table didn't place, and divisibility always wins last."""

    # residual-stream constraint between scan units:
    #   "none"  -> let GSPMD propagate
    #   "seq"   -> (batch, seq->model, None): GSPMD sequence parallelism
    #   "dmodel"-> (batch, None, d->model)
    activation_partitioning: str = "seq"
    # shard embed/unembed vocab dim over model (vocab TP)
    vocab_tp: bool = True
    # shard expert weights' d_model dim over data (FSDP on experts)
    expert_fsdp: bool = True
    # serving: replicate weights over the data axes (pure TP) — decode
    # steps re-gather FSDP-sharded weights for every generated token,
    # which dominates the decode collective term (§Perf cell 3)
    serve_tp: bool = False


DEFAULT_RULES = ShardingRules()


def _param_rule(path_keys, shape, mesh: Mesh, rules: ShardingRules) -> P:
    names = [str(getattr(k, "key", getattr(k, "idx", k))) for k in path_keys]
    name = names[-1]
    rank = len(shape)
    stacked = int("units" in names or "layers" in names)
    base_rank = rank - stacked

    def done(spec_dims):
        if rules.serve_tp:  # pure TP: drop the FSDP (data) axis
            spec_dims = tuple(None if d == "data" else d
                              for d in spec_dims)
        return _fit(mesh, (None,) * stacked + tuple(spec_dims), shape)

    if name == "embed":
        return done(("model" if rules.vocab_tp else None, "data"))
    if name == "unembed":
        return done(("data", "model" if rules.vocab_tp else None))
    if name == "pos_embed":
        return done((None, "data"))
    if base_rank <= 1:
        return done((None,) * base_rank)  # norms, biases, lam: replicate

    in_moe = "moe" in names and "shared" not in names
    if in_moe:
        d_ax = "data" if rules.expert_fsdp else None
        if name == "gate":
            return done(("data", None))
        if name in ("wi_gate", "wi_up"):
            return done(("model", d_ax, None))
        if name == "wo":
            return done(("model", None, d_ax))

    if name == "r" and base_rank == 3:       # slstm recurrent: (H, dh, 4dh)
        return done(("model", None, None))
    if name == "conv_w":
        return done((None, "model"))
    if name in _OUT_IN:
        return done(("model", "data"))
    if name in _IN_OUT:
        return done(("data", "model"))
    # compressed-container children (payload/codes/signmant/escapes/tables)
    if name in ("payload", "codes", "signmant", "escapes"):
        return _fit(mesh, (None,) * stacked + ("model",)
                    + (None,) * (base_rank - 1), shape)
    if name in ("lj_limit", "first_lj", "offset", "perm", "table"):
        return P(*(None,) * rank)
    # default: replicate
    return P(*(None,) * rank)


def param_pspecs(cfg: ArchConfig, params, mesh: Mesh,
                 rules: ShardingRules = DEFAULT_RULES):
    """PartitionSpec pytree matching ``params`` (arrays or ShapeDtypeStructs).

    Works on CompressedTensor-bearing trees too (they flatten to arrays)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = [_param_rule(path, leaf.shape, mesh, rules)
             for path, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)


_PAGED_COLD = ("_cpl", "_csm", "_ctab", "_cperm")


def _cache_leaf_rule(path_keys, shape, cfg: ArchConfig, mesh: Mesh):
    names = [str(getattr(k, "key", getattr(k, "idx", k))) for k in path_keys]
    name = names[-1]
    ba = batch_axes(mesh)
    stacked = int("units" in names)
    rank = len(shape)
    base_rank = rank - stacked
    if name == "cur_len":
        # scalar (shared timeline) replicates; per-slot (B,) shards with
        # the batch like every other cache leaf
        return _fit(mesh, (ba,), shape) if rank == 1 else P()
    # paged-cache leaves (repro.kvcache): the pool's *page* dim, the cold
    # pool's *cold-slot* dim and the page table's batch dim all shard over
    # the batch axes — PagedKVCache(n_shards=...) keeps every slot's pages
    # inside its own shard's id range, so the layout is communication-free
    if name == "page_table":
        return _fit(mesh, (ba, None), shape)
    if name.endswith("_pool") or name.endswith(_PAGED_COLD):
        return _fit(mesh, (None,) * stacked + (ba,)
                    + (None,) * (base_rank - 1), shape)
    if name in ("k", "v") and base_rank == 4:
        # (B, Hkv, S, hd): self-attention caches shard the *sequence* over
        # model (decode_sharded merges shard stats — §Perf cell 3); cross
        # caches (whisper, S=1500 indivisible) fall back to heads/head_dim;
        # meshes without a model axis (pure-DP serving) shard batch only
        S = shape[stacked + 2]
        n_model = mesh.shape.get("model", 0)
        if not n_model:
            spec = (ba, None, None, None)
        elif "cross" not in names and S % n_model == 0:
            spec = (ba, None, "model", None)
        elif shape[stacked + 1] % n_model == 0:
            spec = (ba, "model", None, None)
        else:
            spec = (ba, None, None, "model")
        return _fit(mesh, (None,) * stacked + spec, shape)
    # recurrent states / conv states: batch plus feature -> model where big
    if base_rank >= 1:
        spec = [ba] + [None] * (base_rank - 1)
        if base_rank >= 2 and shape[-1] >= 1024:
            spec[-1] = "model"
        return _fit(mesh, (None,) * stacked + tuple(spec), shape)
    return P(*(None,) * rank)


def cache_pspecs(cfg: ArchConfig, cache, mesh: Mesh):
    """PartitionSpec pytree for a decode cache pytree."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(cache)
    specs = [_cache_leaf_rule(path, leaf.shape, cfg, mesh)
             for path, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)


def opt_pspecs(param_specs):
    """Optimizer-state specs: moments inherit the parameter sharding."""
    return {"mu": param_specs, "nu": param_specs, "count": P()}


def named(mesh: Mesh, tree_of_pspecs):
    """P -> NamedSharding pytree (leaves are PartitionSpec)."""
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), tree_of_pspecs,
        is_leaf=lambda s: isinstance(s, P))


def make_constrainer(mesh: Mesh, rules: ShardingRules):
    """Residual-stream sharding constraint applied between scan units."""
    ba = batch_axes(mesh)
    mode = rules.activation_partitioning

    def constrain(x):
        if mode == "none" or mesh is None:
            return x
        n_model = mesh.shape.get("model", 0)
        if mode == "seq" and n_model and x.ndim == 3 and x.shape[1] > 1 \
                and x.shape[1] % n_model == 0:
            spec = P(ba, "model", None)
        elif mode == "dmodel" and n_model and x.ndim == 3 and (
                x.shape[2] % n_model == 0):
            spec = P(ba, None, "model")
        else:
            spec = P(ba, *(None,) * (x.ndim - 1))
        if x.shape[0] % _axis_size(mesh, spec[0] if spec else None) != 0:
            return x
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    return constrain
