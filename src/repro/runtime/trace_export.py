"""Chrome-trace / Perfetto JSON export of a serving ``SpanTracer`` buffer.

Writes the `Trace Event Format`_ consumed by ``chrome://tracing`` and
https://ui.perfetto.dev: one process row per track family —

  * pid 1 ``engine``: engine-phase spans (prefill phase / decode step /
    evict / fault / preempt / resume) on tid 0, plus the per-step
    counter tracks (queue depth, pages in use) as ``ph: "C"`` events;
  * pid 2 ``requests``: one thread row per request (tid = request id),
    carrying its back-to-back lifecycle state spans (queued ->
    prefilling -> decoding -> preempted -> ... -> finished), so a mixed
    oversubscribed run renders as a timeline of request rows above the
    engine-phase row.

Timestamps are exported in microseconds relative to the tracer's
``t0``.  The top-level object also embeds ``otherData`` with the
metrics-registry snapshot (when given) and the tracer's drop count, so
one file carries the whole observation.

.. _Trace Event Format: https://docs.google.com/document/d/1CvAClvFfyA5R-PhYUmn5OOQtYMH4h6I0nSsKchNAySU
"""
from __future__ import annotations

import json

from .tracing import SpanTracer

_ENGINE_PID = 1
_REQUEST_PID = 2


def _track_ids(track: str, extra_tids: dict) -> tuple:
    """Map a tracer track name to a (pid, tid) pair."""
    if track.startswith("req:"):
        return _REQUEST_PID, int(track.split(":", 1)[1])
    if track == "engine":
        return _ENGINE_PID, 0
    tid = extra_tids.setdefault(track, len(extra_tids) + 1)
    return _ENGINE_PID, tid


def to_chrome_events(tracer: SpanTracer) -> list:
    """Tracer buffer -> list of Chrome trace-event dicts (with metadata)."""
    extra_tids: dict = {}
    seen: dict = {}                     # (pid, tid) -> track name
    events = []
    for ph, cat, name, track, ts, dur, args in tracer.events:
        pid, tid = _track_ids(track, extra_tids)
        seen.setdefault((pid, tid), track)
        ev = {"ph": ph, "cat": cat, "name": name, "pid": pid, "tid": tid,
              "ts": (ts - tracer.t0) * 1e6}
        if ph == "X":
            ev["dur"] = dur * 1e6
        if ph == "C":
            ev["args"] = {"value": args}
        elif args:
            ev["args"] = dict(args)
        events.append(ev)

    meta = [
        {"ph": "M", "pid": _ENGINE_PID, "tid": 0, "name": "process_name",
         "args": {"name": "engine"}},
        {"ph": "M", "pid": _REQUEST_PID, "tid": 0, "name": "process_name",
         "args": {"name": "requests"}},
        {"ph": "M", "pid": _ENGINE_PID, "tid": 0, "name": "thread_name",
         "args": {"name": "engine phases"}},
    ]
    for (pid, tid), track in sorted(seen.items()):
        if pid == _REQUEST_PID:
            meta.append({"ph": "M", "pid": pid, "tid": tid,
                         "name": "thread_name",
                         "args": {"name": f"request {tid}"}})
        elif tid != 0:
            meta.append({"ph": "M", "pid": pid, "tid": tid,
                         "name": "thread_name", "args": {"name": track}})
    return meta + events


def build_trace(tracer: SpanTracer, registry=None) -> dict:
    """The full Chrome-trace JSON object (not yet serialized)."""
    other = {"n_dropped_events": tracer.n_dropped,
             "n_events": len(tracer.events)}
    if registry is not None:
        other["metrics"] = registry.snapshot()
    return {"traceEvents": to_chrome_events(tracer),
            "displayTimeUnit": "ms",
            "otherData": other}


def export_chrome_trace(tracer: SpanTracer, path: str,
                        registry=None) -> dict:
    """Write the trace JSON to ``path`` (open it in ui.perfetto.dev or
    ``chrome://tracing``); returns the written object."""
    trace = build_trace(tracer, registry)
    with open(path, "w") as f:
        json.dump(trace, f)
        f.write("\n")
    return trace


def validate_chrome_trace(obj: dict) -> list:
    """Schema sanity check -> list of error strings (empty = valid).

    Used by the telemetry tests' export round-trip and by anything that
    wants to assert a trace file is loadable before shipping it."""
    errors = []
    if not isinstance(obj, dict) or "traceEvents" not in obj:
        return ["missing top-level traceEvents"]
    for i, ev in enumerate(obj["traceEvents"]):
        for k in ("ph", "name", "pid", "tid"):
            if k not in ev:
                errors.append(f"event {i}: missing {k!r}")
        ph = ev.get("ph")
        if ph not in ("X", "I", "C", "M"):
            errors.append(f"event {i}: unknown ph {ph!r}")
        if ph != "M" and "ts" not in ev:
            errors.append(f"event {i}: missing ts")
        if ph == "X" and ev.get("dur", -1) < 0:
            errors.append(f"event {i}: X span without dur >= 0")
        if ph == "C" and "value" not in ev.get("args", {}):
            errors.append(f"event {i}: counter without args.value")
    return errors
