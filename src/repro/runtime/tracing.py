"""Per-request span tracing for the serving engine (host wall-clock).

The tracing half of the telemetry subsystem (metrics live in
``serving.telemetry``; the Chrome-trace/Perfetto JSON exporter in
``runtime.trace_export``).  The engine records every lifecycle
transition it already performs — submit -> admitted -> prefilling (per
chunk) -> decoding -> preempted/resumed -> finished — as **state spans**
on a per-request track, plus **engine-phase spans** (prefill phase,
decode step, evict/fault a.k.a. host<->device swap, preempt/resume) on
the engine track, and per-step counter samples (queue depth, pages in
use) that render as counter tracks in Perfetto.

Design constraints (the tracer runs inside the serving step loop):

  * **bounded**: the event buffer is capped at ``capacity``; overflow
    bumps ``n_dropped`` instead of growing — a runaway run degrades to
    a truncated trace, never to unbounded host memory;
  * **cheap**: an event is one small tuple append; timestamps are raw
    ``perf_counter`` floats (exported to microseconds only at dump
    time); nothing is formatted or serialized until export.  There is
    no per-token work at all — events are per step / per transition.

Event tuples are ``(ph, cat, name, track, ts, dur, args)`` with ``ph``
one of ``"X"`` (complete span), ``"I"`` (instant), ``"C"`` (counter
sample; ``args`` is the numeric value).  ``track`` is a string:
``"engine"`` (engine-phase rows) or ``"req:<id>"`` (one row per
request).  ``runtime.trace_export`` maps tracks to Chrome-trace
pid/tid pairs.
"""
from __future__ import annotations

import time
import warnings
from contextlib import contextmanager

ENGINE_TRACK = "engine"


def request_track(rid) -> str:
    return f"req:{rid}"


class SpanTracer:
    """Bounded host-side event buffer (see module docstring)."""

    def __init__(self, capacity: int = 200_000, clock=time.perf_counter):
        self._clock = clock
        self.capacity = capacity
        self.events: list[tuple] = []
        self.n_dropped = 0
        self.t0 = clock()

    def __len__(self) -> int:
        return len(self.events)

    def now(self) -> float:
        return self._clock()

    def _push(self, ev: tuple) -> None:
        if len(self.events) >= self.capacity:
            self.n_dropped += 1
            return
        self.events.append(ev)

    def complete(self, cat: str, name: str, track: str, t_start: float,
                 t_end: float | None = None, args: dict | None = None):
        """Record a finished span [t_start, t_end] (end defaults to now)."""
        if t_end is None:
            t_end = self._clock()
        self._push(("X", cat, name, track, t_start,
                    max(t_end - t_start, 0.0), args))

    def instant(self, cat: str, name: str, track: str = ENGINE_TRACK,
                args: dict | None = None):
        self._push(("I", cat, name, track, self._clock(), 0.0, args))

    def counter(self, name: str, value: float,
                track: str = ENGINE_TRACK):
        """One sample of a counter track (queue depth, pages in use)."""
        self._push(("C", "metric", name, track, self._clock(), 0.0,
                    float(value)))

    @contextmanager
    def span(self, cat: str, name: str, track: str = ENGINE_TRACK,
             args: dict | None = None):
        t0 = self._clock()
        try:
            yield
        finally:
            self.complete(cat, name, track, t0, args=args)


class RequestStateTracker:
    """Per-request lifecycle state machine -> non-overlapping state spans.

    Each request's track carries back-to-back spans named after its
    scheduler state (``queued`` / ``prefilling`` / ``decoding`` /
    ``preempted``): :meth:`transition` closes the open state span and
    opens the next, :meth:`finish` closes the last one and stamps an
    instant ``finished`` marker.  Invariants the telemetry tests pin:
    every submitted request's spans close by the time the engine drains
    (``open_states`` is empty), and spans on one track never overlap
    (they share single open-state bookkeeping by construction)."""

    CAT = "request"

    def __init__(self, tracer: SpanTracer):
        self.tracer = tracer
        self._open: dict = {}       # rid -> (state, t_since, args)

    def transition(self, rid, state: str, args: dict | None = None):
        now = self.tracer.now()
        prev = self._open.get(rid)
        if prev is not None:
            pstate, pt, pargs = prev
            self.tracer.complete(self.CAT, pstate, request_track(rid),
                                 pt, now, pargs)
        self._open[rid] = (state, now, args)

    def finish(self, rid, args: dict | None = None):
        prev = self._open.pop(rid, None)
        if prev is not None:
            pstate, pt, pargs = prev
            self.tracer.complete(self.CAT, pstate, request_track(rid),
                                 pt, args=pargs)
        self.tracer.instant(self.CAT, "finished", request_track(rid), args)

    @property
    def open_states(self) -> dict:
        """rid -> current state name (empty once the engine drains)."""
        return {rid: st for rid, (st, _, _) in self._open.items()}


class JaxProfilerHook:
    """Opt-in ``jax.profiler`` capture over an engine-step range.

    Drives ``jax.profiler.start_trace``/``stop_trace`` so a device-side
    profile (XLA execution, transfers) lands next to the host-side span
    trace for the same steps (``launch/serve.py --jax-profile DIR
    --profile-steps A:B``).  Failures to start/stop are downgraded to
    warnings — profiling must never take down a serving run."""

    def __init__(self, logdir: str, start_step: int = 0,
                 stop_step: int | None = None):
        self.logdir = logdir
        self.start_step = start_step
        # default: a one-step capture window
        self.stop_step = (start_step + 1 if stop_step is None
                          else stop_step)
        self.active = False
        self.done = False

    def on_step(self, step: int) -> None:
        if not self.done and not self.active and step >= self.start_step:
            try:
                import jax
                jax.profiler.start_trace(self.logdir)
                self.active = True
            except Exception as e:                  # pragma: no cover
                warnings.warn(f"jax.profiler start failed: {e}")
                self.done = True
        elif self.active and step >= self.stop_step:
            self.close()

    def close(self) -> None:
        if self.active:
            try:
                import jax
                jax.profiler.stop_trace()
            except Exception as e:                  # pragma: no cover
                warnings.warn(f"jax.profiler stop failed: {e}")
            self.active = False
        self.done = True
