"""Compressed collectives: ECF8-FR weight all-gather (beyond-paper).

The paper compresses weights at rest (HBM).  At 1000+ node scale the same
statistical law (exponent concentration) applies to the *interconnect*: an
FSDP weight all-gather moves the same exponent-redundant bytes every step.
ECF8-FR (fixed-rate, static shapes — ``core.fixedrate``) is losslessly
codable *inside* a jitted collective, unlike Huffman whose output length is
data-dependent.

Pipeline (per shard, inside shard_map):
    fp8 bit view -> encode_jnp (codes 2 b/elem + escapes + signmant 4 b/elem)
    -> all_gather the three byte arrays -> vmapped decode -> concat shards.

Wire bytes per element: 0.25 (codes) + 0.5 (signmant) + 0.5 * esc_frac
vs 1.0 for a raw fp8 gather and 2.0 for bf16 — a 25-40 % collective-term
reduction measured in the §Perf hillclimb (serving weight-streaming path).

Escape capacity is static: chosen offline per tensor from the calibration
histogram with a safety margin; ``overflow`` is returned as a metric and
triggers recalibration (weights drift slowly, so this is rare — DESIGN.md).
"""
from __future__ import annotations

from functools import partial

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core import fixedrate, fp8


def calibrate(w8_bits: np.ndarray, margin: float = 1.25):
    """Offline: pick the top-3 exponent table + escape capacity per tensor."""
    flat = np.asarray(w8_bits, np.uint8).reshape(-1)
    exps = fp8.exponent_field(flat, xp=np)
    freqs = np.bincount(exps, minlength=16)
    table = np.argsort(-freqs, kind="stable")[:3].astype(np.uint8)
    esc = int(flat.size - freqs[table].sum())
    cap = max(1, int(np.ceil(esc * margin)))
    # nibble packing works on even counts
    cap += cap % 2
    return jnp.asarray(table), cap


def _gather_decode(w8_shard_bits, table, axis: str, esc_capacity: int):
    """shard_map body: encode local shard, gather bytes, decode all shards."""
    n_local = w8_shard_bits.size
    flat = w8_shard_bits.reshape(-1)
    codes, escapes, signmant, overflow = fixedrate.encode_jnp(
        flat, table, esc_capacity)
    esc_packed = fp8.pack_nibbles(escapes, xp=jnp)
    sm_packed = fp8.pack_nibbles(signmant, xp=jnp)

    codes_g = jax.lax.all_gather(codes, axis)          # (S, n/4)
    esc_g = jax.lax.all_gather(esc_packed, axis)       # (S, cap/2)
    sm_g = jax.lax.all_gather(sm_packed, axis)         # (S, n/2)

    dec = jax.vmap(lambda c, e, s: fixedrate._decode_jnp_impl(
        c, e, table, s, n_elem=n_local))
    bits = dec(codes_g, esc_g, sm_g)                   # (S, n)
    return bits.reshape(-1), jax.lax.all_gather(overflow, axis).any()


def compressed_all_gather(mesh: Mesh, axis: str = "data"):
    """Build a jitted ``(w8_bits_sharded, table) -> (full bits, overflow)``.

    ``w8_bits`` is the uint8 bit view of an fp8 weight, sharded over ``axis``
    on its leading dim.  The gathered result is bit-exact (tested) — the
    collective just moves ~0.8 bytes/elem instead of 1 (fp8) or 2 (bf16).
    """

    def fn(w8_bits, table, esc_capacity: int):
        n = w8_bits.shape[0]
        body = partial(_gather_decode, axis=axis, esc_capacity=esc_capacity)
        out, overflow = shard_map(
            body, mesh=mesh,
            in_specs=(P(axis, *(None,) * (w8_bits.ndim - 1)), P(None)),
            out_specs=(P(None), P()),
            check_rep=False,
        )(w8_bits, table)
        return out.reshape(n, *w8_bits.shape[1:]), overflow

    return fn


def wire_bytes_per_elem(esc_frac: float) -> float:
    """Analytic wire cost of the compressed gather (bytes/element)."""
    return 0.25 + 0.5 + 0.5 * esc_frac


def raw_wire_bytes_per_elem(dtype: str = "float8") -> float:
    return {"float8": 1.0, "bfloat16": 2.0, "float32": 4.0}[dtype]
