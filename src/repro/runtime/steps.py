"""Jit-able step functions: train / prefill / decode (serve).

Each ``make_*_step`` returns a pure function suitable for
``jax.jit(step, in_shardings=..., out_shardings=...)`` — the launcher and
the multi-pod dry-run both consume these.  ``input_specs`` provides
ShapeDtypeStruct stand-ins for every model input so the dry-run lowers
without allocating (the 40-cell x 2-mesh sweep).

Distributed-optimization features wired here:
  * gradient accumulation (microbatching) via ``lax.scan`` — the knob that
    trades HBM for step time at the 1000-node scale;
  * remat (activation checkpointing) at scan-unit granularity;
  * sequence-parallel residual constraint (``runtime.sharding``) so saved
    activations shard over the model axis;
  * optional gradient compression hook (1-bit-sign-like mean-abs scaling is
    NOT lossless and is deliberately absent: the repo's contribution is
    *lossless* compression — see ``runtime.collectives`` for the ECF8-FR
    compressed weight all-gather used on the serving path instead).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import model as M
from repro.optim.adamw import AdamWConfig, adamw, adamw_init
from repro.optim.schedules import cosine_schedule
from .sharding import ShardingRules, DEFAULT_RULES, make_constrainer

F32 = jnp.float32


# --------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins, shardable, no allocation)
# --------------------------------------------------------------------------

def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> dict:
    """ShapeDtypeStructs for every model input of this (arch, shape) cell.

    train:   {tokens (B, T) i32, labels (B, T) i32 [, frames (B, F, d)]}
    prefill: {tokens (B, T) i32 [, frames]}
    decode:  {token (B, 1) i32}  (the cache is built via cache_specs)
    """
    B, T = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    sds = jax.ShapeDtypeStruct
    if shape.kind == "train":
        specs = {"tokens": sds((B, T), i32), "labels": sds((B, T), i32)}
    elif shape.kind == "prefill":
        specs = {"tokens": sds((B, T), i32)}
    else:  # decode
        specs = {"token": sds((B, 1), i32)}
    if cfg.encoder_decoder and shape.kind != "decode":
        specs["frames"] = sds((B, cfg.encoder_frames, cfg.d_model),
                              jnp.dtype(cfg.dtype))
    return specs


def param_specs(cfg: ArchConfig, dtype=None) -> dict:
    """ShapeDtypeStruct pytree of the parameters (eval_shape, no alloc)."""
    dtype = dtype or jnp.dtype(cfg.dtype)
    return jax.eval_shape(
        lambda k: M.init_params(k, cfg, dtype=dtype),
        jax.random.PRNGKey(0))


def cache_specs(cfg: ArchConfig, batch: int, max_len: int,
                dtype=jnp.bfloat16) -> dict:
    """ShapeDtypeStruct pytree of the decode cache."""
    return jax.eval_shape(partial(M.init_cache, cfg, batch, max_len, dtype))


def compressed_param_specs(cfg: ArchConfig, bits_per_exp: float = 3.43,
                           min_elems: int = 65536,
                           out_dtype: str = "bfloat16") -> dict:
    """ShapeDtypeStruct stand-in for an ECF8-TPU-compressed param tree.

    The payload stride is data-dependent at encode time; for lowering we
    size it from the expected exponent code length (``bits_per_exp``, ~3.4
    bits at the trained-weight alpha~1.9 — table1_memory measures 3.2-3.5)
    plus lane-padding slack.  Dry-run only: real serving compresses real
    weights (launch/serve.py) and gets exact strides.
    """
    from repro.core.store import CompressedMeta, CompressedTensor
    from repro.core.tpu_format import DEFAULT_SYM_PER_LANE, LANES
    import numpy as np
    sds = jax.ShapeDtypeStruct
    S = DEFAULT_SYM_PER_LANE
    stride = int(np.ceil(S * (bits_per_exp * 1.06) / 8)) + 1
    base = param_specs(cfg)

    def visit(path, leaf):
        names = [str(getattr(k, "key", getattr(k, "idx", k)))
                 for k in path]
        stacked = int("units" in names or "layers" in names)
        n = int(np.prod(leaf.shape))
        per_layer = n // leaf.shape[0] if stacked else n
        if per_layer < min_elems or len(leaf.shape) < 2 + stacked:
            return leaf
        C = -(-per_layer // (LANES * S))
        lead = (leaf.shape[0],) if stacked else ()
        n_pad = C * LANES * S
        arrays = {
            "payload": sds(lead + (C, stride, LANES), jnp.uint8),
            "signmant": sds(lead + (-(-per_layer // 2),), jnp.uint8),
            "lj_limit": sds(lead + (8,), jnp.int32),
            "first_lj": sds(lead + (8,), jnp.int32),
            "offset": sds(lead + (8,), jnp.int32),
            "perm": sds(lead + (16,), jnp.int32),
        }
        meta = CompressedMeta(
            fmt="tpu", shape=tuple(leaf.shape[stacked:]),
            n_elem=per_layer, sym_per_lane=S, out_dtype=out_dtype)
        return CompressedTensor(arrays=arrays, meta=meta)

    return jax.tree_util.tree_map_with_path(visit, base)


def opt_specs(cfg: ArchConfig, dtype=None) -> dict:
    p = param_specs(cfg, dtype)
    return jax.eval_shape(adamw_init, p)


# --------------------------------------------------------------------------
# steps
# --------------------------------------------------------------------------

def make_train_step(cfg: ArchConfig, opt_cfg: AdamWConfig | None = None,
                    mesh=None, rules: ShardingRules = DEFAULT_RULES,
                    remat: bool = True, grad_accum: int = 1,
                    warmup_steps: int = 100, total_steps: int = 10000):
    """(params, opt_state, batch, step) -> (params, opt_state, metrics)."""
    opt_cfg = opt_cfg or AdamWConfig()
    constrain = make_constrainer(mesh, rules) if mesh is not None else None

    def loss_of(params, tokens, labels, frames):
        loss, met = M.loss_fn(params, cfg, tokens, labels, frames=frames,
                              mesh=mesh, remat=remat, constrain=constrain)
        return loss, met

    grad_fn = jax.value_and_grad(loss_of, has_aux=True)

    def train_step(params, opt_state, batch, step):
        tokens, labels = batch["tokens"], batch["labels"]
        frames = batch.get("frames")
        if grad_accum > 1:
            B = tokens.shape[0]
            mb = B // grad_accum

            def micro(carry, i):
                g_acc, l_acc = carry
                sl = lambda a: jax.lax.dynamic_slice_in_dim(a, i * mb, mb, 0)
                (l, _), g = grad_fn(params, sl(tokens), sl(labels),
                                    sl(frames) if frames is not None
                                    else None)
                g_acc = jax.tree_util.tree_map(jnp.add, g_acc, g)
                return (g_acc, l_acc + l), None

            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, F32), params)
            (grads, loss_sum), _ = jax.lax.scan(
                micro, (g0, jnp.zeros((), F32)), jnp.arange(grad_accum))
            grads = jax.tree_util.tree_map(lambda g: g / grad_accum, grads)
            loss = loss_sum / grad_accum
            met = {"nll": loss, "aux": jnp.zeros((), F32)}
        else:
            (loss, met), grads = grad_fn(params, tokens, labels, frames)

        lr = cosine_schedule(step, warmup_steps, total_steps, opt_cfg.lr)
        params, opt_state, om = adamw(params, grads, opt_state, opt_cfg,
                                      lr=lr)
        metrics = {"loss": loss, "lr": lr, **met, **om}
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg: ArchConfig, mesh=None,
                      rules: ShardingRules = DEFAULT_RULES,
                      max_len: int | None = None):
    """(params, batch) -> (last-pos logits, cache)."""
    constrain = make_constrainer(mesh, rules) if mesh is not None else None

    def prefill_step(params, batch):
        return M.prefill(params, cfg, batch["tokens"],
                         frames=batch.get("frames"), mesh=mesh,
                         max_len=max_len, constrain=constrain)

    return prefill_step


def make_decode_step(cfg: ArchConfig, mesh=None,
                     rules: ShardingRules = DEFAULT_RULES):
    """(params, batch, cache) -> (logits, new cache) — one new token."""

    def decode_step(params, batch, cache):
        return M.decode_step(params, cfg, batch["token"], cache, mesh=mesh)

    return decode_step
