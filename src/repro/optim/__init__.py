from .adamw import adamw, clip_by_global_norm  # noqa: F401
from .schedules import cosine_schedule, linear_warmup  # noqa: F401
