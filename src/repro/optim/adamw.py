"""AdamW with decoupled weight decay and global-norm clipping.

Implemented as pure pytree transforms (no optax dependency) so the optimizer
state inherits the parameter sharding verbatim: under FSDP the first/second
moments are sharded exactly like the weights (ZeRO-1 for free), which the
dry-run verifies by lowering ``train_step`` with optimizer state in the
carry.  Moments are kept in f32 regardless of the parameter dtype (mixed-
precision master-moment convention).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

F32 = jnp.float32


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0          # 0 disables clipping
    # parameters whose path contains one of these substrings skip decay
    no_decay_substrings: tuple = ("norm", "bias", "b_", "lam")


def _path_str(path) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                    for k in path)


def adamw_init(params):
    """Zero moments shaped like params (f32), plus the step counter."""
    zeros = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, F32) if hasattr(p, "shape") else p,
        params)
    return {"mu": zeros,
            "nu": jax.tree_util.tree_map(jnp.copy, zeros),
            "count": jnp.zeros((), jnp.int32)}


def clip_by_global_norm(grads, max_norm: float):
    """Clip a gradient pytree to a maximum global L2 norm.

    Returns (clipped_grads, pre_clip_norm)."""
    sq = jax.tree_util.tree_reduce(
        lambda a, g: a + jnp.sum(jnp.square(g.astype(F32))), grads,
        jnp.zeros((), F32))
    gnorm = jnp.sqrt(sq)
    scale = jnp.where(gnorm > max_norm, max_norm / jnp.maximum(gnorm, 1e-12),
                      1.0)
    return jax.tree_util.tree_map(
        lambda g: (g.astype(F32) * scale).astype(g.dtype), grads), gnorm


def adamw(params, grads, state, cfg: AdamWConfig,
          lr: jnp.ndarray | float | None = None):
    """One AdamW update.  Returns (new_params, new_state, metrics).

    ``lr`` overrides cfg.lr (pass the schedule value as a traced scalar so
    one compiled step serves the whole run).
    """
    lr = cfg.lr if lr is None else lr
    gnorm = jnp.zeros((), F32)
    if cfg.clip_norm:
        grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)

    count = state["count"] + 1
    c1 = 1.0 - cfg.b1 ** count.astype(F32)
    c2 = 1.0 - cfg.b2 ** count.astype(F32)

    flat_p = jax.tree_util.tree_flatten_with_path(params)[0]
    decay_mask = {
        _path_str(path): not any(s in _path_str(path).lower()
                                 for s in cfg.no_decay_substrings)
        for path, _ in flat_p
    }

    def update(path, p, g, mu, nu):
        g32 = g.astype(F32)
        mu = cfg.b1 * mu + (1.0 - cfg.b1) * g32
        nu = cfg.b2 * nu + (1.0 - cfg.b2) * jnp.square(g32)
        step = (mu / c1) / (jnp.sqrt(nu / c2) + cfg.eps)
        if decay_mask.get(_path_str(path), True) and cfg.weight_decay:
            step = step + cfg.weight_decay * p.astype(F32)
        return (p.astype(F32) - lr * step).astype(p.dtype), mu, nu

    out = jax.tree_util.tree_map_with_path(
        update, params, grads, state["mu"], state["nu"])
    # out leaves are (p, mu, nu) tuples; unzip
    new_params = jax.tree_util.tree_map(
        lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_mu = jax.tree_util.tree_map(
        lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    new_nu = jax.tree_util.tree_map(
        lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    new_state = {"mu": new_mu, "nu": new_nu, "count": count}
    return new_params, new_state, {"grad_norm": gnorm}
