"""Learning-rate schedules (pure functions of the step counter)."""
from __future__ import annotations

import jax.numpy as jnp


def linear_warmup(step, warmup_steps: int, peak: float):
    """0 -> peak over ``warmup_steps`` (then flat)."""
    frac = jnp.minimum(step.astype(jnp.float32) / max(warmup_steps, 1), 1.0)
    return peak * frac


def cosine_schedule(step, warmup_steps: int, total_steps: int, peak: float,
                    floor: float = 0.0):
    """Linear warmup then cosine decay to ``floor`` at ``total_steps``."""
    step = step.astype(jnp.float32)
    warm = linear_warmup(step, warmup_steps, peak)
    prog = jnp.clip((step - warmup_steps)
                    / max(total_steps - warmup_steps, 1), 0.0, 1.0)
    cos = floor + 0.5 * (peak - floor) * (1.0 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < warmup_steps, warm, cos)
