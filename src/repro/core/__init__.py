"""ECF8 core: exponent-concentration theory and lossless fp8 compression."""
from . import fp8, theory, stats, huffman, paper_format, tpu_format, fixedrate, store  # noqa: F401
from .store import (  # noqa: F401
    CompressedTensor,
    compress_array,
    compress_stacked,
    compress_tree,
    fp8_cast_tree,
    is_compressed,
    materialize,
)
