"""Compressed parameter store — the paper's §3.3 tensor manager, JAX-native.

The paper intercepts PyTorch forward hooks and decompresses each layer into a
single pre-allocated GPU buffer.  The JAX-native equivalent: parameters are a
pytree in which large weights are ``CompressedTensor`` leaves; model code
calls :func:`materialize` at the point of use, *inside* the jitted step.
Under scan-over-layers, XLA's buffer allocator reuses one decode buffer
across layers — the same constant-overhead property, with no host round-trip.

``CompressedTensor`` is a registered pytree, so it passes transparently
through ``jax.jit`` / ``lax.scan`` (stacked layer compression: every child
array carries a leading layer dim and scan slices it per step).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable

import numpy as np
import jax
import jax.numpy as jnp

from . import fixedrate, fp8, tpu_format

FORMAT_NONE = "none"
FORMAT_TPU = "tpu"          # ECF8-TPU interleaved Huffman (uniform layout)
FORMAT_FIXEDRATE = "fixedrate"  # ECF8-FR 2-bit + escapes


@dataclass(frozen=True)
class CompressedMeta:
    fmt: str
    shape: tuple
    n_elem: int
    sym_per_lane: int = 0
    esc_capacity: int = 0
    out_dtype: str = "bfloat16"


@jax.tree_util.register_pytree_node_class
@dataclass
class CompressedTensor:
    """A compressed fp8 weight; decodes on use inside the jitted step."""

    arrays: dict  # name -> jnp.ndarray (pytree children)
    meta: CompressedMeta  # static

    def tree_flatten(self):
        names = tuple(sorted(self.arrays))
        return tuple(self.arrays[k] for k in names), (names, self.meta)

    @classmethod
    def tree_unflatten(cls, aux, children):
        names, meta = aux
        return cls(arrays=dict(zip(names, children)), meta=meta)

    @property
    def shape(self):  # so shape-inspecting model code keeps working
        return self.meta.shape

    @property
    def ndim(self):
        return len(self.meta.shape)

    def nbytes_compressed(self) -> int:
        return sum(int(np.prod(a.shape)) * a.dtype.itemsize
                   for a in self.arrays.values())


def is_compressed(x: Any) -> bool:
    return isinstance(x, CompressedTensor)


def materialize(x, dtype=None):
    """Decode a CompressedTensor to a dense array (identity for arrays)."""
    if not is_compressed(x):
        if dtype is not None and hasattr(x, "astype"):
            return x.astype(dtype)
        return x
    m = x.meta
    a = x.arrays
    if m.fmt == FORMAT_TPU:
        bits = tpu_format._decode_jnp_impl(
            a["payload"], a["signmant"], a["lj_limit"], a["first_lj"],
            a["offset"], a["perm"], sym_per_lane=m.sym_per_lane,
            n_elem=m.n_elem,
        )
    elif m.fmt == FORMAT_FIXEDRATE:
        bits = fixedrate._decode_jnp_impl(
            a["codes"], a["escapes"], a["table"], a["signmant"],
            n_elem=m.n_elem,
        )
    else:
        raise ValueError(f"unknown format {m.fmt}")
    w8 = bits.view(fp8.FP8_DTYPE).reshape(m.shape)
    out_dtype = dtype if dtype is not None else m.out_dtype
    return w8.astype(out_dtype)


# --------------------------------------------------------------------------
# encoding (host side, numpy)
# --------------------------------------------------------------------------

def compress_array(w8_bits: np.ndarray, fmt: str = FORMAT_TPU,
                   out_dtype: str = "bfloat16",
                   sym_per_lane: int = tpu_format.DEFAULT_SYM_PER_LANE,
                   ) -> CompressedTensor:
    """Compress one fp8 tensor (uint8 bit view, any shape)."""
    if fmt == FORMAT_TPU:
        c = tpu_format.encode(w8_bits, sym_per_lane=sym_per_lane)
        arrays = {
            "payload": jnp.asarray(c.payload),
            "signmant": jnp.asarray(c.signmant),
            "lj_limit": jnp.asarray(c.lj_limit),
            "first_lj": jnp.asarray(c.first_lj),
            "offset": jnp.asarray(c.offset),
            "perm": jnp.asarray(c.perm),
        }
        meta = CompressedMeta(fmt=fmt, shape=tuple(c.shape), n_elem=c.n_elem,
                              sym_per_lane=c.sym_per_lane, out_dtype=out_dtype)
    elif fmt == FORMAT_FIXEDRATE:
        c = fixedrate.encode(w8_bits)
        arrays = {
            "codes": jnp.asarray(c.codes),
            "escapes": jnp.asarray(c.escapes),
            "table": jnp.asarray(c.table),
            "signmant": jnp.asarray(c.signmant),
        }
        meta = CompressedMeta(fmt=fmt, shape=tuple(c.shape), n_elem=c.n_elem,
                              esc_capacity=c.esc_capacity, out_dtype=out_dtype)
    else:
        raise ValueError(f"unknown format {fmt}")
    return CompressedTensor(arrays=arrays, meta=meta)


def compress_stacked(w8_bits_stack: np.ndarray, fmt: str = FORMAT_TPU,
                     out_dtype: str = "bfloat16",
                     sym_per_lane: int = tpu_format.DEFAULT_SYM_PER_LANE,
                     ) -> CompressedTensor:
    """Compress a (layers, ...) stacked fp8 tensor layer-by-layer.

    Each child array gains a leading ``layers`` dim; ``lax.scan`` slices it
    so :func:`materialize` inside the scan body sees one layer's container.
    Per-layer codebooks are kept (entropy varies per layer, paper Fig. 1);
    payload strides / escape capacities are padded to the per-stack max so
    the stack is rectangular.
    """
    L = w8_bits_stack.shape[0]
    per_layer = [
        compress_array(np.asarray(w8_bits_stack[i]), fmt=fmt,
                       out_dtype=out_dtype, sym_per_lane=sym_per_lane)
        for i in range(L)
    ]
    if fmt == FORMAT_TPU:
        # pad payloads to common stride
        stride = max(ct.arrays["payload"].shape[1] for ct in per_layer)
        for ct in per_layer:
            p = np.asarray(ct.arrays["payload"])
            if p.shape[1] < stride:
                p = np.pad(p, ((0, 0), (0, stride - p.shape[1]), (0, 0)))
            ct.arrays["payload"] = jnp.asarray(p)
    elif fmt == FORMAT_FIXEDRATE:
        cap2 = max(ct.arrays["escapes"].shape[0] for ct in per_layer)
        for ct in per_layer:
            e = np.asarray(ct.arrays["escapes"])
            if e.shape[0] < cap2:
                e = np.pad(e, (0, cap2 - e.shape[0]))
            ct.arrays["escapes"] = jnp.asarray(e)
    arrays = {
        k: jnp.stack([ct.arrays[k] for ct in per_layer])
        for k in per_layer[0].arrays
    }
    return CompressedTensor(arrays=arrays, meta=per_layer[0].meta)


def compress_tree(params, fmt: str = FORMAT_TPU, min_elems: int = 65536,
                  out_dtype: str = "bfloat16", stacked_axes="auto",
                  predicate: Callable[[Any], bool] | None = None):
    """Cast a parameter pytree to fp8 and compress the large leaves.

    ``stacked_axes``: 1 treats each leaf's leading dim as a scan (layer)
    axis; 0 treats leaves as single tensors; "auto" (default) stacks leaves
    whose path goes through a scan collection ("units" / "layers") — the
    model's parameter layout.  Small leaves (norm scales, biases) stay in
    their original dtype — same policy as the paper, which compresses only
    weight matrices.  Returns (compressed_tree, report dict).
    """
    report = {"raw_bytes": 0, "fp8_bytes": 0, "compressed_bytes": 0,
              "n_compressed": 0, "n_kept": 0}

    def visit(path, x):
        if not hasattr(x, "shape") or (predicate and not predicate(x)):
            report["n_kept"] += 1
            return x
        if stacked_axes == "auto":
            names = [str(getattr(k, "key", getattr(k, "idx", k)))
                     for k in path]
            stacked = int("units" in names or "layers" in names)
        else:
            stacked = int(stacked_axes)
        n = int(np.prod(x.shape)) if x.ndim else 1
        report["raw_bytes"] += n * x.dtype.itemsize
        per_layer_elems = n // x.shape[0] if (stacked and x.ndim) else n
        if per_layer_elems < min_elems or x.ndim < 2 + stacked:
            report["n_kept"] += 1
            return x
        w8 = np.asarray(jnp.asarray(x).astype(fp8.FP8_DTYPE)).view(np.uint8)
        report["fp8_bytes"] += n
        if stacked:
            ct = compress_stacked(w8, fmt=fmt, out_dtype=out_dtype)
        else:
            ct = compress_array(w8, fmt=fmt, out_dtype=out_dtype)
        report["compressed_bytes"] += ct.nbytes_compressed()
        report["n_compressed"] += 1
        return ct

    tree = jax.tree_util.tree_map_with_path(visit, params)
    return tree, report


def fp8_cast_tree(params, min_elems: int = 65536, stacked_axes="auto"):
    """The FP8 *baseline*: cast large weights to fp8, keep the rest.

    This is what ECF8 is compared against (the paper compresses released FP8
    checkpoints; the fp8 cast itself defines the baseline bits).  The leaf
    selection rule matches :func:`compress_tree` exactly so the two trees
    are bit-comparable."""
    def visit(path, x):
        if not hasattr(x, "shape"):
            return x
        if stacked_axes == "auto":
            names = [str(getattr(k, "key", getattr(k, "idx", k)))
                     for k in path]
            stacked = int("units" in names or "layers" in names)
        else:
            stacked = int(stacked_axes)
        n = int(np.prod(x.shape)) if x.ndim else 1
        per_layer = n // x.shape[0] if (stacked and x.ndim) else n
        if per_layer < min_elems or x.ndim < 2 + stacked:
            return x
        return jnp.asarray(x).astype(fp8.FP8_DTYPE)
    return jax.tree_util.tree_map_with_path(visit, params)
