"""FP8 (float8_e4m3fn) bit-field utilities.

Bit layout (IEEE-754-style, e4m3fn):  [s eeee mmm]
  bit 7      : sign
  bits 6..3  : 4-bit exponent field (biased by 7; field value 0 = subnormal)
  bits 2..0  : 3-bit mantissa

ECF8 splits each byte into the 4-bit exponent field (entropy-coded) and the
4-bit sign+mantissa nibble ``q = (s << 3) | m`` (stored packed, two per byte).

All functions work on the raw ``uint8`` bit view and are implemented for both
numpy (offline encode path) and jax.numpy (in-graph decode path) via the
``xp`` module argument.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

FP8_DTYPE = jnp.float8_e4m3fn

EXP_BITS = 4
MANT_BITS = 3
EXP_BIAS = 7
N_EXP_SYMBOLS = 1 << EXP_BITS  # 16


def to_bits(x) -> "jnp.ndarray":
    """View an fp8 array as raw uint8 bits (no copy semantics where possible)."""
    if isinstance(x, np.ndarray):
        return x.view(np.uint8)
    return jnp.asarray(x).view(jnp.uint8)


def from_bits(bits, xp=jnp):
    """View raw uint8 bits as fp8 values."""
    if xp is np:
        return np.asarray(bits, dtype=np.uint8).view(jnp.float8_e4m3fn)
    return jnp.asarray(bits, dtype=jnp.uint8).view(FP8_DTYPE)


def exponent_field(bits, xp=jnp):
    """Extract the 4-bit exponent field (values 0..15)."""
    return (bits >> 3) & xp.uint8(0x0F)


def signmant_nibble(bits, xp=jnp):
    """Extract the 4-bit sign+mantissa nibble ``(s << 3) | m``."""
    return ((bits >> 4) & xp.uint8(0x08)) | (bits & xp.uint8(0x07))


def assemble(exp_field, signmant, xp=jnp):
    """Rebuild the fp8 byte from a 4-bit exponent field and 4-bit s+m nibble."""
    exp_field = exp_field.astype(xp.uint8)
    signmant = signmant.astype(xp.uint8)
    return (
        ((signmant & xp.uint8(0x08)) << 4)
        | ((exp_field & xp.uint8(0x0F)) << 3)
        | (signmant & xp.uint8(0x07))
    )


def pack_nibbles(nibbles, xp=np):
    """Pack 4-bit values two-per-byte (element 2i -> high nibble of byte i)."""
    n = nibbles.shape[0]
    padded = nibbles
    if n % 2:
        pad = xp.zeros((1,), dtype=xp.uint8)
        padded = xp.concatenate([nibbles.astype(xp.uint8), pad])
    pairs = padded.reshape(-1, 2)
    return (pairs[:, 0] << 4) | (pairs[:, 1] & xp.uint8(0x0F))


def unpack_nibbles(packed, n, xp=jnp):
    """Inverse of :func:`pack_nibbles`; returns ``n`` 4-bit values."""
    hi = (packed >> 4) & xp.uint8(0x0F)
    lo = packed & xp.uint8(0x0F)
    out = xp.stack([hi, lo], axis=-1).reshape(-1)
    return out[:n]


def cast_to_fp8(x, xp=jnp):
    """Round-to-nearest cast of a float array to fp8 e4m3fn."""
    if xp is np:
        return np.asarray(jnp.asarray(x).astype(FP8_DTYPE))
    return jnp.asarray(x).astype(FP8_DTYPE)
