"""Empirical exponent statistics (paper §2.1, Figure 1).

Utilities to measure exponent histograms / Shannon entropy of fp8 weight
tensors and to synthesize "trained-like" weights from the paper's own
statistical model (alpha-stable), used by benchmarks and tests.
"""
from __future__ import annotations

import numpy as np

from . import fp8, theory


def exponent_histogram(bits: np.ndarray) -> np.ndarray:
    """Histogram (length 16) of the 4-bit exponent field of fp8 bit view."""
    exps = fp8.exponent_field(np.asarray(bits, dtype=np.uint8).reshape(-1), xp=np)
    return np.bincount(exps, minlength=fp8.N_EXP_SYMBOLS).astype(np.int64)


def shannon_entropy(counts: np.ndarray) -> float:
    """Shannon entropy (bits/symbol) of an empirical histogram."""
    counts = np.asarray(counts, dtype=np.float64)
    total = counts.sum()
    if total == 0:
        return 0.0
    p = counts[counts > 0] / total
    return float(-(p * np.log2(p)).sum())


def tensor_exponent_entropy(w) -> float:
    """Exponent-field entropy (bits/weight) of an fp8 tensor."""
    bits = np.asarray(fp8.to_bits(w)).reshape(-1)
    return shannon_entropy(exponent_histogram(bits))


def synthesize_fp8_weights(
    shape, alpha: float = 1.9, std: float = 0.15, seed: int = 0
) -> np.ndarray:
    """Synthesize fp8 weights following the paper's statistical law.

    Samples symmetric alpha-stable values (the paper's model of SGD-trained
    weights, §2.2.1), scales them to a typical trained-weight magnitude, and
    rounds to fp8 e4m3fn.  Returns the raw uint8 bit view.
    """
    x = theory.sample_alpha_stable(shape, alpha=alpha, seed=seed)
    # scale so the central mass lands at |w| ~ std, like trained weights
    x = x * std
    # fp8 e4m3fn saturates at +-448; heavy tails would otherwise overflow
    x = np.clip(x, -448.0, 448.0)
    w8 = fp8.cast_to_fp8(x, xp=np)
    return np.asarray(w8).view(np.uint8)


def alpha_fit_from_values(x: np.ndarray) -> float:
    """Estimate alpha from real-valued samples via the unclipped exponent law
    E = floor(log2|x|) (avoids fp8 subnormal-clipping bias)."""
    x = np.asarray(x, dtype=np.float64).reshape(-1)
    x = x[np.isfinite(x) & (x != 0)]
    if x.size < 16:
        return float("nan")
    E = np.floor(np.log2(np.abs(x))).astype(np.int64)
    E -= int(np.bincount(E - E.min()).argmax()) + E.min()  # center at mode
    counts = np.bincount(np.abs(E))
    return theory.geometric_fit_alpha_onesided(counts)


def summarize_tensor(bits: np.ndarray) -> dict:
    """Entropy / fitted-alpha / theory-bound summary for one tensor."""
    hist = exponent_histogram(bits)
    H = shannon_entropy(hist)
    alpha_hat = theory.geometric_fit_alpha(hist)
    lo, hi = (
        theory.exponent_entropy_bounds(alpha_hat)
        if np.isfinite(alpha_hat)
        else (float("nan"), float("nan"))
    )
    return {
        "n": int(hist.sum()),
        "entropy_bits": H,
        "alpha_hat": alpha_hat,
        "bound_lo": lo,
        "bound_hi": hi,
        "hist": hist.tolist(),
    }
