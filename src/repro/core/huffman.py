"""Canonical Huffman coding over the 16 fp8 exponent symbols (paper §3.1).

The paper constrains maximum code length to 16 bits via heuristic frequency
adjustment; we instead use the *package-merge* algorithm, which is optimal
among length-limited prefix codes (strictly at least as good).  The TPU
format (``tpu_format.py``) uses a cap of 8 so decode is a single 8-bit peek.

Codes are *canonical*: symbols sorted by (length, symbol) receive
lexicographically increasing codes, which enables the gather-free
compare/select decoder used by the Pallas kernel.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field

import numpy as np

N_SYMBOLS = 16


def huffman_lengths(freqs: np.ndarray) -> np.ndarray:
    """Unrestricted Huffman code lengths (0 for zero-frequency symbols)."""
    freqs = np.asarray(freqs, dtype=np.int64)
    active = [int(s) for s in np.nonzero(freqs)[0]]
    lengths = np.zeros(len(freqs), dtype=np.int32)
    if not active:
        return lengths
    if len(active) == 1:
        lengths[active[0]] = 1
        return lengths
    heap = [(int(freqs[s]), (s,)) for s in active]
    heapq.heapify(heap)
    while len(heap) > 1:
        w1, s1 = heapq.heappop(heap)
        w2, s2 = heapq.heappop(heap)
        for s in s1 + s2:
            lengths[s] += 1
        heapq.heappush(heap, (w1 + w2, s1 + s2))
    return lengths


def package_merge_lengths(freqs: np.ndarray, max_len: int) -> np.ndarray:
    """Optimal length-limited code lengths via package-merge."""
    freqs = np.asarray(freqs, dtype=np.int64)
    active = [int(s) for s in np.nonzero(freqs)[0]]
    lengths = np.zeros(len(freqs), dtype=np.int32)
    n = len(active)
    if n == 0:
        return lengths
    if n == 1:
        lengths[active[0]] = 1
        return lengths
    if (1 << max_len) < n:
        raise ValueError(f"max_len={max_len} cannot encode {n} symbols")
    originals = sorted((int(freqs[s]), (s,)) for s in active)
    prev: list[tuple[int, tuple[int, ...]]] = []
    for _ in range(max_len):
        packages = []
        for i in range(0, len(prev) - 1, 2):
            packages.append(
                (prev[i][0] + prev[i + 1][0], prev[i][1] + prev[i + 1][1])
            )
        prev = sorted(originals + packages)
    for _, syms in prev[: 2 * n - 2]:
        for s in syms:
            lengths[s] += 1
    return lengths


def canonical_codes(lengths: np.ndarray) -> np.ndarray:
    """Canonical code values (int) per symbol, given code lengths."""
    lengths = np.asarray(lengths, dtype=np.int32)
    order = sorted(s for s in range(len(lengths)) if lengths[s] > 0)
    order.sort(key=lambda s: (lengths[s], s))
    codes = np.zeros(len(lengths), dtype=np.int64)
    code = 0
    prev_len = 0
    for i, s in enumerate(order):
        l = int(lengths[s])
        if i == 0:
            code = 0
        else:
            code = (code + 1) << (l - prev_len)
        codes[s] = code
        prev_len = l
    return codes


def kraft_sum(lengths: np.ndarray) -> float:
    lengths = np.asarray(lengths)
    ls = lengths[lengths > 0]
    return float(np.sum(2.0 ** (-ls.astype(np.float64))))


def expected_length(freqs: np.ndarray, lengths: np.ndarray) -> float:
    freqs = np.asarray(freqs, dtype=np.float64)
    total = freqs.sum()
    if total == 0:
        return 0.0
    return float((freqs * lengths).sum() / total)


@dataclass
class Codebook:
    """A canonical Huffman codebook over an exponent-symbol alphabet.

    The alphabet size is ``len(lengths)`` — 16 for the fp8 4-bit exponent
    field (the paper's case), 256 for the 8-bit exponent field of
    bf16/f32 K/V-cache pages (``repro.kvcache.codec``)."""

    lengths: np.ndarray  # (16,) int32, 0 => unused symbol
    codes: np.ndarray  # (16,) int64 canonical code values
    max_len: int

    # --- canonical-decode tables (computed lazily) -----------------------
    # sorted_syms[i]  : i-th symbol in canonical (length, symbol) order
    # lj_limit[l-1]   : exclusive upper bound, left-justified to max_len bits,
    #                   of codes with length <= l (monotone nondecreasing)
    # first_lj[l-1]   : first code of length l, left-justified to max_len bits
    # offset[l-1]     : index into sorted_syms of the first length-l symbol
    sorted_syms: np.ndarray = field(default=None)  # type: ignore[assignment]
    lj_limit: np.ndarray = field(default=None)  # type: ignore[assignment]
    first_lj: np.ndarray = field(default=None)  # type: ignore[assignment]
    offset: np.ndarray = field(default=None)  # type: ignore[assignment]

    @classmethod
    def from_freqs(cls, freqs: np.ndarray, max_len: int = 16) -> "Codebook":
        lengths = package_merge_lengths(freqs, max_len)
        codes = canonical_codes(lengths)
        cb = cls(lengths=lengths, codes=codes, max_len=max_len)
        cb._build_decode_tables()
        return cb

    def _build_decode_tables(self) -> None:
        L = self.max_len
        order = [s for s in range(len(self.lengths)) if self.lengths[s] > 0]
        order.sort(key=lambda s: (self.lengths[s], s))
        n_syms = len(self.lengths)
        self.sorted_syms = np.asarray(order + [0] * (n_syms - len(order)),
                                      dtype=np.int32)
        lj_limit = np.zeros(L, dtype=np.int64)
        first_lj = np.zeros(L, dtype=np.int64)
        offset = np.zeros(L, dtype=np.int64)
        idx = 0
        running_limit = 0
        for l in range(1, L + 1):
            syms_l = [s for s in order if self.lengths[s] == l]
            offset[l - 1] = idx
            if syms_l:
                first = int(self.codes[syms_l[0]])
                first_lj[l - 1] = first << (L - l)
                running_limit = (first + len(syms_l)) << (L - l)
            else:
                first_lj[l - 1] = running_limit
            lj_limit[l - 1] = running_limit
            idx += len(syms_l)
        self.lj_limit = lj_limit
        self.first_lj = first_lj
        self.offset = offset

    # --- scalar decode (oracle) ------------------------------------------
    def decode_peek(self, peek: int) -> tuple[int, int]:
        """Decode a left-justified ``max_len``-bit peek -> (symbol, length)."""
        L = self.max_len
        for l in range(1, L + 1):
            if peek < self.lj_limit[l - 1]:
                sym_idx = self.offset[l - 1] + (
                    (peek - self.first_lj[l - 1]) >> (L - l)
                )
                return int(self.sorted_syms[sym_idx]), l
        raise ValueError(f"invalid peek {peek:0{L}b}")

    def encode_symbols(self, symbols: np.ndarray) -> tuple[np.ndarray, int]:
        """Encode a symbol sequence into a byte array (MSB-first bitstream).

        Returns (bytes, total_bits)."""
        symbols = np.asarray(symbols, dtype=np.int64)
        lens = self.lengths[symbols].astype(np.int64)
        codes = self.codes[symbols]
        total_bits = int(lens.sum())
        ends = np.cumsum(lens)
        starts = ends - lens
        nbytes = (total_bits + 7) // 8
        out = np.zeros(nbytes, dtype=np.uint8)
        # vectorized bit blit: expand each code into its bits
        if total_bits:
            bit_idx = np.repeat(starts, lens) + _concat_aranges(lens)
            shift = np.repeat(lens, lens) - 1 - _concat_aranges(lens)
            bits = (np.repeat(codes, lens) >> shift) & 1
            np.bitwise_or.at(
                out, bit_idx // 8, (bits << (7 - bit_idx % 8)).astype(np.uint8)
            )
        return out, total_bits

    def decode_bitstream(self, data: np.ndarray, n_symbols: int,
                         start_bit: int = 0) -> np.ndarray:
        """Sequential oracle decoder (numpy, slow)."""
        out = np.empty(n_symbols, dtype=np.uint8)
        bitpos = start_bit
        data = np.asarray(data, dtype=np.uint8)
        L = self.max_len
        for i in range(n_symbols):
            peek = 0
            for b in range(L):
                byte = bitpos + b
                bit = (int(data[byte // 8]) >> (7 - byte % 8)) & 1 \
                    if byte // 8 < len(data) else 0
                peek = (peek << 1) | bit
            sym, l = self.decode_peek(peek)
            out[i] = sym
            bitpos += l
        return out


def _concat_aranges(lens: np.ndarray) -> np.ndarray:
    """[arange(l) for l in lens], concatenated (vectorized)."""
    total = int(lens.sum())
    ids = np.arange(total)
    starts = np.repeat(np.cumsum(lens) - lens, lens)
    return ids - starts
