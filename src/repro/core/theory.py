"""Theory of exponent concentration (paper §2.2, Theorem 2.1 / Corollary 2.2).

If a weight ``X`` follows a symmetric alpha-stable law, its floating-point
exponent ``E = floor(log2 |X|)`` follows a discrete two-sided geometric
distribution with ratio ``q = 2**-alpha``:

    P(E = k) = (1 - q) / (1 + q) * q**|k|,   k in Z

whose Shannon entropy is bounded by

    alpha / (1 + 2**-alpha)  <=  H(E)  <=  alpha / (1 - 2**-alpha).

For alpha = 2 (the Gaussian-like case) the upper bound is 8/3 ~ 2.67 bits,
which with 1 sign bit and ~1 mantissa bit yields the paper's "FP4.67" limit.
"""
from __future__ import annotations

import math

import numpy as np


def two_sided_geometric_pmf(k: np.ndarray, alpha: float) -> np.ndarray:
    """P(E = k) for the two-sided geometric law of Theorem 2.1."""
    q = 2.0 ** (-alpha)
    k = np.asarray(k)
    return (1.0 - q) / (1.0 + q) * q ** np.abs(k)


def exponent_entropy_exact(alpha: float) -> float:
    """Exact Shannon entropy (bits) of the two-sided geometric exponent law.

    Closed form: with ``q = 2^-alpha`` and ``p0 = (1-q)/(1+q)``,
    ``H(E) = -log2(p0) + (2q / (1+q)) * |log2 q| / (1-q)``.
    """
    q = 2.0 ** (-alpha)
    p0 = (1.0 - q) / (1.0 + q)
    return -math.log2(p0) + (2.0 * q / (1.0 + q)) * (alpha / (1.0 - q))


def exponent_entropy_bounds(alpha: float) -> tuple[float, float]:
    """(lower, upper) entropy bounds of Theorem 2.1, in bits."""
    q = 2.0 ** (-alpha)
    return alpha / (1.0 + q), alpha / (1.0 - q)


def compression_limit_bits(alpha: float, mantissa_bits: int = 1) -> float:
    """Corollary 2.2: minimal average bits for a lossless float of alpha-stable
    weights = H(E) upper bound + sign + mantissa.  alpha=2, m=1 -> ~4.67."""
    return exponent_entropy_bounds(alpha)[1] + 1.0 + float(mantissa_bits)


def sample_alpha_stable(
    shape, alpha: float, scale: float = 1.0, seed: int = 0
) -> np.ndarray:
    """Sample a symmetric alpha-stable S_alpha(beta=0, gamma=scale, delta=0)
    via the Chambers–Mallows–Stuck construction (numpy, offline use)."""
    rng = np.random.default_rng(seed)
    u = rng.uniform(-np.pi / 2, np.pi / 2, size=shape)
    w = rng.exponential(1.0, size=shape)
    if abs(alpha - 1.0) < 1e-9:
        x = np.tan(u)
    else:
        x = (
            np.sin(alpha * u)
            / np.cos(u) ** (1.0 / alpha)
            * (np.cos(u - alpha * u) / w) ** ((1.0 - alpha) / alpha)
        )
    return (scale * x).astype(np.float64)


def geometric_fit_alpha_onesided(abs_counts: np.ndarray) -> float:
    """Fit alpha from counts of |E - mode| (k = 0, 1, 2, ...): weighted
    least-squares on log2 P ~ -alpha * k."""
    counts = np.asarray(abs_counts, dtype=np.float64)
    total = counts.sum()
    if total == 0:
        return float("nan")
    p = counts / total
    ks = np.asarray([k for k, pk in enumerate(p) if pk > 0 and k > 0],
                    dtype=np.float64)
    if ks.size < 2:
        return float("inf")
    ys = np.log2(p[ks.astype(int)])
    w = p[ks.astype(int)]
    A = np.stack([ks, np.ones_like(ks)], axis=1)
    coef, *_ = np.linalg.lstsq(A * w[:, None], ys * w, rcond=None)
    return float(-coef[0])


def geometric_fit_alpha(exp_counts: np.ndarray) -> float:
    """Estimate alpha from an empirical exponent histogram by fitting the
    geometric decay rate ``q = 2^-alpha`` of the tail around the mode.

    Robust least-squares fit of log2 P(E=k) ~ -alpha * |k - mode| + c over
    bins with nonzero mass."""
    counts = np.asarray(exp_counts, dtype=np.float64)
    total = counts.sum()
    if total == 0:
        return float("nan")
    p = counts / total
    mode = int(np.argmax(p))
    ks, ys = [], []
    for k, pk in enumerate(p):
        if pk > 0 and k != mode:
            ks.append(abs(k - mode))
            ys.append(np.log2(pk))
    if len(ks) < 2:
        return float("inf")
    ks = np.asarray(ks, dtype=np.float64)
    ys = np.asarray(ys, dtype=np.float64)
    # weighted by probability mass so the dense bins dominate
    w = 2.0 ** ys
    A = np.stack([ks, np.ones_like(ks)], axis=1)
    Aw = A * w[:, None]
    coef, *_ = np.linalg.lstsq(Aw, ys * w, rcond=None)
    return float(-coef[0])
