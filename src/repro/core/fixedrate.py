"""ECF8-FR: fixed-rate 2-bit exponent codes with escapes (beyond-paper).

Exponent concentration (paper §2) means the top-3 exponent values typically
cover 80–95 % of the mass.  ECF8-FR assigns a 2-bit code per element:
codes 0..2 index a per-tensor 3-entry exponent table, code 3 escapes to a
side array of raw 4-bit exponents stored in element order.

Unlike Huffman, *both* encode and decode are O(1) static-shape vector ops —
no bitstream, no data-dependent shapes.  This makes ECF8-FR usable:

  * inside jitted graphs at near-zero cost (serving decode-on-use),
  * inside collectives (compressed weight all-gather, `runtime/collectives`),
  * for on-device compression (checkpoint write path).

Rate: 2 + 4·p_escape bits/exponent (+4 sign/mantissa) vs the entropy H(E);
near-optimal precisely when exponents concentrate — the paper's own law.

Escape capacity is static per tensor: exact for frozen weights (serving,
checkpoints); for training-time collectives a safety margin is applied and
an overflow flag is surfaced (see DESIGN.md — recalibration trigger).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import numpy as np
import jax
import jax.numpy as jnp

from . import fp8

TABLE_SIZE = 3  # 2-bit codes: 3 table entries + 1 escape


@dataclass
class FixedRateECF8:
    """ECF8-FR compressed tensor (host-side numpy arrays)."""

    codes: np.ndarray      # (ceil(N/4),) uint8, four 2-bit codes per byte
    escapes: np.ndarray    # (ceil(cap/2),) uint8 nibble-packed raw exponents
    table: np.ndarray      # (3,) uint8 top-3 exponent values
    signmant: np.ndarray   # (ceil(N/2),) uint8 nibble-packed
    n_elem: int
    esc_capacity: int
    esc_count: int
    shape: tuple

    @property
    def nbytes(self) -> int:
        return (self.codes.nbytes + self.escapes.nbytes + self.table.nbytes
                + self.signmant.nbytes)

    @property
    def ratio(self) -> float:
        return self.nbytes / max(self.n_elem, 1)


def encode(weight_bits: np.ndarray, esc_capacity: int | None = None,
           margin: float = 1.0) -> FixedRateECF8:
    """Compress an fp8 tensor (uint8 bit view) into ECF8-FR (numpy)."""
    orig_shape = tuple(weight_bits.shape)
    flat = np.asarray(weight_bits, dtype=np.uint8).reshape(-1)
    n = flat.shape[0]
    exps = fp8.exponent_field(flat, xp=np)
    signmant = fp8.signmant_nibble(flat, xp=np)

    freqs = np.bincount(exps, minlength=16)
    table = np.argsort(-freqs, kind="stable")[:TABLE_SIZE].astype(np.uint8)

    code = np.full(n, 3, dtype=np.uint8)
    for i, t in enumerate(table):
        code[exps == t] = i
    esc_mask = code == 3
    esc_vals = exps[esc_mask]
    count = int(esc_vals.shape[0])
    cap = count if esc_capacity is None else int(esc_capacity)
    cap = max(int(np.ceil(cap * margin)), count, 1)

    esc_store = np.zeros(cap, dtype=np.uint8)
    esc_store[:count] = esc_vals

    # pack four 2-bit codes per byte (element 4i -> bits 7..6)
    n4 = -(-n // 4) * 4
    code_p = np.zeros(n4, dtype=np.uint8)
    code_p[:n] = code
    quads = code_p.reshape(-1, 4)
    codes = (quads[:, 0] << 6) | (quads[:, 1] << 4) | (quads[:, 2] << 2) | quads[:, 3]

    return FixedRateECF8(
        codes=codes.astype(np.uint8),
        escapes=fp8.pack_nibbles(esc_store, xp=np),
        table=table,
        signmant=fp8.pack_nibbles(signmant, xp=np),
        n_elem=n, esc_capacity=cap, esc_count=count, shape=orig_shape,
    )


def _unpack_codes(codes, n, xp=jnp):
    c = codes[:, None] if False else codes
    parts = xp.stack(
        [(c >> 6) & 3, (c >> 4) & 3, (c >> 2) & 3, c & 3], axis=-1
    ).reshape(-1)
    return parts[:n]


@partial(jax.jit, static_argnames=("n_elem",))
def _decode_jnp_impl(codes, escapes, table, signmant, n_elem: int):
    code = _unpack_codes(codes.astype(jnp.uint8), n_elem, xp=jnp)
    is_esc = code == 3
    # rank of each escape in element order
    esc_rank = jnp.cumsum(is_esc.astype(jnp.int32)) - 1
    esc_vals = fp8.unpack_nibbles(escapes, escapes.shape[0] * 2, xp=jnp)
    esc_e = jnp.take(esc_vals, jnp.clip(esc_rank, 0, esc_vals.shape[0] - 1))
    tab_e = jnp.take(table.astype(jnp.uint8), jnp.minimum(code, 2))
    exps = jnp.where(is_esc, esc_e, tab_e)
    sm = fp8.unpack_nibbles(signmant, n_elem, xp=jnp)
    return fp8.assemble(exps, sm, xp=jnp)


def decode_jnp(c: FixedRateECF8) -> jnp.ndarray:
    """In-graph decode -> uint8 fp8 bits (n_elem,)."""
    return _decode_jnp_impl(
        jnp.asarray(c.codes), jnp.asarray(c.escapes), jnp.asarray(c.table),
        jnp.asarray(c.signmant), n_elem=c.n_elem,
    )


def decode_ref(c: FixedRateECF8) -> np.ndarray:
    """Numpy oracle decode -> original uint8 fp8 bit view."""
    code = np.asarray(_unpack_codes(c.codes, c.n_elem, xp=np))
    esc_vals = np.asarray(fp8.unpack_nibbles(c.escapes, c.escapes.shape[0] * 2,
                                             xp=np))
    is_esc = code == 3
    esc_rank = np.cumsum(is_esc) - 1
    exps = np.where(
        is_esc,
        esc_vals[np.clip(esc_rank, 0, len(esc_vals) - 1)],
        c.table[np.minimum(code, 2)],
    ).astype(np.uint8)
    sm = np.asarray(fp8.unpack_nibbles(c.signmant, c.n_elem, xp=np))
    return fp8.assemble(exps, sm, xp=np).reshape(c.shape)


@partial(jax.jit, static_argnames=("esc_capacity",))
def encode_jnp(weight_bits: jnp.ndarray, table: jnp.ndarray,
               esc_capacity: int):
    """On-device ECF8-FR encode with a *fixed* table and escape capacity.

    Returns (codes, escapes, overflowed) — all static shapes, so this can run
    inside jit / shard_map (compressed collectives).  ``overflowed`` is True
    iff the escape count exceeded capacity (the result is then invalid and
    the caller must fall back / recalibrate — surfaced as a metric).
    """
    flat = weight_bits.reshape(-1)
    n = flat.shape[0]
    exps = fp8.exponent_field(flat, xp=jnp)
    code = jnp.full((n,), 3, dtype=jnp.uint8)
    for i in range(TABLE_SIZE):
        code = jnp.where(exps == table[i], jnp.uint8(i), code)
    is_esc = code == 3
    count = is_esc.sum()
    pos = jnp.cumsum(is_esc.astype(jnp.int32)) - 1
    esc_store = jnp.zeros((esc_capacity,), dtype=jnp.uint8)
    # out-of-bounds indices (non-escapes, overflow) are dropped entirely
    esc_store = esc_store.at[jnp.where(is_esc, pos, esc_capacity)].set(
        exps, mode="drop"
    )

    n4 = -(-n // 4) * 4
    code_p = jnp.zeros((n4,), dtype=jnp.uint8).at[:n].set(code)
    quads = code_p.reshape(-1, 4)
    codes = ((quads[:, 0] << 6) | (quads[:, 1] << 4)
             | (quads[:, 2] << 2) | quads[:, 3])
    signmant = fp8.signmant_nibble(flat, xp=jnp)
    return codes, esc_store, signmant, count > esc_capacity
