"""Faithful implementation of the paper's ECF8 container (§3.1, Algorithm 1).

Layout (per tensor):
  encoded : Huffman bitstream of the 4-bit exponents, MSB-first   (n_bytes,)
  packed  : sign+mantissa nibbles, two per byte                   (ceil(N/2),)
  LUT     : cascaded 8-bit decode subtables + final length table  (n_luts, 256)
  gaps    : per-thread 4-bit bit offsets, two per byte
  outpos  : per-block cumulative output positions (int64)

Threads process ``B`` bytes each, ``T`` threads per block.  ``gaps[t]`` is the
bit offset, within thread t's byte window, of the first codeword that *starts*
in that window; ``outpos[b]`` is the number of symbols decoded by blocks
``< b``.  Max code length is 16 bits, so a codeword spans at most 2 bytes of
lookahead and gaps always fit 4 bits (paper §3.1).

The decoder here is the numpy *oracle* used to validate the Pallas port
(`kernels/paper_block_decode.py`) and the TPU-adapted format.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from . import fp8
from .huffman import Codebook

# Paper constants (Algorithm 1 uses B+2 = 10 lookahead bytes => B = 8).
BYTES_PER_THREAD = 8
THREADS_PER_BLOCK = 128
MAX_CODE_LEN = 16
LUT_POINTER_BASE = 240  # entries >= 240 are pointers; subtable = 256 - entry


@dataclass
class PaperECF8:
    """The paper's compressed tensor container (host-side numpy arrays)."""

    encoded: np.ndarray  # uint8 bitstream
    packed: np.ndarray  # uint8 nibble-packed sign/mantissa
    lut: np.ndarray  # (n_luts, 256) uint8 cascaded tables (+ length table last)
    gaps: np.ndarray  # uint8, two 4-bit gaps per byte
    outpos: np.ndarray  # int64 per-block output positions (n_blocks + 1)
    n_elem: int
    shape: tuple
    codebook: Codebook

    @property
    def n_bytes_total(self) -> int:
        """Total compressed footprint in bytes (all components)."""
        return (
            self.encoded.nbytes
            + self.packed.nbytes
            + self.lut.nbytes
            + self.gaps.nbytes
            + self.outpos.nbytes
        )

    @property
    def ratio(self) -> float:
        """Compressed bytes / original fp8 bytes (1 byte per element)."""
        return self.n_bytes_total / max(self.n_elem, 1)


def build_cascaded_lut(cb: Codebook) -> np.ndarray:
    """Build the paper's cascaded 8-bit lookup tables.

    Table 0 is the root.  Entry values:
      < 16               : decoded symbol (complete code within this byte)
      in [240, 255]      : pointer; subtable index = 256 - value
    The *last* table is the length table: ``lut[-1, x] = len(code(x))``.
    """
    # byte-aligned proper prefixes of codes longer than 8 bits
    prefixes: list[int] = []
    for s in range(16):
        l = int(cb.lengths[s])
        if l > 8:
            p = int(cb.codes[s]) >> (l - 8)
            if p not in prefixes:
                prefixes.append(p)
    n_luts = 1 + len(prefixes) + 1  # root + subtables + length table
    if len(prefixes) > 16:
        raise ValueError("too many subtables for pointer encoding")
    lut = np.zeros((n_luts, 256), dtype=np.uint8)

    for b in range(256):
        # find a code of length <= 8 that is a left-justified prefix of b
        hit = False
        for s in range(16):
            l = int(cb.lengths[s])
            if 0 < l <= 8 and (b >> (8 - l)) == int(cb.codes[s]):
                lut[0, b] = s
                hit = True
                break
        if not hit:
            # must be the start of a longer code: pointer to its subtable
            for j, p in enumerate(prefixes):
                if b == p:
                    lut[0, b] = 256 - (j + 1)
                    hit = True
                    break
        if not hit:
            lut[0, b] = 0  # unreachable padding pattern

    for j, p in enumerate(prefixes):
        for b in range(256):
            for s in range(16):
                l = int(cb.lengths[s])
                if l > 8 and (int(cb.codes[s]) >> (l - 8)) == p:
                    # low byte of the 16-bit left-justified code = tail bits
                    tail_byte = (int(cb.codes[s]) << (16 - l)) & 0xFF
                    tail_bits = l - 8
                    if (b >> (8 - tail_bits)) == (tail_byte >> (8 - tail_bits)):
                        lut[1 + j, b] = s
                        break

    lut[-1, :16] = cb.lengths[:16]
    return lut


def encode(weight_bits: np.ndarray, max_len: int = MAX_CODE_LEN,
           bytes_per_thread: int = BYTES_PER_THREAD,
           threads_per_block: int = THREADS_PER_BLOCK) -> PaperECF8:
    """Compress an fp8 tensor (uint8 bit view) into the paper's container."""
    orig_shape = tuple(weight_bits.shape)
    flat = np.asarray(weight_bits, dtype=np.uint8).reshape(-1)
    n = flat.shape[0]
    exps = fp8.exponent_field(flat, xp=np)
    signmant = fp8.signmant_nibble(flat, xp=np)
    packed = fp8.pack_nibbles(signmant, xp=np)

    freqs = np.bincount(exps, minlength=16)
    cb = Codebook.from_freqs(freqs, max_len=max_len)
    lut = build_cascaded_lut(cb)

    encoded, total_bits = cb.encode_symbols(exps)

    # --- synchronization metadata (gaps, outpos) --------------------------
    B, T = bytes_per_thread, threads_per_block
    block_bytes = B * T
    n_bytes = encoded.shape[0]
    n_blocks = max(1, -(-n_bytes // block_bytes))
    n_threads = n_blocks * T

    lens = cb.lengths[exps].astype(np.int64)
    starts = np.cumsum(lens) - lens  # bit position where each symbol starts

    # first symbol starting at or after each thread-window start bit
    window_starts = np.arange(n_threads, dtype=np.int64) * (8 * B)
    first_sym = np.searchsorted(starts, window_starts, side="left")
    gap_bits = np.where(
        first_sym < n,
        starts[np.minimum(first_sym, n - 1)] - window_starts,
        0,
    )
    gap_bits = np.clip(gap_bits, 0, 15).astype(np.uint8)
    gaps = fp8.pack_nibbles(gap_bits, xp=np)

    # symbols whose codeword starts within block b's byte range
    block_starts_bits = np.arange(n_blocks + 1, dtype=np.int64) * (8 * block_bytes)
    outpos = np.searchsorted(starts, block_starts_bits, side="left").astype(np.int64)
    outpos[-1] = n

    # pad the stream so every thread can read B + 2 lookahead bytes
    padded_len = n_blocks * block_bytes + 2
    if encoded.shape[0] < padded_len:
        encoded = np.concatenate(
            [encoded, np.zeros(padded_len - encoded.shape[0], dtype=np.uint8)]
        )

    return PaperECF8(
        encoded=encoded, packed=packed, lut=lut, gaps=gaps, outpos=outpos,
        n_elem=n, shape=orig_shape, codebook=cb,
    )


def decode_sequential(c: PaperECF8) -> np.ndarray:
    """Sequential oracle decode -> original uint8 fp8 bit view."""
    syms = c.codebook.decode_bitstream(c.encoded, c.n_elem)
    signmant = fp8.unpack_nibbles(c.packed, c.n_elem, xp=np)
    out = fp8.assemble(syms.astype(np.uint8), np.asarray(signmant), xp=np)
    return out.reshape(c.shape)


def _decode_with_lut(encoded: np.ndarray, lut: np.ndarray, bitpos: int):
    """One LUT-cascade decode step at ``bitpos`` -> (symbol, length, newpos)."""
    n_luts = lut.shape[0]

    def peek_byte(p):
        byte0 = p // 8
        sh = p % 8
        b0 = int(encoded[byte0]) if byte0 < len(encoded) else 0
        b1 = int(encoded[byte0 + 1]) if byte0 + 1 < len(encoded) else 0
        return ((b0 << 8 | b1) >> (8 - sh)) & 0xFF

    x = int(lut[0, peek_byte(bitpos)])
    if x >= LUT_POINTER_BASE:
        x = int(lut[256 - x, peek_byte(bitpos + 8)])
    l = int(lut[n_luts - 1, x])
    return x, l, bitpos + l


def decode_blockparallel(c: PaperECF8) -> np.ndarray:
    """Numpy re-implementation of Algorithm 1's block/thread structure.

    Follows the two-phase schedule (count -> prefix-sum -> decode) per block,
    validating that the ``gaps``/``outpos`` metadata is sufficient for fully
    autonomous block decoding (the paper's key kernel property).
    """
    B, T = BYTES_PER_THREAD, THREADS_PER_BLOCK
    block_bytes = B * T
    n_blocks = len(c.outpos) - 1
    gap_vals = np.asarray(fp8.unpack_nibbles(c.gaps, n_blocks * T, xp=np))
    out_syms = np.zeros(c.n_elem, dtype=np.uint8)
    total_bits_limit = len(c.encoded) * 8

    for b in range(n_blocks):
        # Phase 1: per-thread symbol counting
        counts = np.zeros(T, dtype=np.int64)
        for t in range(T):
            tg = b * T + t
            start_bit = tg * 8 * B + int(gap_vals[tg])
            end_bit = (tg + 1) * 8 * B
            pos = start_bit
            cnt = 0
            while pos < min(end_bit, total_bits_limit):
                _, l, pos = _decode_with_lut(c.encoded, c.lut, pos)
                cnt += 1
            counts[t] = cnt
        # prefix sum -> per-thread output starts
        starts = int(c.outpos[b]) + np.concatenate([[0], np.cumsum(counts)[:-1]])
        # Phase 2: decode and write
        for t in range(T):
            tg = b * T + t
            pos = tg * 8 * B + int(gap_vals[tg])
            o = int(starts[t])
            o_end = min(o + int(counts[t]), c.n_elem)
            while o < o_end:
                x, l, pos = _decode_with_lut(c.encoded, c.lut, pos)
                out_syms[o] = x
                o += 1

    signmant = np.asarray(fp8.unpack_nibbles(c.packed, c.n_elem, xp=np))
    return fp8.assemble(out_syms, signmant, xp=np).reshape(c.shape)
