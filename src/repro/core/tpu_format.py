"""ECF8-TPU: the TPU-native adaptation of the paper's compressed container.

Design (DESIGN.md §3): instead of one sequential bitstream + per-thread bit
gaps (a GPU-warp construct), weights are encoded into **128 interleaved lane
streams per chunk** so an 8x128 TPU vector unit decodes 128 streams in
lockstep:

  * element ``i`` of chunk ``c`` maps to lane ``i % 128``, slot ``i // 128``;
  * every lane of every chunk carries exactly ``sym_per_lane`` symbols, so
    output positions are static (no counting phase / prefix sum needed);
  * codes are canonical Huffman with max length 8 (package-merge), decoded by
    comparing the 8-bit peek against per-length canonical limits — 8
    vectorized compare/selects, no table gathers;
  * chunk payloads are stored transposed ``(stride, 128)`` so "byte j of all
    lanes" is one contiguous vector row.

Two payload layouts:
  * ``uniform``: all chunks padded to the tensor-wide max lane stride —
    shape ``(C, stride, 128)``; decodable fully in parallel with plain jnp
    (used in-graph by serve steps on any backend);
  * ``ragged``: per-chunk strides + offsets — denser; consumed by the Pallas
    kernel via scalar-prefetch indexed blocks.

Both are bit-exact; the uniform padding tax is reported by benchmarks.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import numpy as np
import jax
import jax.numpy as jnp

from . import fp8
from .huffman import Codebook

LANES = 128
DEFAULT_SYM_PER_LANE = 256
MAX_CODE_LEN = 8
MIN_STRIDE = 4  # decode window preloads 4 bytes


@dataclass
class TpuECF8:
    """ECF8-TPU compressed tensor (host-side numpy arrays)."""

    payload: np.ndarray        # uniform: (C, stride, LANES) uint8
    payload_ragged: np.ndarray  # flat uint8, per-chunk (stride_c, LANES) blocks
    chunk_offsets: np.ndarray  # (C+1,) int32 byte offsets into payload_ragged
    chunk_strides: np.ndarray  # (C,) int32
    signmant: np.ndarray       # (ceil(N/2),) uint8 nibble-packed
    # canonical decode tables (all small)
    lj_limit: np.ndarray       # (8,) int32, exclusive, left-justified to 8 bits
    first_lj: np.ndarray       # (8,) int32
    offset: np.ndarray         # (8,) int32
    perm: np.ndarray           # (16,) int32 canonical-order symbol values
    lengths: np.ndarray        # (16,) int32 code length per symbol (encode side)
    n_elem: int
    shape: tuple
    sym_per_lane: int

    @property
    def num_chunks(self) -> int:
        return self.payload.shape[0]

    @property
    def stride(self) -> int:
        return self.payload.shape[1]

    def nbytes(self, layout: str = "ragged") -> int:
        tables = (
            self.lj_limit.nbytes + self.first_lj.nbytes + self.offset.nbytes
            + self.perm.nbytes
        )
        if layout == "uniform":
            return self.payload.nbytes + self.signmant.nbytes + tables
        return (
            self.payload_ragged.nbytes + self.chunk_offsets.nbytes
            + self.signmant.nbytes + tables
        )

    def ratio(self, layout: str = "ragged") -> float:
        return self.nbytes(layout) / max(self.n_elem, 1)


def encode(weight_bits: np.ndarray,
           sym_per_lane: int = DEFAULT_SYM_PER_LANE) -> TpuECF8:
    """Compress an fp8 tensor (uint8 bit view) into ECF8-TPU."""
    orig_shape = tuple(weight_bits.shape)
    flat = np.asarray(weight_bits, dtype=np.uint8).reshape(-1)
    n = flat.shape[0]
    exps = fp8.exponent_field(flat, xp=np).astype(np.int64)
    signmant = fp8.signmant_nibble(flat, xp=np)

    freqs = np.bincount(exps, minlength=16)
    cb = Codebook.from_freqs(freqs, max_len=MAX_CODE_LEN)

    # auto-cap the chunk so tensors smaller than one full chunk don't pay
    # a whole chunk of padding (small norm/bias tensors, smoke configs)
    S = min(sym_per_lane, max(-(-n // LANES), MIN_STRIDE))
    chunk_sym = LANES * S
    n_pad = -n % chunk_sym
    pad_sym = int(np.argmax(freqs))
    exps_p = np.concatenate([exps, np.full(n_pad, pad_sym, dtype=np.int64)])
    C = exps_p.shape[0] // chunk_sym

    # element (c, s, l) -> index c*chunk_sym + s*LANES + l
    exps_csl = exps_p.reshape(C, S, LANES)
    codes = cb.codes[exps_csl]                    # (C, S, L) int64
    lens = cb.lengths[exps_csl].astype(np.int64)  # (C, S, L)

    # per-lane streams: rows = (c, l), S symbols each
    codes_r = codes.transpose(0, 2, 1).reshape(C * LANES, S)
    lens_r = lens.transpose(0, 2, 1).reshape(C * LANES, S)
    starts_r = np.cumsum(lens_r, axis=1) - lens_r
    lane_bits = starts_r[:, -1] + lens_r[:, -1]          # (C*L,)
    lane_bytes = (lane_bits + 7) // 8
    stride_per_chunk = np.maximum(
        lane_bytes.reshape(C, LANES).max(axis=1), MIN_STRIDE
    ).astype(np.int64)
    stride = int(stride_per_chunk.max())

    # vectorized bit blit into (C*L, stride*8) bit matrix
    flat_lens = lens_r.reshape(-1)
    total_bits = int(flat_lens.sum())
    rep_rows = np.repeat(
        np.repeat(np.arange(C * LANES), S), flat_lens
    )
    within = _concat_aranges(flat_lens)
    bitpos = np.repeat(starts_r.reshape(-1), flat_lens) + within
    shift = np.repeat(flat_lens, flat_lens) - 1 - within
    bitvals = (np.repeat(codes_r.reshape(-1), flat_lens) >> shift) & 1
    bitmat = np.zeros((C * LANES, stride * 8), dtype=np.uint8)
    bitmat[rep_rows, bitpos] = bitvals.astype(np.uint8)

    weights = (1 << np.arange(7, -1, -1)).astype(np.uint16)
    bytemat = (
        bitmat.reshape(C * LANES, stride, 8).astype(np.uint16) * weights
    ).sum(axis=2).astype(np.uint8)                        # (C*L, stride)
    payload = bytemat.reshape(C, LANES, stride).transpose(0, 2, 1).copy()

    # ragged layout: per-chunk stride_c slices
    offsets = np.zeros(C + 1, dtype=np.int64)
    ragged_parts = []
    for c in range(C):
        sc = int(stride_per_chunk[c])
        ragged_parts.append(payload[c, :sc, :].reshape(-1))
        offsets[c + 1] = offsets[c] + sc * LANES
    payload_ragged = (
        np.concatenate(ragged_parts) if ragged_parts
        else np.zeros(0, dtype=np.uint8)
    )

    return TpuECF8(
        payload=payload,
        payload_ragged=payload_ragged,
        chunk_offsets=offsets.astype(np.int32),
        chunk_strides=stride_per_chunk.astype(np.int32),
        signmant=fp8.pack_nibbles(signmant, xp=np),
        lj_limit=cb.lj_limit.astype(np.int32),
        first_lj=cb.first_lj.astype(np.int32),
        offset=cb.offset.astype(np.int32),
        perm=cb.sorted_syms.astype(np.int32),
        lengths=cb.lengths.astype(np.int32),
        n_elem=n,
        shape=orig_shape,
        sym_per_lane=S,
    )


def decode_ref(c: TpuECF8) -> np.ndarray:
    """Readable per-lane numpy oracle -> original uint8 fp8 bit view."""
    C, stride, L = c.payload.shape
    S = c.sym_per_lane
    syms = np.zeros((C, S, L), dtype=np.uint8)
    cb = _codebook_view(c)
    for ci in range(C):
        for l in range(L):
            stream = c.payload[ci, :, l]
            bitpos = 0
            for s in range(S):
                peek = 0
                for b in range(MAX_CODE_LEN):
                    p = bitpos + b
                    bit = (int(stream[p // 8]) >> (7 - p % 8)) & 1 \
                        if p // 8 < stride else 0
                    peek = (peek << 1) | bit
                sym, ln = cb.decode_peek(peek)
                syms[ci, s, l] = sym
                bitpos += ln
    return _assemble(c, syms.reshape(-1)[: c.n_elem])


@partial(jax.jit, static_argnames=("sym_per_lane", "n_elem"))
def _decode_jnp_impl(payload, signmant, lj_limit, first_lj, offset, perm,
                     sym_per_lane: int, n_elem: int):
    """Vectorized decode of the uniform layout; all chunks in parallel.

    Maintains a per-lane left-aligned uint32 bit window; each round does the
    canonical compare/select decode on the top 8 bits, shifts, and refills at
    most one byte via a per-lane gather (take_along_axis).  Invariant: at the
    top of each round ``bits_valid >= 24 >= 8``.
    """
    C, stride, L = payload.shape
    S = sym_per_lane
    p32 = payload.astype(jnp.uint32)
    win = (
        (p32[:, 0, :] << 24) | (p32[:, 1, :] << 16)
        | (p32[:, 2, :] << 8) | p32[:, 3, :]
    )                                           # (C, L)
    byteptr = jnp.full((C, L), 4, dtype=jnp.int32)
    bits_valid = jnp.full((C, L), 32, dtype=jnp.int32)

    lj_limit_i = lj_limit.astype(jnp.int32)
    first_lj_i = first_lj.astype(jnp.int32)
    offset_i = offset.astype(jnp.int32)
    perm_i = perm.astype(jnp.int32)

    def round_fn(_, carry):
        win, byteptr, bits_valid, outs, s = carry
        peek = (win >> 24).astype(jnp.int32)    # (C, L) in [0, 256)
        lt = peek[..., None] < lj_limit_i[None, None, :]   # (C, L, 8)
        length = jnp.argmax(lt, axis=-1).astype(jnp.int32) + 1
        fl = jnp.take(first_lj_i, length - 1)
        off = jnp.take(offset_i, length - 1)
        sym_idx = off + ((peek - fl) >> (8 - length))
        sym = jnp.take(perm_i, sym_idx).astype(jnp.uint8)
        outs = jax.lax.dynamic_update_index_in_dim(outs, sym, s, axis=1)

        win = win << length.astype(jnp.uint32)
        bits_valid = bits_valid - length
        need = bits_valid <= 24
        safe_ptr = jnp.minimum(byteptr, stride - 1)
        nb = jnp.take_along_axis(
            payload, safe_ptr[:, None, :], axis=1
        )[:, 0, :].astype(jnp.uint32)
        win = jnp.where(
            need, win | (nb << (24 - bits_valid).astype(jnp.uint32)), win
        )
        byteptr = byteptr + need.astype(jnp.int32)
        bits_valid = bits_valid + 8 * need.astype(jnp.int32)
        return win, byteptr, bits_valid, outs, s + 1

    outs = jnp.zeros((C, S, L), dtype=jnp.uint8)
    _, _, _, outs, _ = jax.lax.fori_loop(
        0, S, round_fn, (win, byteptr, bits_valid, outs, 0)
    )
    syms = outs.reshape(-1)[:n_elem]
    sm = fp8.unpack_nibbles(signmant, n_elem, xp=jnp)
    return fp8.assemble(syms, sm, xp=jnp)


def decode_jnp(c: TpuECF8) -> jnp.ndarray:
    """In-graph decode of the uniform layout -> uint8 fp8 bits (n_elem,)."""
    return _decode_jnp_impl(
        jnp.asarray(c.payload), jnp.asarray(c.signmant),
        jnp.asarray(c.lj_limit), jnp.asarray(c.first_lj),
        jnp.asarray(c.offset), jnp.asarray(c.perm),
        sym_per_lane=c.sym_per_lane, n_elem=c.n_elem,
    )


def _codebook_view(c: TpuECF8) -> Codebook:
    cb = Codebook(lengths=np.asarray(c.lengths), codes=None,  # type: ignore
                  max_len=MAX_CODE_LEN)
    cb.sorted_syms = np.asarray(c.perm)
    cb.lj_limit = np.asarray(c.lj_limit, dtype=np.int64)
    cb.first_lj = np.asarray(c.first_lj, dtype=np.int64)
    cb.offset = np.asarray(c.offset, dtype=np.int64)
    return cb


def _assemble(c: TpuECF8, syms: np.ndarray) -> np.ndarray:
    sm = np.asarray(fp8.unpack_nibbles(c.signmant, c.n_elem, xp=np))
    return fp8.assemble(syms.astype(np.uint8), sm, xp=np).reshape(c.shape)


def _concat_aranges(lens: np.ndarray) -> np.ndarray:
    total = int(lens.sum())
    ids = np.arange(total)
    starts = np.repeat(np.cumsum(lens) - lens, lens)
    return ids - starts
