"""Architecture / shape configuration system.

Every assigned architecture is a frozen ``ArchConfig``; the four workload
shapes are ``ShapeConfig``s.  ``registry.get(name)`` resolves ``--arch`` ids.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense|moe|hybrid|ssm|vlm|audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // n_heads
    # blocks / activations
    mlp_type: str = "swiglu"         # swiglu|gelu|geglu|sqrelu
    qk_norm: bool = False
    post_norms: bool = False         # gemma2-style post-block norms
    logit_softcap: float = 0.0
    attn_softcap: float = 0.0
    embed_scale: bool = False        # gemma-style sqrt(d_model) embed scaling
    # per-layer temporal-mixer pattern, cycled over layers:
    #   attn | local | nope (global, no rope) | rglru | slstm | mlstm
    pattern: tuple = ("attn",)
    local_window: int = 4096
    rope_theta: float = 10000.0
    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    # encoder-decoder (whisper)
    encoder_decoder: bool = False
    n_encoder_layers: int = 0
    encoder_frames: int = 1500       # stub frontend sequence length
    # misc
    tie_embeddings: bool = True
    dtype: str = "bfloat16"
    sub_quadratic: bool = False      # eligible for long_500k
    notes: str = ""

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def unit(self) -> int:
        """Layers per scan unit (one repetition of the pattern)."""
        return len(self.pattern)

    def layer_kind(self, i: int) -> str:
        return self.pattern[i % self.unit]

    def param_count(self) -> int:
        """Approximate parameter count (embedding + blocks)."""
        d, ff, V = self.d_model, self.d_ff, self.vocab_size
        hd, Hq, Hkv = self.hd, self.n_heads, self.n_kv_heads
        n = V * d * (1 if self.tie_embeddings else 2)
        for i in range(self.n_layers):
            kind = self.layer_kind(i)
            if kind in ("attn", "local", "nope"):
                n += d * hd * (Hq + 2 * Hkv) + Hq * hd * d
            elif kind == "rglru":
                n += 5 * d * d + 4 * d  # in/gate/a/x/out projections
            elif kind == "slstm":
                n += 4 * d * d + (d // max(self.n_heads, 1)) * 4 * d + d * d
            elif kind == "mlstm":
                di = 2 * d
                n += d * 2 * di + 3 * di * di + di * d
            if self.n_experts:
                n += d * self.n_experts  # gate
                n += self.n_experts * 3 * d * self.moe_d_ff
                if self.n_shared_experts:
                    n += 3 * d * (self.moe_d_ff * self.n_shared_experts)
            elif ff:
                mults = 3 if self.mlp_type in ("swiglu", "geglu") else 2
                n += mults * d * ff
        if self.encoder_decoder:
            for _ in range(self.n_encoder_layers):
                n += 4 * d * self.hd * self.n_heads + (
                    (3 if self.mlp_type in ("swiglu", "geglu") else 2)
                    * d * ff)
                n += 4 * d * self.hd * self.n_heads  # cross attention
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE counts top_k + shared experts)."""
        if not self.n_experts:
            return self.param_count()
        full = self.param_count()
        per_layer_all = self.n_experts * 3 * self.d_model * self.moe_d_ff
        per_layer_act = self.top_k * 3 * self.d_model * self.moe_d_ff
        return full - self.n_layers * (per_layer_all - per_layer_act)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}


def shape_applicable(cfg: ArchConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """(runs?, reason-if-skipped) — the long_500k sub-quadratic rule."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("full/global attention is quadratic at 524288 and the "
                       "KV cache would exceed HBM; see DESIGN.md §4")
    return True, ""


def smoke_variant(cfg: ArchConfig) -> ArchConfig:
    """A reduced same-family config for CPU smoke tests."""
    unit = cfg.unit
    d = 64
    n_heads = max(2, min(4, cfg.n_heads))
    kv = max(1, min(cfg.n_kv_heads, n_heads))
    while n_heads % kv:
        kv -= 1
    return replace(
        cfg,
        name=cfg.name + "-smoke",
        n_layers=unit * 2,
        d_model=d,
        n_heads=n_heads,
        n_kv_heads=kv,
        head_dim=d // n_heads if cfg.head_dim == 0 else 32,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=512,
        local_window=32,
        n_experts=min(cfg.n_experts, 8) if cfg.n_experts else 0,
        top_k=min(cfg.top_k, 2) if cfg.n_experts else 0,
        moe_d_ff=64 if cfg.n_experts else 0,
        capacity_factor=8.0,  # avoid drop asymmetry in consistency tests
        n_shared_experts=min(cfg.n_shared_experts, 1),
        n_encoder_layers=2 if cfg.encoder_decoder else 0,
        encoder_frames=16 if cfg.encoder_decoder else 1500,
        dtype="float32",
    )
