"""--arch moonshot-v1-16b-a3b (see registry.py for the full cited config)."""
from .registry import moonshot_v1_16b as _cfg
from .base import smoke_variant

CONFIG = _cfg
SMOKE = smoke_variant(_cfg)
