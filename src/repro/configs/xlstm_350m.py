"""--arch xlstm-350m (see registry.py for the full cited config)."""
from .registry import xlstm_350m as _cfg
from .base import smoke_variant

CONFIG = _cfg
SMOKE = smoke_variant(_cfg)
