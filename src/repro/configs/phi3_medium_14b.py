"""--arch phi3-medium-14b (see registry.py for the full cited config)."""
from .registry import phi3_medium_14b as _cfg
from .base import smoke_variant

CONFIG = _cfg
SMOKE = smoke_variant(_cfg)
