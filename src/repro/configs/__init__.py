"""Architecture configs: 10 assigned archs + the paper's eval arch."""
from .base import SHAPES, ArchConfig, ShapeConfig, shape_applicable, smoke_variant  # noqa: F401
from .registry import ASSIGNED, get, names  # noqa: F401
