"""--arch llama4-scout-17b-a16e (see registry.py for the full cited config)."""
from .registry import llama4_scout_17b as _cfg
from .base import smoke_variant

CONFIG = _cfg
SMOKE = smoke_variant(_cfg)
