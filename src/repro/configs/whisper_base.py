"""--arch whisper-base (see registry.py for the full cited config)."""
from .registry import whisper_base as _cfg
from .base import smoke_variant

CONFIG = _cfg
SMOKE = smoke_variant(_cfg)
