"""--arch nemotron-4-15b (see registry.py for the full cited config)."""
from .registry import nemotron_4_15b as _cfg
from .base import smoke_variant

CONFIG = _cfg
SMOKE = smoke_variant(_cfg)
