"""--arch recurrentgemma-2b (see registry.py for the full cited config)."""
from .registry import recurrentgemma_2b as _cfg
from .base import smoke_variant

CONFIG = _cfg
SMOKE = smoke_variant(_cfg)
