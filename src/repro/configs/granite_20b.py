"""--arch granite-20b (see registry.py for the full cited config)."""
from .registry import granite_20b as _cfg
from .base import smoke_variant

CONFIG = _cfg
SMOKE = smoke_variant(_cfg)
