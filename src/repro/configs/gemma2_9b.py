"""--arch gemma2-9b (see registry.py for the full cited config)."""
from .registry import gemma2_9b as _cfg
from .base import smoke_variant

CONFIG = _cfg
SMOKE = smoke_variant(_cfg)
