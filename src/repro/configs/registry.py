"""The assigned architecture pool (10 archs) + the paper's own eval arch.

Sources are cited per entry ([arXiv / hf]); approximations relative to the
published configs are recorded in ``notes`` and DESIGN.md §4.
"""
from __future__ import annotations

from .base import ArchConfig

_REGISTRY: dict[str, ArchConfig] = {}


def _reg(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


granite_20b = _reg(ArchConfig(
    name="granite-20b", family="dense", n_layers=52, d_model=6144,
    n_heads=48, n_kv_heads=1, d_ff=24576, vocab_size=49152,
    mlp_type="gelu", pattern=("attn",), tie_embeddings=False,
    notes="llama-arch code model, MQA kv=1 [arXiv:2405.04324]",
))

phi3_medium_14b = _reg(ArchConfig(
    name="phi3-medium-14b", family="dense", n_layers=40, d_model=5120,
    n_heads=40, n_kv_heads=10, d_ff=17920, vocab_size=100352,
    mlp_type="swiglu", pattern=("attn",),
    notes="RoPE SwiGLU GQA [arXiv:2404.14219]; 40 heads pad to 48 on "
          "model=16 TP (GSPMD)",
))

nemotron_4_15b = _reg(ArchConfig(
    name="nemotron-4-15b", family="dense", n_layers=32, d_model=6144,
    n_heads=48, n_kv_heads=8, d_ff=24576, vocab_size=256000,
    mlp_type="sqrelu", pattern=("attn",), tie_embeddings=False,
    rope_theta=10000.0,
    notes="GQA, squared-ReLU MLP [arXiv:2402.16819]",
))

gemma2_9b = _reg(ArchConfig(
    name="gemma2-9b", family="dense", n_layers=42, d_model=3584,
    n_heads=16, n_kv_heads=8, d_ff=14336, vocab_size=256000,
    head_dim=256, mlp_type="geglu", pattern=("local", "attn"),
    local_window=4096, attn_softcap=50.0, logit_softcap=30.0,
    post_norms=True, embed_scale=True,
    notes="local/global alternating, softcaps [arXiv:2408.00118]",
))

recurrentgemma_2b = _reg(ArchConfig(
    name="recurrentgemma-2b", family="hybrid", n_layers=26, d_model=2560,
    n_heads=10, n_kv_heads=1, d_ff=7680, vocab_size=256000,
    head_dim=256, mlp_type="geglu", pattern=("rglru", "rglru", "local"),
    local_window=2048, embed_scale=True, sub_quadratic=True,
    notes="RG-LRU + local attention 2:1 [arXiv:2402.19427]; 26 layers = "
          "8 full (r,r,l) units + 2 tail rglru layers",
))

chameleon_34b = _reg(ArchConfig(
    name="chameleon-34b", family="vlm", n_layers=48, d_model=8192,
    n_heads=64, n_kv_heads=8, d_ff=22016, vocab_size=65536,
    mlp_type="swiglu", pattern=("attn",), qk_norm=True,
    tie_embeddings=False,
    notes="early-fusion VLM: VQ image tokens share the vocab; the VQ "
          "tokenizer frontend is a stub (ids in input_specs) "
          "[arXiv:2405.09818]",
))

llama4_scout_17b = _reg(ArchConfig(
    name="llama4-scout-17b-a16e", family="moe", n_layers=48, d_model=5120,
    n_heads=40, n_kv_heads=8, d_ff=8192, vocab_size=202048,
    mlp_type="swiglu", pattern=("local", "local", "local", "nope"),
    local_window=8192, n_experts=16, top_k=1, moe_d_ff=8192,
    n_shared_experts=1, qk_norm=True,
    notes="MoE 16e top-1 + shared expert; iRoPE chunked-local 3:1 with "
          "NoPE global layers (chunked attention approximated as sliding "
          "window 8192) [hf:meta-llama/Llama-4-Scout-17B-16E]",
))

moonshot_v1_16b = _reg(ArchConfig(
    name="moonshot-v1-16b-a3b", family="moe", n_layers=48, d_model=2048,
    n_heads=16, n_kv_heads=16, d_ff=1408, vocab_size=163840,
    mlp_type="swiglu", pattern=("attn",), n_experts=64, top_k=6,
    moe_d_ff=1408, n_shared_experts=2,
    notes="moonlight/deepseek-v3-style 64e top-6 + 2 shared experts "
          "[hf:moonshotai/Moonlight-16B-A3B]",
))

xlstm_350m = _reg(ArchConfig(
    name="xlstm-350m", family="ssm", n_layers=24, d_model=1024,
    n_heads=4, n_kv_heads=4, d_ff=0, vocab_size=50304,
    pattern=("slstm", "mlstm"), sub_quadratic=True, tie_embeddings=False,
    notes="alternating sLSTM/mLSTM blocks, no separate MLP (cells carry "
          "their own projections) [arXiv:2405.04517]",
))

whisper_base = _reg(ArchConfig(
    name="whisper-base", family="audio", n_layers=6, d_model=512,
    n_heads=8, n_kv_heads=8, d_ff=2048, vocab_size=51865,
    mlp_type="gelu", pattern=("attn",), encoder_decoder=True,
    n_encoder_layers=6, encoder_frames=1500, tie_embeddings=False,
    notes="enc-dec; conv/mel frontend is a stub — input_specs provides "
          "precomputed frame embeddings (B, 1500, d) [arXiv:2212.04356]",
))

# the paper's own smallest eval model (Qwen3-8B-FP8), used by examples
qwen3_8b = _reg(ArchConfig(
    name="qwen3-8b", family="dense", n_layers=36, d_model=4096,
    n_heads=32, n_kv_heads=8, d_ff=12288, vocab_size=151936,
    mlp_type="swiglu", pattern=("attn",), qk_norm=True,
    notes="paper Table 1 row: Qwen3-8B-FP8 [arXiv:2505.09388]",
))

ASSIGNED = [
    "granite-20b", "phi3-medium-14b", "nemotron-4-15b", "gemma2-9b",
    "recurrentgemma-2b", "chameleon-34b", "llama4-scout-17b-a16e",
    "moonshot-v1-16b-a3b", "xlstm-350m", "whisper-base",
]


def get(name: str) -> ArchConfig:
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def names() -> list[str]:
    return list(_REGISTRY)
