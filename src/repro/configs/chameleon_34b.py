"""--arch chameleon-34b (see registry.py for the full cited config)."""
from .registry import chameleon_34b as _cfg
from .base import smoke_variant

CONFIG = _cfg
SMOKE = smoke_variant(_cfg)
