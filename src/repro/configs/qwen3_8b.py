"""--arch qwen3-8b (see registry.py for the full cited config)."""
from .registry import qwen3_8b as _cfg
from .base import smoke_variant

CONFIG = _cfg
SMOKE = smoke_variant(_cfg)
