"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state — the dry-run must set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any jax
initialization, and tests/benches must keep seeing 1 device.

Topology (TPU v5e target):
  single-pod: (data=16, model=16)          = 256 chips
  multi-pod:  (pod=2, data=16, model=16)   = 512 chips

``model`` is the innermost axis -> maps to the fastest ICI ring; ``pod``
is outermost -> crosses the slower inter-pod links (DCI).  Batch shards
over ("pod", "data") so only gradient reduction crosses pods (DESIGN.md §5).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """A tiny mesh over the real local devices (tests / examples)."""
    n = len(jax.devices())
    return jax.make_mesh((n // model, model), ("data", "model"))
