import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))
# ^ MUST precede any jax import/initialization: jax locks the device count
#   on first backend init.  Only the dry-run sees 512 placeholder devices.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this produces, without allocating any real buffers:
  * proof the sharding config is coherent (compile succeeds),
  * ``memory_analysis()``   -> per-device bytes (does it fit HBM),
  * ``cost_analysis()``     -> per-device FLOPs / bytes for the roofline,
  * collective wire bytes parsed from the post-SPMD HLO,
all dumped to ``experiments/artifacts/<arch>__<shape>__<mesh>[__tag].json``.

Usage:
  python -m repro.launch.dryrun --arch granite-20b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both
  python -m repro.launch.dryrun --all --mesh single --rules none --tag base
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.analysis.hlo_cost import analyze as hlo_cost_analyze
from repro.analysis.hlo_parse import collective_bytes, op_histogram
from repro.analysis.roofline import roofline_terms
from repro.configs import ASSIGNED, SHAPES, get, shape_applicable
from repro.launch.mesh import make_production_mesh
from repro.optim.adamw import AdamWConfig
from repro.runtime import sharding as SH
from repro.runtime.steps import (cache_specs, compressed_param_specs,
                                 input_specs, make_decode_step,
                                 make_prefill_step, make_train_step,
                                 opt_specs, param_specs)

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                         "experiments", "artifacts")


def _batch_pspec(specs: dict, mesh) -> dict:
    ba = SH.batch_axes(mesh)   # tuple, single name, or None (no batch axis)
    ba_size = SH._axis_size(mesh, ba)
    out = {}
    for k, v in specs.items():
        b = ba if v.shape[0] % ba_size == 0 else None
        out[k] = P(b, *(None,) * (len(v.shape) - 1))
    return out


def lower_cell(arch: str, shape_name: str, mesh_kind: str,
               rules: SH.ShardingRules = SH.DEFAULT_RULES,
               grad_accum: int = 1, remat: bool = True,
               keep_hlo: bool = False,
               assume_flash_kernel: bool = False,
               param_dtype: str | None = None,
               compressed: bool = False) -> dict:
    """Lower + compile one cell; return the artifact dict."""
    cfg = get(arch)
    shape = SHAPES[shape_name]
    runs, why = shape_applicable(cfg, shape)
    if not runs:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                "skipped": True, "reason": why}

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_chips = mesh.devices.size
    t0 = time.time()

    p_sds = (compressed_param_specs(cfg) if compressed
             else param_specs(cfg, jnp.dtype(param_dtype) if param_dtype
                              else None))
    p_spec = SH.param_pspecs(cfg, p_sds, mesh, rules)
    p_named = SH.named(mesh, p_spec)
    in_sds = input_specs(cfg, shape)
    b_spec = _batch_pspec(in_sds, mesh)
    b_named = {k: NamedSharding(mesh, s) for k, s in b_spec.items()}

    with mesh:
        if shape.kind == "train":
            o_sds = opt_specs(cfg)
            o_named = SH.named(mesh, SH.opt_pspecs(p_spec))
            step = make_train_step(cfg, AdamWConfig(), mesh=mesh,
                                   rules=rules, remat=remat,
                                   grad_accum=grad_accum)
            lowered = jax.jit(
                step,
                in_shardings=(p_named, o_named, b_named, None),
                out_shardings=(p_named, o_named, None),
                donate_argnums=(0, 1),
            ).lower(p_sds, o_sds, in_sds, jax.ShapeDtypeStruct((), jnp.int32))
        elif shape.kind == "prefill":
            step = make_prefill_step(cfg, mesh=mesh, rules=rules,
                                     max_len=shape.seq_len)
            c_sds = cache_specs(cfg, shape.global_batch, shape.seq_len,
                                jnp.dtype(cfg.dtype))
            c_named = SH.named(mesh, SH.cache_pspecs(cfg, c_sds, mesh))
            lowered = jax.jit(
                step,
                in_shardings=(p_named, b_named),
                out_shardings=(None, c_named),
            ).lower(p_sds, in_sds)
        else:  # decode
            step = make_decode_step(cfg, mesh=mesh, rules=rules)
            c_sds = cache_specs(cfg, shape.global_batch, shape.seq_len,
                                jnp.dtype(cfg.dtype))
            c_named = SH.named(mesh, SH.cache_pspecs(cfg, c_sds, mesh))
            lowered = jax.jit(
                step,
                in_shardings=(p_named, b_named, c_named),
                out_shardings=(None, c_named),
                donate_argnums=(2,),
            ).lower(p_sds, in_sds, c_sds)

        compiled = lowered.compile()

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    coll_flat = collective_bytes(hlo)     # no loop scaling (diagnostic)
    hist = op_histogram(hlo)
    # trip-count-aware re-analysis: XLA's cost_analysis counts while bodies
    # once; scans/maps/fori must be scaled by their static trip counts
    vmem_tiles = None
    if assume_flash_kernel and shape.kind in ("train", "prefill"):
        # the Pallas flash kernel (kernels/flash_fwd.py, validated vs the
        # jnp oracle) keeps the s/p tiles in VMEM; exclude their HBM
        # traffic from the memory term (FLOPs/collectives unchanged)
        n_model = 16
        t_loc = max(shape.seq_len // n_model, 1)
        qc = min(512, t_loc)
        vmem_tiles = {"qcs": {qc, qc * cfg.n_heads}, "kc": 1024}
    corrected = hlo_cost_analyze(hlo, vmem_tiles=vmem_tiles)

    mem_d = {
        k: int(getattr(mem, k))
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes")
        if hasattr(mem, k)
    }
    raw_cost_d = {k: float(cost[k]) for k in ("flops", "bytes accessed")
                  if k in cost}
    cost_d = {"flops": corrected["flops"],
              "bytes accessed": corrected["bytes"]}
    coll = dict(corrected["coll"])
    art = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "rules": {"activation_partitioning": rules.activation_partitioning,
                  "vocab_tp": rules.vocab_tp,
                  "expert_fsdp": rules.expert_fsdp},
        "grad_accum": grad_accum, "remat": remat,
        "n_chips": n_chips,
        "memory_analysis": mem_d,
        "cost_analysis": cost_d,
        "cost_analysis_xla_raw": raw_cost_d,
        "unknown_trip_loops": corrected.get("unknown_trip_loops", 0),
        "assume_flash_kernel": assume_flash_kernel,
        "vmem_dropped_bytes": corrected.get("vmem_dropped_bytes", 0.0),
        "collectives": coll,
        "collectives_unscaled": {k: v for k, v in coll_flat.items()
                                 if k != "ops"},
        "collective_ops_top": sorted(
            coll_flat["ops"], key=lambda t: -t[1])[:12],
        "op_histogram": hist,
        "compile_seconds": time.time() - t0,
        "roofline": roofline_terms(cost_d, coll, n_chips, get(arch), shape),
        "skipped": False,
    }
    if keep_hlo:
        art["hlo_text_path"] = _dump_hlo(arch, shape_name, mesh_kind, hlo)
    del compiled, lowered
    return art


def _dump_hlo(arch, shape_name, mesh_kind, hlo):
    os.makedirs(ARTIFACTS, exist_ok=True)
    path = os.path.join(ARTIFACTS, f"{arch}__{shape_name}__{mesh_kind}.hlo")
    with open(path, "w") as f:
        f.write(hlo)
    return path


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--rules", default="seq",
                    choices=["seq", "dmodel", "none"])
    ap.add_argument("--no-vocab-tp", action="store_true")
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--attn", default="flash",
                    choices=["flash", "blockwise"],
                    help="full-attention impl (blockwise = naive baseline)")
    ap.add_argument("--assume-flash-kernel", action="store_true",
                    help="account s/p tiles as VMEM-resident (Pallas "
                         "kernel, kernels/flash_fwd.py)")
    ap.add_argument("--serve-tp", action="store_true",
                    help="serving rule: weights pure-TP (no FSDP axis)")
    ap.add_argument("--param-dtype", default=None,
                    choices=[None, "bfloat16", "float8_e4m3fn"],
                    help="override parameter storage dtype (fp8 = the "
                         "paper's serving baseline)")
    ap.add_argument("--compressed", action="store_true",
                    help="lower with ECF8-compressed weights (decode-on-"
                         "use inside the step — the paper's technique)")
    ap.add_argument("--tag", default="")
    ap.add_argument("--keep-hlo", action="store_true")
    ap.add_argument("--out", default=ARTIFACTS)
    args = ap.parse_args()

    from repro.models.layers import set_attention_impl
    set_attention_impl(args.attn)
    rules = SH.ShardingRules(activation_partitioning=args.rules,
                             vocab_tp=not args.no_vocab_tp,
                             serve_tp=args.serve_tp)
    archs = ASSIGNED if args.all or not args.arch else [args.arch]
    shapes = list(SHAPES) if args.all or not args.shape else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    os.makedirs(args.out, exist_ok=True)
    n_ok = n_fail = n_skip = 0
    for arch in archs:
        for shape_name in shapes:
            for mesh_kind in meshes:
                tag = f"__{args.tag}" if args.tag else ""
                name = f"{arch}__{shape_name}__{mesh_kind}{tag}"
                try:
                    art = lower_cell(arch, shape_name, mesh_kind,
                                     rules=rules,
                                     grad_accum=args.grad_accum,
                                     remat=not args.no_remat,
                                     keep_hlo=args.keep_hlo,
                                     assume_flash_kernel=
                                     args.assume_flash_kernel,
                                     param_dtype=args.param_dtype,
                                     compressed=args.compressed)
                except Exception as e:
                    art = {"arch": arch, "shape": shape_name,
                           "mesh": mesh_kind, "skipped": False,
                           "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()[-3000:]}
                    n_fail += 1
                    print(f"[FAIL] {name}: {type(e).__name__}: "
                          f"{str(e)[:200]}")
                else:
                    if art.get("skipped"):
                        n_skip += 1
                        print(f"[skip] {name}: {art['reason'][:80]}")
                    else:
                        n_ok += 1
                        r = art["roofline"]
                        print(f"[ ok ] {name}: compute {r['t_compute']:.4f}s"
                              f" memory {r['t_memory']:.4f}s collective "
                              f"{r['t_collective']:.4f}s -> {r['dominant']}"
                              f" (compile {art['compile_seconds']:.0f}s)")
                with open(os.path.join(args.out, name + ".json"), "w") as f:
                    json.dump(art, f, indent=1, default=str)
                jax.clear_caches()
    print(f"dry-run done: {n_ok} ok, {n_fail} failed, {n_skip} skipped")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
