"""Batched serving driver: the paper's RQ2 experiment shape.

Loads (or synthesizes) weights, optionally compresses them to ECF8, and
serves a batch of requests through the continuous-batching engine, printing
the memory footprint of both weight representations and the achieved
tokens/step.  On this CPU container the *throughput claim* is expressed as
the roofline memory term (weight-streaming bytes) — see EXPERIMENTS §Perf —
while this driver proves the end-to-end serving path runs and is bit-exact.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b --smoke \
      --compress tpu --requests 8

  # sharded serving on a 2-way data mesh (CPU: export
  # XLA_FLAGS=--xla_force_host_platform_device_count=2 first)
  PYTHONPATH=src python -m repro.launch.serve --smoke --mesh 2 \
      --cache paged-compressed --requests 8
"""
from __future__ import annotations

import argparse
import time
from dataclasses import replace

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.configs import get, smoke_variant
from repro.core import fp8
from repro.core.store import compress_tree, fp8_cast_tree
from repro.models import model as M
from repro.runtime.monitor import KVCacheMonitor
from repro.runtime.trace_export import export_chrome_trace
from repro.runtime.tracing import JaxProfilerHook
from repro.serving import EngineConfig, EngineConfigError, \
    GenerationEngine, Request
from repro.serving.telemetry import Telemetry, serving_report_line


def tree_bytes(tree) -> int:
    return sum(
        x.nbytes_compressed() if hasattr(x, "nbytes_compressed")
        else (int(np.prod(x.shape)) * x.dtype.itemsize
              if hasattr(x, "shape") else 0)
        for x in jax.tree_util.tree_leaves(
            tree, is_leaf=lambda t: hasattr(t, "nbytes_compressed")))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--compress", default="tpu",
                    choices=["none", "tpu", "fixedrate"])
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--check-lossless", action="store_true",
                    help="compare logits vs the uncompressed fp8 baseline")
    ap.add_argument("--cache", default="paged",
                    choices=["monolithic", "paged", "paged-compressed"],
                    help="KV-cache layout (paged-compressed entropy-codes "
                         "cold pages in place, decode-on-use in-graph). "
                         "Combines with --mesh: the paged variants shard "
                         "the page pool/table over the mesh batch axes "
                         "(bit-identical to single-device on a pure data "
                         "mesh); monolithic relies on GSPMD cache "
                         "sharding instead.")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--n-pages", type=int, default=None,
                    help="raw page-pool size (default: worst case).  Set "
                         "it below the worst case to oversubscribe the "
                         "pool; with --swap-bytes the engine then swaps/"
                         "preempts instead of failing with OutOfPages.")
    ap.add_argument("--swap-bytes", type=int, default=0,
                    help="host swap-tier capacity in bytes for entropy-"
                         "coded evicted pages (-1 = unbounded, 0 = "
                         "disabled).  Enables serving workloads whose "
                         "aggregate page demand exceeds the device pool, "
                         "bit-identically.")
    ap.add_argument("--preemption", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="allow whole-request preemption (compress + swap "
                         "out a victim, requeue, resume later).  Requires "
                         "--swap-bytes; --no-preemption restores the "
                         "seed's stall-and-raise admission.")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="chunked, decode-interleaved prefill: split each "
                         "prompt into fixed N-token chunks (one prefill "
                         "compilation for every prompt length) and "
                         "interleave them with decode steps.  0 = "
                         "whole-prompt prefill (one compile per prompt "
                         "length).  Needs --cache paged/paged-compressed "
                         "and an all-attention architecture.")
    ap.add_argument("--prefill-budget", type=int, default=0,
                    help="prompt tokens spent on prefill per engine step "
                         "(bounds decode latency under long prompts); "
                         "default: one chunk.")
    ap.add_argument("--prefix-sharing", action="store_true",
                    help="cross-request prefix sharing: requests with a "
                         "common page-aligned prompt prefix reference one "
                         "physical copy of its KV pages (copy-on-write) "
                         "and skip recomputing the matched positions.  "
                         "Needs --prefill-chunk and a single batch shard.")
    ap.add_argument("--shared-prefix", type=int, default=0, metavar="N",
                    help="prepend the same N-token system prompt to every "
                         "request (chat-style workload; makes "
                         "--prefix-sharing hits visible in the report)")
    ap.add_argument("--draft", default=None, metavar="ARCH",
                    help="speculative decoding: draft-model architecture "
                         "from the registry (e.g. xlstm-350m drafting for "
                         "qwen3-8b; --smoke applies to it too).  The "
                         "draft proposes --spec-k tokens per round and "
                         "the target verifies all k+1 positions in one "
                         "batched forward with exact rejection sampling "
                         "— output is bit-identical to target-only "
                         "decoding under greedy and distribution-"
                         "identical when sampling.  Needs --cache paged/"
                         "paged-compressed, an all-attention target and "
                         "whole-prompt prefill.")
    ap.add_argument("--spec-k", type=int, default=None,
                    help="drafted tokens per speculative round "
                         "(default 4; an error without --draft)")
    ap.add_argument("--draft-seed", type=int, default=None,
                    help="PRNG seed for the synthesized draft weights "
                         "(default 1; an error without --draft)")
    ap.add_argument("--mesh", default=None, metavar="D[xM]",
                    help="serve on a (data=D[, model=M]) device mesh, e.g. "
                         "'2' or '2x2'.  Needs D*M visible devices (on CPU "
                         "set XLA_FLAGS=--xla_force_host_platform_device_"
                         "count=N).  --max-batch must be divisible by D or "
                         "the engine falls back to the monolithic cache.")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace-out", default=None, metavar="FILE",
                    help="write a Chrome-trace/Perfetto JSON of the run "
                         "(per-request lifecycle spans + engine-phase "
                         "spans + counter tracks; open in "
                         "ui.perfetto.dev).  See docs/OBSERVABILITY.md.")
    ap.add_argument("--metrics-interval", type=int, default=0,
                    metavar="STEPS",
                    help="print a one-line stats report every N engine "
                         "steps (tokens, queue depth, step/TTFT "
                         "percentiles)")
    ap.add_argument("--jax-profile", default=None, metavar="DIR",
                    help="capture a jax.profiler device trace into DIR "
                         "over the --profile-steps window")
    ap.add_argument("--profile-steps", default="0:1", metavar="A:B",
                    help="engine-step window for --jax-profile "
                         "(default 0:1)")
    args = ap.parse_args(argv)

    cfg = get(args.arch)
    if args.smoke:
        cfg = smoke_variant(cfg)

    mesh = None
    if args.mesh:
        try:
            dims = [int(x) for x in args.mesh.lower().split("x")]
        except ValueError:
            dims = []
        if not 1 <= len(dims) <= 2 or any(d < 1 for d in dims):
            raise SystemExit(
                f"--mesh {args.mesh!r}: expected 'D' or 'DxM' with "
                f"positive integers (e.g. '2' or '2x2')")
        n_dev = int(np.prod(dims))
        if n_dev > len(jax.devices()):
            raise SystemExit(
                f"--mesh {args.mesh} needs {n_dev} devices, "
                f"{len(jax.devices())} visible (set XLA_FLAGS="
                f"--xla_force_host_platform_device_count={n_dev})")
        axes = ("data", "model")[: len(dims)]
        mesh = Mesh(np.asarray(jax.devices()[:n_dev]).reshape(dims), axes)
        print(f"[serve] mesh {dict(zip(axes, dims))}")

    # the one CLI -> engine-config mapping: strict validation here
    # surfaces ignored flags (--spec-k without --draft) and incompatible
    # feature requests (--prefix-sharing with --draft, chunked prefill
    # on a model mesh axis, ...) *before* any weights are synthesized
    dcfg = None
    if args.draft:
        dcfg = smoke_variant(get(args.draft)) if args.smoke \
            else get(args.draft)
    try:
        ecfg = EngineConfig.from_args(args, cfg, mesh=mesh, draft_cfg=dcfg)
    except EngineConfigError as e:
        ap.error(str(e))

    params = M.init_params(jax.random.PRNGKey(args.seed), cfg)
    # FP8 baseline: the paper compresses released FP8 checkpoints
    params_fp8 = fp8_cast_tree(params, min_elems=4096)

    if args.compress != "none":
        t0 = time.time()
        params_c, report = compress_tree(
            params, fmt=args.compress, min_elems=4096,
            out_dtype=cfg.dtype if not args.smoke else "float32")
        enc_s = time.time() - t0
        fp8_b = max(report["fp8_bytes"], 1)
        print(f"[serve] ECF8({args.compress}) encode {enc_s:.1f}s: "
              f"{report['n_compressed']} tensors, fp8 {fp8_b / 1e6:.2f}MB ->"
              f" {report['compressed_bytes'] / 1e6:.2f}MB "
              f"({100 * (1 - report['compressed_bytes'] / fp8_b):.1f}% "
              f"saved)")
    else:
        params_c = params_fp8

    rng = np.random.default_rng(args.seed)
    system = rng.integers(1, cfg.vocab_size,
                          size=args.shared_prefix).tolist()
    prompts = [system + rng.integers(0, cfg.vocab_size,
                                     size=rng.integers(4, 12)).tolist()
               for _ in range(args.requests)]

    if args.draft:
        draft_seed = 1 if args.draft_seed is None else args.draft_seed
        dparams = M.init_params(jax.random.PRNGKey(draft_seed), dcfg)
        ecfg = replace(ecfg, draft_params=dparams)
        print(f"[serve] speculative: draft {args.draft} "
              f"({sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(dparams)) / 1e6:.2f}M params), k={ecfg.spec_k}")
    tel = Telemetry(trace=args.trace_out is not None)
    mon = KVCacheMonitor(registry=tel.registry)
    eng = GenerationEngine(params_c, cfg,
                           config=replace(ecfg, telemetry=tel,
                                          kv_monitor=mon))
    reqs = [Request(prompt=p, max_new_tokens=args.max_new) for p in prompts]
    for r in reqs:
        eng.submit(r)

    profiler = None
    if args.jax_profile:
        try:
            a, b = (int(x) for x in args.profile_steps.split(":"))
        except ValueError:
            raise SystemExit(f"--profile-steps {args.profile_steps!r}: "
                             f"expected 'A:B' (engine-step window)")
        profiler = JaxProfilerHook(args.jax_profile, a, b)

    def on_step(i):
        if profiler is not None:
            profiler.on_step(i)
        if args.metrics_interval and (i + 1) % args.metrics_interval == 0:
            print(f"[serve] step {i + 1}: "
                  f"{serving_report_line(tel.registry)}")

    t0 = time.time()
    done = eng.run(on_step=on_step)
    dt = time.time() - t0
    if profiler is not None:
        profiler.close()
        print(f"[serve] jax.profiler trace in {args.jax_profile}")
    if args.trace_out:
        trace = export_chrome_trace(tel.tracer, args.trace_out,
                                    registry=tel.registry)
        print(f"[serve] wrote {args.trace_out}: "
              f"{len(trace['traceEvents'])} trace events "
              f"({tel.tracer.n_dropped} dropped) — open in "
              f"ui.perfetto.dev")
    n_tok = sum(len(r.out_tokens) for r in done)
    print(f"[serve] {len(done)} requests, {n_tok} tokens in {dt:.1f}s "
          f"({n_tok / max(dt, 1e-9):.1f} tok/s host wall-clock, "
          f"{eng.steps} decode steps, batch occupancy "
          f"{n_tok / max(eng.steps, 1):.2f})")
    if eng.spec_on:
        sc = eng.spec_counters()
        print(f"[serve] speculative: {sc['spec_rounds']} verify rounds, "
              f"accept rate {sc['spec_accept_rate']:.3f} "
              f"({sc['spec_accepted']}/{sc['spec_drafted']} drafted), "
              f"{n_tok / max(eng.steps, 1):.2f} tokens/step")
    ttft = tel.registry.get("serving_ttft_seconds")
    lat = tel.registry.get("serving_request_latency_seconds")
    if ttft is not None and ttft.count:
        print(f"[serve] ttft p50/p95/p99 "
              f"{ttft.percentile(0.5) * 1e3:.0f}/"
              f"{ttft.percentile(0.95) * 1e3:.0f}/"
              f"{ttft.percentile(0.99) * 1e3:.0f}ms, request latency p50 "
              f"{lat.percentile(0.5):.2f}s p99 {lat.percentile(0.99):.2f}s")
    if eng.cache_mode == "paged" and mon.n_samples:
        s = mon.summary()
        ratio = s["cold_compression_ratio"]
        cold = (f"cold-page compression {ratio:.3f}x raw"
                if ratio == ratio else "no page went cold")
        print(f"[serve] kv-cache ({args.cache}, page={eng.paged.page_size}):"
              f" peak {s['peak_paged_bytes'] / 1e6:.3f}MB vs monolithic "
              f"{s['monolithic_bytes'] / 1e6:.3f}MB "
              f"({100 * (1 - s['paged_vs_monolithic']):.1f}% saved), {cold}")
        if eng.paged.n_shards > 1:
            print(f"[serve] pages-per-shard peak {mon.peak_per_shard()} "
                  f"(free now {eng.paged.free_pages_per_shard})")
        if eng.prefill_chunk:
            print(f"[serve] chunked prefill (chunk={eng.prefill_chunk}, "
                  f"budget={eng.prefill_budget}/step): {eng.n_chunks} "
                  f"chunks / {eng.n_chunk_tokens} prompt tokens, "
                  f"{eng.n_interleaved_steps} interleaved steps, "
                  f"{eng.prefill_compile_count()} prefill compilation(s) "
                  f"across all prompt lengths")
        if eng.prefix_sharing:
            sp = eng.paged.stats()
            hits = tel.registry.get("prefix_hit_total")
            miss = tel.registry.get("prefix_miss_total")
            print(f"[serve] prefix sharing: "
                  f"{hits.value if hits else 0} hits / "
                  f"{miss.value if miss else 0} misses, index "
                  f"{sp['prefix_index_blocks']} blocks "
                  f"({sp['prefix_resident_blocks']} resident), "
                  f"{sp['prefix_retired_total']} retired to swap, "
                  f"{sp['prefix_cow_splits_total']} CoW splits")
        if "peak_swap_bytes" in s:
            print(f"[serve] swap tier: peak host-resident "
                  f"{s['peak_swap_bytes'] / 1e6:.3f}MB, traffic out/in "
                  f"{s['swap_out_bytes_total'] / 1e6:.3f}/"
                  f"{s['swap_in_bytes_total'] / 1e6:.3f}MB, "
                  f"{s['n_preempted']} preemptions "
                  f"({s['n_resumed']} resumed)")

    if args.check_lossless and args.compress != "none":
        eng2 = GenerationEngine(params_fp8, cfg, config=ecfg)
        reqs2 = [Request(prompt=p, max_new_tokens=args.max_new)
                 for p in prompts]
        for r in reqs2:
            eng2.submit(r)
        done2 = eng2.run()
        same = all(a.out_tokens == b.out_tokens
                   for a, b in zip(done, done2))
        print(f"[serve] lossless check vs fp8 baseline: "
              f"{'IDENTICAL' if same else 'MISMATCH'}")
        if not same:
            raise SystemExit(1)
    return done


if __name__ == "__main__":
    main()
