"""End-to-end trainer with fault tolerance.

Features exercised here (and in tests/test_fault_tolerance.py):
  * auto-resume from the latest valid checkpoint (atomic + checksummed);
  * async checkpoint writes every ``save_every`` steps, drained at each
    save point so periodic checkpoints are durability barriers;
  * preemption safety: SIGTERM/SIGINT triggers a final synchronous save;
  * straggler monitor: slow-step alarms trigger an eager async checkpoint
    (and at cluster scale, a scheduler swap — runtime/monitor.py);
  * simulated failure injection (``--fail-at-step``) for the restart test;
  * works on a real mesh (``--mesh host``) or single device.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-8b --smoke \
      --steps 200 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import os
import signal
import sys
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs import SHAPES, get, smoke_variant
from repro.data import DataConfig, SyntheticLMData
from repro.launch.mesh import make_host_mesh
from repro.models import model as M
from repro.optim.adamw import AdamWConfig, adamw_init
from repro.runtime import sharding as SH
from repro.runtime.monitor import StragglerMonitor
from repro.runtime.steps import make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-trainable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-compress", default="none",
                    choices=["none", "ecf8"])
    ap.add_argument("--mesh", default="none", choices=["none", "host"])
    ap.add_argument("--model-axis", type=int, default=1)
    ap.add_argument("--fail-at-step", type=int, default=-1,
                    help="simulate a hard failure (for the restart test)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get(args.arch)
    if args.smoke:
        cfg = smoke_variant(cfg)
    mesh = (make_host_mesh(model=args.model_axis)
            if args.mesh == "host" else None)

    data = SyntheticLMData(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq_len,
        global_batch=args.batch, seed=args.seed))

    params = M.init_params(jax.random.PRNGKey(args.seed), cfg)
    opt_state = adamw_init(params)

    mgr = CheckpointManager(args.ckpt_dir, keep=3,
                            compress=args.ckpt_compress)
    state_tpl = {"params": params, "opt": opt_state,
                 "step": jnp.zeros((), jnp.int32)}
    restored, at = mgr.restore(state_tpl)
    start_step = 0
    if restored is not None:
        params, opt_state = restored["params"], restored["opt"]
        start_step = int(restored["step"]) + 1
        print(f"[train] resumed from step {at} -> starting at {start_step}")

    # one jit per training process (no re-entry): a module cache would
    # only pin the closure alive
    step_fn = jax.jit(make_train_step(  # lint: disable=jit-cache-discipline
        cfg, AdamWConfig(lr=args.lr), mesh=mesh,
        grad_accum=args.grad_accum, remat=True,
        warmup_steps=max(args.steps // 20, 1), total_steps=args.steps))

    # preemption safety: final synchronous checkpoint on SIGTERM/SIGINT
    preempted = {"flag": False}

    def _handler(signum, frame):
        preempted["flag"] = True
    for sig in (signal.SIGTERM, signal.SIGINT):
        signal.signal(sig, _handler)

    mon = StragglerMonitor()
    losses = []
    i = start_step
    for i in range(start_step, args.steps):
        if args.fail_at_step == i:
            print(f"[train] simulating hard failure at step {i}",
                  flush=True)
            os._exit(42)  # no cleanup: models a machine loss
        batch = data.batch(i)
        mon.start()
        params, opt_state, metrics = step_fn(
            params, opt_state, batch, jnp.asarray(i, jnp.int32))
        loss = float(metrics["loss"])
        stats = mon.stop(i)
        losses.append(loss)
        if stats.is_straggler:
            print(f"[train] straggler alarm at step {i}: "
                  f"{stats.seconds:.3f}s (z={stats.z:.1f}) — eager save")
            mgr.save_async(i, {"params": params, "opt": opt_state,
                               "step": jnp.asarray(i, jnp.int32)})
        if i % args.log_every == 0:
            print(f"step {i:5d} loss {loss:.4f} lr {float(metrics['lr']):.2e}"
                  f" gnorm {float(metrics['grad_norm']):.3f}"
                  f" {stats.seconds * 1e3:.0f}ms", flush=True)
        if i and i % args.save_every == 0:
            mgr.save_async(i, {"params": params, "opt": opt_state,
                               "step": jnp.asarray(i, jnp.int32)})
            # periodic saves are the durability boundary of the restart
            # contract: a machine loss anywhere in (i, i+save_every] must
            # resume from step i, so drain the write (and any queued
            # eager saves) before advancing — write errors surface here
            # instead of being silently lost
            mgr.wait()
        if preempted["flag"]:
            print(f"[train] preemption signal at step {i}: final save")
            break

    mgr.wait()   # drain queued async writes before the final sync save
    mgr.save_sync(i, {"params": params, "opt": opt_state,
                      "step": jnp.asarray(i, jnp.int32)})
    mgr.close()
    k = max(len(losses) // 10, 1)
    if len(losses) >= 2 * k:
        print(f"[train] loss first-{k}-avg {np.mean(losses[:k]):.4f} -> "
              f"last-{k}-avg {np.mean(losses[-k:]):.4f}")
    print(f"[train] done at step {i}; ewma step "
          f"{mon.ewma_seconds * 1e3:.0f}ms; alarms={len(mon.alarms)}")
    return losses


if __name__ == "__main__":
    main()
