"""Core transformer layers: norms, RoPE, GQA attention (global / local /
decode), MLP variants, embeddings.  Pure JAX (pytrees of arrays, no flax).

Attention is implemented blockwise (online softmax over KV chunks) so that
32k-token prefill never materializes an (S, S) score matrix; local-window
attention slices only the in-window KV blocks (O(S * W) work), which is what
makes the `long_500k` shapes feasible for the hybrid/ssm architectures.

Weights may be `CompressedTensor`s (ECF8): every use site goes through
``mat`` = materialize-and-cast, the JAX-native version of the paper's
just-in-time per-layer decompression hooks (§3.3).
"""
from __future__ import annotations

from functools import partial

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.store import materialize

F32 = jnp.float32

# full-sequence attention implementation: "flash" (memory-efficient custom
# VJP, production default — EXPERIMENTS.md §Perf iteration 1) or
# "blockwise" (naive autodiff baseline; what the §Roofline baseline rows
# were lowered with).  Switched by the dry-run's --attn flag.
_ATTN_IMPL = {"full": "flash"}


def set_attention_impl(name: str):
    assert name in ("flash", "blockwise"), name
    _ATTN_IMPL["full"] = name


def get_attention_impl() -> str:
    return _ATTN_IMPL["full"]


def mat(w, dtype):
    """Materialize (decode if compressed) and cast a weight for use."""
    return materialize(w, dtype=dtype)


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------

def rms_norm(x, scale, eps: float = 1e-6):
    var = jnp.mean(jnp.square(x.astype(F32)), axis=-1, keepdims=True)
    y = x.astype(F32) * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(F32))).astype(x.dtype)


def layer_norm(x, scale, bias, eps: float = 1e-5):
    xf = x.astype(F32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(F32) + bias.astype(F32)).astype(x.dtype)


# --------------------------------------------------------------------------
# rotary position embeddings
# --------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))


def apply_rope(x, positions, theta: float = 10000.0):
    """x: (..., T, head_dim); positions: (..., T) int32.

    Angles (position-dependent) are computed in f32; the rotation products
    run in the storage dtype.  Casting *x* to f32 here would promote the
    whole upstream QKV matmul to f32 under XLA's convert-hoisting, doubling
    the weight-gather wire bytes (§Perf cell-1 iteration 5)."""
    hd = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(hd, theta), dtype=F32)
    ang = positions.astype(F32)[..., None] * freqs  # (..., T, hd/2)
    cos = jnp.cos(ang).astype(x.dtype)
    sin = jnp.sin(ang).astype(x.dtype)
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin],
                           axis=-1)


# --------------------------------------------------------------------------
# blockwise attention (online softmax)
# --------------------------------------------------------------------------

def _softcap(x, cap: float):
    if cap and cap > 0:
        return jnp.tanh(x / cap) * cap
    return x


def _gqa_scores(q, k):
    """q: (B, Hq, Tq, D), k: (B, Hkv, Tk, D) -> (B, Hq, Tq, Tk)."""
    B, Hq, Tq, D = q.shape
    Hkv = k.shape[1]
    g = Hq // Hkv
    qg = q.reshape(B, Hkv, g, Tq, D)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, k)
    return s.reshape(B, Hq, Tq, k.shape[2])


def _gqa_combine(p, v):
    """p: (B, Hq, Tq, Tk), v: (B, Hkv, Tk, D) -> (B, Hq, Tq, D)."""
    B, Hq, Tq, Tk = p.shape
    Hkv = v.shape[1]
    g = Hq // Hkv
    pg = p.reshape(B, Hkv, g, Tq, Tk)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", pg, v)
    return o.reshape(B, Hq, Tq, v.shape[3])


def blockwise_attention(q, k, v, *, causal: bool = True,
                        q_offset=0, attn_softcap: float = 0.0,
                        q_chunk: int = 512, kv_chunk: int = 1024,
                        kv_len=None):
    """Memory-safe attention.  q: (B, Hq, Tq, D), k/v: (B, Hkv, Tk, D).

    ``q_offset``: absolute position of q[0] (for decode / chunked prefill).
    ``kv_len``: actual valid KV length (int array ok) for cache decode.
    """
    B, Hq, Tq, D = q.shape
    Tk = k.shape[2]
    scale = D ** -0.5
    q = q * scale
    q_chunk = min(q_chunk, Tq)
    kv_chunk = min(kv_chunk, Tk)
    n_q = -(-Tq // q_chunk)
    n_kv = -(-Tk // kv_chunk)
    # pad to chunk multiples
    Tq_p, Tk_p = n_q * q_chunk, n_kv * kv_chunk
    if Tq_p != Tq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, Tq_p - Tq), (0, 0)))
    if Tk_p != Tk:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, Tk_p - Tk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, Tk_p - Tk), (0, 0)))
    if kv_len is None:
        kv_len = Tk
    kv_len = jnp.asarray(kv_len)
    per_batch = kv_len.ndim == 1  # (B,) per-slot lengths (serving engine)

    def q_block(qi, q_blk):
        q_pos = q_offset + qi * q_chunk + jnp.arange(q_chunk)

        def kv_step(carry, ki):
            acc, m, denom = carry
            k_blk = jax.lax.dynamic_slice_in_dim(k, ki * kv_chunk, kv_chunk, 2)
            v_blk = jax.lax.dynamic_slice_in_dim(v, ki * kv_chunk, kv_chunk, 2)
            kv_pos = ki * kv_chunk + jnp.arange(kv_chunk)
            s = _gqa_scores(q_blk, k_blk).astype(F32)
            s = _softcap(s, attn_softcap)
            if per_batch:
                # (B, 1, 1, Tk) validity x (1, 1, Tq, Tk) causality
                mask = (kv_pos[None, None, None, :]
                        < kv_len[:, None, None, None])
            else:
                mask = (kv_pos[None, :] < kv_len)[None, None]
            if causal:
                mask = mask & (kv_pos[None, :]
                               <= q_pos[:, None])[None, None]
            s = jnp.where(mask, s, -1e30)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            denom = denom * corr + p.sum(axis=-1)
            acc = acc * corr[..., None] + _gqa_combine(p, v_blk).astype(F32)
            return (acc, m_new, denom), None

        acc0 = jnp.zeros((B, Hq, q_chunk, D), F32)
        m0 = jnp.full((B, Hq, q_chunk), -1e30, F32)
        d0 = jnp.zeros((B, Hq, q_chunk), F32)
        (acc, m, denom), _ = jax.lax.scan(kv_step, (acc0, m0, d0),
                                          jnp.arange(n_kv))
        return acc / jnp.maximum(denom[..., None], 1e-30)

    if n_q == 1:
        out = q_block(0, q)
    else:
        q_blocks = q.reshape(B, Hq, n_q, q_chunk, D).transpose(2, 0, 1, 3, 4)
        out = jax.lax.map(lambda args: q_block(args[0], args[1]),
                          (jnp.arange(n_q), q_blocks))
        out = out.transpose(1, 2, 0, 3, 4).reshape(B, Hq, Tq_p, D)
    return out[:, :, :Tq].astype(v.dtype)


def local_attention(q, k, v, *, window: int, attn_softcap: float = 0.0,
                    q_chunk: int = 1024):
    """Causal sliding-window attention, O(Tq * window).

    For each q chunk [i*C, (i+1)*C), attends to KV slice
    [i*C - window, (i+1)*C) with the window mask applied inside."""
    B, Hq, Tq, D = q.shape
    scale = D ** -0.5
    q = q * scale
    C = min(q_chunk, Tq)
    n_q = -(-Tq // C)
    Tq_p = n_q * C
    if Tq_p != Tq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, Tq_p - Tq), (0, 0)))
    W = min(window, k.shape[2])
    ctx = C + W  # kv context per q chunk
    k_pad = jnp.pad(k, ((0, 0), (0, 0), (W, Tq_p - Tq), (0, 0)))
    v_pad = jnp.pad(v, ((0, 0), (0, 0), (W, Tq_p - Tq), (0, 0)))

    def q_block(qi):
        q_blk = jax.lax.dynamic_slice_in_dim(q, qi * C, C, 2)
        k_blk = jax.lax.dynamic_slice_in_dim(k_pad, qi * C, ctx, 2)
        v_blk = jax.lax.dynamic_slice_in_dim(v_pad, qi * C, ctx, 2)
        s = _gqa_scores(q_blk, k_blk).astype(F32)
        s = _softcap(s, attn_softcap)
        q_pos = qi * C + jnp.arange(C)
        kv_pos = qi * C + jnp.arange(ctx) - W
        mask = (kv_pos[None, :] <= q_pos[:, None]) & (
            kv_pos[None, :] > q_pos[:, None] - W) & (kv_pos[None, :] >= 0)
        s = jnp.where(mask[None, None], s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        return _gqa_combine(p, v_blk)

    out = jax.lax.map(q_block, jnp.arange(n_q))  # (n_q, B, H, C, D)
    out = out.transpose(1, 2, 0, 3, 4).reshape(B, Hq, Tq_p, D)
    return out[:, :, :Tq].astype(v.dtype)


def decode_attention(q, k_cache, v_cache, *, kv_len,
                     attn_softcap: float = 0.0):
    """Single-token decode attention over a cache.

    q: (B, Hq, 1, D); caches: (B, Hkv, S, D); kv_len: scalar int array."""
    return blockwise_attention(
        q, k_cache, v_cache, causal=False, attn_softcap=attn_softcap,
        kv_len=kv_len, q_chunk=1, kv_chunk=min(2048, k_cache.shape[2]),
    )


# --------------------------------------------------------------------------
# MLPs
# --------------------------------------------------------------------------

def mlp_apply(params, x, mlp_type: str, dtype):
    if mlp_type == "swiglu":
        g = x @ mat(params["wi_gate"], dtype)
        u = x @ mat(params["wi_up"], dtype)
        return (jax.nn.silu(g.astype(F32)).astype(dtype) * u) @ mat(
            params["wo"], dtype)
    if mlp_type == "gelu":
        h = jax.nn.gelu(x @ mat(params["wi"], dtype), approximate=True)
        return h @ mat(params["wo"], dtype)
    if mlp_type == "geglu":
        g = x @ mat(params["wi_gate"], dtype)
        u = x @ mat(params["wi_up"], dtype)
        return (jax.nn.gelu(g.astype(F32), approximate=True).astype(dtype)
                * u) @ mat(params["wo"], dtype)
    if mlp_type == "sqrelu":
        h = jax.nn.relu(x @ mat(params["wi"], dtype))
        return jnp.square(h) @ mat(params["wo"], dtype)
    raise ValueError(mlp_type)


def mlp_init(rng, d_model: int, d_ff: int, mlp_type: str, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(rng, 3)
    s_in = d_model ** -0.5
    s_ff = d_ff ** -0.5
    if mlp_type in ("swiglu", "geglu"):
        return {
            "wi_gate": jax.random.normal(k1, (d_model, d_ff), dtype) * s_in,
            "wi_up": jax.random.normal(k2, (d_model, d_ff), dtype) * s_in,
            "wo": jax.random.normal(k3, (d_ff, d_model), dtype) * s_ff,
        }
    return {
        "wi": jax.random.normal(k1, (d_model, d_ff), dtype) * s_in,
        "wo": jax.random.normal(k3, (d_ff, d_model), dtype) * s_ff,
    }
