"""Unified model: decoder-only LMs (dense / MoE / hybrid / ssm) and the
whisper encoder-decoder, built from ``ArchConfig``.

Layer stacking uses **scan-over-units**: one unit = one repetition of the
config's per-layer ``pattern`` (e.g. ("local","attn") for gemma2).  Units
with identical structure are stacked and run under ``lax.scan`` — one traced
copy regardless of depth, which bounds compile time for the 40-cell dry-run
and gives the remat boundary.  ``n_layers % unit`` leftover layers run
unrolled as the "tail".

Three entry points (all pure functions of (params, inputs)):
  forward(params, cfg, tokens [, frames])         -> logits       (train)
  prefill(params, cfg, tokens [, frames])         -> (logits, cache)
  decode_step(params, cfg, token, cache)          -> (logits, cache)

Caches are pytrees with static shapes (`init_cache`) so decode steps lower
with ``jax.jit`` + ShapeDtypeStructs in the multi-pod dry-run.
"""
from __future__ import annotations

from functools import partial

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.kvcache import paged as paged_kv
from . import recurrent as rec
from .layers import (F32, apply_rope, blockwise_attention, decode_attention,
                     layer_norm, local_attention, mat, mlp_apply, mlp_init,
                     rms_norm)
from .moe import moe_apply, moe_init

ATTN_KINDS = ("attn", "local", "nope")


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------

def _attn_init(rng, cfg: ArchConfig, dtype):
    d, hd = cfg.d_model, cfg.hd
    ks = jax.random.split(rng, 5)
    s = d ** -0.5
    p = {
        "wq": jax.random.normal(ks[0], (d, cfg.n_heads * hd), dtype) * s,
        "wk": jax.random.normal(ks[1], (d, cfg.n_kv_heads * hd), dtype) * s,
        "wv": jax.random.normal(ks[2], (d, cfg.n_kv_heads * hd), dtype) * s,
        "wo": jax.random.normal(ks[3], (cfg.n_heads * hd, d), dtype) * s,
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), dtype)
        p["k_norm"] = jnp.zeros((hd,), dtype)
    return p


def _layer_init(rng, cfg: ArchConfig, kind: str, dtype):
    ks = jax.random.split(rng, 4)
    d = cfg.d_model
    p = {"norm1": jnp.zeros((d,), dtype)}
    if kind in ATTN_KINDS:
        p["attn"] = _attn_init(ks[0], cfg, dtype)
    elif kind == "rglru":
        p["rglru"] = rec.rglru_init(ks[0], d, d, dtype)
    elif kind == "slstm":
        p["cell"] = rec.slstm_init(ks[0], d, cfg.n_heads, dtype)
    elif kind == "mlstm":
        p["cell"] = rec.mlstm_init(ks[0], d, cfg.n_heads, dtype)
    else:
        raise ValueError(kind)
    has_ffn = cfg.d_ff > 0 or cfg.n_experts > 0
    if kind in ("slstm", "mlstm") and cfg.d_ff == 0:
        has_ffn = False
    if has_ffn:
        p["norm2"] = jnp.zeros((d,), dtype)
        if cfg.n_experts:
            p["moe"] = moe_init(ks[1], d, cfg.n_experts, cfg.moe_d_ff,
                                cfg.n_shared_experts, cfg.moe_d_ff,
                                cfg.top_k, dtype)
        else:
            p["mlp"] = mlp_init(ks[1], d, cfg.d_ff, cfg.mlp_type, dtype)
    if cfg.post_norms:
        p["post_norm1"] = jnp.zeros((d,), dtype)
        if has_ffn:
            p["post_norm2"] = jnp.zeros((d,), dtype)
    if cfg.encoder_decoder:  # decoder cross-attention
        p["norm_x"] = jnp.zeros((d,), dtype)
        p["cross"] = _attn_init(ks[2], cfg, dtype)
    return p


def _enc_layer_init(rng, cfg: ArchConfig, dtype):
    ks = jax.random.split(rng, 2)
    d = cfg.d_model
    return {
        "norm1": jnp.zeros((d,), dtype),
        "attn": _attn_init(ks[0], cfg, dtype),
        "norm2": jnp.zeros((d,), dtype),
        "mlp": mlp_init(ks[1], d, cfg.d_ff, cfg.mlp_type, dtype),
    }


def init_params(rng, cfg: ArchConfig, dtype=jnp.float32):
    ks = jax.random.split(rng, 8)
    d, V = cfg.d_model, cfg.vocab_size
    params = {
        "embed": jax.random.normal(ks[0], (V, d), dtype) * (d ** -0.5),
        "final_norm": jnp.zeros((d,), dtype),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = jax.random.normal(ks[1], (d, V), dtype) * (
            d ** -0.5)

    unit = cfg.unit
    n_units = cfg.n_layers // unit
    n_tail = cfg.n_layers - n_units * unit

    def unit_init(r):
        kr = jax.random.split(r, unit)
        return {f"pos{j}": _layer_init(kr[j], cfg, cfg.pattern[j], dtype)
                for j in range(unit)}

    unit_rngs = jax.random.split(ks[2], n_units)
    params["units"] = jax.tree_util.tree_map(
        lambda *xs: jnp.stack(xs), *[unit_init(r) for r in unit_rngs])
    params["tail"] = {
        f"layer{t}": _layer_init(jax.random.split(ks[3], max(n_tail, 1))[t],
                                 cfg, cfg.layer_kind(n_units * unit + t),
                                 dtype)
        for t in range(n_tail)
    }
    if cfg.encoder_decoder:
        enc_rngs = jax.random.split(ks[4], cfg.n_encoder_layers)
        params["encoder"] = {
            "layers": jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs),
                *[_enc_layer_init(r, cfg, dtype) for r in enc_rngs]),
            "final_norm": jnp.zeros((d,), dtype),
            "pos_embed": jax.random.normal(
                ks[5], (cfg.encoder_frames, d), dtype) * 0.02,
        }
    return params


# --------------------------------------------------------------------------
# sub-blocks
# --------------------------------------------------------------------------

def _qkv(p, x, cfg: ArchConfig, dtype, rope: bool, positions):
    """positions: (T,) shared, or (B, T) per-slot (serving engine)."""
    B, T, _ = x.shape
    hd = cfg.hd
    q = (x @ mat(p["wq"], dtype)).reshape(B, T, cfg.n_heads, hd)
    k = (x @ mat(p["wk"], dtype)).reshape(B, T, cfg.n_kv_heads, hd)
    v = (x @ mat(p["wv"], dtype)).reshape(B, T, cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    q = q.transpose(0, 2, 1, 3)
    k = k.transpose(0, 2, 1, 3)
    v = v.transpose(0, 2, 1, 3)
    if rope:
        pos_b = (positions[None, None, :] if positions.ndim == 1
                 else positions[:, None, :])
        q = apply_rope(q, pos_b, cfg.rope_theta)
        k = apply_rope(k, pos_b, cfg.rope_theta)
    return q, k, v


def _attn_out(p, o, dtype):
    B, H, T, hd = o.shape
    o = o.transpose(0, 2, 1, 3).reshape(B, T, H * hd)
    return o @ mat(p["wo"], dtype)


def _self_attention_full(p, x, cfg: ArchConfig, kind: str, dtype,
                         mesh=None):
    """Full-sequence causal self attention (train / prefill)."""
    from .flash_attention import flash_attention, flash_attention_sharded
    from .layers import get_attention_impl
    T = x.shape[1]
    positions = jnp.arange(T)
    q, k, v = _qkv(p, x, cfg, dtype, rope=(kind != "nope"), positions=positions)
    if kind == "local" and (cfg.local_window < T
                            or get_attention_impl() != "flash"):
        o = local_attention(q, k, v, window=cfg.local_window,
                            attn_softcap=cfg.attn_softcap)
    elif get_attention_impl() == "flash":
        if mesh is not None and "model" in mesh.axis_names:
            o = flash_attention_sharded(q, k, v, mesh,
                                        attn_softcap=cfg.attn_softcap)
        else:
            o = flash_attention(q, k, v, True, cfg.attn_softcap)
    else:
        o = blockwise_attention(q, k, v, causal=True,
                                attn_softcap=cfg.attn_softcap)
    return _attn_out(p, o, dtype), (k, v)


def _self_attention_decode(p, x, cfg: ArchConfig, kind: str, dtype, cache,
                           cur_len, mesh=None, page_table=None):
    """One-token decode with KV cache update.

    ``cur_len`` is a scalar (shared timeline) or (B,) per-slot positions
    (continuous-batching serving engine).  A paged cache (``k_pool``
    leaves + shared ``page_table``) routes through the page-scatter /
    page-gather path; cold pages are entropy-decoded in-graph."""
    per_slot = cur_len.ndim == 1
    q, k, v = _qkv(p, x, cfg, dtype, rope=(kind != "nope"),
                   positions=cur_len[:, None] if per_slot else cur_len[None])
    if "k_pool" in cache:
        from .decode_sharded import (paged_decode_attention_sharded,
                                     paged_shardable)
        # fault-before-gather: negative page-table entries are swap
        # sentinels (``kvcache.swap`` holds the page on the host).  The
        # engine faults every *active* slot fully resident before the
        # step, so a sentinel can only belong to a vacated slot whose
        # rows are never read — clamp it to the garbage page so the
        # unconditional scatter/gather below stays in bounds.
        page_table = jnp.maximum(page_table, paged_kv.GARBAGE_PAGE)
        if paged_shardable(cache, page_table, cur_len, mesh):
            # mesh-sharded paged path: pool/table shard over the batch
            # axes (per-shard page ranges, fully local scatter/gather);
            # a model axis splits each slot's pages and merges stats
            o, k_pool, v_pool = paged_decode_attention_sharded(
                q, k, v, cache, page_table, cur_len, mesh,
                softcap=cfg.attn_softcap)
            new_cache = {**cache, "k_pool": k_pool, "v_pool": v_pool}
            return _attn_out(p, o, dtype), new_cache
        k_pool = paged_kv.page_write(cache["k_pool"], page_table, cur_len, k)
        v_pool = paged_kv.page_write(cache["v_pool"], page_table, cur_len, v)
        k_hist = paged_kv.page_gather(k_pool, page_table,
                                      cpool=paged_kv.cold_leaves(cache, "k"))
        v_hist = paged_kv.page_gather(v_pool, page_table,
                                      cpool=paged_kv.cold_leaves(cache, "v"))
        o = decode_attention(q, k_hist, v_hist, kv_len=cur_len + 1,
                             attn_softcap=cfg.attn_softcap)
        new_cache = {**cache, "k_pool": k_pool, "v_pool": v_pool}
        return _attn_out(p, o, dtype), new_cache
    W = cache["k"].shape[2]
    slot = cur_len % W if kind == "local" else cur_len
    if (mesh is not None and not per_slot and "model" in mesh.axis_names
            and W % mesh.shape["model"] == 0 and mesh.shape["model"] > 1):
        # sequence-sharded cache + cross-shard stat merge (§Perf cell 3)
        from .decode_sharded import decode_attention_update_sharded
        vlen = jnp.minimum(cur_len + 1, W) if kind == "local" \
            else cur_len + 1
        o, k_cache, v_cache = decode_attention_update_sharded(
            q, cache["k"], cache["v"], k, v, vlen, slot, mesh,
            softcap=cfg.attn_softcap)
        return _attn_out(p, o, dtype), {"k": k_cache, "v": v_cache}
    if per_slot:
        upd = jax.vmap(lambda c, n, s: jax.lax.dynamic_update_slice_in_dim(
            c, n, s, axis=1))
        k_cache = upd(cache["k"], k, slot)
        v_cache = upd(cache["v"], v, slot)
    else:
        k_cache = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, slot,
                                                      axis=2)
        v_cache = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, slot,
                                                      axis=2)
    if kind == "local":
        # ring buffer: all W slots may be valid once cur_len >= W
        kv_len = jnp.minimum(cur_len + 1, W)
        # mask by validity: slots with position > cur_len are stale only
        # before wrap; kv_len handles that case since slots fill in order.
        o = decode_attention(q, k_cache, v_cache, kv_len=kv_len,
                             attn_softcap=cfg.attn_softcap)
    else:
        o = decode_attention(q, k_cache, v_cache, kv_len=cur_len + 1,
                             attn_softcap=cfg.attn_softcap)
    new_cache = {"k": k_cache, "v": v_cache}
    return _attn_out(p, o, dtype), new_cache


def _self_attention_chunk(p, x, cfg: ArchConfig, kind: str, dtype, cache,
                          page_table, slot, start, n_valid, mesh=None):
    """One prefill **chunk** for a single slot of the paged cache.

    x: (1, C, d) — a fixed-size padded chunk of the slot's prompt;
    ``slot``/``start``/``n_valid`` are traced scalars (one compilation
    serves every prompt length and chunk position).  The chunk's K/V is
    scattered into the slot's pages (``page_write_chunk``), the slot's
    whole history (previous chunks included, cold pages entropy-decoded)
    is gathered back, and the chunk attends causally over it with
    ``q_offset=start`` — resuming prefill from the existing cache prefix.
    Only paged kinds are supported; the engine gates chunked prefill to
    architectures where every layer pages ('attn'/'nope')."""
    C = x.shape[1]
    positions = start + jnp.arange(C)
    q, k, v = _qkv(p, x, cfg, dtype, rope=(kind != "nope"),
                   positions=positions)
    row = jnp.maximum(page_table[slot], paged_kv.GARBAGE_PAGE)
    from .decode_sharded import chunk_shardable, paged_prefill_chunk_sharded
    if chunk_shardable(cache, mesh):
        o, k_pool, v_pool = paged_prefill_chunk_sharded(
            q, k, v, cache, row, slot, positions, n_valid, mesh,
            n_slots=page_table.shape[0], softcap=cfg.attn_softcap)
    else:
        k_pool = paged_kv.page_write_chunk(cache["k_pool"], row, positions,
                                           k, n_valid)
        v_pool = paged_kv.page_write_chunk(cache["v_pool"], row, positions,
                                           v, n_valid)
        k_hist = paged_kv.page_gather(k_pool, row[None],
                                      cpool=paged_kv.cold_leaves(cache, "k"))
        v_hist = paged_kv.page_gather(v_pool, row[None],
                                      cpool=paged_kv.cold_leaves(cache, "v"))
        o = blockwise_attention(q, k_hist, v_hist, causal=True,
                                q_offset=start, kv_len=start + n_valid,
                                attn_softcap=cfg.attn_softcap)
    new_cache = {**cache, "k_pool": k_pool, "v_pool": v_pool}
    return _attn_out(p, o, dtype), new_cache


def _layer_apply_chunk(p, x, cfg: ArchConfig, kind: str, dtype, mesh, cache,
                       page_table, slot, start, n_valid):
    """Chunk-mode layer: decode-layer residual structure at T=C."""
    if kind not in ATTN_KINDS or kind == "local":
        raise ValueError(
            f"chunked prefill only pages 'attn'/'nope' layers, got {kind}")
    h = rms_norm(x, p["norm1"])
    o, cache = _self_attention_chunk(p["attn"], h, cfg, kind, dtype, cache,
                                     page_table, slot, start, n_valid,
                                     mesh=mesh)
    if cfg.post_norms:
        o = rms_norm(o, p["post_norm1"])
    x = x + o
    if "mlp" in p or "moe" in p:
        h2 = rms_norm(x, p["norm2"])
        o2, _ = _ffn(p, h2, cfg, dtype, mesh)
        if cfg.post_norms:
            o2 = rms_norm(o2, p["post_norm2"])
        x = x + o2
    return x, cache


def _ffn(p, x, cfg: ArchConfig, dtype, mesh):
    if "moe" in p:
        y, aux = moe_apply(p["moe"], x, cfg, mesh=mesh, dtype=dtype)
        return y, aux
    return mlp_apply(p["mlp"], x, cfg.mlp_type, dtype), jnp.zeros((), F32)


def _layer_apply_full(p, x, cfg: ArchConfig, kind: str, dtype, mesh,
                      cross_ctx=None, constrain=None):
    """Full-sequence layer (train / prefill).  Returns (x, cache, aux).

    ``constrain`` re-pins the residual stream after every block output so
    GSPMD lowers the TP partial sums as reduce-scatters back to the
    sequence-sharded layout instead of full all-reduces (§Perf cell-1
    iteration 4)."""
    constrain = constrain or (lambda x: x)
    h = rms_norm(x, p["norm1"])
    cache = {}
    if kind in ATTN_KINDS:
        o, (k, v) = _self_attention_full(p["attn"], h, cfg, kind, dtype,
                                         mesh=mesh)
        cache = {"k": k, "v": v}
    elif kind == "rglru":
        o, st = rec.rglru_apply(p["rglru"], h, dtype=dtype)
        cache = st
    elif kind == "slstm":
        o, st = rec.slstm_apply(p["cell"], h, cfg.n_heads, dtype=dtype)
        cache = st
    elif kind == "mlstm":
        o, st = rec.mlstm_apply(p["cell"], h, cfg.n_heads, dtype=dtype,
                                chunk=min(128, h.shape[1]))
        cache = st
    if cfg.post_norms:
        o = rms_norm(o, p["post_norm1"])
    x = constrain(x + o)

    if cross_ctx is not None and "cross" in p:
        hx = rms_norm(x, p["norm_x"])
        o = _cross_attention(p["cross"], hx, cross_ctx, cfg, dtype)
        x = x + o

    aux = jnp.zeros((), F32)
    if "mlp" in p or "moe" in p:
        h2 = rms_norm(x, p["norm2"])
        o2, aux = _ffn(p, h2, cfg, dtype, mesh)
        if cfg.post_norms:
            o2 = rms_norm(o2, p["post_norm2"])
        x = constrain(x + o2)
    return x, cache, aux


def _layer_apply_decode(p, x, cfg: ArchConfig, kind: str, dtype, mesh, cache,
                        cur_len, cross_kv=None, page_table=None):
    h = rms_norm(x, p["norm1"])
    if kind in ATTN_KINDS:
        o, cache = _self_attention_decode(p["attn"], h, cfg, kind, dtype,
                                          cache, cur_len, mesh=mesh,
                                          page_table=page_table)
    elif kind == "rglru":
        o, cache = rec.rglru_step(p["rglru"], h[:, 0], cache, dtype=dtype)
        o = o[:, None, :]
    elif kind == "slstm":
        o, cache = rec.slstm_step(p["cell"], h[:, 0], cache, cfg.n_heads,
                                  dtype=dtype)
        o = o[:, None, :]
    elif kind == "mlstm":
        o, cache = rec.mlstm_step(p["cell"], h[:, 0], cache, cfg.n_heads,
                                  dtype=dtype)
        o = o[:, None, :]
    if cfg.post_norms:
        o = rms_norm(o, p["post_norm1"])
    x = x + o

    if cross_kv is not None and "cross" in p:
        hx = rms_norm(x, p["norm_x"])
        q, _, _ = _qkv(p["cross"], hx, cfg, dtype, rope=False,
                       positions=cur_len[None])
        o = decode_attention(q, cross_kv["k"], cross_kv["v"],
                             kv_len=cross_kv["k"].shape[2])
        x = x + _attn_out(p["cross"], o, dtype)

    if "mlp" in p or "moe" in p:
        h2 = rms_norm(x, p["norm2"])
        o2, _ = _ffn(p, h2, cfg, dtype, mesh)
        if cfg.post_norms:
            o2 = rms_norm(o2, p["post_norm2"])
        x = x + o2
    return x, cache


def _cross_attention(p, x, ctx, cfg: ArchConfig, dtype):
    """x: (B, T, d) queries; ctx: (B, F, d) encoder output."""
    B, T, _ = x.shape
    hd = cfg.hd
    q = (x @ mat(p["wq"], dtype)).reshape(B, T, cfg.n_heads, hd)
    k = (ctx @ mat(p["wk"], dtype)).reshape(B, -1, cfg.n_kv_heads, hd)
    v = (ctx @ mat(p["wv"], dtype)).reshape(B, -1, cfg.n_kv_heads, hd)
    o = blockwise_attention(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                            v.transpose(0, 2, 1, 3), causal=False)
    return _attn_out(p, o, dtype)


# --------------------------------------------------------------------------
# encoder (whisper)
# --------------------------------------------------------------------------

def encode_frames(params, cfg: ArchConfig, frames, dtype):
    """frames: (B, F, d) precomputed frontend embeddings (stub)."""
    enc = params["encoder"]
    x = frames.astype(dtype) + mat(enc["pos_embed"], dtype)[None]

    def enc_layer(x, p):
        h = rms_norm(x, p["norm1"])
        T = h.shape[1]
        q, k, v = _qkv(p["attn"], h, cfg, dtype, rope=False,
                       positions=jnp.arange(T))
        o = blockwise_attention(q, k, v, causal=False)
        x = x + _attn_out(p["attn"], o, dtype)
        h2 = rms_norm(x, p["norm2"])
        x = x + mlp_apply(p["mlp"], h2, cfg.mlp_type, dtype)
        return x, None

    x, _ = jax.lax.scan(enc_layer, x, enc["layers"])
    return rms_norm(x, enc["final_norm"])


# --------------------------------------------------------------------------
# public entry points
# --------------------------------------------------------------------------

def _embed(params, cfg: ArchConfig, tokens, dtype):
    x = jnp.take(mat(params["embed"], dtype), tokens, axis=0)
    if cfg.embed_scale:
        x = x * jnp.asarray(np.sqrt(cfg.d_model), dtype)
    return x


def _unembed(params, cfg: ArchConfig, x, dtype):
    x = rms_norm(x, params["final_norm"])
    if cfg.tie_embeddings:
        logits = x @ mat(params["embed"], dtype).T
    else:
        logits = x @ mat(params["unembed"], dtype)
    logits = logits.astype(F32)
    if cfg.logit_softcap:
        logits = jnp.tanh(logits / cfg.logit_softcap) * cfg.logit_softcap
    return logits


def _run_stack(params, cfg: ArchConfig, x, dtype, mesh, mode: str,
               cache=None, cur_len=None, cross_ctx=None, remat: bool = False,
               constrain=None):
    """Run units (scan) + tail.  mode: 'full' or 'decode'.

    ``constrain``: optional residual-stream sharding constraint, applied at
    every unit boundary (GSPMD sequence-parallelism hook, runtime/sharding).
    """
    unit = cfg.unit
    n_units = cfg.n_layers // unit
    aux_total = jnp.zeros((), F32)
    constrain = constrain or (lambda x: x)

    if mode == "full":
        def unit_body(x, unit_p):
            x = constrain(x)
            aux = jnp.zeros((), F32)
            caches = {}
            for j in range(unit):
                x, c, a = _layer_apply_full(unit_p[f"pos{j}"], x, cfg,
                                            cfg.pattern[j], dtype, mesh,
                                            cross_ctx, constrain=constrain)
                caches[f"pos{j}"] = c
                aux = aux + a
            return x, (caches, aux)

        body = jax.checkpoint(unit_body) if remat else unit_body
        x, (unit_caches, auxes) = jax.lax.scan(body, x, params["units"])
        x = constrain(x)
        aux_total = aux_total + auxes.sum()
        tail_caches = {}
        for t, (name, p) in enumerate(sorted(params["tail"].items())):
            kind = cfg.layer_kind(n_units * unit + t)
            x, c, a = _layer_apply_full(p, x, cfg, kind, dtype, mesh,
                                        cross_ctx)
            tail_caches[name] = c
            aux_total = aux_total + a
        return x, {"units": unit_caches, "tail": tail_caches}, aux_total

    # decode
    page_table = cache.get("page_table")

    def unit_body(x, xs):
        unit_p, unit_c = xs
        new_c = {}
        for j in range(unit):
            x, c = _layer_apply_decode(unit_p[f"pos{j}"], x, cfg,
                                       cfg.pattern[j], dtype, mesh,
                                       unit_c[f"pos{j}"], cur_len,
                                       cross_kv=(unit_c.get("cross")
                                                 if cfg.encoder_decoder
                                                 else None),
                                       page_table=page_table)
            new_c[f"pos{j}"] = c
        if cfg.encoder_decoder and "cross" in unit_c:
            new_c["cross"] = unit_c["cross"]
        return x, new_c

    x, new_unit_caches = jax.lax.scan(unit_body, x,
                                      (params["units"], cache["units"]))
    new_tail = {}
    for t, (name, p) in enumerate(sorted(params["tail"].items())):
        kind = cfg.layer_kind(n_units * unit + t)
        tc = cache["tail"][name]
        x, c = _layer_apply_decode(p, x, cfg, kind, dtype, mesh, tc, cur_len,
                                   cross_kv=tc.get("cross"),
                                   page_table=page_table)
        if cfg.encoder_decoder and "cross" in tc:
            c["cross"] = tc["cross"]
        new_tail[name] = c
    return x, {"units": new_unit_caches, "tail": new_tail}, aux_total


def forward(params, cfg: ArchConfig, tokens, frames=None, mesh=None,
            remat: bool = False, constrain=None):
    """Training forward -> logits (B, T, V)."""
    dtype = jnp.dtype(cfg.dtype)
    x = _embed(params, cfg, tokens, dtype)
    cross_ctx = (encode_frames(params, cfg, frames, dtype)
                 if cfg.encoder_decoder else None)
    x, _, aux = _run_stack(params, cfg, x, dtype, mesh, "full",
                           cross_ctx=cross_ctx, remat=remat,
                           constrain=constrain)
    return _unembed(params, cfg, x, dtype), aux


def loss_fn(params, cfg: ArchConfig, tokens, labels, frames=None, mesh=None,
            remat: bool = False, aux_weight: float = 0.01, constrain=None):
    logits, aux = forward(params, cfg, tokens, frames=frames, mesh=mesh,
                          remat=remat, constrain=constrain)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    loss = nll.mean() + aux_weight * aux
    return loss, {"nll": nll.mean(), "aux": aux}


# ---- caches ---------------------------------------------------------------

def _layer_cache_spec(cfg: ArchConfig, kind: str, batch: int, max_len: int,
                      dtype):
    hd = cfg.hd
    if kind in ("attn", "nope"):
        s = (batch, cfg.n_kv_heads, max_len, hd)
        return {"k": jnp.zeros(s, dtype), "v": jnp.zeros(s, dtype)}
    if kind == "local":
        W = min(cfg.local_window, max_len)
        s = (batch, cfg.n_kv_heads, W, hd)
        return {"k": jnp.zeros(s, dtype), "v": jnp.zeros(s, dtype)}
    if kind == "rglru":
        return rec.rglru_init_state(batch, cfg.d_model)
    if kind == "slstm":
        return rec.slstm_init_state(batch, cfg.n_heads, cfg.d_model)
    if kind == "mlstm":
        return rec.mlstm_init_state(batch, cfg.n_heads, cfg.d_model)
    raise ValueError(kind)


def init_cache(cfg: ArchConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16, per_slot: bool = False):
    """``per_slot=True`` makes ``cur_len`` a (B,) vector — every batch slot
    runs its own timeline (continuous-batching serving engine)."""
    unit = cfg.unit
    n_units = cfg.n_layers // unit
    n_tail = cfg.n_layers - n_units * unit

    def unit_cache():
        c = {f"pos{j}": _layer_cache_spec(cfg, cfg.pattern[j], batch,
                                          max_len, dtype)
             for j in range(unit)}
        if cfg.encoder_decoder:
            s = (batch, cfg.n_kv_heads, cfg.encoder_frames, cfg.hd)
            c["cross"] = {"k": jnp.zeros(s, dtype), "v": jnp.zeros(s, dtype)}
        return c

    units = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs),
                                   *[unit_cache() for _ in range(n_units)])
    tail = {}
    for t in range(n_tail):
        c = _layer_cache_spec(cfg, cfg.layer_kind(n_units * unit + t), batch,
                              max_len, dtype)
        if cfg.encoder_decoder:
            s = (batch, cfg.n_kv_heads, cfg.encoder_frames, cfg.hd)
            c["cross"] = {"k": jnp.zeros(s, dtype), "v": jnp.zeros(s, dtype)}
        tail[f"layer{t}"] = c
    cur = (jnp.zeros((batch,), jnp.int32) if per_slot
           else jnp.zeros((), jnp.int32))
    return {"units": units, "tail": tail, "cur_len": cur}


def prefill(params, cfg: ArchConfig, tokens, frames=None, mesh=None,
            max_len: int | None = None, constrain=None):
    """Process a prompt, build the cache -> (last-pos logits, cache)."""
    dtype = jnp.dtype(cfg.dtype)
    B, T = tokens.shape
    max_len = max_len or T
    x = _embed(params, cfg, tokens, dtype)
    cross_ctx = (encode_frames(params, cfg, frames, dtype)
                 if cfg.encoder_decoder else None)
    x, run_caches, _ = _run_stack(params, cfg, x, dtype, mesh, "full",
                                  cross_ctx=cross_ctx, constrain=constrain)
    logits = _unembed(params, cfg, x[:, -1:], dtype)

    cache = init_cache(cfg, B, max_len, dtype)

    def fill(spec, got, kind):
        if kind in ("attn", "nope"):
            return jax.lax.dynamic_update_slice_in_dim(
                spec, got.astype(spec.dtype), 0, axis=2)
        if kind == "local":
            W = spec.shape[2]
            if T >= W:
                # last W entries, aligned to ring slots (pos % W)
                tail_kv = got[:, :, T - W:]
                shift = T % W
                return jnp.roll(tail_kv.astype(spec.dtype), shift=shift,
                                axis=2)
            return jax.lax.dynamic_update_slice_in_dim(
                spec, got.astype(spec.dtype), 0, axis=2)
        return got  # recurrent states already final

    # units
    unit = cfg.unit
    new_units = {}
    for j in range(unit):
        kind = cfg.pattern[j]
        spec_c = cache["units"][f"pos{j}"]
        got_c = run_caches["units"][f"pos{j}"]
        if kind in ATTN_KINDS:
            new_units[f"pos{j}"] = {
                n: jax.vmap(lambda s, g, n=n: fill(s, g, kind))(spec_c[n],
                                                                got_c[n])
                for n in ("k", "v")
            }
        else:
            new_units[f"pos{j}"] = jax.tree_util.tree_map(
                lambda s, g: g.astype(s.dtype), spec_c, got_c)
    if cfg.encoder_decoder:
        new_units["cross"] = _make_cross_kv(params, cfg, cross_ctx, dtype)
    new_tail = {}
    for t, (name, _) in enumerate(sorted(params["tail"].items())):
        kind = cfg.layer_kind((cfg.n_layers // unit) * unit + t)
        spec_c = cache["tail"][name]
        got_c = run_caches["tail"][name]
        if kind in ATTN_KINDS:
            new_tail[name] = {n: fill(spec_c[n], got_c[n], kind)
                              for n in ("k", "v")}
        else:
            new_tail[name] = jax.tree_util.tree_map(
                lambda s, g: g.astype(s.dtype), spec_c, got_c)
        if cfg.encoder_decoder:
            new_tail[name]["cross"] = jax.tree_util.tree_map(
                lambda x: x[0], _make_cross_kv(params, cfg, cross_ctx, dtype))
    return logits, {"units": new_units, "tail": new_tail,
                    "cur_len": jnp.full((), T, jnp.int32)}


def prefill_chunk(params, cfg: ArchConfig, tokens, cache, slot, n_valid,
                  mesh=None):
    """Process one fixed-size prompt chunk for ``slot`` of a paged cache.

    tokens: (1, C) int32 — a chunk of the prompt padded to the engine's
    chunk size; ``slot`` and ``n_valid`` (the count of real tokens) are
    traced scalars, and the chunk's start position is read from
    ``cache["cur_len"][slot]`` — so **one compilation serves every prompt
    length, chunk index and slot** (the whole-prompt ``prefill`` retraces
    per prompt length).  K/V is appended straight into the slot's pages
    across chunk boundaries; the final chunk's last-position logits are
    where the request's first token is sampled from.

    Returns (logits (1, 1, V) at position ``n_valid - 1`` of the chunk,
    new cache with ``cur_len[slot] += n_valid``).  Requires a paged cache
    and an architecture whose every layer is a paged kind ('attn'/'nope');
    the serving engine falls back to whole-prompt prefill otherwise."""
    x, new_cache, n_valid = _chunk_stack(params, cfg, tokens, cache, slot,
                                         n_valid, mesh)
    last = jax.lax.dynamic_slice_in_dim(
        x, jnp.maximum(n_valid - 1, 0), 1, axis=1)
    logits = _unembed(params, cfg, last, jnp.dtype(cfg.dtype))
    return logits, new_cache


def verify_chunk(params, cfg: ArchConfig, tokens, cache, slot, n_valid,
                 mesh=None):
    """The speculative-decoding verify forward: :func:`prefill_chunk`'s
    chunk program, but unembedding **every** chunk position.

    tokens: (1, C) — the slot's last emitted token followed by the draft's
    proposals (padded to the engine's ``spec_k + 1`` verify width; one
    compilation serves every request/slot, like the prefill chunk).
    Returns (logits (1, C, V), new cache): row ``i`` of the logits
    conditions on the cache prefix plus ``tokens[:, :i+1]`` — the target
    distribution that proposal ``i+1`` is accepted against
    (``serving.spec.verify``), with row ``n_valid - 1`` scoring the bonus
    token.  K/V for all ``n_valid`` tokens lands in the slot's pages and
    ``cur_len[slot]`` advances by ``n_valid``; the engine rolls the
    rejected suffix back afterwards (``PagedKVCache.rollback``) — the
    same timeline-rollback discipline as the chunked-prefill masked
    rows."""
    x, new_cache, _ = _chunk_stack(params, cfg, tokens, cache, slot,
                                   n_valid, mesh)
    logits = _unembed(params, cfg, x, jnp.dtype(cfg.dtype))
    return logits, new_cache


def _chunk_stack(params, cfg: ArchConfig, tokens, cache, slot, n_valid,
                 mesh):
    """Shared chunk program of :func:`prefill_chunk` / :func:`verify_chunk`:
    embed, run every layer in chunk mode (page-append + causal attention
    over the gathered history), advance the slot's timeline.  Returns the
    residual stream ``x`` (1, C, d) before unembedding."""
    dtype = jnp.dtype(cfg.dtype)
    cur_len = cache["cur_len"]
    start = cur_len[slot]
    page_table = cache["page_table"]
    x = _embed(params, cfg, tokens, dtype)

    unit = cfg.unit
    n_units = cfg.n_layers // unit

    def unit_body(x, xs):
        unit_p, unit_c = xs
        new_c = {}
        for j in range(unit):
            x, c = _layer_apply_chunk(unit_p[f"pos{j}"], x, cfg,
                                      cfg.pattern[j], dtype, mesh,
                                      unit_c[f"pos{j}"], page_table, slot,
                                      start, n_valid)
            new_c[f"pos{j}"] = c
        return x, new_c

    x, new_units = jax.lax.scan(unit_body, x,
                                (params["units"], cache["units"]))
    new_tail = {}
    for t, (name, p) in enumerate(sorted(params["tail"].items())):
        kind = cfg.layer_kind(n_units * unit + t)
        x, c = _layer_apply_chunk(p, x, cfg, kind, dtype, mesh,
                                  cache["tail"][name], page_table, slot,
                                  start, n_valid)
        new_tail[name] = c
    new_cache = {"units": new_units, "tail": new_tail,
                 "cur_len": cur_len.at[slot].set(start + n_valid),
                 "page_table": page_table}
    return x, new_cache, n_valid


def _make_cross_kv(params, cfg, cross_ctx, dtype):
    """Precompute per-unit cross-attention K/V from encoder output."""
    def one_unit(unit_p):
        kvs = {}
        for j in range(cfg.unit):
            p = unit_p[f"pos{j}"]["cross"]
            B, Fr, _ = cross_ctx.shape
            k = (cross_ctx @ mat(p["wk"], dtype)).reshape(
                B, Fr, cfg.n_kv_heads, cfg.hd).transpose(0, 2, 1, 3)
            v = (cross_ctx @ mat(p["wv"], dtype)).reshape(
                B, Fr, cfg.n_kv_heads, cfg.hd).transpose(0, 2, 1, 3)
            kvs = {"k": k, "v": v}  # single pattern pos for whisper (unit=1)
        return kvs

    return jax.vmap(one_unit, in_axes=0)(params["units"])


def decode_step(params, cfg: ArchConfig, token, cache, mesh=None):
    """token: (B, 1) int32 -> (logits (B, 1, V), new cache)."""
    dtype = jnp.dtype(cfg.dtype)
    cur_len = cache["cur_len"]
    x = _embed(params, cfg, token, dtype)
    x, new_cache, _ = _run_stack(params, cfg, x, dtype, mesh, "decode",
                                 cache=cache, cur_len=cur_len)
    logits = _unembed(params, cfg, x, dtype)
    new_cache["cur_len"] = cur_len + 1
    if "page_table" in cache:
        new_cache["page_table"] = cache["page_table"]
    return logits, new_cache
