"""Mixture-of-Experts layer with expert parallelism (EP) via shard_map.

Routing: softmax gate, top-k selection, per-expert capacity C = ceil(
T_local * k / E * capacity_factor).  Dispatch is *local-first*: each data
shard selects, for every expert, up to C of its own tokens (vmapped top_k —
static shapes, no global cumsum/sort, no cross-shard serialization).  When
experts are sharded over the ``model`` axis (EP), the (E, C, d) dispatch
buffer is exchanged with a single all_to_all so each shard computes only its
local experts, then a second all_to_all returns expert outputs — the
canonical token->expert->token exchange, expressed with jax-native
collectives instead of torch.distributed semantics (DESIGN.md §5).

Without a mesh (smoke tests, single host) the same code runs with the
all_to_all elided (E_local == E).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from .layers import mat, F32


def moe_init(rng, d_model: int, n_experts: int, moe_d_ff: int,
             n_shared: int, d_ff_shared: int, top_k: int,
             dtype=jnp.float32):
    ks = jax.random.split(rng, 5)
    s_in, s_ff = d_model ** -0.5, moe_d_ff ** -0.5
    p = {
        "gate": jax.random.normal(ks[0], (d_model, n_experts), dtype) * s_in,
        "wi_gate": jax.random.normal(
            ks[1], (n_experts, d_model, moe_d_ff), dtype) * s_in,
        "wi_up": jax.random.normal(
            ks[2], (n_experts, d_model, moe_d_ff), dtype) * s_in,
        "wo": jax.random.normal(
            ks[3], (n_experts, moe_d_ff, d_model), dtype) * s_ff,
    }
    if n_shared:
        from .layers import mlp_init
        p["shared"] = mlp_init(ks[4], d_model, d_ff_shared * n_shared,
                               "swiglu", dtype)
    return p


def _expert_ffn(wi_gate, wi_up, wo, x):
    """x: (E, C, d); weights: (E, d, ff) / (E, ff, d)."""
    g = jnp.einsum("ecd,edf->ecf", x, wi_gate)
    u = jnp.einsum("ecd,edf->ecf", x, wi_up)
    h = jax.nn.silu(g.astype(F32)).astype(x.dtype) * u
    return jnp.einsum("ecf,efd->ecd", h, wo)


def _route_local(x, gate_w, top_k: int, n_experts: int, capacity: int):
    """Local routing: x (T, d) -> dispatch buffer + combine metadata.

    Returns (buf (E, C, d), src_idx (E, C), src_w (E, C), aux_loss)."""
    T, d = x.shape
    logits = (x @ gate_w).astype(F32)                     # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, top_k)            # (T, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # per-expert weight of each token (0 if not routed there): (E, T)
    onehot = jax.nn.one_hot(top_i, n_experts, dtype=F32)  # (T, k, E)
    w_te = (onehot * top_p[..., None]).sum(axis=1)        # (T, E)
    w_et = w_te.T                                         # (E, T)

    # per-expert top-C token selection (static shapes, local)
    sel_w, sel_idx = jax.lax.top_k(w_et, min(capacity, T))  # (E, C)
    if capacity > T:
        pad = capacity - T
        sel_w = jnp.pad(sel_w, ((0, 0), (0, pad)))
        sel_idx = jnp.pad(sel_idx, ((0, 0), (0, pad)))
    buf = jnp.take(x, sel_idx, axis=0)                    # (E, C, d)
    buf = buf * (sel_w[..., None] > 0).astype(x.dtype)

    # load-balancing aux loss (Switch-style)
    frac_tokens = (w_te > 0).astype(F32).mean(axis=0)
    frac_probs = probs.mean(axis=0)
    aux = n_experts * jnp.sum(frac_tokens * frac_probs)
    return buf, sel_idx, sel_w, aux


def moe_apply(params, x, cfg, *, mesh=None, ep_axis: str = "model",
              dtype=jnp.bfloat16):
    """x: (B, T, d) -> (B, T, d), plus aux loss (returned via dict)."""
    B, T, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    cf = getattr(cfg, "capacity_factor", 1.25)

    gate_w = mat(params["gate"], dtype)
    wi_gate = mat(params["wi_gate"], dtype)
    wi_up = mat(params["wi_up"], dtype)
    wo = mat(params["wo"], dtype)

    def local_moe(x_loc, gate_w, wi_gate, wi_up, wo):
        """Runs per data-shard; expert weights are per-model-shard (EP)."""
        Bl, Tl, _ = x_loc.shape
        xt = x_loc.reshape(Bl * Tl, d)
        E_loc = wi_gate.shape[0]
        n_ep = E // E_loc
        cap = max(8, int((Bl * Tl * k * cf) / E + 0.999))
        buf, sel_idx, sel_w, aux = _route_local(xt, gate_w, k, E, cap)

        if n_ep > 1:
            # (E, C, d) -> (n_ep, E_loc, C, d) -> a2a over expert shards
            buf = buf.reshape(n_ep, E_loc, cap, d)
            buf = jax.lax.all_to_all(buf, ep_axis, split_axis=0,
                                     concat_axis=0, tiled=False)
            # now (n_ep, E_loc, C, d): rows = source shards, local experts
            y = _expert_ffn(
                wi_gate, wi_up, wo,
                buf.transpose(1, 0, 2, 3).reshape(E_loc, n_ep * cap, d))
            y = y.reshape(E_loc, n_ep, cap, d).transpose(1, 0, 2, 3)
            y = jax.lax.all_to_all(y, ep_axis, split_axis=0,
                                   concat_axis=0, tiled=False)
            y = y.reshape(E, cap, d)
        else:
            y = _expert_ffn(wi_gate, wi_up, wo, buf)

        # combine: scatter expert outputs back to tokens, weighted
        out = jnp.zeros((Bl * Tl, d), dtype=y.dtype)
        w = sel_w.astype(y.dtype)[..., None]              # (E, C, 1)
        out = out.at[sel_idx.reshape(-1)].add(
            (y * w).reshape(-1, d), mode="drop")
        return out.reshape(Bl, Tl, d), aux.reshape(1)

    if mesh is not None and ep_axis in mesh.axis_names and (
            mesh.shape[ep_axis] > 1):
        batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        out, aux = shard_map(
            local_moe, mesh=mesh,
            in_specs=(P(batch_axes, None, None), P(None, None),
                      P(ep_axis, None, None), P(ep_axis, None, None),
                      P(ep_axis, None, None)),
            out_specs=(P(batch_axes, None, None), P(batch_axes)),
            check_rep=False,
        )(x.astype(dtype), gate_w, wi_gate, wi_up, wo)
        aux = aux.mean()
    else:
        out, aux = local_moe(x.astype(dtype), gate_w, wi_gate, wi_up, wo)
        aux = aux[0]

    if "shared" in params:
        from .layers import mlp_apply
        out = out + mlp_apply(params["shared"], x.astype(dtype), "swiglu",
                              dtype)
    return out.astype(x.dtype), aux
