"""Distributed decode attention: sequence-sharded KV cache + stat merge.

The decode-step profile (§Perf cell 3) showed GSPMD gathering f32 cache
chunks across the model axis every (layer x kv-chunk) when the cache
shards on head_dim (the only shardable dim for MQA archs like granite).
The scalable structure shards the cache on the *sequence* dim instead:

  * each model shard owns a contiguous S/n_model slice of the cache with
    full head_dim — the new token's K/V is written only by the owning
    shard (a masked in-place update);
  * each shard attends over its local slice, producing an *unnormalized*
    accumulator plus online-softmax row stats (m, l);
  * shards merge with one tiny all-gather of (o_partial, m, l) —
    O(B x H x D) bytes per layer instead of O(B x S x D) cache gathers.

This is the flash-attention merge rule applied across devices (tree
attention); forward-only, so no custom VJP is needed.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from .flash_attention import _gqa_scores, _gqa_combine

F32 = jnp.float32
NEG = -1e30


def _local_attend_stats(q, k, v, kv_len_local, softcap: float):
    """One-token attention over the local cache slice, unnormalized.

    q: (B, Hq, 1, D); k/v: (B, Hkv, S_loc, D); kv_len_local: scalar.
    Returns (acc (B, Hq, 1, D) f32, m (B, Hq, 1) f32, l (B, Hq, 1) f32)."""
    D = q.shape[-1]
    s = _gqa_scores(q * (D ** -0.5), k)            # (B, Hq, 1, S_loc) f32
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    pos = jnp.arange(k.shape[2])
    s = jnp.where((pos < kv_len_local)[None, None, None, :], s, NEG)
    m = s.max(axis=-1)
    p = jnp.exp(s - m[..., None])
    p = jnp.where((pos < kv_len_local)[None, None, None, :], p, 0.0)
    l = p.sum(axis=-1)
    acc = _gqa_combine(p.astype(v.dtype), v)       # f32 accumulate
    return acc, m, l


def decode_attention_update_sharded(q, k_cache, v_cache, new_k, new_v,
                                    vlen, slot, mesh, *,
                                    softcap: float = 0.0):
    """Sharded decode: cache update + attention + merge, one shard_map.

    q/new_k/new_v: (B, H*, 1, D); caches: (B, Hkv, S, D) sharded on S over
    ``model``; ``vlen``: scalar count of valid cache slots *after* the
    update (cur_len+1, or min(cur_len+1, W) for ring buffers); ``slot``:
    scalar write position (cur_len, or cur_len % W for rings).
    Returns (o (B, Hq, 1, D), new_k_cache, new_v_cache)."""
    ba = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    ba = ba if len(ba) != 1 else ba[0]
    B, S = k_cache.shape[0], k_cache.shape[2]
    n_model = mesh.shape["model"]
    s_loc = S // n_model
    b_ax = ba if B % _axes_size(mesh, ba) == 0 else None

    def body(q_l, kc, vc, nk, nv, vlen_g, slot_g):
        i = jax.lax.axis_index("model")
        lo = i * s_loc
        slot_l = jnp.clip(slot_g - lo, 0, s_loc - 1)
        owned = (slot_g >= lo) & (slot_g < lo + s_loc)
        # write the new token only on the owning shard (masked update:
        # non-owners re-write the existing value at slot_l)
        cur_k = jax.lax.dynamic_slice_in_dim(kc, slot_l, 1, axis=2)
        cur_v = jax.lax.dynamic_slice_in_dim(vc, slot_l, 1, axis=2)
        up_k = jnp.where(owned, nk.astype(kc.dtype), cur_k)
        up_v = jnp.where(owned, nv.astype(vc.dtype), cur_v)
        kc = jax.lax.dynamic_update_slice_in_dim(kc, up_k, slot_l, axis=2)
        vc = jax.lax.dynamic_update_slice_in_dim(vc, up_v, slot_l, axis=2)

        kv_len_local = jnp.clip(vlen_g - lo, 0, s_loc)
        acc, m, l = _local_attend_stats(q_l, kc, vc, kv_len_local, softcap)

        # merge across the model axis: tiny all-gather of (acc, m, l)
        acc_all = jax.lax.all_gather(acc, "model")   # (n, B, Hq, 1, D)
        m_all = jax.lax.all_gather(m, "model")       # (n, B, Hq, 1)
        l_all = jax.lax.all_gather(l, "model")
        m_g = m_all.max(axis=0)
        w = jnp.exp(m_all - m_g[None])               # (n, B, Hq, 1)
        denom = (l_all * w).sum(axis=0)
        num = (acc_all * w[..., None]).sum(axis=0)
        o = num / jnp.maximum(denom, 1e-30)[..., None]
        return o.astype(vc.dtype), kc, vc

    return shard_map(
        body, mesh=mesh,
        in_specs=(P(b_ax, None, None, None),          # q
                  P(b_ax, None, "model", None),       # k cache
                  P(b_ax, None, "model", None),       # v cache
                  P(b_ax, None, None, None),          # new k
                  P(b_ax, None, None, None),          # new v
                  P(), P()),
        out_specs=(P(b_ax, None, None, None),
                   P(b_ax, None, "model", None),
                   P(b_ax, None, "model", None)),
        check_rep=False,
    )(q, k_cache, v_cache, new_k, new_v, vlen, slot)


def _axes_size(mesh, ba):
    n = 1
    for a in (ba if isinstance(ba, tuple) else (ba,)):
        n *= mesh.shape[a]
    return n
