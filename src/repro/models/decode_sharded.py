"""Distributed decode attention: sequence-sharded KV cache + stat merge,
and the mesh-sharded paged-cache decode path.

The decode-step profile (§Perf cell 3) showed GSPMD gathering f32 cache
chunks across the model axis every (layer x kv-chunk) when the cache
shards on head_dim (the only shardable dim for MQA archs like granite).
The scalable structure shards the cache on the *sequence* dim instead:

  * each model shard owns a contiguous S/n_model slice of the cache with
    full head_dim — the new token's K/V is written only by the owning
    shard (a masked in-place update);
  * each shard attends over its local slice, producing an *unnormalized*
    accumulator plus online-softmax row stats (m, l);
  * shards merge with one tiny all-gather of (o_partial, m, l) —
    O(B x H x D) bytes per layer instead of O(B x S x D) cache gathers.

This is the flash-attention merge rule applied across devices (tree
attention); forward-only, so no custom VJP is needed.

:func:`paged_decode_attention_sharded` applies the same structure to the
**paged** cache (``repro.kvcache``) under a mesh:

  * the page pool's page dim and the page table's batch dim shard over
    the mesh's **batch axes** (``runtime.sharding.batch_axes``); the
    allocator (``PagedKVCache(n_shards=...)``) only ever hands a slot
    pages from its own shard's range, so page scatter/gather is fully
    local — zero cross-device page traffic, and (with no model axis) the
    local path is the *same program* as the single-device paged decode,
    making sharded serving bit-identical to the monolithic baseline;
  * an optional **model** axis splits each slot's logical pages
    round-robin across model shards (page ``p`` -> shard ``p % n_model``,
    a compute/VMEM split of the replicated local pool): every model shard
    gathers only its page columns (entropy-decoding cold pages from the
    local shard only), attends with a per-position validity mask, and
    shards merge with the same tiny (acc, m, l) all-gather as above.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.kvcache import paged as paged_kv
from .flash_attention import _gqa_scores, _gqa_combine

F32 = jnp.float32
NEG = -1e30


def _local_attend_stats(q, k, v, kv_len_local, softcap: float):
    """One-token attention over the local cache slice, unnormalized.

    q: (B, Hq, 1, D); k/v: (B, Hkv, S_loc, D); kv_len_local: scalar.
    Returns (acc (B, Hq, 1, D) f32, m (B, Hq, 1) f32, l (B, Hq, 1) f32)."""
    D = q.shape[-1]
    s = _gqa_scores(q * (D ** -0.5), k)            # (B, Hq, 1, S_loc) f32
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    pos = jnp.arange(k.shape[2])
    s = jnp.where((pos < kv_len_local)[None, None, None, :], s, NEG)
    m = s.max(axis=-1)
    p = jnp.exp(s - m[..., None])
    p = jnp.where((pos < kv_len_local)[None, None, None, :], p, 0.0)
    l = p.sum(axis=-1)
    acc = _gqa_combine(p.astype(v.dtype), v)       # f32 accumulate
    return acc, m, l


def decode_attention_update_sharded(q, k_cache, v_cache, new_k, new_v,
                                    vlen, slot, mesh, *,
                                    softcap: float = 0.0):
    """Sharded decode: cache update + attention + merge, one shard_map.

    q/new_k/new_v: (B, H*, 1, D); caches: (B, Hkv, S, D) sharded on S over
    ``model``; ``vlen``: scalar count of valid cache slots *after* the
    update (cur_len+1, or min(cur_len+1, W) for ring buffers); ``slot``:
    scalar write position (cur_len, or cur_len % W for rings).
    Returns (o (B, Hq, 1, D), new_k_cache, new_v_cache)."""
    ba = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    ba = ba if len(ba) != 1 else ba[0]
    B, S = k_cache.shape[0], k_cache.shape[2]
    n_model = mesh.shape["model"]
    s_loc = S // n_model
    b_ax = ba if B % _axes_size(mesh, ba) == 0 else None

    def body(q_l, kc, vc, nk, nv, vlen_g, slot_g):
        i = jax.lax.axis_index("model")
        lo = i * s_loc
        slot_l = jnp.clip(slot_g - lo, 0, s_loc - 1)
        owned = (slot_g >= lo) & (slot_g < lo + s_loc)
        # write the new token only on the owning shard (masked update:
        # non-owners re-write the existing value at slot_l)
        cur_k = jax.lax.dynamic_slice_in_dim(kc, slot_l, 1, axis=2)
        cur_v = jax.lax.dynamic_slice_in_dim(vc, slot_l, 1, axis=2)
        up_k = jnp.where(owned, nk.astype(kc.dtype), cur_k)
        up_v = jnp.where(owned, nv.astype(vc.dtype), cur_v)
        kc = jax.lax.dynamic_update_slice_in_dim(kc, up_k, slot_l, axis=2)
        vc = jax.lax.dynamic_update_slice_in_dim(vc, up_v, slot_l, axis=2)

        kv_len_local = jnp.clip(vlen_g - lo, 0, s_loc)
        acc, m, l = _local_attend_stats(q_l, kc, vc, kv_len_local, softcap)
        o = _merge_stats(acc, m, l, "model")
        return o.astype(vc.dtype), kc, vc

    return shard_map(
        body, mesh=mesh,
        in_specs=(P(b_ax, None, None, None),          # q
                  P(b_ax, None, "model", None),       # k cache
                  P(b_ax, None, "model", None),       # v cache
                  P(b_ax, None, None, None),          # new k
                  P(b_ax, None, None, None),          # new v
                  P(), P()),
        out_specs=(P(b_ax, None, None, None),
                   P(b_ax, None, "model", None),
                   P(b_ax, None, "model", None)),
        check_rep=False,
    )(q, k_cache, v_cache, new_k, new_v, vlen, slot)


def _axes_size(mesh, ba):
    n = 1
    for a in (ba if isinstance(ba, tuple) else (ba,)):
        n *= mesh.shape[a]
    return n


def _merge_stats(acc, m, l, axis_name):
    """Flash-attention merge of per-shard softmax stats across ``axis_name``.

    acc: (B, Hq, 1, D) unnormalized f32 accumulator; m/l: (B, Hq, 1) f32
    row max / row sum.  One tiny all-gather of (acc, m, l) — O(B x Hq x D)
    bytes — then the tree-attention combine.  Shards with no valid
    position carry m == NEG and weigh in as exp(NEG - m_g) == 0."""
    acc_all = jax.lax.all_gather(acc, axis_name)     # (n, B, Hq, 1, D)
    m_all = jax.lax.all_gather(m, axis_name)         # (n, B, Hq, 1)
    l_all = jax.lax.all_gather(l, axis_name)
    m_g = m_all.max(axis=0)
    w = jnp.exp(m_all - m_g[None])                   # (n, B, Hq, 1)
    denom = (l_all * w).sum(axis=0)
    num = (acc_all * w[..., None]).sum(axis=0)
    return num / jnp.maximum(denom, 1e-30)[..., None]


def _attend_stats_masked(q, k, v, valid, softcap: float):
    """One-token attention over a gathered history with an explicit
    per-position validity mask, unnormalized.

    q: (B, Hq, 1, D); k/v: (B, Hkv, S, D); valid: (B, S) bool.
    Returns (acc (B, Hq, 1, D) f32, m (B, Hq, 1) f32, l (B, Hq, 1) f32)."""
    D = q.shape[-1]
    s = _gqa_scores(q * (D ** -0.5), k).astype(F32)  # (B, Hq, 1, S)
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    vm = valid[:, None, None, :]
    s = jnp.where(vm, s, NEG)
    m = s.max(axis=-1)
    p = jnp.exp(s - m[..., None])
    p = jnp.where(vm, p, 0.0)
    l = p.sum(axis=-1)
    acc = _gqa_combine(p.astype(v.dtype), v).astype(F32)
    return acc, m, l


# --------------------------------------------------------------------------
# paged cache under a mesh
# --------------------------------------------------------------------------

def paged_shardable(cache: dict, page_table, cur_len, mesh) -> bool:
    """Whether this paged cache leaf-dict can take the sharded decode path.

    Requires per-slot timelines, at least one mesh axis of size > 1, and
    batch / pool / cold dims divisible by the batch-axes size (the
    ``PagedKVCache(n_shards=batch_axes_size)`` layout guarantees this)."""
    if mesh is None or page_table is None or cur_len.ndim != 1:
        return False
    ba = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    n_ba = int(np.prod([mesh.shape[a] for a in ba])) if ba else 1
    n_model = mesh.shape["model"] if "model" in mesh.axis_names else 1
    if n_ba == 1 and n_model == 1:
        return False
    B = page_table.shape[0]
    if B % n_ba:
        return False
    if cache["k_pool"].shape[0] % n_ba:
        return False
    if "k_cpl" in cache and cache["k_cpl"].shape[0] % n_ba:
        return False
    return True


def chunk_shardable(cache: dict, mesh) -> bool:
    """Whether a chunk-prefill call on this paged leaf-dict should take
    :func:`paged_prefill_chunk_sharded` — a mesh with batch axes of size
    > 1 and pool/cold dims divisible by that size (the
    ``PagedKVCache(n_shards=...)`` layout).  A model-axis-only mesh
    returns False; the engine gates chunked prefill off there."""
    if mesh is None:
        return False
    ba = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    n_ba = int(np.prod([mesh.shape[a] for a in ba])) if ba else 1
    if n_ba == 1:
        return False
    if cache["k_pool"].shape[0] % n_ba:
        return False
    if "k_cpl" in cache and cache["k_cpl"].shape[0] % n_ba:
        return False
    return True


def paged_prefill_chunk_sharded(q, new_k, new_v, cache, row, slot,
                                positions, n_valid, mesh, *,
                                n_slots: int, softcap: float = 0.0):
    """Chunked prefill for one slot under a batch-axes mesh.

    q/new_k/new_v: (1, H*, C, D) — one padded chunk; ``row``: (P,) the
    slot's page-table row (global ids); ``slot``/``n_valid``: traced
    scalars; ``positions``: (C,) absolute token positions; ``n_slots``:
    the engine's static ``max_batch`` (slot ``s`` lives on batch shard
    ``s // (n_slots / n_ba)``, the allocator's contiguous slot ranges).

    The slot's pages all live on the batch shard that owns the slot
    (per-shard id ranges), so the **owning shard runs the exact
    single-device chunk program on its local pool** — write the chunk
    K/V, gather the slot's history (local cold pages entropy-decoded),
    attend causally from ``q_offset = positions[0]``.  Non-owner shards
    park their writes out of range (dropped) and mask every key
    (``kv_len = 0`` → a zero partial), and one ``psum`` over the batch
    axes replicates the owner's output — bit-identical to the
    single-device chunk, like the sharded decode path.

    Returns (o (1, Hq, C, D), new_k_pool, new_v_pool)."""
    ba = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    n_ba = _axes_size(mesh, ba)
    b_ax = ba if len(ba) != 1 else ba[0]

    k_pool, v_pool = cache["k_pool"], cache["v_pool"]
    cold_k = paged_kv.cold_leaves(cache, "k")
    cold_v = paged_kv.cold_leaves(cache, "v")
    has_cold = cold_k is not None
    n_pool = k_pool.shape[0]
    n_cold = cold_k[0].shape[0] if has_cold else 0
    from .layers import blockwise_attention

    def body(q_l, nk, nv, kp, vp, row_g, pos, slot_g, nv_g, *cold_flat):
        d = jnp.int32(0)
        for a in ba:
            d = d * mesh.shape[a] + jax.lax.axis_index(a)
        L_loc = kp.shape[0]                     # n_pool // n_ba
        lo = d * L_loc
        c_loc = n_cold // n_ba
        cold_lo = d * c_loc
        ck = cold_flat[:4] if has_cold else None
        cv = cold_flat[4:] if has_cold else None
        # contiguous slot ranges per batch shard (PagedKVCache layout):
        # the owner holds every one of the slot's pages locally
        owned = (slot_g // (n_slots // n_ba)) == d
        is_cold = row_g >= n_pool
        raw_loc = row_g - lo
        loc = jnp.where(is_cold, L_loc + (row_g - n_pool - cold_lo),
                        raw_loc)
        wrow = jnp.where((row_g >= lo) & (row_g < lo + L_loc), raw_loc,
                         L_loc)
        nv_l = jnp.where(owned, nv_g, 0)        # park non-owner writes
        kp = paged_kv.page_write_chunk(kp, wrow, pos, nk, nv_l)
        vp = paged_kv.page_write_chunk(vp, wrow, pos, nv, nv_l)
        gtbl = jnp.clip(loc, 0, L_loc + c_loc - 1)
        k_hist = paged_kv.page_gather(kp, gtbl[None], cpool=ck)
        v_hist = paged_kv.page_gather(vp, gtbl[None], cpool=cv)
        o = blockwise_attention(
            q_l, k_hist, v_hist, causal=True, q_offset=pos[0],
            kv_len=jnp.where(owned, pos[0] + nv_g, 0),
            attn_softcap=softcap)
        o = jax.lax.psum(jnp.where(owned, o, jnp.zeros_like(o)), ba)
        return o, kp, vp

    pool_spec = P(b_ax, None, None, None)
    cold_specs = tuple(P(b_ax, *(None,) * (x.ndim - 1))
                       for x in ((*cold_k, *cold_v) if has_cold else ()))
    rep = P(None, None, None, None)
    return shard_map(
        body, mesh=mesh,
        in_specs=(rep, rep, rep,                 # q, new k, new v
                  pool_spec, pool_spec,          # k/v pool
                  P(None),                       # page-table row
                  P(None),                       # positions
                  P(), P(),                      # slot, n_valid
                  *cold_specs),
        out_specs=(rep, pool_spec, pool_spec),
        check_rep=False,
    )(q, new_k, new_v, k_pool, v_pool, row, positions, slot, n_valid,
      *((*cold_k, *cold_v) if has_cold else ()))


def paged_decode_attention_sharded(q, new_k, new_v, cache, page_table,
                                   cur_len, mesh, *, softcap: float = 0.0):
    """Sharded paged decode: page write + gather + attention, one shard_map.

    q/new_k/new_v: (B, H*, 1, D); ``cache`` is one attention group's leaf
    dict (``k_pool``/``v_pool`` (n_pages, Hkv, ps, hd) plus the cold-pool
    leaves when present); ``page_table``: (B, P) global page ids;
    ``cur_len``: (B,) per-slot write positions.

    Sharding invariants (see module docstring): pool page dim, cold-slot
    dim, page-table batch dim and ``cur_len`` shard over the batch axes;
    q/new K/V shard their batch dim likewise and replicate over ``model``.
    With no model axis each batch shard runs the exact single-device
    program on its local rows/pages (bit-identical outputs); with a model
    axis each model shard attends over logical pages ``p % n_model == m``
    and the shards merge softmax stats.

    Returns (o (B, Hq, 1, D), new_k_pool, new_v_pool).
    """
    ba = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    n_ba = _axes_size(mesh, ba) if ba else 1
    n_model = mesh.shape["model"] if "model" in mesh.axis_names else 1
    b_ax = (ba if len(ba) != 1 else ba[0]) if ba else None

    k_pool, v_pool = cache["k_pool"], cache["v_pool"]
    cold_k = paged_kv.cold_leaves(cache, "k")
    cold_v = paged_kv.cold_leaves(cache, "v")
    has_cold = cold_k is not None
    n_pool = k_pool.shape[0]
    ps = k_pool.shape[2]
    P_log = page_table.shape[1]
    n_cold = cold_k[0].shape[0] if has_cold else 0
    from .layers import decode_attention

    def body(q_l, nk, nv, kp, vp, tbl, clen, *cold_flat):
        # linear batch-shard index over the (possibly composite) batch axes
        d = jnp.int32(0)
        for a in ba:
            d = d * mesh.shape[a] + jax.lax.axis_index(a)
        L_loc = kp.shape[0]                     # n_pool // n_ba
        lo = d * L_loc
        c_loc = n_cold // n_ba
        cold_lo = d * c_loc
        ck = cold_flat[:4] if has_cold else None
        cv = cold_flat[4:] if has_cold else None

        # global -> local ids.  Raw local pages land in [0, L_loc); local
        # cold slots in [L_loc, L_loc + c_loc); anything else (another
        # shard's pages, or the garbage id 0 on shards with lo > 0) is
        # clamped/dropped and masked out of the attention below.
        is_cold = tbl >= n_pool
        raw_loc = tbl - lo
        loc = jnp.where(is_cold, L_loc + (tbl - n_pool - cold_lo), raw_loc)
        # writes: only raw local tail pages; everything else out of range
        # (mode="drop" in page_write) so non-owners never touch the pool
        wtbl = jnp.where((tbl >= lo) & (tbl < lo + L_loc), raw_loc, L_loc)
        kp = paged_kv.page_write(kp, wtbl, clen, nk)
        vp = paged_kv.page_write(vp, wtbl, clen, nv)

        if n_model == 1:
            # every page of a local slot is local: run the exact
            # single-device paged decode on the shard's rows
            gtbl = jnp.clip(loc, 0, L_loc + c_loc - 1)
            k_hist = paged_kv.page_gather(kp, gtbl, cpool=ck)
            v_hist = paged_kv.page_gather(vp, gtbl, cpool=cv)
            o = decode_attention(q_l, k_hist, v_hist, kv_len=clen + 1,
                                 attn_softcap=softcap)
            return o, kp, vp

        # model axis: logical page p belongs to model shard p % n_model
        m_idx = jax.lax.axis_index("model")
        P_m = -(-P_log // n_model)              # static ceil
        col = m_idx + n_model * jnp.arange(P_m)             # (P_m,)
        sub = jnp.take(jnp.clip(loc, 0, L_loc + c_loc - 1),
                       jnp.minimum(col, P_log - 1), axis=1)  # (B_loc, P_m)
        k_hist = paged_kv.page_gather(kp, sub, cpool=ck)
        v_hist = paged_kv.page_gather(vp, sub, cpool=cv)
        # validity of gathered position j*ps + t  <->  global position
        # col[j]*ps + t, masked by the slot's live length and col < P
        pos = (col[:, None] * ps + jnp.arange(ps)[None]).reshape(-1)
        valid = (pos[None, :] < (clen + 1)[:, None]) \
            & (col < P_log).repeat(ps)[None, :]
        acc, m, l = _attend_stats_masked(q_l, k_hist, v_hist, valid,
                                         softcap)
        o = _merge_stats(acc, m, l, "model").astype(vp.dtype)
        return o, kp, vp

    pool_spec = P(b_ax, None, None, None)
    cold_specs = tuple(P(b_ax, *(None,) * (x.ndim - 1))
                       for x in ((*cold_k, *cold_v) if has_cold else ()))
    out = shard_map(
        body, mesh=mesh,
        in_specs=(P(b_ax, None, None, None),            # q
                  P(b_ax, None, None, None),            # new k
                  P(b_ax, None, None, None),            # new v
                  pool_spec, pool_spec,                 # k/v pool
                  P(b_ax, None),                        # page table
                  P(b_ax),                              # cur_len
                  *cold_specs),
        out_specs=(P(b_ax, None, None, None), pool_spec, pool_spec),
        check_rep=False,
    )(q, new_k, new_v, k_pool, v_pool, page_table, cur_len,
      *((*cold_k, *cold_v) if has_cold else ()))
    return out
