"""Recurrent sequence mixers: RG-LRU (RecurrentGemma/Griffin), sLSTM and
mLSTM (xLSTM).  Each mixer exposes:

  *_init(rng, ...)                       -> params
  *_apply(params, x, ...)                -> (y, final_state)   # full sequence
  *_step(params, x_t, state, ...)        -> (y_t, state)       # decode

Training/prefill paths are parallel where the math allows it: RG-LRU uses
``associative_scan`` (log-depth linear recurrence), mLSTM uses a chunkwise
parallel form (intra-chunk matmuls + inter-chunk state scan) validated
against the sequential reference; sLSTM is inherently sequential (state-
dependent nonlinearity) and uses ``lax.scan`` — all O(1)-state, which is why
these architectures run the ``long_500k`` shape (DESIGN.md §4).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import F32

# --------------------------------------------------------------------------
# RG-LRU (Griffin)
# --------------------------------------------------------------------------

RGLRU_C = 8.0
CONV_WIDTH = 4


def rglru_init(rng, d_model: int, d_rnn: int, dtype=jnp.float32):
    ks = jax.random.split(rng, 8)
    s = d_model ** -0.5
    return {
        "w_in": jax.random.normal(ks[0], (d_model, d_rnn), dtype) * s,
        "w_gate_in": jax.random.normal(ks[1], (d_model, d_rnn), dtype) * s,
        "conv_w": jax.random.normal(ks[2], (CONV_WIDTH, d_rnn), dtype) * 0.1,
        "conv_b": jnp.zeros((d_rnn,), dtype),
        "w_a": jax.random.normal(ks[3], (d_rnn, d_rnn), dtype) * s,
        "b_a": jnp.zeros((d_rnn,), dtype),
        "w_x": jax.random.normal(ks[4], (d_rnn, d_rnn), dtype) * s,
        "b_x": jnp.zeros((d_rnn,), dtype),
        # Lambda init so a ~ U(0.9, 0.999)-ish (Griffin appendix)
        "lam": jax.random.uniform(ks[5], (d_rnn,), dtype, 2.0, 6.0),
        "w_out": jax.random.normal(ks[6], (d_rnn, d_model), dtype) * s,
    }


def _rglru_coeffs(params, u, dtype):
    """u: (..., d_rnn) post-conv inputs -> (a, b) with h = a*h_prev + b."""
    r = jax.nn.sigmoid((u @ params["w_a"].astype(dtype)
                        + params["b_a"].astype(dtype)).astype(F32))
    i = jax.nn.sigmoid((u @ params["w_x"].astype(dtype)
                        + params["b_x"].astype(dtype)).astype(F32))
    log_a = -RGLRU_C * jax.nn.softplus(params["lam"].astype(F32)) * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-9)) * (
        i * u.astype(F32))
    return a, b


def _causal_conv(params, x, conv_state=None):
    """Depthwise causal conv, width 4.  x: (B, T, d)."""
    w = params["conv_w"].astype(x.dtype)  # (W, d)
    if conv_state is None:
        pad = jnp.zeros((x.shape[0], CONV_WIDTH - 1, x.shape[2]), x.dtype)
    else:
        pad = conv_state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(
        xp[:, j: j + x.shape[1], :] * w[CONV_WIDTH - 1 - j]
        for j in range(CONV_WIDTH)
    ) + params["conv_b"].astype(x.dtype)
    new_state = xp[:, -(CONV_WIDTH - 1):, :]
    return out, new_state


def rglru_apply(params, x, *, dtype, h0=None, conv_state=None):
    """Full-sequence RG-LRU block.  x: (B, T, d_model)."""
    gate = jax.nn.gelu((x @ params["w_gate_in"].astype(dtype)).astype(F32),
                       approximate=True)
    u = x @ params["w_in"].astype(dtype)
    u, conv_state = _causal_conv(params, u, conv_state)
    a, b = _rglru_coeffs(params, u, dtype)
    if h0 is not None:
        # fold the carried state into the first step
        b = b.at[:, 0].add(a[:, 0] * h0)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    y = (h * gate).astype(dtype) @ params["w_out"].astype(dtype)
    return y.astype(x.dtype), {"h": h[:, -1], "conv": conv_state}


def rglru_step(params, x_t, state, *, dtype):
    """Single decode step.  x_t: (B, d_model)."""
    gate = jax.nn.gelu((x_t @ params["w_gate_in"].astype(dtype)).astype(F32),
                       approximate=True)
    u = x_t @ params["w_in"].astype(dtype)
    u, conv_state = _causal_conv(params, u[:, None, :], state["conv"])
    u = u[:, 0]
    a, b = _rglru_coeffs(params, u, dtype)
    h = a * state["h"] + b
    y = (h * gate).astype(dtype) @ params["w_out"].astype(dtype)
    return y.astype(x_t.dtype), {"h": h, "conv": conv_state}


def rglru_init_state(batch: int, d_rnn: int):
    return {"h": jnp.zeros((batch, d_rnn), F32),
            "conv": jnp.zeros((batch, CONV_WIDTH - 1, d_rnn), F32)}


# --------------------------------------------------------------------------
# mLSTM (xLSTM) — matrix memory with exponential gating
# --------------------------------------------------------------------------

def mlstm_init(rng, d_model: int, n_heads: int, dtype=jnp.float32):
    ks = jax.random.split(rng, 7)
    s = d_model ** -0.5
    d_in = 2 * d_model  # up-projection factor 2 (xLSTM block)
    return {
        "w_up": jax.random.normal(ks[0], (d_model, 2 * d_in), dtype) * s,
        "w_q": jax.random.normal(ks[1], (d_in, d_in), dtype) * s,
        "w_k": jax.random.normal(ks[2], (d_in, d_in), dtype) * s,
        "w_v": jax.random.normal(ks[3], (d_in, d_in), dtype) * s,
        "w_if": jax.random.normal(ks[4], (d_in, 2 * n_heads), dtype) * s,
        "b_if": jnp.zeros((2 * n_heads,), dtype),
        "w_down": jax.random.normal(ks[5], (d_in, d_model), dtype) * s,
    }


def _mlstm_qkvg(params, x, n_heads: int, dtype):
    up = x @ params["w_up"].astype(dtype)
    u, z = jnp.split(up, 2, axis=-1)          # value path, gate path
    B, T, d_in = u.shape
    dh = d_in // n_heads

    def heads(w):
        return (u @ w.astype(dtype)).reshape(B, T, n_heads, dh).transpose(
            0, 2, 1, 3)

    q = heads(params["w_q"]) * (dh ** -0.5)
    k = heads(params["w_k"]) * (dh ** -0.5)
    v = heads(params["w_v"])
    gates = (u @ params["w_if"].astype(dtype)
             + params["b_if"].astype(dtype)).astype(F32)
    i_pre, f_pre = jnp.split(gates, 2, axis=-1)  # (B, T, H)
    i_pre = i_pre.transpose(0, 2, 1)             # (B, H, T)
    f_pre = jax.nn.log_sigmoid(f_pre.transpose(0, 2, 1))
    return q, k, v, i_pre, f_pre, z


def mlstm_seq_ref(params, x, n_heads: int, *, dtype):
    """Sequential reference (oracle for the chunkwise path)."""
    q, k, v, i_pre, f_pre, z = _mlstm_qkvg(params, x, n_heads, dtype)
    B, H, T, dh = q.shape
    C0 = jnp.zeros((B, H, dh, dh), F32)
    n0 = jnp.zeros((B, H, dh), F32)
    m0 = jnp.full((B, H), -1e30, F32)

    def step(carry, t):
        C, n, m = carry
        it, ft = i_pre[:, :, t], f_pre[:, :, t]
        m_new = jnp.maximum(ft + m, it)
        i_s = jnp.exp(it - m_new)
        f_s = jnp.exp(ft + m - m_new)
        kt = k[:, :, t].astype(F32)
        vt = v[:, :, t].astype(F32)
        qt = q[:, :, t].astype(F32)
        C = f_s[..., None, None] * C + i_s[..., None, None] * (
            vt[..., :, None] * kt[..., None, :])
        n = f_s[..., None] * n + i_s[..., None] * kt
        num = jnp.einsum("bhde,bhe->bhd", C, qt)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", n, qt)),
                          jnp.exp(-m_new))
        h = num / den[..., None]
        return (C, n, m_new), h

    (_, _, _), hs = jax.lax.scan(step, (C0, n0, m0), jnp.arange(T))
    hs = hs.transpose(1, 2, 0, 3).reshape(B, H, T, dh)  # (B,H,T,dh)
    return _mlstm_out(params, hs, z, x, dtype)


def _mlstm_out(params, hs, z, x, dtype):
    B, H, T, dh = hs.shape
    h = hs.transpose(0, 2, 1, 3).reshape(B, T, H * dh).astype(dtype)
    y = (h * jax.nn.silu(z.astype(F32)).astype(dtype)) @ params[
        "w_down"].astype(dtype)
    return y.astype(x.dtype)


def mlstm_apply(params, x, n_heads: int, *, dtype, chunk: int = 128,
                state=None):
    """Chunkwise-parallel mLSTM.  x: (B, T, d_model)."""
    q, k, v, i_pre, f_pre, z = _mlstm_qkvg(params, x, n_heads, dtype)
    B, H, T, dh = q.shape
    C = min(chunk, T)
    if T % C:
        raise ValueError(f"T={T} must be a multiple of chunk={C}")
    nC = T // C

    def resh(a):  # (B,H,T,...) -> (nC, B, H, C, ...)
        return a.reshape(B, H, nC, C, *a.shape[3:]).transpose(
            2, 0, 1, 3, *range(4, a.ndim + 1))

    qc, kc, vc = resh(q.astype(F32)), resh(k.astype(F32)), resh(v.astype(F32))
    ic = i_pre.reshape(B, H, nC, C).transpose(2, 0, 1, 3)   # (nC,B,H,C)
    fc = f_pre.reshape(B, H, nC, C).transpose(2, 0, 1, 3)

    if state is None:
        C_st = jnp.zeros((B, H, dh, dh), F32)
        n_st = jnp.zeros((B, H, dh), F32)
        m_st = jnp.full((B, H), -1e30, F32)
    else:
        C_st, n_st, m_st = state["C"], state["n"], state["m"]

    def chunk_step(carry, inp):
        # Derivation: unrolling the stabilized recurrence gives
        #   C_t = sum_{s<=t} exp(F_t - F_s + i_s - m_t) v_s k_s^T
        # with F = inclusive cumsum of log-forget.  Per row t the varying
        # part over s is g_s = i_s - F_s, so the row stabilizer is
        #   m_t = F_t + max(m_prev, max_{s<=t} g_s).
        C_st, n_st, m_st = carry
        qb, kb, vb, ib, fb = inp   # (B,H,C,dh) / (B,H,C)
        Fcum = jnp.cumsum(fb, axis=-1)                  # (B,H,C) inclusive
        g = ib - Fcum                                   # g_s = i_s - F_s
        g_run = jax.lax.associative_scan(jnp.maximum, g, axis=-1)
        mx_row = jnp.maximum(m_st[..., None], g_run)    # (B,H,C)
        m_row = Fcum + mx_row
        # state contribution, scaled exp(m_st + F_t - m_row) = exp(m_st-mx)
        st_scale = jnp.exp(m_st[..., None] - mx_row)    # (B,H,C)
        num_state = jnp.einsum("bhde,bhce->bhcd", C_st, qb) \
            * st_scale[..., None]
        den_state = jnp.einsum("bhd,bhcd->bhc", n_st, qb) * st_scale
        # intra-chunk: D[t,s] = F_t + g_s - m_row[t]  (s <= t)
        D = (Fcum[..., :, None] + g[..., None, :] - m_row[..., :, None])
        tri = jnp.tril(jnp.ones((C, C), bool))
        D = jnp.where(tri, D, -1e30)
        W = jnp.exp(D)                                  # (B,H,C,C)
        scores = jnp.einsum("bhcd,bhsd->bhcs", qb, kb) * W
        num_intra = jnp.einsum("bhcs,bhsd->bhcd", scores, vb)
        den_intra = scores.sum(axis=-1)
        num = num_state + num_intra
        den = den_state + den_intra
        h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_row))[..., None]

        # chunk-end state update, stabilized at m_new = F_end + mx_end
        F_end = Fcum[..., -1]
        mx_end = jnp.maximum(m_st, g_run[..., -1])
        m_new = F_end + mx_end
        s_state = jnp.exp(m_st - mx_end)
        s_in = jnp.exp(g - mx_end[..., None])           # (B,H,C)
        C_st = s_state[..., None, None] * C_st + jnp.einsum(
            "bhsd,bhse,bhs->bhde", vb, kb, s_in)
        n_st = s_state[..., None] * n_st + jnp.einsum(
            "bhsd,bhs->bhd", kb, s_in)
        return (C_st, n_st, m_new), h

    (C_st, n_st, m_st), hs = jax.lax.scan(
        chunk_step, (C_st, n_st, m_st), (qc, kc, vc, ic, fc))
    hs = hs.transpose(1, 2, 0, 3, 4).reshape(B, H, T, dh)
    y = _mlstm_out(params, hs, z, x, dtype)
    return y, {"C": C_st, "n": n_st, "m": m_st}


def mlstm_step(params, x_t, state, n_heads: int, *, dtype):
    """Single decode step.  x_t: (B, d_model)."""
    y, new_state = mlstm_apply(params, x_t[:, None, :], n_heads, dtype=dtype,
                               chunk=1, state=state)
    return y[:, 0], new_state


def mlstm_init_state(batch: int, n_heads: int, d_model: int):
    dh = (2 * d_model) // n_heads
    return {"C": jnp.zeros((batch, n_heads, dh, dh), F32),
            "n": jnp.zeros((batch, n_heads, dh), F32),
            "m": jnp.full((batch, n_heads), -1e30, F32)}


# --------------------------------------------------------------------------
# sLSTM (xLSTM) — scalar memory, state-dependent gating (sequential)
# --------------------------------------------------------------------------

def slstm_init(rng, d_model: int, n_heads: int, dtype=jnp.float32):
    ks = jax.random.split(rng, 3)
    s = d_model ** -0.5
    dh = d_model // n_heads
    return {
        "w": jax.random.normal(ks[0], (d_model, 4 * d_model), dtype) * s,
        "r": jax.random.normal(ks[1], (n_heads, dh, 4 * dh), dtype) * s,
        "b": jnp.zeros((4 * d_model,), dtype),
        "w_out": jax.random.normal(ks[2], (d_model, d_model), dtype) * s,
    }


def _slstm_cell(params, wx_t, state, n_heads: int):
    """wx_t: (B, 4*d) precomputed input proj; state dict of (B,H,dh)."""
    h, c, n, m = state["h"], state["c"], state["n"], state["m"]
    B = wx_t.shape[0]
    H = n_heads
    dh = h.shape[-1]
    rec = jnp.einsum("bhd,hde->bhe", h, params["r"].astype(F32))  # (B,H,4dh)
    pre = wx_t.reshape(B, H, 4 * dh).astype(F32) + rec
    i_pre, f_pre, z_pre, o_pre = jnp.split(pre, 4, axis=-1)
    f_log = jax.nn.log_sigmoid(f_pre)
    m_new = jnp.maximum(f_log + m, i_pre)
    i_s = jnp.exp(i_pre - m_new)
    f_s = jnp.exp(f_log + m - m_new)
    c_new = f_s * c + i_s * jnp.tanh(z_pre)
    n_new = f_s * n + i_s
    h_new = jax.nn.sigmoid(o_pre) * c_new / jnp.maximum(n_new, 1.0)
    return {"h": h_new, "c": c_new, "n": n_new, "m": m_new}


def slstm_apply(params, x, n_heads: int, *, dtype, state=None):
    """x: (B, T, d_model) -> (y, state).  Sequential scan over T."""
    B, T, d = x.shape
    dh = d // n_heads
    if state is None:
        state = slstm_init_state(B, n_heads, d)
    wx = x @ params["w"].astype(dtype) + params["b"].astype(dtype)

    def step(st, wx_t):
        st = _slstm_cell(params, wx_t, st, n_heads)
        return st, st["h"]

    state, hs = jax.lax.scan(step, state, wx.transpose(1, 0, 2))
    # hs: (T, B, H, dh) -> (B, T, d)
    y = hs.transpose(1, 0, 2, 3).reshape(B, T, d).astype(dtype) @ params[
        "w_out"].astype(dtype)
    return y.astype(x.dtype), state


def slstm_step(params, x_t, state, n_heads: int, *, dtype):
    wx = x_t @ params["w"].astype(dtype) + params["b"].astype(dtype)
    state = _slstm_cell(params, wx, state, n_heads)
    B, d = x_t.shape
    y = state["h"].reshape(B, d).astype(dtype) @ params["w_out"].astype(dtype)
    return y.astype(x_t.dtype), state


def slstm_init_state(batch: int, n_heads: int, d_model: int):
    dh = d_model // n_heads
    z = lambda: jnp.zeros((batch, n_heads, dh), F32)
    return {"h": z(), "c": z(), "n": z(),
            "m": jnp.full((batch, n_heads, dh), -1e30, F32)}
