"""Memory-efficient attention with a flash-style custom VJP.

The autodiff backward of the naive online-softmax attention saves the
(B, H, Tq, Tk) probability tensors for every (layer, q-block, kv-block) —
the dry-run's byte histogram shows those f32 stacks dominating the memory
roofline term (EXPERIMENTS.md §Perf, granite train_4k iteration 1).

This implementation:
  * forward: chunked online softmax (identical math/outputs to
    ``layers.blockwise_attention``) that additionally returns the row
    statistics (m, l);
  * backward: flash-style recompute — s/p are rebuilt per (q-block,
    kv-block) from q, k, v and never stored; residuals are only
    (q, k, v, o, m, l);
  * probabilities are materialized in the value dtype (bf16 on the full
    configs) for the dv/o dots, with f32 accumulation.

Handles GQA grouping, causality and gemma-style tanh softcap (whose
derivative is recomputed from the raw scores in the backward).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

F32 = jnp.float32


def _softcap(x, cap: float):
    if cap and cap > 0:
        return jnp.tanh(x / cap) * cap
    return x


def _gqa_scores(q, k):
    B, Hq, Tq, D = q.shape
    Hkv = k.shape[1]
    g = Hq // Hkv
    qg = q.reshape(B, Hkv, g, Tq, D)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, k,
                   preferred_element_type=F32)
    return s.reshape(B, Hq, Tq, k.shape[2])


def _gqa_combine(p, v):
    B, Hq, Tq, Tk = p.shape
    Hkv = v.shape[1]
    g = Hq // Hkv
    pg = p.reshape(B, Hkv, g, Tq, Tk)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", pg, v,
                   preferred_element_type=F32)
    return o.reshape(B, Hq, Tq, v.shape[3])


def _pad_to(x, n, axis):
    if x.shape[axis] == n:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, n - x.shape[axis])
    return jnp.pad(x, pad)


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def flash_attention(q, k, v, causal: bool = True,
                    attn_softcap: float = 0.0, q_chunk: int = 512,
                    kv_chunk: int = 1024, q_base=0.0):
    """q: (B, Hq, Tq, D); k/v: (B, Hkv, Tk, D) -> (B, Hq, Tq, D).

    ``q_base``: global position of q[:, :, 0] for causal masking when the
    query sequence is a shard of a longer one (flash_attention_sharded).
    Passed as an f32 scalar so it threads through the custom VJP as a
    regular (zero-cotangent) argument.

    Full-sequence causal (or full bidirectional) attention; for cache
    decode with kv_len masks use ``layers.blockwise_attention`` (forward-
    only, no VJP needed)."""
    o, _, _ = _flash_fwd_impl(q, k, v, causal, attn_softcap, q_chunk,
                              kv_chunk, q_base)
    return o


def _flash_fwd_impl(q, k, v, causal, attn_softcap, q_chunk, kv_chunk,
                    q_base):
    B, Hq, Tq, D = q.shape
    Tk = k.shape[2]
    C = min(q_chunk, Tq)
    K = min(kv_chunk, Tk)
    n_q, n_kv = -(-Tq // C), -(-Tk // K)
    base = jnp.asarray(q_base).astype(jnp.int32)
    qp = _pad_to(q * (D ** -0.5), n_q * C, 2)
    kp = _pad_to(k, n_kv * K, 2)
    vp = _pad_to(v, n_kv * K, 2)

    def q_block(qi):
        q_blk = jax.lax.dynamic_slice_in_dim(qp, qi * C, C, 2)
        q_pos = base + qi * C + jnp.arange(C)

        def kv_step(carry, ki):
            acc, m, denom = carry
            k_blk = jax.lax.dynamic_slice_in_dim(kp, ki * K, K, 2)
            v_blk = jax.lax.dynamic_slice_in_dim(vp, ki * K, K, 2)
            kv_pos = ki * K + jnp.arange(K)
            s = _softcap(_gqa_scores(q_blk, k_blk), attn_softcap)
            mask = kv_pos[None, :] < Tk
            if causal:
                mask = mask & (kv_pos[None, :] <= q_pos[:, None])
            s = jnp.where(mask[None, None], s, -1e30)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            denom = denom * corr + p.sum(axis=-1)
            acc = acc * corr[..., None] + _gqa_combine(
                p.astype(v.dtype), v_blk)
            return (acc, m_new, denom), None

        acc0 = jnp.zeros((B, Hq, C, D), F32)
        m0 = jnp.full((B, Hq, C), -1e30, F32)
        d0 = jnp.zeros((B, Hq, C), F32)
        (acc, m, denom), _ = jax.lax.scan(kv_step, (acc0, m0, d0),
                                          jnp.arange(n_kv))
        o = acc / jnp.maximum(denom[..., None], 1e-30)
        return o, m, denom

    if n_q == 1:
        o, m, l = q_block(0)
    else:
        o, m, l = jax.lax.map(q_block, jnp.arange(n_q))
        o = o.transpose(1, 2, 0, 3, 4).reshape(B, Hq, n_q * C, D)
        m = m.transpose(1, 2, 0, 3).reshape(B, Hq, n_q * C)
        l = l.transpose(1, 2, 0, 3).reshape(B, Hq, n_q * C)
    return o[:, :, :Tq].astype(v.dtype), m[:, :, :Tq], l[:, :, :Tq]


def _flash_fwd(q, k, v, causal, attn_softcap, q_chunk, kv_chunk, q_base):
    o, m, l = _flash_fwd_impl(q, k, v, causal, attn_softcap, q_chunk,
                              kv_chunk, q_base)
    return o, (q, k, v, o, m, l, q_base)


def _flash_bwd(causal, attn_softcap, q_chunk, kv_chunk, res, do):
    q, k, v, o, m, l, q_base = res
    base = jnp.asarray(q_base).astype(jnp.int32)
    B, Hq, Tq, D = q.shape
    Hkv, Tk = k.shape[1], k.shape[2]
    g = Hq // Hkv
    C = min(q_chunk, Tq)
    K = min(kv_chunk, Tk)
    n_q, n_kv = -(-Tq // C), -(-Tk // K)
    scale = D ** -0.5
    qp = _pad_to(q * scale, n_q * C, 2)    # everything below sees scaled q
    kp = _pad_to(k, n_kv * K, 2)
    vp = _pad_to(v, n_kv * K, 2)
    do_p = _pad_to(do.astype(F32), n_q * C, 2)
    op = _pad_to(o.astype(F32), n_q * C, 2)
    # D_i = sum_d do_i * o_i  (flash-2 delta), padded rows are zero
    delta = (do_p * op).sum(-1)                       # (B, Hq, Tq_p)
    m_p = _pad_to(m, n_q * C, 2)
    l_p = jnp.maximum(_pad_to(l, n_q * C, 2), 1e-30)  # pad rows stay finite

    def q_block(carry, qi):
        dk_acc, dv_acc = carry                        # (B,Hkv,Tk_p,D) f32
        q_blk = jax.lax.dynamic_slice_in_dim(qp, qi * C, C, 2)
        do_blk = jax.lax.dynamic_slice_in_dim(do_p, qi * C, C, 2)
        m_blk = jax.lax.dynamic_slice_in_dim(m_p, qi * C, C, 2)
        l_blk = jax.lax.dynamic_slice_in_dim(l_p, qi * C, C, 2)
        dl_blk = jax.lax.dynamic_slice_in_dim(delta, qi * C, C, 2)
        q_pos = base + qi * C + jnp.arange(C)

        def kv_step(carry, ki):
            dq_blk, dk_acc, dv_acc = carry
            k_blk = jax.lax.dynamic_slice_in_dim(kp, ki * K, K, 2)
            v_blk = jax.lax.dynamic_slice_in_dim(vp, ki * K, K, 2)
            kv_pos = ki * K + jnp.arange(K)
            s_raw = _gqa_scores(q_blk, k_blk)          # f32, pre-softcap
            s = _softcap(s_raw, attn_softcap)
            mask = kv_pos[None, :] < Tk
            if causal:
                mask = mask & (kv_pos[None, :] <= q_pos[:, None])
            s = jnp.where(mask[None, None], s, -1e30)
            # normalized probabilities, recomputed (never stored)
            p = jnp.exp(s - m_blk[..., None]) / l_blk[..., None]
            p16 = p.astype(v.dtype)
            # dv_k += p^T do   (sum over q rows and the GQA group)
            dv_k = jnp.einsum(
                "bhgqk,bhgqd->bhkd",
                p16.reshape(B, Hkv, g, C, K),
                do_blk.astype(v.dtype).reshape(B, Hkv, g, C, D),
                preferred_element_type=F32)
            # dp = do @ v^T
            dp = jnp.einsum(
                "bhgqd,bhkd->bhgqk",
                do_blk.astype(v.dtype).reshape(B, Hkv, g, C, D), v_blk,
                preferred_element_type=F32).reshape(B, Hq, C, K)
            ds = p * (dp - dl_blk[..., None])          # f32
            if attn_softcap:
                t = jnp.tanh(s_raw / attn_softcap)
                ds = ds * (1.0 - jnp.square(t))
            ds = jnp.where(mask[None, None], ds, 0.0)
            ds16 = ds.astype(v.dtype)
            dq_blk = dq_blk + jnp.einsum(
                "bhgqk,bhkd->bhgqd",
                ds16.reshape(B, Hkv, g, C, K), k_blk,
                preferred_element_type=F32).reshape(B, Hq, C, D)
            dk_k = jnp.einsum(
                "bhgqk,bhgqd->bhkd",
                ds16.reshape(B, Hkv, g, C, K),
                q_blk.astype(v.dtype).reshape(B, Hkv, g, C, D),
                preferred_element_type=F32)
            dk_acc = jax.lax.dynamic_update_slice_in_dim(
                dk_acc,
                jax.lax.dynamic_slice_in_dim(dk_acc, ki * K, K, 2) + dk_k,
                ki * K, axis=2)
            dv_acc = jax.lax.dynamic_update_slice_in_dim(
                dv_acc,
                jax.lax.dynamic_slice_in_dim(dv_acc, ki * K, K, 2) + dv_k,
                ki * K, axis=2)
            return (dq_blk, dk_acc, dv_acc), None

        dq0 = jnp.zeros((B, Hq, C, D), F32)
        (dq_blk, dk_acc, dv_acc), _ = jax.lax.scan(
            kv_step, (dq0, dk_acc, dv_acc), jnp.arange(n_kv))
        return (dk_acc, dv_acc), dq_blk

    dk0 = jnp.zeros((B, Hkv, n_kv * K, D), F32)
    dv0 = jnp.zeros_like(dk0)
    (dk_f, dv_f), dq_blocks = jax.lax.scan(q_block, (dk0, dv0),
                                           jnp.arange(n_q))
    dq = dq_blocks.transpose(1, 2, 0, 3, 4).reshape(B, Hq, n_q * C, D)
    return ((dq[:, :, :Tq] * scale).astype(q.dtype),
            dk_f[:, :, :Tk].astype(k.dtype),
            dv_f[:, :, :Tk].astype(v.dtype),
            jnp.zeros_like(jnp.asarray(q_base, F32)))


flash_attention.defvjp(_flash_fwd, _flash_bwd)


def flash_attention_sharded(q, k, v, mesh, *, causal: bool = True,
                            attn_softcap: float = 0.0, q_chunk: int = 512,
                            kv_chunk: int = 1024):
    """flash_attention under shard_map: batch -> data axes, the *query
    sequence* -> model (always divisible on the assigned shapes, and
    GQA-group-agnostic — head sharding breaks kv-group alignment for most
    archs).  k/v are replicated inside the model group; each shard masks
    with its global q positions via ``q_base``.

    Why shard_map: plain GSPMD propagation through the flash custom-VJP
    loops gives up and fully replicates dq/dk (25.8 GB all-gathers on the
    granite train cell — EXPERIMENTS.md §Perf cell-1 iteration 2);
    shard_map pins the layout so the backward stays local, and the dk/dv
    partial-sum over the model group comes from the shard_map transpose of
    the replicated k/v inputs.
    """
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    ba = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    ba = ba if len(ba) != 1 else ba[0]
    B, Hq, Tq, _ = q.shape
    ba_size = 1
    for a in (ba if isinstance(ba, tuple) else (ba,)):
        ba_size *= mesh.shape[a]
    b_ax = ba if B % ba_size == 0 else None
    n_model = mesh.shape["model"]
    t_ax = "model" if (Tq % n_model == 0 and Tq > 1) else None
    if t_ax is None:
        return flash_attention(q, k, v, causal, attn_softcap, q_chunk,
                               kv_chunk)
    t_loc = Tq // n_model

    def body(q_l, k_l, v_l):
        base = (jax.lax.axis_index("model") * t_loc).astype(F32)
        return flash_attention(q_l, k_l, v_l, causal, attn_softcap,
                               min(q_chunk, t_loc), kv_chunk, base)

    return shard_map(
        body, mesh=mesh,
        in_specs=(P(b_ax, None, t_ax, None), P(b_ax, None, None, None),
                  P(b_ax, None, None, None)),
        out_specs=P(b_ax, None, t_ax, None),
        check_rep=False,
    )(q, k, v)
