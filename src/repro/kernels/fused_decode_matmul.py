"""Pallas TPU kernel: fused ECF8 decode + GEMM  (y = x @ decode(W)).

The paper's throughput story is that compressed weights stream from memory
and are decompressed *just before* the GEMM.  On TPU we go one step further
and fuse the two: the ECF8-TPU chunk geometry (128 lanes x ``sym_per_lane``
slots) is chosen so **one chunk decodes to exactly one (bk=S, bn=128) weight
tile**, which is fed straight to the MXU from VMEM — compressed bytes are
the only weight traffic that ever touches HBM.

Weight layout: W (K, N) is tiled into (TK, TN) tiles of (S, 128); tile
(tk, tn) is encoded as chunk index ``tk * TN + tn`` with element (k, n) at
slot ``s = k``, lane ``l = n``.  The kernel grid is (TN, TK) with TK
innermost: the fp32 out block (M, 128) for column tn accumulates over tk.

This kernel targets the *decode/serving* GEMM shape (M = batch <= 512, one
M block — the paper's regime: weight-streaming-bound batched token decode).
For prefill-sized M, decode standalone (``ecf8_decode``) + regular GEMM is
the right structure; see DESIGN.md.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import fp8 as fp8mod
from repro.core import tpu_format
from repro.core.tpu_format import LANES, MAX_CODE_LEN


@dataclass
class TiledECF8Weight:
    """(K, N) fp8 weight in fused-GEMM tile order (host-side arrays)."""

    payload: np.ndarray   # (TK, TN, stride, LANES) uint8
    signmant: np.ndarray  # (TK, TN, S * LANES // 2) uint8
    lj_limit: np.ndarray  # (8,) int32
    first_lj: np.ndarray
    offset: np.ndarray
    perm: np.ndarray      # (16,) int32
    k: int
    n: int
    sym_per_lane: int

    @property
    def nbytes(self) -> int:
        return (self.payload.nbytes + self.signmant.nbytes + 4 * (8 * 3 + 16))


def encode_tiled(w_bits: np.ndarray,
                 sym_per_lane: int = 256) -> TiledECF8Weight:
    """Pack a (K, N) fp8 weight (uint8 bit view) into fused-GEMM tile order."""
    K, N = w_bits.shape
    S = sym_per_lane
    assert K % S == 0 and N % LANES == 0, (K, N, S, LANES)
    TK, TN = K // S, N // LANES
    # tile (tk, tn), element (k=s, n=l)  ->  chunk tk*TN+tn, slot s, lane l
    perm_elems = (
        w_bits.reshape(TK, S, TN, LANES).transpose(0, 2, 1, 3).reshape(-1)
    )
    c = tpu_format.encode(perm_elems, sym_per_lane=S)
    C, stride, _ = c.payload.shape
    assert C == TK * TN
    total_sm = C * S * LANES // 2
    sm = np.zeros(total_sm, dtype=np.uint8)
    sm[: c.signmant.shape[0]] = c.signmant
    return TiledECF8Weight(
        payload=np.asarray(c.payload).reshape(TK, TN, stride, LANES),
        signmant=sm.reshape(TK, TN, S * LANES // 2),
        lj_limit=c.lj_limit, first_lj=c.first_lj, offset=c.offset,
        perm=c.perm, k=K, n=N, sym_per_lane=S,
    )


def _fused_kernel(limit_ref, first_ref, offset_ref, perm_ref, x_ref,
                  payload_ref, signmant_ref, out_ref, w_scratch, *,
                  sym_per_lane: int, stride: int, n_tk: int):
    S = sym_per_lane
    tk = pl.program_id(1)
    payload = payload_ref[0, 0].astype(jnp.uint32)     # (stride, L)

    win = ((payload[0:1, :] << 24) | (payload[1:2, :] << 16)
           | (payload[2:3, :] << 8) | payload[3:4, :])
    byteptr = jnp.full((1, LANES), 4, dtype=jnp.int32)
    bits_valid = jnp.full((1, LANES), 32, dtype=jnp.int32)

    smp = signmant_ref[0, 0].reshape(S, LANES // 2)
    sm_hi = (smp >> 4) & jnp.uint8(0x0F)
    sm_lo = smp & jnp.uint8(0x0F)
    sm = jnp.stack([sm_hi, sm_lo], axis=-1).reshape(S, LANES).astype(jnp.int32)

    row_iota = jax.lax.broadcasted_iota(jnp.int32, (stride, LANES), 0)

    def round_fn(s, carry):
        win, byteptr, bits_valid = carry
        peek = (win >> 24).astype(jnp.int32)
        length = jnp.zeros((1, LANES), jnp.int32)
        sym_idx = jnp.zeros((1, LANES), jnp.int32)
        found = jnp.zeros((1, LANES), jnp.bool_)
        for l in range(1, MAX_CODE_LEN + 1):
            cond = jnp.logical_and(peek < limit_ref[0, l - 1],
                                   jnp.logical_not(found))
            idx_l = offset_ref[0, l - 1] + (
                (peek - first_ref[0, l - 1]) >> (8 - l)
            )
            length = jnp.where(cond, l, length)
            sym_idx = jnp.where(cond, idx_l, sym_idx)
            found = jnp.logical_or(found, cond)
        sym = jnp.zeros((1, LANES), jnp.int32)
        for k in range(16):
            sym = jnp.where(sym_idx == k, perm_ref[0, k], sym)

        sm_s = jax.lax.dynamic_slice_in_dim(sm, s, 1, axis=0)
        byte = ((sm_s & 8) << 4) | (sym << 3) | (sm_s & 7)
        w_row = byte.astype(jnp.uint8).view(fp8mod.FP8_DTYPE).astype(
            jnp.bfloat16)
        pl.store(w_scratch, (pl.dslice(s, 1), slice(None)), w_row)

        win = win << length.astype(jnp.uint32)
        bits_valid = bits_valid - length
        need = bits_valid <= 24
        safe_ptr = jnp.minimum(byteptr, stride - 1)
        nb = jnp.sum(jnp.where(row_iota == safe_ptr, payload, jnp.uint32(0)),
                     axis=0, keepdims=True)
        win = jnp.where(need,
                        win | (nb << (24 - bits_valid).astype(jnp.uint32)),
                        win)
        byteptr = byteptr + need.astype(jnp.int32)
        bits_valid = bits_valid + 8 * need.astype(jnp.int32)
        return win, byteptr, bits_valid

    jax.lax.fori_loop(0, S, round_fn, (win, byteptr, bits_valid))

    @pl.when(tk == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += jnp.dot(
        x_ref[...].astype(jnp.bfloat16), w_scratch[...],
        preferred_element_type=jnp.float32,
    )


@functools.partial(jax.jit,
                   static_argnames=("sym_per_lane", "k", "n", "interpret",
                                    "out_dtype"))
def _matmul_impl(x, payload, signmant, lj_limit, first_lj, offset, perm, *,
                 sym_per_lane: int, k: int, n: int, interpret: bool,
                 out_dtype):
    M = x.shape[0]
    S = sym_per_lane
    TK, TN, stride, _ = payload.shape
    kernel = functools.partial(_fused_kernel, sym_per_lane=S, stride=stride,
                               n_tk=TK)
    out = pl.pallas_call(
        kernel,
        grid=(TN, TK),
        in_specs=[
            pl.BlockSpec((1, 8), lambda tn, tk: (0, 0)),
            pl.BlockSpec((1, 8), lambda tn, tk: (0, 0)),
            pl.BlockSpec((1, 8), lambda tn, tk: (0, 0)),
            pl.BlockSpec((1, 16), lambda tn, tk: (0, 0)),
            pl.BlockSpec((M, S), lambda tn, tk: (0, tk)),          # x
            pl.BlockSpec((1, 1, stride, LANES),
                         lambda tn, tk: (tk, tn, 0, 0)),           # payload
            pl.BlockSpec((1, 1, S * LANES // 2),
                         lambda tn, tk: (tk, tn, 0)),              # signmant
        ],
        out_specs=pl.BlockSpec((M, LANES), lambda tn, tk: (0, tn)),
        out_shape=jax.ShapeDtypeStruct((M, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((S, LANES), jnp.bfloat16)],
        interpret=interpret,
    )(
        lj_limit.reshape(1, 8).astype(jnp.int32),
        first_lj.reshape(1, 8).astype(jnp.int32),
        offset.reshape(1, 8).astype(jnp.int32),
        perm.reshape(1, 16).astype(jnp.int32),
        x, payload, signmant,
    )
    return out.astype(out_dtype)


def matmul_pallas(x, w: TiledECF8Weight, *, out_dtype=jnp.float32,
                  interpret: bool = True):
    """y = x @ decode(W); x: (M, K) with M <= 512 (decode-GEMM regime)."""
    assert x.shape[1] == w.k, (x.shape, w.k)
    return _matmul_impl(
        jnp.asarray(x), jnp.asarray(w.payload), jnp.asarray(w.signmant),
        jnp.asarray(w.lj_limit), jnp.asarray(w.first_lj),
        jnp.asarray(w.offset), jnp.asarray(w.perm),
        sym_per_lane=w.sym_per_lane, k=w.k, n=w.n, interpret=interpret,
        out_dtype=out_dtype,
    )
