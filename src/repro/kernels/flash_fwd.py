"""Pallas TPU kernel: flash-attention forward (VMEM-resident s/p tiles).

After §Perf cell-1 iterations 1-5, the memory roofline term of the train
cells is dominated by the f32 (B, H, q_chunk, kv_chunk) score/probability
tiles that XLA materializes in HBM between fusions (the CPU-lowered HLO
cannot keep them in registers across the online-softmax steps).  On the
TPU target this traffic does not exist: this kernel computes the whole
online softmax for one (batch, head, q-block) grid cell with s/p living in
VMEM/VREGs, reading q/k/v tiles from HBM exactly once and writing o once.

Grid: (B * Hq, n_q_blocks).  Block shapes:
  q tile   (1, bq, D)    VMEM
  k/v      (1, Tk, D)    VMEM (whole per-head K/V — Tk*D*2B <= ~2 MB for
                          the assigned shapes at per-shard Tk)
  o tile   (1, bq, D)    VMEM
The kv loop runs in-kernel over Tk in bk-sized slices with VREG-resident
running max / denominator (the same math as models.flash_attention, which
is the validated jnp oracle).

GQA: the index_map routes q head h to kv head h // (Hq // Hkv).
The backward kernel follows the standard flash recompute scheme whose jnp
form is implemented and validated in ``models.flash_attention._flash_bwd``;
its Pallas port shares this kernel's tiling (DESIGN.md §Kernels).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

F32 = jnp.float32
NEG = -1e30


def _flash_fwd_kernel(q_ref, k_ref, v_ref, o_ref, *, bk: int, causal: bool,
                      softcap: float, q_base: int, scale: float):
    bq, D = q_ref.shape[1], q_ref.shape[2]
    Tk = k_ref.shape[1]
    qi = pl.program_id(1)
    q = q_ref[0].astype(F32) * scale                 # (bq, D)
    q_pos = q_base + qi * bq + jax.lax.broadcasted_iota(
        jnp.int32, (bq, 1), 0)

    def body(ki, carry):
        acc, m, l = carry
        k_blk = pl.load(k_ref, (0, pl.dslice(ki * bk, bk),
                                slice(None))).astype(F32)   # (bk, D)
        v_blk = pl.load(v_ref, (0, pl.dslice(ki * bk, bk),
                                slice(None))).astype(F32)
        s = jax.lax.dot_general(q, k_blk, (((1,), (1,)), ((), ())),
                                preferred_element_type=F32)  # (bq, bk)
        if softcap:
            s = jnp.tanh(s / softcap) * softcap
        kv_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)
        mask = jnp.broadcast_to(kv_pos < Tk, (bq, bk))
        if causal:
            mask = mask & (kv_pos <= q_pos)
        s = jnp.where(mask, s, NEG)
        m_new = jnp.maximum(m, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m - m_new)
        l = l * corr + p.sum(axis=-1, keepdims=True)
        acc = acc * corr + jax.lax.dot_general(
            p, v_blk, (((1,), (0,)), ((), ())), preferred_element_type=F32)
        return acc, m_new, l

    n_kv = Tk // bk
    acc0 = jnp.zeros((bq, D), F32)
    m0 = jnp.full((bq, 1), NEG, F32)
    l0 = jnp.zeros((bq, 1), F32)
    acc, m, l = jax.lax.fori_loop(0, n_kv, body, (acc0, m0, l0))
    o_ref[0] = (acc / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "softcap", "bq", "bk", "q_base", "interpret"))
def flash_fwd_pallas(q, k, v, *, causal: bool = True, softcap: float = 0.0,
                     bq: int = 256, bk: int = 512, q_base: int = 0,
                     interpret: bool = True):
    """q: (B, Hq, Tq, D), k/v: (B, Hkv, Tk, D) -> o (B, Hq, Tq, D).

    Tq must divide by bq and Tk by bk (the model pads its inputs)."""
    B, Hq, Tq, D = q.shape
    Hkv, Tk = k.shape[1], k.shape[2]
    g = Hq // Hkv
    bq = min(bq, Tq)
    bk = min(bk, Tk)
    assert Tq % bq == 0 and Tk % bk == 0, (Tq, bq, Tk, bk)
    qf = q.reshape(B * Hq, Tq, D)
    kf = k.reshape(B * Hkv, Tk, D)
    vf = v.reshape(B * Hkv, Tk, D)

    kernel = functools.partial(_flash_fwd_kernel, bk=bk, causal=causal,
                               softcap=softcap, q_base=q_base,
                               scale=D ** -0.5)
    out = pl.pallas_call(
        kernel,
        grid=(B * Hq, Tq // bq),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda bh, qi: (bh, qi, 0)),
            pl.BlockSpec((1, Tk, D), lambda bh, qi, g=g, Hq=Hq, Hkv=Hkv:
                         ((bh // Hq) * Hkv + (bh % Hq) // g, 0, 0)),
            pl.BlockSpec((1, Tk, D), lambda bh, qi, g=g, Hq=Hq, Hkv=Hkv:
                         ((bh // Hq) * Hkv + (bh % Hq) // g, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda bh, qi: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Hq, Tq, D), v.dtype),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, Hq, Tq, D)
