"""Jitted entry points for the Pallas kernels, with backend dispatch.

On TPU the compiled Pallas kernels run natively; elsewhere (this CPU
container, and any backend without Mosaic) the same kernel bodies execute in
``interpret=True`` mode, and large in-graph users (serve steps) fall back to
the algebraically-identical jnp implementations in ``core``/``ref``.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import fp8, tpu_format
from repro.core.tpu_format import LANES
from . import ecf8_decode as _dec
from . import fused_decode_matmul as _fused


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def decode_tpu_format(container: tpu_format.TpuECF8,
                      force_pallas: bool | None = None) -> np.ndarray:
    """Decode an ECF8-TPU container with the Pallas kernel -> fp8 bits (N,).

    ``force_pallas=None`` picks native Pallas on TPU, interpret elsewhere.
    """
    C, stride, _ = container.payload.shape
    S = container.sym_per_lane
    interpret = not _on_tpu() if force_pallas is None else not force_pallas

    # per-chunk signmant bytes (pad tail to rectangle)
    total = C * S * LANES // 2
    sm = np.zeros(total, dtype=np.uint8)
    sm[: container.signmant.shape[0]] = container.signmant
    sm = sm.reshape(C, S * LANES // 2)

    out = _dec.decode_pallas(
        jnp.asarray(container.payload), jnp.asarray(sm),
        jnp.asarray(container.lj_limit), jnp.asarray(container.first_lj),
        jnp.asarray(container.offset), jnp.asarray(container.perm),
        sym_per_lane=S, interpret=interpret,
    )
    return np.asarray(out).reshape(-1)[: container.n_elem]


def fused_decode_matmul(x, tiled, *, force_pallas: bool | None = None,
                        out_dtype=jnp.float32):
    """``x @ decode(W)`` with W in tiled ECF8-FR form (see the kernel)."""
    interpret = not _on_tpu() if force_pallas is None else not force_pallas
    return _fused.matmul_pallas(x, tiled, out_dtype=out_dtype,
                                interpret=interpret)
