"""Pallas TPU kernel: ECF8-TPU interleaved Huffman decode (DESIGN.md §3).

One grid cell decodes one chunk = 128 interleaved lane streams x
``sym_per_lane`` symbols.  The kernel is the TPU-native replacement for the
paper's CUDA Algorithm 1:

  * the 8x128 VPU holds one uint32 bit window **per lane** (a (1, 128) vreg
    row), all lanes decode one symbol per loop round in lockstep;
  * canonical max-8-bit codes are decoded by an unrolled compare/select chain
    against the per-length canonical limits (scalar reads of an 8-entry
    table) — no gathers;
  * window refill is a masked sum over the transposed (stride, 128) payload
    block: "byte j of every lane" is a contiguous VMEM row, so the refill is
    a broadcast-compare + reduce, all vector ops;
  * the sign/mantissa nibbles for the chunk are unpacked and fused into the
    final fp8 byte in-register (the paper's phase-2 "decode and assemble").

VMEM footprint per cell: payload (stride x 128 <= ~32 KB) + signmant
(chunk/2 = 16 KB) + output (chunk = 32 KB) — comfortably inside VMEM, and
the MXU-free decode leaves the matmul pipeline untouched.

Validated in interpret mode against ``core.tpu_format`` oracles (tests sweep
shapes and code distributions).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.tpu_format import LANES, MAX_CODE_LEN


def _decode_chunk_kernel(limit_ref, first_ref, offset_ref, perm_ref,
                         payload_ref, signmant_ref, out_ref, *,
                         sym_per_lane: int, stride: int):
    S = sym_per_lane
    payload = payload_ref[0].astype(jnp.uint32)       # (stride, L)

    win = ((payload[0:1, :] << 24) | (payload[1:2, :] << 16)
           | (payload[2:3, :] << 8) | payload[3:4, :])  # (1, L) uint32
    byteptr = jnp.full((1, LANES), 4, dtype=jnp.int32)
    bits_valid = jnp.full((1, LANES), 32, dtype=jnp.int32)

    # sign/mantissa nibbles, element order within chunk: (S, L)
    smp = signmant_ref[0].reshape(S, LANES // 2)      # bytes: row s
    sm_hi = (smp >> 4) & jnp.uint8(0x0F)
    sm_lo = smp & jnp.uint8(0x0F)
    sm = jnp.stack([sm_hi, sm_lo], axis=-1).reshape(S, LANES)

    row_iota = jax.lax.broadcasted_iota(jnp.int32, (stride, LANES), 0)

    def round_fn(s, carry):
        win, byteptr, bits_valid = carry
        peek = (win >> 24).astype(jnp.int32)          # (1, L) in [0, 256)

        length = jnp.zeros((1, LANES), jnp.int32)
        sym_idx = jnp.zeros((1, LANES), jnp.int32)
        found = jnp.zeros((1, LANES), jnp.bool_)
        for l in range(1, MAX_CODE_LEN + 1):          # unrolled, static
            lim = limit_ref[0, l - 1]
            fl = first_ref[0, l - 1]
            off = offset_ref[0, l - 1]
            cond = jnp.logical_and(peek < lim, jnp.logical_not(found))
            idx_l = off + ((peek - fl) >> (8 - l))
            length = jnp.where(cond, l, length)
            sym_idx = jnp.where(cond, idx_l, sym_idx)
            found = jnp.logical_or(found, cond)

        sym = jnp.zeros((1, LANES), jnp.int32)
        for k in range(16):                           # canonical perm, static
            sym = jnp.where(sym_idx == k, perm_ref[0, k], sym)

        # emit fp8 byte = sign | exponent | mantissa
        sm_s = jax.lax.dynamic_slice_in_dim(sm, s, 1, axis=0).astype(jnp.int32)
        byte = ((sm_s & 8) << 4) | (sym << 3) | (sm_s & 7)
        # all-slice index: a bare int leading index breaks interpret
        # mode's discharge rule on some jax versions
        pl.store(out_ref, (pl.dslice(0, 1), pl.dslice(s, 1), slice(None)),
                 byte.astype(jnp.uint8).reshape(1, 1, LANES))

        # shift and refill (<= 1 byte/round keeps bits_valid >= 24)
        win = win << length.astype(jnp.uint32)
        bits_valid = bits_valid - length
        need = bits_valid <= 24
        safe_ptr = jnp.minimum(byteptr, stride - 1)
        mask = row_iota == safe_ptr                    # (stride, L)
        nb = jnp.sum(jnp.where(mask, payload, jnp.uint32(0)), axis=0,
                     keepdims=True)                    # (1, L)
        win = jnp.where(need,
                        win | (nb << (24 - bits_valid).astype(jnp.uint32)),
                        win)
        byteptr = byteptr + need.astype(jnp.int32)
        bits_valid = bits_valid + 8 * need.astype(jnp.int32)
        return win, byteptr, bits_valid

    jax.lax.fori_loop(0, S, round_fn, (win, byteptr, bits_valid))


@functools.partial(jax.jit, static_argnames=("sym_per_lane", "interpret"))
def decode_pallas(payload, signmant_chunked, lj_limit, first_lj, offset,
                  perm, *, sym_per_lane: int, interpret: bool = True):
    """Decode all chunks -> fp8 bytes (C, S, LANES) uint8.

    Args:
      payload: (C, stride, LANES) uint8 uniform-layout payload.
      signmant_chunked: (C, S * LANES // 2) uint8 nibble bytes per chunk.
      lj_limit / first_lj / offset: (8,) int32 canonical decode tables.
      perm: (16,) int32 canonical symbol permutation.
    """
    C, stride, _ = payload.shape
    S = sym_per_lane
    kernel = functools.partial(_decode_chunk_kernel, sym_per_lane=S,
                               stride=stride)
    return pl.pallas_call(
        kernel,
        grid=(C,),
        in_specs=[
            pl.BlockSpec((1, 8), lambda c: (0, 0)),          # lj_limit
            pl.BlockSpec((1, 8), lambda c: (0, 0)),          # first_lj
            pl.BlockSpec((1, 8), lambda c: (0, 0)),          # offset
            pl.BlockSpec((1, 16), lambda c: (0, 0)),         # perm
            pl.BlockSpec((1, stride, LANES), lambda c: (c, 0, 0)),
            pl.BlockSpec((1, S * LANES // 2), lambda c: (c, 0)),
        ],
        out_specs=pl.BlockSpec((1, S, LANES), lambda c: (c, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((C, S, LANES), jnp.uint8),
        interpret=interpret,
    )(
        lj_limit.reshape(1, 8).astype(jnp.int32),
        first_lj.reshape(1, 8).astype(jnp.int32),
        offset.reshape(1, 8).astype(jnp.int32),
        perm.reshape(1, 16).astype(jnp.int32),
        payload,
        signmant_chunked,
    )
