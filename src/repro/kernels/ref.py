"""Pure-jnp / numpy oracles for every Pallas kernel in this package."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import fixedrate, fp8, tpu_format


def decode_tpu_ref(container: tpu_format.TpuECF8) -> np.ndarray:
    """Oracle for ``ecf8_decode`` (readable per-lane numpy loop)."""
    return tpu_format.decode_ref(container)


def decode_tpu_jnp(container: tpu_format.TpuECF8) -> jnp.ndarray:
    """Vectorized jnp reference (also the in-graph fallback path)."""
    return tpu_format.decode_jnp(container)


def decode_fixedrate_ref(container: fixedrate.FixedRateECF8) -> np.ndarray:
    """Oracle for the fixed-rate decode path."""
    return fixedrate.decode_ref(container)


def fused_decode_matmul_ref(x: np.ndarray, w_bits: np.ndarray,
                            out_dtype=jnp.float32) -> jnp.ndarray:
    """Oracle for ``fused_decode_matmul``: x @ upcast(fp8(W)).

    ``w_bits`` is the (K, N) uint8 bit view of the fp8 weight."""
    w = jnp.asarray(w_bits).view(fp8.FP8_DTYPE).astype(jnp.bfloat16)
    return jnp.dot(jnp.asarray(x, jnp.bfloat16), w,
                   preferred_element_type=out_dtype)
