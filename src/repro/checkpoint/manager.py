"""Fault-tolerant checkpointing: atomic, checksummed, async, elastic.

Properties (the fault-tolerance contract, exercised by tests):

  * **Atomic**: a checkpoint is written into ``<dir>.tmp`` and ``os.rename``d
    into place; the manifest is written *last* inside the tmp dir, so a
    visible ``step_XXXXXXXX`` directory with a manifest is complete by
    construction.  A crash mid-write leaves only a ``.tmp`` that restore
    ignores and the next save garbage-collects.
  * **Checksummed**: every array's crc32 is in the manifest; ``restore``
    verifies and falls back to the previous checkpoint on corruption.
  * **Async**: ``save_async`` snapshots arrays to host memory synchronously
    (so training can mutate buffers immediately) and writes on a background
    thread — the training loop never blocks on the filesystem.
  * **Elastic / mesh-agnostic**: arrays are stored host-shaped (full logical
    shape).  ``restore`` re-shards onto whatever mesh/sharding the caller
    passes — restart on a different pod count or topology works by
    construction (tested: save on one mesh, restore onto another).
  * **ECF8-compressed** (the paper's technique on the fault-tolerance path):
    fp8 leaves are entropy-coded with the ECF8-TPU container at write time
    and decoded bit-exactly at restore (``compress="ecf8"``), cutting
    checkpoint bytes by the weight-compression ratio and therefore restart
    time — useful at scale where restore bandwidth gates MTTR.

Layout:
    <root>/step_00000042/
        manifest.json      {step, leaves: {path: {file, crc32, shape, ...}}}
        arrays.npz         raw leaves
        ecf8_<i>.npz       compressed fp8 leaves (one file per leaf)
"""
from __future__ import annotations

import json
import os
import queue
import shutil
import threading
import zlib
from dataclasses import dataclass

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import fp8, tpu_format

_SEP = "\x1e"  # path separator in flattened keys (never appears in names)

# live tmp-dir registry: every in-flight ``save_tree`` in this process
# registers its (unique) tmp path here so GC never reclaims a directory a
# concurrent writer is still filling.  Tmp names carry the owning pid so a
# *different* process's GC can distinguish a live foreign writer from the
# orphan of a crashed one.
_TMP_LOCK = threading.Lock()
_LIVE_TMPS: set = set()


def _tmp_is_orphan(path: str) -> bool:
    """True when a ``step_XXXXXXXX.tmp[.pid.tid]`` dir belongs to no live
    writer and is safe to garbage-collect."""
    with _TMP_LOCK:
        if path in _LIVE_TMPS:
            return False
    name = os.path.basename(path)
    if name.endswith(".tmp"):
        # legacy unowned tmp name: only ever left behind by a crash
        return True
    parts = name.rsplit(".", 2)         # step_XXXXXXXX.tmp, pid, tid
    try:
        pid = int(parts[1])
    except (IndexError, ValueError):
        return True
    if pid == os.getpid():
        # ours but unregistered -> the writer already failed/finished
        return True
    try:
        os.kill(pid, 0)
    except ProcessLookupError:
        return True                     # owning process is gone
    except OSError:
        pass                            # e.g. EPERM: alive, other user
    return False


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = _SEP.join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        out[key] = leaf
    return out, treedef


def _host(x):
    """Fetch a (possibly sharded) jax.Array fully to host memory."""
    if isinstance(x, jax.Array):
        x = jax.device_get(x)
    return np.asarray(x)


def _crc(a: np.ndarray) -> int:
    return zlib.crc32(np.ascontiguousarray(a).view(np.uint8).tobytes())


def save_tree(tree, directory: str, step: int, compress: str = "none"):
    """Synchronous atomic checkpoint write.  compress: none|ecf8.

    Each writer gets a **unique** tmp dir (``step_XXXXXXXX.tmp.<pid>.<tid>``)
    registered in the live-writer set, so concurrent writers (async worker
    vs. main-thread ``save_sync``, or two processes sharing a directory)
    never delete each other's in-progress work and GC only reclaims
    orphans."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = f"{final}.tmp.{os.getpid()}.{threading.get_ident()}"
    with _TMP_LOCK:
        _LIVE_TMPS.add(tmp)
    try:
        return _save_tree_into(tree, tmp, final, step, compress)
    finally:
        with _TMP_LOCK:
            _LIVE_TMPS.discard(tmp)
        shutil.rmtree(tmp, ignore_errors=True)   # no-op after rename


def _save_tree_into(tree, tmp: str, final: str, step: int, compress: str):
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    flat, _ = _flatten(tree)
    manifest = {"step": step, "compress": compress, "leaves": {}}
    raw = {}
    for i, (key, leaf) in enumerate(sorted(flat.items())):
        a = _host(leaf)
        entry = {"shape": list(a.shape), "dtype": str(a.dtype),
                 "crc32": _crc(a)}
        if compress == "ecf8" and a.dtype == np.dtype(jnp.float8_e4m3fn):
            c = tpu_format.encode(a.view(np.uint8))
            fn = f"ecf8_{i}.npz"
            np.savez(os.path.join(tmp, fn), payload=c.payload,
                     signmant=c.signmant, lj_limit=c.lj_limit,
                     first_lj=c.first_lj, offset=c.offset, perm=c.perm,
                     lengths=c.lengths,
                     meta=np.asarray([c.n_elem, c.sym_per_lane]))
            entry.update(format="ecf8", file=fn)
        else:
            # npz stores by name; float8 views as uint8 for portability
            if a.dtype == np.dtype(jnp.float8_e4m3fn):
                raw[key] = a.view(np.uint8)
                entry["stored_as"] = "uint8_bits"
            else:
                raw[key] = a
            entry.update(format="raw", file="arrays.npz")
        manifest["leaves"][key] = entry
    np.savez(os.path.join(tmp, "arrays.npz"), **raw)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    try:
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
    except OSError:
        # another writer renamed its copy of this step between our rmtree
        # and rename: the step is durable either way, discard our tmp
        if not os.path.isfile(os.path.join(final, "manifest.json")):
            raise
        shutil.rmtree(tmp, ignore_errors=True)
    return final


def _load_dir(path: str, template_tree, shardings=None, verify: bool = True):
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    flat_t, treedef = _flatten(template_tree)
    npz = np.load(os.path.join(path, "arrays.npz"))
    out = {}
    for key, leaf in flat_t.items():
        entry = manifest["leaves"][key]
        want_dtype = entry["dtype"]
        if entry["format"] == "ecf8":
            z = np.load(os.path.join(path, entry["file"]))
            n_elem, spl = (int(v) for v in z["meta"])
            c = tpu_format.TpuECF8(
                payload=z["payload"], payload_ragged=np.zeros(0, np.uint8),
                chunk_offsets=np.zeros(1, np.int32),
                chunk_strides=np.zeros(0, np.int32),
                signmant=z["signmant"], lj_limit=z["lj_limit"],
                first_lj=z["first_lj"], offset=z["offset"], perm=z["perm"],
                lengths=z["lengths"], n_elem=n_elem,
                shape=tuple(entry["shape"]), sym_per_lane=spl)
            bits = np.asarray(tpu_format.decode_jnp(c))
            a = bits.view(jnp.float8_e4m3fn).reshape(c.shape)
        else:
            a = npz[key]
            if entry.get("stored_as") == "uint8_bits":
                a = a.view(jnp.float8_e4m3fn)
        if verify and _crc(a) != entry["crc32"]:
            raise IOError(f"checksum mismatch for {key} in {path}")
        a = a.reshape(entry["shape"])
        out[key] = a
    # rebuild in the template's flatten order (keys are unique paths)
    if shardings is not None:
        flat_s, _ = _flatten(shardings)
        leaves = [jax.device_put(out[k], flat_s[k]) if k in flat_s
                  else jnp.asarray(out[k]) for k in flat_t]
    else:
        leaves = [out[k] for k in flat_t]
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    return tree, manifest["step"]


def restore_tree(directory: str, template_tree, shardings=None,
                 step: int | None = None, verify: bool = True):
    """Restore the latest (or given) valid checkpoint.

    ``shardings``: optional pytree of NamedSharding — arrays are placed
    directly onto the (possibly different) target mesh (elastic restore).
    Returns (tree, step) or (None, -1) when nothing restorable exists.
    """
    steps = available_steps(directory)
    if step is not None:
        steps = [s for s in steps if s == step]
    for s in sorted(steps, reverse=True):
        path = os.path.join(directory, f"step_{s:08d}")
        try:
            return _load_dir(path, template_tree, shardings, verify=verify)
        except Exception as e:  # corrupt -> try older
            print(f"[checkpoint] skipping {path}: {e}")
    return None, -1


def available_steps(directory: str) -> list:
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        if not name.startswith("step_") or ".tmp" in name:
            continue
        if not os.path.exists(os.path.join(directory, name,
                                           "manifest.json")):
            continue
        try:
            out.append(int(name[5:]))
        except ValueError:
            # stray entry (step_foo/, junk from an interrupted copy):
            # skip it instead of taking down restore
            print(f"[checkpoint] ignoring stray entry {name!r} in "
                  f"{directory}")
    return sorted(out)


@dataclass
class CheckpointManager:
    """Async checkpoint manager with retention and auto-resume."""

    directory: str
    keep: int = 3
    compress: str = "none"

    def __post_init__(self):
        os.makedirs(self.directory, exist_ok=True)
        self._q: queue.Queue = queue.Queue()
        self._errors: list = []
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        while True:
            item = self._q.get()
            if item is None:
                self._q.task_done()
                return
            step, host_tree = item
            try:
                save_tree(host_tree, self.directory, step,
                          compress=self.compress)
                self._gc()
            except Exception as e:  # surfaced via .errors
                self._errors.append((step, repr(e)))
            finally:
                self._q.task_done()

    def _gc(self):
        steps = available_steps(self.directory)
        for s in steps[: max(0, len(steps) - self.keep)]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)
        # stale tmp dirs from crashes — but never one a live writer owns
        # (async worker GC racing a main-thread ``save_sync`` used to
        # delete the sync writer's half-written tmp out from under it)
        for name in os.listdir(self.directory):
            if ".tmp" not in name:
                continue
            path = os.path.join(self.directory, name)
            if _tmp_is_orphan(path):
                shutil.rmtree(path, ignore_errors=True)

    def save_async(self, step: int, tree):
        """Snapshot to host now; write on the background thread."""
        host_tree = jax.tree_util.tree_map(_host, tree)
        self._q.put((step, host_tree))

    def save_sync(self, step: int, tree):
        host_tree = jax.tree_util.tree_map(_host, tree)
        save_tree(host_tree, self.directory, step, compress=self.compress)
        self._gc()

    def wait(self):
        self._q.join()
        if self._errors:
            errs, self._errors = self._errors, []
            raise IOError(f"async checkpoint writes failed: {errs}")

    def restore(self, template_tree, shardings=None):
        self.wait()
        return restore_tree(self.directory, template_tree, shardings)

    def close(self):
        """Drain the queue, stop the worker, and surface any pending
        write errors (a failed final async save must not be swallowed)."""
        self._q.put(None)
        self._q.join()
        self._thread.join(timeout=30.0)
        if self._errors:
            errs, self._errors = self._errors, []
            raise IOError(f"async checkpoint writes failed: {errs}")
