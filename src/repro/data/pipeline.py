"""Deterministic synthetic token pipeline, per-host sharded.

Design constraints (1000+ node target):
  * **Deterministic and stateless**: batch ``i`` is a pure function of
    ``(seed, i)`` — any host can (re)generate any batch, so restart after a
    failure needs only the step counter from the checkpoint, and elastic
    re-sharding needs no data-state migration at all.
  * **Per-host sharding**: each host materializes only its slice of the
    global batch (``jax.process_index()``-derived), then the slices are
    assembled into a global jax.Array via
    ``jax.make_array_from_process_local_data`` — the standard multi-host
    input path (works identically on 1 host with 512 virtual devices).
  * The token stream is a fixed-vocab LCG-mixed sequence with a learnable
    structure (next-token = f(prev tokens) with noise) so a ~100M model's
    loss actually falls during the example training run — pure-uniform
    tokens would hide optimizer bugs (loss would sit at log V regardless).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    # structure of the synthetic language (see _gen_tokens)
    n_states: int = 97          # hidden markov-ish state count
    noise: float = 0.1          # probability of a uniform-random token


class SyntheticLMData:
    """Deterministic synthetic LM batches; batch i is a function of (seed, i).

    ``batch(i)`` -> dict(tokens (B, T) int32, labels (B, T) int32) where
    labels are next-token targets (tokens shifted left; last label wraps).
    """

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def _gen_tokens(self, rows: np.ndarray) -> np.ndarray:
        """Generate the token matrix for *global* row ids ``rows``.

        Every row is a pure function of (seed, row id): the noise streams
        are drawn from a per-row SeedSequence, so any host generating any
        subset of rows produces identical tokens (the elastic property)."""
        c = self.cfg
        T = c.seq_len + 1
        n = len(rows)
        noise = np.empty((n, T))
        rand_toks = np.empty((n, T), dtype=np.int64)
        for i, r in enumerate(rows):
            rng = np.random.default_rng(
                np.random.SeedSequence([c.seed, int(r)]))
            noise[i] = rng.random(T)
            rand_toks[i] = rng.integers(0, c.vocab_size, size=T)
        is_noise = noise < c.noise
        # structured stream: token = state-projected value, state advances
        # by an LCG of (state, token); occasional uniform noise
        toks = np.empty((n, T), dtype=np.int64)
        s = (rows.astype(np.int64) * 2654435761) % c.n_states
        for t in range(T):
            tok = (s * 7919 + 13) % c.vocab_size
            tok = np.where(is_noise[:, t], rand_toks[:, t], tok)
            toks[:, t] = tok
            s = (s * 6364136223846793005 + tok + 1442695040888963407) \
                % c.n_states
        return toks.astype(np.int32)

    def batch_numpy(self, idx: int, rows: np.ndarray | None = None) -> dict:
        """Host-side batch for the given local row ids (default: all)."""
        c = self.cfg
        if rows is None:
            rows = np.arange(c.global_batch, dtype=np.int64)
        rows = np.asarray(rows) + np.int64(idx) * c.global_batch
        toks = self._gen_tokens(rows)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def batch(self, idx: int) -> dict:
        """Single-process batch as device arrays."""
        b = self.batch_numpy(idx)
        return {k: jnp.asarray(v) for k, v in b.items()}

    def sharded_batch(self, idx: int, mesh: Mesh, batch_axes) -> dict:
        """Global jax.Array batch sharded over ``batch_axes`` of ``mesh``.

        Each process generates only its local rows (deterministically), then
        the global array is assembled — no cross-host data exchange.
        """
        c = self.cfg
        spec = P(batch_axes, None)
        sharding = NamedSharding(mesh, spec)
        n_proc = jax.process_count()
        per_proc = c.global_batch // n_proc
        lo = jax.process_index() * per_proc
        rows = np.arange(lo, lo + per_proc, dtype=np.int64)
        local = self.batch_numpy(idx, rows=rows)
        return {
            k: jax.make_array_from_process_local_data(sharding, v,
                                                      (c.global_batch,
                                                       c.seq_len))
            for k, v in local.items()
        }


def make_global_array(x: np.ndarray, mesh: Mesh, spec: P):
    """Utility: place a host array as a global sharded jax.Array."""
    return jax.device_put(x, NamedSharding(mesh, spec))
