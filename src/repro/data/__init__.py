from .pipeline import DataConfig, SyntheticLMData, make_global_array  # noqa: F401
