"""Pytest config.  NOTE: no XLA_FLAGS here — tests must see 1 device;
multi-device tests spawn subprocesses (test_sharding.py) and only the
dry-run sets the 512-device flag (launch/dryrun.py)."""


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: multi-minute tests (subprocess compiles, drills)")
