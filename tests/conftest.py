"""Pytest config.  NOTE: no XLA_FLAGS here — tests must see 1 device;
multi-device tests spawn subprocesses (via :func:`run_subprocess`) and only
the dry-run sets the 512-device flag (launch/dryrun.py)."""
import os
import subprocess
import sys
import textwrap

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

try:
    # Two example budgets for the property suites: "tier1" keeps the
    # default run fast (tests that pin their own ``@settings`` are
    # unaffected); the tier-2 ``tests-extended`` CI job raises it with
    # ``--hypothesis-profile=ci`` (the pytest plugin's CLI flag wins over
    # the ``load_profile`` default below).
    from hypothesis import settings as _hyp_settings
    _hyp_settings.register_profile("tier1", max_examples=5, deadline=None)
    _hyp_settings.register_profile("ci", max_examples=40, deadline=None)
    _hyp_settings.load_profile("tier1")
except ImportError:
    pass


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: multi-minute tests (subprocess compiles, drills)")


def run_subprocess(body: str, devices: int = 8) -> str:
    """Run a multi-device test body in a fresh interpreter with
    ``--xla_force_host_platform_device_count=devices`` (the main pytest
    process must keep seeing exactly 1 device).  Asserts a zero exit and
    returns stdout."""
    env = dict(os.environ,
               PYTHONPATH=os.path.join(REPO, "src"),
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}")
    p = subprocess.run([sys.executable, "-c", textwrap.dedent(body)],
                       env=env, capture_output=True, text=True, timeout=900)
    assert p.returncode == 0, \
        f"stdout:\n{p.stdout}\nstderr:\n{p.stderr[-4000:]}"
    return p.stdout
