"""Per-architecture smoke tests (deliverable f) + mixer/MoE correctness.

Each assigned architecture instantiates its reduced same-family config and
runs one forward/train step on CPU, asserting output shapes and no NaNs,
plus prefill/decode consistency against the full forward pass.
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import ASSIGNED, get, smoke_variant
from repro.models import model as M
from repro.models import recurrent as R
from repro.models import moe as MOE


def _inputs(cfg, B=2, T=16, seed=1):
    toks = jax.random.randint(jax.random.PRNGKey(seed), (B, T), 0,
                              cfg.vocab_size)
    frames = None
    if cfg.encoder_decoder:
        frames = jax.random.normal(
            jax.random.PRNGKey(seed + 1), (B, cfg.encoder_frames, cfg.d_model))
    return toks, frames


@pytest.mark.parametrize("name", ASSIGNED)
def test_arch_smoke_forward(name):
    cfg = smoke_variant(get(name))
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    toks, frames = _inputs(cfg)
    logits, aux = M.forward(params, cfg, toks, frames=frames)
    assert logits.shape == (2, 16, cfg.vocab_size)
    assert not np.any(np.isnan(np.asarray(logits)))


@pytest.mark.parametrize("name", ASSIGNED)
def test_arch_smoke_train_step(name):
    cfg = smoke_variant(get(name))
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    toks, frames = _inputs(cfg)
    labels = jnp.roll(toks, -1, axis=1)

    def loss(p):
        l, _ = M.loss_fn(p, cfg, toks, labels, frames=frames)
        return l

    val, grads = jax.value_and_grad(loss)(params)
    assert np.isfinite(float(val))
    gnorm = jax.tree_util.tree_reduce(
        lambda a, g: a + float(jnp.sum(jnp.square(g))), grads, 0.0)
    assert np.isfinite(gnorm) and gnorm > 0


@pytest.mark.parametrize("name", ASSIGNED)
def test_arch_prefill_decode_consistency(name):
    cfg = smoke_variant(get(name))
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    toks, frames = _inputs(cfg)
    logits, _ = M.forward(params, cfg, toks, frames=frames)
    lp, cache = M.prefill(params, cfg, toks, frames=frames, max_len=24)
    np.testing.assert_allclose(np.asarray(lp[:, 0]),
                               np.asarray(logits[:, -1]), atol=2e-4)
    nxt = jax.random.randint(jax.random.PRNGKey(3), (2, 1), 0,
                             cfg.vocab_size)
    ld, cache = M.decode_step(params, cfg, nxt, cache)
    logits2, _ = M.forward(params, cfg, jnp.concatenate([toks, nxt], 1),
                           frames=frames)
    np.testing.assert_allclose(np.asarray(ld[:, 0]),
                               np.asarray(logits2[:, -1]), atol=5e-4)


def test_mlstm_chunkwise_matches_sequential():
    B, T, d, H = 2, 64, 32, 4
    p = R.mlstm_init(jax.random.PRNGKey(0), d, H)
    x = jax.random.normal(jax.random.PRNGKey(1), (B, T, d)) * 0.5
    y_ref = R.mlstm_seq_ref(p, x, H, dtype=jnp.float32)
    for chunk in (1, 8, 16, 64):
        y, _ = R.mlstm_apply(p, x, H, dtype=jnp.float32, chunk=chunk)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   atol=1e-5)


def test_rglru_scan_matches_stepwise():
    B, T, d = 2, 32, 16
    p = R.rglru_init(jax.random.PRNGKey(0), d, d)
    x = jax.random.normal(jax.random.PRNGKey(1), (B, T, d)) * 0.5
    y_full, st_full = R.rglru_apply(p, x, dtype=jnp.float32)
    st = R.rglru_init_state(B, d)
    ys = []
    for t in range(T):
        yt, st = R.rglru_step(p, x[:, t], st, dtype=jnp.float32)
        ys.append(yt)
    np.testing.assert_allclose(np.asarray(jnp.stack(ys, 1)),
                               np.asarray(y_full), atol=1e-5)
    np.testing.assert_allclose(np.asarray(st["h"]), np.asarray(st_full["h"]),
                               atol=1e-5)


def test_moe_matches_dense_reference():
    from dataclasses import replace
    cfg = replace(smoke_variant(get("moonshot-v1-16b-a3b")),
                  capacity_factor=100.0, n_shared_experts=0)
    p = MOE.moe_init(jax.random.PRNGKey(0), cfg.d_model, cfg.n_experts,
                     cfg.moe_d_ff, 0, cfg.moe_d_ff, cfg.top_k)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 9, cfg.d_model)) * 0.3
    y, aux = MOE.moe_apply(p, x, cfg, mesh=None, dtype=jnp.float32)

    logits = jnp.einsum("btd,de->bte", x, p["gate"])
    probs = jax.nn.softmax(logits, -1)
    tp, ti = jax.lax.top_k(probs, cfg.top_k)
    tp = tp / tp.sum(-1, keepdims=True)

    def expert(e, xt):
        g = xt @ p["wi_gate"][e]
        u = xt @ p["wi_up"][e]
        return (jax.nn.silu(g) * u) @ p["wo"][e]

    ref = np.zeros_like(np.asarray(x))
    for b in range(2):
        for t in range(9):
            acc = sum(float(tp[b, t, j]) * np.asarray(
                expert(int(ti[b, t, j]), x[b, t]))
                for j in range(cfg.top_k))
            ref[b, t] = acc
    np.testing.assert_allclose(np.asarray(y), ref, atol=1e-4)
    assert np.isfinite(float(aux))


def test_local_attention_matches_masked_full():
    from repro.models.layers import blockwise_attention, local_attention
    B, H, T, D, W = 1, 2, 64, 16, 16
    q = jax.random.normal(jax.random.PRNGKey(0), (B, H, T, D))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, H, T, D))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, H, T, D))
    out = local_attention(q, k, v, window=W, q_chunk=16)
    # reference: full attention with a band mask
    s = jnp.einsum("bhqd,bhkd->bhqk", q * D ** -0.5, k)
    pos = jnp.arange(T)
    mask = (pos[None, :] <= pos[:, None]) & (pos[None, :] > pos[:, None] - W)
    s = jnp.where(mask[None, None], s, -1e30)
    ref = jnp.einsum("bhqk,bhkd->bhqd", jax.nn.softmax(s, -1), v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


def test_blockwise_attention_matches_naive():
    from repro.models.layers import blockwise_attention
    B, Hq, Hkv, T, D = 2, 4, 2, 50, 8
    q = jax.random.normal(jax.random.PRNGKey(0), (B, Hq, T, D))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, Hkv, T, D))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, Hkv, T, D))
    out = blockwise_attention(q, k, v, causal=True, q_chunk=16, kv_chunk=8)
    kk = jnp.repeat(k, Hq // Hkv, axis=1)
    vv = jnp.repeat(v, Hq // Hkv, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q * D ** -0.5, kk)
    pos = jnp.arange(T)
    s = jnp.where((pos[None, :] <= pos[:, None])[None, None], s, -1e30)
    ref = jnp.einsum("bhqk,bhkd->bhqd", jax.nn.softmax(s, -1), vv)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)
