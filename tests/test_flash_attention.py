"""flash_attention (custom VJP) vs blockwise_attention autodiff oracle."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.models.flash_attention import flash_attention
from repro.models.layers import blockwise_attention


def _qkv(B, Hq, Hkv, Tq, Tk, D, seed=0, dtype=jnp.float32):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, Hq, Tq, D), dtype) * 0.4
    k = jax.random.normal(ks[1], (B, Hkv, Tk, D), dtype) * 0.4
    v = jax.random.normal(ks[2], (B, Hkv, Tk, D), dtype) * 0.4
    return q, k, v


@pytest.mark.parametrize("Hq,Hkv", [(4, 4), (8, 2)])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("cap", [0.0, 20.0])
def test_forward_matches_blockwise(Hq, Hkv, causal, cap):
    q, k, v = _qkv(2, Hq, Hkv, 48, 48, 16)
    out = flash_attention(q, k, v, causal, cap, 16, 16)
    ref = blockwise_attention(q, k, v, causal=causal, attn_softcap=cap,
                              q_chunk=16, kv_chunk=16)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-6)


@pytest.mark.parametrize("Hq,Hkv", [(4, 4), (6, 2)])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("cap", [0.0, 15.0])
def test_grads_match_autodiff_oracle(Hq, Hkv, causal, cap):
    q, k, v = _qkv(2, Hq, Hkv, 40, 40, 8, seed=3)

    def loss_flash(q, k, v):
        return (flash_attention(q, k, v, causal, cap, 16, 16) ** 2).sum()

    def loss_ref(q, k, v):
        return (blockwise_attention(q, k, v, causal=causal,
                                    attn_softcap=cap, q_chunk=16,
                                    kv_chunk=16) ** 2).sum()

    gf = jax.grad(loss_flash, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b, name in zip(gf, gr, "qkv"):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=3e-5, err_msg=f"d{name}")


def test_grads_uneven_lengths_and_chunks():
    q, k, v = _qkv(1, 4, 2, 37, 53, 8, seed=5)

    def loss(fn):
        def f(q, k, v):
            if fn == "flash":
                o = flash_attention(q, k, v, False, 0.0, 16, 16)
            else:
                o = blockwise_attention(q, k, v, causal=False,
                                        q_chunk=16, kv_chunk=16)
            return (o * jnp.sin(jnp.arange(o.shape[-1]))).sum()
        return f

    gf = jax.grad(loss("flash"), argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(loss("ref"), argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gf, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=3e-5)


def test_no_probability_residuals_saved():
    """The residuals of the VJP must be O(B*H*T*(D+2)) — not O(T^2)."""
    B, H, T, D = 1, 2, 256, 16
    q, k, v = _qkv(B, H, H, T, T, D)
    _, vjp = jax.vjp(
        lambda q, k, v: flash_attention(q, k, v, True, 0.0, 64, 64),
        q, k, v)
    leaves = jax.tree_util.tree_leaves(vjp)
    biggest = max(int(np.prod(l.shape)) for l in leaves
                  if hasattr(l, "shape"))
    assert biggest <= B * H * T * D, biggest  # no (T, T) tensor saved
