"""Bit-exactness of every compression path (the paper's core claim, Fig. 3).

Covers the three containers (paper-faithful, ECF8-TPU, ECF8-FR), the
parameter-store decode-on-use path, and end-to-end equal logits between
compressed and fp8-baseline models.
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get, smoke_variant
from repro.core import fixedrate, fp8, paper_format, stats, tpu_format
from repro.core.store import (compress_tree, fp8_cast_tree, materialize)
from repro.models import model as M

SHAPES = [(64,), (257,), (128, 384), (1000, 33)]
ALPHAS = [1.2, 1.9]


def _weights(shape, alpha, seed=0):
    return stats.synthesize_fp8_weights(shape, alpha=alpha, seed=seed)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("alpha", ALPHAS)
def test_paper_container_roundtrip(shape, alpha):
    bits = _weights(shape, alpha)
    c = paper_format.encode(bits)
    np.testing.assert_array_equal(paper_format.decode_sequential(c), bits)
    np.testing.assert_array_equal(paper_format.decode_blockparallel(c), bits)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("alpha", ALPHAS)
def test_tpu_container_roundtrip(shape, alpha):
    bits = _weights(shape, alpha)
    c = tpu_format.encode(bits, sym_per_lane=32)
    np.testing.assert_array_equal(
        tpu_format.decode_ref(c).reshape(-1), bits.reshape(-1))
    np.testing.assert_array_equal(
        np.asarray(tpu_format.decode_jnp(c)), bits.reshape(-1))


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("alpha", ALPHAS)
def test_fixedrate_roundtrip(shape, alpha):
    bits = _weights(shape, alpha)
    c = fixedrate.encode(bits)
    np.testing.assert_array_equal(fixedrate.decode_ref(c), bits)
    np.testing.assert_array_equal(
        np.asarray(fixedrate.decode_jnp(c)),
        bits.reshape(-1))


def test_adversarial_exponent_distributions():
    """Degenerate histograms: single symbol, two symbols, all 16 uniform."""
    for bits in [
        np.full(5000, 0b0_0111_010, np.uint8),              # one exponent
        np.where(np.arange(5000) % 2, 0b0_0111_000,
                 0b1_1000_111).astype(np.uint8),            # two exponents
        (np.arange(5000) * 7 % 256).astype(np.uint8),       # all fields
    ]:
        for enc, dec in [
            (paper_format.encode, paper_format.decode_blockparallel),
            (tpu_format.encode, lambda c: np.asarray(
                tpu_format.decode_jnp(c)).reshape(c.shape)),
            (fixedrate.encode, fixedrate.decode_ref),
        ]:
            c = enc(bits)
            np.testing.assert_array_equal(np.asarray(dec(c)).reshape(-1),
                                          bits)


def test_store_materialize_bit_exact():
    bits = _weights((512, 96), 1.9)
    w8 = bits.view(jnp.float8_e4m3fn)
    for fmt in ("tpu", "fixedrate"):
        ct, _ = compress_tree({"w": w8.astype(jnp.float32)},
                              fmt=fmt, min_elems=1, stacked_axes=0)
        got = materialize(ct["w"], dtype=jnp.float32)
        want = w8.astype(jnp.float32)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_equal_logits_compressed_vs_fp8_baseline():
    """End-to-end Fig. 3: identical outputs from compressed weights."""
    cfg = smoke_variant(get("gemma2-9b"))
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    base = fp8_cast_tree(params, min_elems=2048)
    comp, rep = compress_tree(params, fmt="tpu", min_elems=2048,
                              out_dtype="float32")
    assert rep["n_compressed"] > 0
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                              cfg.vocab_size)
    lb, _ = M.forward(base, cfg, toks)
    lc, _ = M.forward(comp, cfg, toks)
    np.testing.assert_array_equal(np.asarray(lb), np.asarray(lc))


def test_equal_decode_path_compressed_vs_fp8():
    cfg = smoke_variant(get("qwen3-8b"))
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    base = fp8_cast_tree(params, min_elems=2048)
    comp, _ = compress_tree(params, fmt="fixedrate", min_elems=2048,
                            out_dtype="float32")
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                              cfg.vocab_size)
    lb, cb = M.prefill(base, cfg, toks, max_len=12)
    lc, cc = M.prefill(comp, cfg, toks, max_len=12)
    np.testing.assert_array_equal(np.asarray(lb), np.asarray(lc))
    nxt = jnp.full((2, 1), 3, jnp.int32)
    db, _ = M.decode_step(base, cfg, nxt, cb)
    dc, _ = M.decode_step(comp, cfg, nxt, cc)
    np.testing.assert_array_equal(np.asarray(db), np.asarray(dc))


def test_compression_ratio_in_paper_band():
    """Realistic trained-like tensors land in the 9.8-26.9% savings band."""
    bits = _weights((2048, 512), 1.9, seed=7)
    for ratio in (paper_format.encode(bits).ratio,
                  tpu_format.encode(bits).ratio("ragged")):
        saving = 1.0 - ratio
        assert 0.05 < saving < 0.45, saving
