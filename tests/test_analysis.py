"""HLO collective parsing + roofline term arithmetic."""
import numpy as np

from repro.analysis.hlo_parse import collective_bytes, op_histogram
from repro.analysis.roofline import HW, model_flops, roofline_terms
from repro.configs import SHAPES, get

HLO = """
HloModule jit_step
  %ag = bf16[16,4096,384]{2,1,0} all-gather(%x), replica_groups={{0,1,2,3}}
  %ar = f32[1024]{0} all-reduce(%y), replica_groups={{0,1}}
  %rs = bf16[8,128]{1,0} reduce-scatter(%z), replica_groups={{0,1,2,3}}
  %ags = (bf16[256]{0}, bf16[256]{0}) all-gather-start(%a, %b)
  %agd = bf16[512]{0} all-gather-done(%ags)
  %cp = u8[64]{0} collective-permute(%w), replica_groups={{0,1}}
  %dot = f32[8,8]{1,0} dot(%p, %q)
"""


def test_collective_bytes_parsing():
    c = collective_bytes(HLO)
    ag = 16 * 4096 * 384 * 2 + 256 * 2  # big gather + start (largest part)
    ar = 1024 * 4 * 2.0                 # all-reduce counts 2x
    rs = 8 * 128 * 2
    cp = 64
    np.testing.assert_allclose(c["all-gather"], ag)
    np.testing.assert_allclose(c["all-reduce"], ar)
    np.testing.assert_allclose(c["reduce-scatter"], rs)
    np.testing.assert_allclose(c["collective-permute"], cp)
    np.testing.assert_allclose(c["total"], ag + ar + rs + cp)
    assert c["count"] == 5  # ag, ar, rs, ag-start, cp; -done not counted


def test_done_not_counted_and_histogram():
    c = collective_bytes(HLO)
    assert all(op != "all-gather-done" for op, _, _ in c["ops"])
    h = op_histogram(HLO)
    assert h.get("dot") == 1


def test_roofline_terms_and_dominance():
    cfg = get("granite-20b")
    shape = SHAPES["train_4k"]
    cost = {"flops": 197e12 * 0.1, "bytes accessed": 819e9 * 0.5}
    coll = {"total": 50e9 * 0.2}
    r = roofline_terms(cost, coll, 256, cfg, shape)
    np.testing.assert_allclose(r["t_compute"], 0.1)
    np.testing.assert_allclose(r["t_memory"], 0.5)
    np.testing.assert_allclose(r["t_collective"], 0.2)
    assert r["dominant"] == "memory"
    assert 0 < r["useful_flops_ratio"]
    assert 0 < r["mfu_bound"]


def test_model_flops_conventions():
    cfg = get("moonshot-v1-16b-a3b")
    tr = model_flops(cfg, SHAPES["train_4k"])
    pf = model_flops(cfg, SHAPES["prefill_32k"])
    dc = model_flops(cfg, SHAPES["decode_32k"])
    # MoE: active < total params in the 6ND count
    assert cfg.active_param_count() < cfg.param_count()
    assert tr / (SHAPES["train_4k"].global_batch
                 * SHAPES["train_4k"].seq_len) == 6.0 * cfg.active_param_count()
    assert dc == 2.0 * cfg.active_param_count() * 128
    assert pf > dc
