"""Speculative decoding (ISSUE 7): differential + statistical identity.

Three proof layers for the draft/verify engine path:

  * **differential anchor** — greedy speculative output must be
    **token-identical** to the target-only engine for k in {1, 2, 4, 8},
    including under forced preemption/resume and (slow, subprocess) on a
    2-device data mesh; a draft that equals the target must reproduce
    the plain-decode *sampled* stream bit for bit at any temperature
    (the key-discipline contract of ``serving.spec``);
  * **statistical identity** — the rejection-sampling marginal over many
    seeded trials matches the analytic target distribution (chi-square,
    fixed seeds, and must *not* match the draft distribution — the
    test's power check); ``residual_probs`` is exact on hand-built p/q;
  * **rollback property** — hypothesis over (prompt length, page size,
    window size, accepted-prefix length): rejecting a suffix that
    straddles a page boundary restores ``cur_len``, the page table and
    the per-shard free lists bit-exactly to an allocator that never saw
    the window.
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from conftest import run_subprocess

from repro.configs import get, smoke_variant
from repro.kvcache import PagedKVCache
from repro.models import model as M
from repro.serving import EngineConfig, GenerationEngine, Request, spec
from repro.serving.sampler import request_key, residual_probs, sample_logits

try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # tier-1 may run without hypothesis
    given = None


def _tcfg():
    return smoke_variant(get("qwen3-8b"))


def _dcfg():
    return smoke_variant(get("xlstm-350m"))   # recurrent draft, same vocab


def _params(cfg, seed):
    return M.init_params(jax.random.PRNGKey(seed), cfg)


def _stream(temps=(0.0,)):
    return [Request(prompt=[i + 1] * (4 + 2 * i), max_new_tokens=5 + i,
                    temperature=temps[i % len(temps)], id=40_000 + i)
            for i in range(4)]


def _serve(params, cfg, reqs, **kw):
    eng = GenerationEngine(params, cfg, config=EngineConfig(max_batch=3, max_len=64, **kw))
    for r in reqs:
        eng.submit(r)
    eng.run()
    assert all(r.done for r in reqs)
    return [r.out_tokens for r in reqs], eng


# --------------------------------------------------------------------------
# differential anchor: greedy spec == target-only, k-invariant
# --------------------------------------------------------------------------

def test_greedy_spec_identical_to_target_only_all_k():
    cfg, dcfg = _tcfg(), _dcfg()
    params, dparams = _params(cfg, 0), _params(dcfg, 1)
    base, _ = _serve(params, cfg, _stream())
    for k in (1, 2, 4, 8):
        got, eng = _serve(params, cfg, _stream(),
                          draft_params=dparams, draft_cfg=dcfg, spec_k=k)
        assert eng.spec_on
        assert got == base, k
        sc = eng.spec_counters()
        assert sc["spec_drafted"] >= sc["spec_rounds"] > 0
        # every round emits >= 1 token even when all proposals reject
        assert sum(len(t) for t in got) >= sc["spec_rounds"]


def test_self_draft_sampled_identical_to_plain_decode():
    """draft == target makes every proposal's distribution equal the
    target's, so acceptance is 1.0 and — because proposals/bonus use the
    plain-decode rule and key — the *sampled* output is bit-identical to
    the non-speculative engine at any temperature."""
    cfg = _tcfg()
    params = _params(cfg, 0)
    base, _ = _serve(params, cfg, _stream(temps=(0.9, 0.0, 0.6)))
    for k in (1, 3):
        got, eng = _serve(params, cfg, _stream(temps=(0.9, 0.0, 0.6)),
                          draft_params=params, draft_cfg=cfg, spec_k=k)
        assert eng.spec_on
        assert got == base, k
        sc = eng.spec_counters()
        assert sc["spec_accept_rate"] == 1.0, sc


def test_spec_under_forced_preemption_and_pressure():
    """Page pressure preempts draft/target pairs mid-stream, plus one
    explicit mid-generation ``_preempt``; the resumed pair (target pages
    faulted back, draft row re-spliced from the host stash) must keep
    the greedy stream identical to target-only."""
    cfg, dcfg = _tcfg(), _dcfg()
    params, dparams = _params(cfg, 0), _params(dcfg, 1)

    def reqs():
        return [Request(prompt=[i + 1] * (6 + 3 * i), max_new_tokens=10 + i,
                        priority=i % 2, id=41_000 + i) for i in range(6)]

    def serve(spec_on, **kw):
        eng = GenerationEngine(
            params, cfg, config=EngineConfig(max_batch=2, max_len=64, page_size=4, n_pages=10,
            swap_bytes=-1,
            **(dict(draft_params=dparams, draft_cfg=dcfg, spec_k=4)
               if spec_on else {}), **kw))
        rs = reqs()
        for r in rs:
            eng.submit(r)
        for _ in range(4):
            eng.step()
        occupied = [s for s in range(eng.max_batch)
                    if eng.slots[s] is not None]
        if occupied:
            assert eng._preempt(occupied[0])    # force a swap round trip
        eng.run()
        assert all(r.done for r in rs)
        return [r.out_tokens for r in rs], eng

    base, _ = serve(False)
    got, eng = serve(True)
    assert eng.spec_on
    assert eng.scheduler.n_preempted > 0 and eng.scheduler.n_resumed > 0
    assert got == base


def test_spec_gating_falls_back_to_target_only():
    """Unsupported combinations warn and serve target-only instead of
    failing: monolithic cache, chunked prefill, vocab mismatch."""
    from dataclasses import replace
    cfg, dcfg = _tcfg(), _dcfg()
    params, dparams = _params(cfg, 0), _params(dcfg, 1)
    for kw in (dict(cache_mode="monolithic"), dict(prefill_chunk=16)):
        with pytest.warns(UserWarning, match="speculative"):
            eng = GenerationEngine(params, cfg, config=EngineConfig(max_batch=2, max_len=64,
                                   draft_params=dparams, draft_cfg=dcfg,
                                   **kw))
        assert not eng.spec_on
    bad = replace(dcfg, vocab_size=dcfg.vocab_size * 2)
    with pytest.warns(UserWarning, match="speculative"):
        eng = GenerationEngine(params, cfg, config=EngineConfig(max_batch=2, max_len=64,
                               draft_params=_params(bad, 1), draft_cfg=bad))
    assert not eng.spec_on
    r = Request(prompt=[1, 2, 3], max_new_tokens=4, id=42_000)
    eng.submit(r)
    eng.run()
    assert r.done and len(r.out_tokens) == 4


# --------------------------------------------------------------------------
# exact rejection sampling: unit + statistical identity
# --------------------------------------------------------------------------

def test_residual_probs_exact_on_handbuilt_cases():
    # zero overlap: residual is exactly p (Z = 1)
    p = jnp.asarray([0.5, 0.5, 0.0, 0.0])
    q = jnp.asarray([0.0, 0.0, 0.5, 0.5])
    np.testing.assert_allclose(np.asarray(residual_probs(p, q)),
                               np.asarray(p), atol=0)
    # identical: Z = 0; the total-function convention returns p
    np.testing.assert_allclose(np.asarray(residual_probs(p, p)),
                               np.asarray(p), atol=0)
    # one-hot target: residual collapses to the same one-hot
    p1 = jnp.asarray([0.0, 1.0, 0.0, 0.0])
    q1 = jnp.asarray([0.25, 0.25, 0.25, 0.25])
    np.testing.assert_allclose(np.asarray(residual_probs(p1, q1)),
                               np.asarray(p1), atol=1e-7)
    # generic: max(0, p - q) / Z, batched over leading axes
    p2 = jnp.asarray([[0.6, 0.2, 0.1, 0.1]])
    q2 = jnp.asarray([[0.1, 0.5, 0.2, 0.2]])
    want = np.asarray([[1.0, 0.0, 0.0, 0.0]]) * 0.5 / 0.5
    np.testing.assert_allclose(np.asarray(residual_probs(p2, q2)), want,
                               atol=1e-7)


def test_verify_greedy_is_exact_argmax_prefix():
    """Greedy verify accepts exactly the longest argmax-matching prefix
    and corrects/appends with the target argmax."""
    V = 8
    rng = np.random.default_rng(0)
    p = rng.normal(size=(4, V)).astype(np.float32)
    arg = [int(np.argmax(row)) for row in p]
    q = rng.normal(size=(3, V)).astype(np.float32)
    rng0 = jax.random.PRNGKey(0)
    # all proposals match the target argmax: full accept + bonus
    out, m = spec.verify(p, q, arg[:3], rng0=rng0, req_id=1, pos0=0,
                         temperature=0.0)
    assert m == 3 and out == arg
    # mismatch at index 1: keep 1, emit the target argmax there
    props = [arg[0], (arg[1] + 1) % V, arg[2]]
    out, m = spec.verify(p, q, props, rng0=rng0, req_id=1, pos0=0,
                         temperature=0.0)
    assert m == 1 and out == arg[:2]
    # empty window (k_eff == 0): plain greedy step on the single row
    out, m = spec.verify(p[:1], q[:0], [], rng0=rng0, req_id=1, pos0=0,
                         temperature=0.0)
    assert m == 0 and out == arg[:1]


def _chi_square(counts, probs):
    n = counts.sum()
    exp = probs * n
    return float(((counts - exp) ** 2 / np.maximum(exp, 1e-12)).sum())


def test_verify_marginal_matches_target_chi_square():
    """Statistical identity: over many seeded trials the emitted token's
    empirical distribution matches the analytic *target* softmax (chi-
    square below the dof=V-1 99.9% critical value) and does **not**
    match the draft's (the power check) — exactly the Leviathan/Chen
    speculative-sampling theorem, through the real ``spec.propose`` /
    ``spec.verify`` code path."""
    V, T, N = 6, 0.9, 1500
    rng = np.random.default_rng(5)
    p_log = (rng.normal(size=(2, V)) * 2).astype(np.float32)
    q_log = (rng.normal(size=(1, V)) * 2).astype(np.float32)
    p = np.asarray(jax.nn.softmax(jnp.asarray(p_log[0]) / T))
    q = np.asarray(jax.nn.softmax(jnp.asarray(q_log[0]) / T))
    rng0 = jax.random.PRNGKey(0)
    counts = np.zeros(V)
    accepted = 0
    for trial in range(N):
        t = spec.propose(jnp.asarray(q_log)[None], rng0, trial, 9,
                         temperature=T)
        out, m = spec.verify(p_log, q_log, [t], rng0=rng0, req_id=trial,
                             pos0=9, temperature=T)
        counts[out[0]] += 1
        accepted += m
    crit = 24.32    # chi-square 0.999 quantile, dof = 5
    chi_p = _chi_square(counts, p)
    chi_q = _chi_square(counts, q)
    assert chi_p < crit, (chi_p, counts / N, p)
    assert chi_q > crit, (chi_q, counts / N, q)   # power: p and q differ
    # analytic acceptance rate sum(min(p, q)) within a loose band
    a = float(np.minimum(p, q).sum())
    assert abs(accepted / N - a) < 0.05, (accepted / N, a)


def test_verify_key_stream_matches_plain_decode_when_q_equals_p():
    """With q == p every proposal accepts, and the emitted stream over
    any window split equals the plain-decode stream token for token —
    the k-invariance of the key discipline, isolated from the engine."""
    V, T = 11, 0.8
    rng = np.random.default_rng(2)
    rows = (rng.normal(size=(12, V)) * 1.5).astype(np.float32)
    rng0 = jax.random.PRNGKey(7)
    rid = 123
    plain = [int(sample_logits(jnp.asarray(rows[i])[None, None, :] / T,
                               request_key(rng0, rid, i),
                               temperature=1.0)[0, 0])
             for i in range(10)]
    for k in (1, 2, 5):
        got, pos = [], 0
        while len(got) < 10:
            n = min(k, 10 - pos - 1) if pos < 9 else 0
            props = [spec.propose(jnp.asarray(rows[pos + i])[None, None],
                                  rng0, rid, pos + i, temperature=T)
                     for i in range(n)]
            out, m = spec.verify(rows[pos: pos + n + 1], rows[pos: pos + n],
                                 props, rng0=rng0, req_id=rid, pos0=pos,
                                 temperature=T)
            assert m == n, "q == p must accept every proposal"
            got.extend(out)
            pos += len(out)
        assert got[:10] == plain, k


def test_rejection_draw_invariant_to_window_offset():
    """The accept/residual draws at an absolute position depend only on
    (rng0, req_id, position): a rejection at position 7 resamples the
    same token whether the window started at 7 or at 5."""
    V, T = 9, 1.0
    rng = np.random.default_rng(3)
    # q concentrates where p has little mass: rejections are common
    p_row = (rng.normal(size=V)).astype(np.float32)
    q_row = p_row[::-1].copy() * 3
    shared = (rng.normal(size=(2, V))).astype(np.float32)   # positions 5, 6
    rng0 = jax.random.PRNGKey(11)
    rid = 9
    # window starting at 7, single proposal
    prop7 = spec.propose(jnp.asarray(q_row)[None, None], rng0, rid, 7,
                         temperature=T)
    p_log = np.stack([p_row, rng.normal(size=V).astype(np.float32)])
    out_a, m_a = spec.verify(p_log, q_row[None], [prop7], rng0=rng0,
                             req_id=rid, pos0=7, temperature=T)
    # window starting at 5 whose first two positions accept (q == p
    # there), reaching position 7 at window index 2
    props = [spec.propose(jnp.asarray(shared[i])[None, None], rng0, rid,
                          5 + i, temperature=T) for i in range(2)]
    props.append(prop7)
    p_log_b = np.concatenate([shared, p_log], 0)
    q_log_b = np.stack([shared[0], shared[1], q_row])
    out_b, m_b = spec.verify(p_log_b, q_log_b, props, rng0=rng0,
                             req_id=rid, pos0=5, temperature=T)
    assert m_b >= 2, "q == p prefix must accept"
    assert out_b[2] == out_a[0], (out_a, out_b)
    assert m_b - 2 == m_a
    # and the dedicated draw streams never alias the proposal stream
    k0 = request_key(rng0, rid, 7)
    assert not np.array_equal(np.asarray(spec.accept_key(rng0, rid, 7)),
                              np.asarray(k0))
    assert not np.array_equal(np.asarray(spec.residual_key(rng0, rid, 7)),
                              np.asarray(k0))
    assert not np.array_equal(np.asarray(spec.accept_key(rng0, rid, 7)),
                              np.asarray(spec.residual_key(rng0, rid, 7)))


# --------------------------------------------------------------------------
# rollback property: allocator state restored bit-exactly
# --------------------------------------------------------------------------

_PREFILL_FRAGS = {}


def _frag(cfg, n):
    if n not in _PREFILL_FRAGS:
        params = _params(cfg, 0)
        _, frag = M.prefill(params, cfg, jnp.ones((1, n), jnp.int32),
                            max_len=64)
        _PREFILL_FRAGS[n] = frag
    return _PREFILL_FRAGS[n]


def _alloc_state(pkv, cache):
    return ([list(f) for f in pkv._free],
            {s: list(p) for s, p in pkv._slot_pages.items()},
            np.asarray(cache["page_table"]).tolist(),
            np.asarray(cache["cur_len"]).tolist())


if given is not None:
    @given(ps=st.sampled_from((4, 8, 16)),
           lens=st.lists(st.sampled_from((3, 9, 17)), min_size=1,
                         max_size=3),
           target=st.integers(0, 2),
           d=st.integers(0, 9),
           j=st.integers(1, 10))
    def test_rollback_restores_allocator_bit_exactly(ps, lens, target,
                                                     d, j):
        """Twin-allocator property: allocator A admits slots, grows the
        target slot for a (d+1)-token verify window, then rolls back to
        keep j tokens; allocator B (identical admissions) only ever
        allocates for the j kept tokens.  Free lists (per shard, exact
        order), slot page lists, the device page table and ``cur_len``
        must match bit-exactly — including windows and keeps that
        straddle page boundaries, which hypothesis hits for every
        page size here."""
        cfg = _tcfg()
        target %= len(lens)
        L0 = lens[target]
        d = min(d, 64 - 1 - L0)
        j = min(j, d + 1)
        new_len = L0 + j
        pkvs, caches = [], []
        for _ in range(2):
            pkv = PagedKVCache(cfg, 4, 64, dtype=jnp.float32, page_size=ps,
                               n_pages=40)
            cache = pkv.init_cache()
            for s, n in enumerate(lens):
                cache = pkv.admit(cache, s, _frag(cfg, n), n)
            pkvs.append(pkv)
            caches.append(cache)
        (A, B), (ca, cb) = pkvs, caches
        # A: grow for the window, emulate the verify's cur_len advance,
        # then reject down to j kept tokens
        ca = A.ensure(ca, target, L0 + d)
        ca = dict(ca)
        ca["cur_len"] = ca["cur_len"].at[target].set(L0 + d + 1)
        ca = A.rollback(ca, target, new_len)
        # B: the counterfactual that only ever appended j tokens
        cb = B.ensure(cb, target, new_len - 1)
        cb = dict(cb)
        cb["cur_len"] = cb["cur_len"].at[target].set(new_len)
        assert _alloc_state(A, ca) == _alloc_state(B, cb)
        # rolling back pages below the admission floor is refused
        # implicitly: a second rollback to the same length is a no-op
        ca2 = A.rollback(ca, target, new_len)
        assert _alloc_state(A, ca2) == _alloc_state(B, cb)


# --------------------------------------------------------------------------
# sharded variant (slow tier-2)
# --------------------------------------------------------------------------

@pytest.mark.slow
def test_spec_sharded_data_mesh_bit_identical():
    """Acceptance: greedy speculative decoding on a 2-device data mesh
    (sharded page pool, monolithic draft cache under GSPMD) emits the
    same tokens as the target-only engine on the same mesh and as the
    single-device run."""
    run_subprocess("""
        import numpy as np, jax
        from jax.sharding import Mesh
        from repro.configs import get, smoke_variant
        from repro.models import model as M
        from repro.serving import EngineConfig, GenerationEngine, Request

        cfg = smoke_variant(get('qwen3-8b'))
        dcfg = smoke_variant(get('xlstm-350m'))
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        dparams = M.init_params(jax.random.PRNGKey(1), dcfg)

        def stream():
            return [Request(prompt=[i + 1] * (4 + 2 * i),
                            max_new_tokens=6 + i, id=43_000 + i)
                    for i in range(4)]

        def serve(mesh, **kw):
            eng = GenerationEngine(params, cfg, config=EngineConfig(max_batch=2, max_len=64,
                                   mesh=mesh, **kw))
            reqs = stream()
            for r in reqs:
                eng.submit(r)
            eng.run()
            assert all(r.done for r in reqs)
            return [r.out_tokens for r in reqs], eng

        single, _ = serve(None)
        mesh = Mesh(np.array(jax.devices()[:2]), ('data',))
        base, _ = serve(mesh)
        spec_t, eng = serve(mesh, draft_params=dparams, draft_cfg=dcfg,
                            spec_k=4)
        assert eng.spec_on and eng.paged.n_shards == 2
        assert base == single, 'mesh target-only deviated'
        assert spec_t == base, 'mesh speculative deviated'
        sc = eng.spec_counters()
        assert sc['spec_rounds'] > 0
        print('sharded speculative == target-only == single-device: OK')
    """, devices=2)
