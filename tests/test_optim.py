"""AdamW / schedules / clipping unit tests."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.optim.adamw import AdamWConfig, adamw, adamw_init, \
    clip_by_global_norm
from repro.optim.schedules import cosine_schedule, linear_warmup


def test_adamw_matches_scalar_reference():
    """One param, no decay/clip: compare against a hand-rolled Adam step."""
    cfg = AdamWConfig(lr=0.1, b1=0.9, b2=0.99, eps=1e-8, weight_decay=0.0,
                      clip_norm=0.0)
    p = {"w": jnp.asarray([2.0, -3.0])}
    g = {"w": jnp.asarray([0.5, -1.0])}
    st = adamw_init(p)
    p1, st1, _ = adamw(p, g, st, cfg)
    m = 0.1 * np.asarray(g["w"])
    v = 0.01 * np.asarray(g["w"]) ** 2
    step = (m / (1 - 0.9)) / (np.sqrt(v / (1 - 0.99)) + 1e-8)
    np.testing.assert_allclose(np.asarray(p1["w"]),
                               np.asarray(p["w"]) - 0.1 * step, rtol=1e-6)
    assert int(st1["count"]) == 1


def test_weight_decay_skips_norms():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.5, clip_norm=0.0)
    p = {"w": jnp.ones((2,)), "norm1": jnp.ones((2,))}
    g = {"w": jnp.zeros((2,)), "norm1": jnp.zeros((2,))}
    st = adamw_init(p)
    p1, _, _ = adamw(p, g, st, cfg)
    # zero grad: decayed params move, no-decay params don't
    assert float(p1["w"][0]) < 1.0
    assert float(p1["norm1"][0]) == 1.0


def test_clip_by_global_norm():
    g = {"a": jnp.asarray([3.0]), "b": jnp.asarray([4.0])}
    clipped, norm = clip_by_global_norm(g, 1.0)
    np.testing.assert_allclose(float(norm), 5.0)
    total = np.sqrt(sum(float(jnp.sum(jnp.square(x)))
                        for x in jax.tree_util.tree_leaves(clipped)))
    np.testing.assert_allclose(total, 1.0, rtol=1e-6)
    # under the threshold: untouched
    same, _ = clip_by_global_norm(g, 10.0)
    np.testing.assert_allclose(np.asarray(same["a"]), np.asarray(g["a"]))


def test_schedules():
    s = jnp.asarray
    np.testing.assert_allclose(
        float(linear_warmup(s(5), 10, 1.0)), 0.5)
    np.testing.assert_allclose(
        float(cosine_schedule(s(10), 10, 110, 2.0)), 2.0)
    np.testing.assert_allclose(
        float(cosine_schedule(s(110), 10, 110, 2.0, floor=0.1)), 0.1,
        atol=1e-6)
    mid = float(cosine_schedule(s(60), 10, 110, 2.0, floor=0.0))
    np.testing.assert_allclose(mid, 1.0, atol=1e-6)


def test_optimizer_state_is_param_shaped():
    p = {"layer": {"w": jnp.zeros((3, 4)), "b": jnp.zeros((4,))}}
    st = adamw_init(p)
    assert st["mu"]["layer"]["w"].shape == (3, 4)
    assert st["nu"]["layer"]["b"].shape == (4,)
    assert st["mu"]["layer"]["w"].dtype == jnp.float32
