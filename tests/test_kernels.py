"""Pallas kernel validation (interpret mode on CPU) against pure oracles.

Per the deliverable: sweep shapes/dtypes/code distributions and
assert_allclose (bit-equality for decode; fp tolerance for the fused GEMM)
against the ref.py oracles.
"""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import stats, tpu_format
from repro.kernels import ops, ref
from repro.kernels.fused_decode_matmul import encode_tiled, matmul_pallas


@pytest.mark.parametrize("n_elem", [128 * 32, 128 * 32 * 3 + 5, 100_000])
@pytest.mark.parametrize("alpha", [1.2, 1.9])
@pytest.mark.parametrize("spl", [32, 64])
def test_decode_kernel_matches_oracle(n_elem, alpha, spl):
    bits = stats.synthesize_fp8_weights((n_elem,), alpha=alpha,
                                        seed=n_elem % 97)
    c = tpu_format.encode(bits, sym_per_lane=spl)
    got = ops.decode_tpu_format(c)
    np.testing.assert_array_equal(got, bits.reshape(-1))


def test_decode_kernel_degenerate_codebooks():
    # single-symbol codebook (1-bit codes) and near-uniform (4-bit codes)
    for bits in [np.full(128 * 64, 0b0_0111_010, np.uint8),
                 (np.arange(128 * 64) * 11 % 256).astype(np.uint8)]:
        c = tpu_format.encode(bits, sym_per_lane=32)
        np.testing.assert_array_equal(ops.decode_tpu_format(c), bits)


@pytest.mark.parametrize("M,K,N", [(8, 64, 128), (16, 128, 256)])
@pytest.mark.parametrize("alpha", [1.9])
def test_fused_decode_matmul_matches_ref(M, K, N, alpha):
    S = 32
    w_bits = stats.synthesize_fp8_weights((K, N), alpha=alpha, seed=K + N)
    tiled = encode_tiled(w_bits, sym_per_lane=S)
    x = np.random.default_rng(0).normal(size=(M, K)).astype(np.float32) * 0.1
    got = matmul_pallas(jnp.asarray(x), tiled, interpret=True)
    want = ref.fused_decode_matmul_ref(x, w_bits)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=5e-2, atol=5e-2)


def test_fused_decode_matmul_bitexact_weight_path():
    """The decoded weight inside the fused kernel is bit-exact: compare a
    matmul against an identity input which reads the weight out directly."""
    K, N, S = 64, 128, 32
    w_bits = stats.synthesize_fp8_weights((K, N), alpha=1.9, seed=5)
    tiled = encode_tiled(w_bits, sym_per_lane=S)
    eye = np.eye(K, dtype=np.float32)
    got = np.asarray(matmul_pallas(jnp.asarray(eye), tiled, interpret=True))
    want = np.asarray(
        jnp.asarray(w_bits).view(jnp.float8_e4m3fn).astype(jnp.bfloat16)
        .astype(jnp.float32))
    np.testing.assert_array_equal(got, want)
