"""Sharding rules + distributed execution on a small virtual mesh.

Multi-device tests run in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` so the main pytest
process keeps seeing 1 device (the dry-run-only requirement).
"""
import pytest
import jax
from jax.sharding import PartitionSpec as P

from conftest import run_subprocess as _run_subprocess

from repro.configs import get, smoke_variant
from repro.runtime import sharding as SH
from repro.runtime.steps import param_specs


def _mesh16():
    from jax.sharding import AbstractMesh
    try:
        return AbstractMesh((16, 16), ("data", "model"))
    except TypeError:
        # older jax: AbstractMesh(shape_tuple) with (name, size) pairs
        return AbstractMesh((("data", 16), ("model", 16)))


def test_param_rules_structure():
    """Rules put TP on the right axes and never shard indivisible dims."""
    mesh = _mesh16()
    cfg = get("granite-20b")
    sds = param_specs(cfg)
    specs = SH.param_pspecs(cfg, sds, mesh)
    flat = jax.tree_util.tree_flatten_with_path(specs)[0]
    by_name = {}
    for path, spec in flat:
        name = str(getattr(path[-1], "key", path[-1]))
        by_name.setdefault(name, spec)
    assert by_name["embed"] == P("model", "data")
    assert by_name["wq"] == P(None, "data", "model")  # stacked under units
    assert by_name["wo"] == P(None, "model", "data")
    assert by_name["norm1"] == P(None, None)          # replicated


def test_moe_expert_rules():
    mesh = _mesh16()
    cfg = get("moonshot-v1-16b-a3b")
    specs = SH.param_pspecs(cfg, param_specs(cfg), mesh)
    moe = specs["units"]["pos0"]["moe"]
    assert moe["wi_gate"] == P(None, "model", "data", None)   # EP + FSDP
    assert moe["wo"] == P(None, "model", None, "data")
    assert moe["gate"] == P(None, "data", None)


def test_rules_drop_indivisible_axes():
    spec = SH._fit(_mesh16(), ("data", "model"), (7, 13))
    assert spec == P(None, None)  # 7 and 13 don't divide 16 -> replicate
    spec = SH._fit(_mesh16(), ("data", "model"), (32, 48))
    assert spec == P("data", "model")


def test_cache_rules_seq_sharded():
    mesh = _mesh16()
    from repro.runtime.steps import cache_specs
    cfg = get("granite-20b")  # self-attn caches shard the sequence dim
    c = cache_specs(cfg, 128, 64)
    specs = SH.cache_pspecs(cfg, c, mesh)
    assert specs["units"]["pos0"]["k"] == P(None, "data", None, "model",
                                            None)
    # indivisible seq (whisper cross, 1500 frames) falls back to heads
    cfg2 = get("whisper-base")
    c2 = cache_specs(cfg2, 128, 64)
    specs2 = SH.cache_pspecs(cfg2, c2, mesh)
    assert specs2["units"]["cross"]["k"][3] != "model"


@pytest.mark.slow
def test_sharded_train_step_matches_single_device():
    """The same train step on a (2, 4) mesh and on 1 device must agree —
    the distribution layer must not change the math."""
    _run_subprocess("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get, smoke_variant
        from repro.models import model as M
        from repro.optim.adamw import AdamWConfig, adamw_init
        from repro.runtime import sharding as SH
        from repro.runtime.steps import make_train_step
        from repro.data import DataConfig, SyntheticLMData

        cfg = smoke_variant(get('phi3-medium-14b'))
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        opt = adamw_init(params)
        data = SyntheticLMData(DataConfig(vocab_size=cfg.vocab_size,
                                          seq_len=16, global_batch=4))
        batch = data.batch(0)
        step0 = jnp.zeros((), jnp.int32)

        # single-device reference
        ref_step = make_train_step(cfg, AdamWConfig(lr=1e-3), remat=False)
        p_ref, _, m_ref = jax.jit(ref_step)(params, opt, batch, step0)

        mesh = jax.make_mesh((2, 4), ('data', 'model'))
        rules = SH.ShardingRules(activation_partitioning='seq')
        p_spec = SH.param_pspecs(cfg, params, mesh, rules)
        p_sh = SH.named(mesh, p_spec)
        o_sh = SH.named(mesh, SH.opt_pspecs(p_spec))
        b_sh = {k: NamedSharding(mesh, P('data', None))
                for k in batch}
        params_s = jax.device_put(params, p_sh)
        opt_s = jax.device_put(opt, o_sh)
        batch_s = {k: jax.device_put(v, b_sh[k]) for k, v in batch.items()}
        step = make_train_step(cfg, AdamWConfig(lr=1e-3), mesh=mesh,
                               rules=rules, remat=False)
        with mesh:
            p_new, o_new, m = jax.jit(
                step, in_shardings=(p_sh, o_sh, b_sh, None),
                out_shardings=(p_sh, o_sh, None))(
                params_s, opt_s, batch_s, step0)
        print('loss single', float(m_ref['loss']), 'sharded',
              float(m['loss']))
        np.testing.assert_allclose(float(m['loss']), float(m_ref['loss']),
                                   rtol=2e-4)
        for (pa, a), (pb, b) in zip(
                jax.tree_util.tree_flatten_with_path(p_ref)[0],
                jax.tree_util.tree_flatten_with_path(p_new)[0]):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=2e-4, err_msg=str(pa))
        print('sharded == single: OK')
    """)


@pytest.mark.slow
def test_sharded_moe_ep_matches_single_device():
    """Expert-parallel MoE (all_to_all path) vs single-device routing."""
    _run_subprocess("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get, smoke_variant
        from repro.models import moe as MOE
        cfg = smoke_variant(get('moonshot-v1-16b-a3b'))
        p = MOE.moe_init(jax.random.PRNGKey(0), cfg.d_model, cfg.n_experts,
                         cfg.moe_d_ff, 0, cfg.moe_d_ff, cfg.top_k)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, cfg.d_model)) * .3
        y_ref, aux_ref = MOE.moe_apply(p, x, cfg, mesh=None,
                                       dtype=jnp.float32)
        mesh = jax.make_mesh((2, 4), ('data', 'model'))
        with mesh:
            y, aux = jax.jit(lambda p, x: MOE.moe_apply(
                p, x, cfg, mesh=mesh, dtype=jnp.float32))(p, x)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   atol=2e-4)
        print('EP MoE == local MoE: OK')
    """)


@pytest.mark.slow
def test_compressed_all_gather_bit_exact():
    """ECF8-FR compressed weight all-gather returns the exact bytes."""
    _run_subprocess("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import stats
        from repro.runtime.collectives import calibrate, compressed_all_gather
        mesh = jax.make_mesh((8,), ('data',))
        bits = stats.synthesize_fp8_weights((1024, 64), alpha=1.8, seed=0)
        table, cap = calibrate(bits, margin=1.3)
        # per-shard capacity: shards see 1/8 of the escapes, margin covers skew
        cap_shard = max(2, int(np.ceil(cap / 8 * 1.5)));
        cap_shard += cap_shard % 2
        gather = compressed_all_gather(mesh, 'data')
        with mesh:
            out, overflow = jax.jit(
                lambda w: gather(w, jnp.asarray(table), cap_shard))(
                jnp.asarray(bits))
        assert not bool(overflow), 'escape overflow'
        np.testing.assert_array_equal(np.asarray(out), bits)
        print('compressed all-gather bit-exact: OK')
    """)


@pytest.mark.slow
def test_sharded_decode_matches_single_device():
    """Seq-sharded cache decode (stat merge) vs the plain decode path."""
    _run_subprocess("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get, smoke_variant
        from repro.models import model as M
        from repro.runtime import sharding as SH
        from repro.runtime.steps import cache_specs
        cfg = smoke_variant(get('gemma2-9b'))   # local+global, softcaps
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        toks = jax.random.randint(jax.random.PRNGKey(1), (4, 8), 0,
                                  cfg.vocab_size)
        logits, cache = M.prefill(params, cfg, toks, max_len=16)
        nxt = jnp.full((4, 1), 5, jnp.int32)
        ref, _ = M.decode_step(params, cfg, nxt, cache)

        mesh = jax.make_mesh((2, 4), ('data', 'model'))
        c_spec = SH.named(mesh, SH.cache_pspecs(cfg, cache, mesh))
        cache_s = jax.device_put(cache, c_spec)
        with mesh:
            got, new_cache = jax.jit(lambda p, t, c: M.decode_step(
                p, cfg, t, c, mesh=mesh))(params, nxt, cache_s)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   atol=3e-4)
        # continue one more step to prove the updated cache is coherent
        ref2, _ = M.decode_step(params, cfg, nxt + 1,
                                M.decode_step(params, cfg, nxt, cache)[1])
        with mesh:
            got2, _ = jax.jit(lambda p, t, c: M.decode_step(
                p, cfg, t, c, mesh=mesh))(params, nxt + 1, new_cache)
        np.testing.assert_allclose(np.asarray(got2), np.asarray(ref2),
                                   atol=3e-4)
        print('sharded decode == single-device decode: OK')
    """)


@pytest.mark.slow
def test_dryrun_single_cell_small_mesh():
    """The dry-run driver itself works end-to-end (8 virtual devices would
    not divide the production mesh, so run the real 512-device config on the
    smallest arch x shape)."""
    _run_subprocess("""
        from repro.launch.dryrun import lower_cell
        art = lower_cell('whisper-base', 'decode_32k', 'multi')
        assert not art.get('skipped') and 'error' not in art, art
        assert art['collectives']['total'] > 0
        assert art['cost_analysis']['flops'] > 0
        print('multi-pod lower+compile OK:', art['roofline']['dominant'])
    """, devices=512)
