"""Sampler properties (ISSUE 3): greedy limit, mask semantics, determinism.

``filter_logits`` is the testable masking stage: it must *never*
renormalize over excluded logits — survivors keep their original values
(the final softmax renormalizes implicitly over the support), the greedy
token always survives, and top-k / top-p select exactly the documented
sets.  ``sample_logits`` must be exact greedy at ``temperature <= 0`` and
bit-deterministic for a fixed key, jitted or not.
"""
import functools

import numpy as np
import jax
import jax.numpy as jnp

from repro.serving.sampler import filter_logits, greedy, sample_logits

try:
    from hypothesis import given, strategies as st
except ImportError:
    given = None


def _rand_logits(seed, B=2, V=17):
    rng = np.random.default_rng(seed)
    # distinct values: tie-free argmax/cutoffs keep assertions exact
    x = rng.permutation(B * V).astype(np.float32).reshape(B, V)
    return jnp.asarray(x + rng.uniform(0, 0.25, (B, V)).astype(np.float32))


def test_temperature_zero_is_exact_greedy():
    logits = _rand_logits(0)[:, None, :]
    want = greedy(logits)
    for t in (0.0, -1.0):
        got = sample_logits(logits, jax.random.PRNGKey(3), temperature=t)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # the limit t -> 0+ agrees with greedy too (mass collapses to argmax)
    got = sample_logits(logits, jax.random.PRNGKey(3), temperature=1e-5)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_top_k_mask_keeps_original_logits():
    """top-k keeps exactly k survivors, each with its *original* value —
    masking never renormalizes or shifts the included logits."""
    x = _rand_logits(1)
    for k in (1, 3, x.shape[-1]):
        m = np.asarray(filter_logits(x, top_k=k))
        xs = np.asarray(x)
        for b in range(x.shape[0]):
            kept = np.isfinite(m[b])
            assert kept.sum() == k
            np.testing.assert_array_equal(m[b][kept], xs[b][kept])
            assert np.all(m[b][~kept] == -np.inf)
            # survivors are precisely the k largest
            assert set(np.flatnonzero(kept)) == set(
                np.argsort(xs[b])[-k:])


def test_top_p_mask_is_smallest_covering_set_unrenormalized():
    """top-p keeps the smallest set with softmax mass >= p; survivors
    keep their original values, so renormalization happens only in the
    downstream softmax over the support (never over excluded logits)."""
    x = _rand_logits(2)
    xs = np.asarray(x, np.float64)
    for p in (0.1, 0.5, 0.9):
        m = np.asarray(filter_logits(x, top_p=p))
        for b in range(x.shape[0]):
            kept = np.isfinite(m[b])
            np.testing.assert_array_equal(m[b][kept],
                                          np.asarray(x)[b][kept])
            probs = np.exp(xs[b] - xs[b].max())
            probs /= probs.sum()
            order = np.argsort(-probs)
            mass = np.cumsum(probs[order])
            n_min = int(np.searchsorted(mass, p) + 1)   # smallest covering
            assert set(np.flatnonzero(kept)) == set(order[:n_min])
            # the greedy token always survives
            assert kept[np.argmax(xs[b])]
            # dropping the weakest survivor would fall below p
            if n_min > 1:
                assert mass[n_min - 2] < p <= mass[n_min - 1] + 1e-12


def test_combined_masks_and_sampling_support():
    """Sampled tokens always come from the masked support."""
    x = _rand_logits(3, B=8, V=11)
    logits = x[:, None, :]
    m = np.asarray(filter_logits(x, top_k=4, top_p=0.8))
    support = [set(np.flatnonzero(np.isfinite(m[b]))) for b in range(8)]
    for s in range(20):
        tok = np.asarray(sample_logits(logits, jax.random.PRNGKey(s),
                                       temperature=1.0, top_k=4, top_p=0.8))
        for b in range(8):
            assert int(tok[b, 0]) in support[b], (b, s)


def test_fixed_seed_deterministic_across_jit():
    """A fixed key samples the same token eagerly, re-invoked, and under
    ``jax.jit`` — the engine's fold-in sampling relies on this."""
    logits = _rand_logits(4, B=4, V=29)[:, None, :]
    jitted = jax.jit(functools.partial(sample_logits, temperature=0.7,
                                       top_k=5, top_p=0.9))
    for seed in range(5):
        key = jax.random.PRNGKey(seed)
        eager1 = sample_logits(logits, key, temperature=0.7, top_k=5,
                               top_p=0.9)
        eager2 = sample_logits(logits, key, temperature=0.7, top_k=5,
                               top_p=0.9)
        jit1 = jitted(logits, key)
        np.testing.assert_array_equal(np.asarray(eager1), np.asarray(eager2))
        np.testing.assert_array_equal(np.asarray(eager1), np.asarray(jit1))


if given is not None:
    @given(st.integers(0, 2**31 - 1), st.integers(1, 16),
           st.floats(0.05, 0.999))
    def test_mask_invariants_hold_for_any_draw(seed, k, p):
        """Property: for any logits, k, p — survivors keep original
        values, the greedy token survives, and |top-k support| <= k."""
        x = _rand_logits(seed, B=3, V=16)
        xs = np.asarray(x)
        m = np.asarray(filter_logits(x, top_k=k, top_p=float(p)))
        for b in range(x.shape[0]):
            kept = np.isfinite(m[b])
            assert kept.sum() >= 1
            assert kept.sum() <= k
            assert kept[np.argmax(xs[b])]
            np.testing.assert_array_equal(m[b][kept], xs[b][kept])


def test_greedy_shape_and_dtype():
    logits = _rand_logits(5)[:, None, :]
    g = greedy(logits)
    assert g.shape == (2, 1) and g.dtype == jnp.int32


def test_request_key_invariant_across_spec_paths():
    """Key-invariance regression (ISSUE 7): the draw at absolute token
    position ``pos`` is a pure function of ``(rng0, request id, pos)``
    and is **the same draw** on every path that can emit that position —
    plain decode, a draft proposal (draft-accept path), and the bonus
    token after a fully accepted verify window.  The accept/residual
    streams are tagged fold-ins that never alias the proposal stream."""
    from repro.serving import spec
    from repro.serving.sampler import request_key

    rng0 = jax.random.PRNGKey(3)
    V, T, rid, pos = 13, 0.7, 42, 11
    logits = _rand_logits(7, B=1, V=V)[0]
    row = jnp.asarray(logits)[None, None, :]
    plain = int(sample_logits(row / T, request_key(rng0, rid, pos),
                              temperature=1.0)[0, 0])
    # draft proposal at the same position is the identical draw
    assert spec.propose(row, rng0, rid, pos, temperature=T) == plain
    # bonus draw of an empty verify window (k_eff == 0) is the plain step
    out, m = spec.verify(np.asarray(logits)[None],
                         np.zeros((0, V), np.float32), [],
                         rng0=rng0, req_id=rid, pos0=pos, temperature=T)
    assert m == 0 and out == [plain]
    # a self-agreeing draft accepts its proposal: the emitted token on
    # the draft-accept path is again the same plain-decode draw
    out, m = spec.verify(np.stack([logits, logits]),
                         np.asarray(logits)[None], [plain],
                         rng0=rng0, req_id=rid, pos0=pos, temperature=T)
    assert m == 1 and out[0] == plain
    # purity: recomputation is bit-identical; streams never alias
    k = np.asarray(request_key(rng0, rid, pos))
    np.testing.assert_array_equal(k, np.asarray(request_key(rng0, rid,
                                                            pos)))
    ka = np.asarray(spec.accept_key(rng0, rid, pos))
    kr = np.asarray(spec.residual_key(rng0, rid, pos))
    assert not np.array_equal(ka, k) and not np.array_equal(kr, k)
    assert not np.array_equal(ka, kr)
    # distinct (id, pos) give distinct base keys
    assert not np.array_equal(k, np.asarray(request_key(rng0, rid,
                                                        pos + 1)))
    assert not np.array_equal(k, np.asarray(request_key(rng0, rid + 1,
                                                        pos)))
