"""Faithfulness checks of the paper's own container (§3.1 / Algorithm 1)."""
import numpy as np
import pytest

from repro.core import fp8, paper_format, stats
from repro.core.huffman import Codebook


def test_lut_cascade_structure():
    """Cascaded 8-bit LUTs: entries <16 decode, >=240 point to subtables."""
    # force long codes: extremely skewed distribution over many symbols
    freqs = np.asarray([2 ** max(0, 14 - i) for i in range(16)])
    cb = Codebook.from_freqs(freqs, max_len=16)
    lut = paper_format.build_cascaded_lut(cb)
    assert lut.shape[1] == 256
    # the length table is the last LUT
    np.testing.assert_array_equal(lut[-1, :16], cb.lengths[:16])
    if lut.shape[0] > 2:  # pointers exist
        assert (lut[0] >= paper_format.LUT_POINTER_BASE).any()


def test_lut_decode_matches_codebook():
    freqs = np.asarray([3, 1000, 500, 7, 90, 0, 2, 44, 800, 1, 0, 0, 60, 5,
                        10, 9])
    cb = Codebook.from_freqs(freqs, max_len=16)
    lut = paper_format.build_cascaded_lut(cb)
    rng = np.random.default_rng(0)
    syms = rng.choice(np.nonzero(freqs)[0], 500, p=freqs[freqs > 0]
                      / freqs.sum())
    enc, nbits = cb.encode_symbols(syms)
    pos = 0
    for want in syms:
        got, l, pos = paper_format._decode_with_lut(enc, lut, pos)
        assert got == want
    assert pos == nbits


def test_gaps_fit_four_bits():
    """The paper packs gaps in 4 bits; max code length 16 and 8-byte thread
    windows keep every gap < 16 (paper §3.1) — verify on skewed data."""
    bits = stats.synthesize_fp8_weights((40_000,), alpha=1.2, seed=2)
    c = paper_format.encode(bits)
    gaps = np.asarray(fp8.unpack_nibbles(c.gaps, len(c.gaps) * 2, xp=np))
    assert gaps.max() <= 15


def test_outpos_monotone_and_complete():
    bits = stats.synthesize_fp8_weights((30_000,), alpha=1.9, seed=3)
    c = paper_format.encode(bits)
    outpos = np.asarray(c.outpos)
    assert (np.diff(outpos) >= 0).all()
    assert outpos[0] == 0 and outpos[-1] == c.n_elem


def test_compressed_footprint_accounting():
    bits = stats.synthesize_fp8_weights((64, 1024), alpha=1.9, seed=4)
    c = paper_format.encode(bits)
    assert c.n_bytes_total == (c.encoded.nbytes + c.packed.nbytes
                               + c.lut.nbytes + c.gaps.nbytes
                               + c.outpos.nbytes)
    assert c.ratio < 1.0  # actually compresses trained-like weights


@pytest.mark.parametrize("n", [1, 2, 127, 128, 1025])
def test_tiny_tensors(n):
    rng = np.random.default_rng(n)
    bits = rng.integers(0, 256, n).astype(np.uint8)
    c = paper_format.encode(bits)
    np.testing.assert_array_equal(paper_format.decode_blockparallel(c), bits)
