"""Serving telemetry subsystem (ISSUE 5): metrics registry accuracy,
span-tracer invariants, Chrome-trace export schema, and the zero-
behavior-change guarantee.

The load-bearing test is the differential: the tier-1 serving anchor
workload must emit **bit-identical** tokens with telemetry fully on
(registry + tracer) vs off — telemetry is host-side observation only.
The export round-trip runs the oversubscribed swap/preemption workload
and checks the trace carries at least one preempt/resume pair plus the
evict/fault engine spans (the ISSUE acceptance trace).
"""
import importlib.util
import json
import math
import os

import numpy as np
import jax
import pytest

from repro.configs import get, smoke_variant
from repro.models import model as M
from repro.runtime.monitor import KVCacheMonitor, StragglerMonitor
from repro.runtime.tracing import (ENGINE_TRACK, RequestStateTracker,
                                   SpanTracer, request_track)
from repro.runtime.trace_export import (build_trace, export_chrome_trace,
                                        validate_chrome_trace)
from repro.serving import EngineConfig, GenerationEngine, Request
from repro.serving.telemetry import (Counter, Gauge, Histogram,
                                     MetricsRegistry, Telemetry,
                                     geometric_edges, linear_edges,
                                     serving_report_line)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# --------------------------------------------------------------------------
# metrics registry
# --------------------------------------------------------------------------

def test_histogram_percentiles_match_numpy_linear_buckets():
    """With buckets much finer than the sample spacing, interpolated
    percentiles track numpy's to within a couple of bucket widths."""
    rng = np.random.default_rng(7)
    xs = rng.uniform(0.0, 1.0, size=2_000)
    h = Histogram("t", edges=linear_edges(0.0, 1.0, 500))
    for x in xs:
        h.observe(float(x))
    for q in (0.5, 0.95, 0.99):
        assert h.percentile(q) == pytest.approx(
            float(np.quantile(xs, q)), abs=3 * (1.0 / 500)), q
    assert h.mean == pytest.approx(float(xs.mean()), rel=1e-9)
    assert h.min == xs.min() and h.max == xs.max()
    assert h.count == len(xs)


def test_histogram_percentiles_geometric_default_relative_error():
    """The default serving buckets (geometric, factor 1.2) keep the
    quantile estimate within the documented ~20% relative error."""
    rng = np.random.default_rng(3)
    xs = np.exp(rng.normal(-4.0, 1.0, size=5_000))     # lognormal seconds
    h = Histogram("t")                                  # default edges
    for x in xs:
        h.observe(float(x))
    for q in (0.5, 0.95, 0.99):
        ref = float(np.quantile(xs, q))
        assert abs(h.percentile(q) - ref) / ref < 0.25, q


def test_histogram_edge_cases():
    h = Histogram("t", edges=[1.0, 2.0])
    assert math.isnan(h.percentile(0.5)) and math.isnan(h.mean)
    h.observe(5.0)                       # overflow bucket, single sample
    assert h.percentile(0.5) == 5.0 == h.percentile(0.99)
    h.observe(0.25)                      # underflow bucket
    assert h.percentile(0.0) >= h.min
    assert h.min <= h.percentile(0.5) <= h.max
    with pytest.raises(ValueError):
        Histogram("bad", edges=[2.0, 1.0])
    assert geometric_edges(1e-5, 60.0)[0] == 1e-5


def test_registry_get_or_create_and_kind_conflicts():
    reg = MetricsRegistry()
    c = reg.counter("a_total", unit="tok")
    c.inc(3)
    assert reg.counter("a_total") is c and reg.value("a_total") == 3
    g = reg.gauge("depth")
    g.set(5.0)
    g.set(2.0)
    assert g.value == 2.0 and g.peak == 5.0
    with pytest.raises(TypeError):
        reg.gauge("a_total")             # name bound to a counter
    assert "a_total" in reg and reg.get("missing") is None
    snap = reg.snapshot()
    assert snap["a_total"]["value"] == 3
    assert snap["depth"]["peak"] == 5.0
    json.dumps(snap)                     # JSON-safe by contract
    assert serving_report_line(reg).startswith("tok=")


# --------------------------------------------------------------------------
# span tracer
# --------------------------------------------------------------------------

def test_tracer_bounded_buffer_drops_instead_of_growing():
    tr = SpanTracer(capacity=4)
    for i in range(10):
        tr.instant("engine", f"e{i}")
    assert len(tr) == 4 and tr.n_dropped == 6
    trace = build_trace(tr)
    assert trace["otherData"]["n_dropped_events"] == 6


def test_request_state_tracker_invariants():
    """State spans on one request track are back-to-back (never
    overlapping) and every open state closes on finish."""
    t = [0.0]
    tr = SpanTracer(clock=lambda: t[0])
    rs = RequestStateTracker(tr)
    for rid in (1, 2):
        rs.transition(rid, "queued")
    t[0] = 1.0
    rs.transition(1, "prefilling")
    t[0] = 2.0
    rs.transition(1, "decoding")
    assert rs.open_states == {1: "decoding", 2: "queued"}
    t[0] = 3.0
    rs.finish(1)
    rs.finish(2)
    assert rs.open_states == {}
    spans = [(name, track, ts, dur) for ph, cat, name, track, ts, dur, _
             in tr.events if ph == "X"]
    per_track: dict = {}
    for name, track, ts, dur in spans:
        per_track.setdefault(track, []).append((ts, ts + dur, name))
    for track, ivs in per_track.items():
        ivs.sort()
        for (s0, e0, _), (s1, _, _) in zip(ivs, ivs[1:]):
            assert s1 >= e0, (track, ivs)       # no overlap
    assert [n for _, _, n in sorted(per_track[request_track(1)])] == \
        ["queued", "prefilling", "decoding"]


def test_tracer_span_context_manager_and_counters():
    tr = SpanTracer()
    with tr.span("engine", "decode_step", args={"step": 1}):
        pass
    tr.counter("serving_queue_depth", 3)
    (ph, cat, name, track, ts, dur, args) = tr.events[0]
    assert (ph, cat, name, track) == ("X", "engine", "decode_step",
                                      ENGINE_TRACK)
    assert dur >= 0 and args == {"step": 1}
    assert tr.events[1][0] == "C" and tr.events[1][6] == 3.0


# --------------------------------------------------------------------------
# monitors (satellite fixes)
# --------------------------------------------------------------------------

def test_straggler_monitor_zero_first_sample_seeds_ewma():
    """A legitimate 0.0-second first sample must seed the EWMA (the old
    ``_ewma = 0.0`` sentinel treated it as uninitialized and let the
    next sample overwrite it wholesale)."""
    m = StragglerMonitor(ewma_alpha=0.05)
    assert m.ewma_seconds == 0.0         # no samples yet
    m.observe(0.0, step=0)
    m.observe(1.0, step=1)
    assert m.ewma_seconds == pytest.approx(0.05)    # not 1.0
    # outlier detection still works through observe()
    for i in range(20):
        m.observe(0.01, step=i + 2)
    stats = m.observe(10.0, step=99)
    assert stats.is_straggler and m.alarms[-1].step == 99


def test_kvcache_monitor_mixed_engines_no_keyerror():
    """One monitor shared across engines with different capabilities
    (with/without swap tier, with/without chunked prefill) summarizes
    what it saw instead of raising KeyError."""
    mon = KVCacheMonitor()
    mon.record({"pages_in_use": 4, "cold_pages_in_use": 1,
                "page_bytes": 100, "cache_bytes_paged": 500,
                "cache_bytes_raw_equiv": 600, "monolithic_bytes": 1000,
                "cold_bytes_ragged": 60,
                "pages_in_use_per_shard": [3, 1]})
    s = mon.summary()                    # no swap keys ever recorded
    assert s["steps"] == 1 and "peak_swap_bytes" not in s
    assert s["peak_pages_in_use"] == 5
    mon.record({"pages_in_use": 2, "swap_bytes_used": 7,
                "swap_out_bytes_total": 7, "swap_in_bytes_total": 0,
                "n_preempted": 1, "n_resumed": 0,
                "pages_in_use_per_shard": [1, 4]})
    s = mon.summary()                    # swap section appears, defaulted
    assert s["peak_swap_bytes"] == 7 and s["n_preempted"] == 1
    assert mon.peak_per_shard() == [3, 4]
    assert mon.n_samples == 2
    assert KVCacheMonitor().summary() == {}     # empty monitor


# --------------------------------------------------------------------------
# engine integration: bit-identity, compile counters, export round-trip
# --------------------------------------------------------------------------

def _anchor_requests():
    return [Request(prompt=[1, 2, 3, 4], max_new_tokens=5, id=9_100),
            Request(prompt=[5, 6, 7], max_new_tokens=6, id=9_101),
            Request(prompt=[9, 10], max_new_tokens=4, id=9_102),
            Request(prompt=[11, 12, 13], max_new_tokens=4, id=9_103)]


def _serve(params, cfg, reqs, **kw):
    eng = GenerationEngine(params, cfg, config=EngineConfig(max_batch=2, max_len=48, **kw))
    for r in reqs:
        eng.submit(r)
    eng.run()
    assert all(r.done for r in reqs)
    return [r.out_tokens for r in reqs], eng


def test_telemetry_on_off_bit_identical():
    """The tier-1 serving anchor emits the same tokens with telemetry
    fully on (registry + tracer) as with it off."""
    cfg = smoke_variant(get("qwen3-8b"))
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    kw = dict(cache_mode="paged", prefill_chunk=4)
    bare, _ = _serve(params, cfg, _anchor_requests(), **kw)
    tel = Telemetry()
    instr, eng = _serve(params, cfg, _anchor_requests(), telemetry=tel,
                        **kw)
    assert instr == bare

    reg = tel.registry
    assert reg.value("serving_requests_submitted_total") == 4
    assert reg.value("serving_requests_finished_total") == 4
    assert reg.value("serving_tokens_generated_total") == \
        sum(len(t) for t in bare)
    ttft = reg.get("serving_ttft_seconds")
    assert ttft.count == 4 and ttft.min > 0
    assert reg.get("serving_request_latency_seconds").count == 4
    assert reg.get("serving_decode_step_seconds").count == eng.steps
    # compile counters exist and are deltas vs engine construction
    # (the jit caches are process-shared, so the absolute value depends
    # on what compiled before — it must only never go negative)
    assert reg.value("serving_decode_compile_total") >= 0
    assert reg.value("serving_prefill_compile_total") >= 0
    assert eng.decode_compile_count() >= 1       # process-wide cache
    # every request's state spans closed on drain
    assert tel.requests.open_states == {}
    assert serving_report_line(reg)              # heartbeat renders


def test_oversubscribed_trace_export_round_trip(tmp_path):
    """ISSUE acceptance: an oversubscribed run exports a valid
    Chrome-trace with lifecycle spans incl. >= 1 preempt/resume pair."""
    from test_serving import _OVERSUB, _oversub_requests
    cfg = smoke_variant(get("qwen3-8b"))
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    tel = Telemetry()
    _, eng = _serve(params, cfg, _oversub_requests(id_base=9_200),
                    telemetry=tel, **_OVERSUB)
    assert eng.scheduler.n_preempted > 0

    path = tmp_path / "trace.json"
    trace = export_chrome_trace(tel.tracer, str(path), tel.registry)
    assert validate_chrome_trace(trace) == []
    loaded = json.loads(path.read_text())
    assert validate_chrome_trace(loaded) == []
    assert loaded == trace

    evs = loaded["traceEvents"]
    names = {e["name"] for e in evs}
    # the acceptance spans: request preempt/resume pair + swap movement
    assert {"preempted", "resume", "preempt", "evict", "fault",
            "decode_step", "finished"} <= names
    cats = {e.get("cat") for e in evs if e["ph"] == "X"}
    assert {"engine", "swap", "request"} <= cats
    # request rows: pid 2, thread-named, one per submitted request
    req_tids = {e["tid"] for e in evs if e["pid"] == 2 and e["ph"] != "M"}
    assert req_tids == {9_200 + i for i in range(
        len(_oversub_requests()))}
    thread_names = {e["args"]["name"] for e in evs
                    if e["ph"] == "M" and e["name"] == "thread_name"}
    assert "request 9200" in thread_names
    # counter tracks render as ph C with numeric args.value
    ctr = [e for e in evs if e["ph"] == "C"]
    assert {"serving_queue_depth", "kvcache_pages_in_use"} <= \
        {e["name"] for e in ctr}
    assert all(isinstance(e["args"]["value"], (int, float)) for e in ctr)
    # embedded registry snapshot travels with the trace
    metrics = loaded["otherData"]["metrics"]
    assert metrics["serving_preempted_total"]["value"] > 0
    assert metrics["serving_resumed_total"]["value"] > 0
    assert loaded["otherData"]["n_dropped_events"] == 0


def test_metrics_only_mode_keeps_no_event_buffer():
    cfg = smoke_variant(get("qwen3-8b"))
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    tel = Telemetry(trace=False)
    assert tel.tracer is None and tel.requests is None
    toks, _ = _serve(params, cfg, _anchor_requests(), telemetry=tel,
                     cache_mode="paged")
    assert tel.registry.get("serving_ttft_seconds").count == 4


# --------------------------------------------------------------------------
# docs lint (tools/check_metrics.py, same contract as the CI docs job)
# --------------------------------------------------------------------------

def _load_metrics_checker():
    spec = importlib.util.spec_from_file_location(
        "check_metrics", os.path.join(REPO, "tools", "check_metrics.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_every_emitted_metric_name_is_documented():
    chk = _load_metrics_checker()
    assert chk.check_metrics() == []
    names = chk.emitted_names()
    assert len(names) >= 20              # the subsystem is wired in
    assert "serving_ttft_seconds" in names
    assert "kvcache_evict_pages_total" in names
