"""Async serving front end + router (ISSUE 9).

Differential layer: token streams flushed by ``AsyncServingFrontend``
must be **bit-identical** to a synchronous ``GenerationEngine.run()`` of
the same requests — including under whole-request preemption (the
oversubscribed swap tier), under prefix sharing, and across 2 replicas
behind the least-loaded router.  Sampling keys fold
``(rng_seed, request.id, position)`` only, so admission timing, replica
choice and placement cannot change any token.

Tests run the driver with ``asyncio.run`` (no pytest-asyncio in the
image); when a test needs concurrent consumption it spawns
``frontend.run()`` as a background task inside one event loop.
"""
import asyncio

import pytest
import jax

from repro.configs import get, smoke_variant
from repro.models import model as M
from repro.runtime.monitor import KVCacheMonitor
from repro.serving import (AsyncServingFrontend, EngineConfig,
                           FrontendClosed, FrontendOverloaded,
                           GenerationEngine, Request, Router, Telemetry)

from benchmarks.load_replay import build_trace, replay


@pytest.fixture(scope="module")
def world():
    cfg = smoke_variant(get("qwen3-8b"))
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return params, cfg


def _requests(id_base=8_000, n=5):
    return [Request(prompt=[i + 1] * (3 + i % 4), max_new_tokens=4 + i % 3,
                    priority=i % 2, id=id_base + i) for i in range(n)]


def _sync_reference(params, cfg, ecfg, reqs):
    """Serve clones of ``reqs`` (same ids => same sampling keys) on one
    synchronous engine; returns {id: out_tokens}."""
    eng = GenerationEngine(params, cfg, config=ecfg)
    clones = [Request(prompt=r.prompt, max_new_tokens=r.max_new_tokens,
                      priority=r.priority, id=r.id) for r in reqs]
    for r in clones:
        eng.submit(r)
    eng.run()
    assert all(r.done for r in clones)
    return {r.id: r.out_tokens for r in clones}


def _serve_async(params, cfg, ecfg, reqs, *, n_replicas=1, **fe_kw):
    """Submit all of ``reqs`` up front, drain, return {id: stream}."""
    replicas = [GenerationEngine(params, cfg, config=ecfg)
                for _ in range(n_replicas)]
    fe = AsyncServingFrontend(replicas, **fe_kw)

    async def go():
        streams = {r.id: fe.submit_nowait(r) for r in reqs}
        await fe.drain()
        return streams

    return asyncio.run(go()), fe


def test_async_stream_bit_identical_to_sync(world):
    params, cfg = world
    ecfg = EngineConfig(max_batch=2, max_len=48)
    reqs = _requests()
    ref = _sync_reference(params, cfg, ecfg, reqs)
    streams, fe = _serve_async(params, cfg, ecfg, reqs)
    assert fe.n_completed == len(reqs) and fe.n_shed == 0
    for r in reqs:
        assert r.done and streams[r.id].finished
        assert streams[r.id].tokens == r.out_tokens == ref[r.id], r.id


def test_async_bit_identical_under_preemption(world):
    """The differential holds through eviction + whole-request
    preemption: the async frontend over the oversubscribed swap-tier
    config streams the same tokens as the monolithic sync engine."""
    from test_serving import _OVERSUB, _oversub_requests
    params, cfg = world
    reqs = _oversub_requests(id_base=8_100)
    ref = _sync_reference(
        params, cfg, EngineConfig(max_batch=2, max_len=48,
                                  cache_mode="monolithic"), reqs)
    mon = KVCacheMonitor()
    ecfg = EngineConfig(max_batch=2, max_len=48, kv_monitor=mon, **_OVERSUB)
    streams, _ = _serve_async(params, cfg, ecfg, reqs)
    assert mon.summary()["n_preempted"] > 0      # preemption really fired
    for r in reqs:
        assert streams[r.id].tokens == ref[r.id], r.id


def test_async_bit_identical_with_prefix_sharing(world):
    params, cfg = world
    ecfg = EngineConfig(max_batch=3, max_len=64, prefill_chunk=8,
                        prefix_sharing=True)
    system = [7] * 16
    reqs = [Request(prompt=system + [i + 1] * 3, max_new_tokens=4,
                    id=8_200 + i) for i in range(4)]
    ref = _sync_reference(params, cfg, ecfg, reqs)
    tel = Telemetry(trace=False)
    from dataclasses import replace
    streams, _ = _serve_async(params, cfg, replace(ecfg, telemetry=tel),
                              reqs, telemetry=tel)
    assert tel.registry.value("prefix_hit_total") > 0
    for r in reqs:
        assert streams[r.id].tokens == ref[r.id], r.id


def test_two_replicas_bit_identical_and_balanced(world):
    """Replica placement cannot change tokens (shared rng_seed), and the
    least-loaded router actually uses both replicas."""
    params, cfg = world
    ecfg = EngineConfig(max_batch=2, max_len=48)
    reqs = _requests(id_base=8_300, n=6)
    ref = _sync_reference(params, cfg, ecfg, reqs)
    streams, fe = _serve_async(params, cfg, ecfg, reqs, n_replicas=2)
    for r in reqs:
        assert streams[r.id].tokens == ref[r.id], r.id
    used = {idx for _, idx, _ in fe.router.placements}
    assert used == {0, 1}, fe.router.placements


def test_streaming_consumer_sees_tokens_incrementally(world):
    """``async for`` over a stream while ``run()`` drives in the
    background yields every token in order and terminates."""
    params, cfg = world
    ecfg = EngineConfig(max_batch=2, max_len=48)
    req = Request(prompt=[1, 2, 3], max_new_tokens=5, id=8_400)
    ref = _sync_reference(params, cfg, ecfg, [req])

    async def go():
        fe = AsyncServingFrontend(
            GenerationEngine(params, cfg, config=ecfg))
        driver = asyncio.create_task(fe.run())
        stream = await fe.submit(req)
        got = [tok async for tok in stream]
        await fe.close()
        await driver
        return got

    assert asyncio.run(go()) == ref[req.id]


def test_backpressure_reject(world):
    params, cfg = world
    ecfg = EngineConfig(max_batch=2, max_len=48)
    fe = AsyncServingFrontend(GenerationEngine(params, cfg, config=ecfg),
                              max_pending=2, shed_policy="reject")
    a, b = _requests(id_base=8_500, n=2)
    fe.submit_nowait(a), fe.submit_nowait(b)
    with pytest.raises(FrontendOverloaded):
        fe.submit_nowait(Request(prompt=[9], max_new_tokens=2, id=8_510))
    assert fe.n_shed == 1
    asyncio.run(fe.drain())
    assert a.done and b.done


def test_backpressure_drop_lowest(world):
    """A full queue under ``drop-lowest``: a higher-priority newcomer
    evicts the lowest-priority queued request (latest arrival within the
    class); a lowest-or-equal newcomer is itself shed."""
    params, cfg = world
    ecfg = EngineConfig(max_batch=1, max_len=48)
    fe = AsyncServingFrontend(GenerationEngine(params, cfg, config=ecfg),
                              max_pending=2, shed_policy="drop-lowest")
    lo1 = Request(prompt=[1], max_new_tokens=2, priority=0, id=8_600)
    lo2 = Request(prompt=[2], max_new_tokens=2, priority=0, id=8_601)
    s_lo1, s_lo2 = fe.submit_nowait(lo1), fe.submit_nowait(lo2)

    # equal priority: the newcomer is the victim, stream pre-terminated
    eq = Request(prompt=[3], max_new_tokens=2, priority=0, id=8_602)
    s_eq = fe.submit_nowait(eq)
    assert s_eq.shed and s_eq.finished and fe.n_shed == 1

    # higher priority: sheds the latest-queued lowest-priority request
    hi = Request(prompt=[4], max_new_tokens=2, priority=2, id=8_603)
    s_hi = fe.submit_nowait(hi)
    assert s_lo2.shed and not s_hi.shed and fe.n_shed == 2

    asyncio.run(fe.drain())
    assert lo1.done and hi.done and not lo2.done
    assert s_lo1.tokens == lo1.out_tokens
    assert s_hi.tokens == hi.out_tokens


def test_close_semantics(world):
    """``close(drain=False)`` sheds the queue but finishes in-flight
    work; submissions after close raise ``FrontendClosed``."""
    params, cfg = world
    ecfg = EngineConfig(max_batch=1, max_len=48)

    async def go():
        fe = AsyncServingFrontend(
            GenerationEngine(params, cfg, config=ecfg), max_pending=8)
        reqs = _requests(id_base=8_700, n=4)
        streams = {r.id: fe.submit_nowait(r) for r in reqs}
        await fe.step()                      # admits up to the backlog cap
        await fe.close(drain=False)
        with pytest.raises(FrontendClosed):
            fe.submit_nowait(Request(prompt=[1], max_new_tokens=1, id=8_710))
        return fe, reqs, streams

    fe, reqs, streams = asyncio.run(go())
    assert fe.n_shed > 0 and fe.n_completed > 0
    assert fe.n_shed + fe.n_completed == len(reqs)
    for r in reqs:
        s = streams[r.id]
        assert s.finished and (s.shed or (r.done and s.tokens == r.out_tokens))


def test_router_prefix_affinity(world):
    """A request sharing a served prefix routes to the replica holding
    it even when that replica is busier."""
    params, cfg = world
    ecfg = EngineConfig(max_batch=3, max_len=64, prefill_chunk=8,
                        prefix_sharing=True)
    replicas = [GenerationEngine(params, cfg, config=ecfg)
                for _ in range(2)]
    router = Router(replicas)
    system = [5] * 16
    warm = Request(prompt=system + [1, 2], max_new_tokens=2, id=8_800)
    router.submit_to(1, warm, reason="warm")     # replica 1 owns the prefix
    replicas[1].run()
    assert replicas[1].prefix_match_tokens(system + [9]) > 0
    # replica 1 is also the busier one -> affinity must win over load
    router.submit_to(1, Request(prompt=[3], max_new_tokens=8, id=8_801),
                     reason="fill")
    idx, reason = router.place(
        Request(prompt=system + [4], max_new_tokens=2, id=8_802))
    assert (idx, reason) == (1, "prefix-affinity")
    # no shared prefix -> plain least-loaded (replica 0 is idle)
    idx, reason = router.place(
        Request(prompt=[6, 7], max_new_tokens=2, id=8_803))
    assert (idx, reason) == (0, "least-loaded")


def test_router_placement_deterministic_under_seeded_trace(world):
    """The seeded bursty trace replayed twice through identical fleets
    produces identical placements and identical shed sets — frontend
    decisions are tick-state functions, never wall clock."""
    params, cfg = world
    ecfg = EngineConfig(max_batch=2, max_len=64, prefill_chunk=8,
                        prefix_sharing=True)
    trace = build_trace(seed=3, n_requests=12, vocab=cfg.vocab_size)

    def once():
        fe = AsyncServingFrontend(
            [GenerationEngine(params, cfg, config=ecfg) for _ in range(2)],
            max_pending=4, shed_policy="reject")
        streams, reqs = asyncio.run(replay(fe, trace))
        shed = [i for i, s in enumerate(streams) if s is None]
        toks = [s.tokens for s in streams if s is not None]
        return [(rid, idx, why) for rid, idx, why in fe.router.placements], \
            shed, toks

    p1, shed1, t1 = once()
    p2, shed2, t2 = once()
    assert p1 == p2 and shed1 == shed2 and t1 == t2
    assert len(p1) + len(shed1) == len(trace)


def test_frontend_metrics_published(world):
    """frontend_*/router_* metrics land in the shared registry."""
    params, cfg = world
    tel = Telemetry(trace=False)
    ecfg = EngineConfig(max_batch=2, max_len=48, telemetry=tel)
    reqs = _requests(id_base=8_900, n=3)
    streams, fe = _serve_async(params, cfg, ecfg, reqs, n_replicas=2,
                               telemetry=tel)
    reg = tel.registry
    assert reg.value("frontend_requests_total") == 3
    assert reg.value("frontend_completed_total") == 3
    assert reg.value("frontend_stream_tokens_total") == \
        sum(len(s.tokens) for s in streams.values())
    assert reg.value("router_placements_total") == 3
    assert reg.value("frontend_queue_depth") == 0
    assert reg.get("frontend_stream_ttft_seconds").count == 3
    assert reg.value("router_replica0_load") == 0
    assert reg.value("router_replica1_load") == 0
