"""Theorem 2.1 / Corollary 2.2: exponent entropy concentration."""
import numpy as np
import pytest

from repro.core import stats, theory


@pytest.mark.parametrize("alpha", [0.5, 1.0, 1.5, 1.9, 2.0])
def test_two_sided_geometric_is_a_distribution(alpha):
    ks = np.arange(-200, 201)
    p = theory.two_sided_geometric_pmf(ks, alpha)
    assert np.all(p > 0)
    np.testing.assert_allclose(p.sum(), 1.0, atol=1e-12)
    # symmetry and geometric decay rate q = 2^-alpha
    np.testing.assert_allclose(p[ks == 5], p[ks == -5])
    np.testing.assert_allclose(p[ks == 6] / p[ks == 5], 2.0 ** -alpha)


@pytest.mark.parametrize("alpha", [0.5, 1.0, 1.5, 1.9, 2.0])
def test_entropy_closed_form_matches_pmf(alpha):
    h = theory.exponent_entropy_exact(alpha)
    ks = np.arange(-800, 801)
    p = theory.two_sided_geometric_pmf(ks, alpha)
    p = p[p > 0]  # tail bins underflow for large alpha
    h_num = float(-(p * np.log2(p)).sum())
    np.testing.assert_allclose(h, h_num, atol=1e-9)


@pytest.mark.parametrize("alpha", [1.5, 1.7, 1.9, 2.0])
def test_theorem_bounds_hold_in_trained_weight_regime(alpha):
    """Thm 2.1's bounds hold for the alpha range of trained weights."""
    lo, hi = theory.exponent_entropy_bounds(alpha)
    h = theory.exponent_entropy_exact(alpha)
    assert lo <= h <= hi + 1e-12, (lo, h, hi)


@pytest.mark.parametrize("alpha", [0.5, 1.0, 1.4])
def test_paper_upper_bound_fails_for_small_alpha(alpha):
    """REPRODUCTION FINDING (recorded in DESIGN.md §Repro-notes): the
    paper's upper bound H(E) <= alpha/(1-2^-alpha) is *violated* by the
    exact entropy of the two-sided geometric law for alpha < ~1.476.
    The exact entropy (verified against the pmf above) is
        H = -log2 p0 + 2*alpha*q / ((1+q)(1-q)),  p0=(1-q)/(1+q), q=2^-alpha
    while the paper's proof bounds the h2 term by 1 but then drops it.
    The paper's *numerical instance* (alpha=2 -> H in [1.6, 2.67]) is
    correct, and all empirical alphas of trained weights sit in the valid
    regime — the practical conclusions stand."""
    lo, hi = theory.exponent_entropy_bounds(alpha)
    h = theory.exponent_entropy_exact(alpha)
    assert lo <= h          # the lower bound does hold
    assert h > hi           # the claimed upper bound does not


def test_fp467_limit():
    """The paper's numerical instance: alpha=2 -> bounds [1.6, 2.67] and a
    ~4.67-bit lossless floor with sign + 1 mantissa bit."""
    lo, hi = theory.exponent_entropy_bounds(2.0)
    assert abs(lo - 1.6) < 0.01
    assert abs(hi - 8.0 / 3.0) < 0.01
    assert abs(theory.compression_limit_bits(2.0) - 4.67) < 0.01


@pytest.mark.parametrize("alpha", [1.0, 1.4])
def test_alpha_stable_exponents_follow_geometric_law(alpha):
    """Sampled alpha-stable values' exponents decay like q=2^-alpha in the
    tails (Thm 2.1's mechanism).  The tail fit is biased by the non-
    geometric central region (and by slow tail convergence as alpha -> 2,
    where the stable law degenerates to a Gaussian with non-power tails),
    so the recovery tolerance is loose and alpha stays < 1.5 here."""
    x = theory.sample_alpha_stable((600_000,), alpha=alpha, seed=3)
    a_hat = stats.alpha_fit_from_values(x)
    assert abs(a_hat - alpha) / alpha < 0.35, (alpha, a_hat)


def test_alpha_stable_entropy_near_theory():
    """REPRODUCTION NOTE: Thm 2.1's two-sided geometric law is exact only
    in the tails (the paper's own proof says P(E=k) ~ approx); the actual
    alpha-stable exponent entropy exceeds the idealized law's because the
    central region is broader.  Empirically the gap is <1 bit, and the
    *empirical* entropy is exactly the 2-3 bits the paper reports."""
    alpha = 1.8
    x = theory.sample_alpha_stable((1_000_000,), alpha=alpha, seed=0)
    E = np.floor(np.log2(np.abs(x[x != 0]))).astype(int)
    E -= E.min()
    H = stats.shannon_entropy(np.bincount(E))
    h_theory = theory.exponent_entropy_exact(alpha)
    assert h_theory < H < h_theory + 1.0, (H, h_theory)
    assert 2.0 < H < 3.0  # the paper's empirical band (Fig. 1)


def test_entropy_decreases_with_alpha():
    """REPRODUCTION FINDING: the exact two-sided-geometric entropy is
    *decreasing* in alpha — heavier tails (smaller alpha) spread exponents
    wider and carry MORE entropy.  The paper's interpretation ('tighter
    concentration (smaller alpha) leads to smaller entropy') has the
    direction backwards; its bound alpha/(1-2^-alpha) is increasing in
    alpha, which likely caused the mix-up.  See DESIGN.md §Repro-notes."""
    hs = [theory.exponent_entropy_exact(a)
          for a in (0.25, 0.5, 1.0, 1.5, 2.0)]
    assert all(a > b for a, b in zip(hs, hs[1:]))


def test_synthesized_weights_match_paper_band():
    """The synthesized fp8 weights reproduce the paper's empirical law:
    exponent entropy ~ 2-3 bits (Fig. 1) and a 9.8-26.9% saving band."""
    bits = stats.synthesize_fp8_weights((512, 512), alpha=1.9, seed=1)
    H = stats.tensor_exponent_entropy(bits.view(np.uint8))
    assert 1.5 < H < 3.5, H
