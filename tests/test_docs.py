"""The docs/ subsystem can't rot: intra-repo Markdown links must resolve
and the FORMATS.md worked example must execute (same checks as the CI
``docs`` job — tools/check_docs.py)."""
import importlib.util
import os

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _load_checker():
    spec = importlib.util.spec_from_file_location(
        "check_docs", os.path.join(REPO, "tools", "check_docs.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_markdown_links_resolve():
    errors = _load_checker().check_links()
    assert not errors, "\n".join(errors)


def test_formats_spec_doctests_pass():
    errors = _load_checker().run_doctests()
    assert not errors, "\n".join(errors)


def test_docs_exist_and_linked_from_readme():
    """Acceptance (ISSUE 2): ARCHITECTURE.md + FORMATS.md exist and the
    README links them."""
    for f in ("ARCHITECTURE.md", "FORMATS.md"):
        assert os.path.exists(os.path.join(REPO, "docs", f)), f
    with open(os.path.join(REPO, "README.md"), encoding="utf-8") as fh:
        readme = fh.read()
    assert "docs/ARCHITECTURE.md" in readme
    assert "docs/FORMATS.md" in readme
