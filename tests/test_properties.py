"""Property-based tests (hypothesis) on the system's invariants.

The compression invariant is universal: *any* byte content roundtrips
bit-exactly through every container — not just alpha-stable-shaped weights.
Codebook invariants: prefix-freeness (Kraft), length cap, near-optimality.

Hypothesis is optional: without it only the ``@given`` tests skip (the
deterministic regression suites below still run in tier-1); the CI
``tests-extended`` job runs everything with ``--hypothesis-profile=ci``.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:                    # pragma: no cover - CI installs it
    HAS_HYPOTHESIS = False

    def given(*_a, **_k):
        return lambda f: pytest.mark.skip(
            reason="property tests need the hypothesis package")(f)

    def settings(*_a, **_k):
        return lambda f: f

    class _AnyStrategy:
        """Stands in for ``hypothesis.strategies`` at decoration time
        (strategy expressions are built but never drawn from)."""

        def __getattr__(self, _name):
            return self

        def __call__(self, *_a, **_k):
            return self

    st = _AnyStrategy()

from repro.core import (fixedrate, fp8, huffman, paper_format,  # noqa: E402
                        stats, tpu_format)
from repro.kvcache import codec as kv_codec, kernels as kv_kernels  # noqa: E402
from repro.kvcache.swap import SwapEntry, SwappedPage, SwapStore  # noqa: E402

bytes_arrays = st.integers(1, 4096).flatmap(
    lambda n: st.builds(
        lambda seed, mode: _make_bytes(n, seed, mode),
        st.integers(0, 2**31 - 1),
        st.sampled_from(["uniform", "concentrated", "two", "constant"])))


def _make_bytes(n, seed, mode):
    rng = np.random.default_rng(seed)
    if mode == "uniform":
        return rng.integers(0, 256, n).astype(np.uint8)
    if mode == "concentrated":
        return np.asarray(
            stats.synthesize_fp8_weights((n,), alpha=1.7, seed=seed))
    if mode == "two":
        return rng.choice(np.asarray([0x3A, 0xC5], np.uint8), n)
    return np.full(n, rng.integers(0, 256), np.uint8)


@settings(max_examples=25, deadline=None)
@given(bytes_arrays)
def test_paper_container_roundtrips_any_bytes(bits):
    c = paper_format.encode(bits)
    np.testing.assert_array_equal(paper_format.decode_sequential(c), bits)
    np.testing.assert_array_equal(paper_format.decode_blockparallel(c), bits)


@settings(max_examples=25, deadline=None)
@given(bytes_arrays)
def test_tpu_container_roundtrips_any_bytes(bits):
    c = tpu_format.encode(bits, sym_per_lane=16)
    np.testing.assert_array_equal(
        np.asarray(tpu_format.decode_jnp(c)), bits.reshape(-1))


@settings(max_examples=25, deadline=None)
@given(bytes_arrays)
def test_fixedrate_roundtrips_any_bytes(bits):
    c = fixedrate.encode(bits)
    np.testing.assert_array_equal(fixedrate.decode_ref(c), bits)


@settings(max_examples=50, deadline=None)
@given(st.lists(st.integers(0, 10**7), min_size=1, max_size=16),
       st.sampled_from([4, 8, 16]))
def test_codebook_invariants(freq_list, cap):
    freqs = np.zeros(16, dtype=np.int64)
    freqs[: len(freq_list)] = freq_list
    if freqs.sum() == 0:
        freqs[0] = 1
    n_active = int((freqs > 0).sum())
    if (1 << cap) < n_active:
        return
    cb = huffman.Codebook.from_freqs(freqs, max_len=cap)
    lens = cb.lengths[freqs > 0]
    assert np.all(lens >= 1) and np.all(lens <= cap)
    # Kraft inequality (prefix-freeness feasibility)
    assert huffman.kraft_sum(cb.lengths) <= 1.0 + 1e-12
    # near-optimality: E[len] <= H + 1 for the unrestricted cap
    if cap == 16:
        H = stats.shannon_entropy(freqs)
        assert huffman.expected_length(freqs, cb.lengths) <= H + 1 + 1e-9
    # canonical decode tables invert the codes
    for s in range(16):
        if freqs[s] == 0:
            continue
        l = int(cb.lengths[s])
        peek = int(cb.codes[s]) << (cb.max_len - l)
        sym, ln = cb.decode_peek(peek)
        assert (sym, ln) == (s, l)


@settings(max_examples=50, deadline=None)
@given(st.integers(1, 999), st.integers(0, 2**31 - 1))
def test_nibble_pack_unpack_inverse(n, seed):
    rng = np.random.default_rng(seed)
    nib = rng.integers(0, 16, n).astype(np.uint8)
    packed = fp8.pack_nibbles(nib, xp=np)
    got = np.asarray(fp8.unpack_nibbles(packed, n, xp=np))
    np.testing.assert_array_equal(got, nib)


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 2000))
def test_fp8_field_split_assemble_identity(seed, n):
    rng = np.random.default_rng(seed)
    bits = rng.integers(0, 256, n).astype(np.uint8)
    e = fp8.exponent_field(bits, xp=np)
    sm = fp8.signmant_nibble(bits, xp=np)
    np.testing.assert_array_equal(fp8.assemble(e, sm, xp=np), bits)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(64, 4096))
def test_onDevice_fixedrate_encode_matches_host(seed, n):
    import jax.numpy as jnp
    rng = np.random.default_rng(seed)
    bits = rng.integers(0, 256, n).astype(np.uint8)
    host = fixedrate.encode(bits, esc_capacity=n, margin=1.0)
    codes, esc, sm, overflow = fixedrate.encode_jnp(
        jnp.asarray(bits), jnp.asarray(host.table),
        esc_capacity=host.esc_capacity)
    assert not bool(overflow)
    np.testing.assert_array_equal(np.asarray(codes), host.codes)
    got_esc = np.asarray(esc)[: host.esc_count]
    want_esc = np.asarray(
        fp8.unpack_nibbles(host.escapes, host.esc_capacity,
                           xp=np))[: host.esc_count]
    np.testing.assert_array_equal(got_esc, want_esc)


_PAGE_VIEWS = {"float8_e4m3fn": np.uint8, "bfloat16": np.uint16,
               "float32": np.uint32}


def _page_bits(n, seed, mode, dtype_name):
    """Adversarial exponent distributions as raw bit patterns."""
    rng = np.random.default_rng(seed)
    uint = _PAGE_VIEWS[dtype_name]
    nbits = np.dtype(uint).itemsize * 8
    if mode == "uniform":           # every exponent equally likely
        return rng.integers(0, 1 << nbits, n, dtype=np.uint64).astype(uint)
    if mode == "concentrated":      # trained-like alpha-stable values
        from repro.core import theory
        import jax.numpy as jnp
        v = theory.sample_alpha_stable((n,), alpha=1.7, seed=seed) * 0.15
        if dtype_name == "float8_e4m3fn":
            return stats.synthesize_fp8_weights((n,), alpha=1.7, seed=seed)
        return np.asarray(jnp.asarray(v, jnp.dtype(dtype_name))).view(uint)
    if mode == "two":               # two extreme exponents only
        lo = np.uint64(1)           # smallest subnormal pattern
        hi = np.uint64((1 << nbits) - 1)   # all-ones (NaN-ish)
        return rng.choice(np.asarray([lo, hi]), n).astype(uint)
    return np.full(n, rng.integers(0, 1 << nbits), dtype=np.uint64) \
        .astype(uint)               # constant


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 2048), st.integers(0, 2**31 - 1),
       st.sampled_from(sorted(_PAGE_VIEWS)),
       st.sampled_from(["uniform", "concentrated", "two", "constant"]))
def test_kv_page_codec_roundtrips_any_bits(n, seed, dtype_name, mode):
    """The page codec is lossless for *any* bit content in every cache
    dtype — including NaN payloads and adversarial exponent histograms
    a trained model would never produce."""
    import jax.numpy as jnp
    uint = _PAGE_VIEWS[dtype_name]
    bits = _page_bits(n, seed, mode, dtype_name)
    view = {"float8_e4m3fn": jnp.float8_e4m3fn, "bfloat16": jnp.bfloat16,
            "float32": np.float32}[dtype_name]
    cp = kv_codec.encode_page(bits.view(view))
    np.testing.assert_array_equal(
        np.asarray(kv_codec.decode_page(cp)).view(uint).reshape(-1), bits)
    got = kv_codec.decode_pages_jnp(
        jnp.asarray(cp.payload)[None], jnp.asarray(cp.signmant)[None],
        jnp.asarray(cp.tables())[None], jnp.asarray(cp.perm)[None],
        n_elem=cp.n_elem, dtype_name=dtype_name)
    np.testing.assert_array_equal(np.asarray(got)[0].view(uint), bits)


# --------------------------------------------------------------------------
# codec edge cases through the swap tier (ISSUE 3 regression suite)
# --------------------------------------------------------------------------

def _edge_page(case, dtype_name, n, seed):
    """Degenerate exponent planes the entropy coder must survive."""
    rng = np.random.default_rng(seed)
    uint = _PAGE_VIEWS[dtype_name]
    nbits = np.dtype(uint).itemsize * 8
    exp_bits = 4 if dtype_name == "float8_e4m3fn" else 8
    mant_bits = nbits - 1 - exp_bits
    sign = rng.integers(0, 2, n).astype(np.uint64) << (nbits - 1)
    mant = rng.integers(0, 1 << mant_bits, n).astype(np.uint64)
    if case == "single-symbol":     # one exponent value for the whole page
        exp = np.full(n, (1 << exp_bits) // 2, np.uint64)
    elif case == "all-subnormal":   # exponent field 0, nonzero mantissa
        exp = np.zeros(n, np.uint64)
        mant = np.maximum(mant, 1)
    elif case == "all-nan-inf":     # exponent field all-ones
        exp = np.full(n, (1 << exp_bits) - 1, np.uint64)
    else:
        raise ValueError(case)
    return (sign | (exp << mant_bits) | mant).astype(uint)


@pytest.mark.parametrize("dtype_name", sorted(_PAGE_VIEWS))
@pytest.mark.parametrize("case", ["single-symbol", "all-subnormal",
                                  "all-nan-inf"])
@pytest.mark.parametrize("n", [kv_codec.LANES, kv_codec.LANES * 4 - 1, 769])
def test_page_codec_edge_cases_roundtrip_through_swap(dtype_name, case, n):
    """Degenerate pages (one-symbol exponent plane, all-subnormal,
    all-NaN/Inf; including exactly lane-boundary lengths) round-trip
    bit-exactly through compress -> swap store -> the Pallas restore
    path used by ``PagedKVCache.fault``."""
    import jax.numpy as jnp
    uint = _PAGE_VIEWS[dtype_name]
    bits = _edge_page(case, dtype_name, n, seed=n)
    view = {"float8_e4m3fn": jnp.float8_e4m3fn, "bfloat16": jnp.bfloat16,
            "float32": np.float32}[dtype_name]
    cp = kv_codec.encode_page(bits.view(view))
    # host oracle
    np.testing.assert_array_equal(
        np.asarray(kv_codec.decode_page(cp)).view(uint).reshape(-1), bits)
    # swap-store round trip, restored through the Pallas decode path
    store = SwapStore(capacity_bytes=1 << 24)
    page = SwappedPage(entries=[SwapEntry(
        "tail", "layer0", False, "k", None, cp.payload, cp.signmant,
        cp.tables(), cp.perm)], was_cold=False, nbytes=cp.nbytes())
    key = store.put(page, shard=0)
    assert store.bytes_used == cp.nbytes()
    ent = store.pop(key).entries[0]
    assert store.bytes_used == 0 and store.swap_in_bytes == cp.nbytes()
    got = kv_kernels.decode_pages(
        jnp.asarray(ent.payload)[None], jnp.asarray(ent.signmant)[None],
        jnp.asarray(ent.tables)[None], jnp.asarray(ent.perm)[None],
        n_elem=cp.n_elem, dtype_name=dtype_name, interpret=True)
    np.testing.assert_array_equal(np.asarray(got)[0].view(uint), bits)


def test_swap_store_capacity_and_accounting():
    """Capacity is a hard ceiling; discard (a request finishing while
    preempted) frees bytes without counting as swap-in traffic."""
    import jax.numpy as jnp
    from repro.kvcache.swap import SwapExhausted
    bits = _edge_page("single-symbol", "bfloat16", 512, seed=0)
    cp = kv_codec.encode_page(bits.view(jnp.bfloat16))
    page = SwappedPage(entries=[], was_cold=False, nbytes=cp.nbytes())
    store = SwapStore(capacity_bytes=cp.nbytes(), n_shards=2)
    key = store.put(page, shard=1)
    assert store.bytes_used_per_shard == [0, cp.nbytes()]
    with pytest.raises(SwapExhausted):
        store.put(SwappedPage(nbytes=1), shard=0)
    store.discard(key)
    assert store.bytes_used == 0 and store.swap_in_bytes == 0
    assert store.swap_out_bytes == cp.nbytes()   # traffic is cumulative


@settings(max_examples=20, deadline=None)
@given(st.floats(0.3, 2.0))
def test_entropy_lower_bound_holds_for_all_alpha(alpha):
    """The paper's lower bound holds everywhere; its upper bound only for
    alpha >~ 1.476 (see test_theory.py::test_paper_upper_bound_fails...)."""
    from repro.core import theory
    lo, hi = theory.exponent_entropy_bounds(alpha)
    h = theory.exponent_entropy_exact(alpha)
    assert lo - 1e-9 <= h
    if alpha >= 1.48:
        assert h <= hi + 1e-9
