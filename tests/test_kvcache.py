"""Paged, ECF8-compressed KV cache: codec, kernel, allocator, end-to-end.

Acceptance (ISSUE 1): the compressed paged cache produces **bit-identical**
decode outputs to the monolithic cache on the same request stream, and
compressed cold pages cost <= 0.75x raw bf16 bytes on trained-like
(alpha-stable) synthetic data.

Acceptance (ISSUE 2): the same paged+compressed engine on a >= 2-device
CPU mesh (pool/table sharded over the batch axes, per-shard free lists)
emits **bit-identical** tokens and logits to the single-device monolithic
baseline.  Multi-device tests run in subprocesses (conftest: the main
pytest process must keep seeing 1 device).
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from conftest import run_subprocess

from repro.configs import get, smoke_variant
from repro.core import theory
from repro.kvcache import OutOfPages, PagedKVCache, codec, kernels
from repro.models import model as M
from repro.runtime.monitor import KVCacheMonitor
from repro.serving import EngineConfig, GenerationEngine, Request
from repro.serving.engine import splice_fragment


def _rand_bits(rng, n, dtype_name):
    if dtype_name == "float8_e4m3fn":
        return rng.integers(0, 256, n).astype(np.uint8)
    if dtype_name == "bfloat16":
        return rng.integers(0, 1 << 16, n).astype(np.uint16)
    return rng.integers(0, 1 << 32, n, dtype=np.uint64).astype(np.uint32)


_VIEW = {"float8_e4m3fn": (np.uint8, jnp.float8_e4m3fn),
         "bfloat16": (np.uint16, jnp.bfloat16),
         "float32": (np.uint32, np.float32)}


# --------------------------------------------------------------------------
# codec
# --------------------------------------------------------------------------

@pytest.mark.parametrize("dtype_name", list(_VIEW))
@pytest.mark.parametrize("n", [1, 127, 128, 1000, 4096])
def test_codec_roundtrip_bit_exact(dtype_name, n):
    """Any bit content (NaNs included) roundtrips through host + jnp."""
    uint, view = _VIEW[dtype_name]
    bits = _rand_bits(np.random.default_rng(n), n, dtype_name)
    cp = codec.encode_page(bits.view(view))
    np.testing.assert_array_equal(
        np.asarray(codec.decode_page(cp)).view(uint), bits)
    got = codec.decode_pages_jnp(
        jnp.asarray(cp.payload)[None], jnp.asarray(cp.signmant)[None],
        jnp.asarray(cp.tables())[None], jnp.asarray(cp.perm)[None],
        n_elem=cp.n_elem, dtype_name=dtype_name)
    np.testing.assert_array_equal(np.asarray(got)[0].view(uint), bits)


def test_codec_ratio_alpha_stable_bf16():
    """Acceptance: cold-page bytes <= 0.75x raw bf16 on trained-like data."""
    for alpha, seed in [(1.9, 0), (1.7, 1), (1.5, 2)]:
        v = theory.sample_alpha_stable((16384,), alpha=alpha, seed=seed)
        page = np.asarray(jnp.asarray(v * 0.15, jnp.bfloat16))
        cp = codec.encode_page(page)
        np.testing.assert_array_equal(
            np.asarray(codec.decode_page(cp)).view(np.uint16),
            page.view(np.uint16))
        assert cp.ratio() <= 0.75, (alpha, cp.ratio())


def test_kernel_matches_jnp_and_oracle():
    """Pallas decode (interpret) == jnp decode == per-lane host oracle,
    across pages with different codebooks zero-padded to one stride."""
    rng = np.random.default_rng(7)
    pages = [np.asarray(jnp.asarray(rng.standard_normal(2048) * s,
                                    jnp.bfloat16))
             for s in (0.05, 1.0, 300.0)]
    cps = [codec.encode_page(p) for p in pages]
    sb = max(c.stride for c in cps)
    pay = np.zeros((len(cps), sb, codec.LANES), np.uint8)
    for i, c in enumerate(cps):
        pay[i, : c.stride] = c.payload
    args = (jnp.asarray(pay), jnp.asarray(np.stack([c.signmant for c in cps])),
            jnp.asarray(np.stack([c.tables() for c in cps])),
            jnp.asarray(np.stack([c.perm for c in cps])))
    got_k = kernels.decode_pages(*args, n_elem=2048, dtype_name="bfloat16",
                                 interpret=True)
    got_j = codec.decode_pages_jnp(*args, n_elem=2048, dtype_name="bfloat16")
    for i, (p, c) in enumerate(zip(pages, cps)):
        want = p.view(np.uint16)
        np.testing.assert_array_equal(np.asarray(got_k)[i].view(np.uint16),
                                      want)
        np.testing.assert_array_equal(np.asarray(got_j)[i].view(np.uint16),
                                      want)
        np.testing.assert_array_equal(
            np.asarray(codec.decode_page(c)).view(np.uint16), want)


# --------------------------------------------------------------------------
# allocator
# --------------------------------------------------------------------------

def test_allocator_lifecycle_and_garbage_page():
    cfg = smoke_variant(get("qwen3-8b"))
    pkv = PagedKVCache(cfg, 2, 32, dtype=jnp.float32, page_size=8, n_pages=5)
    assert pkv.pages_per_slot == 4
    assert 0 not in pkv._free[0]       # garbage page is never allocatable
    assert pkv.pages_needed(7) == 1 and pkv.pages_needed(8) == 2
    assert pkv.can_admit(20)
    tiny = PagedKVCache(cfg, 2, 32, dtype=jnp.float32, page_size=8,
                        n_pages=3)
    assert not tiny.can_admit(20)      # needs 3 pages, pool holds 2

    params = M.init_params(jax.random.PRNGKey(0), cfg)
    cache = pkv.init_cache()
    _, frag = M.prefill(params, cfg, jnp.ones((1, 9), jnp.int32), max_len=32)
    cache = pkv.admit(cache, 0, frag, 9)
    assert pkv._slot_pages[0] == [1, 2] and pkv.free_pages == 2
    cache = pkv.ensure(cache, 0, 16)   # write pos 16 -> third page
    assert len(pkv._slot_pages[0]) == 3 and pkv.free_pages == 1
    with pytest.raises(OutOfPages):
        pkv.admit(cache, 1, frag, 9)   # needs 2 pages, 1 free
    cache = pkv.release(cache, 0)
    assert pkv.free_pages == 4 and not pkv._slot_pages
    assert np.all(np.asarray(cache["page_table"]) == 0)


# --------------------------------------------------------------------------
# end-to-end: paged + compressed == monolithic, bit for bit
# --------------------------------------------------------------------------

def _mixed_stream():
    prompts = [[1, 2, 3, 4, 5, 6, 7, 8, 9, 10], [5, 6, 7], [9, 10] * 4,
               [11, 12, 13], [2] * 7, [40, 41]]
    news = [30, 25, 20, 12, 18, 6]
    return prompts, news


def test_engine_paged_bit_identical_to_monolithic():
    """Same mixed-length stream through all three cache modes -> identical
    tokens (greedy decode is bit-exact end to end)."""
    cfg = smoke_variant(get("qwen3-8b"))
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    prompts, news = _mixed_stream()

    def run(**kw):
        eng = GenerationEngine(params, cfg, config=EngineConfig(max_batch=2, max_len=64, **kw))
        reqs = [Request(prompt=p, max_new_tokens=n)
                for p, n in zip(prompts, news)]
        for r in reqs:
            eng.submit(r)
        eng.run()
        assert all(r.done for r in reqs)
        return [r.out_tokens for r in reqs], eng

    mono, _ = run(cache_mode="monolithic")
    paged, ep = run(cache_mode="paged", page_size=16)
    comp, ec = run(cache_mode="paged", page_size=16, compress_cold=True)
    assert ep.cache_mode == "paged" and ec.cache_mode == "paged"
    assert mono == paged
    assert mono == comp
    # the compressed run actually exercised the cold pool
    assert ec.paged.compress and not ec.paged._cold_bytes  # all released
    assert ec.paged.free_pages == ec.paged.n_pages - 1     # all returned


def test_decode_step_logits_bit_identical_with_compression():
    """Stronger than token equality: the jitted decode step's logits are
    bit-identical between the monolithic cache and a paged cache whose
    cold pages live entropy-coded (decode-on-use in-graph)."""
    cfg = smoke_variant(get("qwen3-8b"))
    params = M.init_params(jax.random.PRNGKey(1), cfg)
    B, max_len, ps = 2, 32, 8
    pkv = PagedKVCache(cfg, B, max_len, dtype=jnp.float32, page_size=ps,
                       compress_cold=True)
    cache_p = pkv.init_cache()
    cache_m = M.init_cache(cfg, B, max_len, dtype=jnp.float32, per_slot=True)
    lens = [11, 6]
    for slot, T in enumerate(lens):
        toks = jnp.arange(1, T + 1, dtype=jnp.int32)[None] + 3 * slot
        _, frag = M.prefill(params, cfg, toks, max_len=max_len)
        cache_p = pkv.admit(cache_p, slot, frag, T)
        cache_m = splice_fragment(cache_m, frag, slot)
        cache_m["cur_len"] = cache_m["cur_len"].at[slot].set(T)

    tok = jnp.asarray([[17], [29]], jnp.int32)
    for step in range(12):
        for slot in range(B):
            cache_p = pkv.ensure(cache_p, slot, lens[slot])
        lp, cache_p = M.decode_step(params, cfg, tok, cache_p)
        lm, cache_m = M.decode_step(params, cfg, tok, cache_m)
        np.testing.assert_array_equal(np.asarray(lp), np.asarray(lm))
        for slot in range(B):
            lens[slot] += 1
            cache_p = pkv.compress_cold_pages(cache_p, slot, lens[slot])
        tok = (tok + 7) % cfg.vocab_size
    assert pkv._cold_bytes, "no page was ever compressed - test is vacuous"


def test_engine_undersized_pool_serializes_admission():
    """An oversubscribed pool (n_pages < worst case) defers admission
    until a slot releases its pages — and still matches monolithic."""
    cfg = smoke_variant(get("qwen3-8b"))
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    prompts = [[i + 1] * 9 for i in range(3)]

    def run(**kw):
        eng = GenerationEngine(params, cfg, config=EngineConfig(max_batch=2, max_len=32, **kw))
        reqs = [Request(prompt=p, max_new_tokens=7) for p in prompts]
        for r in reqs:
            eng.submit(r)
        eng.run()
        assert all(r.done for r in reqs)
        return [r.out_tokens for r in reqs], eng

    mono, _ = run(cache_mode="monolithic")
    # pool of 2 usable pages = exactly one request's working set
    tight, eng = run(cache_mode="paged", page_size=8, n_pages=3)
    assert mono == tight
    # 6 decode tokens per request (first comes from prefill), no overlap
    assert eng.steps >= 18
    assert eng.paged.free_pages == 2  # all pages returned


# --------------------------------------------------------------------------
# mesh-sharded paged cache (ISSUE 2)
# --------------------------------------------------------------------------

def test_allocator_per_shard_free_lists():
    """Pages and cold slots partition into per-shard ranges; exhaustion is
    per shard and OutOfPages names the shard that ran dry."""
    cfg = smoke_variant(get("qwen3-8b"))
    pkv = PagedKVCache(cfg, 4, 32, dtype=jnp.float32, page_size=8,
                       n_pages=8, n_shards=2)
    assert pkv.pages_per_shard == 4
    assert pkv._free[0] == [3, 2, 1]       # shard 0 loses id 0 (garbage)
    assert pkv._free[1] == [7, 6, 5, 4]
    assert pkv.shard_of_slot(1) == 0 and pkv.shard_of_slot(2) == 1

    params = M.init_params(jax.random.PRNGKey(0), cfg)
    cache = pkv.init_cache()
    _, frag = M.prefill(params, cfg, jnp.ones((1, 9), jnp.int32), max_len=32)
    cache = pkv.admit(cache, 2, frag, 9)   # slot 2 -> shard 1 ids only
    assert pkv._slot_pages[2] == [4, 5]
    cache = pkv.admit(cache, 3, frag, 9)   # shard 1 now fully allocated
    with pytest.raises(OutOfPages, match="shard 1"):
        pkv.ensure(cache, 2, 16)           # slot 2 needs a third page
    with pytest.raises(OutOfPages, match="shard 1"):
        pkv.admit(cache, 3, frag, 9)
    # shard 0 is untouched: its slots still admit
    assert pkv.can_admit(9, slot=0) and not pkv.can_admit(9, slot=2)
    assert pkv.free_pages_per_shard == [3, 0]
    cache = pkv.release(cache, 3)
    assert pkv.free_pages_per_shard == [3, 2]  # returned to shard 1's list


@pytest.mark.slow
def test_engine_sharded_paged_bit_identical_to_monolithic():
    """Acceptance (ISSUE 2): the sharded paged+compressed engine on 2- and
    4-device data meshes emits bit-identical tokens to the single-device
    monolithic baseline."""
    run_subprocess("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import Mesh
        from repro.configs import get, smoke_variant
        from repro.models import model as M
        from repro.serving import EngineConfig, GenerationEngine, Request

        cfg = smoke_variant(get('qwen3-8b'))
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        prompts = [[1,2,3,4,5,6,7,8,9,10], [5,6,7], [9,10]*4,
                   [11,12,13], [2]*7, [40,41]]
        news = [18, 12, 10, 8, 9, 6]

        def run(mesh=None, **kw):
            eng = GenerationEngine(params, cfg, config=EngineConfig(max_batch=4, max_len=64,
                                   mesh=mesh, **kw))
            reqs = [Request(prompt=p, max_new_tokens=n)
                    for p, n in zip(prompts, news)]
            for r in reqs:
                eng.submit(r)
            eng.run()
            assert all(r.done for r in reqs)
            return [r.out_tokens for r in reqs], eng

        mono, _ = run(cache_mode='monolithic')
        for n_dev in (2, 4):
            mesh = Mesh(np.array(jax.devices()[:n_dev]), ('data',))
            got, eng = run(mesh=mesh, cache_mode='paged', page_size=16,
                           compress_cold=True)
            assert eng.cache_mode == 'paged', 'fell back to monolithic'
            assert eng.paged.n_shards == n_dev
            assert got == mono, (n_dev, got, mono)
            assert eng.paged.free_pages == eng.paged.n_pages - 1

        # hybrid arch: local-attention ring buffers stay monolithic
        # per-slot leaves (GSPMD batch-sharded) next to the paged pools
        cfg = smoke_variant(get('gemma2-9b'))
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        mono, _ = run(cache_mode='monolithic')
        mesh = Mesh(np.array(jax.devices()[:2]), ('data',))
        got, eng = run(mesh=mesh, cache_mode='paged', page_size=16,
                       compress_cold=True)
        assert eng.cache_mode == 'paged' and got == mono
        print('sharded paged engine == single-device monolithic: OK')
    """, devices=4)


@pytest.mark.slow
def test_sharded_decode_step_logits_bit_identical():
    """Stronger than token equality: jitted decode-step logits on a
    2-device data mesh (paged + cold pages entropy-coded per shard) are
    bit-identical to the single-device monolithic cache."""
    run_subprocess("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import Mesh
        from repro.configs import get, smoke_variant
        from repro.kvcache import PagedKVCache
        from repro.models import model as M
        from repro.runtime import sharding as SH
        from repro.serving.engine import splice_fragment

        cfg = smoke_variant(get('qwen3-8b'))
        params = M.init_params(jax.random.PRNGKey(1), cfg)
        B, max_len, ps = 2, 32, 8
        mesh = Mesh(np.array(jax.devices()[:2]), ('data',))
        pkv = PagedKVCache(cfg, B, max_len, dtype=jnp.float32, page_size=ps,
                           compress_cold=True, n_shards=2)
        cache_p = pkv.init_cache()
        cache_m = M.init_cache(cfg, B, max_len, dtype=jnp.float32,
                               per_slot=True)
        lens = [11, 6]
        for slot, T in enumerate(lens):
            toks = jnp.arange(1, T + 1, dtype=jnp.int32)[None] + 3 * slot
            _, frag = M.prefill(params, cfg, toks, max_len=max_len)
            cache_p = pkv.admit(cache_p, slot, frag, T)
            cache_m = splice_fragment(cache_m, frag, slot)
            cache_m['cur_len'] = cache_m['cur_len'].at[slot].set(T)
        cache_p = jax.device_put(cache_p, SH.named(
            mesh, SH.cache_pspecs(cfg, cache_p, mesh)))
        dec = jax.jit(lambda p, t, c: M.decode_step(p, cfg, t, c, mesh=mesh))
        tok = jnp.asarray([[17], [29]], jnp.int32)
        for step in range(12):
            for slot in range(B):
                cache_p = pkv.ensure(cache_p, slot, lens[slot])
            lp, cache_p = dec(params, tok, cache_p)
            lm, cache_m = M.decode_step(params, cfg, tok, cache_m)
            np.testing.assert_array_equal(np.asarray(lp), np.asarray(lm))
            for slot in range(B):
                lens[slot] += 1
                cache_p = pkv.compress_cold_pages(cache_p, slot, lens[slot])
            tok = (tok + 7) % cfg.vocab_size
        assert pkv._cold_bytes, 'no page went cold - test is vacuous'
        print('sharded paged+cold logits bit-identical: OK')
    """, devices=2)


@pytest.mark.slow
def test_paged_model_axis_and_sharded_kernel():
    """The model-axis page split (local attend-stats + cross-shard stat
    merge) matches the single-device paged decode, and the sharded Pallas
    cold-page decode is bit-exact vs the unsharded kernel."""
    run_subprocess("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import Mesh
        from repro.configs import get, smoke_variant
        from repro.kvcache import PagedKVCache, codec, kernels
        from repro.models import model as M
        from repro.runtime import sharding as SH

        # sharded Pallas decode == unsharded, bit for bit
        rng = np.random.default_rng(3)
        pages = [np.asarray(jnp.asarray(rng.standard_normal(2048) * s,
                                        jnp.bfloat16))
                 for s in (0.05, 1.0, 300.0, 7.0)]
        cps = [codec.encode_page(p) for p in pages]
        sb = max(c.stride for c in cps)
        pay = np.zeros((len(cps), sb, codec.LANES), np.uint8)
        for i, c in enumerate(cps):
            pay[i, : c.stride] = c.payload
        args = (jnp.asarray(pay),
                jnp.asarray(np.stack([c.signmant for c in cps])),
                jnp.asarray(np.stack([c.tables() for c in cps])),
                jnp.asarray(np.stack([c.perm for c in cps])))
        mesh_d = Mesh(np.array(jax.devices()[:2]), ('data',))
        got = kernels.decode_pages_sharded(*args, mesh_d, n_elem=2048,
                                           dtype_name='bfloat16')
        want = kernels.decode_pages(*args, n_elem=2048,
                                    dtype_name='bfloat16')
        np.testing.assert_array_equal(np.asarray(got).view(np.uint16),
                                      np.asarray(want).view(np.uint16))

        # model-axis combine: decode steps match the single-device paged
        # path (flash-merge across shards -> allclose, not bit-equal)
        cfg = smoke_variant(get('qwen3-8b'))
        params = M.init_params(jax.random.PRNGKey(1), cfg)
        B, max_len, ps = 2, 32, 8
        mesh = Mesh(np.array(jax.devices()[:2]), ('model',))
        pkv = PagedKVCache(cfg, B, max_len, dtype=jnp.float32, page_size=ps,
                           compress_cold=True)
        cache_s = pkv.init_cache()
        pkv1 = PagedKVCache(cfg, B, max_len, dtype=jnp.float32, page_size=ps,
                            compress_cold=True)
        cache_1 = pkv1.init_cache()
        lens = [11, 6]
        for slot, T in enumerate(lens):
            toks = jnp.arange(1, T + 1, dtype=jnp.int32)[None] + 3 * slot
            _, frag = M.prefill(params, cfg, toks, max_len=max_len)
            cache_s = pkv.admit(cache_s, slot, frag, T)
            cache_1 = pkv1.admit(cache_1, slot, frag, T)
        dec = jax.jit(lambda p, t, c: M.decode_step(p, cfg, t, c, mesh=mesh))
        tok = jnp.asarray([[17], [29]], jnp.int32)
        for step in range(10):
            for slot in range(B):
                cache_s = pkv.ensure(cache_s, slot, lens[slot])
                cache_1 = pkv1.ensure(cache_1, slot, lens[slot])
            ls, cache_s = dec(params, tok, cache_s)
            l1, cache_1 = M.decode_step(params, cfg, tok, cache_1)
            np.testing.assert_allclose(np.asarray(ls), np.asarray(l1),
                                       atol=3e-4)
            for slot in range(B):
                lens[slot] += 1
                cache_s = pkv.compress_cold_pages(cache_s, slot, lens[slot])
                cache_1 = pkv1.compress_cold_pages(cache_1, slot,
                                                   lens[slot])
            tok = (tok + 7) % cfg.vocab_size
        assert pkv._cold_bytes
        print('model-axis paged decode + sharded kernel: OK')
    """, devices=2)


def test_paged_memory_stats_beat_monolithic():
    """Short requests hold only the pages they wrote."""
    cfg = smoke_variant(get("qwen3-8b"))
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    mon = KVCacheMonitor()
    eng = GenerationEngine(params, cfg, config=EngineConfig(max_batch=4, max_len=64,
                           page_size=16, compress_cold=True, kv_monitor=mon))
    for i in range(6):
        eng.submit(Request(prompt=[i + 1, i + 2], max_new_tokens=6))
    eng.run()
    s = mon.summary()
    assert s["steps"] > 0
    assert s["peak_paged_bytes"] < s["monolithic_bytes"]
    assert s["peak_pages_in_use"] <= 6
