"""Cross-request prefix sharing (ISSUE 8): refcounted CoW pages + the
content-addressed prefix index.

Acceptance bar: serving with sharing on is **byte-identical** to serving
with sharing off (and to the monolithic reference) — including runs that
retire shared prefixes to swap and fault them back, and runs that
preempt mid-flight — while N requests with a common prompt prefix hold
ONE physical copy of its pages (asserted on the refcounts and the page
tables, not just the stats).
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get, smoke_variant
from repro.kvcache import OutOfPages, PagedKVCache, SwapStore
from repro.models import model as M
from repro.serving import EngineConfig, GenerationEngine, Request

try:
    from hypothesis import given, strategies as st
except ImportError:          # tier-1 may run without hypothesis
    given = None


@pytest.fixture(scope="module")
def model():
    cfg = smoke_variant(get("qwen3-8b"))
    return M.init_params(jax.random.PRNGKey(0), cfg), cfg


def _serve(params, cfg, reqs, *, max_batch=3, max_len=64, **kw):
    eng = GenerationEngine(params, cfg, config=EngineConfig(max_batch=max_batch,
                           max_len=max_len, **kw))
    for r in reqs:
        eng.submit(r)
    eng.run()
    assert all(r.done for r in reqs)
    return [r.out_tokens for r in reqs], eng


def _chat_requests(prefix, suffixes, max_new=6, id_base=20_000):
    """A chat-style stream: every request shares ``prefix`` (the system
    prompt) and appends its own suffix."""
    return [Request(prompt=list(prefix) + list(sfx), max_new_tokens=max_new,
                    id=id_base + i)
            for i, sfx in enumerate(suffixes)]


def _stream(make):
    """Fresh Request objects for each engine (they accumulate tokens)."""
    return make()


# --------------------------------------------------------------------------
# bit-identity: shared vs unshared
# --------------------------------------------------------------------------


def test_prefix_shared_serving_bit_identical(model):
    """The acceptance anchor: a common-prefix workload served with
    sharing on emits byte-identical tokens to sharing off, requests
    really hit the index, and prefill work shrinks by the matched
    tokens."""
    params, cfg = model
    prefix = list(range(1, 17))                     # 16 tokens = 2 pages
    suffixes = [[40 + i, 50 + i, 60 + i] for i in range(4)] + [[70]]

    def make():
        return _chat_requests(prefix, suffixes)

    kw = dict(cache_mode="paged", page_size=8, prefill_chunk=8)
    off, eng_off = _serve(params, cfg, _stream(make), **kw)
    on, eng_on = _serve(params, cfg, _stream(make), prefix_sharing=True,
                        **kw)
    assert on == off
    assert eng_on.prefix_sharing and not eng_off.prefix_sharing
    # the first request misses; later ones match both full-prefix blocks
    assert len(eng_on.paged.prefix) >= 2
    # matched positions were never recomputed: chunk-token totals differ
    # by exactly 16 tokens per hit
    assert eng_on.n_chunk_tokens < eng_off.n_chunk_tokens
    st_p = eng_on.paged.stats()
    assert st_p["prefix_cow_splits_total"] == 0     # structurally unreachable
    # all requests finished: index-only pages remain, no slot pages leak
    assert st_p["prefix_shared_pages"] == 0
    assert st_p["prefix_reclaimable_pages"] == st_p["prefix_resident_blocks"]


def test_prefix_sharing_one_physical_copy(model):
    """While N common-prefix requests are in flight, their page tables
    point at the SAME physical pages, whose refcount equals the holder
    count — one copy in device memory, verified on the allocator."""
    params, cfg = model
    prefix = list(range(1, 17))                     # 2 full pages of 8
    eng = GenerationEngine(params, cfg, config=EngineConfig(max_batch=4, max_len=64,
                           cache_mode="paged", page_size=8,
                           prefill_chunk=32, prefix_sharing=True))
    warm = Request(prompt=prefix + [99], max_new_tokens=2, id=21_000)
    eng.submit(warm)
    eng.run()
    assert len(eng.paged.prefix) == 2               # both blocks published
    base = eng.paged.stats()["pages_in_use"]

    reqs = [Request(prompt=prefix + [50 + i], max_new_tokens=8,
                    id=21_001 + i) for i in range(3)]
    for r in reqs:
        eng.submit(r)
    eng.step()
    slots = [eng.slots.index(r) for r in reqs]
    rows = [eng.paged._slot_pages[s][:2] for s in slots]
    assert rows[0] == rows[1] == rows[2]            # same physical pages
    for pid in rows[0]:
        # 3 slots + the index hold the page; it is counted once
        assert eng.paged._ref[pid] == 4
    assert eng.paged.n_shared_pages() == 2
    # physical accounting: 3 in-flight requests with a 17-token prompt
    # each cost 2 shared + 3x1 own pages, not 3x3
    assert eng.paged.stats()["pages_in_use"] <= base + 3 + 1
    eng.run()
    for r in reqs:
        assert r.done and r.out_tokens == warm_ref(params, cfg, r)


def warm_ref(params, cfg, req):
    """Monolithic greedy reference for one request."""
    toks = list(req.prompt)
    for _ in range(req.max_new_tokens):
        logits, _ = M.forward(params, cfg,
                              jnp.asarray(toks, jnp.int32)[None])
        toks.append(int(jnp.argmax(logits[0, -1])))
    return toks[len(req.prompt):]


def test_prefix_retire_to_swap_and_fault_back_bit_identical(model):
    """Under page pressure the shared prefix retires into the swap
    tier's unpinned LRU cache and a later match faults it back — tokens
    stay byte-identical and the retire/fault counters prove the path
    ran."""
    params, cfg = model
    prefix = list(range(1, 17))
    # tiny pool (capacity 6): the 30-token prompt needs 5 pages, so with
    # the 2-page idle prefix resident the allocator must reclaim
    kw = dict(cache_mode="paged", page_size=8, n_pages=7,
              prefill_chunk=8, swap_bytes=1 << 28, max_batch=2)

    def make():
        return [Request(prompt=prefix + [40], max_new_tokens=4, id=22_000),
                Request(prompt=[90 + i for i in range(30)],
                        max_new_tokens=8, id=22_001),
                Request(prompt=prefix + [41], max_new_tokens=4, id=22_002)]

    off, _ = _serve(params, cfg, _stream(make), **kw)
    # serialize admission so the index is idle when the long prompt lands
    on_reqs = _stream(make)
    eng = GenerationEngine(params, cfg, config=EngineConfig(max_len=64, prefix_sharing=True,
                           **kw))
    for r in on_reqs:
        eng.submit(r)
        eng.run()
    assert [r.out_tokens for r in on_reqs] == off
    assert eng.paged.n_prefix_retired > 0           # pressure retired it
    assert eng.paged.swap.n_prefix_evicted == 0     # store had room
    # the third request faulted the retired block back into the pool
    assert eng.paged.stats()["prefix_resident_blocks"] >= 1


def test_prefix_sharing_with_preemption_bit_identical(model):
    """Sharing composes with the oversubscribed swap/preemption tier:
    mixed-priority common-prefix workload, sized to preempt, byte-equal
    to the monolithic engine."""
    params, cfg = model
    prefix = list(range(1, 9))                      # one full page of 8
    wl = [(3, 12, 1), (8, 10, 2), (1, 12, 0), (6, 8, 0)]
    rng = np.random.default_rng(3)
    sfx = [rng.integers(1, cfg.vocab_size, size=n).tolist()
           for n, _, _ in wl]

    def make():
        return [Request(prompt=prefix + sfx[i], max_new_tokens=mn,
                        priority=pr, id=23_000 + i)
                for i, (_, mn, pr) in enumerate(wl)]

    mono, _ = _serve(params, cfg, _stream(make), max_batch=2,
                     cache_mode="monolithic")
    on, eng = _serve(params, cfg, _stream(make), max_batch=2,
                     cache_mode="paged", page_size=8, n_pages=5,
                     compress_cold=True, n_cold_slots=1,
                     swap_bytes=1 << 28, prefill_chunk=4,
                     prefix_sharing=True)
    assert on == mono
    assert eng.scheduler.n_preempted > 0            # the point of the sizing


# --------------------------------------------------------------------------
# refcounted allocator: property test
# --------------------------------------------------------------------------


def _check_invariants(pkv):
    """The audit invariants of the refcounted page allocator."""
    cap = sum(pkv.shard_capacity(k) for k in range(pkv.n_shards))
    free = [pid for f in pkv._free for pid in f]
    assert len(free) == len(set(free)), "free list has duplicates"
    assert not (set(free) & set(pkv._ref)), "freed page still referenced"
    # conservation: every raw page is either free or refcounted-live
    assert len(free) + len(pkv._ref) == cap, (len(free), len(pkv._ref))
    # refcount == holder count (slots' page lists + the prefix index)
    holders = {}
    for pages in pkv._slot_pages.values():
        for e in pages:
            if 0 < e < pkv.n_pages:
                holders[e] = holders.get(e, 0) + 1
    if pkv.prefix is not None:
        for e in pkv.prefix.entries():
            if e > 0:
                holders[e] = holders.get(e, 0) + 1
    assert holders == pkv._ref, (holders, pkv._ref)


_PREFIX_POOL = [tuple(range(1, 10)), tuple(range(1, 18)),
                tuple(range(1, 26)), tuple([5] * 17),
                tuple(range(100, 121))]


def _random_allocator_walk(seed):
    """Random admit_shared / register / CoW / evict / fault / release /
    reclaim sequences never double-free, never leak, and never free a
    page another holder still references — invariant-checked after
    every operation."""
    rng = np.random.default_rng(seed)
    cfg = smoke_variant(get("qwen3-8b"))
    pkv = PagedKVCache(cfg, 4, 32, dtype=jnp.float32, page_size=8,
                       n_pages=10)
    pkv.enable_prefix_sharing()
    pkv.attach_swap(SwapStore(capacity_bytes=1 << 24))
    cache = pkv.init_cache()
    live = {}                            # slot -> prompt

    def pick(xs):
        return xs[int(rng.integers(len(xs)))]

    for _ in range(int(rng.integers(8, 25))):
        ops = ["admit", "reclaim"]
        if live:
            ops += ["register", "cow", "evict", "fault", "release"]
        op = pick(ops)
        if op == "admit":
            free_slots = [s for s in range(4) if s not in live]
            if not free_slots:
                continue
            slot = pick(free_slots)
            prompt = list(pick(_PREFIX_POOL))
            try:
                cache, _ = pkv.admit_shared(cache, slot, prompt, 2)
            except OutOfPages:
                continue
            live[slot] = prompt
        elif op == "register":
            slot = pick(sorted(live))
            pkv.register_prefix(slot, live[slot],
                                int(rng.integers(len(live[slot]) + 1)))
        elif op == "cow":
            slot = pick(sorted(live))
            hi = len(pkv._slot_pages[slot]) * pkv.page_size - 1
            try:
                cache = pkv.make_writable(cache, slot, 0, hi)
            except OutOfPages:
                pass
        elif op == "evict":
            cache = pkv.evict(cache, pick(sorted(live)))
        elif op == "fault":
            try:
                cache = pkv.fault(cache, pick(sorted(live)))
            except OutOfPages:
                pass
        elif op == "release":
            slot = pick(sorted(live))
            cache = pkv.release(cache, slot)
            del live[slot]
        elif op == "reclaim":
            cache = pkv._reclaim_prefix(cache, 0,
                                        int(rng.integers(1, 5)))
        _check_invariants(pkv)
    for slot in sorted(live):
        cache = pkv.release(cache, slot)
    _check_invariants(pkv)
    # draining the index returns every raw page to the free list
    cache = pkv._reclaim_prefix(cache, 0, pkv.n_pages)
    _check_invariants(pkv)
    assert not any(e > 0 for e in pkv.prefix.entries())
    assert pkv.free_pages == pkv.n_pages - 1


def test_refcount_invariants_fixed_seeds():
    """Tier-1 anchor for the allocator property (no hypothesis needed)."""
    for seed in (0, 1, 7, 123):
        _random_allocator_walk(seed)


if given is not None:
    @given(st.integers(0, 2**31 - 1))
    def test_refcount_invariants_random_ops(seed):
        _random_allocator_walk(seed)
