"""Synthetic data pipeline: determinism and shard-assembly invariants."""
import numpy as np

from repro.data import DataConfig, SyntheticLMData


def _cfg(**kw):
    d = dict(vocab_size=1000, seq_len=32, global_batch=8, seed=7)
    d.update(kw)
    return DataConfig(**d)


def test_deterministic_across_instances():
    a = SyntheticLMData(_cfg()).batch_numpy(3)
    b = SyntheticLMData(_cfg()).batch_numpy(3)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    np.testing.assert_array_equal(a["labels"], b["labels"])


def test_batches_differ_by_index_and_seed():
    d = SyntheticLMData(_cfg())
    assert not np.array_equal(d.batch_numpy(0)["tokens"],
                              d.batch_numpy(1)["tokens"])
    d2 = SyntheticLMData(_cfg(seed=8))
    assert not np.array_equal(d.batch_numpy(0)["tokens"],
                              d2.batch_numpy(0)["tokens"])


def test_labels_are_next_tokens():
    b = SyntheticLMData(_cfg()).batch_numpy(0)
    # labels[t] continues tokens: generator produces T+1 and splits
    assert b["tokens"].shape == (8, 32)
    assert b["labels"].shape == (8, 32)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_row_sharded_generation_matches_full():
    """Any host generating its row slice gets exactly the full batch rows —
    the elastic-restart property (no data state to migrate)."""
    d = SyntheticLMData(_cfg())
    full = d.batch_numpy(5)
    lo = d.batch_numpy(5, rows=np.arange(0, 4))
    hi = d.batch_numpy(5, rows=np.arange(4, 8))
    np.testing.assert_array_equal(
        np.concatenate([lo["tokens"], hi["tokens"]]), full["tokens"])


def test_learnable_structure():
    """The stream is predictable: the same (state) prefix recurs, so a
    bigram table gets far below uniform entropy — guards against the
    pipeline silently emitting pure noise."""
    d = SyntheticLMData(_cfg(vocab_size=97, seq_len=512, global_batch=4))
    toks = d.batch_numpy(0)["tokens"].reshape(-1)
    pairs = {}
    for a, b in zip(toks[:-1], toks[1:]):
        pairs.setdefault(int(a), []).append(int(b))
    # for most contexts the most-common successor dominates
    acc = np.mean([max(np.bincount(v).max() / len(v), 0)
                   for v in pairs.values() if len(v) >= 5])
    assert acc > 0.5, acc
