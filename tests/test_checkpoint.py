"""Fault-tolerance contract: atomic, checksummed, async, elastic, ECF8."""
import glob
import json
import os
import subprocess
import sys

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager, restore_tree, save_tree

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "params": {"w": jax.random.normal(k, (32, 16)),
                   "norm": jnp.ones((16,))},
        "opt": {"mu": jnp.zeros((32, 16))},
        "step": jnp.asarray(seed, jnp.int32),
    }


def test_atomic_save_and_restore(tmp_path):
    d = str(tmp_path)
    t = _tree(3)
    save_tree(t, d, step=3)
    r, step = restore_tree(d, t)
    assert step == 3
    np.testing.assert_array_equal(np.asarray(r["params"]["w"]),
                                  np.asarray(t["params"]["w"]))


def test_crash_mid_write_leaves_no_visible_checkpoint(tmp_path):
    """A .tmp dir (simulated crash) is invisible to restore and GC'd."""
    d = str(tmp_path)
    os.makedirs(os.path.join(d, "step_00000009.tmp"))
    r, step = restore_tree(d, _tree())
    assert r is None and step == -1
    mgr = CheckpointManager(d, keep=2)
    mgr.save_sync(1, _tree(1))
    assert not glob.glob(os.path.join(d, "*.tmp"))
    mgr.close()


def test_corruption_falls_back_to_previous(tmp_path):
    d = str(tmp_path)
    save_tree(_tree(1), d, step=1)
    save_tree(_tree(2), d, step=2)
    p = os.path.join(d, "step_00000002", "manifest.json")
    with open(p) as f:
        m = json.load(f)
    next(iter(m["leaves"].values()))["crc32"] = 123
    with open(p, "w") as f:
        json.dump(m, f)
    r, step = restore_tree(d, _tree())
    assert step == 1
    assert int(r["step"]) == 1


def test_async_and_retention(tmp_path):
    d = str(tmp_path)
    mgr = CheckpointManager(d, keep=2)
    for s in range(5):
        mgr.save_async(s, _tree(s))
    mgr.wait()
    steps = sorted(int(p[-8:]) for p in
                   glob.glob(os.path.join(d, "step_*")))
    assert steps == [3, 4]
    mgr.close()


def test_ecf8_compressed_checkpoint_bit_exact(tmp_path):
    from repro.core import stats
    d = str(tmp_path)
    bits = stats.synthesize_fp8_weights((256, 128), alpha=1.9, seed=0)
    t = {"w8": jnp.asarray(bits).view(jnp.float8_e4m3fn).reshape(256, 128),
         "f32": jnp.ones((4,))}
    save_tree(t, d, step=0, compress="ecf8")
    # the compressed file must actually be smaller than the raw fp8 bytes
    z = glob.glob(os.path.join(d, "step_00000000", "ecf8_*.npz"))
    assert z, "fp8 leaf was not ECF8-compressed"
    r, _ = restore_tree(d, t)
    np.testing.assert_array_equal(
        np.asarray(r["w8"]).view(np.uint8),
        np.asarray(t["w8"]).view(np.uint8))


def test_elastic_restore_onto_different_sharding(tmp_path):
    """Save unsharded, restore onto a sharded layout (mesh-agnostic)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    d = str(tmp_path)
    t = _tree(5)
    save_tree(t, d, step=5)
    mesh = jax.make_mesh((1,), ("data",))
    sh = {
        "params": {"w": NamedSharding(mesh, P("data", None)),
                   "norm": NamedSharding(mesh, P(None))},
        "opt": {"mu": NamedSharding(mesh, P("data", None))},
        "step": NamedSharding(mesh, P()),
    }
    r, step = restore_tree(d, t, shardings=sh)
    assert step == 5
    assert r["params"]["w"].sharding == sh["params"]["w"]
    np.testing.assert_array_equal(np.asarray(r["params"]["w"]),
                                  np.asarray(t["params"]["w"]))


@pytest.mark.slow
def test_train_failure_restart_continuity(tmp_path):
    """Kill the trainer mid-run (os._exit), restart, and verify the run
    resumes from the checkpoint and completes — the restart drill."""
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    args = [sys.executable, "-m", "repro.launch.train", "--arch", "qwen3-8b",
            "--smoke", "--steps", "40", "--batch", "2", "--seq-len", "16",
            "--save-every", "10", "--log-every", "100",
            "--ckpt-dir", str(tmp_path)]
    p1 = subprocess.run(args + ["--fail-at-step", "25"], env=env,
                        capture_output=True, text=True, timeout=600)
    assert p1.returncode == 42, p1.stderr[-2000:]
    p2 = subprocess.run(args, env=env, capture_output=True, text=True,
                        timeout=600)
    assert p2.returncode == 0, p2.stderr[-2000:]
    assert "resumed from step 20 -> starting at 21" in p2.stdout, p2.stdout
    assert "done at step 39" in p2.stdout
