"""Fault-tolerance contract: atomic, checksummed, async, elastic, ECF8."""
import glob
import json
import os
import subprocess
import sys

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager, restore_tree, save_tree

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "params": {"w": jax.random.normal(k, (32, 16)),
                   "norm": jnp.ones((16,))},
        "opt": {"mu": jnp.zeros((32, 16))},
        "step": jnp.asarray(seed, jnp.int32),
    }


def test_atomic_save_and_restore(tmp_path):
    d = str(tmp_path)
    t = _tree(3)
    save_tree(t, d, step=3)
    r, step = restore_tree(d, t)
    assert step == 3
    np.testing.assert_array_equal(np.asarray(r["params"]["w"]),
                                  np.asarray(t["params"]["w"]))


def test_crash_mid_write_leaves_no_visible_checkpoint(tmp_path):
    """A .tmp dir (simulated crash) is invisible to restore and GC'd."""
    d = str(tmp_path)
    os.makedirs(os.path.join(d, "step_00000009.tmp"))
    r, step = restore_tree(d, _tree())
    assert r is None and step == -1
    mgr = CheckpointManager(d, keep=2)
    mgr.save_sync(1, _tree(1))
    assert not glob.glob(os.path.join(d, "*.tmp"))
    mgr.close()


def test_corruption_falls_back_to_previous(tmp_path):
    d = str(tmp_path)
    save_tree(_tree(1), d, step=1)
    save_tree(_tree(2), d, step=2)
    p = os.path.join(d, "step_00000002", "manifest.json")
    with open(p) as f:
        m = json.load(f)
    next(iter(m["leaves"].values()))["crc32"] = 123
    with open(p, "w") as f:
        json.dump(m, f)
    r, step = restore_tree(d, _tree())
    assert step == 1
    assert int(r["step"]) == 1


def test_async_and_retention(tmp_path):
    d = str(tmp_path)
    mgr = CheckpointManager(d, keep=2)
    for s in range(5):
        mgr.save_async(s, _tree(s))
    mgr.wait()
    steps = sorted(int(p[-8:]) for p in
                   glob.glob(os.path.join(d, "step_*")))
    assert steps == [3, 4]
    mgr.close()


def test_ecf8_compressed_checkpoint_bit_exact(tmp_path):
    from repro.core import stats
    d = str(tmp_path)
    bits = stats.synthesize_fp8_weights((256, 128), alpha=1.9, seed=0)
    t = {"w8": jnp.asarray(bits).view(jnp.float8_e4m3fn).reshape(256, 128),
         "f32": jnp.ones((4,))}
    save_tree(t, d, step=0, compress="ecf8")
    # the compressed file must actually be smaller than the raw fp8 bytes
    z = glob.glob(os.path.join(d, "step_00000000", "ecf8_*.npz"))
    assert z, "fp8 leaf was not ECF8-compressed"
    r, _ = restore_tree(d, t)
    np.testing.assert_array_equal(
        np.asarray(r["w8"]).view(np.uint8),
        np.asarray(t["w8"]).view(np.uint8))


def test_elastic_restore_onto_different_sharding(tmp_path):
    """Save unsharded, restore onto a sharded layout (mesh-agnostic)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    d = str(tmp_path)
    t = _tree(5)
    save_tree(t, d, step=5)
    mesh = jax.make_mesh((1,), ("data",))
    sh = {
        "params": {"w": NamedSharding(mesh, P("data", None)),
                   "norm": NamedSharding(mesh, P(None))},
        "opt": {"mu": NamedSharding(mesh, P("data", None))},
        "step": NamedSharding(mesh, P()),
    }
    r, step = restore_tree(d, t, shardings=sh)
    assert step == 5
    assert r["params"]["w"].sharding == sh["params"]["w"]
    np.testing.assert_array_equal(np.asarray(r["params"]["w"]),
                                  np.asarray(t["params"]["w"]))


def test_close_surfaces_pending_write_errors(tmp_path):
    """A failed async write queued right before close() must raise, not
    be silently appended to ._errors and dropped."""
    d = str(tmp_path)
    mgr = CheckpointManager(d, keep=2)
    mgr.save_async(0, _tree(0))
    mgr.wait()
    # an unserializable leaf makes the worker's save_tree raise
    bad = {"w": np.array([object()], dtype=object)}
    mgr.save_async(1, bad)
    with pytest.raises(IOError, match="async checkpoint writes"):
        mgr.close()


def test_available_steps_skips_stray_entries(tmp_path):
    """A non-numeric step_foo/ dir must not take down restore."""
    from repro.checkpoint.manager import available_steps
    d = str(tmp_path)
    save_tree(_tree(4), d, step=4)
    stray = os.path.join(d, "step_foo")
    os.makedirs(stray)
    with open(os.path.join(stray, "manifest.json"), "w") as f:
        f.write("{}")
    assert available_steps(d) == [4]
    r, step = restore_tree(d, _tree())
    assert step == 4 and int(r["step"]) == 4


@pytest.mark.slow
def test_concurrent_writers_do_not_destroy_each_other(tmp_path):
    """Interleaved save_async / save_sync / GC / restore on one directory:
    the regression drill for the tmp-dir race (worker GC used to rmtree
    the sync writer's half-written tmp)."""
    import threading

    d = str(tmp_path)
    mgr = CheckpointManager(d, keep=3)
    errors = []

    def sync_writer():
        try:
            for s in range(30, 45):
                mgr.save_sync(s, _tree(s))
        except Exception as e:  # noqa: BLE001 — collected for the assert
            errors.append(repr(e))

    def restorer():
        try:
            for _ in range(10):
                restore_tree(d, _tree())
        except Exception as e:  # noqa: BLE001
            errors.append(repr(e))

    threads = [threading.Thread(target=sync_writer),
               threading.Thread(target=restorer)]
    for s in range(15):
        mgr.save_async(s, _tree(s))  # each worker write runs _gc() too
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    mgr.wait()
    mgr.close()
    assert not errors, errors
    # every surviving checkpoint restores cleanly
    from repro.checkpoint.manager import available_steps
    steps = available_steps(d)
    assert steps, "no checkpoint survived the stress run"
    r, step = restore_tree(d, _tree())
    assert step == max(steps)
    assert int(r["step"]) == step
    # no unowned tmp litter once all writers are done
    mgr2 = CheckpointManager(d, keep=3)
    mgr2.save_sync(99, _tree(99))
    mgr2.close()
    assert not glob.glob(os.path.join(d, "*.tmp*"))


@pytest.mark.slow
def test_train_failure_restart_continuity(tmp_path):
    """Kill the trainer mid-run (os._exit), restart, and verify the run
    resumes from the checkpoint and completes — the restart drill."""
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    args = [sys.executable, "-m", "repro.launch.train", "--arch", "qwen3-8b",
            "--smoke", "--steps", "40", "--batch", "2", "--seq-len", "16",
            "--save-every", "10", "--log-every", "100",
            "--ckpt-dir", str(tmp_path)]
    p1 = subprocess.run(args + ["--fail-at-step", "25"], env=env,
                        capture_output=True, text=True, timeout=600)
    assert p1.returncode == 42, p1.stderr[-2000:]
    p2 = subprocess.run(args, env=env, capture_output=True, text=True,
                        timeout=600)
    assert p2.returncode == 0, p2.stderr[-2000:]
    assert "resumed from step 20 -> starting at 21" in p2.stdout, p2.stdout
    assert "done at step 39" in p2.stdout
