"""EngineConfig: scalar validation, the centralised gating matrix,
``from_args`` CLI mapping, and the legacy-kwargs deprecation shim
(ISSUE 9).

The gating matrix used to live as scattered warn-and-fall-back checks in
``GenerationEngine.__init__``; these tests pin the resolved fields and
warning texts for every row, in both lenient (warn + fall back) and
strict (one ``EngineConfigError`` listing all problems) modes.
"""
from types import SimpleNamespace

import pytest
import jax

from repro.configs import get, smoke_variant
from repro.serving import (EngineConfig, EngineConfigError,
                           GenerationEngine, Request)


@pytest.fixture(scope="module")
def arch():
    return smoke_variant(get("qwen3-8b"))       # all-'attn' stack


@pytest.fixture(scope="module")
def world(arch):
    from repro.models import model as M
    return M.init_params(jax.random.PRNGKey(0), arch), arch


# -- scalar field validation (construction time) ---------------------------

@pytest.mark.parametrize("kw, frag", [
    (dict(cache_mode="lru"), "cache_mode"),
    (dict(max_batch=0), "max_batch"),
    (dict(max_len=0), "max_len"),
    (dict(page_size=0), "page_size"),
    (dict(spec_k=0), "spec_k"),
])
def test_scalar_errors(kw, frag):
    with pytest.raises(EngineConfigError, match=frag):
        EngineConfig(**kw)


def test_scalar_errors_are_collected():
    with pytest.raises(EngineConfigError) as e:
        EngineConfig(max_batch=0, spec_k=-1)
    assert "max_batch" in str(e.value) and "spec_k" in str(e.value)


# -- the gating matrix -----------------------------------------------------

def test_arch_driven_resolution_is_silent():
    """A pure-recurrent stack has nothing to page: cache_mode resolves
    to monolithic with no warning — it is not a user error."""
    import warnings
    xl = smoke_variant(get("xlstm-350m"))
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        out = EngineConfig(cache_mode="paged").validate(xl)
    assert out.cache_mode == "monolithic"


def test_chunked_prefill_needs_paged_cache(arch):
    with pytest.warns(UserWarning, match="prefill_chunk"):
        out = EngineConfig(cache_mode="monolithic",
                           prefill_chunk=8).validate(arch)
    assert out.prefill_chunk == 0 and out.prefill_budget == 0


def test_prefill_chunk_clamped_and_budget_defaulted(arch):
    out = EngineConfig(max_len=32, prefill_chunk=100).validate(arch)
    assert out.prefill_chunk == 32          # clamped to max_len
    assert out.prefill_budget == 32         # budget defaults to the chunk
    out = EngineConfig(prefill_chunk=8, prefill_budget=24).validate(arch)
    assert (out.prefill_chunk, out.prefill_budget) == (8, 24)


def test_prefix_sharing_needs_chunked_prefill(arch):
    with pytest.warns(UserWarning, match="prefix_sharing"):
        out = EngineConfig(prefix_sharing=True).validate(arch)
    assert out.prefix_sharing is False


def test_speculative_incompatible_with_chunked_prefill(arch):
    draft = smoke_variant(get("qwen3-8b"))
    with pytest.warns(UserWarning, match="speculative"):
        out = EngineConfig(prefill_chunk=8, draft_cfg=draft,
                           draft_params=object()).validate(arch)
    assert out.draft_cfg is None and out.draft_params is None
    assert out.prefill_chunk == 8           # the chunk itself survives


def test_speculative_needs_same_vocab(arch):
    from dataclasses import replace
    draft = replace(smoke_variant(get("qwen3-8b")),
                    vocab_size=arch.vocab_size * 2)
    with pytest.warns(UserWarning, match="speculative"):
        out = EngineConfig(draft_cfg=draft,
                           draft_params=object()).validate(arch)
    assert out.draft_cfg is None


def test_strict_mode_collects_every_problem(arch):
    with pytest.raises(EngineConfigError) as e:
        EngineConfig(cache_mode="monolithic", prefill_chunk=8,
                     prefix_sharing=True,
                     draft_cfg=smoke_variant(get("qwen3-8b")),
                     draft_params=object()).validate(arch, strict=True)
    msg = str(e.value)
    assert msg.startswith("incompatible engine configuration:")
    for frag in ("prefill_chunk", "prefix_sharing", "speculative"):
        assert frag in msg, frag


def test_valid_config_resolves_unchanged(arch):
    from dataclasses import replace
    ecfg = EngineConfig(max_batch=4, max_len=64, prefill_chunk=8,
                        prefix_sharing=True)
    out = ecfg.validate(arch, strict=True)    # no warning, no error
    # identical up to budget resolution (None -> the chunk); frozen
    # dataclass equality compares the declarative fields only
    assert out == replace(ecfg, prefill_budget=8)
    assert out.validate(arch, strict=True) == out     # idempotent


# -- from_args CLI mapping -------------------------------------------------

def _args(**over):
    base = dict(max_batch=2, max_len=48, seed=0, cache="paged",
                page_size=16, n_pages=None, swap_bytes=None,
                preemption=True, prefill_chunk=0, prefill_budget=0,
                prefix_sharing=False, draft=None, spec_k=None,
                draft_seed=None)
    base.update(over)
    return SimpleNamespace(**base)


def test_from_args_spec_flags_require_draft():
    with pytest.raises(EngineConfigError, match="--spec-k"):
        EngineConfig.from_args(_args(spec_k=4))
    with pytest.raises(EngineConfigError,
                       match="--spec-k/--draft-seed have no effect"):
        EngineConfig.from_args(_args(spec_k=4, draft_seed=1))


def test_from_args_mapping_and_strict_validation(arch):
    ecfg = EngineConfig.from_args(
        _args(cache="paged-compressed", prefill_chunk=8), arch)
    assert ecfg.cache_mode == "paged" and ecfg.compress_cold
    assert ecfg.prefill_chunk == 8 and ecfg.prefill_budget == 8
    assert ecfg.spec_k == 4                  # default when flag unset
    # incompatible feature requests fail at parse time, not in the engine
    with pytest.raises(EngineConfigError, match="prefix_sharing"):
        EngineConfig.from_args(_args(prefix_sharing=True), arch)


def test_from_args_engine_round_trip(world):
    """args -> from_args -> engine: the engine serves the resolved
    config and generates."""
    params, arch = world
    ecfg = EngineConfig.from_args(_args(prefill_chunk=8), arch)
    eng = GenerationEngine(params, arch, config=ecfg)
    assert eng.config == ecfg and eng.prefill_chunk == 8
    r = Request(prompt=[1, 2, 3], max_new_tokens=3, id=7_500)
    eng.submit(r)
    eng.run()
    assert r.done and len(r.out_tokens) == 3


# -- constructor paths -----------------------------------------------------

def test_legacy_kwargs_deprecated_but_equivalent(world):
    params, arch = world
    with pytest.deprecated_call(match="EngineConfig"):
        legacy = GenerationEngine(params, arch, max_batch=2, max_len=32)
    modern = GenerationEngine(params, arch,
                              config=EngineConfig(max_batch=2, max_len=32))
    assert legacy.config == modern.config
    a, b = (Request(prompt=[1, 2], max_new_tokens=3, id=7_600)
            for _ in range(2))
    legacy.submit(a), legacy.run()
    modern.submit(b), modern.run()
    assert a.out_tokens == b.out_tokens


def test_legacy_kwargs_still_gated(world):
    """The deprecation shim routes through the same gating matrix."""
    params, arch = world
    with pytest.deprecated_call():
        with pytest.warns(UserWarning, match="prefix_sharing"):
            eng = GenerationEngine(params, arch, max_batch=2, max_len=32,
                                   prefix_sharing=True)
    assert eng.prefix_sharing is False


def test_config_and_legacy_kwargs_are_exclusive(world):
    params, arch = world
    with pytest.raises(TypeError, match="config"):
        GenerationEngine(params, arch, config=EngineConfig(), max_batch=2)


def test_draft_params_and_cfg_must_travel_together(world):
    params, arch = world
    with pytest.raises(ValueError, match="together"):
        GenerationEngine(
            params, arch,
            config=EngineConfig(draft_cfg=smoke_variant(get("qwen3-8b"))))
