"""Serving engine: continuous batching correctness, slot reuse, and the
oversubscribed swap/preemption tier.

Differential layer (ISSUE 3): random mixed-length, mixed-priority
workloads through the paged+compressed+swap engine must emit tokens
**bit-identical** per request to the monolithic-cache engine — including
runs sized to force eviction and whole-request preemption (hypothesis
property test; example budget raised by the ``ci`` profile, see
conftest.py), and on a 2-device CPU mesh (subprocess, marked slow).
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from conftest import run_subprocess

from repro.configs import get, smoke_variant
from repro.models import model as M
from repro.runtime.monitor import KVCacheMonitor
from repro.serving import EngineConfig, GenerationEngine, Request
from repro.serving.sampler import greedy, sample_logits

try:
    from hypothesis import given, strategies as st
except ImportError:          # tier-1 may run without hypothesis
    given = None


def _ref_greedy(params, cfg, prompt, n):
    toks = list(prompt)
    for _ in range(n):
        logits, _ = M.forward(params, cfg, jnp.asarray(toks, jnp.int32)[None])
        toks.append(int(jnp.argmax(logits[0, -1])))
    return toks[len(prompt):]


def test_engine_matches_full_forward_greedy():
    cfg = smoke_variant(get("qwen3-8b"))
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    eng = GenerationEngine(params, cfg, config=EngineConfig(max_batch=3, max_len=48))
    reqs = [Request(prompt=[1, 2, 3, 4], max_new_tokens=5),
            Request(prompt=[5, 6, 7], max_new_tokens=6),
            Request(prompt=[9, 10], max_new_tokens=4),
            Request(prompt=[11, 12, 13], max_new_tokens=4)]  # > max_batch
    for r in reqs:
        eng.submit(r)
    done = eng.run()
    assert all(r.done for r in done)
    for r in done:
        assert r.out_tokens == _ref_greedy(params, cfg, r.prompt,
                                           r.max_new_tokens), r.id


def test_engine_slot_reuse_and_occupancy():
    cfg = smoke_variant(get("xlstm-350m"))
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    eng = GenerationEngine(params, cfg, config=EngineConfig(max_batch=2, max_len=32))
    reqs = [Request(prompt=[i + 1], max_new_tokens=3) for i in range(5)]
    for r in reqs:
        eng.submit(r)
    done = eng.run()
    assert all(r.done for r in done)
    assert all(len(r.out_tokens) == 3 for r in done)
    # 5 requests x 3 tokens across batch-2 decode steps: slots were reused
    assert eng.steps < 15


def test_samplers():
    logits = jnp.asarray([[[0.0, 5.0, 1.0, -2.0]]])
    assert int(greedy(logits)[0, 0]) == 1
    t = sample_logits(logits, jax.random.PRNGKey(0), temperature=1e-4)
    assert int(t[0, 0]) == 1
    tk = sample_logits(jnp.tile(logits, (8, 1, 1)), jax.random.PRNGKey(1),
                       temperature=1.0, top_k=2)
    assert set(np.asarray(tk).reshape(-1).tolist()) <= {1, 2}


def test_splice_axes():
    """_splice picks the batch axis from the leaf's path: unit-stacked
    leaves carry it at axis 1, tail leaves at axis 0, and cur_len is a
    per-slot scalar write."""
    from repro.serving.engine import _splice
    full = jnp.zeros((3, 4, 2, 8, 5))            # (U, B, n_kv, T, hd)
    frag = jnp.ones((3, 1, 2, 8, 5))
    out = _splice(full, frag, 2, ["units", "pos0", "k"])
    assert float(out[:, 2].min()) == 1.0 and float(out[:, :2].max()) == 0.0

    full_t = jnp.zeros((4, 2, 8, 5))             # (B, n_kv, T, hd)
    out_t = _splice(full_t, jnp.ones((1, 2, 8, 5)), 1,
                    ["tail", "layer0", "v"])
    assert float(out_t[1].min()) == 1.0 and float(out_t[0].max()) == 0.0

    cur = _splice(jnp.zeros((4,), jnp.int32), jnp.asarray(7, jnp.int32), 3,
                  ["cur_len"])
    assert cur.tolist() == [0, 0, 0, 7]


def test_splice_fragment_roundtrips_prefill():
    """Splicing a single-row prefill fragment at slot s reproduces that
    request's cache content at batch row s for every leaf."""
    from repro.serving.engine import splice_fragment
    cfg = smoke_variant(get("gemma2-9b"))        # local+attn mixed pattern
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(2), (1, 6), 0,
                              cfg.vocab_size)
    _, frag = M.prefill(params, cfg, toks, max_len=16)
    cache = M.init_cache(cfg, 3, 16, dtype=jnp.float32, per_slot=True)
    cache = splice_fragment(cache, frag, 2)

    def batch_axis(path):
        names = [str(getattr(k, "key", getattr(k, "idx", k))) for k in path]
        return None if "cur_len" in names else (1 if "units" in names else 0)

    flat_c = jax.tree_util.tree_flatten_with_path(cache)[0]
    flat_f = jax.tree_util.tree_flatten(frag)[0]
    for (path, leaf), fr in zip(flat_c, flat_f):
        ax = batch_axis(path)
        if ax is None:
            continue
        got = jnp.take(leaf, jnp.asarray([2]), axis=ax)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(fr, np.float32))
        other = jnp.take(leaf, jnp.asarray([0, 1]), axis=ax)
        assert float(jnp.abs(other).max()) == 0.0


def test_engine_slot_reclamation_mixed_lengths():
    """Finished slots are reclaimed mid-stream (6 requests, 2 slots) and
    every request still matches the full-forward greedy reference."""
    cfg = smoke_variant(get("qwen3-8b"))
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    eng = GenerationEngine(params, cfg, config=EngineConfig(max_batch=2, max_len=48))
    reqs = [Request(prompt=[i + 1, i + 2, i + 3], max_new_tokens=n)
            for i, n in enumerate([2, 9, 4, 7, 3, 5])]
    for r in reqs:
        eng.submit(r)
    done = eng.run()
    assert all(r.done for r in done)
    for r in done:
        assert r.out_tokens == _ref_greedy(params, cfg, r.prompt,
                                           r.max_new_tokens), r.id
    # 30 tokens through 2 slots: reuse means well under 30 decode steps
    assert eng.steps < 25
    if eng.paged is not None:   # all pages returned to the pool
        assert eng.paged.free_pages == eng.paged.n_pages - 1
        assert not eng.paged._slot_pages


def test_run_returns_requests_admitted_before_run():
    """Regression: ``run()`` used to snapshot the queue, so requests
    already admitted to slots (e.g. by a manual ``step()``) were lost
    from its return value."""
    cfg = smoke_variant(get("qwen3-8b"))
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    eng = GenerationEngine(params, cfg, config=EngineConfig(max_batch=2, max_len=48))
    r1 = Request(prompt=[1, 2, 3], max_new_tokens=3)
    r2 = Request(prompt=[4, 5], max_new_tokens=3)
    eng.submit(r1)
    eng.submit(r2)
    eng.step()                       # both now sit in slots, queue empty
    done = eng.run()
    assert r1 in done and r2 in done
    assert all(r.done for r in (r1, r2))
    # late submissions are tracked independently of earlier returns
    r3 = Request(prompt=[7], max_new_tokens=2)
    eng.submit(r3)
    assert eng.run() == [r3] and r3.done


# --------------------------------------------------------------------------
# oversubscription: swap tier + preemptive scheduler (ISSUE 3)
# --------------------------------------------------------------------------

_OVERSUB = dict(cache_mode="paged", page_size=8, n_pages=5,
                compress_cold=True, n_cold_slots=1, swap_bytes=1 << 28)

# the canonical >= 2x-oversubscribed stream (kept in sync with
# benchmarks/kvcache_bench.py::OVERSUB_WORKLOAD, which also injects it
# into its sharded subprocess)
_OVERSUB_WL = (
    [[i + 1] * (7 + 3 * (i % 3)) for i in range(6)],    # prompts
    [14, 10, 16, 9, 12, 11],                            # max_new_tokens
    [0, 1, 0, 2, 1, 0],                                 # priorities
)


def _oversub_requests(id_base=5_000):
    prompts, news, prios = _OVERSUB_WL
    return [Request(prompt=p, max_new_tokens=n, priority=pr,
                    id=id_base + i)
            for i, (p, n, pr) in enumerate(zip(prompts, news, prios))]


def _serve(params, cfg, reqs, **kw):
    eng = GenerationEngine(params, cfg, config=EngineConfig(max_batch=2, max_len=48, **kw))
    for r in reqs:
        eng.submit(r)
    eng.run()
    assert all(r.done for r in reqs)
    return [r.out_tokens for r in reqs], eng


def test_oversubscribed_workload_completes_bit_identical():
    """Acceptance: aggregate page demand >= 2x ``n_pages`` completes
    without OutOfPages via eviction + whole-request preemption, and every
    request's tokens are bit-identical to the monolithic reference."""
    cfg = smoke_variant(get("qwen3-8b"))
    params = M.init_params(jax.random.PRNGKey(0), cfg)

    stream = _oversub_requests
    mono, _ = _serve(params, cfg, stream(), cache_mode="monolithic")
    mon = KVCacheMonitor()
    over, eng = _serve(params, cfg, stream(), kv_monitor=mon, **_OVERSUB)
    demand = sum(eng.paged.pages_worst_case(len(r.prompt), r.max_new_tokens)
                 for r in stream())
    assert demand >= 2 * eng.paged.n_pages, (demand, eng.paged.n_pages)
    assert over == mono
    s = mon.summary()
    assert s["n_preempted"] > 0 and s["n_resumed"] > 0
    assert s["swap_out_bytes_total"] > 0
    assert s["swap_in_bytes_total"] == s["swap_out_bytes_total"]
    # everything drained: no host-resident swap, full free lists
    assert len(eng.paged.swap) == 0 and eng.paged.swap.bytes_used == 0
    assert eng.paged.free_pages == eng.paged.n_pages - 1


def test_priority_classes_preempt_lower_priority_work():
    """A late high-priority request preempts running priority-0 work (the
    victim is swapped out wholesale and still finishes bit-identically)."""
    cfg = smoke_variant(get("qwen3-8b"))
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    lo = [Request(prompt=[i + 1] * 9, max_new_tokens=14, priority=0,
                  id=6_000 + i) for i in range(2)]
    hi = Request(prompt=[40] * 9, max_new_tokens=8, priority=5, id=6_100)

    ref = {}
    for r in lo + [hi]:
        mono, _ = _serve(params, cfg,
                         [Request(prompt=list(r.prompt),
                                  max_new_tokens=r.max_new_tokens,
                                  id=r.id)],
                         cache_mode="monolithic")
        ref[r.id] = mono[0]

    eng = GenerationEngine(params, cfg, config=EngineConfig(max_batch=2, max_len=48, **_OVERSUB))
    for r in lo:
        eng.submit(r)
    for _ in range(3):               # both low-priority requests running
        eng.step()
    eng.submit(hi)
    eng.step()                       # admission preemption kicks one out
    assert eng.scheduler.n_preempted >= 1
    assert hi in eng.slots
    eng.run()
    for r in lo + [hi]:
        assert r.done and r.out_tokens == ref[r.id], r.id


def test_scheduler_never_places_request_on_shard_it_outgrows():
    """Regression: a request whose worst-case working set fits shard 1
    (capacity 4) but not shard 0 (capacity 3 — the garbage page) must
    not be placed on a shard-0 slot just because its *prompt* fits —
    that wedges mid-flight with nothing to preempt."""
    from repro.kvcache import PagedKVCache, SwapStore
    from repro.serving.scheduler import Scheduler
    cfg = smoke_variant(get("qwen3-8b"))
    pkv = PagedKVCache(cfg, 4, 64, dtype=jnp.float32, page_size=16,
                       n_pages=8, n_shards=2)
    pkv.attach_swap(SwapStore())
    sched = Scheduler(paged=pkv)
    big = Request(prompt=[1] * 10, max_new_tokens=54, id=9_400)
    assert pkv.pages_worst_case(10, 54) == 4      # > shard 0's capacity 3
    sched.submit(big)
    assert sched.pick(0) is None and sched.pick(1) is None   # shard 0
    assert sched.pick(2) is big                              # shard 1
    # a shard-0-sized request still lands on shard 0
    small = Request(prompt=[1] * 10, max_new_tokens=8, id=9_401)
    sched.submit(small)
    assert sched.pick(0) is small


def test_hybrid_arch_preemption_preserves_nonpaged_state():
    """Preemption must stash and restore a hybrid architecture's
    *non-paged* per-slot cache state: gemma2's local-attention ring
    buffers live in monolithic batch-dim leaves next to the page pools
    (only 'attn'/'nope' layers page), would be clobbered by the next
    request admitted to the slot, and carry no page ids for the swap
    tier to save.  Regression for `Preempted.state` /
    `snapshot_slot_state`."""
    cfg = smoke_variant(get("gemma2-9b"))        # ('local','attn') pattern
    params = M.init_params(jax.random.PRNGKey(0), cfg)

    def stream():
        return [Request(prompt=[i + 1] * (7 + 3 * (i % 3)),
                        max_new_tokens=n, priority=pr, id=9_500 + i)
                for i, (n, pr) in enumerate(
                    zip([14, 10, 16, 9], [0, 1, 0, 2]))]

    mono, _ = _serve(params, cfg, stream(), cache_mode="monolithic")
    over, eng = _serve(params, cfg, stream(), **_OVERSUB)
    assert eng.cache_mode == "paged"
    assert eng.scheduler.n_preempted > 0, "no preemption - test is vacuous"
    assert over == mono


def test_page_boundary_prompt_swap_roundtrip_bit_identical():
    """A prompt of exactly k * page_size tokens (its fragment exactly
    fills its pages) survives compress -> swap -> restore: preempting the
    slot mid-generation and resuming changes no output bit."""
    cfg = smoke_variant(get("qwen3-8b"))
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    for k in (1, 2):
        prompt = list(range(1, 8 * k + 1))       # page_size below is 8
        req = Request(prompt=list(prompt), max_new_tokens=10, id=7_000 + k)
        mono, _ = _serve(params, cfg,
                         [Request(prompt=list(prompt), max_new_tokens=10,
                                  id=req.id)],
                         cache_mode="monolithic")
        eng = GenerationEngine(params, cfg, config=EngineConfig(max_batch=2, max_len=48,
                               **_OVERSUB))
        eng.submit(req)
        for _ in range(3):
            eng.step()
        slot = eng.slots.index(req)
        assert eng._preempt(slot)                # force the swap round trip
        assert req not in eng.slots
        eng.run()
        assert req.done and req.out_tokens == mono[0], k
        assert eng.scheduler.n_resumed >= 1


def _check_differential_workload(wl, seed, prefill_chunk=0):
    """Differential core: a workload of (prompt_len, max_new, priority,
    temperature) tuples through the paged+compressed+swap engine emits
    per-request tokens bit-identical to the monolithic engine.  The tiny
    pool (5 pages, 1 cold slot) makes most workloads force eviction and
    preemption; sampling keys fold (seed, request.id, position) so even
    sampled requests are schedule-invariant.  ``prefill_chunk`` > 0 runs
    the chunked, decode-interleaved prefill path — same invariant."""
    cfg = smoke_variant(get("qwen3-8b"))
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(1, cfg.vocab_size, size=p).tolist()
               for p, _, _, _ in wl]

    def stream():
        return [Request(prompt=list(prompts[i]), max_new_tokens=n,
                        priority=pr, temperature=t, id=8_000 + i)
                for i, (_, n, pr, t) in enumerate(wl)]

    mono, _ = _serve(params, cfg, stream(), cache_mode="monolithic")
    over, eng = _serve(params, cfg, stream(), prefill_chunk=prefill_chunk,
                       **_OVERSUB)
    assert over == mono
    assert len(eng.paged.swap) == 0              # swap fully drained
    if prefill_chunk:
        assert eng.prefill_chunk == prefill_chunk and eng.n_chunks > 0
    return eng


def test_differential_fixed_workloads_bit_identical():
    """Tier-1 anchor for the differential property (no hypothesis
    needed): two hand-picked workloads — one sized to force eviction and
    preemption, one mixing sampled and greedy requests."""
    eng = _check_differential_workload(
        [(20, 12, 1, 0.0), (16, 10, 2, 0.0), (9, 12, 0, 0.0),
         (14, 8, 0, 0.0)], seed=123)
    assert eng.scheduler.n_preempted > 0         # the point of the sizing
    _check_differential_workload(
        [(3, 8, 0, 0.8), (5, 6, 1, 0.0), (2, 5, 0, 0.8)], seed=7)


# --------------------------------------------------------------------------
# chunked, decode-interleaved prefill (ISSUE 4)
# --------------------------------------------------------------------------


def test_chunked_prefill_fixed_workloads_bit_identical():
    """Tier-1 anchor: the chunked-prefill engine (chunk smaller than most
    prompts, so multi-chunk prefill really happens and interleaves with
    decode) emits tokens bit-identical to the monolithic engine, under
    oversubscription with eviction and preemption."""
    eng = _check_differential_workload(
        [(20, 12, 1, 0.0), (16, 10, 2, 0.0), (9, 12, 0, 0.0),
         (14, 8, 0, 0.0)], seed=123, prefill_chunk=4)
    assert eng.scheduler.n_preempted > 0
    assert eng.n_interleaved_steps > 0           # prefill mixed with decode
    _check_differential_workload(                # sampled + greedy mix
        [(13, 8, 0, 0.8), (5, 6, 1, 0.0), (18, 5, 0, 0.8)], seed=7,
        prefill_chunk=8)


def test_chunked_prefill_exactly_one_compile_across_lengths():
    """Regression (the recompile-per-prompt-length failure mode must
    never return silently): a mixed-length stream through the chunked
    engine traces **exactly one** prefill program — counted on the jitted
    chunk step itself — where the whole-prompt path would trace one per
    distinct length.  A second engine with the same shape shares the
    cached program (zero new traces)."""
    cfg = smoke_variant(get("qwen3-8b"))
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    # max_len=40 is this test's own jit-cache key; compress_cold=False so
    # no second (cold-pool) trace can appear
    kw = dict(max_batch=2, max_len=40, page_size=8, prefill_chunk=8)

    def serve(lens, id_base):
        eng = GenerationEngine(params, cfg, config=EngineConfig(**kw))
        reqs = [Request(prompt=[(i * 7 + j) % 50 + 1 for j in range(n)],
                        max_new_tokens=3, id=id_base + i)
                for i, n in enumerate(lens)]
        for r in reqs:
            eng.submit(r)
        eng.run()
        assert all(r.done for r in reqs)
        return eng

    eng = serve([3, 7, 12, 17, 25, 31], id_base=11_000)
    assert eng.n_chunks >= 10
    assert eng.prefill_compile_count() == 1, eng.prefill_compile_count()
    eng2 = serve([5, 9, 2, 33], id_base=11_100)   # new lengths, same program
    assert eng2.prefill_compile_count() == 1, eng2.prefill_compile_count()


def test_chunked_midprefill_preempt_resume_bit_identical():
    """A request preempted **mid-prefill** (Preempted.prefill_pos set)
    swaps its first chunks out, requeues, resumes prefill at the recorded
    position and finishes bit-identical to an unpreempted run."""
    cfg = smoke_variant(get("qwen3-8b"))
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    req = Request(prompt=list(range(1, 21)), max_new_tokens=8, id=12_000)
    ref, _ = _serve(params, cfg,
                    [Request(prompt=list(req.prompt), max_new_tokens=8,
                             id=req.id)], cache_mode="monolithic")
    eng = GenerationEngine(params, cfg, config=EngineConfig(max_batch=2, max_len=48,
                           prefill_chunk=4, prefill_budget=4, **_OVERSUB))
    eng.submit(req)
    eng.step()                                   # one 4-token chunk in
    slot = eng.slots.index(req)
    assert eng._prefill_pos[slot] == 4
    assert eng._preempt(slot)                    # force mid-prefill preempt
    assert req not in eng.slots and not req.out_tokens
    st = eng.scheduler.head()
    assert st.prefill_pos == 4 and st.prefill_tokens_left == 16
    eng.run()
    assert req.done and req.out_tokens == ref[0]
    assert eng.scheduler.n_resumed >= 1


def test_scheduler_token_budget_blocks_new_prefill_work():
    """pick() with an exhausted prefill budget admits only zero-prefill
    items (decode-phase resumes); a budget-blocked class head blocks its
    class, preserving FIFO."""
    from repro.kvcache import PagedKVCache, SwapStore
    from repro.serving.scheduler import Preempted, Scheduler
    cfg = smoke_variant(get("qwen3-8b"))
    pkv = PagedKVCache(cfg, 2, 64, dtype=jnp.float32, page_size=16)
    pkv.attach_swap(SwapStore())
    sched = Scheduler(paged=pkv, chunk_tokens=8)
    a = Request(prompt=[1] * 10, max_new_tokens=4, id=13_000)
    sched.submit(a)
    assert sched.pick(0, prefill_budget=0) is None       # needs prefill
    assert sched.pick(0, prefill_budget=8) is a
    # a decode-phase resume admits even with no budget left
    done = Preempted(req=Request(prompt=[1] * 4, max_new_tokens=4,
                                 id=13_001),
                     pages=[], skip=set(), host_len=5, last_tok=3)
    sched.requeue(done)
    assert sched.prefill_tokens(done) == 0
    assert sched.pick(1, prefill_budget=0) is done


if given is not None:
    workloads = st.lists(
        st.tuples(st.integers(1, 20),            # prompt length
                  st.integers(2, 12),            # max_new_tokens
                  st.integers(0, 2),             # priority class
                  st.sampled_from([0.0, 0.0, 0.0, 0.8])),  # temperature
        min_size=3, max_size=6)

    @given(workloads, st.integers(0, 2**31 - 1))
    def test_differential_random_workloads_bit_identical(wl, seed):
        _check_differential_workload(wl, seed)

    @given(workloads, st.integers(1, 12), st.integers(0, 2**31 - 1))
    def test_chunked_random_workloads_bit_identical(wl, chunk, seed):
        """Property: for any (prompt length, chunk size, priority,
        temperature) mix — chunks bigger, smaller and incommensurate
        with the page size — the chunked engine is bit-identical to the
        monolithic reference, including runs that preempt mid-prefill."""
        _check_differential_workload(wl, seed, prefill_chunk=chunk)


@pytest.mark.slow
def test_oversubscribed_sharded_bit_identical():
    """Acceptance: the oversubscribed workload on a 2-device data mesh
    (per-shard free lists + per-shard swap ledgers) completes with
    preemption and stays bit-identical to the single-device monolithic
    reference."""
    run_subprocess("""
        import numpy as np, jax
        from jax.sharding import Mesh
        from repro.configs import get, smoke_variant
        from repro.models import model as M
        from repro.runtime.monitor import KVCacheMonitor
        from repro.serving import EngineConfig, GenerationEngine, Request

        cfg = smoke_variant(get('qwen3-8b'))
        params = M.init_params(jax.random.PRNGKey(0), cfg)

        def stream():
            prompts, news, prios = __OVERSUB_WL__
            return [Request(prompt=p, max_new_tokens=n, priority=pr,
                            id=9_000 + i)
                    for i, (p, n, pr) in enumerate(
                        zip(prompts, news, prios))]

        def serve(mesh, **kw):
            mon = KVCacheMonitor()
            eng = GenerationEngine(params, cfg, config=EngineConfig(max_batch=4, max_len=48,
                                   kv_monitor=mon, mesh=mesh, **kw))
            reqs = stream()
            for r in reqs:
                eng.submit(r)
            eng.run()
            assert all(r.done for r in reqs)
            return [r.out_tokens for r in reqs], eng, mon

        mono, _, _ = serve(None, cache_mode='monolithic')
        mesh = Mesh(np.array(jax.devices()[:2]), ('data',))
        over, eng, mon = serve(mesh, cache_mode='paged', page_size=8,
                               n_pages=8, compress_cold=True,
                               n_cold_slots=2, swap_bytes=1 << 28)
        assert eng.cache_mode == 'paged' and eng.paged.n_shards == 2
        demand = sum(eng.paged.pages_worst_case(len(r.prompt),
                                                r.max_new_tokens)
                     for r in stream())
        assert demand >= 2 * eng.paged.n_pages
        assert over == mono, (over, mono)
        s = mon.summary()
        assert s['n_preempted'] > 0 and s['swap_in_bytes_total'] > 0
        assert len(eng.paged.swap) == 0
        print('oversubscribed sharded == single-device monolithic: OK')
    """.replace("__OVERSUB_WL__", repr(_OVERSUB_WL)), devices=2)


@pytest.mark.slow
def test_chunked_prefill_sharded_bit_identical():
    """Acceptance: the chunked-prefill engine on a 2-device data mesh
    (owner-shard chunk writes, psum'd outputs) serves the oversubscribed
    mixed-length workload bit-identical to the single-device monolithic
    reference, with preemption mid-run and a bounded number of chunk
    compilations across all prompt lengths."""
    run_subprocess("""
        import numpy as np, jax
        from jax.sharding import Mesh
        from repro.configs import get, smoke_variant
        from repro.models import model as M
        from repro.serving import EngineConfig, GenerationEngine, Request

        cfg = smoke_variant(get('qwen3-8b'))
        params = M.init_params(jax.random.PRNGKey(0), cfg)

        def stream(extra=0):
            prompts, news, prios = __OVERSUB_WL__
            return [Request(prompt=p + [1] * extra, max_new_tokens=n,
                            priority=pr, id=14_000 + 100 * extra + i)
                    for i, (p, n, pr) in enumerate(
                        zip(prompts, news, prios))]

        def serve(mesh, reqs, **kw):
            eng = GenerationEngine(params, cfg, config=EngineConfig(max_batch=4, max_len=48,
                                   mesh=mesh, **kw))
            for r in reqs:
                eng.submit(r)
            eng.run()
            assert all(r.done for r in reqs)
            return [r.out_tokens for r in reqs], eng

        kw = dict(cache_mode='paged', page_size=8, n_pages=8,
                  compress_cold=True, n_cold_slots=2, swap_bytes=1 << 28,
                  prefill_chunk=4)
        mesh = Mesh(np.array(jax.devices()[:2]), ('data',))
        mono, _ = serve(None, stream(), cache_mode='monolithic')
        over, eng = serve(mesh, stream(), **kw)
        assert eng.cache_mode == 'paged' and eng.paged.n_shards == 2
        assert eng.prefill_chunk == 4 and eng.n_chunks > 0
        assert over == mono, (over, mono)
        assert eng.scheduler.n_preempted > 0
        c1 = eng.prefill_compile_count()
        # new prompt lengths reuse the same chunk program(s): the count
        # must not grow with the length mix (<= 2 traces per cold/no-cold
        # cache variant, sharding-commit included)
        mono2, _ = serve(None, stream(extra=3), cache_mode='monolithic')
        over2, eng2 = serve(mesh, stream(extra=3), **kw)
        assert over2 == mono2
        assert eng2.prefill_compile_count() == c1, (
            eng2.prefill_compile_count(), c1)
        print('chunked sharded == single-device monolithic: OK')
    """.replace("__OVERSUB_WL__", repr(_OVERSUB_WL)), devices=2)


def test_per_slot_cache_decode_matches_scalar():
    """Per-slot timelines with equal lengths must equal the shared path."""
    cfg = smoke_variant(get("gemma2-9b"))
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 6), 0,
                              cfg.vocab_size)
    _, cache_s = M.prefill(params, cfg, toks, max_len=16)
    # build per-slot cache with vector cur_len
    cache_v = dict(cache_s)
    cache_v["cur_len"] = jnp.full((2,), 6, jnp.int32)
    nxt = jnp.asarray([[3], [7]], jnp.int32)
    ls, _ = M.decode_step(params, cfg, nxt, cache_s)
    lv, _ = M.decode_step(params, cfg, nxt, cache_v)
    np.testing.assert_allclose(np.asarray(ls), np.asarray(lv), atol=1e-5)
