"""Serving engine: continuous batching correctness and slot reuse."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get, smoke_variant
from repro.models import model as M
from repro.serving import GenerationEngine, Request
from repro.serving.sampler import greedy, sample_logits


def _ref_greedy(params, cfg, prompt, n):
    toks = list(prompt)
    for _ in range(n):
        logits, _ = M.forward(params, cfg, jnp.asarray(toks, jnp.int32)[None])
        toks.append(int(jnp.argmax(logits[0, -1])))
    return toks[len(prompt):]


def test_engine_matches_full_forward_greedy():
    cfg = smoke_variant(get("qwen3-8b"))
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    eng = GenerationEngine(params, cfg, max_batch=3, max_len=48)
    reqs = [Request(prompt=[1, 2, 3, 4], max_new_tokens=5),
            Request(prompt=[5, 6, 7], max_new_tokens=6),
            Request(prompt=[9, 10], max_new_tokens=4),
            Request(prompt=[11, 12, 13], max_new_tokens=4)]  # > max_batch
    for r in reqs:
        eng.submit(r)
    done = eng.run()
    assert all(r.done for r in done)
    for r in done:
        assert r.out_tokens == _ref_greedy(params, cfg, r.prompt,
                                           r.max_new_tokens), r.id


def test_engine_slot_reuse_and_occupancy():
    cfg = smoke_variant(get("xlstm-350m"))
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    eng = GenerationEngine(params, cfg, max_batch=2, max_len=32)
    reqs = [Request(prompt=[i + 1], max_new_tokens=3) for i in range(5)]
    for r in reqs:
        eng.submit(r)
    done = eng.run()
    assert all(r.done for r in done)
    assert all(len(r.out_tokens) == 3 for r in done)
    # 5 requests x 3 tokens across batch-2 decode steps: slots were reused
    assert eng.steps < 15


def test_samplers():
    logits = jnp.asarray([[[0.0, 5.0, 1.0, -2.0]]])
    assert int(greedy(logits)[0, 0]) == 1
    t = sample_logits(logits, jax.random.PRNGKey(0), temperature=1e-4)
    assert int(t[0, 0]) == 1
    tk = sample_logits(jnp.tile(logits, (8, 1, 1)), jax.random.PRNGKey(1),
                       temperature=1.0, top_k=2)
    assert set(np.asarray(tk).reshape(-1).tolist()) <= {1, 2}


def test_splice_axes():
    """_splice picks the batch axis from the leaf's path: unit-stacked
    leaves carry it at axis 1, tail leaves at axis 0, and cur_len is a
    per-slot scalar write."""
    from repro.serving.engine import _splice
    full = jnp.zeros((3, 4, 2, 8, 5))            # (U, B, n_kv, T, hd)
    frag = jnp.ones((3, 1, 2, 8, 5))
    out = _splice(full, frag, 2, ["units", "pos0", "k"])
    assert float(out[:, 2].min()) == 1.0 and float(out[:, :2].max()) == 0.0

    full_t = jnp.zeros((4, 2, 8, 5))             # (B, n_kv, T, hd)
    out_t = _splice(full_t, jnp.ones((1, 2, 8, 5)), 1,
                    ["tail", "layer0", "v"])
    assert float(out_t[1].min()) == 1.0 and float(out_t[0].max()) == 0.0

    cur = _splice(jnp.zeros((4,), jnp.int32), jnp.asarray(7, jnp.int32), 3,
                  ["cur_len"])
    assert cur.tolist() == [0, 0, 0, 7]


def test_splice_fragment_roundtrips_prefill():
    """Splicing a single-row prefill fragment at slot s reproduces that
    request's cache content at batch row s for every leaf."""
    from repro.serving.engine import splice_fragment
    cfg = smoke_variant(get("gemma2-9b"))        # local+attn mixed pattern
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(2), (1, 6), 0,
                              cfg.vocab_size)
    _, frag = M.prefill(params, cfg, toks, max_len=16)
    cache = M.init_cache(cfg, 3, 16, dtype=jnp.float32, per_slot=True)
    cache = splice_fragment(cache, frag, 2)

    def batch_axis(path):
        names = [str(getattr(k, "key", getattr(k, "idx", k))) for k in path]
        return None if "cur_len" in names else (1 if "units" in names else 0)

    flat_c = jax.tree_util.tree_flatten_with_path(cache)[0]
    flat_f = jax.tree_util.tree_flatten(frag)[0]
    for (path, leaf), fr in zip(flat_c, flat_f):
        ax = batch_axis(path)
        if ax is None:
            continue
        got = jnp.take(leaf, jnp.asarray([2]), axis=ax)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(fr, np.float32))
        other = jnp.take(leaf, jnp.asarray([0, 1]), axis=ax)
        assert float(jnp.abs(other).max()) == 0.0


def test_engine_slot_reclamation_mixed_lengths():
    """Finished slots are reclaimed mid-stream (6 requests, 2 slots) and
    every request still matches the full-forward greedy reference."""
    cfg = smoke_variant(get("qwen3-8b"))
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    eng = GenerationEngine(params, cfg, max_batch=2, max_len=48)
    reqs = [Request(prompt=[i + 1, i + 2, i + 3], max_new_tokens=n)
            for i, n in enumerate([2, 9, 4, 7, 3, 5])]
    for r in reqs:
        eng.submit(r)
    done = eng.run()
    assert all(r.done for r in done)
    for r in done:
        assert r.out_tokens == _ref_greedy(params, cfg, r.prompt,
                                           r.max_new_tokens), r.id
    # 30 tokens through 2 slots: reuse means well under 30 decode steps
    assert eng.steps < 25
    if eng.paged is not None:   # all pages returned to the pool
        assert eng.paged.free_pages == eng.paged.n_pages - 1
        assert not eng.paged._slot_pages


def test_per_slot_cache_decode_matches_scalar():
    """Per-slot timelines with equal lengths must equal the shared path."""
    cfg = smoke_variant(get("gemma2-9b"))
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 6), 0,
                              cfg.vocab_size)
    _, cache_s = M.prefill(params, cfg, toks, max_len=16)
    # build per-slot cache with vector cur_len
    cache_v = dict(cache_s)
    cache_v["cur_len"] = jnp.full((2,), 6, jnp.int32)
    nxt = jnp.asarray([[3], [7]], jnp.int32)
    ls, _ = M.decode_step(params, cfg, nxt, cache_s)
    lv, _ = M.decode_step(params, cfg, nxt, cache_v)
    np.testing.assert_allclose(np.asarray(ls), np.asarray(lv), atol=1e-5)
