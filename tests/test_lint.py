"""repro-lint framework tests (tools/lint/ — see docs/LINTS.md).

Each pass gets fixture trees with a seeded violation (the pass must
fire) and a known-good twin (it must stay silent); plus suppression,
baseline, and cache round-trips, CLI exit semantics, and the live-tree
self-check that the analyzer's gate (`python -m tools.lint --check`)
holds on this repo with an empty baseline for serving/ and kvcache/.
"""
from __future__ import annotations

import json
import os
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if REPO not in sys.path:
    sys.path.insert(0, REPO)

from tools.lint import PASSES, run_lint  # noqa: E402
from tools.lint.runner import main as lint_main, write_baseline  # noqa: E402


def write_tree(root, files):
    for rel, text in files.items():
        path = os.path.join(root, rel)
        os.makedirs(os.path.dirname(path), exist_ok=True)
        with open(path, "w", encoding="utf-8") as fh:
            fh.write(textwrap.dedent(text))
    return root


def lint(root, **kw):
    kw.setdefault("use_cache", False)
    return run_lint(str(root), **kw)


def rules_of(result):
    return sorted({f.rule for f in result["new"]})


# ---------------------------------------------------------------------------
# jit-discipline
# ---------------------------------------------------------------------------

def test_jit_cache_discipline_fires_on_uncached_jit(tmp_path):
    write_tree(tmp_path, {"src/mod.py": """\
        import jax

        def fn(x):
            return x

        def hot_path(x):
            return jax.jit(fn)(x)
        """})
    res = lint(tmp_path)
    assert rules_of(res) == ["jit-cache-discipline"]
    (f,) = res["new"]
    assert f.path == "src/mod.py" and "hot_path" in f.message


def test_jit_cache_discipline_known_good_shapes(tmp_path):
    # module level, decorator, cache-store, factory return, AOT .lower
    write_tree(tmp_path, {"src/mod.py": """\
        import jax
        from functools import partial

        _CACHE: dict = {}

        @partial(jax.jit, static_argnames=("n",))
        def decorated(x, n):
            return x

        def fn(x):
            return x

        top = jax.jit(fn)

        def cached(key):
            if key not in _CACHE:
                _CACHE[key] = jax.jit(fn)
            return _CACHE[key]

        def make_step(cfg):
            def step(x):
                return x + cfg
            return jax.jit(step)

        def aot(x):
            return jax.jit(fn).lower(x)
        """})
    res = lint(tmp_path)
    assert res["new"] == []


def test_shard_map_inside_traced_function_is_compliant(tmp_path):
    write_tree(tmp_path, {"src/mod.py": """\
        import jax
        from jax.experimental.shard_map import shard_map

        def inner(x):
            return shard_map(lambda v: v, mesh=None,
                             in_specs=None, out_specs=None)(x)

        @jax.jit
        def entry(x):
            return inner(x)
        """})
    assert lint(tmp_path)["new"] == []


def test_jit_host_sync_fires_inside_traced_body(tmp_path):
    write_tree(tmp_path, {"src/mod.py": """\
        import jax
        import jax.numpy as jnp
        import numpy as np

        @jax.jit
        def traced(x):
            y = jnp.exp(x)
            return float(y), np.asarray(jnp.cumsum(y)), y.sum().item()
        """})
    res = lint(tmp_path)
    assert rules_of(res) == ["jit-host-sync"]
    msgs = " | ".join(f.message for f in res["new"])
    assert "float" in msgs and "np.asarray" in msgs and ".item()" in msgs


def test_jit_host_sync_ignores_static_config_math(tmp_path):
    # np over config attrs / mesh shapes is host-static, never flagged
    write_tree(tmp_path, {"src/mod.py": """\
        import jax
        import numpy as np

        @jax.jit
        def traced(x, cfg):
            scale = np.sqrt(cfg.d_model)
            n = int(np.prod([4, 8]))
            return x * scale * n
        """})
    assert lint(tmp_path)["new"] == []


def test_eager_loop_sync_fires_in_serving_host_loop(tmp_path):
    write_tree(tmp_path, {"src/repro/serving/mod.py": """\
        import jax
        import jax.numpy as jnp

        def host_loop(keys):
            out = []
            for k in keys:
                out.append(float(jax.random.uniform(k)))
            return out
        """})
    res = lint(tmp_path)
    assert rules_of(res) == ["eager-loop-sync"]


def test_eager_loop_sync_silent_on_hoisted_batch_draw(tmp_path):
    write_tree(tmp_path, {"src/repro/serving/mod.py": """\
        import jax
        import jax.numpy as jnp
        import numpy as np

        def host_loop(keys):
            us = np.asarray(jax.vmap(jax.random.uniform)(jnp.stack(keys)))
            return [float(u) for u in us.tolist()]
        """})
    assert lint(tmp_path)["new"] == []


# ---------------------------------------------------------------------------
# prng-discipline
# ---------------------------------------------------------------------------

def test_prng_raw_key_fires_in_serving(tmp_path):
    write_tree(tmp_path, {"src/repro/serving/mod.py": """\
        import jax

        def draw(seed, i):
            key = jax.random.split(jax.random.PRNGKey(seed))[0]
            return jax.random.fold_in(key, i)
        """})
    res = lint(tmp_path)
    assert rules_of(res) == ["prng-raw-key"]
    assert len(res["new"]) == 3           # PRNGKey + split + fold_in


def test_prng_helper_definitions_and_keyed_draws_are_exempt(tmp_path):
    write_tree(tmp_path, {"src/repro/serving/sampler.py": """\
        import jax

        def root_key(seed):
            return jax.random.PRNGKey(seed)

        def request_key(rng0, req_id, position):
            return jax.random.fold_in(jax.random.fold_in(rng0, req_id),
                                      position)

        def sample(logits, rng0, req_id, pos):
            return jax.random.categorical(request_key(rng0, req_id, pos),
                                          logits)
        """})
    assert lint(tmp_path)["new"] == []


def test_prng_unkeyed_draw_fires_on_unregistered_helper(tmp_path):
    write_tree(tmp_path, {"src/repro/serving/mod.py": """\
        import jax

        def my_key(i):
            return i

        def draw(logits, i):
            return jax.random.categorical(my_key(i), logits)
        """})
    res = lint(tmp_path)
    assert rules_of(res) == ["prng-unkeyed-draw"]


def test_prng_pass_ignores_non_serving_code(tmp_path):
    write_tree(tmp_path, {"src/repro/launch/mod.py": """\
        import jax

        def init(seed):
            return jax.random.PRNGKey(seed)
        """})
    assert lint(tmp_path)["new"] == []


# ---------------------------------------------------------------------------
# refcount-pairing
# ---------------------------------------------------------------------------

def test_refcount_leak_on_raise_fires(tmp_path):
    write_tree(tmp_path, {"src/repro/kvcache/paged.py": """\
        class Pool:
            def admit(self, n):
                pids = [self._alloc_raw(16) for _ in range(n)]
                if n > self.capacity:
                    raise OutOfPages(n)
                return pids
        """})
    res = lint(tmp_path)
    assert rules_of(res) == ["refcount-leak-on-raise"]


def test_refcount_undo_loop_and_early_raise_are_compliant(tmp_path):
    write_tree(tmp_path, {"src/repro/kvcache/paged.py": """\
        class Pool:
            def admit_shared(self, n):
                if n > self.capacity:
                    raise OutOfPages(n)          # before any acquire
                taken = []
                for pid in range(n):
                    self._incref(pid)
                    taken.append(pid)
                if self.broken:
                    for pid in taken:            # the undo loop
                        self._decref(pid)
                    raise OutOfPages(n)
                return taken

            def admit_guarded(self, n):
                pid = self._alloc_raw(16)
                try:
                    self.commit(pid)
                finally:
                    if not self.committed:
                        self._decref(pid)
                return pid

            def admit_unchecked(self, n):
                pid = self._alloc_raw(16)
                if self.late_check:
                    # caller releases on this exception (documented)
                    raise RuntimeError(n)  # lint: disable=refcount-leak-on-raise
                return pid
        """})
    res = lint(tmp_path)
    assert res["new"] == [] and res["suppressed"] == 1


def test_refcount_cleanup_in_enclosing_try_is_compliant(tmp_path):
    write_tree(tmp_path, {"src/repro/kvcache/paged.py": """\
        class Pool:
            def fault(self, n):
                pid = self._alloc_raw(16)
                try:
                    if n > self.capacity:
                        raise OutOfPages(n)
                except OutOfPages:
                    self._decref(pid)
                    raise
                return pid
        """})
    assert lint(tmp_path)["new"] == []


# ---------------------------------------------------------------------------
# async-blocking
# ---------------------------------------------------------------------------

def test_async_blocking_call_fires(tmp_path):
    write_tree(tmp_path, {"src/repro/serving/async_engine.py": """\
        import time

        async def step(self):
            time.sleep(0.1)
            with open("/tmp/x") as fh:
                return fh.read()
        """})
    res = lint(tmp_path)
    assert rules_of(res) == ["async-blocking-call"]
    assert len(res["new"]) == 2           # time.sleep + open


def test_async_sync_step_without_cooperative_await_fires(tmp_path):
    write_tree(tmp_path, {"src/repro/serving/async_engine.py": """\
        async def drain(self):
            while self.pending:
                self.eng.step()
        """})
    res = lint(tmp_path)
    assert rules_of(res) == ["async-sync-step"]


def test_async_cooperative_step_loop_is_compliant(tmp_path):
    # the AsyncServingFrontend pattern: sync step + sleep(0) yield
    write_tree(tmp_path, {"src/repro/serving/async_engine.py": """\
        import asyncio

        async def step(self):
            for eng in self.engines:
                eng.step()
                await asyncio.sleep(0)

        async def drain(self):
            while await self.step():
                pass
        """})
    assert lint(tmp_path)["new"] == []


# ---------------------------------------------------------------------------
# suppressions / baseline / cache / CLI
# ---------------------------------------------------------------------------

BAD_SERVING = {"src/repro/serving/mod.py": """\
    import jax

    def init(seed):
        return jax.random.PRNGKey(seed)
    """}


def test_inline_suppression_round_trip(tmp_path):
    write_tree(tmp_path, {"src/repro/serving/mod.py": """\
        import jax

        def init(seed):
            return jax.random.PRNGKey(seed)  # lint: disable=prng-raw-key

        def init2(seed):
            return jax.random.PRNGKey(seed)  # lint: disable=all
        """})
    res = lint(tmp_path)
    assert res["new"] == [] and res["suppressed"] == 2


def test_suppression_of_other_rule_does_not_hide(tmp_path):
    write_tree(tmp_path, {"src/repro/serving/mod.py": """\
        import jax

        def init(seed):
            return jax.random.PRNGKey(seed)  # lint: disable=jit-host-sync
        """})
    res = lint(tmp_path)
    assert rules_of(res) == ["prng-raw-key"] and res["suppressed"] == 0


def test_baseline_round_trip(tmp_path):
    write_tree(tmp_path, BAD_SERVING)
    baseline = str(tmp_path / "baseline.json")
    first = lint(tmp_path, baseline_path=baseline)
    assert len(first["new"]) == 1
    write_baseline(first, baseline)
    second = lint(tmp_path, baseline_path=baseline)
    assert second["new"] == []
    assert [f.baselined for f in second["findings"]] == [True]
    # a *new* violation still surfaces through the baseline
    write_tree(tmp_path, {"src/repro/serving/other.py": """\
        import jax

        def more(seed):
            return jax.random.PRNGKey(seed)
        """})
    third = lint(tmp_path, baseline_path=baseline)
    assert len(third["new"]) == 1
    assert third["new"][0].path == "src/repro/serving/other.py"


def test_cache_round_trip_and_invalidation(tmp_path):
    write_tree(tmp_path, BAD_SERVING)
    warm = run_lint(str(tmp_path), use_cache=True)
    assert len(warm["new"]) == 1
    assert os.path.exists(tmp_path / ".lint_cache.json")
    cached = run_lint(str(tmp_path), use_cache=True)
    assert [f.fingerprint() for f in cached["new"]] == \
           [f.fingerprint() for f in warm["new"]]
    # editing the file invalidates its entry: the fix is picked up
    write_tree(tmp_path, {"src/repro/serving/mod.py": """\
        def init(seed):
            return seed
        """})
    fixed = run_lint(str(tmp_path), use_cache=True)
    assert fixed["new"] == []


def test_select_and_skip(tmp_path):
    write_tree(tmp_path, BAD_SERVING)
    assert rules_of(lint(tmp_path, select=["prng-discipline"])) == \
        ["prng-raw-key"]
    assert lint(tmp_path, select=["refcount-pairing"])["new"] == []
    assert lint(tmp_path, skip=["prng-discipline"])["new"] == []


def test_cli_exit_codes_and_json_report(tmp_path, capsys):
    write_tree(tmp_path, BAD_SERVING)
    out = str(tmp_path / "report.json")
    rc = lint_main(["--root", str(tmp_path), "--check", "--no-cache",
                    "--json-out", out])
    assert rc == 1
    report = json.load(open(out))
    assert report["new"] == 1
    assert report["findings"][0]["rule"] == "prng-raw-key"
    rc = lint_main(["--root", str(tmp_path), "--check", "--no-cache",
                    "--skip", "prng-discipline"])
    assert rc == 0
    capsys.readouterr()


def test_parse_error_is_reported_not_crashed(tmp_path):
    write_tree(tmp_path, {"src/bad.py": "def broken(:\n"})
    res = lint(tmp_path)
    assert rules_of(res) == ["parse-error"]


# ---------------------------------------------------------------------------
# live tree
# ---------------------------------------------------------------------------

def test_registry_has_the_documented_passes():
    assert {"jit-discipline", "prng-discipline", "refcount-pairing",
            "async-blocking", "surface-docs",
            "surface-metrics"} <= set(PASSES)


def test_live_tree_is_clean():
    # the CI gate: no new findings on this repo (surface passes run in
    # their own jobs/tests and need a working jax install; the AST
    # passes are the ones this check pins)
    res = run_lint(REPO, use_cache=False,
                   skip=["surface-docs", "surface-metrics"])
    assert res["new"] == [], "\n".join(f.format() for f in res["new"])


def test_live_baseline_is_empty_for_serving_and_kvcache():
    with open(os.path.join(REPO, "tools", "lint", "baseline.json")) as fh:
        entries = json.load(fh)["findings"]
    offenders = [e for e in entries
                 if e["path"].startswith(("src/repro/serving/",
                                          "src/repro/kvcache/"))]
    assert offenders == []
