"""Trip-count-aware HLO cost analysis vs hand-computable programs."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.analysis.hlo_cost import analyze, parse_module


def _hlo(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_plain_matmul_flops():
    M = K = N = 128
    x = jax.ShapeDtypeStruct((M, K), jnp.float32)
    y = jax.ShapeDtypeStruct((K, N), jnp.float32)
    c = analyze(_hlo(lambda a, b: a @ b, x, y))
    np.testing.assert_allclose(c["flops"], 2 * M * K * N, rtol=0.05)


def test_scanned_matmul_scales_by_trip_count():
    """The whole point: a scan of T matmuls must cost T x one matmul."""
    T, M = 10, 64
    x = jax.ShapeDtypeStruct((M, M), jnp.float32)
    w = jax.ShapeDtypeStruct((T, M, M), jnp.float32)

    def fn(x, ws):
        def body(h, w):
            return h @ w, None
        h, _ = jax.lax.scan(body, x, ws)
        return h

    c = analyze(_hlo(fn, x, w))
    want = T * 2 * M ** 3
    assert want * 0.9 <= c["flops"] <= want * 1.3, (c["flops"], want)
    # XLA's own analysis undercounts by ~T (regression guard for why this
    # module exists)
    xla = jax.jit(fn).lower(x, w).compile().cost_analysis()
    if isinstance(xla, (list, tuple)):   # older jax returns [dict]
        xla = xla[0]
    assert float(xla["flops"]) < 0.5 * want


def test_nested_loops_multiply():
    M, T_out, T_in = 32, 4, 6
    x = jax.ShapeDtypeStruct((M, M), jnp.float32)

    def fn(x):
        def outer(h, _):
            def inner(h2, _):
                return h2 @ h2, None
            h, _ = jax.lax.scan(inner, h, None, length=T_in)
            return h, None
        h, _ = jax.lax.scan(outer, x, None, length=T_out)
        return h

    c = analyze(_hlo(fn, x))
    want = T_out * T_in * 2 * M ** 3
    assert want * 0.9 <= c["flops"] <= want * 1.3, (c["flops"], want)


def test_collectives_scaled_by_loops():
    """A psum inside a scan counts trip x wire bytes (1-device degenerate
    meshes elide collectives, so parse a synthetic module instead)."""
    HLO = """
HloModule m
%cond (p: (s32[], f32[256])) -> pred[] {
  %p = (s32[], f32[256]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(7)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}
%body (p: (s32[], f32[256])) -> (s32[], f32[256]) {
  %p = (s32[], f32[256]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[256]{0} get-tuple-element(%p), index=1
  %ar = f32[256]{0} all-reduce(%x), replica_groups={{0,1}}, to_apply=%sum
  %one = s32[] constant(1)
  %i2 = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[256]) tuple(%i2, %ar)
}
ENTRY %main (a: f32[256]) -> f32[256] {
  %a = f32[256]{0} parameter(0)
  %z = s32[] constant(0)
  %t0 = (s32[], f32[256]) tuple(%z, %a)
  %w = (s32[], f32[256]) while(%t0), condition=%cond, body=%body
  ROOT %out = f32[256]{0} get-tuple-element(%w), index=1
}
"""
    c = analyze(HLO)
    want = 7 * 256 * 4 * 2.0  # trips x bytes x all-reduce factor
    np.testing.assert_allclose(c["coll"]["all-reduce"], want)
    np.testing.assert_allclose(c["coll"]["total"], want)


def test_parse_module_structure():
    x = jax.ShapeDtypeStruct((8, 8), jnp.float32)
    comps = parse_module(_hlo(lambda a: jnp.tanh(a @ a), x))
    entry = [c for c in comps.values() if c.is_entry]
    assert len(entry) == 1
    assert len(entry[0].order) >= 2


def test_bytes_reasonable_for_streaming_op():
    """bytes ~ inputs + outputs for a simple fused elementwise chain."""
    n = 1 << 20
    x = jax.ShapeDtypeStruct((n,), jnp.float32)
    c = analyze(_hlo(lambda a: jnp.tanh(a) * 2.0 + 1.0, x))
    want = 2 * n * 4  # read + write
    assert want * 0.5 <= c["bytes"] <= want * 3, (c["bytes"], want)
