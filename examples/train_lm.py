"""End-to-end training example: a ~100M-parameter LM for a few hundred steps.

Uses the full production stack: config system, synthetic data pipeline,
AdamW + cosine schedule, remat, async checkpointing, straggler monitor —
everything ``repro.launch.train`` provides, at a size a CPU can actually
train.  The loss falling from ~log(V) proves the whole substrate works.

Usage:  PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse
import sys

from dataclasses import replace

from repro.configs import get
from repro.configs.registry import _REGISTRY
from repro.launch import train as T


def make_100m():
    """A ~100M-param dense LM (qwen3-family shape, scaled down)."""
    base = get("qwen3-8b")
    cfg = replace(
        base, name="qwen3-100m", n_layers=8, d_model=512, n_heads=8,
        n_kv_heads=4, head_dim=64, d_ff=2048, vocab_size=8192,
        dtype="float32",
    )
    _REGISTRY[cfg.name] = cfg
    return cfg


def main():
    import tempfile
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--ckpt-dir", default=None,
                    help="default: a fresh tmp dir (a pre-existing dir "
                         "triggers auto-resume, which is launch/train.py's "
                         "job — this example shows a from-scratch run)")
    args = ap.parse_args()
    if args.ckpt_dir is None:
        args.ckpt_dir = tempfile.mkdtemp(prefix="repro_train_lm_")

    cfg = make_100m()
    n = cfg.param_count()
    print(f"=== training {cfg.name}: {n / 1e6:.0f}M params, "
          f"{args.steps} steps ===")
    losses = T.main([
        "--arch", cfg.name, "--steps", str(args.steps),
        "--batch", str(args.batch), "--seq-len", str(args.seq_len),
        "--lr", "1e-3", "--save-every", "100", "--log-every", "20",
        "--ckpt-dir", args.ckpt_dir,
    ])
    k = max(len(losses) // 10, 1)
    import numpy as np
    first, last = np.mean(losses[:k]), np.mean(losses[-k:])
    assert last < first - 0.5, (first, last)
    print(f"loss fell {first:.3f} -> {last:.3f}: training works ✓")


if __name__ == "__main__":
    main()
