"""Checkpoint-compression example: ECF8 on the fault-tolerance path.

Saves an fp8 model checkpoint twice — raw and ECF8-compressed — then
restores the compressed one and proves bit-exactness, reporting the size
difference.  At 1000-node scale, restore bandwidth gates MTTR; the paper's
compression ratio applies directly to restart time.

Usage:  PYTHONPATH=src python examples/compress_checkpoint.py
"""
import os
import tempfile

import numpy as np
import jax

from repro.checkpoint import restore_tree, save_tree
from repro.configs import get, smoke_variant
from repro.core import stats
from repro.core.store import fp8_cast_tree
from repro.models import model as M
import jax.numpy as jnp


def dir_bytes(d):
    return sum(os.path.getsize(os.path.join(r, f))
               for r, _, fs in os.walk(d) for f in fs)


def main():
    # a ~20M-param variant: big enough that per-tensor coding overheads
    # (codebooks, lane padding) are amortized like in a real checkpoint
    from dataclasses import replace
    cfg = replace(smoke_variant(get("qwen3-8b")), name="qwen3-20m",
                  d_model=768, n_heads=8, n_kv_heads=4, head_dim=96,
                  d_ff=2048, vocab_size=8192, n_layers=4)
    params = M.init_params(jax.random.PRNGKey(0), cfg)

    # give the weights the paper's trained-weight statistics (alpha-stable),
    # then cast to fp8 — the checkpoint the paper would compress
    def trained_like(path, x):
        if hasattr(x, "ndim") and x.ndim >= 2:
            bits = stats.synthesize_fp8_weights(
                x.shape, alpha=1.9, seed=abs(hash(str(path))) % 2**31)
            return jnp.asarray(bits).view(jnp.float8_e4m3fn)
        return x
    params = jax.tree_util.tree_map_with_path(trained_like, params)

    raw_dir = tempfile.mkdtemp(prefix="ckpt_raw_")
    ecf_dir = tempfile.mkdtemp(prefix="ckpt_ecf8_")
    save_tree(params, raw_dir, step=0, compress="none")
    save_tree(params, ecf_dir, step=0, compress="ecf8")
    rb, eb = dir_bytes(raw_dir), dir_bytes(ecf_dir)
    print(f"raw fp8 checkpoint : {rb / 1e6:.2f} MB")
    print(f"ECF8 checkpoint    : {eb / 1e6:.2f} MB "
          f"(savings {100 * (1 - eb / rb):.1f}%)")

    restored, step = restore_tree(ecf_dir, params)
    for (pa, a), (pb, b) in zip(
            jax.tree_util.tree_flatten_with_path(params)[0],
            jax.tree_util.tree_flatten_with_path(restored)[0]):
        np.testing.assert_array_equal(
            np.asarray(a).view(np.uint8), np.asarray(b).view(np.uint8))
    print("restore is bit-exact ✓")


if __name__ == "__main__":
    main()
