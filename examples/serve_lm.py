"""Serving demo: paged, ECF8-compressed KV cache under mixed-length load.

The paper's deployment story, cache edition.  Weights are entropy-coded
fp8 (decode-on-use in the jitted step); the KV cache is **paged**
(``repro.kvcache``): short requests hold only the pages they wrote, and
pages that fill up go cold and live entropy-coded — the same exponent
concentration the paper measures for weights holds for K/V activations
(Heilper & Singer 2025), so the cold pool is losslessly smaller.

The demo queues a mixed-length request stream through a small batch,
proves the paged+compressed path emits the exact tokens of the
monolithic baseline, and prints raw-vs-compressed cache bytes and
throughput.  Runs on CPU (interpret mode), no TPU required.

Usage:  PYTHONPATH=src python examples/serve_lm.py
"""
import time

import numpy as np
import jax

from repro.configs import get, smoke_variant
from repro.core.store import compress_tree
from repro.models import model as M
from repro.runtime.monitor import KVCacheMonitor
from repro.serving import EngineConfig, GenerationEngine, Request

MAX_BATCH, MAX_LEN, PAGE = 4, 96, 16


def make_requests(vocab_size: int, seed: int = 0):
    """A mixed-length stream: chatty short prompts next to long ones."""
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(10):
        plen = int(rng.integers(2, 40))
        new = int(rng.integers(4, 40))
        prompt = rng.integers(0, vocab_size, size=plen).tolist()
        reqs.append(Request(prompt=prompt, max_new_tokens=new))
    return reqs


def run_stream(params, cfg, reqs, **cache_kw):
    mon = KVCacheMonitor()
    eng = GenerationEngine(params, cfg, config=EngineConfig(max_batch=MAX_BATCH, max_len=MAX_LEN,
                           kv_monitor=mon, **cache_kw))
    for r in reqs:
        eng.submit(r)
    t0 = time.time()
    eng.run()
    dt = time.time() - t0
    n_tok = sum(len(r.out_tokens) for r in reqs)
    return [r.out_tokens for r in reqs], eng, mon, n_tok, dt


def main():
    cfg = smoke_variant(get("qwen3-8b"))
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    # the paper's weight story rides along: fp8 weights, entropy-coded,
    # decoded on use inside the jitted step (both streams serve them)
    params_c, rep = compress_tree(params, fmt="tpu", min_elems=4096,
                                  out_dtype="float32")
    print(f"== {cfg.name}: ECF8 weights "
          f"{rep['fp8_bytes'] / 1e6:.2f}MB fp8 -> "
          f"{rep['compressed_bytes'] / 1e6:.2f}MB | "
          f"{len(make_requests(cfg.vocab_size))} mixed-length requests, "
          f"batch {MAX_BATCH}, window {MAX_LEN}, page {PAGE}")

    base, _, _, _, _ = run_stream(params_c, cfg,
                                  make_requests(cfg.vocab_size),
                                  cache_mode="monolithic")
    toks, eng, mon, n_tok, dt = run_stream(
        params_c, cfg, make_requests(cfg.vocab_size), cache_mode="paged",
        page_size=PAGE, compress_cold=True)

    lossless = toks == base
    print(f"paged+compressed vs monolithic tokens: "
          f"{'IDENTICAL' if lossless else 'MISMATCH'}")

    s = mon.summary()
    print(f"{n_tok} tokens in {dt:.1f}s ({n_tok / max(dt, 1e-9):.1f} tok/s "
          f"host wall-clock, {eng.steps} decode steps, occupancy "
          f"{n_tok / max(eng.steps, 1):.2f})")
    print(f"cache bytes: monolithic {s['monolithic_bytes'] / 1e6:.3f}MB | "
          f"paged peak {s['peak_paged_bytes'] / 1e6:.3f}MB "
          f"({100 * (1 - s['paged_vs_monolithic']):.1f}% saved) | "
          f"peak pages in use {s['peak_pages_in_use']}")
    print(f"cold pages: raw-equivalent peak "
          f"{s['peak_raw_equiv_bytes'] / 1e6:.3f}MB, entropy-coded at "
          f"{s['cold_compression_ratio']:.3f}x raw bytes")
    if not lossless:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
