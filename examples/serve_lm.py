"""Serving example: batched generation with ECF8-compressed weights.

The paper's deployment story end-to-end: fp8 weights are entropy-coded,
the engine decodes them on use inside the jitted step, requests stream
through a continuously-batched decode loop, and the outputs are bit-exact
vs the uncompressed fp8 baseline.

Usage:  PYTHONPATH=src python examples/serve_lm.py
"""
from repro.launch import serve as S


def main():
    S.main([
        "--arch", "qwen3-8b", "--smoke", "--compress", "tpu",
        "--requests", "8", "--max-batch", "4", "--max-new", "12",
        "--max-len", "96", "--check-lossless",
    ])


if __name__ == "__main__":
    main()
