"""Quickstart: compress fp8 weights losslessly with ECF8 and verify.

Runs in ~30s on CPU:
  1. synthesize "trained-like" fp8 weights (alpha-stable law, paper §2.2.1);
  2. measure exponent entropy vs the paper's Theorem 2.1 bounds;
  3. compress with all three containers (paper-faithful / ECF8-TPU / ECF8-FR);
  4. verify bit-exact roundtrips and report the compression ratios.

Usage:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import fixedrate, fp8, paper_format, stats, theory, tpu_format

SHAPE = (1024, 1024)
ALPHA = 1.9


def main():
    print(f"=== ECF8 quickstart: {SHAPE} fp8 weights, alpha={ALPHA} ===\n")
    w_bits = stats.synthesize_fp8_weights(SHAPE, alpha=ALPHA, seed=0)

    # 1. exponent concentration (paper §2.1/§2.2)
    s = stats.summarize_tensor(w_bits)
    lo, hi = theory.exponent_entropy_bounds(ALPHA)
    print(f"exponent entropy  : {s['entropy_bits']:.3f} bits "
          f"(paper reports 2-3; Thm 2.1 bounds for alpha={ALPHA}: "
          f"[{lo:.2f}, {hi:.2f}])")
    print(f"fitted alpha      : {s['alpha_hat']:.2f}")
    print(f"compression limit : {theory.compression_limit_bits(2.0):.2f} "
          f"bits/weight (the paper's FP4.67 floor at alpha=2)\n")

    # 2. the three containers
    c_paper = paper_format.encode(w_bits)
    assert np.array_equal(paper_format.decode_sequential(c_paper), w_bits)
    assert np.array_equal(paper_format.decode_blockparallel(c_paper), w_bits)
    print(f"paper container   : {8 * c_paper.ratio:.3f} bits/weight "
          f"(lossless ✓, block-parallel decode ✓)")

    c_tpu = tpu_format.encode(w_bits)
    assert np.array_equal(tpu_format.decode_ref(c_tpu).reshape(-1),
                          w_bits.reshape(-1))
    assert np.array_equal(np.asarray(tpu_format.decode_jnp(c_tpu)),
                          w_bits.reshape(-1))
    print(f"ECF8-TPU (ragged) : {8 * c_tpu.ratio('ragged'):.3f} bits/weight "
          f"(lossless ✓, vectorized decode ✓)")
    print(f"ECF8-TPU (uniform): {8 * c_tpu.ratio('uniform'):.3f} bits/weight")

    c_fr = fixedrate.encode(w_bits)
    assert np.array_equal(fixedrate.decode_ref(c_fr), w_bits)
    print(f"ECF8-FR           : {8 * c_fr.ratio:.3f} bits/weight "
          f"(lossless ✓, static-shape encode+decode ✓, "
          f"escape rate {c_fr.esc_count / c_fr.n_elem:.2%})")

    ideal = s["entropy_bits"] + 1 + 3  # H(E) + sign + mantissa
    print(f"\nentropy-coding floor for this tensor: {ideal:.3f} bits/weight "
          f"(H(E) + 4-bit sign/mantissa)")
    print(f"memory saving vs fp8: paper {100 * (1 - c_paper.ratio):.1f}%  "
          f"tpu {100 * (1 - c_tpu.ratio('ragged')):.1f}%  "
          f"fr {100 * (1 - c_fr.ratio):.1f}%  "
          f"(paper Table 1 band: 9.8-26.9%)")


if __name__ == "__main__":
    main()
